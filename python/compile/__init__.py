"""Build-time compile path (L1 Pallas kernels + L2 jax graphs + AOT).
Never imported on the request path — rust loads the HLO artifacts."""
