"""Separable convolution (paper benchmark 1): 5-tap row and column passes
as Pallas kernels, parameterized by the TPU-adapted tuning axes."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import KernelConfig, effective_block_h, pad2d, interpret_call

TAPS = 5
HALO = TAPS // 2


def _row_kernel(cfg: KernelConfig, w: int, bh: int):
    """out[y, x] = sum_t in[y, x + t - 2] * f[t] (input pre-padded in x)."""

    def kernel(xp_ref, f_ref, o_ref):
        i = pl.program_id(0)
        rows = pl.dslice(i * bh, bh)
        if cfg.stage:
            # Stage the halo'd tile once (VMEM analogue of local memory).
            tile = xp_ref[rows, pl.dslice(0, w + 2 * HALO)]
            if cfg.unroll:
                acc = jnp.zeros((bh, w), jnp.float32)
                for t in range(TAPS):
                    acc = acc + jax.lax.dynamic_slice(
                        tile, (0, t), (bh, w)
                    ) * f_ref[t]
            else:
                def body(t, acc):
                    return acc + jax.lax.dynamic_slice(
                        tile, (0, t), (bh, w)
                    ) * f_ref[t]

                acc = jax.lax.fori_loop(
                    0, TAPS, body, jnp.zeros((bh, w), jnp.float32)
                )
        else:
            # One strided load per tap (no staging).
            if cfg.unroll:
                acc = jnp.zeros((bh, w), jnp.float32)
                for t in range(TAPS):
                    acc = acc + xp_ref[rows, pl.dslice(t, w)] * f_ref[t]
            else:
                def body(t, acc):
                    return acc + xp_ref[rows, pl.dslice(t, w)] * f_ref[t]

                acc = jax.lax.fori_loop(
                    0, TAPS, body, jnp.zeros((bh, w), jnp.float32)
                )
        o_ref[rows, :] = acc

    return kernel


def conv_row(x, f, cfg: KernelConfig = KernelConfig(), boundary=0.0):
    """5-tap row convolution (along x/width). ``boundary``: "clamped" or a
    constant value (paper: constant 0 for the separable benchmark)."""
    h, w = x.shape
    bh = effective_block_h(h, cfg.block_h)
    xp = pad2d(x.astype(jnp.float32), 0, 0, HALO, HALO, boundary)
    call = interpret_call(
        _row_kernel(cfg, w, bh),
        grid=(h // bh,),
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        num_inputs=2,
    )
    return call(xp, f.astype(jnp.float32))


def _col_kernel(cfg: KernelConfig, w: int, bh: int):
    """out[y, x] = sum_t in[y + t - 2, x] * f[t] (input pre-padded in y)."""

    def kernel(xp_ref, f_ref, o_ref):
        i = pl.program_id(0)
        if cfg.stage:
            tile = xp_ref[pl.dslice(i * bh, bh + 2 * HALO), pl.dslice(0, w)]
            if cfg.unroll:
                acc = jnp.zeros((bh, w), jnp.float32)
                for t in range(TAPS):
                    acc = acc + jax.lax.dynamic_slice(
                        tile, (t, 0), (bh, w)
                    ) * f_ref[t]
            else:
                def body(t, acc):
                    return acc + jax.lax.dynamic_slice(
                        tile, (t, 0), (bh, w)
                    ) * f_ref[t]

                acc = jax.lax.fori_loop(
                    0, TAPS, body, jnp.zeros((bh, w), jnp.float32)
                )
        else:
            if cfg.unroll:
                acc = jnp.zeros((bh, w), jnp.float32)
                for t in range(TAPS):
                    acc = acc + xp_ref[pl.dslice(i * bh + t, bh), pl.dslice(0, w)] * f_ref[t]
            else:
                def body(t, acc):
                    return (
                        acc
                        + xp_ref[pl.dslice(i * bh + t, bh), pl.dslice(0, w)] * f_ref[t]
                    )

                acc = jax.lax.fori_loop(
                    0, TAPS, body, jnp.zeros((bh, w), jnp.float32)
                )
        o_ref[pl.dslice(i * bh, bh), :] = acc

    return kernel


def conv_col(x, f, cfg: KernelConfig = KernelConfig(), boundary=0.0):
    """5-tap column convolution (along y/height)."""
    h, w = x.shape
    bh = effective_block_h(h, cfg.block_h)
    xp = pad2d(x.astype(jnp.float32), HALO, HALO, 0, 0, boundary)
    call = interpret_call(
        _col_kernel(cfg, w, bh),
        grid=(h // bh,),
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        num_inputs=2,
    )
    return call(xp, f.astype(jnp.float32))
