"""Harris corner response over a 2x2 block (second stage of the paper's
Harris benchmark), k = 0.04, clamped boundary on the gradient images."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import KernelConfig, effective_block_h, pad2d

#: Window extent (paper: "a block size of 2x2", offsets 0..1).
B = 2
HARRIS_K = 0.04


def _kernel(cfg: KernelConfig, w: int, bh: int):
    def kernel(dxp_ref, dyp_ref, o_ref):
        i = pl.program_id(0)
        # Tiles with bottom/right halo of 1 (window offsets are 0..1).
        tx = dxp_ref[pl.dslice(i * bh, bh + B - 1), pl.dslice(0, w + B - 1)]
        ty = dyp_ref[pl.dslice(i * bh, bh + B - 1), pl.dslice(0, w + B - 1)]

        sxx = jnp.zeros((bh, w), jnp.float32)
        syy = jnp.zeros((bh, w), jnp.float32)
        sxy = jnp.zeros((bh, w), jnp.float32)
        if cfg.unroll:
            for dy in range(B):
                for dx in range(B):
                    gx = jax.lax.dynamic_slice(tx, (dy, dx), (bh, w))
                    gy = jax.lax.dynamic_slice(ty, (dy, dx), (bh, w))
                    sxx = sxx + gx * gx
                    syy = syy + gy * gy
                    sxy = sxy + gx * gy
        else:
            def body(t, carry):
                sxx, syy, sxy = carry
                dy, dx = t // B, t % B
                gx = jax.lax.dynamic_slice(tx, (dy, dx), (bh, w))
                gy = jax.lax.dynamic_slice(ty, (dy, dx), (bh, w))
                return (sxx + gx * gx, syy + gy * gy, sxy + gx * gy)

            sxx, syy, sxy = jax.lax.fori_loop(0, B * B, body, (sxx, syy, sxy))

        trace = sxx + syy
        o_ref[pl.dslice(i * bh, bh), :] = (
            sxx * syy - sxy * sxy - HARRIS_K * trace * trace
        )

    return kernel


def harris(dx, dy, cfg: KernelConfig = KernelConfig(), boundary="clamped"):
    """Harris response image from gradient images (ImageCL `harris`)."""
    h, w = dx.shape
    bh = effective_block_h(h, cfg.block_h)
    dxp = pad2d(dx.astype(jnp.float32), 0, B - 1, 0, B - 1, boundary)
    dyp = pad2d(dy.astype(jnp.float32), 0, B - 1, 0, B - 1, boundary)
    call = pl.pallas_call(
        _kernel(cfg, w, bh),
        grid=(h // bh,),
        in_specs=[pl.no_block_spec, pl.no_block_spec],
        out_specs=pl.no_block_spec,
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        interpret=True,
    )
    return call(dxp, dyp)
