"""Pure-jnp oracles for every Pallas kernel — the build-time correctness
signal (pytest compares kernels against these with assert_allclose)."""

import jax.numpy as jnp


def _pad(x, pads, boundary):
    if boundary == "clamped":
        return jnp.pad(x, pads, mode="edge")
    return jnp.pad(x, pads, mode="constant", constant_values=boundary)


def conv_row(x, f, boundary=0.0):
    x = x.astype(jnp.float32)
    h, w = x.shape
    xp = _pad(x, ((0, 0), (2, 2)), boundary)
    return sum(xp[:, t : t + w] * f[t] for t in range(5))


def conv_col(x, f, boundary=0.0):
    x = x.astype(jnp.float32)
    h, w = x.shape
    xp = _pad(x, ((2, 2), (0, 0)), boundary)
    return sum(xp[t : t + h, :] * f[t] for t in range(5))


def sepconv(x, f, boundary=0.0):
    """Row pass then column pass (paper benchmark 1)."""
    return conv_col(conv_row(x, f, boundary), f, boundary)


def conv2d(x, f, boundary="clamped"):
    x = x.astype(jnp.float32)
    h, w = x.shape
    xp = _pad(x, ((2, 2), (2, 2)), boundary)
    acc = jnp.zeros((h, w), jnp.float32)
    for dy in range(5):
        for dx in range(5):
            acc = acc + xp[dy : dy + h, dx : dx + w] * f[dy * 5 + dx]
    return jnp.clip(acc, 0.0, 255.0).astype(jnp.uint8)


def sobel(x, boundary="clamped"):
    x = x.astype(jnp.float32)
    h, w = x.shape
    xp = _pad(x, ((1, 1), (1, 1)), boundary)

    def at(dy, dx):
        return xp[1 + dy : 1 + dy + h, 1 + dx : 1 + dx + w]

    gx = (
        at(-1, 1) + 2.0 * at(0, 1) + at(1, 1)
        - at(-1, -1) - 2.0 * at(0, -1) - at(1, -1)
    )
    gy = (
        at(1, -1) + 2.0 * at(1, 0) + at(1, 1)
        - at(-1, -1) - 2.0 * at(-1, 0) - at(-1, 1)
    )
    return gx, gy


def harris(dx, dy, boundary="clamped", k=0.04):
    dx = dx.astype(jnp.float32)
    dy = dy.astype(jnp.float32)
    h, w = dx.shape
    dxp = _pad(dx, ((0, 1), (0, 1)), boundary)
    dyp = _pad(dy, ((0, 1), (0, 1)), boundary)
    sxx = jnp.zeros((h, w), jnp.float32)
    syy = jnp.zeros((h, w), jnp.float32)
    sxy = jnp.zeros((h, w), jnp.float32)
    for oy in range(2):
        for ox in range(2):
            gx = dxp[oy : oy + h, ox : ox + w]
            gy = dyp[oy : oy + h, ox : ox + w]
            sxx = sxx + gx * gx
            syy = syy + gy * gy
            sxy = sxy + gx * gy
    tr = sxx + syy
    return sxx * syy - sxy * sxy - k * tr * tr


def harris_pipeline(x, boundary="clamped"):
    """Full Harris benchmark: sobel -> harris response."""
    gx, gy = sobel(x, boundary)
    return harris(gx, gy, boundary)
