"""Shared infrastructure for the Pallas kernels (Layer 1).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's OpenCL
tuning axes are re-thought for TPU-style execution:

* work-group size      -> ``block_h``: rows per program instance (the VMEM
                          tile is ``block_h x W``);
* thread coarsening    -> implicit: one program computes a whole tile;
* loop unrolling       -> ``unroll``: static Python tap loop (fully
                          unrolled at trace time) vs ``lax.fori_loop``;
* local memory staging -> ``stage``: load the halo'd input tile into one
                          VMEM value and slice it per tap, vs issuing one
                          strided load per tap;
* boundary conditions  -> realized as padding in the enclosing jax
                          function (L2), so every program sees in-range
                          data (the TPU analogue of the paper's boundary
                          code: resolved before the hot loop).

All kernels run with ``interpret=True``: real-TPU lowering emits Mosaic
custom calls the CPU PJRT client cannot execute (see /opt/xla-example).
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class KernelConfig:
    """One tuning-variant of a Pallas kernel."""

    block_h: int = 8
    #: Fully unroll the tap loop at trace time (True) or keep a fori_loop.
    unroll: bool = True
    #: Stage the halo'd tile once into a VMEM value, then slice statically.
    stage: bool = True

    def key(self) -> str:
        return f"bh={self.block_h} unroll={int(self.unroll)} stage={int(self.stage)}"

    @staticmethod
    def parse(s: str) -> "KernelConfig":
        kv = dict(tok.split("=", 1) for tok in s.split())
        return KernelConfig(
            block_h=int(kv.get("bh", 8)),
            unroll=bool(int(kv.get("unroll", 1))),
            stage=bool(int(kv.get("stage", 1))),
        )


#: The variant grid swept by AOT compilation and the benchmark harness.
DEFAULT_VARIANTS = tuple(
    KernelConfig(block_h=bh, unroll=u, stage=s)
    for bh in (8, 32)
    for u in (False, True)
    for s in (False, True)
)


def effective_block_h(h: int, requested: int) -> int:
    """Largest divisor of ``h`` that is <= requested (grid must tile)."""
    bh = min(requested, h)
    while h % bh:
        bh -= 1
    return bh


def pad2d(x, halo_top, halo_bottom, halo_left, halo_right, boundary):
    """Apply the ImageCL boundary condition as padding (L2-side).

    ``boundary``: "clamped" (edge replication) or a float constant.
    """
    pads = ((halo_top, halo_bottom), (halo_left, halo_right))
    if boundary == "clamped":
        return jnp.pad(x, pads, mode="edge")
    return jnp.pad(x, pads, mode="constant", constant_values=boundary)


def as_f32(x):
    return x.astype(jnp.float32)


def interpret_call(kernel, *, grid, out_shape, num_inputs):
    """``pallas_call`` with the conventions used by all our kernels:
    whole-array inputs (no BlockSpec — kernels slice explicitly) and
    interpret mode."""
    import jax.experimental.pallas as pl

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.no_block_spec] * num_inputs,
        out_specs=pl.no_block_spec,
        out_shape=out_shape,
        interpret=True,
    )


def vmem_bytes(shape, dtype=jnp.float32) -> int:
    """Estimated VMEM footprint of one tile (perf model input; see
    DESIGN.md §8 — interpret-mode wallclock is NOT a TPU proxy, structure
    is)."""
    n = 1
    for d in shape:
        n *= d
    return n * jnp.dtype(dtype).itemsize
