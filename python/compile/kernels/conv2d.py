"""Non-separable 5x5 convolution on uchar pixels (paper benchmark 2),
clamped boundary condition."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import KernelConfig, effective_block_h, pad2d, interpret_call

K = 5
HALO = K // 2


def _kernel(cfg: KernelConfig, w: int, bh: int):
    def kernel(xp_ref, f_ref, o_ref):
        i = pl.program_id(0)
        if cfg.stage:
            tile = xp_ref[
                pl.dslice(i * bh, bh + 2 * HALO), pl.dslice(0, w + 2 * HALO)
            ]
            if cfg.unroll:
                acc = jnp.zeros((bh, w), jnp.float32)
                for dy in range(K):
                    for dx in range(K):
                        acc = acc + jax.lax.dynamic_slice(
                            tile, (dy, dx), (bh, w)
                        ) * f_ref[dy * K + dx]
            else:
                def body(t, acc):
                    dy, dx = t // K, t % K
                    return acc + jax.lax.dynamic_slice(
                        tile, (dy, dx), (bh, w)
                    ) * f_ref[t]

                acc = jax.lax.fori_loop(
                    0, K * K, body, jnp.zeros((bh, w), jnp.float32)
                )
        else:
            if cfg.unroll:
                acc = jnp.zeros((bh, w), jnp.float32)
                for dy in range(K):
                    for dx in range(K):
                        acc = acc + xp_ref[
                            pl.dslice(i * bh + dy, bh), pl.dslice(dx, w)
                        ] * f_ref[dy * K + dx]
            else:
                def body(t, acc):
                    dy, dx = t // K, t % K
                    return acc + xp_ref[
                        pl.dslice(i * bh + dy, bh), pl.dslice(dx, w)
                    ] * f_ref[t]

                acc = jax.lax.fori_loop(
                    0, K * K, body, jnp.zeros((bh, w), jnp.float32)
                )
        # (uchar)(clamp(sum, 0, 255)) — same semantics as the ImageCL
        # kernel's store.
        o_ref[pl.dslice(i * bh, bh), :] = jnp.clip(acc, 0.0, 255.0).astype(
            jnp.uint8
        )

    return kernel


def conv2d(x, f, cfg: KernelConfig = KernelConfig(), boundary="clamped"):
    """5x5 convolution; ``x`` is uint8 (or float), output uint8.

    The filter is a runtime argument (paper §6: "they are only known at
    run time for the non-separable convolution").
    """
    h, w = x.shape
    bh = effective_block_h(h, cfg.block_h)
    xp = pad2d(x.astype(jnp.float32), HALO, HALO, HALO, HALO, boundary)
    call = interpret_call(
        _kernel(cfg, w, bh),
        grid=(h // bh,),
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.uint8),
        num_inputs=2,
    )
    return call(xp, f.astype(jnp.float32))
