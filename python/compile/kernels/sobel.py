"""Sobel gradient kernel (first stage of Harris corner detection),
clamped boundary, two outputs (dx, dy)."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import KernelConfig, effective_block_h, pad2d

HALO = 1


def _kernel(cfg: KernelConfig, w: int, bh: int):
    def kernel(xp_ref, dx_ref, dy_ref):
        i = pl.program_id(0)
        tile = xp_ref[pl.dslice(i * bh, bh + 2), pl.dslice(0, w + 2)]

        def at(dy, dx):
            return jax.lax.dynamic_slice(tile, (dy + 1, dx + 1), (bh, w))

        gx = (
            at(-1, 1) + 2.0 * at(0, 1) + at(1, 1)
            - at(-1, -1) - 2.0 * at(0, -1) - at(1, -1)
        )
        gy = (
            at(1, -1) + 2.0 * at(1, 0) + at(1, 1)
            - at(-1, -1) - 2.0 * at(-1, 0) - at(-1, 1)
        )
        rows = pl.dslice(i * bh, bh)
        dx_ref[rows, :] = gx
        dy_ref[rows, :] = gy

    return kernel


def sobel(x, cfg: KernelConfig = KernelConfig(), boundary="clamped"):
    """Returns (dx, dy) Sobel gradients, matching the ImageCL `sobel`
    kernel (3x3 operators, clamped boundary)."""
    h, w = x.shape
    bh = effective_block_h(h, cfg.block_h)
    xp = pad2d(x.astype(jnp.float32), HALO, HALO, HALO, HALO, boundary)
    out_shape = (
        jax.ShapeDtypeStruct((h, w), jnp.float32),
        jax.ShapeDtypeStruct((h, w), jnp.float32),
    )
    call = pl.pallas_call(
        _kernel(cfg, w, bh),
        grid=(h // bh,),
        in_specs=[pl.no_block_spec],
        out_specs=(pl.no_block_spec, pl.no_block_spec),
        out_shape=out_shape,
        interpret=True,
    )
    return call(xp)
