"""Layer 1: Pallas kernels for the paper's three benchmarks (DESIGN.md
§Hardware-Adaptation), plus pure-jnp oracles in :mod:`ref`."""

from .common import KernelConfig, DEFAULT_VARIANTS, effective_block_h, vmem_bytes
from .conv2d import conv2d
from .conv_sep import conv_col, conv_row
from .harris import harris
from .sobel import sobel

__all__ = [
    "KernelConfig",
    "DEFAULT_VARIANTS",
    "effective_block_h",
    "vmem_bytes",
    "conv2d",
    "conv_col",
    "conv_row",
    "harris",
    "sobel",
]
