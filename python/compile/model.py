"""Layer 2: the benchmark compute graphs, composing the L1 Pallas kernels.

These are the jax functions AOT-lowered to HLO text and executed from the
rust runtime (never from Python at run time). Each graph mirrors one of
the paper's benchmarks (§6)."""

import jax.numpy as jnp

from .kernels import KernelConfig, conv2d, conv_col, conv_row, harris, sobel


def sepconv_row_graph(x, f, cfg: KernelConfig = KernelConfig()):
    """Row pass of the separable convolution (constant-0 boundary)."""
    return conv_row(x, f, cfg, boundary=0.0)


def sepconv_col_graph(x, f, cfg: KernelConfig = KernelConfig()):
    """Column pass of the separable convolution."""
    return conv_col(x, f, cfg, boundary=0.0)


def sepconv_graph(x, f, cfg: KernelConfig = KernelConfig()):
    """Full separable convolution: row then column (paper benchmark 1)."""
    return conv_col(conv_row(x, f, cfg, boundary=0.0), f, cfg, boundary=0.0)


def conv2d_graph(x, f, cfg: KernelConfig = KernelConfig()):
    """Non-separable 5x5 convolution on uchar pixels, clamped boundary
    (paper benchmark 2)."""
    return conv2d(x, f, cfg, boundary="clamped")


def sobel_graph(x, cfg: KernelConfig = KernelConfig()):
    """Sobel gradients (Harris stage 1)."""
    return sobel(x, cfg, boundary="clamped")


def harris_graph(dx, dy, cfg: KernelConfig = KernelConfig()):
    """Harris response from gradients (Harris stage 2)."""
    return harris(dx, dy, cfg, boundary="clamped")


def harris_pipeline_graph(x, cfg: KernelConfig = KernelConfig()):
    """Full Harris corner benchmark: sobel -> harris (paper benchmark 3).

    Both stages lower into ONE XLA module, letting the compiler fuse the
    intermediate gradient images — the optimization the paper (§7) notes
    Halide wins with on the separable benchmark and ImageCL cannot
    express (no synchronization primitives). In the three-layer port we
    recover it at L2.
    """
    gx, gy = sobel(x, cfg, boundary="clamped")
    return harris(gx, gy, cfg, boundary="clamped")


def normalized_gauss5():
    """The 5-tap filter used by the benchmarks."""
    f = jnp.array([1.0, 4.0, 6.0, 4.0, 1.0], jnp.float32)
    return f / f.sum()


def normalized_gauss5x5():
    g = normalized_gauss5()
    return jnp.outer(g, g).reshape(25)
