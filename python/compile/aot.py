"""AOT compilation: lower every benchmark-graph variant to HLO text and
write the artifact manifest consumed by the rust runtime.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Usage: ``python -m compile.aot --out-dir ../artifacts`` (from python/).
Re-running is cheap and idempotent; the Makefile skips it when inputs are
unchanged.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import KernelConfig

#: Grid sizes compiled per graph: a small correctness size (used by rust
#: integration tests) and the bench size (scaled-down paper workload —
#: the full 4096²/8192² lower fine but bloat compile time ~20x for no
#: extra signal on a CPU testbed; EXPERIMENTS.md reports the scaling).
SMALL = 32
BENCH = 512

#: Kernel-config variants compiled per graph (TPU-adapted tuning axes).
VARIANTS = (
    KernelConfig(block_h=8, unroll=True, stage=True),
    KernelConfig(block_h=8, unroll=False, stage=False),
    KernelConfig(block_h=32, unroll=True, stage=True),
    KernelConfig(block_h=32, unroll=True, stage=False),
)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def graph_entries(n):
    """(graph_id, fn(cfg) -> (jit_fn, example_args)) for an n×n image."""
    img_f32 = spec((n, n), jnp.float32)
    img_u8 = spec((n, n), jnp.uint8)
    f5 = spec((5,), jnp.float32)
    f25 = spec((25,), jnp.float32)

    return [
        ("sepconv_row", lambda cfg: (lambda x, f: model.sepconv_row_graph(x, f, cfg), (img_f32, f5))),
        ("sepconv_col", lambda cfg: (lambda x, f: model.sepconv_col_graph(x, f, cfg), (img_f32, f5))),
        ("sepconv", lambda cfg: (lambda x, f: model.sepconv_graph(x, f, cfg), (img_f32, f5))),
        ("conv2d", lambda cfg: (lambda x, f: model.conv2d_graph(x, f, cfg), (img_u8, f25))),
        ("sobel", lambda cfg: (lambda x: model.sobel_graph(x, cfg), (img_f32,))),
        ("harris", lambda cfg: (lambda dx, dy: model.harris_graph(dx, dy, cfg), (img_f32, img_f32))),
        ("harris_pipeline", lambda cfg: (lambda x: model.harris_pipeline_graph(x, cfg), (img_f32,))),
    ]


def arg_sig(args):
    return ";".join(f"{a.shape[0]}x{a.shape[1] if len(a.shape) > 1 else 1}:{a.dtype}" for a in args)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sizes", default=f"{SMALL},{BENCH}")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_rows = []
    for n in [int(s) for s in args.sizes.split(",")]:
        for graph_id, make in graph_entries(n):
            for cfg in VARIANTS:
                fn, ex_args = make(cfg)
                lowered = jax.jit(fn).lower(*ex_args)
                hlo = to_hlo_text(lowered)
                art_id = f"{graph_id}_{n}_bh{cfg.block_h}u{int(cfg.unroll)}s{int(cfg.stage)}"
                fname = f"{art_id}.hlo.txt"
                with open(os.path.join(args.out_dir, fname), "w") as fh:
                    fh.write(hlo)
                manifest_rows.append(
                    "\t".join(
                        [
                            art_id,
                            graph_id,
                            str(n),
                            cfg.key(),
                            arg_sig(ex_args),
                            fname,
                        ]
                    )
                )
                print(f"  wrote {fname} ({len(hlo)} chars)")

    with open(os.path.join(args.out_dir, "manifest.tsv"), "w") as fh:
        fh.write("# artifact_id\tgraph\tgrid_n\tvariant\targs\tfile\n")
        fh.write("\n".join(manifest_rows) + "\n")
    print(f"manifest: {len(manifest_rows)} artifacts")


if __name__ == "__main__":
    main()
