"""L1 correctness: every Pallas kernel vs its pure-jnp oracle, swept over
shapes, variants and boundary conditions with hypothesis."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    DEFAULT_VARIANTS,
    KernelConfig,
    conv2d,
    conv_col,
    conv_row,
    harris,
    sobel,
)
from compile.kernels import ref

SETTINGS = dict(max_examples=12, deadline=None)


def rand_img(rng, h, w, dtype=np.float32):
    if dtype == np.uint8:
        return jnp.asarray(rng.integers(0, 256, (h, w), dtype=np.uint8))
    return jnp.asarray(rng.random((h, w), dtype=np.float32) * 255.0)


def rand_filter(rng, n):
    f = rng.random(n, dtype=np.float32)
    return jnp.asarray(f / f.sum())


shapes = st.tuples(st.integers(3, 40), st.integers(3, 40))
variants = st.sampled_from(DEFAULT_VARIANTS)
boundaries = st.sampled_from(["clamped", 0.0, 7.5])
seeds = st.integers(0, 2**31 - 1)


@settings(**SETTINGS)
@given(shapes, variants, boundaries, seeds)
def test_conv_row_matches_ref(shape, cfg, boundary, seed):
    rng = np.random.default_rng(seed)
    x = rand_img(rng, *shape)
    f = rand_filter(rng, 5)
    got = conv_row(x, f, cfg, boundary)
    want = ref.conv_row(x, f, boundary)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


@settings(**SETTINGS)
@given(shapes, variants, boundaries, seeds)
def test_conv_col_matches_ref(shape, cfg, boundary, seed):
    rng = np.random.default_rng(seed)
    x = rand_img(rng, *shape)
    f = rand_filter(rng, 5)
    got = conv_col(x, f, cfg, boundary)
    want = ref.conv_col(x, f, boundary)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


@settings(**SETTINGS)
@given(shapes, variants, st.sampled_from(["clamped", 0.0]), seeds)
def test_conv2d_matches_ref(shape, cfg, boundary, seed):
    rng = np.random.default_rng(seed)
    x = rand_img(rng, *shape, dtype=np.uint8)
    f = rand_filter(rng, 25)
    got = conv2d(x, f, cfg, boundary)
    want = ref.conv2d(x, f, boundary)
    assert got.dtype == jnp.uint8
    # uint8 output: float rounding at the truncation edge may differ by 1.
    diff = np.abs(got.astype(np.int32) - want.astype(np.int32))
    assert diff.max() <= 1


@settings(**SETTINGS)
@given(shapes, variants, seeds)
def test_sobel_matches_ref(shape, cfg, seed):
    rng = np.random.default_rng(seed)
    x = rand_img(rng, *shape)
    gx, gy = sobel(x, cfg)
    rx, ry = ref.sobel(x)
    np.testing.assert_allclose(gx, rx, rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(gy, ry, rtol=1e-5, atol=1e-3)


@settings(**SETTINGS)
@given(shapes, variants, seeds)
def test_harris_matches_ref(shape, cfg, seed):
    rng = np.random.default_rng(seed)
    dx = rand_img(rng, *shape)
    dy = rand_img(rng, *shape)
    got = harris(dx, dy, cfg)
    want = ref.harris(dx, dy)
    # det - k*tr^2 suffers catastrophic f32 cancellation; tolerance must
    # be relative to the magnitude of the cancelled terms, not the result.
    scale = float(np.abs(np.asarray(want)).max()) + 1.0
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=1e-4 * scale)


@pytest.mark.parametrize("cfg", DEFAULT_VARIANTS, ids=lambda c: c.key())
def test_variants_consistent(cfg):
    """All kernel variants must agree with the bh=1 variant — the Pallas
    analogue of the rust config sweep. Unrolled variants are bit-exact;
    fori_loop variants may differ by 1 ulp (XLA contracts mul+add into FMA
    differently inside the loop body)."""
    rng = np.random.default_rng(11)
    x = rand_img(rng, 24, 17)
    f = rand_filter(rng, 5)
    base = np.asarray(conv_row(x, f, KernelConfig(block_h=1), 0.0))
    got = np.asarray(conv_row(x, f, cfg, 0.0))
    if cfg.unroll:
        np.testing.assert_array_equal(base, got)
    else:
        np.testing.assert_allclose(base, got, rtol=3e-7, atol=1e-4)


def test_non_divisible_block_h_shrinks():
    from compile.kernels import effective_block_h

    assert effective_block_h(30, 8) == 6
    assert effective_block_h(31, 8) == 1
    assert effective_block_h(32, 8) == 8
    assert effective_block_h(4, 64) == 4


def test_sobel_flat_zero():
    x = jnp.full((16, 16), 9.0, jnp.float32)
    gx, gy = sobel(x)
    assert float(jnp.abs(gx).max()) == 0.0
    assert float(jnp.abs(gy).max()) == 0.0


def test_conv2d_saturates():
    x = jnp.full((8, 8), 255, jnp.uint8)
    f = jnp.full((25,), 1.0, jnp.float32)  # gain 25 -> saturate at 255
    out = conv2d(x, f)
    assert int(out.min()) == 255
