"""L2 correctness: benchmark graphs vs oracles, and AOT lowering sanity
(HLO text round-trips through the xla_extension parser contract)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.aot import to_hlo_text
from compile.kernels import KernelConfig, ref


def rand(h, w, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.random((h, w), dtype=np.float32) * 10.0)


def test_sepconv_graph_matches_ref():
    x = rand(33, 21)
    f = model.normalized_gauss5()
    got = model.sepconv_graph(x, f)
    want = ref.sepconv(x, f)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_harris_pipeline_matches_ref():
    x = rand(40, 28, seed=3)
    got = model.harris_pipeline_graph(x)
    want = ref.harris_pipeline(x)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-1)


def test_harris_detects_synthetic_corner():
    # A bright square on dark background: strongest response near corners.
    x = jnp.zeros((32, 32), jnp.float32).at[8:24, 8:24].set(100.0)
    r = np.asarray(model.harris_pipeline_graph(x))
    # Response at the square's corner must exceed the response at the
    # middle of an edge and in flat regions.
    corner = np.abs(r[7:10, 7:10]).max()
    edge_mid = np.abs(r[15:17, 7:9]).max()
    flat = np.abs(r[0:4, 0:4]).max()
    assert corner > edge_mid
    assert flat == 0.0


def test_hlo_text_lowering():
    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    f = jax.ShapeDtypeStruct((5,), jnp.float32)
    lowered = jax.jit(
        lambda x, f: model.sepconv_graph(x, f, KernelConfig(block_h=8))
    ).lower(x, f)
    hlo = to_hlo_text(lowered)
    assert "HloModule" in hlo
    assert "ENTRY" in hlo
    # interpret=True means no Mosaic custom-calls — loadable on CPU PJRT.
    assert "tpu_custom_call" not in hlo


def test_variant_changes_structure_not_value():
    x = rand(32, 32, seed=5)
    f = model.normalized_gauss5()
    a = model.sepconv_graph(x, f, KernelConfig(block_h=8, stage=True))
    b = model.sepconv_graph(x, f, KernelConfig(block_h=32, stage=False, unroll=False))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
