//! Quickstart: write an ImageCL kernel, compile it under two different
//! tuning configurations, look at the generated OpenCL, and execute both
//! candidates to see that optimization never changes results.
//!
//! Run with: `cargo run --release --example quickstart`

use std::collections::BTreeMap;

use imagecl::analysis::KernelInfo;
use imagecl::exec::{execute, Arg, ImageBuf};
use imagecl::imagecl::{frontend, ScalarType};
use imagecl::transform::{emit_opencl, lower, TuningConfig};

/// The paper's Listing 1: a 3x3 box blur.
const BLUR: &str = r#"
#pragma imcl grid(in)
void blur(Image<float> in, Image<float> out) {
  float sum = 0.0f;
  for (int i = -1; i < 2; i++) {
    for (int j = -1; j < 2; j++) {
      sum += in[idx + i][idy + j];
    }
  }
  out[idx][idy] = sum / 9.0f;
}
"#;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. Frontend + analysis: what can be tuned here?
    let info = KernelInfo::analyze(frontend(BLUR)?);
    println!("kernel `{}`:", info.prog.kernel.name);
    println!("  read stencil of `in`: {:?}", info.read_stencil("in"));
    println!("  image-memory eligible: in={}, out={}",
        info.image_mem_eligible("in"), info.image_mem_eligible("out"));
    println!("  local-memory eligible: in={}", info.local_mem_eligible("in"));
    println!("  unrollable loops: {}\n", info.unrollable_loops().len());

    // 2. Two candidate implementations from the same source.
    let naive = TuningConfig::default();
    let tuned = TuningConfig::parse(
        "wg=8x8 px=2x2 map=interleaved lmem=in unroll=1:0,2:0",
    )?;
    for (name, cfg) in [("naive", &naive), ("tuned", &tuned)] {
        let plan = lower(&info, cfg)?;
        let cl = emit_opencl(&plan);
        println!("--- {name} ({cfg}) — {} lines of OpenCL ---", cl.lines().count());
        for line in cl.lines().take(6) {
            println!("{line}");
        }
        println!("...\n");
    }

    // 3. Execute both candidates under NDRange emulation: identical output.
    let (w, h) = (64, 48);
    let input = ImageBuf::from_fn(ScalarType::F32, w, h, |x, y| ((x * 3 + y * 7) % 32) as f64);
    let mut run = |cfg: &TuningConfig| -> Result<Vec<f64>, Box<dyn std::error::Error>> {
        let plan = lower(&info, cfg)?;
        let mut args = BTreeMap::new();
        args.insert("in".to_string(), Arg::Image(input.clone()));
        args.insert("out".to_string(), Arg::Image(ImageBuf::new(ScalarType::F32, w, h)));
        execute(&plan, &mut args, (w, h))?;
        match args.remove("out").unwrap() {
            Arg::Image(img) => Ok(img.buf.data),
            _ => unreachable!(),
        }
    };
    let a = run(&naive)?;
    let b = run(&tuned)?;
    let max_diff = a
        .iter()
        .zip(&b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f64, f64::max);
    println!("naive vs tuned max pixel difference: {max_diff:e}");
    assert!(max_diff < 1e-6, "candidates must agree");
    println!("quickstart OK");
    Ok(())
}
