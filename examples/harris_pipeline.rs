//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): the full three-layer system
//! on a real workload.
//!
//! A FAST-style Harris-corner pipeline (sobel → harris) processes a batch
//! of synthetic camera frames through the AOT Pallas/XLA artifacts on the
//! PJRT CPU client — Python never runs here. The driver:
//!
//!  1. real-execution-tunes the kernel variant (times every AOT variant,
//!     picks the fastest — the auto-tuner's CPU path),
//!  2. streams a batch of frames through the pipeline, reporting
//!     per-frame latency and throughput,
//!  3. validates the output against the scalar Rust reference,
//!  4. prints the simulated heterogeneous schedule FAST would use.
//!
//! Run with: `cargo run --release --example harris_pipeline [frames]`
//! (requires `make artifacts`).

use std::time::Instant;

use imagecl::bench_defs::{reference, synth_image};
use imagecl::devices::ALL_DEVICES;
use imagecl::exec::ImageBuf;
use imagecl::imagecl::ScalarType;
use imagecl::pipeline::{schedule, Pipeline, Port};
use imagecl::report::Ms;
use imagecl::runtime::{default_artifact_dir, Tensor, XlaRuntime};
use imagecl::transform::TuningConfig;

const N: usize = 512;

fn tensor_of(img: &ImageBuf) -> Tensor {
    Tensor::new(img.h, img.w, img.buf.data.iter().map(|&v| v as f32).collect())
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let frames: usize = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(50);
    let mut rt = XlaRuntime::new(&default_artifact_dir())?;
    println!("PJRT platform: {}", rt.platform());

    // -- 1. real-execution variant tuning (fused harris pipeline) --------
    let ids: Vec<String> = rt
        .manifest()
        .variants_of("harris_pipeline", N)
        .iter()
        .map(|a| (a.id.clone(), a.variant.clone()))
        .map(|(id, _)| id)
        .collect();
    let probe = synth_image(ScalarType::F32, N, N, 1);
    let probe_t = tensor_of(&probe);
    let mut best: Option<(String, f64)> = None;
    println!("\nvariant timings ({N}x{N}, best of 5):");
    for id in &ids {
        let (_, secs) = rt.time(id, &[&probe_t], 5)?;
        println!("  {:<36} {}", id, Ms::from(secs));
        if best.as_ref().map(|(_, b)| secs < *b).unwrap_or(true) {
            best = Some((id.clone(), secs));
        }
    }
    let (best_id, best_secs) = best.expect("no variants — run `make artifacts`");
    println!("selected: {best_id} ({})", Ms::from(best_secs));

    // -- 2. stream a batch of frames --------------------------------------
    let inputs: Vec<Tensor> = (0..frames)
        .map(|i| tensor_of(&synth_image(ScalarType::F32, N, N, 100 + i as u64)))
        .collect();
    let mut latencies = Vec::with_capacity(frames);
    let mut checksum = 0.0f64;
    let t_batch = Instant::now();
    for frame in &inputs {
        let t0 = Instant::now();
        let outs = rt.execute(&best_id, &[frame])?;
        latencies.push(t0.elapsed().as_secs_f64());
        checksum += outs[0].data[0] as f64;
    }
    let wall = t_batch.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mpix = (N * N * frames) as f64 / 1e6;
    println!(
        "\nbatch: {frames} frames of {N}x{N} in {:.3}s  ({:.1} frames/s, {:.1} Mpixel/s)",
        wall,
        frames as f64 / wall,
        mpix / wall
    );
    println!(
        "latency p50 {}  p90 {}  max {}   (checksum {checksum:.3})",
        Ms::from(latencies[frames / 2]),
        Ms::from(latencies[frames * 9 / 10]),
        Ms::from(*latencies.last().unwrap()),
    );

    // -- 3. validate one frame against the scalar reference ---------------
    let img = synth_image(ScalarType::F32, N, N, 100);
    let outs = rt.execute(&best_id, &[&tensor_of(&img)])?;
    let (dx, dy) = reference::sobel(&img);
    let mut dximg = ImageBuf::new(ScalarType::F32, N, N);
    let mut dyimg = ImageBuf::new(ScalarType::F32, N, N);
    for y in 0..N {
        for x in 0..N {
            dximg.set(x, y, dx[y * N + x]);
            dyimg.set(x, y, dy[y * N + x]);
        }
    }
    let want = reference::harris(&dximg, &dyimg);
    let scale = want.iter().fold(1.0f64, |m, v| m.max(v.abs()));
    let mut max_rel = 0.0f64;
    for i in 0..want.len() {
        max_rel = max_rel.max((outs[0].data[i] as f64 - want[i]).abs() / scale);
    }
    println!("\nvalidation vs scalar reference: max scaled error {max_rel:.2e}");
    assert!(max_rel < 1e-4, "numerics drifted");

    // -- 4. the heterogeneous schedule FAST would pick --------------------
    let mut p = Pipeline::new();
    let src = p.source("img", tensor_of(&img));
    let sob = p.filter("sobel", &[p.port(src)]);
    let har = p.filter(
        "harris",
        &[Port { node: sob, port: 0 }, Port { node: sob, port: 1 }],
    );
    p.output(p.port(har));
    let s = schedule(&p, &ALL_DEVICES, 5120, &TuningConfig::default());
    println!("\nsimulated FAST schedule at the paper's 5120x5120 size:");
    for pl in &s.placements {
        println!(
            "  {:<8} -> {:<9} (est {})",
            pl.filter,
            pl.device,
            Ms::from(pl.est_exec_s)
        );
    }
    println!("  makespan {}", Ms::from(s.makespan_s));
    println!("\nharris_pipeline OK");
    Ok(())
}
