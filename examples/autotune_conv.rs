//! Auto-tune the non-separable convolution for all four devices of the
//! paper's testbed and print the Table-3-style configuration column per
//! device, plus the speedup over the naive configuration — the paper's
//! performance-portability pitch in one run.
//!
//! Run with: `cargo run --release --example autotune_conv [grid-size]`

use imagecl::analysis::KernelInfo;
use imagecl::bench_defs::CONV2D;
use imagecl::devices::{predict, KernelModel, ALL_DEVICES};
use imagecl::imagecl::frontend;
use imagecl::report::{render_config_table, Ms};
use imagecl::transform::TuningConfig;
use imagecl::tuner::{tune_on_simulator, MlSearchOpts, Strategy};

fn main() {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(2048);
    let info = KernelInfo::analyze(frontend(CONV2D).unwrap());
    let strategy = Strategy::MlTwoPhase(MlSearchOpts::default());

    let mut columns = Vec::new();
    println!("tuning conv2d ({n}x{n} uchar, 5x5 filter, clamped boundary)\n");
    for dev in ALL_DEVICES {
        let res = tune_on_simulator(&info, dev, (n, n), &strategy);
        let naive = predict(
            dev,
            &KernelModel::build(&info, &TuningConfig::default()),
            n,
            n,
        );
        println!(
            "{:<10} {:<60} est {:>10}  speedup over naive {:>5.2}x  ({} candidates timed)",
            dev.name,
            res.best.to_string(),
            Ms::from(res.best_time).to_string(),
            naive.seconds / res.best_time,
            res.evals,
        );
        columns.push((dev.name.to_string(), res.best));
    }
    println!();
    println!(
        "{}",
        render_config_table(
            "Configurations found by the auto-tuner (cf. paper Table 3)",
            &info,
            &columns
        )
    );
}
