//! Candidate-equivalence sweep: for every benchmark kernel, every tuning
//! configuration must produce output identical (up to f32 rounding noise)
//! to the naive configuration — and the naive configuration must match
//! the direct Rust reference filters.
//!
//! This is the correctness backbone of the reproduction (DESIGN.md §2,
//! §6): it executes the *transformed* code under NDRange emulation, so any
//! bug in coarsening, mapping, staging, boundary handling or unrolling
//! corrupts pixels and fails here.

use std::collections::BTreeMap;

use imagecl::analysis::KernelInfo;
use imagecl::bench_defs::{self, reference, workload};
use imagecl::exec::{execute, Arg};
use imagecl::imagecl::frontend;
use imagecl::testutil::{check, Rng};
use imagecl::transform::{lower, TuningConfig};

/// Execute one kernel under a config, returning all written images.
fn run(
    kernel_id: &str,
    cfg: &TuningConfig,
    size: (usize, usize),
    seed: u64,
) -> BTreeMap<String, Vec<f64>> {
    let kdef = bench_defs::kernel_by_id(kernel_id).unwrap();
    let info = KernelInfo::analyze(frontend(kdef.source).unwrap());
    let plan = lower(&info, cfg)
        .unwrap_or_else(|e| panic!("{kernel_id} under {cfg}: {e}"));
    let mut args = workload(kernel_id, size.0, size.1, seed);
    execute(&plan, &mut args, size)
        .unwrap_or_else(|e| panic!("{kernel_id} under {cfg}: {e}"));
    args.into_iter()
        .filter_map(|(name, a)| match a {
            Arg::Image(img) => Some((name, img.buf.data)),
            _ => None,
        })
        .collect()
}

fn assert_images_eq(
    kernel_id: &str,
    cfg: &TuningConfig,
    got: &BTreeMap<String, Vec<f64>>,
    want: &BTreeMap<String, Vec<f64>>,
) {
    for (name, w) in want {
        let g = &got[name];
        assert_eq!(g.len(), w.len());
        for i in 0..w.len() {
            assert!(
                (g[i] - w[i]).abs() <= 1e-4,
                "{kernel_id} under `{cfg}`: image `{name}` differs at {i}: \
                 got {}, want {}",
                g[i],
                w[i]
            );
        }
    }
}

/// Draw a random *valid* config for a kernel (mirrors the tuner's space).
fn random_config(rng: &mut Rng, kernel_id: &str) -> TuningConfig {
    let kdef = bench_defs::kernel_by_id(kernel_id).unwrap();
    let info = KernelInfo::analyze(frontend(kdef.source).unwrap());
    let mut cfg = TuningConfig::default();
    cfg.wg = [
        *rng.pick(&[1usize, 2, 4, 8, 16]),
        *rng.pick(&[1usize, 2, 4, 8]),
    ];
    cfg.coarsen = [*rng.pick(&[1usize, 2, 3, 4]), *rng.pick(&[1usize, 2, 4])];
    cfg.interleaved = rng.flip();
    for p in &info.prog.kernel.params {
        let name = p.name.clone();
        if info.local_mem_eligible(&name) && rng.flip() {
            cfg.local_mem.insert(name.clone(), true);
        } else if info.image_mem_eligible(&name) && rng.flip() {
            cfg.image_mem.insert(name.clone(), true);
        }
        if info.constant_mem_eligible(&name, 64 << 10) && rng.flip() {
            cfg.constant_mem.insert(name.clone(), true);
        }
    }
    for l in info.unrollable_loops() {
        if rng.flip() {
            cfg.unroll.insert(l.id, *rng.pick(&[0usize, 2]));
        }
    }
    cfg
}

const KERNELS: [&str; 5] = ["sepconv_row", "sepconv_col", "conv2d", "sobel", "harris"];

#[test]
fn naive_config_matches_reference_filters() {
    let (w, h) = (33, 27);
    let seed = 42;

    // sepconv row/col
    for (kid, reff) in [
        ("sepconv_row", reference::sepconv_row as fn(&_, &[f64]) -> Vec<f64>),
        ("sepconv_col", reference::sepconv_col as fn(&_, &[f64]) -> Vec<f64>),
    ] {
        let input = bench_defs::synth_image(imagecl::imagecl::ScalarType::F32, w, h, seed);
        let want = reff(&input, &bench_defs::gauss5());
        let got = run(kid, &TuningConfig::default(), (w, h), seed);
        for (i, &v) in want.iter().enumerate() {
            assert!((got["out"][i] - v).abs() < 1e-4, "{kid} differs at {i}");
        }
    }

    // conv2d
    let input = bench_defs::synth_image(imagecl::imagecl::ScalarType::U8, w, h, seed);
    let want = reference::conv2d(&input, &bench_defs::gauss5x5());
    let got = run("conv2d", &TuningConfig::default(), (w, h), seed);
    for (i, &v) in want.iter().enumerate() {
        // uchar output: allow ±1 for float rounding at the truncation edge.
        assert!(
            (got["out"][i] - v).abs() <= 1.0,
            "conv2d differs at {i}: {} vs {v}",
            got["out"][i]
        );
    }

    // sobel
    let input = bench_defs::synth_image(imagecl::imagecl::ScalarType::F32, w, h, seed);
    let (dx, dy) = reference::sobel(&input);
    let got = run("sobel", &TuningConfig::default(), (w, h), seed);
    for i in 0..dx.len() {
        assert!((got["dx"][i] - dx[i]).abs() < 1e-3, "sobel dx differs at {i}");
        assert!((got["dy"][i] - dy[i]).abs() < 1e-3, "sobel dy differs at {i}");
    }

    // harris
    let dximg = bench_defs::synth_image(imagecl::imagecl::ScalarType::F32, w, h, seed);
    let dyimg = bench_defs::synth_image(imagecl::imagecl::ScalarType::F32, w, h, seed ^ 0xABCD);
    let want = reference::harris(&dximg, &dyimg);
    let got = run("harris", &TuningConfig::default(), (w, h), seed);
    // det - k*tr² cancels catastrophically in f32 (the kernel accumulates
    // in float, the reference in f64): tolerance scales with the largest
    // cancelled term, not the per-pixel result.
    let scale = want.iter().fold(1.0f64, |m, v| m.max(v.abs()));
    for (i, &v) in want.iter().enumerate() {
        let g = got["out"][i];
        assert!(
            (g - v).abs() < 1e-4 * scale,
            "harris differs at {i}: {g} vs {v}"
        );
    }
}

#[test]
fn all_configs_equivalent_to_naive_property() {
    // Odd sizes so rounding/guard paths are exercised.
    let size = (21, 17);
    let seed = 7;
    let baselines: BTreeMap<&str, BTreeMap<String, Vec<f64>>> = KERNELS
        .iter()
        .map(|&k| (k, run(k, &TuningConfig::default(), size, seed)))
        .collect();

    let cases = if cfg!(debug_assertions) { 12 } else { 40 };
    check(cases, |rng| {
        let kid = *rng.pick(&KERNELS);
        let cfg = random_config(rng, kid);
        let got = run(kid, &cfg, size, seed);
        assert_images_eq(kid, &cfg, &got, &baselines[kid]);
    });
}

#[test]
fn paper_table_configs_exact() {
    // The exact configurations the paper's auto-tuner found (Tables 2-5)
    // must lower, execute, and agree with naive. A representative subset
    // (work-group / coarsening scaled to test-image size):
    let cases: [(&str, &str); 6] = [
        ("sepconv_row", "wg=8x4 px=4x1 map=interleaved lmem=in cmem=f"),
        ("sepconv_col", "wg=16x16 px=2x2 map=blocked img=in cmem=f"),
        ("conv2d", "wg=8x8 px=4x4 map=interleaved lmem=in cmem=f unroll=1:0,2:0"),
        ("conv2d", "wg=2x8 px=16x2 map=interleaved cmem=f unroll=1:0,2:0"),
        ("sobel", "wg=8x4 px=1x4 map=blocked img=in"),
        ("harris", "wg=8x8 px=1x1 map=blocked lmem=dx,dy"),
    ];
    let size = (19, 23);
    let seed = 99;
    for (kid, cfg_s) in cases {
        let cfg = TuningConfig::parse(cfg_s).unwrap();
        let naive = run(kid, &TuningConfig::default(), size, seed);
        let got = run(kid, &cfg, size, seed);
        assert_images_eq(kid, &cfg, &got, &naive);
    }
}

#[test]
fn opencl_emitted_for_every_random_config() {
    // Codegen must succeed and contain structural invariants for any
    // valid config.
    let cases = if cfg!(debug_assertions) { 10 } else { 30 };
    check(cases, |rng| {
        let kid = *rng.pick(&KERNELS);
        let cfg = random_config(rng, kid);
        let kdef = bench_defs::kernel_by_id(kid).unwrap();
        let cl = imagecl::transform::compile_to_opencl(kdef.source, &cfg).unwrap();
        assert!(cl.contains("__kernel void"));
        if cfg.any_local_mem() {
            assert!(cl.contains("barrier(CLK_LOCAL_MEM_FENCE);"));
            assert!(cl.contains("__local"));
        }
        let texture_on = cfg
            .image_mem
            .iter()
            .any(|(a, &v)| v && !cfg.uses_local_mem(a));
        if texture_on {
            assert!(cl.contains("image2d_t"));
        }
        assert!(!cl.contains("__read_tex"), "unrewritten intrinsic:\n{cl}");
    });
}
