//! Differential tests for pipeline-level stencil fusion: every fused
//! plan — across producer→consumer chains, a grid of tuning configs and
//! the whole engine ladder — must be bit-identical (f64 payload bits) to
//! the staged two-kernel pipeline run on the tree-walking oracle.
//!
//! Staged reference and fused runs consume identically-seeded workloads,
//! so any divergence is the fusion transform's fault: halo composition,
//! boundary clamping, intermediate-precision rounding or the local-stage
//! plan surgery.

use imagecl::bench_defs::kernel_by_id;
use imagecl::exec::{execute_with, Engine};
use imagecl::pipeline::fusion::{fused_by_id, fused_workload, image_bits, run_staged};
use imagecl::transform::{lower_fused, FuseMode, FusedKernel, TuningConfig};

/// Build an ad-hoc fusion of two benchmark kernels by id.
fn chain(id: &str, producer: &str, consumer: &str, bindings: &[(&str, &str)]) -> FusedKernel {
    let p = kernel_by_id(producer).expect("producer source");
    let c = kernel_by_id(consumer).expect("consumer source");
    FusedKernel::build(id, (producer, p.source), (consumer, c.source), bindings)
        .unwrap_or_else(|e| panic!("{id}: {e}"))
}

/// Run the fused kernel over work-group × coarsening × interleave ×
/// fuse-mode × engine combinations and compare every output against the
/// staged tree-walk oracle.
fn sweep(fk: &FusedKernel, w: usize, h: usize) {
    let seed = 42;
    let staged = run_staged(fk, w, h, seed, Engine::TreeWalk).expect("staged oracle");
    let want = image_bits(&staged, &fk.consumer_output);
    assert!(
        want.iter().any(|&b| b != 0),
        "{}: staged oracle produced an all-zero output — vacuous comparison",
        fk.id
    );

    let engines = [Engine::TreeWalk, Engine::VmUnopt, Engine::VmScalar, Engine::Vm];
    let mut plans = 0;
    for wg in [[16, 16], [8, 4], [3, 5]] {
        for coarsen in [[1, 1], [2, 2]] {
            for interleaved in [false, true] {
                for mode in fk.modes() {
                    let cfg = TuningConfig {
                        wg,
                        coarsen,
                        interleaved,
                        fuse: Some(mode),
                        ..TuningConfig::default()
                    };
                    let plan = lower_fused(fk, &cfg)
                        .unwrap_or_else(|e| panic!("{} cfg={cfg}: {e}", fk.id));
                    plans += 1;
                    for engine in engines {
                        let mut args = fused_workload(fk, &plan, w, h, seed);
                        execute_with(&plan, &mut args, (w, h), engine).unwrap_or_else(|e| {
                            panic!("{} cfg={cfg} engine={engine:?}: {e}", fk.id)
                        });
                        assert_eq!(
                            image_bits(&args, &fk.consumer_output),
                            want,
                            "{} diverged from staged at cfg={cfg} engine={engine:?}",
                            fk.id
                        );
                    }
                }
            }
        }
    }
    assert!(plans >= 12, "{}: config grid collapsed to {plans} plans", fk.id);
}

#[test]
fn sobel_harris_fused_matches_staged_everywhere() {
    // The registry kernel the Harris pipeline actually ships.
    let fk = fused_by_id("fused_sobel_harris").expect("registry kernel");
    assert!(fk.lstage_ok, "sobel→harris should support local staging");
    sweep(fk, 19, 13);
}

#[test]
fn blur_threshold_fused_matches_staged_everywhere() {
    // Stencil producer into a point consumer: no composed halo on the
    // consumer side, no fused-dims scalars needed.
    let fk = chain("fused_blur_threshold", "blur", "threshold", &[("out", "in")]);
    sweep(&fk, 17, 11);
}

#[test]
fn blur_erode_fused_matches_staged_everywhere() {
    // Stencil into stencil under a clamped boundary: the composed halo
    // is the Minkowski sum of blur's 3×3 and erode's 3×3.
    let fk = chain("fused_blur_erode", "blur", "erode", &[("out", "in")]);
    sweep(&fk, 16, 16);
}

#[test]
fn sobel_grad_mag_fused_matches_staged_everywhere() {
    // Two bound intermediates consumed at the identity coordinate.
    let fk = chain(
        "fused_sobel_grad_mag",
        "sobel",
        "grad_mag",
        &[("dx", "dx"), ("dy", "dy")],
    );
    sweep(&fk, 13, 19);
}

#[test]
fn unsharp_consumer_is_rejected() {
    // unsharp reads its input at offsets under a *constant* boundary;
    // fusion can only recompute offset reads under clamping.
    let p = kernel_by_id("blur").unwrap();
    let c = kernel_by_id("unsharp").unwrap();
    let err = FusedKernel::build("x", ("blur", p.source), ("unsharp", c.source), &[("out", "in")])
        .unwrap_err();
    assert!(err.to_string().contains("clamped"), "{err}");
}

#[test]
fn sepconv_chain_is_rejected() {
    // The column stage reads the row output at y-offsets under a
    // constant boundary — the documented reason sepconv stays staged.
    let p = kernel_by_id("sepconv_row").unwrap();
    let c = kernel_by_id("sepconv_col").unwrap();
    let err = FusedKernel::build(
        "x",
        ("sepconv_row", p.source),
        ("sepconv_col", c.source),
        &[("out", "in")],
    )
    .unwrap_err();
    assert!(err.to_string().contains("clamped"), "{err}");
}

#[test]
fn unknown_binding_is_rejected() {
    let p = kernel_by_id("sobel").unwrap();
    let c = kernel_by_id("harris").unwrap();
    let err = FusedKernel::build(
        "x",
        ("sobel", p.source),
        ("harris", c.source),
        &[("dx", "nope")],
    )
    .unwrap_err();
    assert!(err.to_string().contains("no param"), "{err}");
}
