//! Knowledge-base integration: the transfer-tuning acceptance criterion
//! (a cold grid reaches a near-optimal config with a fraction of the
//! full search's measured evaluations), the service-level tier wiring,
//! the legacy-TSV migration shim, and db-backed pipeline scheduling.
//! Everything is deterministic — device-model evaluations and counters,
//! never wall-clock.

use std::sync::Arc;

use imagecl::analysis::KernelInfo;
use imagecl::bench_defs::SEPCONV_ROW;
use imagecl::devices::{predict, DeviceSpec, KernelModel, INTEL_I7, K40};
use imagecl::imagecl::frontend;
use imagecl::serve::{ExecMode, KernelService, ServiceConfig, TuneSource};
use imagecl::transform::TuningConfig;
use imagecl::tunedb::TuneDb;
use imagecl::tuner::{exhaustive, seeded, FeatureMap, Strategy, TuningSpace};

/// Unique temp path per test (tests run concurrently in one process).
fn temp_db(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "imagecl_tunedb_test_{}_{}.tsv",
        tag,
        std::process::id()
    ))
}

fn thinned_space(dev: &DeviceSpec) -> (KernelInfo, FeatureMap, TuningSpace) {
    let info = KernelInfo::analyze(frontend(SEPCONV_ROW).unwrap());
    let fm = FeatureMap::new(&info);
    let full = TuningSpace::enumerate(&info, dev);
    // Thin for test speed, like the tuner's own tests.
    let step = if cfg!(debug_assertions) { 25 } else { 5 };
    let configs = full.configs.into_iter().step_by(step).collect();
    (info, fm, TuningSpace { configs })
}

fn eval_at<'a>(
    info: &'a KernelInfo,
    dev: &'a DeviceSpec,
    n: usize,
) -> impl FnMut(&TuningConfig) -> f64 + 'a {
    move |cfg| {
        let km = KernelModel::build(info, cfg);
        predict(dev, &km, n, n).seconds
    }
}

/// The PR's acceptance criterion: with a populated knowledge base, a
/// cold (kernel, device, grid) key reaches a config within 10% of the
/// full-search winner using ≤ 25% of the full search's measured
/// evaluations.
#[test]
fn cold_grid_transfer_within_10pct_at_quarter_cost() {
    let (info, fm, space) = thinned_space(&K40);

    // Populate the knowledge base with a tune at a *different* grid.
    let db = TuneDb::ephemeral();
    let seed_res = exhaustive(&space, eval_at(&info, &K40, 512));
    db.record_tune("sepconv_row", &K40, (512, 512), &seed_res, &fm);

    // Full search at the cold grid — the quality/cost baseline.
    let full = exhaustive(&space, eval_at(&info, &K40, 1024));
    assert_eq!(full.evals, space.len());

    // Cold-grid query: tier 2 hands back the 512-grid winner as a seed.
    let (rec, dist) = db
        .nearest_grid("sepconv_row", K40.name, (1024, 1024))
        .expect("populated db answers the transfer tier");
    assert_eq!(rec.grid, (512, 512));
    assert!(dist > 0.0);

    // Seeded neighborhood search with a quarter-budget ceiling.
    let budget = (full.evals / 5).max(8);
    let res = seeded(&space, &fm, &rec.config, budget, eval_at(&info, &K40, 1024));

    assert!(
        res.evals * 4 <= full.evals,
        "transfer used {} evals vs full {}",
        res.evals,
        full.evals
    );
    assert!(
        res.best_time <= full.best_time * 1.10,
        "transfer best {} not within 10% of full-search best {} ({})",
        res.best_time,
        full.best_time,
        res.best
    );
}

/// Service-level wiring of the same property: a second process (fresh
/// service, shared db) serving a new grid transfers instead of running
/// the full cold search, observable in the counters.
#[test]
fn service_cold_grid_uses_fewer_evals_than_from_scratch() {
    let db_path = temp_db("cold_grid");
    let _ = std::fs::remove_file(&db_path);
    let cold_evals = 200;
    let transfer_budget = 32;
    let config = |db: Option<std::path::PathBuf>| ServiceConfig {
        strategy: Strategy::Random { evals: cold_evals, seed: 17 },
        db_path: db,
        legacy_tsv: None,
        exec: ExecMode::Simulate,
        plan_cache_cap: None,
        transfer_budget,
        predict_budget: 0,
        explore_eps: 0.0,
    };

    // First process tunes grid 32 from scratch and persists.
    let first = KernelService::new(config(Some(db_path.clone())));
    let e = first.plan("sepconv_row", &K40, (32, 32)).unwrap();
    assert_eq!(e.source, TuneSource::Fresh);
    assert_eq!(first.stats().search_evals, cold_evals as u64);

    // Second process, new grid: transfer tier, quarter of the evals.
    let second = KernelService::new(config(Some(db_path.clone())));
    let e = second.plan("sepconv_row", &K40, (64, 64)).unwrap();
    assert_eq!(e.source, TuneSource::Transfer);
    let s = second.stats();
    assert_eq!(s.tunes, 0, "transfer must replace the full cold search");
    assert_eq!(s.db_transfers, 1);
    assert_eq!(s.search_evals, transfer_budget as u64);
    assert!(s.search_evals * 4 <= cold_evals as u64);

    // And the transfer outcome was recorded: a third service at the same
    // grid warm-starts exactly.
    let third = KernelService::new(config(Some(db_path.clone())));
    let e = third.plan("sepconv_row", &K40, (64, 64)).unwrap();
    assert_eq!(e.source, TuneSource::WarmStart);
    assert_eq!(third.stats().search_evals, 0);

    let _ = std::fs::remove_file(&db_path);
}

/// Migration shim end-to-end: a legacy PR-1 warm-start TSV is folded
/// into the knowledge base on service startup, so existing deployments
/// never re-tune their known keys.
#[test]
fn legacy_tsv_migrates_into_db_on_startup() {
    use imagecl::serve::cache::{PlanKey, TunedRecord};
    use imagecl::serve::TunedStore;

    let legacy = temp_db("legacy_in");
    let db_path = temp_db("legacy_db");
    let _ = std::fs::remove_file(&legacy);
    let _ = std::fs::remove_file(&db_path);

    // A PR-1 deployment's tuned config.
    let store = TunedStore::open(&legacy);
    let mut cfg = TuningConfig::default();
    cfg.wg = [16, 8];
    cfg.coarsen = [2, 1];
    cfg.constant_mem.insert("f".into(), true);
    store.insert(
        PlanKey { kernel: "sepconv_row".to_string(), device: K40.name, grid: (40, 40) },
        TunedRecord { config: cfg.clone(), est_seconds: 2.5e-4 },
    );

    let svc = KernelService::new(ServiceConfig {
        strategy: Strategy::Random { evals: 40, seed: 23 },
        db_path: Some(db_path.clone()),
        legacy_tsv: Some(legacy.clone()),
        exec: ExecMode::Simulate,
        plan_cache_cap: None,
        transfer_budget: 0,
        predict_budget: 0,
        explore_eps: 0.0,
    });
    assert_eq!(svc.tuned_len(), 1, "legacy config visible in the db");
    let entry = svc.plan("sepconv_row", &K40, (40, 40)).unwrap();
    assert_eq!(entry.source, TuneSource::WarmStart);
    assert_eq!(entry.config, cfg);
    assert_eq!(svc.stats().tunes, 0);

    // The migrated record persists in the db file itself: a service
    // without the legacy file still warm-starts.
    let svc2 = KernelService::new(ServiceConfig {
        strategy: Strategy::Random { evals: 40, seed: 23 },
        db_path: Some(db_path.clone()),
        legacy_tsv: None,
        exec: ExecMode::Simulate,
        plan_cache_cap: None,
        transfer_budget: 0,
        predict_budget: 0,
        explore_eps: 0.0,
    });
    let entry = svc2.plan("sepconv_row", &K40, (40, 40)).unwrap();
    assert_eq!(entry.source, TuneSource::WarmStart);
    assert_eq!(entry.config, cfg);

    let _ = std::fs::remove_file(&legacy);
    let _ = std::fs::remove_file(&db_path);
}

/// The knowledge base feeds the pipeline scheduler without ever tuning:
/// recorded estimates drive placement, unknown keys fall back to the
/// naive model.
#[test]
fn db_backed_schedule_needs_no_tuner() {
    use imagecl::pipeline::{schedule_with_db, Pipeline, Port};
    use imagecl::runtime::Tensor;

    // Accumulate knowledge through a service.
    let svc: Arc<KernelService> = KernelService::new(ServiceConfig {
        strategy: Strategy::Random { evals: 60, seed: 29 },
        db_path: None,
        legacy_tsv: None,
        exec: ExecMode::Simulate,
        plan_cache_cap: None,
        transfer_budget: 0,
        predict_budget: 0,
        explore_eps: 0.0,
    });
    for kernel in ["sobel", "harris"] {
        svc.plan(kernel, &K40, (256, 256)).unwrap();
        svc.plan(kernel, &INTEL_I7, (256, 256)).unwrap();
    }

    let mut p = Pipeline::new();
    let img = p.source("img", Tensor::zeros(4, 4));
    let sob = p.filter("sobel", &[p.port(img)]);
    let har = p.filter(
        "harris",
        &[Port { node: sob, port: 0 }, Port { node: sob, port: 1 }],
    );
    p.output(p.port(har));

    let tunes_before = svc.stats().tunes;
    let sched = schedule_with_db(
        &p,
        &[&K40, &INTEL_I7],
        256,
        svc.db(),
        &TuningConfig::default(),
    );
    assert_eq!(sched.placements.len(), 2);
    assert!(sched.makespan_s.is_finite() && sched.makespan_s > 0.0);
    // Scheduling read recorded estimates — no tuner invocations at all.
    assert_eq!(svc.stats().tunes, tunes_before);
}
