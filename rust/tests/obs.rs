//! Observability integration: a serving burst must leave complete
//! traces in the span ring, a lintable Prometheus export in the metrics
//! registry, and coherent execution-tier profiler coverage.
//!
//! One test function drives everything: the tracer, registry and
//! profiler are process globals, so splitting the assertions across
//! parallel `#[test]`s would race their contents.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::mpsc;
use std::sync::Arc;

use imagecl::devices::INTEL_I7;
use imagecl::exec::profile;
use imagecl::obs;
use imagecl::serve::worker::submit_with_retry;
use imagecl::serve::{
    DevicePool, ExecMode, KernelService, ServeReply, ServeRequest, ServiceConfig,
};
use imagecl::tuner::Strategy;

fn real_service() -> Arc<KernelService> {
    KernelService::new(ServiceConfig {
        strategy: Strategy::Random { evals: 20, seed: 9 },
        db_path: None,
        legacy_tsv: None,
        exec: ExecMode::Real,
        plan_cache_cap: None,
        transfer_budget: 0,
        predict_budget: 0,
        explore_eps: 0.0,
    })
}

/// Drive `n` real-execution requests through a fresh pool, returning
/// their trace IDs (replies are asserted OK).
fn burst(service: &Arc<KernelService>, n: u64) -> Vec<u64> {
    let pool = DevicePool::start(&INTEL_I7, service.clone(), 2, 16, 4);
    let (tx, rx) = mpsc::channel();
    let queue = pool.queue();
    let mut traces = Vec::new();
    for seed in 0..n {
        let kernel = if seed % 2 == 0 { "sobel" } else { "sepconv_row" };
        let req = ServeRequest::new(kernel, (16, 16), seed, tx.clone());
        traces.push(req.trace);
        assert!(submit_with_retry(&queue, &service.counters, req));
    }
    let replies: Vec<ServeReply> = (0..n).map(|_| rx.recv().unwrap()).collect();
    assert!(replies.iter().all(ServeReply::is_ok));
    pool.shutdown();
    traces
}

/// Counter sample values from a Prometheus text export, keyed by the
/// full series (name + rendered labels).
fn counter_values(text: &str) -> BTreeMap<String, f64> {
    let mut counters = BTreeSet::new();
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            if let (Some(name), Some("counter")) = (it.next(), it.next()) {
                counters.insert(name.to_string());
            }
        }
    }
    let mut vals = BTreeMap::new();
    for line in text.lines() {
        if line.starts_with('#') || line.trim().is_empty() {
            continue;
        }
        let Some((series, val)) = line.rsplit_once(' ') else { continue };
        let base = series.split('{').next().unwrap_or(series);
        if counters.contains(base) {
            if let Ok(v) = val.trim().parse::<f64>() {
                vals.insert(series.to_string(), v);
            }
        }
    }
    vals
}

#[test]
fn burst_yields_traces_lintable_export_and_coherent_profile() {
    let service = real_service();
    let traces = burst(&service, 10);

    // (i) Every completed request left a full trace in the ring: one
    // root span named "request" (recorded before the reply was sent)
    // plus at least serve.submit, serve.execute and the exec.* leaf.
    let snap = obs::tracer().snapshot();
    for &trace in &traces {
        let spans: Vec<_> = snap.iter().filter(|s| s.trace == trace).collect();
        let roots: Vec<_> = spans.iter().filter(|s| s.parent == 0).collect();
        assert_eq!(roots.len(), 1, "trace {trace} has {} roots", roots.len());
        assert_eq!(roots[0].name, "request");
        let children = spans.len() - 1;
        assert!(
            children >= 3,
            "trace {trace} has only {children} child spans: \
             {:?}",
            spans.iter().map(|s| s.name).collect::<Vec<_>>()
        );
    }
    // The batch leads' planning path produced the deep spans somewhere.
    for name in ["serve.plan", "serve.cache", "tunedb.query", "tune.search"] {
        assert!(snap.iter().any(|s| s.name == name), "no {name} span recorded");
    }

    // (ii) The Prometheus export passes the in-repo linter and covers
    // every instrumented subsystem.
    service.publish_obs();
    let text1 = obs::export::prometheus();
    let (families, samples) =
        obs::export::lint_prometheus(&text1).expect("export must lint clean");
    assert!(families > 0 && samples >= families);
    for needle in
        ["imagecl_serve_", "imagecl_tunedb_", "imagecl_tuner_", "imagecl_exec_"]
    {
        assert!(text1.contains(needle), "export missing {needle} metrics");
    }
    // The durability counters (PR 10) are always exported — fleet
    // dashboards must see zeros, not absent series.
    for name in [
        "imagecl_tunedb_fsck_quarantined_total",
        "imagecl_tunedb_fsync_failures_total",
        "imagecl_serve_warm_restarts_total",
        "imagecl_serve_explores_total",
    ] {
        assert!(text1.contains(name), "export missing {name}");
    }
    // Counters are monotone across sequential exports.
    let counters1 = counter_values(&text1);
    assert!(!counters1.is_empty());
    burst(&service, 6);
    service.publish_obs();
    let text2 = obs::export::prometheus();
    obs::export::lint_prometheus(&text2).expect("second export must lint clean");
    let counters2 = counter_values(&text2);
    for (series, v1) in &counters1 {
        let v2 = counters2
            .get(series)
            .unwrap_or_else(|| panic!("counter {series} vanished"));
        assert!(v2 >= v1, "counter {series} went backwards: {v1} -> {v2}");
    }

    // (iii) Tier-profiler coverage is coherent: per plan, the batched
    // and scalar row fractions sum to at most 1.0, and utilization is a
    // ratio.
    let profiles = profile::profiler().snapshot();
    assert!(!profiles.is_empty(), "real execution must populate the profiler");
    for (key, p) in &profiles {
        let cover = p.batched_frac() + p.scalar_frac();
        assert!(
            cover <= 1.0 + 1e-9,
            "plan {}@{} coverage {cover} exceeds 1.0",
            key.kernel,
            key.device
        );
        assert!(p.utilization() <= 1.0 + 1e-9);
    }
    assert!(profiles.iter().any(|(_, p)| p.total_runs() > 0));
}

#[test]
fn linter_rejects_duplicate_series_and_unlabeled_buckets() {
    let dup = "# TYPE imagecl_a_total counter\nimagecl_a_total 1\nimagecl_a_total 2\n";
    assert!(obs::export::lint_prometheus(dup).is_err());
    let nolabel = "# TYPE imagecl_h histogram\nimagecl_h_bucket 1\n";
    assert!(obs::export::lint_prometheus(nolabel).is_err());
}

#[test]
fn prometheus_export_escapes_hostile_label_values() {
    // Label values with quotes, backslashes and newlines must render as
    // \" \\ \n escape sequences — and the escaped export must still
    // both lint and round-trip the sample-splitting logic.
    obs::registry()
        .counter(
            "imagecl_obs_escape_test_total",
            "escaping test",
            &[("path", "C:\\tmp\\\"quoted\" multi\nline")],
        )
        .inc();
    let text = obs::export::prometheus();
    let line = text
        .lines()
        .find(|l| l.starts_with("imagecl_obs_escape_test_total"))
        .expect("escaped series rendered");
    assert!(line.contains("C:\\\\tmp\\\\\\\"quoted\\\""), "{line}");
    assert!(line.contains("multi\\nline"), "{line}");
    assert!(!line.contains('\n'), "newline leaked into the sample line");
    obs::export::lint_prometheus(&text).expect("escaped export lints");
}

#[test]
fn chrome_trace_export_is_valid_and_stable() {
    // Spans from a device-attributed thread...
    std::thread::spawn(|| {
        obs::set_thread_device("chrome-test-dev");
        let _root = obs::span("chrometest.root");
        std::thread::sleep(std::time::Duration::from_millis(1));
        let _child = obs::span("chrometest.child");
    })
    .join()
    .unwrap();
    let doc = obs::export::chrome_trace(256);

    // ...render as a valid trace-event JSON document.
    let v = imagecl::jsonlite::parse(&doc).expect(&doc);
    let events = v.get("traceEvents").and_then(|e| e.as_arr()).expect(&doc);
    assert!(!events.is_empty());
    let phase = |e: &imagecl::jsonlite::Json| {
        e.get("ph").and_then(|p| p.as_str()).unwrap_or("").to_string()
    };
    let ours: Vec<_> = events
        .iter()
        .filter(|e| {
            phase(e) == "X"
                && e.get("name")
                    .and_then(|n| n.as_str())
                    .is_some_and(|n| n.starts_with("chrometest."))
        })
        .collect();
    assert_eq!(ours.len(), 2, "{doc}");

    // "X" events are emitted in non-decreasing ts order.
    let ts: Vec<f64> = events
        .iter()
        .filter(|e| phase(e) == "X")
        .map(|e| e.get("ts").unwrap().as_f64().unwrap())
        .collect();
    assert!(ts.windows(2).all(|w| w[0] <= w[1]), "ts not monotone: {ts:?}");

    // Same thread ⇒ same pid/tid on both spans; the device has a
    // process_name metadata record carrying its name.
    let pid = ours[0].get("pid").unwrap().as_f64().unwrap();
    let tid = ours[0].get("tid").unwrap().as_f64().unwrap();
    assert_eq!(ours[1].get("pid").unwrap().as_f64(), Some(pid));
    assert_eq!(ours[1].get("tid").unwrap().as_f64(), Some(tid));
    assert!(events.iter().any(|e| {
        phase(e) == "M"
            && e.get("pid").unwrap().as_f64() == Some(pid)
            && e.path(&["args", "name"]).and_then(|n| n.as_str())
                == Some("chrome-test-dev")
    }));

    // Args carry the span identity for cross-referencing with /traces.
    for e in &ours {
        assert!(e.path(&["args", "span"]).is_some());
        assert!(e.path(&["args", "trace"]).is_some());
    }
    // Parent/child share a trace and the child points at the root.
    let root = ours
        .iter()
        .find(|e| e.get("name").unwrap().as_str() == Some("chrometest.root"))
        .unwrap();
    let child = ours
        .iter()
        .find(|e| e.get("name").unwrap().as_str() == Some("chrometest.child"))
        .unwrap();
    assert_eq!(
        root.path(&["args", "trace"]).unwrap().as_f64(),
        child.path(&["args", "trace"]).unwrap().as_f64()
    );
    assert_eq!(
        child.path(&["args", "parent"]).unwrap().as_f64(),
        root.path(&["args", "span"]).unwrap().as_f64()
    );
}

#[test]
fn loadgen_obs_server_reports_slo_and_drains_on_completion() {
    use imagecl::serve::{run_loadgen, LoadGenOpts};

    let service = KernelService::new(ServiceConfig {
        strategy: Strategy::Random { evals: 20, seed: 3 },
        db_path: None,
        legacy_tsv: None,
        exec: ExecMode::Simulate,
        plan_cache_cap: None,
        transfer_budget: 0,
        predict_budget: 0,
        explore_eps: 0.0,
    });
    let opts = LoadGenOpts {
        requests: 24,
        concurrency: 3,
        // blur is gallery-sourced: the kernel_by_id fallback makes it
        // servable, and the SLO engine must end up reporting on it.
        kernels: vec!["blur".to_string(), "sobel".to_string()],
        devices: vec![&INTEL_I7],
        grid: 16,
        queue_cap: 16,
        max_batch: 4,
        workers_per_device: 1,
        obs_addr: Some("127.0.0.1:0".to_string()),
        ..Default::default()
    };
    let report = run_loadgen(service, &opts).unwrap();
    assert_eq!(report.completed, 24);

    // The server bound a real port (0 was resolved) and was drained
    // before run_loadgen returned: connecting now must fail.
    let bound = report.obs_bound.expect("obs server bound an address");
    assert!(bound.port() != 0);
    assert!(
        std::net::TcpStream::connect_timeout(
            &bound,
            std::time::Duration::from_millis(500)
        )
        .is_err(),
        "obs server still accepting after loadgen returned"
    );

    // Shutdown ordering: the final snapshot was published before the
    // drain, so the registry holds the run's latency histogram...
    let text = obs::export::prometheus();
    obs::export::lint_prometheus(&text).expect("final export lints");
    assert!(text.contains("imagecl_serve_latency_us"), "{text}");

    // ...and the SLO engine saw every completed request, blur included.
    let slo = obs::slo::engine().report();
    let blur = slo
        .kernels
        .iter()
        .find(|k| k.kernel == "blur")
        .expect("blur SLO row");
    assert!(blur.total >= 12, "{slo:?}");
    assert_eq!(blur.burn.len(), 2, "5m + 1h burn windows");
}
