//! Observability integration: a serving burst must leave complete
//! traces in the span ring, a lintable Prometheus export in the metrics
//! registry, and coherent execution-tier profiler coverage.
//!
//! One test function drives everything: the tracer, registry and
//! profiler are process globals, so splitting the assertions across
//! parallel `#[test]`s would race their contents.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::mpsc;
use std::sync::Arc;

use imagecl::devices::INTEL_I7;
use imagecl::exec::profile;
use imagecl::obs;
use imagecl::serve::worker::submit_with_retry;
use imagecl::serve::{
    DevicePool, ExecMode, KernelService, ServeReply, ServeRequest, ServiceConfig,
};
use imagecl::tuner::Strategy;

fn real_service() -> Arc<KernelService> {
    KernelService::new(ServiceConfig {
        strategy: Strategy::Random { evals: 20, seed: 9 },
        db_path: None,
        legacy_tsv: None,
        exec: ExecMode::Real,
        plan_cache_cap: None,
        transfer_budget: 0,
        predict_budget: 0,
    })
}

/// Drive `n` real-execution requests through a fresh pool, returning
/// their trace IDs (replies are asserted OK).
fn burst(service: &Arc<KernelService>, n: u64) -> Vec<u64> {
    let pool = DevicePool::start(&INTEL_I7, service.clone(), 2, 16, 4);
    let (tx, rx) = mpsc::channel();
    let queue = pool.queue();
    let mut traces = Vec::new();
    for seed in 0..n {
        let kernel = if seed % 2 == 0 { "sobel" } else { "sepconv_row" };
        let req = ServeRequest::new(kernel, (16, 16), seed, tx.clone());
        traces.push(req.trace);
        assert!(submit_with_retry(&queue, &service.counters, req));
    }
    let replies: Vec<ServeReply> = (0..n).map(|_| rx.recv().unwrap()).collect();
    assert!(replies.iter().all(ServeReply::is_ok));
    pool.shutdown();
    traces
}

/// Counter sample values from a Prometheus text export, keyed by the
/// full series (name + rendered labels).
fn counter_values(text: &str) -> BTreeMap<String, f64> {
    let mut counters = BTreeSet::new();
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            if let (Some(name), Some("counter")) = (it.next(), it.next()) {
                counters.insert(name.to_string());
            }
        }
    }
    let mut vals = BTreeMap::new();
    for line in text.lines() {
        if line.starts_with('#') || line.trim().is_empty() {
            continue;
        }
        let Some((series, val)) = line.rsplit_once(' ') else { continue };
        let base = series.split('{').next().unwrap_or(series);
        if counters.contains(base) {
            if let Ok(v) = val.trim().parse::<f64>() {
                vals.insert(series.to_string(), v);
            }
        }
    }
    vals
}

#[test]
fn burst_yields_traces_lintable_export_and_coherent_profile() {
    let service = real_service();
    let traces = burst(&service, 10);

    // (i) Every completed request left a full trace in the ring: one
    // root span named "request" (recorded before the reply was sent)
    // plus at least serve.submit, serve.execute and the exec.* leaf.
    let snap = obs::tracer().snapshot();
    for &trace in &traces {
        let spans: Vec<_> = snap.iter().filter(|s| s.trace == trace).collect();
        let roots: Vec<_> = spans.iter().filter(|s| s.parent == 0).collect();
        assert_eq!(roots.len(), 1, "trace {trace} has {} roots", roots.len());
        assert_eq!(roots[0].name, "request");
        let children = spans.len() - 1;
        assert!(
            children >= 3,
            "trace {trace} has only {children} child spans: \
             {:?}",
            spans.iter().map(|s| s.name).collect::<Vec<_>>()
        );
    }
    // The batch leads' planning path produced the deep spans somewhere.
    for name in ["serve.plan", "serve.cache", "tunedb.query", "tune.search"] {
        assert!(snap.iter().any(|s| s.name == name), "no {name} span recorded");
    }

    // (ii) The Prometheus export passes the in-repo linter and covers
    // every instrumented subsystem.
    service.publish_obs();
    let text1 = obs::export::prometheus();
    let (families, samples) =
        obs::export::lint_prometheus(&text1).expect("export must lint clean");
    assert!(families > 0 && samples >= families);
    for needle in
        ["imagecl_serve_", "imagecl_tunedb_", "imagecl_tuner_", "imagecl_exec_"]
    {
        assert!(text1.contains(needle), "export missing {needle} metrics");
    }
    // Counters are monotone across sequential exports.
    let counters1 = counter_values(&text1);
    assert!(!counters1.is_empty());
    burst(&service, 6);
    service.publish_obs();
    let text2 = obs::export::prometheus();
    obs::export::lint_prometheus(&text2).expect("second export must lint clean");
    let counters2 = counter_values(&text2);
    for (series, v1) in &counters1 {
        let v2 = counters2
            .get(series)
            .unwrap_or_else(|| panic!("counter {series} vanished"));
        assert!(v2 >= v1, "counter {series} went backwards: {v1} -> {v2}");
    }

    // (iii) Tier-profiler coverage is coherent: per plan, the batched
    // and scalar row fractions sum to at most 1.0, and utilization is a
    // ratio.
    let profiles = profile::profiler().snapshot();
    assert!(!profiles.is_empty(), "real execution must populate the profiler");
    for (key, p) in &profiles {
        let cover = p.batched_frac() + p.scalar_frac();
        assert!(
            cover <= 1.0 + 1e-9,
            "plan {}@{} coverage {cover} exceeds 1.0",
            key.kernel,
            key.device
        );
        assert!(p.utilization() <= 1.0 + 1e-9);
    }
    assert!(profiles.iter().any(|(_, p)| p.total_runs() > 0));
}

#[test]
fn linter_rejects_duplicate_series_and_unlabeled_buckets() {
    let dup = "# TYPE imagecl_a_total counter\nimagecl_a_total 1\nimagecl_a_total 2\n";
    assert!(obs::export::lint_prometheus(dup).is_err());
    let nolabel = "# TYPE imagecl_h histogram\nimagecl_h_bucket 1\n";
    assert!(obs::export::lint_prometheus(nolabel).is_err());
}
