//! Pipeline end-to-end: build a FAST-style Harris pipeline, execute it
//! for real through the AOT artifacts, check numerics, and verify the
//! heterogeneous schedule behaves like FAST's (GPU placement for large
//! images, stage colocation).

use imagecl::bench_defs::{reference, synth_image};
use imagecl::devices::ALL_DEVICES;
use imagecl::exec::ImageBuf;
use imagecl::imagecl::ScalarType;
use imagecl::pipeline::{schedule, Pipeline, Port};
use imagecl::runtime::{Tensor, XlaRuntime};
use imagecl::transform::TuningConfig;

const N: usize = 32;

fn tensor_of(img: &ImageBuf) -> Tensor {
    Tensor::new(img.h, img.w, img.buf.data.iter().map(|&v| v as f32).collect())
}

/// Clean skip (via `testutil::artifact_dir_or_skip`) when the `xla`
/// feature or the AOT artifacts are absent.
fn runtime() -> Option<XlaRuntime> {
    let dir = imagecl::testutil::artifact_dir_or_skip()?;
    Some(XlaRuntime::new(&dir).expect("runtime"))
}

#[test]
fn harris_pipeline_runs_and_matches_reference() {
    let Some(mut rt) = runtime() else { return };
    let img = synth_image(ScalarType::F32, N, N, 17);

    let mut p = Pipeline::new();
    let src = p.source("img", tensor_of(&img));
    let sob = p.filter("sobel", &[p.port(src)]);
    let har = p.filter(
        "harris",
        &[Port { node: sob, port: 0 }, Port { node: sob, port: 1 }],
    );
    p.output(p.port(har));

    let outs = p.run(&mut rt, N).expect("pipeline run");
    assert_eq!(outs.len(), 1);

    // Reference: sobel → harris on the same input.
    let (dx, dy) = reference::sobel(&img);
    let mut dximg = ImageBuf::new(ScalarType::F32, N, N);
    let mut dyimg = ImageBuf::new(ScalarType::F32, N, N);
    for y in 0..N {
        for x in 0..N {
            dximg.set(x, y, dx[y * N + x]);
            dyimg.set(x, y, dy[y * N + x]);
        }
    }
    let want = reference::harris(&dximg, &dyimg);
    let scale = want.iter().fold(1.0f64, |m, v| m.max(v.abs()));
    for i in 0..want.len() {
        assert!(
            (outs[0].data[i] as f64 - want[i]).abs() < 1e-4 * scale,
            "pipeline harris differs at {i}"
        );
    }

    // The fused single-artifact version must agree with the two-filter
    // pipeline (XLA fusion is value-preserving).
    let fused = rt
        .execute("harris_pipeline_32_bh8u1s1", &[&tensor_of(&img)])
        .unwrap();
    for i in 0..fused[0].data.len() {
        assert!((fused[0].data[i] - outs[0].data[i]).abs() <= 1e-2 * scale as f32);
    }
}

#[test]
fn sepconv_pipeline_two_stage() {
    let Some(mut rt) = runtime() else { return };
    let img = synth_image(ScalarType::F32, N, N, 29);
    let taps = Tensor::new(5, 1, vec![0.0625, 0.25, 0.375, 0.25, 0.0625]);

    let mut p = Pipeline::new();
    let src = p.source("img", tensor_of(&img));
    let f = p.source("taps", taps);
    let row = p.filter("sepconv_row", &[p.port(src), p.port(f)]);
    let col = p.filter("sepconv_col", &[p.port(row), p.port(f)]);
    p.output(p.port(col));
    let outs = p.run(&mut rt, N).expect("pipeline run");

    // vs single fused sepconv artifact.
    let fused = rt
        .execute(
            "sepconv_32_bh8u1s1",
            &[&tensor_of(&img), &Tensor::new(5, 1, vec![0.0625, 0.25, 0.375, 0.25, 0.0625])],
        )
        .unwrap();
    for i in 0..fused[0].data.len() {
        assert!((fused[0].data[i] - outs[0].data[i]).abs() < 1e-4);
    }
}

#[test]
fn schedule_reported_for_real_pipeline() {
    let mut p = Pipeline::new();
    let src = p.source("img", Tensor::zeros(4, 4));
    let sob = p.filter("sobel", &[p.port(src)]);
    let har = p.filter(
        "harris",
        &[Port { node: sob, port: 0 }, Port { node: sob, port: 1 }],
    );
    p.output(p.port(har));
    let s = schedule(&p, &ALL_DEVICES, 5120, &TuningConfig::default());
    assert_eq!(s.placements.len(), 2);
    assert!(s.makespan_s.is_finite() && s.makespan_s > 0.0);
}
