//! Runtime integration: load AOT artifacts (built by `make artifacts`),
//! execute them on the PJRT CPU client, and check numerics against the
//! rust reference filters — proving the three layers compose:
//! Pallas kernel (L1) → jax graph (L2) → HLO text → rust PJRT (L3).

use imagecl::bench_defs::{gauss5, gauss5x5, reference, synth_image};
use imagecl::exec::ImageBuf;
use imagecl::imagecl::ScalarType;
use imagecl::runtime::{Tensor, XlaRuntime};

/// Clean skip (via `testutil::artifact_dir_or_skip`) when the `xla`
/// feature or the AOT artifacts are absent.
fn runtime() -> Option<XlaRuntime> {
    let dir = imagecl::testutil::artifact_dir_or_skip()?;
    Some(XlaRuntime::new(&dir).expect("creating runtime"))
}

fn tensor_of(img: &ImageBuf) -> Tensor {
    Tensor::new(
        img.h,
        img.w,
        img.buf.data.iter().map(|&v| v as f32).collect(),
    )
}

const N: usize = 32;

#[test]
fn sepconv_row_artifact_matches_reference() {
    let Some(mut rt) = runtime() else { return };
    let img = synth_image(ScalarType::F32, N, N, 7);
    let f5: Vec<f32> = gauss5().iter().map(|&v| v as f32).collect();
    let x = tensor_of(&img);
    let f = Tensor::new(5, 1, f5);
    let out = rt
        .execute("sepconv_row_32_bh8u1s1", &[&x, &f])
        .expect("execute");
    assert_eq!(out.len(), 1);
    let want = reference::sepconv_row(&img, &gauss5());
    for i in 0..want.len() {
        assert!(
            (out[0].data[i] as f64 - want[i]).abs() < 1e-3,
            "sepconv_row differs at {i}: {} vs {}",
            out[0].data[i],
            want[i]
        );
    }
}

#[test]
fn all_sepconv_variants_agree() {
    let Some(mut rt) = runtime() else { return };
    let img = synth_image(ScalarType::F32, N, N, 13);
    let f5: Vec<f32> = gauss5().iter().map(|&v| v as f32).collect();
    let x = tensor_of(&img);
    let f = Tensor::new(5, 1, f5);
    let ids: Vec<String> = rt
        .manifest()
        .variants_of("sepconv", N)
        .iter()
        .map(|a| a.id.clone())
        .collect();
    assert!(ids.len() >= 4, "expected >=4 variants, got {ids:?}");
    let base = rt.execute(&ids[0], &[&x, &f]).unwrap();
    for id in &ids[1..] {
        let out = rt.execute(id, &[&x, &f]).unwrap();
        for i in 0..base[0].data.len() {
            assert!(
                (out[0].data[i] - base[0].data[i]).abs() < 1e-4,
                "{id} differs from {} at {i}",
                ids[0]
            );
        }
    }
}

#[test]
fn conv2d_artifact_uchar_semantics() {
    let Some(mut rt) = runtime() else { return };
    let img = synth_image(ScalarType::U8, N, N, 21);
    let f25: Vec<f32> = gauss5x5().iter().map(|&v| v as f32).collect();
    let x = tensor_of(&img);
    let f = Tensor::new(25, 1, f25);
    let out = rt.execute("conv2d_32_bh8u1s1", &[&x, &f]).expect("execute");
    let want = reference::conv2d(&img, &gauss5x5());
    for i in 0..want.len() {
        let diff = (out[0].data[i] as f64 - want[i]).abs();
        assert!(diff <= 1.0, "conv2d differs at {i}: {} vs {}", out[0].data[i], want[i]);
    }
}

#[test]
fn sobel_artifact_two_outputs() {
    let Some(mut rt) = runtime() else { return };
    let img = synth_image(ScalarType::F32, N, N, 3);
    let x = tensor_of(&img);
    let out = rt.execute("sobel_32_bh8u1s1", &[&x]).expect("execute");
    assert_eq!(out.len(), 2);
    let (dx, dy) = reference::sobel(&img);
    for i in 0..dx.len() {
        assert!((out[0].data[i] as f64 - dx[i]).abs() < 1e-2);
        assert!((out[1].data[i] as f64 - dy[i]).abs() < 1e-2);
    }
}

#[test]
fn harris_pipeline_artifact_end_to_end() {
    let Some(mut rt) = runtime() else { return };
    let img = synth_image(ScalarType::F32, N, N, 5);
    let x = tensor_of(&img);
    let out = rt
        .execute("harris_pipeline_32_bh8u1s1", &[&x])
        .expect("execute");
    // Rust reference: sobel then harris.
    let (dx, dy) = reference::sobel(&img);
    let mut dximg = ImageBuf::new(ScalarType::F32, N, N);
    let mut dyimg = ImageBuf::new(ScalarType::F32, N, N);
    for y in 0..N {
        for x in 0..N {
            dximg.set(x, y, dx[y * N + x]);
            dyimg.set(x, y, dy[y * N + x]);
        }
    }
    let want = reference::harris(&dximg, &dyimg);
    let scale = want.iter().fold(1.0f64, |m, v| m.max(v.abs()));
    for i in 0..want.len() {
        assert!(
            (out[0].data[i] as f64 - want[i]).abs() < 1e-4 * scale,
            "harris differs at {i}: {} vs {}",
            out[0].data[i],
            want[i]
        );
    }
}

#[test]
fn timing_returns_positive_best() {
    let Some(mut rt) = runtime() else { return };
    let img = synth_image(ScalarType::F32, N, N, 9);
    let x = tensor_of(&img);
    let (_, secs) = rt.time("sobel_32_bh8u1s1", &[&x], 3).unwrap();
    assert!(secs > 0.0 && secs < 1.0, "{secs}");
}

#[test]
fn wrong_arity_is_error() {
    let Some(mut rt) = runtime() else { return };
    let img = synth_image(ScalarType::F32, N, N, 9);
    let x = tensor_of(&img);
    assert!(rt.execute("sobel_32_bh8u1s1", &[&x, &x]).is_err());
    assert!(rt.execute("no_such_artifact", &[&x]).is_err());
}
