//! Serving-layer integration: cache hit/miss semantics, TSV warm-start
//! round-trip, bounded-queue backpressure, batch grouping, and the full
//! loadgen → worker-pool → metrics path. Everything here is
//! deterministic — counters and counts, never wall-clock.

use std::sync::Arc;

use imagecl::devices::{ALL_DEVICES, INTEL_I7, K40};
use imagecl::serve::{
    BoundedQueue, ExecMode, KernelService, LoadGenOpts, PushError, ServiceConfig,
    TuneSource,
};
use imagecl::tuner::Strategy;

fn fast_strategy() -> Strategy {
    Strategy::Random { evals: 40, seed: 13 }
}

/// Service with the knowledge-base transfer/model tiers disabled (zero
/// budgets): these tests pin the PR-1 plan-cache and exact-warm-start
/// semantics. The tiers are covered in `tests/tunedb.rs`.
fn service(db_path: Option<std::path::PathBuf>, exec: ExecMode) -> Arc<KernelService> {
    KernelService::new(ServiceConfig {
        strategy: fast_strategy(),
        db_path,
        legacy_tsv: None,
        exec,
        plan_cache_cap: None,
        transfer_budget: 0,
        predict_budget: 0,
        explore_eps: 0.0,
    })
}

/// Unique temp path per test (tests run concurrently in one process).
fn temp_tsv(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "imagecl_serve_test_{}_{}.tsv",
        tag,
        std::process::id()
    ))
}

#[test]
fn plan_cache_tunes_and_compiles_once_per_key() {
    let svc = service(None, ExecMode::Simulate);
    for _ in 0..5 {
        svc.plan("conv2d", &K40, (32, 32)).unwrap();
    }
    svc.plan("conv2d", &K40, (64, 64)).unwrap(); // new grid → new key
    svc.plan("conv2d", &INTEL_I7, (32, 32)).unwrap(); // new device → new key
    let s = svc.stats();
    assert_eq!(s.tunes, 3);
    assert_eq!(s.plan_compiles, 3);
    assert_eq!(s.cache_misses, 3);
    assert_eq!(s.cache_hits, 4);
    assert_eq!(s.warm_starts, 0);
}

#[test]
fn tsv_persistence_round_trips_and_warm_starts() {
    let path = temp_tsv("roundtrip");
    let _ = std::fs::remove_file(&path);

    // Cold service: tunes and persists.
    let cold = service(Some(path.clone()), ExecMode::Simulate);
    let a = cold.plan("sepconv_row", &K40, (48, 48)).unwrap();
    let b = cold.plan("harris", &INTEL_I7, (48, 48)).unwrap();
    assert_eq!(cold.stats().tunes, 2);
    assert_eq!(a.source, TuneSource::Fresh);
    assert!(path.exists(), "tuned TSV not written to {path:?}");

    // Fresh service on the same file: tuner never runs, configs match.
    let warm = service(Some(path.clone()), ExecMode::Simulate);
    assert_eq!(warm.tuned_len(), 2);
    let a2 = warm.plan("sepconv_row", &K40, (48, 48)).unwrap();
    let b2 = warm.plan("harris", &INTEL_I7, (48, 48)).unwrap();
    let s = warm.stats();
    assert_eq!(s.tunes, 0, "warm start must not re-tune");
    assert_eq!(s.warm_starts, 2);
    assert_eq!(a2.source, TuneSource::WarmStart);
    assert_eq!(a2.config, a.config);
    assert_eq!(b2.config, b.config);
    assert_eq!(b2.est_seconds, b.est_seconds);

    let _ = std::fs::remove_file(&path);
}

#[test]
fn bounded_queue_rejects_at_capacity() {
    let q: BoundedQueue<u32, u32> = BoundedQueue::new(3);
    for i in 0..3 {
        q.push(i, i).unwrap();
    }
    match q.push(9, 9) {
        Err(PushError::Full(v)) => assert_eq!(v, 9),
        other => panic!("expected Full, got {other:?}"),
    }
    // Draining one batch frees space again.
    q.pop_batch(1).unwrap();
    q.push(9, 9).unwrap();
}

#[test]
fn batcher_groups_same_key_requests() {
    let q: BoundedQueue<&str, u32> = BoundedQueue::new(16);
    let seq = [
        ("sobel", 0),
        ("conv2d", 1),
        ("sobel", 2),
        ("sobel", 3),
        ("conv2d", 4),
    ];
    for (k, v) in seq {
        q.push(k, v).unwrap();
    }
    // The head's key collects everything queued behind it, order kept.
    assert_eq!(q.pop_batch(8), Some(("sobel", vec![0, 2, 3])));
    assert_eq!(q.pop_batch(8), Some(("conv2d", vec![1, 4])));
    assert!(q.is_empty());
    q.close();
    assert_eq!(q.pop_batch(8), None);
}

#[test]
fn serve_end_to_end_sim_mode() {
    let svc = service(None, ExecMode::Simulate);
    let opts = LoadGenOpts {
        requests: 80,
        concurrency: 4,
        kernels: vec![
            "sepconv_row".to_string(),
            "conv2d".to_string(),
            "sobel".to_string(),
            "harris".to_string(),
        ],
        devices: ALL_DEVICES.to_vec(),
        grid: 32,
        queue_cap: 8, // small queue: backpressure path gets exercised
        max_batch: 8,
        workers_per_device: 2,
        obs_addr: None,
        ..Default::default()
    };
    let report = imagecl::serve::run_loadgen(svc.clone(), &opts).unwrap();
    assert_eq!(report.completed, 80);
    assert_eq!(report.errors, 0);
    assert_eq!(report.latencies_us.len(), 80);
    assert_eq!(report.per_kernel.len(), 4);
    assert!(report.per_kernel.values().all(|&c| c == 20), "{:?}", report.per_kernel);
    // 4 kernels × 4 devices unique keys.
    assert_eq!(report.stats.tunes, 16);
    assert_eq!(report.stats.plan_compiles, 16);
    assert!(report.stats.batches >= 16);
    assert!(report.stats.max_batch >= 1);

    // Same service again: pure cache hits, no new tuning.
    let report2 = imagecl::serve::run_loadgen(svc, &opts).unwrap();
    assert_eq!(report2.completed, 80);
    assert_eq!(report2.stats.tunes, 16);
    assert_eq!(report2.stats.plan_compiles, 16);
}

#[test]
fn serve_real_execution_produces_output() {
    // Small real run through the NDRange interpreter on the CPU device.
    let svc = service(None, ExecMode::Real);
    let opts = LoadGenOpts {
        requests: 8,
        concurrency: 2,
        kernels: vec!["sobel".to_string(), "sepconv_row".to_string()],
        devices: vec![&INTEL_I7],
        grid: 16,
        queue_cap: 16,
        max_batch: 4,
        workers_per_device: 2,
        obs_addr: None,
        ..Default::default()
    };
    let report = imagecl::serve::run_loadgen(svc, &opts).unwrap();
    assert_eq!(report.completed, 8);
    assert_eq!(report.errors, 0);
}

#[test]
fn warm_start_serving_run_skips_tuner_entirely() {
    // The acceptance path behind `imagecl serve` run twice: first run
    // tunes and persists; a second *process* (fresh service) serves the
    // same traffic with zero tuner invocations, observable in metrics.
    let path = temp_tsv("serve_warm");
    let _ = std::fs::remove_file(&path);
    let opts = LoadGenOpts {
        requests: 24,
        concurrency: 3,
        kernels: vec!["sepconv_row".to_string(), "sobel".to_string()],
        devices: vec![&K40, &INTEL_I7],
        grid: 32,
        queue_cap: 16,
        max_batch: 8,
        workers_per_device: 1,
        obs_addr: None,
        ..Default::default()
    };

    let first = service(Some(path.clone()), ExecMode::Simulate);
    let r1 = imagecl::serve::run_loadgen(first, &opts).unwrap();
    assert_eq!(r1.completed, 24);
    assert_eq!(r1.stats.tunes, 4);

    let second = service(Some(path.clone()), ExecMode::Simulate);
    let r2 = imagecl::serve::run_loadgen(second, &opts).unwrap();
    assert_eq!(r2.completed, 24);
    assert_eq!(r2.stats.tunes, 0, "second run must warm-start from {path:?}");
    assert_eq!(r2.stats.warm_starts, 4);

    let _ = std::fs::remove_file(&path);
}
