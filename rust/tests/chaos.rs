//! Chaos acceptance tests (PR 8): the full wire path under seeded fault
//! injection must lose nothing and answer nothing wrongly.
//!
//! What "nothing lost, nothing wrong" means here:
//!
//! * every submitted request gets exactly one reply (the client call
//!   returns exactly once, with a typed outcome — no hangs, no silent
//!   drops even when connections are chopped mid-flight);
//! * every `OK` reply's checksum is bit-identical to the serial
//!   tree-walk oracle for that (kernel, grid, seed) workload — fault
//!   paths (retries after injected panics/drops, quarantined plans) may
//!   change *where* a request executes, never *what* it computes;
//! * the injected faults actually fired (a zero-injection pass proves
//!   nothing — the injector's per-site counters are part of the
//!   acceptance), and graceful drain finishes all in-flight work.

use std::collections::BTreeMap;
use std::sync::Arc;

use imagecl::analysis::KernelInfo;
use imagecl::bench_defs::{args_checksum, kernel_by_id, workload};
use imagecl::devices::INTEL_I7;
use imagecl::exec::{Engine, PreparedKernel};
use imagecl::imagecl::frontend;
use imagecl::serve::metrics::percentile;
use imagecl::serve::net::{SubmitSpec, STATUS_SHUTDOWN};
use imagecl::serve::{
    ExecMode, FaultInjector, FaultSpec, KernelService, LoadGenOpts, NetClient,
    NetError, NetServer, NetServerOpts, ServiceConfig,
};
use imagecl::transform::lower;
use imagecl::tuner::{tune_on_simulator, Strategy};

const GRID: (usize, usize) = (16, 16);

fn service(exec: ExecMode, db: Option<std::path::PathBuf>) -> Arc<KernelService> {
    KernelService::new(ServiceConfig {
        strategy: Strategy::Random { evals: 20, seed: 1 },
        db_path: db,
        legacy_tsv: None,
        exec,
        plan_cache_cap: None,
        transfer_budget: 0,
        predict_budget: 0,
        explore_eps: 0.0,
    })
}

fn server(svc: Arc<KernelService>, workers: usize, max_batch: usize) -> NetServer {
    NetServer::start(
        svc,
        NetServerOpts {
            devices: vec![&INTEL_I7],
            workers_per_device: workers,
            queue_cap: 32,
            max_batch,
            ..Default::default()
        },
    )
    .unwrap()
}

/// Reference answer: run the workload through the serial tree-walk
/// interpreter and checksum the outputs. Any valid plan config computes
/// the same bits (the repo's bit-identity invariant), so one tuned plan
/// per kernel serves every seed.
fn oracle_checksums(kernels: &[&str], seeds: u64) -> BTreeMap<(String, u64), u64> {
    let mut out = BTreeMap::new();
    for kernel in kernels {
        let kdef = kernel_by_id(kernel).unwrap();
        let info = KernelInfo::analyze(frontend(kdef.source).unwrap());
        let res = tune_on_simulator(
            &info,
            &INTEL_I7,
            GRID,
            &Strategy::Random { evals: 5, seed: 1 },
        );
        let plan = lower(&info, &res.best).unwrap();
        for seed in 0..seeds {
            let mut args = workload(kernel, GRID.0, GRID.1, seed);
            let prepared = PreparedKernel::prepare(&plan, &args, GRID).unwrap();
            prepared.run_with(&mut args, Engine::TreeWalk).unwrap();
            out.insert((kernel.to_string(), seed), args_checksum(&args));
        }
    }
    out
}

/// The headline chaos run: real execution over TCP with panics injected
/// into kernels, connections dropped post-read, every tunedb disk append
/// failed, and a fixed pre-execution delay — all from one fixed seed.
/// Zero lost requests, zero wrong answers, clean drain.
#[test]
fn chaos_wire_path_loses_nothing_and_answers_match_the_oracle() {
    let kernels = ["sobel", "sepconv_row"];
    // 4 client threads × 5 seeds each → seeds 0..20 per kernel.
    let seeds_per_thread = 5u64;
    let oracle = oracle_checksums(&kernels, 4 * seeds_per_thread);

    let tsv = std::env::temp_dir()
        .join(format!("imagecl_chaos_{}.tsv", std::process::id()));
    let _ = std::fs::remove_file(&tsv);
    let svc = service(ExecMode::Real, Some(tsv.clone()));
    // tunedb_io=1 makes *every* disk append fail — serving must run on
    // memory alone. The panic/drop rates are high enough that the fixed
    // seed's first ~60 draws contain hits with near-certainty.
    svc.set_faults(FaultInjector::new(
        FaultSpec::parse("exec_panic=0.15,net_drop=0.2,tunedb_io=1.0,exec_delay=200us,seed=42")
            .unwrap(),
    ));
    let srv = server(svc.clone(), 2, 4);
    let addr = srv.addr().to_string();

    // 4 client threads, each its own connection and retry stream.
    let replies: Vec<(String, u64, Result<imagecl::serve::net::NetReply, String>)> =
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..4u64)
                .map(|t| {
                    let addr = addr.clone();
                    let kernels = &kernels;
                    scope.spawn(move || {
                        let mut client = NetClient::new(&addr, 100 + t);
                        // Enough attempts that exhausting the retry
                        // budget under these fault rates is a
                        // non-event (p(fail)^12 per request).
                        client.max_attempts = 12;
                        let mut got = Vec::new();
                        for i in 0..seeds_per_thread {
                            for &kernel in kernels {
                                let seed = t * seeds_per_thread + i;
                                let spec = SubmitSpec::new(kernel, GRID, seed);
                                let r = client
                                    .submit(&spec)
                                    .map_err(|e| e.to_string());
                                got.push((kernel.to_string(), seed, r));
                            }
                        }
                        got
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });

    // Exactly one outcome per request, all of them successes: injected
    // drops/panics are absorbed by the client's bounded retry, never by
    // losing the request.
    assert_eq!(replies.len(), 4 * seeds_per_thread as usize * kernels.len());
    for (kernel, seed, r) in &replies {
        let reply = r.as_ref().unwrap_or_else(|e| {
            panic!("{kernel}/{seed} lost to chaos: {e}");
        });
        assert!(reply.is_ok(), "{kernel}/{seed}: {}", reply.code());
        // Bit-identity: the reply's checksum matches the tree-walk
        // oracle regardless of which path (plan cache, retry after
        // panic, quarantine fallback) served it.
        let want = oracle[&(kernel.clone(), *seed)];
        assert_eq!(
            reply.checksum, want,
            "{kernel}/{seed}: wire answer diverged from the oracle"
        );
    }

    // The chaos actually happened: the deterministic streams fired at
    // every site (tunedb_io=1.0 fires on the first append; the seeded
    // panic/drop streams fire well within this many draws).
    let (panics, tunedb, drops) = svc.faults().injected();
    assert!(panics + drops > 0, "no exec/net faults fired — vacuous run");
    assert!(tunedb > 0, "no tunedb appends attempted — vacuous run");
    let stats = svc.stats();
    assert_eq!(stats.exec_panics, panics, "every injected panic was caught");
    assert_eq!(stats.net_drops, drops);
    assert!(stats.net_requests >= replies.len() as u64);

    // Graceful drain via the wire: stop accepting, finish in-flight,
    // then the process-side join.
    let mut closer = NetClient::new(&addr, 999);
    closer.shutdown_server().unwrap();
    srv.wait();
    srv.shutdown();
    let mut late = NetClient::new(&addr, 1000);
    assert!(late.submit(&SubmitSpec::new("sobel", GRID, 0)).is_err());
    let _ = std::fs::remove_file(&tsv);
}

/// A plan that panics on every execution is quarantined after the
/// threshold and the key reroutes to the tree-walk fallback — observed
/// end-to-end through the TCP client's retry loop.
#[test]
fn chaos_quarantine_trips_over_the_wire() {
    let svc = service(ExecMode::Simulate, None);
    svc.set_faults(FaultInjector::new(FaultSpec {
        exec_panic: 1.0,
        seed: 7,
        ..Default::default()
    }));
    let srv = server(svc.clone(), 1, 1);
    let mut client = NetClient::new(&srv.addr().to_string(), 5);

    // Attempts 1..=3 panic (each caught by worker isolation), tripping
    // the quarantine; the retry loop's 4th attempt is served by the
    // fallback. One submit call, one OK reply.
    let reply = client.submit(&SubmitSpec::new("sobel", GRID, 0)).unwrap();
    assert!(reply.is_ok(), "{}", reply.code());
    let stats = svc.stats();
    assert_eq!(stats.exec_panics, KernelService::QUARANTINE_THRESHOLD);
    assert_eq!(stats.quarantines, 1);

    // The key stays quarantined: later requests succeed first try and
    // inject nothing further.
    let before = svc.faults().injected().0;
    for seed in 1..4 {
        assert!(client.submit(&SubmitSpec::new("sobel", GRID, seed)).unwrap().is_ok());
    }
    assert_eq!(svc.faults().injected().0, before);
    srv.shutdown();
}

/// Drain during a burst: every submit issued around the shutdown frame
/// still gets exactly one typed outcome — `OK` for whatever was
/// admitted, `SHUTDOWN` (or a terminal transport error once the listener
/// is gone) for the rest. Nothing hangs, nothing is half-answered.
#[test]
fn chaos_graceful_drain_mid_burst_loses_no_request() {
    let svc = service(ExecMode::Simulate, None);
    // A per-request delay so the burst is still in the queues when the
    // shutdown frame lands.
    svc.set_faults(FaultInjector::new(
        FaultSpec::parse("exec_delay=2ms,seed=3").unwrap(),
    ));
    let srv = server(svc.clone(), 1, 2);
    let addr = srv.addr().to_string();

    let outcomes: Vec<Result<u8, String>> = std::thread::scope(|scope| {
        let clients: Vec<_> = (0..3u64)
            .map(|t| {
                let addr = addr.clone();
                scope.spawn(move || {
                    let mut client = NetClient::new(&addr, 20 + t);
                    (0..10u64)
                        .map(|seed| match client
                            .submit(&SubmitSpec::new("sobel", GRID, seed))
                        {
                            Ok(r) => Ok(r.status),
                            Err(NetError::Rejected(r)) => Ok(r.status),
                            Err(NetError::Transport(e)) => Err(e),
                        })
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        // Let the burst get going, then pull the plug mid-flight.
        std::thread::sleep(std::time::Duration::from_millis(15));
        let mut closer = NetClient::new(&addr, 99);
        closer.shutdown_server().unwrap();
        clients.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    srv.wait();
    srv.shutdown();

    assert_eq!(outcomes.len(), 30, "every submit returned exactly once");
    let ok = outcomes.iter().filter(|o| matches!(o, Ok(0))).count();
    let refused = outcomes
        .iter()
        .filter(|o| matches!(o, Ok(s) if *s == STATUS_SHUTDOWN))
        .count();
    let transport = outcomes.iter().filter(|o| o.is_err()).count();
    assert_eq!(ok + refused + transport, 30);
    assert!(ok >= 1, "requests admitted before the drain completed");
    // Unexpected statuses (EXEC/BADREQ/...) would mean drain corrupted
    // an answer; there must be none.
    assert!(outcomes
        .iter()
        .all(|o| !matches!(o, Ok(s) if *s != 0 && *s != STATUS_SHUTDOWN)));
}

/// PR 10 chaos: every store append is torn *and* byte-flipped (the
/// worst mid-write kill), yet the server answers every request; a
/// restart over the damaged store quarantines the damage, keeps every
/// intact record, and `fsck --repair`'s snapshot rewrite converges the
/// file to clean — zero accepted requests lost across the kill-restart.
#[test]
fn chaos_kill_restart_over_damaged_store_loses_no_request() {
    let tsv = std::env::temp_dir()
        .join(format!("imagecl_chaos_killrestart_{}.tsv", std::process::id()));
    let side = imagecl::tunedb::quarantine_path(&tsv);
    let _ = std::fs::remove_file(&tsv);
    let _ = std::fs::remove_file(&side);

    // Generation 1: serve for real while every journal append is
    // damaged at the byte level.
    let svc = service(ExecMode::Real, Some(tsv.clone()));
    svc.set_faults(FaultInjector::new(
        FaultSpec::parse("tunedb_torn=1.0,tunedb_corrupt=1.0,seed=11").unwrap(),
    ));
    let srv = server(svc.clone(), 2, 4);
    let addr = srv.addr().to_string();
    let mut client = NetClient::new(&addr, 1);
    for seed in 0..6u64 {
        for kernel in ["sobel", "sepconv_row"] {
            let reply = client.submit(&SubmitSpec::new(kernel, GRID, seed)).unwrap();
            assert!(reply.is_ok(), "{kernel}/{seed}: {}", reply.code());
        }
    }
    // The journal damage actually landed (tuning outcomes + wall
    // samples were appended, each one torn/corrupted).
    let (torn, corrupt) = svc.faults().injected_tunedb_damage();
    assert!(torn > 0 && corrupt > 0, "no journal damage injected — vacuous run");
    // The legacy 3-site view is unaffected by the new sites.
    assert_eq!(svc.faults().injected(), (0, 0, 0));
    srv.shutdown();
    drop(svc);

    // The "kill": the process is gone, the store carries real byte
    // damage. Recovery must quarantine — not refuse, not silently drop
    // everything.
    let report = imagecl::tunedb::fsck(&tsv).unwrap();
    assert!(!report.clean(), "torn appends must be visible to fsck");
    assert!(report.records > 0, "intact records must survive the damage");
    let intact = report.records;

    // Repair converges the store, stashing damage in the sidecar.
    let repaired = imagecl::tunedb::fsck_repair(&tsv).unwrap();
    assert_eq!(repaired.quarantined.len(), report.quarantined.len());
    let after = imagecl::tunedb::fsck(&tsv).unwrap();
    assert!(after.clean());
    assert_eq!(after.records, intact);
    assert!(side.exists(), "quarantined lines are stashed, not destroyed");

    // Generation 2 over the same store dir: loads clean, serves again —
    // the restart lost no accepted request and no intact knowledge.
    let svc2 = service(ExecMode::Real, Some(tsv.clone()));
    assert_eq!(
        svc2.db().obs.fsck_quarantined.load(std::sync::atomic::Ordering::Relaxed),
        0,
        "repaired store must load without quarantines"
    );
    let srv2 = server(svc2.clone(), 1, 2);
    let mut client2 = NetClient::new(&srv2.addr().to_string(), 2);
    let reply = client2.submit(&SubmitSpec::new("sobel", GRID, 99)).unwrap();
    assert!(reply.is_ok(), "{}", reply.code());
    srv2.shutdown();

    let _ = std::fs::remove_file(&tsv);
    let _ = std::fs::remove_file(&side);
}

/// Remote serving stays in the same latency class as in-process serving
/// at the same offered load: p99 within 2x, plus an absolute allowance
/// for the two loopback syscalls (dominant at sub-millisecond simulated
/// latencies).
#[test]
fn chaos_remote_p99_within_budget_of_in_process() {
    let opts = LoadGenOpts {
        requests: 120,
        concurrency: 4,
        kernels: vec!["sobel".to_string(), "sepconv_row".to_string()],
        devices: vec![&INTEL_I7],
        grid: GRID.0,
        queue_cap: 64,
        max_batch: 8,
        workers_per_device: 2,
        ..Default::default()
    };

    let local = service(ExecMode::Simulate, None);
    let in_process = imagecl::serve::run_loadgen(local, &opts).unwrap();
    assert_eq!(in_process.completed, opts.requests);

    let remote_svc = service(ExecMode::Simulate, None);
    let srv = server(remote_svc.clone(), 2, 8);
    let remote_opts =
        LoadGenOpts { remote: Some(srv.addr().to_string()), ..opts.clone() };
    let remote = imagecl::serve::run_loadgen(remote_svc, &remote_opts).unwrap();
    srv.shutdown();
    assert_eq!(remote.completed, opts.requests);

    let in_p99 = percentile(&in_process.latencies_us, 99.0);
    let tcp_p99 = percentile(&remote.latencies_us, 99.0);
    let budget = (in_p99 * 2).max(in_p99 + 20_000);
    assert!(
        tcp_p99 <= budget,
        "remote p99 {tcp_p99}us vs in-process {in_p99}us (budget {budget}us)"
    );
}
