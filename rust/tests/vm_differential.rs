//! Differential sweep: the bytecode VM must be **bit-identical** to the
//! tree-walking interpreter (the retained oracle) for every gallery and
//! paper kernel across a grid of tuning configurations — coarsening,
//! interleaved mapping, local/image/constant memory, unrolling — plus
//! the clamped-boundary and uchar-wrap edge cases. The engine axis spans
//! the full VM ladder: `VmUnopt` (no optimizer, scalar), `VmScalar`
//! (optimizer on, batching off) and `Vm` (optimizer + batched row
//! interpretation), so the optimizer pipeline and the batched
//! interpreter are each individually pinned to the oracle.
//!
//! "Bit-identical" is literal: outputs are compared as `f64::to_bits`,
//! not within a tolerance. The VM is only allowed to exist because this
//! holds.

use std::collections::BTreeMap;

use imagecl::analysis::KernelInfo;
use imagecl::bench_defs::gallery::{gallery_workload, GALLERY};
use imagecl::bench_defs::{self, workload};
use imagecl::exec::{execute_with, Arg, Buffer, Engine, ImageBuf};
use imagecl::imagecl::{frontend, ScalarType};
use imagecl::transform::{lower, TuningConfig};

/// All image/array payloads of an argument map, as raw bits.
fn bits(args: &BTreeMap<String, Arg>) -> Vec<(String, Vec<u64>)> {
    args.iter()
        .filter_map(|(name, a)| {
            let data = match a {
                Arg::Image(img) => &img.buf.data,
                Arg::Array(b) => &b.data,
                Arg::Scalar(_) => return None,
            };
            Some((name.clone(), data.iter().map(|v| v.to_bits()).collect()))
        })
        .collect()
}

/// Every VM variant the differential grid pins to the oracle.
const VM_ENGINES: [Engine; 3] = [Engine::VmUnopt, Engine::VmScalar, Engine::Vm];

/// Run `src` under `cfg` on the oracle and every VM variant
/// (unoptimized, optimizer-only, optimizer+batched) and assert exact
/// agreement. The VM engines are hard: a plan the VM cannot lower fails
/// the test — the whole kernel set must stay on the fast path.
fn assert_engines_agree(
    what: &str,
    src: &str,
    cfg: &TuningConfig,
    mk_args: &dyn Fn() -> BTreeMap<String, Arg>,
    grid: (usize, usize),
) {
    let info = KernelInfo::analyze(frontend(src).unwrap());
    let plan = lower(&info, cfg).unwrap_or_else(|e| panic!("{what} under `{cfg}`: {e}"));
    let mut tree_args = mk_args();
    execute_with(&plan, &mut tree_args, grid, Engine::TreeWalk)
        .unwrap_or_else(|e| panic!("{what} under `{cfg}` (tree): {e}"));
    let t = bits(&tree_args);
    for engine in VM_ENGINES {
        let mut vm_args = mk_args();
        execute_with(&plan, &mut vm_args, grid, engine)
            .unwrap_or_else(|e| panic!("{what} under `{cfg}` ({engine:?}): {e}"));
        let v = bits(&vm_args);
        assert_eq!(
            t.len(),
            v.len(),
            "{what} under `{cfg}` ({engine:?}): buffer sets differ"
        );
        for ((name, tb), (vname, vb)) in t.iter().zip(&v) {
            assert_eq!(name, vname);
            assert_eq!(
                tb.len(),
                vb.len(),
                "{what}/{name} under `{cfg}` ({engine:?}): lengths differ"
            );
            for i in 0..tb.len() {
                assert_eq!(
                    tb[i],
                    vb[i],
                    "{what} under `{cfg}` ({engine:?}): `{name}` differs at {i}: \
                     tree {} vs vm {}",
                    f64::from_bits(tb[i]),
                    f64::from_bits(vb[i])
                );
            }
        }
    }
}

/// The deterministic config grid for a kernel: every combination of
/// coarsening × mapping, crossed with each memory-space choice the
/// kernel is eligible for, plus full-unroll variants.
fn config_grid(info: &KernelInfo) -> Vec<TuningConfig> {
    let shapes: [(usize, usize, usize, usize, bool); 5] = [
        (16, 16, 1, 1, false),
        (8, 4, 2, 2, false),
        (4, 4, 3, 2, true),
        (8, 2, 1, 4, true),
        (2, 2, 5, 1, false),
    ];
    let mut out = Vec::new();
    for &(wx, wy, cx, cy, il) in &shapes {
        let base = TuningConfig {
            wg: [wx, wy],
            coarsen: [cx, cy],
            interleaved: il,
            ..Default::default()
        };
        // Memory-space variants: global, local (eligible images), image
        // (eligible), each with constant memory on eligible arrays.
        let mut variants = vec![base.clone()];
        let mut lmem = base.clone();
        let mut any_lmem = false;
        let mut imem = base.clone();
        let mut any_imem = false;
        for p in &info.prog.kernel.params {
            if info.local_mem_eligible(&p.name) {
                lmem.local_mem.insert(p.name.clone(), true);
                any_lmem = true;
            }
            if info.image_mem_eligible(&p.name) {
                imem.image_mem.insert(p.name.clone(), true);
                any_imem = true;
            }
            for v in [&mut lmem, &mut imem] {
                if info.constant_mem_eligible(&p.name, 64 << 10) {
                    v.constant_mem.insert(p.name.clone(), true);
                }
            }
        }
        if any_lmem {
            variants.push(lmem);
        }
        if any_imem {
            variants.push(imem);
        }
        // Unrolled flavor of each variant (full unroll of every
        // unrollable loop).
        let unrolled: Vec<TuningConfig> = variants
            .iter()
            .filter(|_| !info.unrollable_loops().is_empty())
            .map(|v| {
                let mut u = v.clone();
                for l in info.unrollable_loops() {
                    u.unroll.insert(l.id, 0);
                }
                u
            })
            .collect();
        variants.extend(unrolled);
        out.extend(variants);
    }
    out
}

#[test]
fn gallery_kernels_bit_identical_across_config_grid() {
    // Odd size so the rounding guard paths execute.
    let (w, h) = (33, 27);
    for (name, src) in GALLERY {
        let info = KernelInfo::analyze(frontend(src).unwrap());
        let cfgs = config_grid(&info);
        assert!(cfgs.len() >= 5, "{name}: degenerate config grid");
        for cfg in &cfgs {
            assert_engines_agree(
                name,
                src,
                cfg,
                &|| gallery_workload(name, w, h, 1234),
                (w, h),
            );
        }
    }
}

#[test]
fn paper_kernels_bit_identical_across_config_grid() {
    // conv2d covers the uchar-wrap store path and the clamped boundary;
    // sepconv the constant boundary + constant memory; sobel/harris the
    // multi-output and 2×2-block shapes.
    let (w, h) = (21, 17);
    for kid in ["sepconv_row", "sepconv_col", "conv2d", "sobel", "harris"] {
        let src = bench_defs::kernel_by_id(kid).unwrap().source;
        let info = KernelInfo::analyze(frontend(src).unwrap());
        for cfg in &config_grid(&info) {
            assert_engines_agree(kid, src, cfg, &|| workload(kid, w, h, 77), (w, h));
        }
    }
}

#[test]
fn uchar_wrap_bit_identical() {
    // The C-cast wrap on narrow stores (300 → 44 in a uchar image) must
    // round-trip the VM's int register file exactly.
    let src = "void k(Image<uchar> a, Image<uchar> b) {\n\
                 a[idx][idy] = 300;\n\
                 b[idx][idy] = (uchar)(a[idx][idy] + idx * 251 - idy * 509);\n\
               }";
    let mk = || {
        let mut args = BTreeMap::new();
        args.insert("a".to_string(), Arg::Image(ImageBuf::new(ScalarType::U8, 13, 9)));
        args.insert("b".to_string(), Arg::Image(ImageBuf::new(ScalarType::U8, 13, 9)));
        args
    };
    for cfg_s in ["wg=16x16 px=1x1 map=blocked", "wg=4x2 px=3x2 map=interleaved"] {
        let cfg = TuningConfig::parse(cfg_s).unwrap();
        assert_engines_agree("uchar_wrap", src, &cfg, &mk, (13, 9));
    }
}

#[test]
fn clamped_boundary_bit_identical() {
    // Clamped reads index-clamp at the edges — all-int min/max chains in
    // the VM's int file.
    let src = "#pragma imcl grid(in)\n\
               #pragma imcl boundary(in, clamped)\n\
               void k(Image<float> in, Image<float> out) {\n\
                 out[idx][idy] = in[idx - 2][idy + 3] + in[idx + 2][idy - 3];\n\
               }";
    let mk = || {
        let mut args = BTreeMap::new();
        let input = ImageBuf::from_fn(ScalarType::F32, 19, 11, |x, y| (x * 31 + y * 7) as f64);
        args.insert("in".to_string(), Arg::Image(input));
        args.insert("out".to_string(), Arg::Image(ImageBuf::new(ScalarType::F32, 19, 11)));
        args
    };
    for cfg_s in [
        "wg=16x16 px=1x1 map=blocked",
        "wg=8x4 px=2x2 map=interleaved",
        "wg=8x8 px=1x1 map=blocked lmem=in",
    ] {
        let cfg = TuningConfig::parse(cfg_s).unwrap();
        assert_engines_agree("clamped", src, &cfg, &mk, (19, 11));
    }
}

#[test]
fn parallel_dispatch_bit_identical_at_scale() {
    // Large enough (161×121 > the VM's parallel threshold) that proven-
    // independent work-groups actually fan out across threads; the
    // result must still match the serial oracle bit-for-bit — and not
    // just under the naive config: coarsening, interleaved mapping and
    // local-memory staging all reshape which pixels each work-item owns,
    // so each must hold up under concurrent group execution too. Odd
    // sizes keep the rounding-guard threads in play.
    let (w, h) = (161, 121);
    let src = imagecl::bench_defs::gallery::BLUR;
    let info = KernelInfo::analyze(frontend(src).unwrap());
    let plan = lower(&info, &TuningConfig::default()).unwrap();
    assert!(plan.parallel_groups, "blur should prove group independence");
    for cfg_s in [
        "wg=16x16 px=1x1 map=blocked",
        "wg=8x4 px=3x2 map=blocked",
        "wg=8x8 px=2x2 map=interleaved",
        "wg=8x8 px=1x1 map=blocked lmem=in",
        "wg=4x4 px=2x4 map=interleaved lmem=in unroll=1:0,2:0",
    ] {
        let cfg = TuningConfig::parse(cfg_s).unwrap();
        assert_engines_agree(
            "blur-parallel",
            src,
            &cfg,
            &|| gallery_workload("blur", w, h, 9),
            (w, h),
        );
    }
}

#[test]
fn row_partitioned_and_strided_plans_bit_identical() {
    // Few large groups (heavy coarsening): the driver may partition at
    // work-item-row granularity instead of whole groups; results must
    // still match the serial oracle bit-for-bit across every engine.
    let src = imagecl::bench_defs::gallery::BLUR;
    for cfg_s in ["wg=16x16 px=8x8 map=blocked", "wg=32x8 px=4x8 map=blocked"] {
        let cfg = TuningConfig::parse(cfg_s).unwrap();
        assert_engines_agree(
            "blur-row-partition",
            src,
            &cfg,
            &|| gallery_workload("blur", 256, 256, 3),
            (256, 256),
        );
    }
    // Strided writes (each thread owns an interleaved element pair) are
    // newly parallel + batchable under the affine disjointness proof.
    let strided = "#pragma imcl grid(256, 1)\n\
        void k(float* a, float* b) {\n\
          b[idx * 2] = a[idx] * 2.0f;\n\
          b[idx * 2 + 1] = a[idx] + 1.0f;\n\
        }";
    let info = KernelInfo::analyze(frontend(strided).unwrap());
    let plan = lower(&info, &TuningConfig::default()).unwrap();
    assert!(plan.parallel_groups, "strided writes should prove disjoint");
    let mk = || {
        let mut args = BTreeMap::new();
        args.insert(
            "a".to_string(),
            Arg::Array(Buffer::from_f64(
                ScalarType::F32,
                (0..256).map(|i| (i % 37) as f64).collect(),
            )),
        );
        args.insert("b".to_string(), Arg::Array(Buffer::new(ScalarType::F32, 512)));
        args
    };
    for cfg_s in ["wg=16x16 px=1x1 map=blocked", "wg=64x1 px=2x1 map=blocked"] {
        let cfg = TuningConfig::parse(cfg_s).unwrap();
        assert_engines_agree("strided", strided, &cfg, &mk, (256, 1));
    }
}

#[test]
fn scalar_and_array_params_bit_identical() {
    // Scalars inline as constants; runtime-indexed arrays stay loads.
    let src = "#pragma imcl grid(a)\n\
               #pragma imcl array_size(lut, 4)\n\
               void k(Image<float> a, float* lut, float gain, int shift) {\n\
                 int i = (idx + shift) % 4;\n\
                 a[idx][idy] = lut[i] * gain + (float)(i);\n\
               }";
    let mk = || {
        let mut args = BTreeMap::new();
        args.insert("a".to_string(), Arg::Image(ImageBuf::new(ScalarType::F32, 12, 10)));
        args.insert(
            "lut".to_string(),
            Arg::Array(Buffer::from_f64(ScalarType::F32, vec![0.5, 1.5, 2.5, 3.5])),
        );
        args.insert("gain".to_string(), Arg::Scalar(imagecl::exec::Value::F(1.25)));
        args.insert("shift".to_string(), Arg::Scalar(imagecl::exec::Value::I(3)));
        args
    };
    for cfg_s in ["wg=16x16 px=1x1 map=blocked", "wg=4x4 px=2x2 map=interleaved cmem=lut"] {
        let cfg = TuningConfig::parse(cfg_s).unwrap();
        assert_engines_agree("scalar_array", src, &cfg, &mk, (12, 10));
    }
}
