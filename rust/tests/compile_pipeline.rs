//! Full compiler pipeline integration: ImageCL source → frontend →
//! analysis → lowering → OpenCL text + host code, for every benchmark
//! kernel under representative configs. (Execution equivalence lives in
//! `exec_sweep.rs`; this file checks the *artifacts* of compilation.)

use imagecl::analysis::KernelInfo;
use imagecl::bench_defs::ALL;
use imagecl::imagecl::frontend;
use imagecl::transform::{
    emit_fast_filter, emit_opencl, emit_standalone_host, lower, TuningConfig,
};

#[test]
fn every_benchmark_kernel_compiles_to_opencl() {
    for b in &ALL {
        for k in b.kernels {
            let info = KernelInfo::analyze(frontend(k.source).unwrap());
            for cfg_s in [
                "wg=16x16 px=1x1 map=blocked",
                "wg=64x4 px=4x2 map=interleaved",
            ] {
                let cfg = TuningConfig::parse(cfg_s).unwrap();
                let plan = lower(&info, &cfg)
                    .unwrap_or_else(|e| panic!("{}: {e}", k.id));
                let cl = emit_opencl(&plan);
                assert!(cl.contains(&format!("__kernel void {}(", plan.name)), "{cl}");
                // Host code generation must succeed for both flavours.
                let host = emit_standalone_host(&plan);
                assert!(host.contains(&format!("int {}_run(", plan.name)));
                let filt = emit_fast_filter(&plan);
                assert!(filt.contains("ProcessObject"));
            }
        }
    }
}

#[test]
fn generated_opencl_is_structurally_sound() {
    // Balanced braces/parens in every emitted kernel (cheap syntax guard —
    // we cannot run a real OpenCL compiler in this environment).
    for b in &ALL {
        for k in b.kernels {
            let info = KernelInfo::analyze(frontend(k.source).unwrap());
            let mut cfg = TuningConfig::default();
            for p in &info.prog.kernel.params {
                if info.local_mem_eligible(&p.name) {
                    cfg.local_mem.insert(p.name.clone(), true);
                }
                if info.constant_mem_eligible(&p.name, 64 << 10) {
                    cfg.constant_mem.insert(p.name.clone(), true);
                }
            }
            let cl = emit_opencl(&lower(&info, &cfg).unwrap());
            let balance = |open: char, close: char| {
                cl.chars().filter(|&c| c == open).count()
                    == cl.chars().filter(|&c| c == close).count()
            };
            assert!(balance('{', '}'), "{}:\n{cl}", k.id);
            assert!(balance('(', ')'), "{}:\n{cl}", k.id);
            assert!(balance('[', ']'), "{}:\n{cl}", k.id);
            assert!(!cl.contains("__read_tex"), "{cl}");
            assert!(!cl.contains("__write_tex"), "{cl}");
        }
    }
}

#[test]
fn paper_listing1_compiles_verbatim() {
    // Listing 1 from the paper, character-for-character structure.
    let src = r#"
#pragma imcl grid(input)
void blur(Image<float> input, Image<float> out) {
  float sum = 0.0;
  for (int i = -1; i < 2; i++) {
    for (int j = -1; j < 2; j++) {
      sum += input[idx + i][idy + j];
    }
  }
  out[idx][idy] = sum / 9.0;
}
"#;
    let info = KernelInfo::analyze(frontend(src).unwrap());
    assert!(info.local_mem_eligible("input"));
    let cl = emit_opencl(&lower(&info, &TuningConfig::default()).unwrap());
    assert!(cl.contains("__kernel void blur("));
}
