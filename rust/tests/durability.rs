//! Durability acceptance (PR 10): the crash-consistency and replica-merge
//! guarantees of the checksummed tunedb journal, proven the blunt way —
//! kill the file at *every* byte offset, merge replicas in every order —
//! plus the warm-restart serving contract (a drained server's checkpoint
//! lets its successor answer its first request from a cached plan).

use std::path::PathBuf;

use imagecl::devices::{DeviceSpec, ALL_DEVICES, INTEL_I7, K40};
use imagecl::serve::{ExecMode, KernelService, ServiceConfig, TuneSource};
use imagecl::testutil::Rng;
use imagecl::transform::TuningConfig;
use imagecl::tunedb::{
    device_fingerprint, fsck, fsck_repair, merge_files, merge_records, quarantine_path, TuneDb,
    TuneRecord,
};
use imagecl::tuner::Strategy;

/// Fresh per-test scratch directory (tests run concurrently in one
/// process, and some leave sidecar files beside the store).
fn scratch(tag: &str) -> PathBuf {
    let p = std::env::temp_dir()
        .join(format!("imagecl_durability_{}_{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&p);
    std::fs::create_dir_all(&p).unwrap();
    p
}

fn rec(
    kernel: &str,
    dev: &'static DeviceSpec,
    n: usize,
    secs: f64,
    best: bool,
    wall: bool,
) -> TuneRecord {
    let mut config = TuningConfig::default();
    config.wg = [32, 4];
    TuneRecord {
        kernel: kernel.to_string(),
        device: dev.name,
        dev_fp: device_fingerprint(dev),
        grid: (n, n),
        seconds: secs,
        best,
        wall,
        config,
        features: vec![3.0, 1.0],
        seq: 0,
        kfeat: [0.0; 3],
    }
}

/// The headline crash-consistency property: truncate the journal at
/// *every* byte offset (a kill can land anywhere) and at each offset the
/// load must keep exactly the records whose lines are intact, quarantine
/// exactly the torn fragment, never error, and repair back to a clean
/// store. "Loses at most the last un-synced append", proven exhaustively.
#[test]
fn kill_at_every_byte_offset_loses_at_most_the_torn_tail() {
    let dir = scratch("kill");
    let store = dir.join("store.tsv");
    {
        let db = TuneDb::open(&store);
        db.record(rec("sobel", &K40, 64, 1e-4, true, false));
        db.record(rec("sobel", &K40, 128, 2e-4, true, false));
        db.record(rec("sepconv_row", &INTEL_I7, 64, 3e-4, false, true));
        db.record(rec("conv2d", &INTEL_I7, 256, 4e-4, true, false));
    }
    let full = std::fs::read_to_string(&store).unwrap();
    assert!(full.ends_with('\n'));

    // Per-line byte spans [start, end) (end includes the newline).
    let mut spans: Vec<(usize, usize, String)> = Vec::new();
    let mut start = 0usize;
    for line in full.split_inclusive('\n') {
        let text = line.trim_end_matches('\n').to_string();
        spans.push((start, start + line.len(), text));
        start += line.len();
    }

    let cut_path = dir.join("cut.tsv");
    let side = quarantine_path(&cut_path);
    for cut in 0..=full.len() {
        std::fs::write(&cut_path, &full.as_bytes()[..cut]).unwrap();

        // First-principles expectation: complete non-comment lines are
        // records; a non-empty trailing fragment is quarantined unless it
        // still reads as a plain comment (a torn `#!` directive is
        // damage — it must not pass as an opaque comment).
        let mut want_records = 0usize;
        let mut want_quarantined = 0usize;
        for (s, e, text) in &spans {
            if cut >= *e {
                if !text.is_empty() && !text.starts_with('#') {
                    want_records += 1;
                }
            } else {
                if cut > *s {
                    let frag = &full[*s..cut];
                    if frag == text {
                        // Only the newline is missing — the line itself
                        // is whole and parses (head lines stay head).
                        if !text.starts_with('#') {
                            want_records += 1;
                        }
                    } else {
                        let comment = frag.starts_with('#') && !frag.starts_with("#!");
                        if !comment {
                            want_quarantined = 1;
                        }
                    }
                }
                break;
            }
        }

        let report = fsck(&cut_path).unwrap();
        assert_eq!(report.records, want_records, "cut at byte {cut}");
        assert_eq!(
            report.quarantined.len(),
            want_quarantined,
            "cut at byte {cut}: {:?}",
            report.quarantined
        );
        assert_eq!(report.stale, 0, "cut at byte {cut}");

        // The serving load path agrees and never refuses to start.
        let db = TuneDb::open(&cut_path);
        assert_eq!(db.len(), want_records, "cut at byte {cut}");

        // Repair converges: damage moves to the sidecar, the rewritten
        // store is clean and keeps every intact record.
        let repaired = fsck_repair(&cut_path).unwrap();
        assert_eq!(repaired.records, want_records, "cut at byte {cut}");
        let after = fsck(&cut_path).unwrap();
        assert!(after.clean(), "cut at byte {cut}: repair left damage");
        assert_eq!(after.records, want_records, "cut at byte {cut}");
    }
    // Damaged fragments were stashed, not destroyed.
    assert!(std::fs::read_to_string(&side).unwrap().contains("cut.tsv"));
    let _ = std::fs::remove_dir_all(&dir);
}

/// Merge fuzz: random replica record sets (overlapping keys, conflicting
/// measurements, wall vs sim, duplicate outcomes) merged under every
/// rotation of the input order produce *byte-identical* stores, re-merge
/// is a no-op, and the pure resolution is order-independent. This is the
/// CRDT claim behind `imagecl tunedb merge`: replicas can cross-pollinate
/// in any topology and converge.
#[test]
fn merge_fuzz_shuffled_replica_orders_converge_to_identical_stores() {
    let dir = scratch("fuzz");
    let kernels = ["sobel", "sepconv_row", "conv2d", "harris"];
    let grids = [16usize, 32, 64, 128];
    let wgs = [[16usize, 4], [32, 8], [64, 4]];
    for case in 0..6u64 {
        let mut rng = Rng::new(0xD00D + case);

        // Three replicas with deliberately colliding keys.
        let mut replicas: Vec<Vec<TuneRecord>> = Vec::new();
        for _ in 0..3 {
            let n = 4 + rng.below(8);
            let mut set = Vec::new();
            for _ in 0..n {
                let mut r = rec(
                    rng.pick(&kernels),
                    *rng.pick(&ALL_DEVICES),
                    *rng.pick(&grids),
                    1e-4 * (1 + rng.below(40)) as f64,
                    rng.flip(),
                    rng.flip(),
                );
                r.config.wg = *rng.pick(&wgs);
                r.config.interleaved = rng.flip();
                set.push(r);
            }
            replicas.push(set);
        }

        // Persist each replica through the journaling path (assigns
        // real sequence numbers, stamps kernel features).
        let paths: Vec<PathBuf> = replicas
            .iter()
            .enumerate()
            .map(|(i, set)| {
                let p = dir.join(format!("case{case}_replica{i}.tsv"));
                let db = TuneDb::open(&p);
                for r in set {
                    db.record(r.clone());
                }
                p
            })
            .collect();

        // Every rotation of the merge order → byte-identical output.
        let orders = [[0usize, 1, 2], [2, 0, 1], [1, 2, 0]];
        let mut outs = Vec::new();
        for (oi, order) in orders.iter().enumerate() {
            let dst = dir.join(format!("case{case}_merge{oi}.tsv"));
            let srcs: Vec<PathBuf> = order.iter().map(|&i| paths[i].clone()).collect();
            let stats = merge_files(&dst, &srcs).unwrap();
            assert_eq!(stats.inputs, 3, "case {case}");
            assert_eq!(stats.quarantined, 0, "case {case}");
            assert!(stats.merged <= stats.records_in, "case {case}");
            outs.push(std::fs::read(&dst).unwrap());
        }
        assert_eq!(outs[0], outs[1], "case {case}: merge order changed the store");
        assert_eq!(outs[0], outs[2], "case {case}: merge order changed the store");

        // Idempotence: merging the same replicas into an already-merged
        // destination changes nothing.
        let dst = dir.join(format!("case{case}_merge0.tsv"));
        merge_files(&dst, &paths).unwrap();
        assert_eq!(std::fs::read(&dst).unwrap(), outs[0], "case {case}: re-merge was not a no-op");

        // The pure resolution commutes over input-set order too.
        let fwd = merge_records(replicas.clone());
        let rev = merge_records(replicas.iter().rev().cloned().collect());
        assert_eq!(fwd, rev, "case {case}: merge_records is order-dependent");

        // The merged store parses back clean, record for record.
        let text = std::fs::read_to_string(&dst).unwrap();
        let loaded = imagecl::tunedb::store::parse_file(&text);
        assert!(loaded.quarantined.is_empty(), "case {case}");
        assert_eq!(loaded.stale, 0, "case {case}");
        assert_eq!(loaded.records.len(), fwd.len(), "case {case}");
        assert!(loaded.epoch.is_some(), "case {case}: merged store lost its epoch");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The warm-restart serving contract end-to-end: a drained service
/// checkpoints its plan-cache index beside the store; a successor
/// restores it, rebuilding every hot plan from the durable db with zero
/// tuning searches, so its first request is a plan-cache hit on a
/// warm-started entry.
#[test]
fn warm_restart_answers_first_request_from_a_cached_plan() {
    let dir = scratch("warm");
    let db_path = dir.join("db.tsv");
    let config = || ServiceConfig {
        strategy: Strategy::Random { evals: 30, seed: 11 },
        db_path: Some(db_path.clone()),
        legacy_tsv: None,
        exec: ExecMode::Simulate,
        plan_cache_cap: None,
        transfer_budget: 0,
        predict_budget: 0,
        explore_eps: 0.0,
    };

    // Generation 1: tune two keys, checkpoint on drain.
    let first = KernelService::new(config());
    first.plan("sobel", &K40, (32, 32)).unwrap();
    first.plan("sepconv_row", &INTEL_I7, (64, 64)).unwrap();
    assert_eq!(first.stats().tunes, 2);
    assert_eq!(first.write_checkpoint(None), Some(2));
    assert!(first.checkpoint_path().unwrap().exists());
    drop(first);

    // Generation 2: restore replays the checkpoint. The durable store
    // answers every config lookup — no search, no re-tune.
    let second = KernelService::new(config());
    assert_eq!(second.plans_len(), 0);
    let warmed = second.restore_checkpoint(None);
    assert_eq!(warmed, 2);
    assert_eq!(second.plans_len(), 2);
    let s = second.stats();
    assert_eq!(s.tunes, 0, "restore must not run a tuning search");
    assert_eq!(s.search_evals, 0);
    assert_eq!(s.warm_restarts, 2);

    // First post-restart request: a cache hit on the warmed entry.
    let hits_before = second.stats().cache_hits;
    let entry = second.plan("sobel", &K40, (32, 32)).unwrap();
    assert_eq!(entry.source, TuneSource::WarmStart);
    assert_eq!(second.stats().cache_hits, hits_before + 1);
    assert_eq!(second.stats().tunes, 0);

    // A missing/stale checkpoint degrades to a cold start, never an
    // error: a service pointed at an empty dir restores nothing.
    let cold = KernelService::new(ServiceConfig {
        strategy: Strategy::Random { evals: 30, seed: 11 },
        db_path: Some(dir.join("elsewhere.tsv")),
        legacy_tsv: None,
        exec: ExecMode::Simulate,
        plan_cache_cap: None,
        transfer_budget: 0,
        predict_budget: 0,
        explore_eps: 0.0,
    });
    assert_eq!(cold.restore_checkpoint(None), 0);
    let _ = std::fs::remove_dir_all(&dir);
}
