//! Tuner integration: full ML tuning runs per device on the simulator,
//! checking the *shape* of the paper's Tables 2–5 (which optimizations
//! each device ends up with), and the real-execution tuning path through
//! the XLA runtime artifacts.

use imagecl::analysis::KernelInfo;
use imagecl::bench_defs::{synth_image, CONV2D, SEPCONV_ROW};
use imagecl::devices::{AMD_7970, GTX_960, INTEL_I7, K40};
use imagecl::imagecl::{frontend, ScalarType};
use imagecl::runtime::{Tensor, XlaRuntime};
use imagecl::tuner::{tune_on_simulator, MlSearchOpts, Strategy};

fn fast_opts() -> Strategy {
    let budget = if cfg!(debug_assertions) { 150 } else { 350 };
    Strategy::MlTwoPhase(MlSearchOpts {
        train_samples: budget,
        top_k: budget / 7,
        epochs: 20,
        ..Default::default()
    })
}

#[test]
fn table2_shape_sepconv_row() {
    let info = KernelInfo::analyze(frontend(SEPCONV_ROW).unwrap());
    let strategy = fast_opts();

    // AMD 7970 (paper: local memory on, constant on).
    let amd = tune_on_simulator(&info, &AMD_7970, (1024, 1024), &strategy);
    assert!(amd.best.uses_local_mem("in"), "7970: {}", amd.best);
    assert!(amd.best.uses_constant_mem("f"), "7970: {}", amd.best);

    // K40 (paper: image memory; Kepler's global path is the slow road).
    // Our model ranks the texture and local paths within noise of each
    // other here — assert the load-bearing fact: the tuner routes the
    // stencil reads off the global path (see EXPERIMENTS.md §Deviations).
    let k40 = tune_on_simulator(&info, &K40, (1024, 1024), &strategy);
    assert!(
        k40.best.uses_image_mem("in") || k40.best.uses_local_mem("in"),
        "K40: {}",
        k40.best
    );

    // GTX 960 (paper: neither local nor image memory for the row kernel —
    // Maxwell's cache already serves the reuse). Local-vs-global is within
    // noise for a memory-bound 5-tap conv (the fixed-config contrast with
    // the 7970 is asserted in devices::model::tests); the robust fact is
    // that the *texture* path is never preferred on Maxwell.
    let nv = tune_on_simulator(&info, &GTX_960, (1024, 1024), &strategy);
    assert!(!nv.best.uses_image_mem("in"), "960: {}", nv.best);

    // Intel i7 (paper: px/thread 128, no image memory).
    let cpu = tune_on_simulator(&info, &INTEL_I7, (1024, 1024), &strategy);
    assert!(cpu.best.pixels_per_thread() >= 16, "i7: {}", cpu.best);
    assert!(!cpu.best.uses_image_mem("in"), "i7: {}", cpu.best);
}

#[test]
fn tuner_stats_match_paper_scale() {
    // Paper §7: ~1700 executed candidates per device/benchmark with the
    // default budget.
    let info = KernelInfo::analyze(frontend(CONV2D).unwrap());
    let opts = if cfg!(debug_assertions) {
        MlSearchOpts { train_samples: 1500, top_k: 200, epochs: 5, ..Default::default() }
    } else {
        MlSearchOpts::default()
    };
    let res = tune_on_simulator(&info, &K40, (512, 512), &Strategy::MlTwoPhase(opts));
    assert!(
        (1000..=2000).contains(&res.evals),
        "evals {} not in the paper's ballpark",
        res.evals
    );
    assert!(res.space_size > res.evals * 5, "space {} too small", res.space_size);
}

#[test]
fn real_execution_tuning_over_artifacts() {
    // The "Intel i7" row of the reproduction runs for real: tune over the
    // AOT variant artifacts by timing them on the PJRT CPU client. Clean
    // skip when the `xla` feature or the artifacts are absent.
    let Some(dir) = imagecl::testutil::artifact_dir_or_skip() else {
        return;
    };
    let mut rt = XlaRuntime::new(&dir).unwrap();
    let img = synth_image(ScalarType::F32, 32, 32, 4);
    let x = Tensor::new(32, 32, img.buf.data.iter().map(|&v| v as f32).collect());
    let f = Tensor::new(5, 1, vec![0.0625, 0.25, 0.375, 0.25, 0.0625]);

    let ids: Vec<String> = rt
        .manifest()
        .variants_of("sepconv", 32)
        .iter()
        .map(|a| a.id.clone())
        .collect();
    let mut best: Option<(String, f64)> = None;
    for id in &ids {
        let (_, secs) = rt.time(id, &[&x, &f], 3).unwrap();
        if best.as_ref().map(|(_, b)| secs < *b).unwrap_or(true) {
            best = Some((id.clone(), secs));
        }
    }
    let (best_id, best_t) = best.unwrap();
    assert!(best_t > 0.0);
    assert!(ids.contains(&best_id));
}
