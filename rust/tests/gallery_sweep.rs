//! Gallery sweep: every extra kernel under random tuning configurations
//! against its direct reference, plus edge cases the paper calls out —
//! images smaller than the thread grid, grids not divisible by the
//! work-group, and scalar parameters under every transformation.

use std::collections::BTreeMap;

use imagecl::analysis::KernelInfo;
use imagecl::bench_defs::gallery::*;
use imagecl::bench_defs::synth_image;
use imagecl::exec::{execute, Arg, Buffer, ImageBuf, Value};
use imagecl::imagecl::{frontend, ScalarType};
use imagecl::testutil::{check, Rng};
use imagecl::transform::{lower, TuningConfig};

fn random_config(rng: &mut Rng, info: &KernelInfo) -> TuningConfig {
    let mut cfg = TuningConfig::default();
    cfg.wg = [*rng.pick(&[1usize, 2, 4, 8, 16]), *rng.pick(&[1usize, 2, 4, 8])];
    cfg.coarsen = [*rng.pick(&[1usize, 2, 3, 5]), *rng.pick(&[1usize, 2, 4])];
    cfg.interleaved = rng.flip();
    for p in &info.prog.kernel.params {
        if info.local_mem_eligible(&p.name) && rng.flip() {
            cfg.local_mem.insert(p.name.clone(), true);
        } else if info.image_mem_eligible(&p.name) && rng.flip() {
            cfg.image_mem.insert(p.name.clone(), true);
        }
        if info.constant_mem_eligible(&p.name, 64 << 10) && rng.flip() {
            cfg.constant_mem.insert(p.name.clone(), true);
        }
    }
    for l in info.unrollable_loops() {
        if rng.flip() {
            cfg.unroll.insert(l.id, 0);
        }
    }
    cfg
}

fn run(
    src: &str,
    cfg: &TuningConfig,
    args: &mut BTreeMap<String, Arg>,
    grid: (usize, usize),
) {
    let info = KernelInfo::analyze(frontend(src).unwrap());
    let plan = lower(&info, cfg).unwrap_or_else(|e| panic!("{cfg}: {e}"));
    execute(&plan, args, grid).unwrap_or_else(|e| panic!("{cfg}: {e}"));
}

fn out_data(args: &BTreeMap<String, Arg>, name: &str) -> Vec<f64> {
    match &args[name] {
        Arg::Image(i) => i.buf.data.clone(),
        _ => panic!("{name} not an image"),
    }
}

fn assert_close(got: &[f64], want: &[f64], tol: f64, what: &str) {
    assert_eq!(got.len(), want.len());
    for i in 0..want.len() {
        assert!(
            (got[i] - want[i]).abs() <= tol,
            "{what} differs at {i}: {} vs {}",
            got[i],
            want[i]
        );
    }
}

#[test]
fn gallery_kernels_match_references_under_random_configs() {
    let (w, h) = (23, 19);
    let cases = if cfg!(debug_assertions) { 10 } else { 30 };
    check(cases, |rng| {
        let input = synth_image(ScalarType::F32, w, h, rng.next_u64());
        let which = rng.below(5);
        let cfgsrc = [THRESHOLD, ERODE, DILATE, UNSHARP, GRAD_MAG][which];
        let info = KernelInfo::analyze(frontend(cfgsrc).unwrap());
        let cfg = random_config(rng, &info);
        match which {
            0 => {
                let mut args = BTreeMap::new();
                args.insert("in".into(), Arg::Image(input.clone()));
                args.insert("out".into(), Arg::Image(ImageBuf::new(ScalarType::F32, w, h)));
                args.insert("level".into(), Arg::Scalar(Value::F(128.0)));
                run(THRESHOLD, &cfg, &mut args, (w, h));
                assert_close(
                    &out_data(&args, "out"),
                    &ref_threshold(&input, 128.0),
                    0.0,
                    "threshold",
                );
            }
            1 | 2 => {
                let src = if which == 1 { ERODE } else { DILATE };
                let mut args = BTreeMap::new();
                args.insert("in".into(), Arg::Image(input.clone()));
                args.insert("out".into(), Arg::Image(ImageBuf::new(ScalarType::F32, w, h)));
                run(src, &cfg, &mut args, (w, h));
                let want = if which == 1 { ref_erode(&input) } else { ref_dilate(&input) };
                assert_close(&out_data(&args, "out"), &want, 0.0, "morph");
            }
            3 => {
                let mut args = BTreeMap::new();
                args.insert("in".into(), Arg::Image(input.clone()));
                args.insert("out".into(), Arg::Image(ImageBuf::new(ScalarType::F32, w, h)));
                args.insert("amount".into(), Arg::Scalar(Value::F(0.7)));
                run(UNSHARP, &cfg, &mut args, (w, h));
                assert_close(
                    &out_data(&args, "out"),
                    &ref_unsharp(&input, 0.7),
                    2e-4,
                    "unsharp",
                );
            }
            _ => {
                let dy = synth_image(ScalarType::F32, w, h, rng.next_u64());
                let mut args = BTreeMap::new();
                args.insert("dx".into(), Arg::Image(input.clone()));
                args.insert("dy".into(), Arg::Image(dy.clone()));
                args.insert("out".into(), Arg::Image(ImageBuf::new(ScalarType::F32, w, h)));
                run(GRAD_MAG, &cfg, &mut args, (w, h));
                assert_close(
                    &out_data(&args, "out"),
                    &ref_grad_mag(&input, &dy),
                    2e-3,
                    "grad_mag",
                );
            }
        }
    });
}

#[test]
fn downsample_grid_smaller_than_input() {
    // Paper §5.2.4: "it might also be the case that the Image read from is
    // smaller than the thread-grid" — here the inverse: the grid comes
    // from the *output* image and the input is 2x larger.
    let (ow, oh) = (16, 11);
    let input = synth_image(ScalarType::F32, 2 * ow, 2 * oh, 5);
    for cfg_s in ["wg=16x16 px=1x1 map=blocked", "wg=4x2 px=3x2 map=interleaved img=in"] {
        let cfg = TuningConfig::parse(cfg_s).unwrap();
        let mut args = BTreeMap::new();
        args.insert("in".into(), Arg::Image(input.clone()));
        args.insert("out".into(), Arg::Image(ImageBuf::new(ScalarType::F32, ow, oh)));
        run(DOWNSAMPLE, &cfg, &mut args, (ow, oh));
        assert_close(
            &out_data(&args, "out"),
            &ref_downsample(&input, ow, oh),
            1e-4,
            cfg_s,
        );
    }
}

#[test]
fn input_smaller_than_grid_uses_boundary() {
    // The thread grid (from `a`, 16x16) is larger than image `b` (4x4):
    // reads outside `b` must resolve via its boundary condition rather
    // than faulting.
    let src = "#pragma imcl grid(a)\n\
        #pragma imcl boundary(b, constant, 9.0)\n\
        void k(Image<float> a, Image<float> b, Image<float> out) {\n\
          out[idx][idy] = a[idx][idy] + b[idx][idy];\n\
        }";
    let a = synth_image(ScalarType::F32, 16, 16, 3);
    let b = ImageBuf::from_fn(ScalarType::F32, 4, 4, |_, _| 1.0);
    let mut args = BTreeMap::new();
    args.insert("a".into(), Arg::Image(a.clone()));
    args.insert("b".into(), Arg::Image(b));
    args.insert("out".into(), Arg::Image(ImageBuf::new(ScalarType::F32, 16, 16)));
    run(src, &TuningConfig::default(), &mut args, (16, 16));
    let out = out_data(&args, "out");
    // Inside b: a+1; outside: a+9.
    assert!((out[0] - (a.get(0, 0) + 1.0)).abs() < 1e-5);
    assert!((out[15 * 16 + 15] - (a.get(15, 15) + 9.0)).abs() < 1e-5);
}

#[test]
fn blend_with_constant_weights() {
    let (w, h) = (12, 9);
    let a = synth_image(ScalarType::F32, w, h, 1);
    let b = synth_image(ScalarType::F32, w, h, 2);
    for cfg_s in [
        "wg=16x16 px=1x1 map=blocked",
        "wg=8x2 px=2x2 map=interleaved cmem=w",
    ] {
        let cfg = TuningConfig::parse(cfg_s).unwrap();
        let mut args = BTreeMap::new();
        args.insert("a".into(), Arg::Image(a.clone()));
        args.insert("b".into(), Arg::Image(b.clone()));
        args.insert("out".into(), Arg::Image(ImageBuf::new(ScalarType::F32, w, h)));
        args.insert(
            "w".into(),
            Arg::Array(Buffer::from_f64(ScalarType::F32, vec![0.25, 0.75])),
        );
        run(BLEND, &cfg, &mut args, (w, h));
        // f32 double-rounding between kernel (f64 arithmetic, f32 store)
        // and reference (f32 arithmetic) leaves ~1-ulp differences.
        assert_close(
            &out_data(&args, "out"),
            &ref_blend(&a, &b, 0.25, 0.75),
            1e-3,
            cfg_s,
        );
    }
}

#[test]
fn prime_sized_grids_survive_all_mappings() {
    // Grid sizes coprime to every work-group/coarsening choice stress the
    // rounding guard.
    let src = THRESHOLD;
    let info = KernelInfo::analyze(frontend(src).unwrap());
    check(15, |rng| {
        let (w, h) = (rng.range(1, 41) as usize, rng.range(1, 37) as usize);
        let cfg = random_config(rng, &info);
        let input = synth_image(ScalarType::F32, w, h, 77);
        let mut args = BTreeMap::new();
        args.insert("in".into(), Arg::Image(input.clone()));
        args.insert("out".into(), Arg::Image(ImageBuf::new(ScalarType::F32, w, h)));
        args.insert("level".into(), Arg::Scalar(Value::F(100.0)));
        run(src, &cfg, &mut args, (w, h));
        assert_close(
            &out_data(&args, "out"),
            &ref_threshold(&input, 100.0),
            0.0,
            "threshold-prime",
        );
    });
}
