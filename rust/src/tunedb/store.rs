//! Persistence for the tuning knowledge base: an append-only TSV of
//! [`TuneRecord`]s plus the legacy PR-1 warm-start TSV reader.
//!
//! Unlike the PR-1 `TunedStore` (which kept only the winner per key and
//! rewrote its whole file on every insert), the knowledge base is
//! append-only: every tuning outcome — winners *and* sampled search
//! history — is one immutable line, so concurrent servers can share a
//! file and a crashed write loses at most its own line. Format
//! (tab-separated, `#` comments):
//!
//! ```text
//! # kernel  device  dev_fp  grid_w  grid_h  seconds  best  config  features
//! sepconv_row  K40  a3f09c11d2e47b65  2048  2048  1.23e-4  1  wg=64x4 px=4x1 map=interleaved cmem=f  6,2,2,0,...
//! ```
//!
//! `config` reuses [`TuningConfig`]'s display/parse round-trip; `features`
//! is the comma-joined [`crate::tuner::FeatureMap`] encoding of the
//! config, stored inline so model training never needs to re-analyze the
//! kernel. `dev_fp` fingerprints the device spec the record was measured
//! against — records whose fingerprint no longer matches the current
//! spec are dropped on load (the knowledge is stale). The trailing `src`
//! column distinguishes simulator estimates (`sim`) from real-execution
//! wall-clock measurements (`wall`, fed back by the serving workers);
//! nine-column files from before the column exist parse as `sim`.

use std::path::Path;

use crate::devices::{self, DeviceSpec};
use crate::transform::TuningConfig;

/// One tuning outcome in the knowledge base.
#[derive(Debug, Clone, PartialEq)]
pub struct TuneRecord {
    pub kernel: String,
    pub device: &'static str,
    /// Fingerprint of the device spec this was measured against.
    pub dev_fp: u64,
    pub grid: (usize, usize),
    /// Measured (simulator or wall-clock) execution time, seconds.
    pub seconds: f64,
    /// Winner of its tuning run (false = sampled search history).
    pub best: bool,
    /// `seconds` is a *real-execution wall-clock* measurement (a serving
    /// worker timed this config on the hardware it serves on) rather
    /// than a simulator estimate — ground truth the model can learn the
    /// actual machine from.
    pub wall: bool,
    pub config: TuningConfig,
    /// Config feature vector in the kernel's `FeatureMap` layout.
    pub features: Vec<f64>,
}

/// Stable fingerprint of a device spec (FNV-1a over its debug encoding,
/// which covers every behavioural coefficient). Records are only trusted
/// when the spec they were measured on still matches.
pub fn device_fingerprint(dev: &DeviceSpec) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in format!("{dev:?}").bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

pub const HEADER: &str =
    "# kernel\tdevice\tdev_fp\tgrid_w\tgrid_h\tseconds\tbest\tconfig\tfeatures\tsrc\n";

/// Render one record as its TSV line (no trailing newline).
pub fn render_line(r: &TuneRecord) -> String {
    let feats: Vec<String> = r.features.iter().map(|v| format!("{v:e}")).collect();
    format!(
        "{}\t{}\t{:016x}\t{}\t{}\t{:e}\t{}\t{}\t{}\t{}",
        r.kernel,
        r.device,
        r.dev_fp,
        r.grid.0,
        r.grid.1,
        r.seconds,
        if r.best { 1 } else { 0 },
        r.config,
        feats.join(","),
        if r.wall { "wall" } else { "sim" }
    )
}

/// Parse one TSV line. `None` = malformed or no longer applicable
/// (unknown device, stale fingerprint). Nine columns (pre-`src` files)
/// parse as simulator records.
pub(crate) fn parse_line(line: &str) -> Option<TuneRecord> {
    let cols: Vec<&str> = line.split('\t').collect();
    if cols.len() != 9 && cols.len() != 10 {
        return None;
    }
    let dev = devices::by_name(cols[1])?;
    let dev_fp = u64::from_str_radix(cols[2], 16).ok()?;
    if dev_fp != device_fingerprint(dev) {
        return None;
    }
    let features = if cols[8].is_empty() {
        Vec::new()
    } else {
        cols[8]
            .split(',')
            .map(|v| v.parse::<f64>())
            .collect::<Result<Vec<f64>, _>>()
            .ok()?
    };
    let wall = match cols.get(9) {
        None | Some(&"sim") => false,
        Some(&"wall") => true,
        _ => return None,
    };
    Some(TuneRecord {
        kernel: cols[0].to_string(),
        device: dev.name,
        dev_fp,
        grid: (cols[3].parse().ok()?, cols[4].parse().ok()?),
        seconds: cols[5].parse().ok()?,
        best: match cols[6] {
            "1" => true,
            "0" => false,
            _ => return None,
        },
        wall,
        config: TuningConfig::parse(cols[7]).ok()?,
        features,
    })
}

/// Parse a whole store file, warning on (and skipping) unusable lines —
/// including a truncated trailing record from a crashed append. Returns
/// the records plus the skipped-line count (crash-safety telemetry:
/// `imagecl_tunedb_skipped_lines_total`).
pub(crate) fn parse_file(text: &str) -> (Vec<TuneRecord>, usize) {
    let mut out = Vec::new();
    let mut skipped = 0;
    for (lno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        match parse_line(line) {
            Some(r) => out.push(r),
            None => {
                skipped += 1;
                eprintln!(
                    "warning: skipping unusable tunedb line {}: {line:?}",
                    lno + 1
                );
            }
        }
    }
    (out, skipped)
}

/// The one serialization path for store writes: records rendered to
/// their TSV block, optionally headed. Both [`append`] (header only on a
/// fresh file) and [`rewrite`] (always headed) go through here, so the
/// on-disk format cannot drift between the two write sites.
fn render_block(records: &[TuneRecord], with_header: bool) -> String {
    let mut buf = String::new();
    if with_header {
        buf.push_str(HEADER);
    }
    for r in records {
        buf.push_str(&render_line(r));
        buf.push('\n');
    }
    buf
}

/// Append `records` to the store file (creating it, with header, on first
/// write). Best effort: serving continues even if the disk write fails.
pub(crate) fn append(path: &Path, records: &[TuneRecord]) {
    use std::io::Write as _;
    if records.is_empty() {
        return;
    }
    if let Some(dir) = path.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    let fresh = !path.exists();
    let file = std::fs::OpenOptions::new().create(true).append(true).open(path);
    match file {
        Ok(mut f) => {
            let buf = render_block(records, fresh);
            if let Err(e) = f.write_all(buf.as_bytes()) {
                eprintln!("warning: cannot append to tunedb {path:?}: {e}");
            }
        }
        Err(e) => eprintln!("warning: cannot open tunedb {path:?}: {e}"),
    }
}

/// Rewrite the whole store file (compaction). Written to a sibling temp
/// file and renamed into place so a crash mid-rewrite never truncates
/// the store. Best effort, like [`append`] — and sharing its
/// serialization path ([`render_block`]).
pub(crate) fn rewrite(path: &Path, records: &[TuneRecord]) {
    let buf = render_block(records, true);
    if let Some(dir) = path.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    let tmp = path.with_extension("tsv.tmp");
    if let Err(e) =
        std::fs::write(&tmp, &buf).and_then(|()| std::fs::rename(&tmp, path))
    {
        eprintln!("warning: cannot rewrite tunedb {path:?}: {e}");
    }
}

/// Parse the legacy PR-1 warm-start TSV (`kernel device grid_w grid_h
/// est_seconds config`) into winner records with the current device
/// fingerprint and no stored features (the importer computes them when
/// the kernel is a known built-in).
pub(crate) fn parse_legacy_tsv(text: &str) -> Vec<TuneRecord> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let cols: Vec<&str> = line.split('\t').collect();
        if cols.len() != 6 {
            continue;
        }
        let Some(dev) = devices::by_name(cols[1]) else { continue };
        let (Ok(gw), Ok(gh)) = (cols[2].parse(), cols[3].parse()) else { continue };
        let Ok(seconds) = cols[4].parse() else { continue };
        let Ok(config) = TuningConfig::parse(cols[5]) else { continue };
        out.push(TuneRecord {
            kernel: cols[0].to_string(),
            device: dev.name,
            dev_fp: device_fingerprint(dev),
            grid: (gw, gh),
            seconds,
            best: true,
            wall: false,
            config,
            features: Vec::new(),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::{INTEL_I7, K40};

    fn record(best: bool) -> TuneRecord {
        let mut config = TuningConfig::default();
        config.wg = [64, 4];
        config.coarsen = [4, 1];
        config.constant_mem.insert("f".into(), true);
        TuneRecord {
            kernel: "sepconv_row".to_string(),
            device: K40.name,
            dev_fp: device_fingerprint(&K40),
            grid: (2048, 2048),
            seconds: 1.25e-4,
            best,
            wall: false,
            config,
            features: vec![6.0, 2.0, 2.0, 0.0, 0.5],
        }
    }

    #[test]
    fn line_roundtrip() {
        for best in [true, false] {
            let r = record(best);
            let line = render_line(&r);
            assert_eq!(parse_line(&line), Some(r), "{line}");
        }
    }

    #[test]
    fn wall_flag_roundtrips_and_legacy_lines_parse_as_sim() {
        let r = TuneRecord { wall: true, best: false, ..record(false) };
        let line = render_line(&r);
        assert!(line.ends_with("\twall"), "{line}");
        assert_eq!(parse_line(&line), Some(r));
        // A pre-`src` nine-column line (strip the trailing column) is a
        // simulator record.
        let nine = render_line(&record(true));
        let nine = nine.rsplit_once('\t').unwrap().0;
        let parsed = parse_line(nine).unwrap();
        assert!(!parsed.wall);
        assert_eq!(parsed, record(true));
    }

    #[test]
    fn empty_features_roundtrip() {
        let r = TuneRecord { features: Vec::new(), ..record(true) };
        assert_eq!(parse_line(&render_line(&r)), Some(r));
    }

    #[test]
    fn device_names_with_spaces_roundtrip() {
        let r = TuneRecord {
            device: INTEL_I7.name,
            dev_fp: device_fingerprint(&INTEL_I7),
            ..record(true)
        };
        assert_eq!(parse_line(&render_line(&r)), Some(r));
    }

    #[test]
    fn stale_fingerprint_dropped() {
        let r = TuneRecord { dev_fp: 0xDEAD, ..record(true) };
        assert_eq!(parse_line(&render_line(&r)), None);
    }

    #[test]
    fn malformed_lines_skipped() {
        let good = render_line(&record(true));
        let text = format!("# header\n\nnot\tenough\tcols\n{good}\n");
        let (parsed, skipped) = parse_file(&text);
        assert_eq!(parsed.len(), 1);
        assert_eq!(skipped, 1);
        assert_eq!(parsed[0], record(true));
    }

    #[test]
    fn truncated_trailing_record_is_skipped_not_fatal() {
        // A crash mid-append leaves a partial final line. Loading must
        // keep every complete record and count exactly one skip —
        // regardless of where the truncation lands.
        let a = render_line(&record(true));
        let b = render_line(&record(false));
        for cut in 1..b.len() {
            let text = format!("{a}\n{}", &b[..cut]);
            // Stay on a UTF-8 boundary (the record content is ASCII, but
            // guard anyway).
            if !text.is_char_boundary(text.len()) {
                continue;
            }
            let (parsed, skipped) = parse_file(&text);
            // The complete record always survives; the partial line is
            // either skipped (counted) or — when the cut lands on a
            // column boundary that happens to form a shorter valid
            // record (TSV has no length prefix) — parsed. Never fatal,
            // never corrupts the preceding record.
            assert!(!parsed.is_empty(), "cut at {cut}");
            assert_eq!(parsed[0], record(true), "cut at {cut}");
            assert_eq!(parsed.len() + skipped, 2, "cut at {cut}");
        }
    }

    #[test]
    fn fingerprints_distinguish_devices() {
        assert_ne!(device_fingerprint(&K40), device_fingerprint(&INTEL_I7));
        assert_eq!(device_fingerprint(&K40), device_fingerprint(&K40));
    }

    #[test]
    fn legacy_tsv_parses() {
        let text = "# kernel\tdevice\tgrid_w\tgrid_h\test_seconds\tconfig\n\
            sobel\tK40\t64\t64\t1e-4\twg=8x8 px=1x1\n\
            sobel\tNoSuchDevice\t64\t64\t1e-4\twg=8x8 px=1x1\n";
        let recs = parse_legacy_tsv(text);
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].kernel, "sobel");
        assert!(recs[0].best);
        assert_eq!(recs[0].dev_fp, device_fingerprint(&K40));
    }

    #[test]
    fn append_and_parse_file() {
        let path = std::env::temp_dir()
            .join(format!("imagecl_tunedb_store_test_{}.tsv", std::process::id()));
        let _ = std::fs::remove_file(&path);
        append(&path, &[record(true)]);
        append(&path, &[record(false)]);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("# kernel"), "{text}");
        let (recs, skipped) = parse_file(&text);
        assert_eq!(skipped, 0);
        assert_eq!(recs.len(), 2);
        assert!(recs[0].best && !recs[1].best);
        let _ = std::fs::remove_file(&path);
    }
}
