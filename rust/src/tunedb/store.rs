//! Persistence for the tuning knowledge base: a checksummed append-only
//! journal of [`TuneRecord`]s plus the legacy PR-1 warm-start TSV reader.
//!
//! ## Journal format (v2)
//!
//! ```text
//! #! imagecl-tunedb v2 epoch=9f41c2b07a3d5e68
//! # seq  crc  kernel  device  dev_fp  grid_w  grid_h  seconds  best  config  features  src  kfeat
//! 17  a3b1c9d2  sepconv_row  K40  a3f09c11d2e47b65  2048  2048  1.23e-4  1  wg=64x4 ...  6,2,2,...  wall  4e0,0e0,1.5e0
//! ```
//!
//! Every record line is framed `seq <TAB> crc32 <TAB> payload`: `seq` is
//! a store-assigned monotone sequence number and `crc32` (IEEE, 8 hex
//! digits) covers `"{seq}\t{payload}"` — so a torn append, a flipped
//! byte, or a splice *anywhere* in the file is detected on load and the
//! damaged line quarantined, not just a truncated tail. The `#!` epoch
//! header fingerprints the snapshot content at the last full write
//! (compaction / merge); plain appends extend it.
//!
//! The payload keeps the v1 TSV columns — `config` reuses
//! [`TuningConfig`]'s display/parse round-trip, `features` is the
//! comma-joined [`crate::tuner::FeatureMap`] encoding, `dev_fp`
//! fingerprints the device spec (stale records drop on load), `src` is
//! `sim` or `wall` — plus the v2 `kfeat` column: three comma-joined
//! *static kernel* features (stencil extent in x and y, arithmetic
//! intensity) that let a brand-new kernel's cold start be seeded from
//! records of similar kernels. Unframed v1 lines (9 or 10 payload
//! columns, no seq/crc) still parse, with `seq = 0` and zero `kfeat`.
//!
//! Appends are fsynced ([`append`] reports sync failures for the
//! `imagecl_tunedb_fsync_failures_total` counter) and full rewrites go
//! through [`crate::fsutil::write_atomic`] (temp + fsync + rename), so a
//! kill at any byte offset loses at most the last un-synced append.

use std::collections::HashMap;
use std::path::Path;

use crate::devices::{self, DeviceSpec};
use crate::serve::faults::FaultInjector;
use crate::transform::TuningConfig;

/// One tuning outcome in the knowledge base.
#[derive(Debug, Clone)]
pub struct TuneRecord {
    pub kernel: String,
    pub device: &'static str,
    /// Fingerprint of the device spec this was measured against.
    pub dev_fp: u64,
    pub grid: (usize, usize),
    /// Measured (simulator or wall-clock) execution time, seconds.
    pub seconds: f64,
    /// Winner of its tuning run (false = sampled search history).
    pub best: bool,
    /// `seconds` is a *real-execution wall-clock* measurement (a serving
    /// worker timed this config on the hardware it serves on) rather
    /// than a simulator estimate — ground truth the model can learn the
    /// actual machine from.
    pub wall: bool,
    pub config: TuningConfig,
    /// Config feature vector in the kernel's `FeatureMap` layout.
    pub features: Vec<f64>,
    /// Journal sequence number (store-assigned, monotone per store;
    /// 0 = not yet journaled / legacy line). Replica merge resolution
    /// prefers higher sequence numbers.
    pub seq: u64,
    /// Static kernel features — stencil extent in x, stencil extent in
    /// y, arithmetic intensity (weighted ops per memory access) — for
    /// seeding new kernels from similar ones. All-zero = not stamped.
    pub kfeat: [f64; 3],
}

/// Identity excludes the journal metadata: `seq` is assigned by whichever
/// store holds the record and `kfeat` is derived from the kernel source,
/// so neither distinguishes two measurements of the same outcome.
impl PartialEq for TuneRecord {
    fn eq(&self, other: &Self) -> bool {
        self.kernel == other.kernel
            && self.device == other.device
            && self.dev_fp == other.dev_fp
            && self.grid == other.grid
            && self.seconds == other.seconds
            && self.best == other.best
            && self.wall == other.wall
            && self.config == other.config
            && self.features == other.features
    }
}

/// Stable fingerprint of a device spec (FNV-1a over its debug encoding,
/// which covers every behavioural coefficient). Records are only trusted
/// when the spec they were measured on still matches.
pub fn device_fingerprint(dev: &DeviceSpec) -> u64 {
    fnv1a(format!("{dev:?}").as_bytes())
}

/// FNV-1a over bytes (also the store-epoch content fingerprint).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) — the per-record
/// checksum. Hand-rolled bitwise; record lines are short and loads are
/// one pass, so table-free is fast enough.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

const EPOCH_PREFIX: &str = "#! imagecl-tunedb v2 epoch=";

pub const HEADER: &str = "# seq\tcrc\tkernel\tdevice\tdev_fp\tgrid_w\tgrid_h\tseconds\tbest\tconfig\tfeatures\tsrc\tkfeat\n";

/// The record payload (everything the CRC protects besides the seq).
fn render_payload(r: &TuneRecord) -> String {
    let feats: Vec<String> = r.features.iter().map(|v| format!("{v:e}")).collect();
    let kfeat: Vec<String> = r.kfeat.iter().map(|v| format!("{v:e}")).collect();
    format!(
        "{}\t{}\t{:016x}\t{}\t{}\t{:e}\t{}\t{}\t{}\t{}\t{}",
        r.kernel,
        r.device,
        r.dev_fp,
        r.grid.0,
        r.grid.1,
        r.seconds,
        if r.best { 1 } else { 0 },
        r.config,
        feats.join(","),
        if r.wall { "wall" } else { "sim" },
        kfeat.join(","),
    )
}

/// Render one record as its framed journal line (no trailing newline):
/// `seq <TAB> crc32 <TAB> payload`.
pub fn render_line(r: &TuneRecord) -> String {
    let payload = render_payload(r);
    let crc = crc32(format!("{}\t{payload}", r.seq).as_bytes());
    format!("{}\t{crc:08x}\t{payload}", r.seq)
}

/// A structurally damaged journal line (torn append, flipped bytes):
/// the CRC does not match, or an unframed line has no recognizable
/// column shape. Distinct from *stale* lines, whose bytes are intact
/// but whose content no longer applies (unknown device, changed spec).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct CorruptLine;

/// Parse the payload columns (without seq/crc framing). `Ok(None)` =
/// intact but no longer applicable. Accepts 9 (pre-`src`), 10 (pre-
/// `kfeat`) and 11 (current) columns.
fn parse_payload(cols: &[&str]) -> Result<Option<TuneRecord>, CorruptLine> {
    if !(9..=11).contains(&cols.len()) {
        return Err(CorruptLine);
    }
    let stale = || Ok(None);
    let Some(dev) = devices::by_name(cols[1]) else {
        return stale();
    };
    let Ok(dev_fp) = u64::from_str_radix(cols[2], 16) else {
        return stale();
    };
    if dev_fp != device_fingerprint(dev) {
        return stale();
    }
    let parse_f64_list = |s: &str| -> Option<Vec<f64>> {
        if s.is_empty() {
            return Some(Vec::new());
        }
        s.split(',').map(|v| v.parse::<f64>().ok()).collect()
    };
    let Some(features) = parse_f64_list(cols[8]) else {
        return stale();
    };
    let wall = match cols.get(9) {
        None | Some(&"sim") => false,
        Some(&"wall") => true,
        _ => return stale(),
    };
    let mut kfeat = [0.0; 3];
    if let Some(kf) = cols.get(10) {
        match parse_f64_list(kf) {
            Some(v) if v.len() == 3 => kfeat.copy_from_slice(&v),
            _ => return stale(),
        }
    }
    let parsed = (|| {
        Some(TuneRecord {
            kernel: cols[0].to_string(),
            device: dev.name,
            dev_fp,
            grid: (cols[3].parse().ok()?, cols[4].parse().ok()?),
            seconds: cols[5].parse().ok()?,
            best: match cols[6] {
                "1" => true,
                "0" => false,
                _ => return None,
            },
            wall,
            config: TuningConfig::parse(cols[7]).ok()?,
            features,
            seq: 0,
            kfeat,
        })
    })();
    Ok(parsed)
}

/// Parse one journal line: a framed `seq\tcrc\tpayload` record (CRC
/// verified) or an unframed legacy v1 line (`seq = 0`). `Ok(None)` =
/// intact but stale; `Err(CorruptLine)` = torn/corrupt bytes.
pub(crate) fn parse_line(line: &str) -> Result<Option<TuneRecord>, CorruptLine> {
    let cols: Vec<&str> = line.split('\t').collect();
    let framed = cols.len() >= 3
        && !cols[0].is_empty()
        && cols[0].bytes().all(|b| b.is_ascii_digit())
        && cols[1].len() == 8
        && cols[1].bytes().all(|b| b.is_ascii_hexdigit());
    if framed {
        let seq: u64 = cols[0].parse().map_err(|_| CorruptLine)?;
        let want = u32::from_str_radix(cols[1], 16).map_err(|_| CorruptLine)?;
        // The payload is everything after the second tab, verbatim.
        let payload = &line[cols[0].len() + 1 + cols[1].len() + 1..];
        if crc32(format!("{seq}\t{payload}").as_bytes()) != want {
            return Err(CorruptLine);
        }
        return Ok(parse_payload(&cols[2..]).unwrap_or(None).map(|mut r| {
            r.seq = seq;
            r
        }));
    }
    parse_payload(&cols)
}

/// Everything a store load learns about the file.
#[derive(Debug, Default)]
pub struct LoadReport {
    /// Intact, applicable records, in file order.
    pub records: Vec<TuneRecord>,
    /// Torn/corrupt lines: (1-based line number, raw text). These are
    /// *damage* — a crashed append, flipped bits — as opposed to stale.
    pub quarantined: Vec<(usize, String)>,
    /// Intact lines dropped as no longer applicable (unknown device,
    /// stale device fingerprint).
    pub stale: usize,
    /// The `#!` epoch header's content fingerprint, when present.
    pub epoch: Option<u64>,
    /// Highest sequence number seen (0 = none / legacy file).
    pub max_seq: u64,
}

/// Parse a whole store file, classifying every line: record, stale (both
/// silently usable outcomes) or quarantined damage. Never fails — a
/// store with damage anywhere still yields every intact record.
pub fn parse_file(text: &str) -> LoadReport {
    let mut report = LoadReport::default();
    for (lno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix(EPOCH_PREFIX) {
            match u64::from_str_radix(rest.trim(), 16) {
                Ok(e) if rest.trim().len() == 16 => report.epoch = Some(e),
                _ => report.quarantined.push((lno + 1, line.to_string())),
            }
            continue;
        }
        if let Some(bang) = line.strip_prefix("#!") {
            // A directive line we don't recognize — most likely a torn
            // epoch header from a crash during file creation.
            let _ = bang;
            report.quarantined.push((lno + 1, line.to_string()));
            continue;
        }
        if line.starts_with('#') {
            continue;
        }
        match parse_line(line) {
            Ok(Some(rec)) => {
                report.max_seq = report.max_seq.max(rec.seq);
                report.records.push(rec);
            }
            Ok(None) => report.stale += 1,
            Err(CorruptLine) => {
                report.quarantined.push((lno + 1, line.to_string()));
                eprintln!(
                    "warning: quarantining corrupt tunedb line {}: {line:?}",
                    lno + 1
                );
            }
        }
    }
    report
}

/// Content epoch: FNV-1a over the rendered payloads. Deterministic for a
/// given record set, so replicas that converge to the same merged
/// content converge to the same epoch (and byte-identical files).
fn epoch_of(records: &[TuneRecord]) -> u64 {
    let mut buf = String::new();
    for r in records {
        buf.push_str(&render_payload(r));
        buf.push('\n');
    }
    fnv1a(buf.as_bytes())
}

fn file_head(records: &[TuneRecord]) -> String {
    format!("{EPOCH_PREFIX}{:016x}\n{HEADER}", epoch_of(records))
}

/// What one [`append`] actually did (counter food for
/// `imagecl_tunedb_fsync_failures_total` and the fault sites).
#[derive(Debug, Default, Clone, Copy)]
pub(crate) struct AppendReport {
    /// Bytes reached the file (possibly torn/corrupt under injection).
    pub wrote: bool,
    /// `fsync` after the write failed (data may not survive a crash).
    pub sync_failed: bool,
    /// Injected `tunedb_torn` fault truncated this append mid-record.
    pub torn: bool,
    /// Injected `tunedb_corrupt` fault flipped a byte in this append.
    pub corrupt: bool,
}

/// Append `records` to the journal (creating it, with epoch header, on
/// first write), then fsync. Best effort: serving continues even if the
/// disk write fails, but the report says what happened. The injector's
/// `tunedb_torn`/`tunedb_corrupt` sites damage the append at the byte
/// level — exactly what a mid-write crash or bit rot produces — to prove
/// the load path quarantines it.
pub(crate) fn append(
    path: &Path,
    records: &[TuneRecord],
    faults: &FaultInjector,
) -> AppendReport {
    use std::io::Write as _;
    let mut report = AppendReport::default();
    if records.is_empty() {
        return report;
    }
    if let Some(dir) = path.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    let fresh = !path.exists();
    let file = std::fs::OpenOptions::new().create(true).append(true).open(path);
    match file {
        Ok(mut f) => {
            let mut buf = String::new();
            if fresh {
                buf.push_str(&file_head(records));
            }
            let body_start = buf.len();
            for r in records {
                buf.push_str(&render_line(r));
                buf.push('\n');
            }
            let mut bytes = buf.into_bytes();
            if faults.tunedb_corrupt() {
                // Flip one bit mid-way through the appended body.
                let at = body_start + (bytes.len() - body_start) / 2;
                bytes[at] ^= 0x01;
                report.corrupt = true;
            }
            if faults.tunedb_torn() {
                // Truncate the append mid-record: drop the second half
                // of the final line (newline included).
                let keep = body_start + (bytes.len() - body_start) / 2;
                bytes.truncate(keep.max(body_start + 1));
                report.torn = true;
            }
            if let Err(e) = f.write_all(&bytes) {
                eprintln!("warning: cannot append to tunedb {path:?}: {e}");
                return report;
            }
            report.wrote = true;
            if let Err(e) = f.sync_all() {
                report.sync_failed = true;
                eprintln!("warning: cannot fsync tunedb {path:?}: {e}");
            }
        }
        Err(e) => eprintln!("warning: cannot open tunedb {path:?}: {e}"),
    }
    report
}

/// Rewrite the whole store (snapshot compaction / fsck repair / merge):
/// fresh epoch header + every record, written atomically (temp file,
/// fsync, rename) so a crash at any byte offset leaves either the old
/// complete store or the new one.
pub(crate) fn rewrite(path: &Path, records: &[TuneRecord]) -> std::io::Result<()> {
    let mut buf = file_head(records);
    for r in records {
        buf.push_str(&render_line(r));
        buf.push('\n');
    }
    crate::fsutil::write_atomic(path, buf.as_bytes())
}

/// Conflict-free merge of record sets from concurrent replica stores.
///
/// Keyed on (kernel, dev_fp, grid, config): the same measured outcome
/// appearing in several stores collapses to one record, chosen by a
/// total order — prefer real `wall` measurements over `sim` estimates,
/// then the higher sequence number (the more recent journal entry), then
/// the lexicographically greater payload. Selection under a total order
/// makes the merge idempotent, commutative and associative (the fuzz
/// test in `tests/durability.rs` exercises all three).
///
/// Output is deterministically ordered — by key, history before winners,
/// winners in descending-seconds order so the *fastest* winner lands
/// last (which is what [`crate::tunedb::TuneDb::exact`] answers with) —
/// and renumbered `seq = 1..n`.
pub fn merge_records(sets: Vec<Vec<TuneRecord>>) -> Vec<TuneRecord> {
    type Key = (String, u64, (usize, usize), String);
    let mut by_key: HashMap<Key, TuneRecord> = HashMap::new();
    for rec in sets.into_iter().flatten() {
        let key = (rec.kernel.clone(), rec.dev_fp, rec.grid, rec.config.to_string());
        match by_key.entry(key) {
            std::collections::hash_map::Entry::Occupied(mut e) => {
                if merge_wins(&rec, e.get()) {
                    e.insert(rec);
                }
            }
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert(rec);
            }
        }
    }
    let mut out: Vec<TuneRecord> = by_key.into_values().collect();
    out.sort_by(|a, b| {
        (a.kernel.as_str(), a.device, a.grid, a.best)
            .cmp(&(b.kernel.as_str(), b.device, b.grid, b.best))
            .then(b.seconds.total_cmp(&a.seconds))
            .then_with(|| render_payload(a).cmp(&render_payload(b)))
    });
    for (i, r) in out.iter_mut().enumerate() {
        r.seq = (i + 1) as u64;
    }
    out
}

/// Whether `a` replaces `b` under the merge's total order.
fn merge_wins(a: &TuneRecord, b: &TuneRecord) -> bool {
    (a.wall, a.seq)
        .cmp(&(b.wall, b.seq))
        .then_with(|| render_payload(a).cmp(&render_payload(b)))
        .is_gt()
}

/// Parse the legacy PR-1 warm-start TSV (`kernel device grid_w grid_h
/// est_seconds config`) into winner records with the current device
/// fingerprint and no stored features (the importer computes them when
/// the kernel is a known built-in).
pub(crate) fn parse_legacy_tsv(text: &str) -> Vec<TuneRecord> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let cols: Vec<&str> = line.split('\t').collect();
        if cols.len() != 6 {
            continue;
        }
        let Some(dev) = devices::by_name(cols[1]) else { continue };
        let (Ok(gw), Ok(gh)) = (cols[2].parse(), cols[3].parse()) else { continue };
        let Ok(seconds) = cols[4].parse() else { continue };
        let Ok(config) = TuningConfig::parse(cols[5]) else { continue };
        out.push(TuneRecord {
            kernel: cols[0].to_string(),
            device: dev.name,
            dev_fp: device_fingerprint(dev),
            grid: (gw, gh),
            seconds,
            best: true,
            wall: false,
            config,
            features: Vec::new(),
            seq: 0,
            kfeat: [0.0; 3],
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::{INTEL_I7, K40};

    fn record(best: bool) -> TuneRecord {
        let mut config = TuningConfig::default();
        config.wg = [64, 4];
        config.coarsen = [4, 1];
        config.constant_mem.insert("f".into(), true);
        TuneRecord {
            kernel: "sepconv_row".to_string(),
            device: K40.name,
            dev_fp: device_fingerprint(&K40),
            grid: (2048, 2048),
            seconds: 1.25e-4,
            best,
            wall: false,
            config,
            features: vec![6.0, 2.0, 2.0, 0.0, 0.5],
            seq: 0,
            kfeat: [0.0; 3],
        }
    }

    #[test]
    fn line_roundtrip() {
        for best in [true, false] {
            let r = TuneRecord { seq: 42, kfeat: [2.0, 2.0, 1.5], ..record(best) };
            let line = render_line(&r);
            let parsed = parse_line(&line).unwrap().unwrap();
            assert_eq!(parsed, r, "{line}");
            // PartialEq excludes the journal metadata — check it raw.
            assert_eq!(parsed.seq, 42, "{line}");
            assert_eq!(parsed.kfeat, [2.0, 2.0, 1.5], "{line}");
        }
    }

    #[test]
    fn crc_catches_any_single_byte_flip() {
        let r = TuneRecord { seq: 7, ..record(true) };
        let line = render_line(&r);
        let bytes = line.as_bytes();
        for i in 0..bytes.len() {
            let mut damaged = bytes.to_vec();
            damaged[i] ^= 0x01;
            let Ok(s) = std::str::from_utf8(&damaged) else { continue };
            if s.contains('\t') {
                // Still tab-structured: must be rejected as corrupt (or,
                // if the flip broke the framing shape entirely, at least
                // never parse into a record).
                assert_ne!(
                    parse_line(s).ok().flatten().as_ref(),
                    Some(&r),
                    "flip at {i} silently accepted: {s:?}"
                );
            }
        }
    }

    #[test]
    fn wall_flag_roundtrips_and_legacy_lines_parse_as_sim() {
        let r = TuneRecord { wall: true, best: false, ..record(false) };
        let line = render_line(&r);
        assert!(line.contains("\twall\t"), "{line}");
        assert_eq!(parse_line(&line).unwrap(), Some(r));
        // An unframed v1 ten-column payload (no seq/crc/kfeat) parses as
        // a legacy record with seq 0.
        let v1 = {
            let full = render_line(&record(true));
            let payload = full.splitn(3, '\t').nth(2).unwrap().to_string();
            payload.rsplit_once('\t').unwrap().0.to_string()
        };
        let parsed = parse_line(&v1).unwrap().unwrap();
        assert!(!parsed.wall);
        assert_eq!(parsed.seq, 0);
        assert_eq!(parsed, record(true));
        // And the nine-column pre-`src` shape still parses as sim.
        let v0 = v1.rsplit_once('\t').unwrap().0;
        let parsed = parse_line(v0).unwrap().unwrap();
        assert!(!parsed.wall);
        assert_eq!(parsed, record(true));
    }

    #[test]
    fn empty_features_roundtrip() {
        let r = TuneRecord { features: Vec::new(), ..record(true) };
        assert_eq!(parse_line(&render_line(&r)).unwrap(), Some(r));
    }

    #[test]
    fn device_names_with_spaces_roundtrip() {
        let r = TuneRecord {
            device: INTEL_I7.name,
            dev_fp: device_fingerprint(&INTEL_I7),
            ..record(true)
        };
        assert_eq!(parse_line(&render_line(&r)).unwrap(), Some(r));
    }

    #[test]
    fn stale_fingerprint_dropped_as_stale_not_corrupt() {
        let r = TuneRecord { dev_fp: 0xDEAD, ..record(true) };
        // The line is intact (CRC valid) but inapplicable.
        assert_eq!(parse_line(&render_line(&r)), Ok(None));
        let report = parse_file(&format!("{}\n", render_line(&r)));
        assert!(report.records.is_empty());
        assert_eq!(report.stale, 1);
        assert!(report.quarantined.is_empty());
    }

    #[test]
    fn malformed_lines_quarantined() {
        let good = render_line(&record(true));
        let text = format!("# header\n\nnot\tenough\tcols\n{good}\n");
        let report = parse_file(&text);
        assert_eq!(report.records.len(), 1);
        assert_eq!(report.quarantined.len(), 1);
        assert_eq!(report.quarantined[0].0, 3);
        assert_eq!(report.records[0], record(true));
    }

    #[test]
    fn epoch_header_roundtrips_and_torn_header_is_quarantined() {
        let recs = vec![record(true), record(false)];
        let text = {
            let mut buf = file_head(&recs);
            for r in &recs {
                buf.push_str(&render_line(r));
                buf.push('\n');
            }
            buf
        };
        let report = parse_file(&text);
        assert_eq!(report.epoch, Some(epoch_of(&recs)));
        assert_eq!(report.records.len(), 2);
        assert!(report.quarantined.is_empty());
        // A truncated epoch header is damage, and is counted as such.
        let torn = "#! imagecl-tunedb v2 epoch=9f41\n";
        let report = parse_file(torn);
        assert_eq!(report.epoch, None);
        assert_eq!(report.quarantined.len(), 1);
    }

    #[test]
    fn truncated_trailing_record_is_quarantined_not_fatal() {
        // A crash mid-append leaves a partial final line. Loading must
        // keep every complete record and quarantine exactly the damage —
        // regardless of where the truncation lands. The CRC framing
        // makes this exact: no cut point of a framed line can parse.
        let a = render_line(&TuneRecord { seq: 1, ..record(true) });
        let b = render_line(&TuneRecord { seq: 2, ..record(false) });
        for cut in 1..b.len() {
            let text = format!("{a}\n{}", &b[..cut]);
            if !text.is_char_boundary(text.len()) {
                continue;
            }
            let report = parse_file(&text);
            assert_eq!(report.records.len(), 1, "cut at {cut}");
            assert_eq!(report.records[0], record(true), "cut at {cut}");
            assert_eq!(report.quarantined.len(), 1, "cut at {cut}");
            assert_eq!(report.max_seq, 1, "cut at {cut}");
        }
    }

    #[test]
    fn fingerprints_distinguish_devices() {
        assert_ne!(device_fingerprint(&K40), device_fingerprint(&INTEL_I7));
        assert_eq!(device_fingerprint(&K40), device_fingerprint(&K40));
    }

    #[test]
    fn legacy_tsv_parses() {
        let text = "# kernel\tdevice\tgrid_w\tgrid_h\test_seconds\tconfig\n\
            sobel\tK40\t64\t64\t1e-4\twg=8x8 px=1x1\n\
            sobel\tNoSuchDevice\t64\t64\t1e-4\twg=8x8 px=1x1\n";
        let recs = parse_legacy_tsv(text);
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].kernel, "sobel");
        assert!(recs[0].best);
        assert_eq!(recs[0].dev_fp, device_fingerprint(&K40));
    }

    #[test]
    fn append_and_parse_file() {
        let path = std::env::temp_dir()
            .join(format!("imagecl_tunedb_store_test_{}.tsv", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let quiet = FaultInjector::disabled();
        let rep = append(&path, &[TuneRecord { seq: 1, ..record(true) }], &quiet);
        assert!(rep.wrote && !rep.torn && !rep.corrupt);
        append(&path, &[TuneRecord { seq: 2, ..record(false) }], &quiet);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with(EPOCH_PREFIX), "{text}");
        let report = parse_file(&text);
        assert!(report.quarantined.is_empty());
        assert_eq!(report.stale, 0);
        assert_eq!(report.records.len(), 2);
        assert!(report.records[0].best && !report.records[1].best);
        assert_eq!(report.max_seq, 2);
        assert!(report.epoch.is_some());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rewrite_is_byte_deterministic() {
        let path = std::env::temp_dir()
            .join(format!("imagecl_tunedb_store_rw_{}.tsv", std::process::id()));
        let recs = vec![
            TuneRecord { seq: 3, ..record(true) },
            TuneRecord { seq: 9, ..record(false) },
        ];
        rewrite(&path, &recs).unwrap();
        let first = std::fs::read(&path).unwrap();
        rewrite(&path, &recs).unwrap();
        assert_eq!(first, std::fs::read(&path).unwrap());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn merge_prefers_wall_then_seq_and_dedups() {
        let sim = TuneRecord { seq: 5, seconds: 2e-4, ..record(false) };
        let wall = TuneRecord { seq: 3, wall: true, seconds: 2e-4, ..record(false) };
        let newer_sim = TuneRecord { seq: 9, seconds: 2e-4, ..record(false) };
        // wall beats sim regardless of seq.
        let merged = merge_records(vec![vec![sim.clone()], vec![wall.clone()]]);
        assert_eq!(merged.len(), 1);
        assert!(merged[0].wall);
        // Same wall-ness: higher seq wins. (Same key: these share the
        // identical config + seconds, so the survivor is whichever
        // journal entry is newer.)
        let merged = merge_records(vec![vec![sim.clone()], vec![newer_sim.clone()]]);
        assert_eq!(merged.len(), 1);
        // Different configs are different keys — both survive.
        let mut other = record(false);
        other.config.wg = [8, 8];
        let merged = merge_records(vec![vec![sim.clone()], vec![other.clone()]]);
        assert_eq!(merged.len(), 2);
        // Output seqs are renumbered 1..n.
        let seqs: Vec<u64> = merged.iter().map(|r| r.seq).collect();
        assert_eq!(seqs, vec![1, 2]);
    }

    #[test]
    fn merge_orders_fastest_winner_last() {
        let slow = TuneRecord { seconds: 5e-4, ..record(true) };
        let mut fast = record(true);
        fast.config.wg = [16, 16];
        fast.seconds = 1e-4;
        let merged = merge_records(vec![vec![slow], vec![fast]]);
        assert_eq!(merged.len(), 2);
        // Ascending index order ends at the fastest winner, which is the
        // record `TuneDb::exact` (latest winner wins) will answer with.
        assert_eq!(merged.last().unwrap().seconds, 1e-4);
    }
}
