//! `tunedb`: the transfer-tuning knowledge base.
//!
//! The serving layer (PR 1) tuned every new (kernel, device, grid) key
//! from scratch and its flat TSV warm-start only replayed exact-key
//! hits. This module turns tuning from a per-process cost into
//! accumulated cross-run knowledge: every tuning outcome is persisted as
//! a [`TuneRecord`] (kernel, device-spec fingerprint, grid, config,
//! measured time, config feature vector), and lookups answer in three
//! tiers:
//!
//! 1. **Exact** — a winner record for the precise (kernel, device, grid)
//!    key: return its config directly, no search at all.
//! 2. **Transfer** — same kernel + device, nearest grid by log-scale
//!    distance: the recorded winner seeds
//!    [`crate::tuner::search::seeded`], which searches only the seed's
//!    feature-space neighborhood instead of the full space.
//! 3. **Model** — no same-device knowledge at all: an MLP
//!    ([`PerfModel`], trained on the kernel's accumulated records across
//!    devices and grids) ranks the candidate space and only the top
//!    predictions are measured ([`crate::tuner::search::shortlist`]).
//!
//! The store is a checksummed append-only journal (`store.rs`: per-record
//! CRC + sequence numbers + epoch header) with an in-memory index;
//! corruption anywhere in the file is quarantined on load, audited by
//! [`fsck`] and repaired by [`fsck_repair`]'s atomic snapshot rewrite.
//! Replica stores from a serving fleet cross-pollinate via
//! [`merge_files`] — a deterministic, idempotent, commutative merge.
//! [`TuneDb::import_legacy_tsv`] migrates PR-1 warm-start files so
//! existing deployments keep their tuned configs.

pub mod model;
pub mod store;

pub use model::{device_features, PerfModel, MIN_TRAIN_RECORDS};
pub use store::{device_fingerprint, merge_records, LoadReport, TuneRecord};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::analysis::KernelInfo;
use crate::bench_defs;
use crate::devices::DeviceSpec;
use crate::imagecl::frontend;
use crate::tuner::{FeatureMap, TuneResult};

/// Sampled search-history records persisted per tuning run (the winner
/// is always recorded; history feeds model training).
const HISTORY_SAMPLES: usize = 48;

/// Default per-(kernel, device, grid) history cap applied by compaction
/// (~2–3 tuning runs' worth of samples). The store is append-only, so
/// without compaction every re-tune of a hot key grows it forever.
pub const HISTORY_CAP_PER_KEY: usize = 128;

/// Outcome of a [`TuneDb::compact`] pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CompactStats {
    /// Records surviving the pass.
    pub kept: usize,
    /// Records dropped (superseded winners + over-cap history).
    pub removed: usize,
}

/// What the knowledge base knows about a (kernel, device, grid) key.
#[derive(Debug, Clone)]
pub enum Answer {
    /// A winner record at exactly this key.
    Exact(TuneRecord),
    /// A winner record for the same kernel + device at the nearest other
    /// grid (log-scale distance attached) — a warm-start seed.
    Transfer { rec: TuneRecord, distance: f64 },
    /// Nothing usable for this kernel + device.
    Miss,
}

/// Lifetime activity counters for one knowledge base, published to the
/// global metrics registry as `imagecl_tunedb_*` by
/// [`TuneDb::publish_obs`]. Plain atomics outside the index mutex: the
/// hot lookup path bumps them without extending the critical section.
#[derive(Default)]
pub struct DbCounters {
    /// Lookups answered by an exact-key winner (tier 1).
    pub lookups_exact: AtomicU64,
    /// Lookups answered by a nearest-grid transfer seed (tier 2).
    pub lookups_transfer: AtomicU64,
    /// Lookups with no same-device knowledge at all.
    pub lookups_miss: AtomicU64,
    /// Records appended (winners, history and wall samples alike).
    pub records_appended: AtomicU64,
    /// Model (re)trainings actually executed (cache misses in
    /// [`TuneDb::model_for`], not calls).
    pub model_refreshes: AtomicU64,
    /// Unusable store lines skipped on load (truncated trailing record
    /// from a crashed append, corrupt or stale lines).
    pub skipped_lines: AtomicU64,
    /// Structurally damaged (torn/corrupt) lines quarantined on load —
    /// the subset of `skipped_lines` that is byte damage rather than
    /// staleness. Non-zero after a crash or bit rot; `tunedb fsck`
    /// audits and repairs.
    pub fsck_quarantined: AtomicU64,
    /// Journal appends whose post-write fsync failed (the data reached
    /// the file but may not survive a power cut).
    pub fsync_failures: AtomicU64,
    /// Disk appends skipped by injected `tunedb_io` faults (chaos
    /// testing; the in-memory index still gets the records).
    pub io_faults: AtomicU64,
}

#[derive(Default)]
struct DbInner {
    records: Vec<TuneRecord>,
    /// Last journal sequence number assigned/loaded; appends get
    /// `last_seq + 1` so replica merge can prefer newer entries.
    last_seq: u64,
    /// Static kernel-feature cache (`None` caches "not derivable" for
    /// kernels whose source we don't hold).
    kfeats: HashMap<String, Option<[f64; 3]>>,
    /// Winner-record indices per (kernel, device).
    best: HashMap<(String, &'static str), Vec<usize>>,
    /// All-record indices per kernel (model training set).
    by_kernel: HashMap<String, Vec<usize>>,
    /// Training outcomes, keyed by kernel, stamped with the record count
    /// they saw (stale entries retrain lazily). `None` caches a *failed*
    /// training — unusable kernels must not pay a record-set clone and
    /// train attempt on every lookup.
    models: HashMap<String, (usize, Option<Arc<PerfModel>>)>,
}

/// The compaction policy over a record sequence (order-preserving):
/// per (kernel, device, grid) key keep the latest winner and the `cap`
/// most recent history records. Returns (kept, removed-count).
fn compact_records(records: Vec<TuneRecord>, cap: usize) -> (Vec<TuneRecord>, usize) {
    type Key = (String, &'static str, (usize, usize));
    let mut last_winner: HashMap<Key, usize> = HashMap::new();
    for (i, r) in records.iter().enumerate() {
        if r.best {
            last_winner.insert((r.kernel.clone(), r.device, r.grid), i);
        }
    }
    let mut keep = vec![false; records.len()];
    let mut hist_kept: HashMap<Key, usize> = HashMap::new();
    for (i, r) in records.iter().enumerate().rev() {
        let key = (r.kernel.clone(), r.device, r.grid);
        if r.best {
            keep[i] = last_winner.get(&key) == Some(&i);
        } else {
            let c = hist_kept.entry(key).or_insert(0);
            if *c < cap {
                keep[i] = true;
                *c += 1;
            }
        }
    }
    let total = records.len();
    let kept: Vec<TuneRecord> = records
        .into_iter()
        .enumerate()
        .filter_map(|(i, r)| keep[i].then_some(r))
        .collect();
    let removed = total - kept.len();
    (kept, removed)
}

impl DbInner {
    fn index(&mut self, idx: usize) {
        let r = &self.records[idx];
        if r.best {
            self.best
                .entry((r.kernel.clone(), r.device))
                .or_default()
                .push(idx);
        }
        self.by_kernel.entry(r.kernel.clone()).or_default().push(idx);
    }
}

/// The persistent, queryable tuning knowledge base. Thread-safe; all
/// mutation appends (memory and disk alike).
pub struct TuneDb {
    path: Option<PathBuf>,
    inner: Mutex<DbInner>,
    /// Activity counters (see [`DbCounters`]).
    pub obs: DbCounters,
    /// Fault injector for chaos testing (disabled by default); its
    /// `tunedb_io` site makes disk appends fail while the in-memory
    /// index stays correct — the crash-safety path `open()` already
    /// tolerates.
    faults: Mutex<Arc<crate::serve::faults::FaultInjector>>,
}

/// Default knowledge-base path: `<crate>/target/tunedb.tsv` (override
/// with `IMAGECL_TUNEDB`).
pub fn default_db_path() -> PathBuf {
    if let Ok(p) = std::env::var("IMAGECL_TUNEDB") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("target")
        .join("tunedb.tsv")
}

/// Log-scale distance between two grids (geometric: 512 is as far from
/// 1024 as 1024 is from 2048).
pub fn grid_distance(a: (usize, usize), b: (usize, usize)) -> f64 {
    let ln = |v: usize| (v.max(1) as f64).ln();
    let dx = ln(a.0) - ln(b.0);
    let dy = ln(a.1) - ln(b.1);
    (dx * dx + dy * dy).sqrt()
}

/// Static features of a kernel's *source* — stencil extent in x and y
/// (max over read arrays) and arithmetic intensity (weighted ops per
/// element of memory traffic) — the `kfeat` journal column. `None` when
/// the kernel is not a known built-in (we don't hold its source).
///
/// These are structure-of-the-computation features: two kernels with
/// similar stencils and intensity tend to prefer similar configs, so
/// they let a brand-new kernel's cold start be seeded from the records
/// of its nearest structural neighbors (ROADMAP #4).
pub fn kernel_static_features(kernel: &str) -> Option<[f64; 3]> {
    let def = bench_defs::kernel_by_id(kernel)?;
    let prog = frontend(def.source).ok()?;
    let info = KernelInfo::analyze(prog);
    let (mut ex, mut ey) = (0i64, 0i64);
    for array in info.stencils.keys() {
        if let Some(s) = info.read_stencil(array) {
            ex = ex.max(s.extent_x());
            ey = ey.max(s.extent_y());
        }
    }
    let traffic = info.cost.total_reads() + info.cost.total_writes();
    let intensity = if traffic > 0.0 { info.cost.weighted_ops() / traffic } else { 0.0 };
    Some([ex as f64, ey as f64, intensity])
}

/// Distance between two static kernel-feature vectors: Euclidean over
/// (extent_x, extent_y, ln(1 + intensity)) — the log keeps a pathological
/// intensity from drowning the stencil shape.
pub fn kernel_feature_distance(a: [f64; 3], b: [f64; 3]) -> f64 {
    let di = (1.0 + a[2].max(0.0)).ln() - (1.0 + b[2].max(0.0)).ln();
    let dx = a[0] - b[0];
    let dy = a[1] - b[1];
    (dx * dx + dy * dy + di * di).sqrt()
}

impl TuneDb {
    /// In-memory only (no persistence).
    pub fn ephemeral() -> TuneDb {
        TuneDb {
            path: None,
            inner: Mutex::new(DbInner::default()),
            obs: DbCounters::default(),
            faults: Mutex::new(crate::serve::faults::FaultInjector::disabled()),
        }
    }

    /// Backed by `path`; loads any existing file, skipping unusable
    /// lines with a warning rather than refusing to start. Keys whose
    /// history outgrew [`HISTORY_CAP_PER_KEY`] are compacted on load (and
    /// the file rewritten), so long-lived deployments stay bounded.
    pub fn open(path: &Path) -> TuneDb {
        let mut inner = DbInner::default();
        let mut skipped = 0;
        let mut quarantined = 0;
        if let Ok(text) = std::fs::read_to_string(path) {
            let report = store::parse_file(&text);
            skipped = report.quarantined.len() + report.stale;
            quarantined = report.quarantined.len();
            inner.last_seq = report.max_seq;
            for rec in report.records {
                inner.records.push(rec);
                inner.index(inner.records.len() - 1);
            }
        }
        let db = TuneDb {
            path: Some(path.to_path_buf()),
            inner: Mutex::new(inner),
            obs: DbCounters::default(),
            faults: Mutex::new(crate::serve::faults::FaultInjector::disabled()),
        };
        db.obs.skipped_lines.store(skipped as u64, Ordering::Relaxed);
        db.obs.fsck_quarantined.store(quarantined as u64, Ordering::Relaxed);
        db.compact(HISTORY_CAP_PER_KEY);
        db
    }

    /// Install a fault injector (chaos testing). Its `tunedb_io` site
    /// makes subsequent disk appends fail.
    pub fn set_faults(&self, injector: Arc<crate::serve::faults::FaultInjector>) {
        *self.faults.lock().unwrap() = injector;
    }

    /// Compact the store: per (kernel, device, grid) key, keep only the
    /// *latest* winner record (the only one [`TuneDb::exact`] can ever
    /// answer with) and the most recent `cap` history records; everything
    /// older is dropped, in memory and — when anything was removed — on
    /// disk via a full rewrite. Cached models are invalidated.
    pub fn compact(&self, cap: usize) -> CompactStats {
        let mut g = self.inner.lock().unwrap();
        let old = std::mem::take(&mut g.records);
        let total = old.len();
        let (kept, removed) = compact_records(old, cap);
        g.records = kept;
        g.best.clear();
        g.by_kernel.clear();
        g.models.clear();
        for i in 0..g.records.len() {
            g.index(i);
        }
        debug_assert_eq!(total, g.records.len() + removed);
        // Rewrite under the lock: concurrent `record()`s append to the
        // file before releasing this same lock, so the rename can never
        // clobber a record the index doesn't already hold.
        if removed > 0 {
            if let Some(path) = &self.path {
                if let Err(e) = store::rewrite(path, &g.records) {
                    eprintln!("warning: cannot rewrite tunedb {path:?}: {e}");
                }
            }
        }
        CompactStats { kept: g.records.len(), removed }
    }

    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    /// Total records (winners + history samples).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Winner records only.
    pub fn best_len(&self) -> usize {
        self.inner.lock().unwrap().best.values().map(Vec::len).sum()
    }

    /// Clone of every record (CLI export / stats; records are small).
    pub fn snapshot(&self) -> Vec<TuneRecord> {
        self.inner.lock().unwrap().records.clone()
    }

    /// Append one record (memory + disk).
    pub fn record(&self, rec: TuneRecord) {
        self.record_batch(vec![rec]);
    }

    fn record_batch(&self, mut recs: Vec<TuneRecord>) {
        if recs.is_empty() {
            return;
        }
        self.obs.records_appended.fetch_add(recs.len() as u64, Ordering::Relaxed);
        // Disk append happens under the same lock as the in-memory index
        // so an in-process `compact()` (which rewrites the file) can
        // never race a concurrent append and erase it from disk. Sequence
        // numbers are assigned under it too — monotone per store.
        let mut g = self.inner.lock().unwrap();
        for rec in &mut recs {
            g.last_seq += 1;
            rec.seq = g.last_seq;
            if rec.kfeat == [0.0; 3] {
                let kf = g
                    .kfeats
                    .entry(rec.kernel.clone())
                    .or_insert_with(|| kernel_static_features(&rec.kernel));
                if let Some(kf) = kf {
                    rec.kfeat = *kf;
                }
            }
        }
        if let Some(path) = &self.path {
            // Injected IO fault: only the disk append is lost (matching
            // a real failed write — `store::append` is best-effort);
            // the in-memory index stays correct, so serving answers
            // don't change. A restart would re-tune, which `open()`'s
            // quarantine-and-warn load path tolerates.
            let injector = self.faults.lock().unwrap().clone();
            if injector.tunedb_io() {
                self.obs.io_faults.fetch_add(1, Ordering::Relaxed);
                eprintln!(
                    "warning: injected tunedb_io fault: dropping disk append of {} record(s)",
                    recs.len()
                );
            } else {
                let rep = store::append(path, &recs, &injector);
                if rep.sync_failed {
                    self.obs.fsync_failures.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        for rec in recs {
            g.records.push(rec);
            let idx = g.records.len() - 1;
            g.index(idx);
        }
    }

    /// Record one tuning run: the winner plus up to [`HISTORY_SAMPLES`]
    /// evenly-spaced finite history entries (model-training food).
    pub fn record_tune(
        &self,
        kernel: &str,
        dev: &'static DeviceSpec,
        grid: (usize, usize),
        res: &TuneResult,
        fm: &FeatureMap,
    ) {
        let fp = device_fingerprint(dev);
        let make = |config: &crate::transform::TuningConfig, seconds: f64, best: bool| TuneRecord {
            kernel: kernel.to_string(),
            device: dev.name,
            dev_fp: fp,
            grid,
            seconds,
            best,
            wall: false,
            config: config.clone(),
            features: fm.features(config),
            seq: 0,
            kfeat: [0.0; 3],
        };
        let mut recs = vec![make(&res.best, res.best_time, true)];
        let finite: Vec<&(crate::transform::TuningConfig, f64)> =
            res.history.iter().filter(|(_, t)| t.is_finite()).collect();
        if !finite.is_empty() {
            // Ceiling stride: the samples stay evenly spaced over the
            // whole history (a floor stride would take a prefix whenever
            // the history is under 2× the sample count, biasing the
            // model's training set toward one corner of the space).
            let mut step = finite.len() / HISTORY_SAMPLES;
            if finite.len() % HISTORY_SAMPLES != 0 {
                step += 1;
            }
            let step = step.max(1);
            for (cfg, t) in finite.into_iter().step_by(step).take(HISTORY_SAMPLES) {
                recs.push(make(cfg, *t, false));
            }
        }
        self.record_batch(recs);
    }

    /// Record one *real-execution* wall-clock measurement of a served
    /// config (the worker-side timing that `TuneResult::wall_secs`
    /// accounts for searches): stored as non-winner history flagged
    /// `wall`, so the per-kernel model accumulates ground truth from the
    /// hardware it actually serves on alongside simulator estimates.
    pub fn record_wall(
        &self,
        kernel: &str,
        dev: &'static DeviceSpec,
        grid: (usize, usize),
        config: &crate::transform::TuningConfig,
        features: Vec<f64>,
        secs: f64,
    ) {
        if !secs.is_finite() || secs <= 0.0 {
            return;
        }
        self.record(TuneRecord {
            kernel: kernel.to_string(),
            device: dev.name,
            dev_fp: device_fingerprint(dev),
            grid,
            seconds: secs,
            best: false,
            wall: true,
            config: config.clone(),
            features,
            seq: 0,
            kfeat: [0.0; 3],
        });
    }

    /// Wall-clock (real-execution) records currently held.
    pub fn wall_len(&self) -> usize {
        self.inner.lock().unwrap().records.iter().filter(|r| r.wall).count()
    }

    /// Tier-1 lookup: the latest winner record at exactly this key.
    pub fn exact(&self, kernel: &str, device: &str, grid: (usize, usize)) -> Option<TuneRecord> {
        let g = self.inner.lock().unwrap();
        let idxs = g.best.get(&(kernel.to_string(), crate::devices::by_name(device)?.name))?;
        idxs.iter()
            .rev()
            .map(|&i| &g.records[i])
            .find(|r| r.grid == grid)
            .cloned()
    }

    /// Tier-2 lookup: winner records for the same kernel + device,
    /// sorted by ascending grid distance (ties broken latest-first),
    /// truncated to `k`. Excludes exact-grid records.
    pub fn nearest_grids(
        &self,
        kernel: &str,
        device: &str,
        grid: (usize, usize),
        k: usize,
    ) -> Vec<(TuneRecord, f64)> {
        let Some(dev) = crate::devices::by_name(device) else { return Vec::new() };
        let g = self.inner.lock().unwrap();
        let Some(idxs) = g.best.get(&(kernel.to_string(), dev.name)) else {
            return Vec::new();
        };
        let mut scored: Vec<(usize, f64)> = idxs
            .iter()
            .rev()
            .map(|&i| (i, grid_distance(g.records[i].grid, grid)))
            .filter(|&(i, _)| g.records[i].grid != grid)
            .collect();
        scored.sort_by(|a, b| {
            a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal)
        });
        scored
            .into_iter()
            .take(k)
            .map(|(i, d)| (g.records[i].clone(), d))
            .collect()
    }

    /// The single nearest-grid winner (tier 2).
    pub fn nearest_grid(
        &self,
        kernel: &str,
        device: &str,
        grid: (usize, usize),
    ) -> Option<(TuneRecord, f64)> {
        self.nearest_grids(kernel, device, grid, 1).into_iter().next()
    }

    /// Tiered lookup (exact, then transfer). The model tier needs the
    /// kernel's tuning space, so it stays with the caller — see
    /// [`TuneDb::model_for`].
    pub fn lookup(&self, kernel: &str, device: &str, grid: (usize, usize)) -> Answer {
        if let Some(rec) = self.exact(kernel, device, grid) {
            self.obs.lookups_exact.fetch_add(1, Ordering::Relaxed);
            return Answer::Exact(rec);
        }
        if let Some((rec, distance)) = self.nearest_grid(kernel, device, grid) {
            self.obs.lookups_transfer.fetch_add(1, Ordering::Relaxed);
            return Answer::Transfer { rec, distance };
        }
        self.obs.lookups_miss.fetch_add(1, Ordering::Relaxed);
        Answer::Miss
    }

    /// The kernel's cached model **without training**: `(model, fresh)`.
    /// `fresh == false` means records arrived since the model was fitted
    /// (or none was ever fitted while training data exists). Callers
    /// that must not block — the serve request path — use whatever is
    /// cached and hand the retrain to a background thread
    /// ([`Self::refresh_model`]; see `serve`'s model trainer).
    pub fn cached_model(&self, kernel: &str) -> (Option<Arc<PerfModel>>, bool) {
        let g = self.inner.lock().unwrap();
        let n = g.by_kernel.get(kernel).map_or(0, Vec::len);
        match g.models.get(kernel) {
            Some((stamp, model)) => (model.clone(), *stamp == n),
            // No cache entry: fresh only in the trivial no-records case
            // (nothing to train on → nothing to schedule).
            None => (None, n == 0),
        }
    }

    /// Train (or retrain) the kernel's model on the current records,
    /// blocking the caller — the CLI's `tunedb train` and the serving
    /// layer's *background* trainer thread use this; the request path
    /// never should.
    pub fn refresh_model(&self, kernel: &str) -> Option<Arc<PerfModel>> {
        self.model_for(kernel)
    }

    /// Tier-3 support: the kernel's performance model, trained lazily on
    /// the current records and cached until new records arrive. `None`
    /// when there is too little usable data.
    pub fn model_for(&self, kernel: &str) -> Option<Arc<PerfModel>> {
        // Snapshot the training set under the lock, but train *outside*
        // it — training takes milliseconds and must not stall concurrent
        // lookups/records for unrelated keys.
        let (stamp, records) = {
            let g = self.inner.lock().unwrap();
            let idxs = g.by_kernel.get(kernel)?;
            if let Some((stamp, model)) = g.models.get(kernel) {
                if *stamp == idxs.len() {
                    return model.clone();
                }
            }
            let records: Vec<TuneRecord> =
                idxs.iter().map(|&i| g.records[i].clone()).collect();
            (idxs.len(), records)
        };
        let refs: Vec<&TuneRecord> = records.iter().collect();
        self.obs.model_refreshes.fetch_add(1, Ordering::Relaxed);
        let model = PerfModel::train(kernel, &refs).map(Arc::new);
        // Concurrent trainers race benignly: last insert wins, and a
        // stale stamp just means a lazy retrain on the next call. Failed
        // trainings are cached too (retry only once new records arrive).
        let mut g = self.inner.lock().unwrap();
        g.models.insert(kernel.to_string(), (stamp, model.clone()));
        model
    }

    /// Records known for one kernel (winners + history).
    pub fn kernel_len(&self, kernel: &str) -> usize {
        self.inner.lock().unwrap().by_kernel.get(kernel).map_or(0, Vec::len)
    }

    /// Kernels in the db nearest to `kernel` by static structure
    /// (stencil shape + arithmetic intensity), sorted ascending by
    /// [`kernel_feature_distance`] and truncated to `k`. The seed for
    /// cold-starting a brand-new kernel from its structural neighbors'
    /// records. Empty when `kernel`'s features are underivable or no
    /// other kernel in the db carries stamped features.
    pub fn similar_kernels(&self, kernel: &str, k: usize) -> Vec<(String, f64)> {
        let g = self.inner.lock().unwrap();
        // Target features: derived from source when we hold it, else the
        // stamped kfeat of any of the kernel's own records.
        let target = kernel_static_features(kernel).or_else(|| {
            g.by_kernel.get(kernel).and_then(|idxs| {
                idxs.iter().map(|&i| g.records[i].kfeat).find(|kf| *kf != [0.0; 3])
            })
        });
        let Some(target) = target else { return Vec::new() };
        let mut seen: HashMap<&str, [f64; 3]> = HashMap::new();
        for r in &g.records {
            if r.kernel != kernel && r.kfeat != [0.0; 3] {
                seen.entry(r.kernel.as_str()).or_insert(r.kfeat);
            }
        }
        let mut scored: Vec<(String, f64)> = seen
            .into_iter()
            .map(|(name, kf)| (name.to_string(), kernel_feature_distance(target, kf)))
            .collect();
        scored.sort_by(|a, b| a.1.total_cmp(&b.1).then_with(|| a.0.cmp(&b.0)));
        scored.truncate(k);
        scored
    }

    /// Execution-time estimate for a key, for schedulers: an exact
    /// winner's measured time, or the nearest-grid winner's time scaled
    /// by the pixel-count ratio. `None` = no same-device knowledge.
    pub fn estimate(&self, kernel: &str, device: &str, grid: (usize, usize)) -> Option<f64> {
        if let Some(rec) = self.exact(kernel, device, grid) {
            return Some(rec.seconds);
        }
        let (rec, _) = self.nearest_grid(kernel, device, grid)?;
        let pixels = (grid.0 * grid.1).max(1) as f64;
        let rec_pixels = (rec.grid.0 * rec.grid.1).max(1) as f64;
        Some(rec.seconds * pixels / rec_pixels)
    }

    /// Publish this knowledge base's state into the global metrics
    /// registry as `imagecl_tunedb_*`. Counters publish via
    /// max-absolute (idempotent re-publish); sizes are gauges because
    /// compaction shrinks them.
    pub fn publish_obs(&self) {
        let reg = crate::obs::registry();
        let counters: [(&str, &str, &AtomicU64); 9] = [
            (
                "imagecl_tunedb_lookups_exact_total",
                "Lookups answered by an exact-key winner (tier 1)",
                &self.obs.lookups_exact,
            ),
            (
                "imagecl_tunedb_lookups_transfer_total",
                "Lookups answered by a nearest-grid transfer seed (tier 2)",
                &self.obs.lookups_transfer,
            ),
            (
                "imagecl_tunedb_lookups_miss_total",
                "Lookups with no same-device knowledge",
                &self.obs.lookups_miss,
            ),
            (
                "imagecl_tunedb_records_appended_total",
                "Records appended to the knowledge base",
                &self.obs.records_appended,
            ),
            (
                "imagecl_tunedb_model_refreshes_total",
                "Performance-model trainings executed",
                &self.obs.model_refreshes,
            ),
            (
                "imagecl_tunedb_skipped_lines",
                "Unusable store lines skipped on load (truncated/corrupt)",
                &self.obs.skipped_lines,
            ),
            (
                "imagecl_tunedb_fsck_quarantined_total",
                "Torn/corrupt journal lines quarantined on load",
                &self.obs.fsck_quarantined,
            ),
            (
                "imagecl_tunedb_fsync_failures_total",
                "Journal appends whose post-write fsync failed",
                &self.obs.fsync_failures,
            ),
            (
                "imagecl_tunedb_io_faults_total",
                "Disk appends dropped by injected tunedb_io faults",
                &self.obs.io_faults,
            ),
        ];
        for (name, help, v) in counters {
            reg.counter(name, help, &[]).set_max(v.load(Ordering::Relaxed));
        }
        reg.gauge("imagecl_tunedb_records", "Records currently held", &[])
            .set(self.len() as f64);
        reg.gauge("imagecl_tunedb_winners", "Winner records currently held", &[])
            .set(self.best_len() as f64);
        reg.gauge(
            "imagecl_tunedb_wall_records",
            "Real-execution wall records currently held",
            &[],
        )
        .set(self.wall_len() as f64);
    }

    /// Migration shim: import a legacy PR-1 warm-start TSV
    /// (`kernel device grid_w grid_h est_seconds config`), skipping keys
    /// the db already has an exact winner for. Feature vectors are
    /// recomputed for built-in kernels (unknown kernels import without
    /// features — usable for exact/transfer hits, invisible to the
    /// model). Returns the number of records imported.
    pub fn import_legacy_tsv(&self, path: &Path) -> usize {
        let Ok(text) = std::fs::read_to_string(path) else { return 0 };
        let mut fms: HashMap<String, Option<FeatureMap>> = HashMap::new();
        let mut imported = Vec::new();
        for mut rec in store::parse_legacy_tsv(&text) {
            if self.exact(&rec.kernel, rec.device, rec.grid).is_some() {
                continue;
            }
            let fm = fms.entry(rec.kernel.clone()).or_insert_with(|| {
                bench_defs::kernel_by_id(&rec.kernel).and_then(|k| {
                    frontend(k.source)
                        .ok()
                        .map(|prog| FeatureMap::new(&KernelInfo::analyze(prog)))
                })
            });
            if let Some(fm) = fm {
                rec.features = fm.features(&rec.config);
            }
            imported.push(rec);
        }
        let n = imported.len();
        self.record_batch(imported);
        n
    }
}

/// What [`fsck`] found in a store file.
#[derive(Debug, Clone)]
pub struct FsckReport {
    /// Intact, applicable records.
    pub records: usize,
    /// Torn/corrupt lines: (1-based line number, raw text).
    pub quarantined: Vec<(usize, String)>,
    /// Intact lines dropped as inapplicable (unknown device / stale
    /// device fingerprint).
    pub stale: usize,
    /// The store's epoch header, when present.
    pub epoch: Option<u64>,
    /// Highest journal sequence number.
    pub max_seq: u64,
}

impl FsckReport {
    /// No damage anywhere in the file.
    pub fn clean(&self) -> bool {
        self.quarantined.is_empty()
    }
}

fn fsck_report(text: &str) -> (FsckReport, Vec<TuneRecord>) {
    let report = store::parse_file(text);
    (
        FsckReport {
            records: report.records.len(),
            quarantined: report.quarantined,
            stale: report.stale,
            epoch: report.epoch,
            max_seq: report.max_seq,
        },
        report.records,
    )
}

/// Audit a store file: classify every line (record / stale / torn or
/// corrupt) without modifying anything. The CLI's `tunedb fsck`.
pub fn fsck(path: &Path) -> std::io::Result<FsckReport> {
    let text = std::fs::read_to_string(path)?;
    Ok(fsck_report(&text).0)
}

/// Sidecar file quarantined raw lines are stashed into on repair.
pub fn quarantine_path(path: &Path) -> PathBuf {
    let mut name = path.file_name().unwrap_or_default().to_os_string();
    name.push(".quarantine");
    path.with_file_name(name)
}

/// Repair a store file: stash every damaged raw line into the
/// `.quarantine` sidecar (appending — earlier stashes survive), then
/// atomically rewrite the store as a clean v2 snapshot of the intact
/// records (legacy lines are re-framed with CRCs; stale lines drop).
/// The CLI's `tunedb fsck --repair`.
pub fn fsck_repair(path: &Path) -> std::io::Result<FsckReport> {
    use std::io::Write as _;
    let text = std::fs::read_to_string(path)?;
    let (report, records) = fsck_report(&text);
    if !report.quarantined.is_empty() {
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(quarantine_path(path))?;
        for (lno, raw) in &report.quarantined {
            writeln!(f, "# {}:{lno}", path.display())?;
            writeln!(f, "{raw}")?;
        }
        f.sync_all()?;
    }
    store::rewrite(path, &records)?;
    Ok(report)
}

/// Outcome of a [`merge_files`] run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MergeStats {
    /// Store files read (destination, when it existed, + sources).
    pub inputs: usize,
    /// Records read across all inputs (before dedup/resolution).
    pub records_in: usize,
    /// Records in the merged store.
    pub merged: usize,
    /// Damaged lines quarantined across all inputs (left in place in
    /// the sources; excluded from the merge).
    pub quarantined: usize,
}

/// Conflict-free merge of replica store files into `dst` (which need
/// not exist; when it does, its records participate). Resolution is
/// [`store::merge_records`]'s total order, and the output is written
/// atomically with a content-derived epoch — so any merge order of the
/// same replica set produces a byte-identical `dst`, and re-merging is
/// a no-op. The CLI's `tunedb merge`.
pub fn merge_files(dst: &Path, srcs: &[PathBuf]) -> std::io::Result<MergeStats> {
    let mut sets = Vec::new();
    let mut stats = MergeStats { inputs: 0, records_in: 0, merged: 0, quarantined: 0 };
    let mut load = |path: &Path, required: bool| -> std::io::Result<()> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if !required && e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(())
            }
            Err(e) => return Err(e),
        };
        let report = store::parse_file(&text);
        stats.inputs += 1;
        stats.records_in += report.records.len();
        stats.quarantined += report.quarantined.len();
        sets.push(report.records);
        Ok(())
    };
    load(dst, false)?;
    for src in srcs {
        load(src, true)?;
    }
    let merged = merge_records(sets);
    stats.merged = merged.len();
    store::rewrite(dst, &merged)?;
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::{INTEL_I7, K40};
    use crate::transform::TuningConfig;

    fn rec(kernel: &str, dev: &'static DeviceSpec, n: usize, secs: f64, best: bool) -> TuneRecord {
        let mut config = TuningConfig::default();
        config.wg = [64, 4];
        TuneRecord {
            kernel: kernel.to_string(),
            device: dev.name,
            dev_fp: device_fingerprint(dev),
            grid: (n, n),
            seconds: secs,
            best,
            wall: false,
            config,
            features: vec![6.0, 2.0],
            seq: 0,
            kfeat: [0.0; 3],
        }
    }

    #[test]
    fn db_counters_track_lookups_and_appends() {
        let db = TuneDb::ephemeral();
        let _ = db.lookup("sobel", K40.name, (64, 64)); // miss
        db.record(rec("sobel", &K40, 32, 1e-4, true));
        let _ = db.lookup("sobel", K40.name, (64, 64)); // transfer
        db.record(rec("sobel", &K40, 64, 1e-4, true));
        let _ = db.lookup("sobel", K40.name, (64, 64)); // exact
        assert_eq!(db.obs.lookups_miss.load(Ordering::Relaxed), 1);
        assert_eq!(db.obs.lookups_transfer.load(Ordering::Relaxed), 1);
        assert_eq!(db.obs.lookups_exact.load(Ordering::Relaxed), 1);
        assert_eq!(db.obs.records_appended.load(Ordering::Relaxed), 2);
        // Publishing registers the family set without panicking.
        db.publish_obs();
    }

    #[test]
    fn wall_records_stored_and_counted() {
        let db = TuneDb::ephemeral();
        db.record(rec("sobel", &K40, 64, 1e-4, true));
        assert_eq!(db.wall_len(), 0);
        db.record_wall("sobel", &K40, (64, 64), &TuningConfig::default(), vec![1.0], 2.5e-4);
        // Non-finite / non-positive measurements are dropped.
        db.record_wall("sobel", &K40, (64, 64), &TuningConfig::default(), vec![], f64::NAN);
        db.record_wall("sobel", &K40, (64, 64), &TuningConfig::default(), vec![], 0.0);
        assert_eq!(db.wall_len(), 1);
        assert_eq!(db.len(), 2);
        let wall: Vec<TuneRecord> =
            db.snapshot().into_iter().filter(|r| r.wall).collect();
        assert_eq!(wall.len(), 1);
        assert!(!wall[0].best);
        assert_eq!(wall[0].seconds, 2.5e-4);
        // Wall history never answers exact-winner lookups.
        assert_eq!(db.exact("sobel", K40.name, (64, 64)).unwrap().seconds, 1e-4);
    }

    #[test]
    fn cached_model_reports_staleness_without_training() {
        let db = TuneDb::ephemeral();
        // Empty: nothing cached, and nothing to train → fresh.
        assert!(matches!(db.cached_model("sobel"), (None, true)));
        db.record(rec("sobel", &K40, 64, 1e-4, true));
        // Records exist but no fit ran yet → stale, still no model.
        assert!(matches!(db.cached_model("sobel"), (None, false)));
        // A (failed — too few records) training is cached as fresh.
        assert!(db.refresh_model("sobel").is_none());
        let (m, fresh) = db.cached_model("sobel");
        assert!(m.is_none() && fresh);
        // New records invalidate the cache again.
        db.record(rec("sobel", &K40, 128, 2e-4, true));
        assert!(!db.cached_model("sobel").1);
    }

    #[test]
    fn exact_prefers_latest_winner() {
        let db = TuneDb::ephemeral();
        db.record(rec("sobel", &K40, 64, 2e-4, true));
        db.record(rec("sobel", &K40, 64, 1e-4, true)); // re-tune, newer
        db.record(rec("sobel", &K40, 64, 5e-5, false)); // history, ignored
        let hit = db.exact("sobel", K40.name, (64, 64)).unwrap();
        assert_eq!(hit.seconds, 1e-4);
        assert!(db.exact("sobel", K40.name, (128, 128)).is_none());
        assert!(db.exact("sobel", INTEL_I7.name, (64, 64)).is_none());
    }

    #[test]
    fn nearest_grid_orders_by_log_distance() {
        let db = TuneDb::ephemeral();
        db.record(rec("sobel", &K40, 128, 1e-4, true));
        db.record(rec("sobel", &K40, 500, 2e-4, true));
        db.record(rec("sobel", &K40, 2000, 3e-4, true));
        // Log-scale: 2000 is nearer to 1024 (|ln 2000/1024| ≈ 0.67) than
        // 500 (≈ 0.72) than 128 (≈ 2.08).
        let hits = db.nearest_grids("sobel", K40.name, (1024, 1024), 3);
        let grids: Vec<usize> = hits.iter().map(|(r, _)| r.grid.0).collect();
        assert_eq!(grids, vec![2000, 500, 128]);
        assert!(hits[0].1 < hits[1].1 && hits[1].1 < hits[2].1);
        // Exact-grid records are excluded from transfer candidates.
        db.record(rec("sobel", &K40, 1024, 9e-5, true));
        let hits = db.nearest_grids("sobel", K40.name, (1024, 1024), 4);
        assert!(hits.iter().all(|(r, _)| r.grid.0 != 1024));
        // Other devices contribute nothing.
        assert!(db.nearest_grid("sobel", INTEL_I7.name, (1024, 1024)).is_none());
    }

    #[test]
    fn lookup_tiers() {
        let db = TuneDb::ephemeral();
        assert!(matches!(db.lookup("sobel", K40.name, (64, 64)), Answer::Miss));
        db.record(rec("sobel", &K40, 32, 1e-4, true));
        assert!(matches!(
            db.lookup("sobel", K40.name, (64, 64)),
            Answer::Transfer { .. }
        ));
        db.record(rec("sobel", &K40, 64, 1e-4, true));
        assert!(matches!(db.lookup("sobel", K40.name, (64, 64)), Answer::Exact(_)));
    }

    #[test]
    fn estimate_scales_by_pixels() {
        let db = TuneDb::ephemeral();
        db.record(rec("sobel", &K40, 512, 1e-3, true));
        // Exact.
        assert_eq!(db.estimate("sobel", K40.name, (512, 512)), Some(1e-3));
        // Transfer: 4× the pixels → 4× the estimate.
        let est = db.estimate("sobel", K40.name, (1024, 1024)).unwrap();
        assert!((est - 4e-3).abs() < 1e-12, "{est}");
        assert!(db.estimate("sobel", INTEL_I7.name, (512, 512)).is_none());
    }

    #[test]
    fn store_reload_roundtrip() {
        let path = std::env::temp_dir()
            .join(format!("imagecl_tunedb_roundtrip_{}.tsv", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let db = TuneDb::open(&path);
            assert!(db.is_empty());
            db.record(rec("sobel", &K40, 64, 1e-4, true));
            db.record(rec("sobel", &K40, 64, 3e-4, false));
            db.record(rec("conv2d", &INTEL_I7, 128, 2e-3, true));
            assert_eq!(db.len(), 3);
            assert_eq!(db.best_len(), 2);
        }
        let db = TuneDb::open(&path);
        assert_eq!(db.len(), 3);
        assert_eq!(db.best_len(), 2);
        let hit = db.exact("sobel", K40.name, (64, 64)).unwrap();
        assert_eq!(hit, rec("sobel", &K40, 64, 1e-4, true));
        assert_eq!(
            db.exact("conv2d", INTEL_I7.name, (128, 128)).unwrap().seconds,
            2e-3
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn legacy_import_roundtrip() {
        use crate::serve::TunedStore;
        use crate::serve::cache::{PlanKey, TunedRecord};
        let legacy = std::env::temp_dir()
            .join(format!("imagecl_tunedb_legacy_{}.tsv", std::process::id()));
        let _ = std::fs::remove_file(&legacy);
        let store = TunedStore::open(&legacy);
        let mut config = TuningConfig::default();
        config.wg = [32, 8];
        config.constant_mem.insert("f".into(), true);
        store.insert(
            PlanKey { kernel: "sepconv_row".to_string(), device: K40.name, grid: (96, 96) },
            TunedRecord { config: config.clone(), est_seconds: 7e-4 },
        );

        let db = TuneDb::ephemeral();
        assert_eq!(db.import_legacy_tsv(&legacy), 1);
        let hit = db.exact("sepconv_row", K40.name, (96, 96)).unwrap();
        assert_eq!(hit.config, config);
        assert_eq!(hit.seconds, 7e-4);
        // Built-in kernel → features recomputed for model training.
        assert!(!hit.features.is_empty());
        // Re-import is idempotent (exact key already known).
        assert_eq!(db.import_legacy_tsv(&legacy), 0);
        let _ = std::fs::remove_file(&legacy);
    }

    #[test]
    fn compact_caps_history_and_keeps_latest_winner() {
        let db = TuneDb::ephemeral();
        // Three generations of winners + 10 history records at one key,
        // plus an untouched second key.
        db.record(rec("sobel", &K40, 64, 3e-4, true));
        db.record(rec("sobel", &K40, 64, 2e-4, true));
        for i in 0..10 {
            db.record(rec("sobel", &K40, 64, 1e-3 + i as f64 * 1e-5, false));
        }
        db.record(rec("sobel", &K40, 64, 1e-4, true));
        db.record(rec("conv2d", &INTEL_I7, 128, 2e-3, true));
        let stats = db.compact(4);
        // Keeps: latest sobel winner + 4 newest history + conv2d winner.
        assert_eq!(stats.kept, 6);
        assert_eq!(stats.removed, 8);
        assert_eq!(db.len(), 6);
        assert_eq!(db.best_len(), 2);
        // The latest winner still answers exact lookups.
        assert_eq!(db.exact("sobel", K40.name, (64, 64)).unwrap().seconds, 1e-4);
        // The surviving history is the most recent (largest seconds).
        let hist: Vec<f64> = db
            .snapshot()
            .iter()
            .filter(|r| !r.best)
            .map(|r| r.seconds)
            .collect();
        let want: Vec<f64> = (6..10).map(|i| 1e-3 + i as f64 * 1e-5).collect();
        assert_eq!(hist, want);
    }

    #[test]
    fn compact_roundtrips_through_disk_and_load() {
        let path = std::env::temp_dir()
            .join(format!("imagecl_tunedb_compact_{}.tsv", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let db = TuneDb::open(&path);
            // Two winner generations + more history than the load cap.
            db.record(rec("sobel", &K40, 64, 5e-4, true));
            for i in 0..(HISTORY_CAP_PER_KEY + 20) {
                db.record(rec("sobel", &K40, 64, 1e-3 + i as f64 * 1e-6, false));
            }
            db.record(rec("sobel", &K40, 64, 1e-4, true));
        }
        // Reload: compaction on load trims to cap + 1 winner and rewrites
        // the file; a second reload sees the already-compact store.
        for _ in 0..2 {
            let db = TuneDb::open(&path);
            assert_eq!(db.len(), HISTORY_CAP_PER_KEY + 1);
            assert_eq!(db.best_len(), 1);
            let win = db.exact("sobel", K40.name, (64, 64)).unwrap();
            assert_eq!(win.seconds, 1e-4);
        }
        // Explicit compaction with a tighter cap shrinks further and
        // persists (the CLI path: `imagecl tunedb compact --cap N`).
        {
            let db = TuneDb::open(&path);
            let stats = db.compact(8);
            assert_eq!(stats.kept, 9);
            assert!(stats.removed > 0);
        }
        let db = TuneDb::open(&path);
        assert_eq!(db.len(), 9);
        assert_eq!(db.exact("sobel", K40.name, (64, 64)).unwrap().seconds, 1e-4);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn open_tolerates_truncated_trailing_record() {
        let path = std::env::temp_dir()
            .join(format!("imagecl_tunedb_trunc_{}.tsv", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let db = TuneDb::open(&path);
            db.record(rec("sobel", &K40, 64, 1e-4, true));
            db.record(rec("conv2d", &INTEL_I7, 128, 2e-3, true));
        }
        // Simulate a crash mid-append: chop the file mid-way through the
        // last record's line.
        let text = std::fs::read_to_string(&path).unwrap();
        let cut = text.trim_end().len() - 17;
        std::fs::write(&path, &text[..cut]).unwrap();
        // Load succeeds, keeps the intact record, counts the skip.
        let db = TuneDb::open(&path);
        assert_eq!(db.len(), 1);
        assert!(db.exact("sobel", K40.name, (64, 64)).is_some());
        assert_eq!(db.obs.skipped_lines.load(Ordering::Relaxed), 1);
        db.publish_obs(); // registers the skipped-lines family
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn injected_io_fault_drops_disk_append_only() {
        use crate::serve::faults::{FaultInjector, FaultSpec};
        let path = std::env::temp_dir()
            .join(format!("imagecl_tunedb_iofault_{}.tsv", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let db = TuneDb::open(&path);
            let spec = FaultSpec { tunedb_io: 1.0, ..FaultSpec::default() };
            db.set_faults(FaultInjector::new(spec));
            db.record(rec("sobel", &K40, 64, 1e-4, true));
            // In-memory index is intact: lookups still answer.
            assert!(db.exact("sobel", K40.name, (64, 64)).is_some());
            assert_eq!(db.obs.io_faults.load(Ordering::Relaxed), 1);
        }
        // The append never reached disk.
        let db = TuneDb::open(&path);
        assert!(db.is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn seq_numbers_are_monotone_across_reload() {
        let path = std::env::temp_dir()
            .join(format!("imagecl_tunedb_seq_{}.tsv", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let db = TuneDb::open(&path);
            db.record(rec("sobel", &K40, 64, 1e-4, true));
            db.record(rec("sobel", &K40, 128, 2e-4, true));
            let seqs: Vec<u64> = db.snapshot().iter().map(|r| r.seq).collect();
            assert_eq!(seqs, vec![1, 2]);
        }
        // A reloaded store continues the sequence, never reuses it.
        let db = TuneDb::open(&path);
        db.record(rec("sobel", &K40, 256, 3e-4, true));
        let max = db.snapshot().iter().map(|r| r.seq).max().unwrap();
        assert_eq!(max, 3);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn kfeat_stamped_for_builtin_kernels_and_similarity_ranks() {
        let db = TuneDb::ephemeral();
        db.record(rec("sobel", &K40, 64, 1e-4, true));
        db.record(rec("sepconv_row", &K40, 64, 1e-4, true));
        db.record(rec("not_a_builtin", &K40, 64, 1e-4, true));
        let snap = db.snapshot();
        let by_name = |n: &str| snap.iter().find(|r| r.kernel == n).unwrap().clone();
        // Built-in kernels get real static features; unknown sources
        // stay unstamped (all-zero).
        assert_ne!(by_name("sobel").kfeat, [0.0; 3]);
        assert_ne!(by_name("sepconv_row").kfeat, [0.0; 3]);
        assert_eq!(by_name("not_a_builtin").kfeat, [0.0; 3]);
        // Sobel reads a 3x3 neighborhood.
        assert_eq!(by_name("sobel").kfeat[0], 2.0);
        assert_eq!(by_name("sobel").kfeat[1], 2.0);
        assert!(by_name("sobel").kfeat[2] > 0.0);
        // Similarity query: sees only kernels with stamped features,
        // never echoes the query kernel itself.
        let sim = db.similar_kernels("sobel", 8);
        let names: Vec<&str> = sim.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["sepconv_row"]);
        assert!(sim[0].1.is_finite());
        // Unknown kernel with no records → no basis for similarity.
        assert!(db.similar_kernels("never_seen", 8).is_empty());
    }

    #[test]
    fn fsck_audits_and_repair_quarantines() {
        let path = std::env::temp_dir()
            .join(format!("imagecl_tunedb_fsck_{}.tsv", std::process::id()));
        let side = quarantine_path(&path);
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&side);
        {
            let db = TuneDb::open(&path);
            db.record(rec("sobel", &K40, 64, 1e-4, true));
            db.record(rec("conv2d", &INTEL_I7, 128, 2e-3, true));
        }
        // Flip a byte in the middle of the first record line.
        let mut bytes = std::fs::read(&path).unwrap();
        let first_rec = {
            let text = std::str::from_utf8(&bytes).unwrap();
            let start = text.lines().take_while(|l| l.starts_with('#')).map(|l| l.len() + 1).sum::<usize>();
            start + 40
        };
        bytes[first_rec] ^= 0x20;
        std::fs::write(&path, &bytes).unwrap();

        let report = fsck(&path).unwrap();
        assert!(!report.clean());
        assert_eq!(report.records, 1);
        assert_eq!(report.quarantined.len(), 1);
        // open() surfaces the same damage in its counters.
        {
            let db = TuneDb::open(&path);
            assert_eq!(db.obs.fsck_quarantined.load(Ordering::Relaxed), 1);
            db.publish_obs();
        }
        // Repair: damage stashed to the sidecar, store rewritten clean.
        let repaired = fsck_repair(&path).unwrap();
        assert_eq!(repaired.quarantined.len(), 1);
        let after = fsck(&path).unwrap();
        assert!(after.clean());
        assert_eq!(after.records, 1);
        let stash = std::fs::read_to_string(&side).unwrap();
        assert!(stash.contains(&repaired.quarantined[0].1));
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&side);
    }

    #[test]
    fn merge_files_is_idempotent_and_order_independent() {
        let base = std::env::temp_dir()
            .join(format!("imagecl_tunedb_merge_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let replica = |name: &str, recs: &[TuneRecord]| -> PathBuf {
            let p = base.join(name);
            let db = TuneDb::open(&p);
            for r in recs {
                db.record(r.clone());
            }
            p
        };
        let a = replica(
            "a.tsv",
            &[rec("sobel", &K40, 64, 1e-4, true), rec("sobel", &K40, 128, 2e-4, true)],
        );
        let b = replica(
            "b.tsv",
            &[rec("sobel", &K40, 64, 1e-4, true), rec("conv2d", &INTEL_I7, 128, 2e-3, true)],
        );
        let ab = base.join("ab.tsv");
        let ba = base.join("ba.tsv");
        let stats = merge_files(&ab, &[a.clone(), b.clone()]).unwrap();
        merge_files(&ba, &[b.clone(), a.clone()]).unwrap();
        assert_eq!(stats.inputs, 2);
        assert_eq!(stats.records_in, 4);
        // The duplicate (sobel, 64) outcome collapses.
        assert_eq!(stats.merged, 3);
        // Order independence: byte-identical outputs.
        assert_eq!(std::fs::read(&ab).unwrap(), std::fs::read(&ba).unwrap());
        // Idempotence: re-merging changes nothing.
        let again = merge_files(&ab, &[a, b]).unwrap();
        assert_eq!(again.merged, 3);
        assert_eq!(std::fs::read(&ab).unwrap(), std::fs::read(&ba).unwrap());
        // The merged store answers lookups.
        let db = TuneDb::open(&ab);
        assert_eq!(db.len(), 3);
        assert!(db.exact("sobel", K40.name, (64, 64)).is_some());
        assert!(db.exact("conv2d", INTEL_I7.name, (128, 128)).is_some());
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn record_tune_stores_winner_and_sampled_history() {
        let info = crate::analysis::KernelInfo::analyze(
            frontend(crate::bench_defs::SOBEL).unwrap(),
        );
        let fm = FeatureMap::new(&info);
        let mut history = Vec::new();
        for i in 0..200 {
            let mut c = TuningConfig::default();
            c.wg = [16, 1 << (i % 4)];
            history.push((c, 1e-4 + i as f64 * 1e-6));
        }
        let res = TuneResult {
            best: TuningConfig::default(),
            best_time: 9e-5,
            evals: 200,
            space_size: 1000,
            history,
            wall_secs: 0.02,
        };
        let db = TuneDb::ephemeral();
        db.record_tune("sobel", &K40, (64, 64), &res, &fm);
        assert_eq!(db.best_len(), 1);
        assert!(db.len() > 1 && db.len() <= 1 + HISTORY_SAMPLES + 1, "{}", db.len());
        let win = db.exact("sobel", K40.name, (64, 64)).unwrap();
        assert_eq!(win.seconds, 9e-5);
        assert_eq!(win.features, fm.features(&win.config));
    }
}
