//! Model-backed warm starts: train the tuner's MLP on the knowledge
//! base's accumulated records and rank candidate configurations for a
//! *cold* (kernel, device) pair before any measurement.
//!
//! This is the transfer-tuning idea of Falch & Elster's companion work
//! (arXiv:1506.00842): a performance model trained on observed
//! (configuration, device, problem) → time samples predicts good
//! configurations for unseen problems. Features are the per-kernel
//! config encoding ([`crate::tuner::FeatureMap`], stored inline in each
//! record) concatenated with a device-characteristics vector and the
//! log grid dimensions, so one model per kernel covers every device and
//! grid the store has seen.

use crate::devices::DeviceSpec;
use crate::transform::TuningConfig;
use crate::tuner::{FeatureMap, Mlp, TuningSpace};

use super::store::TuneRecord;

/// Minimum usable records before a model is trained (below this the
/// service falls back to a full cold search).
pub const MIN_TRAIN_RECORDS: usize = 16;

/// Training epochs — records arrive continuously, so the model is
/// retrained cheaply and often rather than heavily and once.
const EPOCHS: usize = 30;
const HIDDEN: [usize; 2] = [32, 16];
const SEED: u64 = 0x7E5B_A5ED;

/// Device-characteristics features (fixed layout, log-scaled where the
/// quantity spans orders of magnitude).
pub fn device_features(dev: &DeviceSpec) -> Vec<f64> {
    let lg = |v: f64| v.max(1e-12).log2();
    vec![
        lg(dev.compute_units as f64),
        lg(dev.simd_width as f64),
        dev.clock_ghz,
        lg(dev.flops_per_cycle_cu),
        lg(dev.mem_bw_gbs),
        dev.global_cache_eff,
        dev.tex_cache_eff,
        lg(dev.tex_access_cost),
        lg(dev.lds_access_iops),
        lg(dev.max_wg as f64),
        lg(dev.max_threads_per_cu as f64),
        lg(dev.cpu_vector_width as f64),
    ]
}

fn full_features(cfg_feats: &[f64], dev: &DeviceSpec, grid: (usize, usize)) -> Vec<f64> {
    let mut f = cfg_feats.to_vec();
    f.extend(device_features(dev));
    f.push((grid.0 as f64).max(1.0).log2());
    f.push((grid.1 as f64).max(1.0).log2());
    f
}

/// A per-kernel performance model over the knowledge base's records.
pub struct PerfModel {
    pub kernel: String,
    mlp: Mlp,
    /// Config-feature dimensionality the model was trained with.
    cfg_dim: usize,
    /// Usable records the model was trained on.
    pub samples: usize,
    /// Mean-squared error on the training set, log10-seconds units.
    pub train_mse: f64,
}

impl PerfModel {
    /// Train on the kernel's records (winners and history alike). `None`
    /// when there are too few usable records or the feature layouts
    /// disagree (e.g. records imported without features).
    pub fn train(kernel: &str, records: &[&TuneRecord]) -> Option<PerfModel> {
        let mut xs: Vec<Vec<f64>> = Vec::new();
        let mut ys: Vec<f64> = Vec::new();
        let mut cfg_dim = None;
        for r in records {
            if r.features.is_empty() || !r.seconds.is_finite() || r.seconds <= 0.0 {
                continue;
            }
            match cfg_dim {
                None => cfg_dim = Some(r.features.len()),
                Some(d) if d != r.features.len() => continue,
                _ => {}
            }
            let Some(dev) = crate::devices::by_name(r.device) else { continue };
            xs.push(full_features(&r.features, dev, r.grid));
            ys.push(r.seconds.log10());
        }
        let cfg_dim = cfg_dim?;
        if xs.len() < MIN_TRAIN_RECORDS {
            return None;
        }
        let mut mlp = Mlp::new(xs[0].len(), &HIDDEN, SEED);
        mlp.fit(&xs, &ys, EPOCHS, SEED ^ 0x77);
        let train_mse = mlp.mse(&xs, &ys);
        Some(PerfModel {
            kernel: kernel.to_string(),
            mlp,
            cfg_dim,
            samples: xs.len(),
            train_mse,
        })
    }

    /// Predicted log10-time of one configuration on `dev` at `grid`.
    pub fn predict(&self, fm: &FeatureMap, cfg: &TuningConfig, dev: &DeviceSpec, grid: (usize, usize)) -> f64 {
        self.mlp.predict(&full_features(&fm.features(cfg), dev, grid))
    }

    /// The `k` best-predicted configurations of `space` for a cold
    /// (device, grid), fastest-predicted first. Empty when the kernel's
    /// feature layout doesn't match the model (defensive — should only
    /// happen across incompatible code versions).
    pub fn rank(
        &self,
        space: &TuningSpace,
        fm: &FeatureMap,
        dev: &DeviceSpec,
        grid: (usize, usize),
        k: usize,
    ) -> Vec<TuningConfig> {
        if fm.dim() != self.cfg_dim || space.is_empty() || k == 0 {
            return Vec::new();
        }
        let mut scored: Vec<(usize, f64)> = space
            .configs
            .iter()
            .enumerate()
            .map(|(i, cfg)| (i, self.predict(fm, cfg, dev, grid)))
            .collect();
        scored.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal));
        scored
            .into_iter()
            .take(k)
            .map(|(i, _)| space.configs[i].clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::KernelInfo;
    use crate::bench_defs::SEPCONV_ROW;
    use crate::devices::{predict, KernelModel, INTEL_I7, K40};
    use crate::imagecl::frontend;
    use crate::tunedb::store::device_fingerprint;

    fn training_records(dev: &'static DeviceSpec, n: usize) -> (KernelInfo, FeatureMap, TuningSpace, Vec<TuneRecord>) {
        let info = KernelInfo::analyze(frontend(SEPCONV_ROW).unwrap());
        let fm = FeatureMap::new(&info);
        let full = TuningSpace::enumerate(&info, dev);
        let step = (full.len() / 160).max(1);
        let configs: Vec<TuningConfig> =
            full.configs.into_iter().step_by(step).collect();
        let space = TuningSpace { configs };
        let recs: Vec<TuneRecord> = space
            .configs
            .iter()
            .map(|cfg| {
                let km = KernelModel::build(&info, cfg);
                TuneRecord {
                    kernel: "sepconv_row".to_string(),
                    device: dev.name,
                    dev_fp: device_fingerprint(dev),
                    grid: (n, n),
                    seconds: predict(dev, &km, n, n).seconds,
                    best: false,
                    wall: false,
                    config: cfg.clone(),
                    features: fm.features(cfg),
                }
            })
            .filter(|r| r.seconds.is_finite())
            .collect();
        (info, fm, space, recs)
    }

    #[test]
    fn too_few_records_is_none() {
        let (_, _, _, recs) = training_records(&K40, 256);
        let few: Vec<&TuneRecord> = recs.iter().take(MIN_TRAIN_RECORDS - 1).collect();
        assert!(PerfModel::train("sepconv_row", &few).is_none());
    }

    #[test]
    fn records_without_features_unusable() {
        let (_, _, _, recs) = training_records(&K40, 256);
        let stripped: Vec<TuneRecord> = recs
            .iter()
            .map(|r| TuneRecord { features: Vec::new(), ..r.clone() })
            .collect();
        let refs: Vec<&TuneRecord> = stripped.iter().collect();
        assert!(PerfModel::train("sepconv_row", &refs).is_none());
    }

    #[test]
    fn ranked_candidates_beat_the_space_median() {
        // Train on the K40's own measurements and check the model ranks
        // genuinely fast configs first on the same device: the best
        // *measured* config among the model's top picks must beat the
        // space's median config comfortably.
        let (info, fm, space, recs) = training_records(&K40, 512);
        let refs: Vec<&TuneRecord> = recs.iter().collect();
        let model = PerfModel::train("sepconv_row", &refs).expect("trainable");
        assert_eq!(model.samples, refs.len());
        let top = model.rank(&space, &fm, &K40, (512, 512), 12);
        assert_eq!(top.len(), 12);
        let eval = |cfg: &TuningConfig| {
            let km = KernelModel::build(&info, cfg);
            predict(&K40, &km, 512, 512).seconds
        };
        let best_of_top =
            top.iter().map(|c| eval(c)).fold(f64::INFINITY, f64::min);
        let mut all: Vec<f64> =
            recs.iter().map(|r| r.seconds).collect();
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = all[all.len() / 2];
        assert!(
            best_of_top < median,
            "model's best pick {best_of_top} not better than median {median}"
        );
    }

    #[test]
    fn rank_rejects_mismatched_layout() {
        let (_, _, space, recs) = training_records(&K40, 256);
        let refs: Vec<&TuneRecord> = recs.iter().collect();
        let model = PerfModel::train("sepconv_row", &refs).unwrap();
        // A feature map with a different dimensionality must yield no
        // candidates rather than garbage.
        let bogus = FeatureMap { arrays: Vec::new(), loops: Vec::new() };
        assert!(model.rank(&space, &bogus, &INTEL_I7, (256, 256), 8).is_empty());
    }

    #[test]
    fn device_features_distinguish_cpu_and_gpu() {
        assert_ne!(device_features(&K40), device_features(&INTEL_I7));
        assert_eq!(device_features(&K40).len(), device_features(&INTEL_I7).len());
    }
}
