//! Kernel features consumed by the device performance model: per-buffer
//! traffic characteristics and per-pixel instruction counts, derived from
//! the static analyses plus a concrete tuning configuration.

use std::collections::BTreeMap;

use crate::analysis::KernelInfo;
use crate::imagecl::{BoundaryCond, GridSpec, Type};
use crate::transform::{effective_config, MemSpace, TuningConfig};

/// Traffic-relevant facts about one buffer under a config.
#[derive(Debug, Clone, PartialEq)]
pub struct BufferUse {
    pub name: String,
    pub elem_bytes: f64,
    /// Reads per logical pixel (from static cost analysis).
    pub reads_per_pixel: f64,
    pub writes_per_pixel: f64,
    pub space: MemSpace,
    pub is_image: bool,
    /// Boundary handling applies (image with non-point read stencil, or a
    /// read stencil we could not prove point-only).
    pub boundary_checked: bool,
    pub boundary: BoundaryCond,
    /// Local staging only: staged tile elements / group pixels (≥ 1; the
    /// halo overhead of paper Figure 5).
    pub halo_ratio: f64,
    /// Local staging only: staged tile dims in elements (w, h); (0, 0)
    /// otherwise. Used for DRAM transaction-granularity modelling.
    pub tile: (usize, usize),
}

/// Everything the performance model needs about (kernel, config).
#[derive(Debug, Clone)]
pub struct KernelModel {
    pub name: String,
    pub cfg: TuningConfig,
    pub buffers: Vec<BufferUse>,
    /// Float ops per logical pixel (divisions and transcendentals
    /// pre-weighted by their throughput cost; excl. addressing).
    pub flops_per_pixel: f64,
    /// Integer/control ops per logical pixel (excl. addressing).
    pub iops_per_pixel: f64,
    /// Loop-control ops per pixel removed by the configured unrolling.
    pub unroll_savings: f64,
    /// True if some unrolled loop was innermost (ILP bonus).
    pub unrolled_inner: bool,
    /// Per-image-read boundary ops are added by the model itself.
    pub grid_is_image: bool,
}

/// Ops charged per loop iteration for control (cmp + inc + branch).
const LOOP_CONTROL_OPS: f64 = 3.0;

impl KernelModel {
    /// Build the model inputs for a kernel under a tuning configuration.
    pub fn build(info: &KernelInfo, config: &TuningConfig) -> KernelModel {
        let cfg = effective_config(info, config);
        let kernel = &info.prog.kernel;
        let tile = cfg.group_tile();

        let mut buffers = Vec::new();
        for p in &kernel.params {
            let (elem, is_image) = match &p.ty {
                Type::Image { elem, .. } => (*elem, true),
                Type::Array { elem } => (*elem, false),
                Type::Scalar(_) => continue,
            };
            let reads = info.cost.reads.get(&p.name).copied().unwrap_or(0.0);
            let writes = info.cost.writes.get(&p.name).copied().unwrap_or(0.0);
            let space = cfg.space_of(&p.name);
            let stencil = info.read_stencil(&p.name);
            let point_only = stencil
                .map(|s| s.extent_x() == 0 && s.extent_y() == 0)
                .unwrap_or(false);
            // Exact own-pixel reads of the grid image skip boundary code
            // (mirrors transform::lower::is_exact_grid_point).
            let grid_img = matches!(&info.prog.grid, GridSpec::FromImage(g) if *g == p.name);
            let boundary_checked = is_image && reads > 0.0 && !(point_only && grid_img);
            let (halo_ratio, tile_dims) = match (space, stencil) {
                (MemSpace::Local, Some(s)) => {
                    let tw = tile[0] + s.extent_x() as usize;
                    let th = tile[1] + s.extent_y() as usize;
                    (
                        (tw * th) as f64 / (tile[0] * tile[1]) as f64,
                        (tw, th),
                    )
                }
                _ => (1.0, (0, 0)),
            };
            buffers.push(BufferUse {
                name: p.name.clone(),
                elem_bytes: elem.size_bytes() as f64,
                reads_per_pixel: reads,
                writes_per_pixel: writes,
                space,
                is_image,
                boundary_checked,
                boundary: info
                    .prog
                    .boundary
                    .get(&p.name)
                    .copied()
                    .unwrap_or_default(),
                halo_ratio,
                tile: tile_dims,
            });
        }

        // Loop-control savings from unrolling: each fully unrolled loop
        // eliminates its control ops (multiplicity = product of its own and
        // ancestor trip counts, reconstructed from pre-order + depth).
        let mut unroll_savings = 0.0;
        let mut unrolled_inner = false;
        let mut stack: Vec<(usize, f64)> = Vec::new(); // (depth, mult)
        let max_depth = info.loops.iter().map(|l| l.depth).max().unwrap_or(0);
        for l in &info.loops {
            while stack.last().map(|(d, _)| *d >= l.depth) == Some(true) {
                stack.pop();
            }
            let parent_mult = stack.last().map(|(_, m)| *m).unwrap_or(1.0);
            let trips = l.trips.unwrap_or(8) as f64;
            let mult = parent_mult * trips;
            stack.push((l.depth, mult));
            let factor = cfg.unroll_factor(l.id);
            if factor != 1 && l.trips.is_some() {
                let eliminated = if factor == 0 {
                    1.0
                } else {
                    1.0 - 1.0 / factor as f64
                };
                unroll_savings += LOOP_CONTROL_OPS * mult * eliminated;
                if l.depth == max_depth {
                    unrolled_inner = true;
                }
            }
        }

        KernelModel {
            name: kernel.name.clone(),
            cfg,
            buffers,
            flops_per_pixel: info.cost.flops
                + 8.0 * info.cost.fdivs
                + 16.0 * info.cost.transcendentals,
            iops_per_pixel: info.cost.iops,
            unroll_savings,
            unrolled_inner,
            grid_is_image: matches!(info.prog.grid, GridSpec::FromImage(_)),
        }
    }

    /// Local memory bytes per work-group under this config.
    pub fn local_bytes_per_group(&self) -> f64 {
        let tile = self.cfg.group_tile();
        self.buffers
            .iter()
            .filter(|b| b.space == MemSpace::Local)
            .map(|b| {
                // halo_ratio encodes (tile+halo)/tile.
                b.halo_ratio * tile[0] as f64 * tile[1] as f64 * b.elem_bytes
            })
            .sum()
    }

    /// Any boundary-checked read with the given condition?
    pub fn has_boundary(&self, clamped: bool) -> bool {
        self.buffers.iter().any(|b| {
            b.boundary_checked
                && b.reads_per_pixel > 0.0
                && matches!(b.boundary, BoundaryCond::Clamped) == clamped
        })
    }

    /// Summed per-pixel read traffic keyed by memory space (bytes before
    /// device-dependent cache modelling).
    pub fn reads_by_space(&self) -> BTreeMap<MemSpace, f64> {
        let mut m = BTreeMap::new();
        for b in &self.buffers {
            if b.reads_per_pixel > 0.0 {
                *m.entry(b.space).or_insert(0.0) += b.reads_per_pixel * b.elem_bytes;
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::KernelInfo;
    use crate::bench_defs::{CONV2D, HARRIS, SEPCONV_ROW};
    use crate::imagecl::frontend;

    fn model(src: &str, cfg: &TuningConfig) -> KernelModel {
        KernelModel::build(&KernelInfo::analyze(frontend(src).unwrap()), cfg)
    }

    #[test]
    fn sepconv_row_features() {
        let m = model(SEPCONV_ROW, &TuningConfig::default());
        let inb = m.buffers.iter().find(|b| b.name == "in").unwrap();
        assert_eq!(inb.reads_per_pixel, 5.0);
        assert!(inb.boundary_checked);
        assert_eq!(inb.space, MemSpace::Global);
        let outb = m.buffers.iter().find(|b| b.name == "out").unwrap();
        assert_eq!(outb.writes_per_pixel, 1.0);
        assert!(!outb.boundary_checked); // exact grid-point write
        let fb = m.buffers.iter().find(|b| b.name == "f").unwrap();
        assert_eq!(fb.reads_per_pixel, 5.0);
    }

    #[test]
    fn halo_ratio_grows_with_stencil() {
        let mut cfg = TuningConfig { wg: [16, 16], ..Default::default() };
        cfg.local_mem.insert("in".into(), true);
        let m = model(CONV2D, &cfg);
        let inb = m.buffers.iter().find(|b| b.name == "in").unwrap();
        // 16x16 tile with 5x5 stencil → 20x20/256.
        assert!((inb.halo_ratio - (20.0 * 20.0) / 256.0).abs() < 1e-12);
        assert!(m.local_bytes_per_group() > 0.0);
    }

    #[test]
    fn unroll_savings_scales_with_mult() {
        let mut cfg = TuningConfig::default();
        cfg.unroll.insert(2, 0); // inner 5-trip loop of conv2d, mult 25
        let inner_only = model(CONV2D, &cfg).unroll_savings;
        cfg.unroll.insert(1, 0);
        let both = model(CONV2D, &cfg).unroll_savings;
        assert_eq!(inner_only, 3.0 * 25.0);
        assert_eq!(both, 3.0 * 25.0 + 3.0 * 5.0);
        assert!(model(CONV2D, &cfg).unrolled_inner);
    }

    #[test]
    fn boundary_kinds_detected() {
        let m = model(CONV2D, &TuningConfig::default());
        assert!(m.has_boundary(true)); // clamped
        assert!(!m.has_boundary(false));
        let m = model(SEPCONV_ROW, &TuningConfig::default());
        assert!(m.has_boundary(false)); // constant
    }

    #[test]
    fn harris_two_staged_inputs() {
        let mut cfg = TuningConfig::default();
        cfg.local_mem.insert("dx".into(), true);
        cfg.local_mem.insert("dy".into(), true);
        let m = model(HARRIS, &cfg);
        let staged: Vec<_> =
            m.buffers.iter().filter(|b| b.space == MemSpace::Local).collect();
        assert_eq!(staged.len(), 2);
        // Two f32 tiles of (16+1)x(16+1).
        assert!((m.local_bytes_per_group() - 2.0 * 17.0 * 17.0 * 4.0).abs() < 1e-9);
    }
}
