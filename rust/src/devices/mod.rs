//! Heterogeneous device simulation (DESIGN.md §2).
//!
//! The paper's testbed (AMD 7970, GTX 960, K40, Intel i7-4771) is
//! replaced by an analytical performance model per device. The auto-tuner
//! "times" candidate implementations against these models for the GPU
//! devices; the CPU path additionally has a real-execution route through
//! the XLA runtime ([`crate::runtime`]).

pub mod kmodel;
pub mod model;
pub mod spec;

pub use kmodel::{BufferUse, KernelModel};
pub use model::{predict, Prediction};
pub use spec::{by_name, DeviceKind, DeviceSpec, ALL_DEVICES, AMD_7970, GTX_960, INTEL_I7, K40};
