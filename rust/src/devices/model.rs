//! The analytical device performance model.
//!
//! Predicts the execution time of one kernel launch from the
//! [`KernelModel`] features and a [`DeviceSpec`]. It implements exactly
//! the mechanisms the paper's §2/§5.2/§7 reason about:
//!
//! * **coalescing** — blocked coarsening strides consecutive lanes apart,
//!   wasting transaction bytes (GPU); interleaving restores stride-1
//!   (paper Figure 4);
//! * **caches** — redundant stencil re-reads are served by the global or
//!   texture cache with device-dependent efficiency (Kepler's global path
//!   is poor → image memory wins on the K40, paper §7);
//! * **local memory** — DRAM traffic drops to the halo'd tile, at the
//!   price of staging instructions and barriers (paper Figure 5);
//! * **constant memory** — broadcast-cached filter taps, near-free;
//! * **occupancy** — resident threads per CU limited by work-group size
//!   and local-memory usage; too little parallelism stalls latency hiding;
//! * **CPU execution** — implicit vectorization when lanes are
//!   contiguous, per-work-group scheduling overhead (drives the huge
//!   pixels-per-thread values of the paper's Table 2 CPU column), and the
//!   clamped-boundary vectorization penalty the paper measures as ~2×
//!   (§7).
//!
//! Absolute times are *synthetic-testbed* estimates (DESIGN.md §2); the
//! reproduction targets the paper's qualitative shape, which the tests in
//! this module pin down.

use crate::transform::MemSpace;

use super::kmodel::KernelModel;
use super::spec::{DeviceKind, DeviceSpec};

/// Predicted execution time, with its breakdown.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Prediction {
    pub seconds: f64,
    pub compute_s: f64,
    pub memory_s: f64,
    pub overhead_s: f64,
    /// Occupancy [0,1] (GPU) or core utilization (CPU).
    pub occupancy: f64,
}

impl Prediction {
    pub const INVALID: Prediction = Prediction {
        seconds: f64::INFINITY,
        compute_s: f64::INFINITY,
        memory_s: f64::INFINITY,
        overhead_s: 0.0,
        occupancy: 0.0,
    };

    pub fn is_valid(&self) -> bool {
        self.seconds.is_finite()
    }
}

/// Ops charged per buffer access for address arithmetic.
const ADDR_OPS: f64 = 2.0;
/// Extra ops for a boundary-checked access.
const CLAMP_OPS: f64 = 4.0; // two min/max pairs
const CONSTBC_OPS: f64 = 3.0; // range compare chain
// (per-device LDS access cost lives in DeviceSpec::lds_access_iops)
/// Ops per staged element during cooperative load (load+store+addr).
const STAGE_OPS: f64 = 4.0;
/// Ops per texture access (sampler issue).
const TEX_OPS: f64 = 2.0;
/// Ops per constant-memory access (broadcast hit).
const CONST_OPS: f64 = 0.5;
/// Barrier cost, cycles per work-group-thread.
const BARRIER_CYCLES: f64 = 2.0;
/// CPU per-work-item scheduling overhead (seconds, scalar path).
const CPU_ITEM_OVERHEAD_S: f64 = 12e-9;
/// Register-pressure knee: pixels per thread beyond which spills start.
const COARSEN_SPILL_KNEE: f64 = 32.0;

/// Predict the execution time of `km` on `dev` for a `gw`×`gh` grid.
pub fn predict(dev: &DeviceSpec, km: &KernelModel, gw: usize, gh: usize) -> Prediction {
    let cfg = &km.cfg;
    let [cx, cy] = [cfg.coarsen[0] as f64, cfg.coarsen[1] as f64];
    let wg_threads = cfg.wg_threads() as f64;
    let npix = (gw * gh) as f64;

    // -- validity -------------------------------------------------------
    if cfg.wg_threads() > dev.max_wg {
        return Prediction::INVALID;
    }
    let lmem_group = km.local_bytes_per_group();
    if lmem_group > dev.local_mem_per_cu as f64 {
        return Prediction::INVALID;
    }
    // Constant-memory limit is enforced by space enumeration (eligibility
    // uses the device's 64 KiB); re-check defensively.
    // (All paper devices share the 64 KiB limit — see DeviceSpec.)

    // -- thread geometry ---------------------------------------------------
    let rt_x = (gw as f64 / cx).ceil();
    let rt_y = (gh as f64 / cy).ceil();
    let total_threads = (rt_x / cfg.wg[0] as f64).ceil()
        * cfg.wg[0] as f64
        * (rt_y / cfg.wg[1] as f64).ceil()
        * cfg.wg[1] as f64;
    let n_groups = total_threads / wg_threads;

    let is_cpu = dev.kind == DeviceKind::Cpu;

    // -- occupancy (GPU) / utilization (CPU) -----------------------------
    let occupancy;
    if is_cpu {
        occupancy = (n_groups / dev.compute_units as f64).min(1.0);
    } else {
        let groups_by_lmem = if lmem_group > 0.0 {
            (dev.local_mem_per_cu as f64 / lmem_group).floor().max(1.0)
        } else {
            f64::INFINITY
        };
        let groups_by_threads =
            (dev.max_threads_per_cu as f64 / wg_threads).floor().max(1.0);
        let resident = groups_by_lmem
            .min(groups_by_threads)
            .min(16.0)
            * wg_threads;
        let resident = resident.min(dev.max_threads_per_cu as f64);
        let available = total_threads / dev.compute_units as f64;
        let active = resident.min(available);
        occupancy = (active / dev.latency_hiding_threads as f64).min(1.0);
    }

    // -- CPU vectorization ------------------------------------------------
    // Lane-contiguity: interleaved mapping or unit-stride lanes (cx == 1)
    // vectorize across work-items; blocked with a long-enough inner
    // coarsening run vectorizes that loop instead.
    let mut vector_eff = 1.0;
    if is_cpu {
        let lanes_contig = cfg.interleaved || cx == 1.0;
        let inner_run = !cfg.interleaved && cx >= 4.0;
        vector_eff = if lanes_contig || inner_run { 0.85 } else { 1.2 / dev.cpu_vector_width as f64 };
        // Clamped boundary code inserts per-lane min/max address clamps the
        // vectorizer cannot hoist (paper §7: ~2× on the CPU conv2d).
        if km.has_boundary(true) {
            vector_eff *= 0.5;
        }
    }

    // -- per-pixel instruction count -------------------------------------
    // Integer/control ops issue alongside float math on GPUs (separate
    // scalar/int pipes); weight them below peak-FLOP cost.
    let iop_weight = if is_cpu { 0.9 } else { 0.25 };
    let flops = km.flops_per_pixel;
    let mut iops = (km.iops_per_pixel - km.unroll_savings).max(0.0);
    // Coarsening loop control + idx/idy recomputation.
    iops += 6.0 / (cx * cy).max(1.0) + 3.0;
    let mut stage_bytes_per_pixel = 0.0;
    for b in &km.buffers {
        let accesses = b.reads_per_pixel + b.writes_per_pixel;
        if accesses == 0.0 {
            continue;
        }
        match b.space {
            MemSpace::Global => {
                iops += accesses * ADDR_OPS;
                if b.boundary_checked {
                    let bc = if matches!(b.boundary, crate::imagecl::BoundaryCond::Clamped) {
                        CLAMP_OPS
                    } else {
                        CONSTBC_OPS
                    };
                    iops += b.reads_per_pixel * bc;
                }
            }
            MemSpace::Image => {
                iops += accesses * TEX_OPS * dev.tex_access_cost;
                // Hardware samplers clamp to edge for free
                // (CLK_ADDRESS_CLAMP_TO_EDGE) — a key texture-path
                // advantage; a constant boundary still needs the guard.
                if b.boundary_checked
                    && !matches!(b.boundary, crate::imagecl::BoundaryCond::Clamped)
                {
                    iops += b.reads_per_pixel * CONSTBC_OPS;
                }
            }
            MemSpace::Constant => {
                iops += accesses * CONST_OPS;
            }
            MemSpace::Local => {
                // Compute-phase LDS reads + staging work.
                iops += b.reads_per_pixel * (dev.lds_access_iops + 1.0);
                iops += b.halo_ratio * STAGE_OPS;
                // Staging does its own boundary handling once per element.
                iops += b.halo_ratio * CLAMP_OPS;
                stage_bytes_per_pixel += b.halo_ratio * b.elem_bytes;
            }
        }
    }
    // Barrier cost (local staging implies one barrier per group).
    if lmem_group > 0.0 {
        iops += BARRIER_CYCLES; // amortized per thread ≈ per pixel / (cx·cy)
    }
    let ops = flops + iop_weight * iops;

    // -- memory traffic per pixel -----------------------------------------
    // Blocked coarsening strides consecutive lanes `cx` elements apart.
    let mut bytes = 0.0;
    for b in &km.buffers {
        let line_elems = (dev.cacheline as f64 / b.elem_bytes).max(1.0);
        let lane_stride = if cfg.interleaved { 1.0 } else { cx };
        let waste = |cache_eff: f64| {
            if is_cpu {
                1.0 // prefetchers serve both mappings on the CPU
            } else {
                1.0 + (lane_stride.min(line_elems) - 1.0) * (1.0 - cache_eff)
            }
        };
        // Global interleaving spreads a thread's successive accesses
        // `gdim` apart, hurting 2-D cache locality of stencil re-reads.
        let interleave_locality =
            if cfg.interleaved && !cfg.any_local_mem() && !is_cpu { 0.9 } else { 1.0 };
        match b.space {
            MemSpace::Global => {
                let eff = dev.global_cache_eff * interleave_locality;
                let r = b.reads_per_pixel;
                if r > 0.0 {
                    bytes += b.elem_bytes * (1.0 + (r - 1.0) * (1.0 - eff)) * waste(eff);
                }
                bytes += b.writes_per_pixel * b.elem_bytes * waste(1.0);
            }
            MemSpace::Image => {
                let eff = dev.tex_cache_eff;
                let r = b.reads_per_pixel;
                if r > 0.0 {
                    // 2-D texture cache: no coalescing waste.
                    bytes += b.elem_bytes * (1.0 + (r - 1.0) * (1.0 - eff));
                }
                bytes += b.writes_per_pixel * b.elem_bytes;
            }
            MemSpace::Constant => { /* broadcast-cached: negligible DRAM */ }
            MemSpace::Local => {
                // Cold tile bytes, at DRAM transaction granularity: each
                // staged tile row fetches whole cachelines, so narrow
                // tiles waste bandwidth (a real Kepler/GCN effect that
                // makes texture preferable for small tiles).
                let (tw, th) = b.tile;
                if tw > 0 {
                    let row_bytes = tw as f64 * b.elem_bytes;
                    let lines = (row_bytes / dev.cacheline as f64).ceil();
                    let group_pixels =
                        (cfg.group_tile()[0] * cfg.group_tile()[1]) as f64;
                    let granular =
                        th as f64 * lines * dev.cacheline as f64 / group_pixels;
                    bytes += granular.max(b.halo_ratio * b.elem_bytes);
                } else {
                    bytes += stage_bytes_per_pixel.min(b.halo_ratio * b.elem_bytes);
                }
            }
        }
    }

    // -- throughputs --------------------------------------------------------
    let (compute_s, memory_s, overhead_s);
    if is_cpu {
        let peak = dev.peak_gflops() * 1e9;
        let eff_flops = peak * vector_eff * occupancy.max(1.0 / dev.compute_units as f64);
        compute_s = ops * npix / eff_flops;
        memory_s = bytes * npix / (dev.mem_bw_gbs * 1e9);
        let item_oh = total_threads * CPU_ITEM_OVERHEAD_S
            / dev.compute_units as f64
            / if vector_eff > 0.5 { dev.cpu_vector_width as f64 } else { 1.0 };
        let group_oh = n_groups * dev.group_overhead_s / dev.compute_units as f64;
        overhead_s = dev.launch_overhead_s + item_oh + group_oh;
    } else {
        // SIMD granularity waste: partial wavefronts burn lanes.
        let simd = dev.simd_width as f64;
        let simd_eff = wg_threads / ((wg_threads / simd).ceil() * simd);
        // Register pressure: very fat threads spill.
        let ppx = (cx * cy).max(1.0);
        let spill = if ppx > COARSEN_SPILL_KNEE {
            (COARSEN_SPILL_KNEE / ppx).powf(0.3)
        } else {
            1.0
        };
        let lat_eff = 0.35 + 0.65 * occupancy;
        let eff_flops = dev.peak_gflops() * 1e9 * simd_eff * lat_eff * spill;
        compute_s = ops * npix / eff_flops;
        let bw_eff = 0.45 + 0.55 * occupancy;
        memory_s = bytes * npix / (dev.mem_bw_gbs * 1e9 * bw_eff);
        overhead_s = dev.launch_overhead_s;
    }

    // Compute and memory overlap; the longer one dominates, plus a small
    // serial fraction of the shorter (no perfect overlap in practice).
    let seconds =
        compute_s.max(memory_s) + 0.15 * compute_s.min(memory_s) + overhead_s;
    Prediction { seconds, compute_s, memory_s, overhead_s, occupancy }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::KernelInfo;
    use crate::bench_defs::{CONV2D, HARRIS, SEPCONV_ROW, SOBEL};
    use crate::devices::spec::*;
    use crate::imagecl::frontend;
    use crate::transform::TuningConfig;

    fn pred(dev: &DeviceSpec, src: &str, cfg: &str, gw: usize, gh: usize) -> Prediction {
        let info = KernelInfo::analyze(frontend(src).unwrap());
        let cfg = TuningConfig::parse(cfg).unwrap();
        let km = KernelModel::build(&info, &cfg);
        predict(dev, &km, gw, gh)
    }

    const N: usize = 4096;

    #[test]
    fn local_memory_wins_on_7970_not_on_960() {
        // Paper Table 2: 7970 row kernel uses local memory; GTX 960 does
        // not (Maxwell's cache already captures the reuse).
        let base = "wg=16x16 px=1x1 map=blocked cmem=f";
        let lmem = "wg=16x16 px=1x1 map=blocked cmem=f lmem=in";
        let amd_base = pred(&AMD_7970, SEPCONV_ROW, base, N, N);
        let amd_lmem = pred(&AMD_7970, SEPCONV_ROW, lmem, N, N);
        assert!(
            amd_lmem.seconds < amd_base.seconds,
            "7970: local {} !< global {}",
            amd_lmem.seconds,
            amd_base.seconds
        );
        let nv_base = pred(&GTX_960, SEPCONV_ROW, base, N, N);
        let nv_lmem = pred(&GTX_960, SEPCONV_ROW, lmem, N, N);
        assert!(
            nv_lmem.seconds > nv_base.seconds,
            "960: local {} !> global {}",
            nv_lmem.seconds,
            nv_base.seconds
        );
    }

    #[test]
    fn image_memory_wins_on_k40() {
        // Paper §7: "the good performance compared to Halide on the K40 is
        // caused in part by ImageCL using image memory".
        let base = "wg=16x16 px=1x1 map=blocked cmem=f";
        let img = "wg=16x16 px=1x1 map=blocked cmem=f img=in";
        let k40_base = pred(&K40, CONV2D, base, 8192, 8192);
        let k40_img = pred(&K40, CONV2D, img, 8192, 8192);
        assert!(
            k40_img.seconds < 0.8 * k40_base.seconds,
            "K40: image {} not clearly better than global {}",
            k40_img.seconds,
            k40_base.seconds
        );
        // On the CPU, image memory must lose (software samplers).
        let cpu_base = pred(&INTEL_I7, CONV2D, base, 1024, 1024);
        let cpu_img = pred(&INTEL_I7, CONV2D, img, 1024, 1024);
        assert!(cpu_img.seconds > cpu_base.seconds);
    }

    #[test]
    fn constant_memory_always_helps() {
        // Paper Tables 2-3: constant memory chosen on every device.
        for dev in ALL_DEVICES {
            let no = pred(dev, SEPCONV_ROW, "wg=16x16 px=1x1 map=blocked", N, N);
            let yes = pred(dev, SEPCONV_ROW, "wg=16x16 px=1x1 map=blocked cmem=f", N, N);
            assert!(
                yes.seconds <= no.seconds,
                "{}: constant mem hurt ({} vs {})",
                dev.name,
                yes.seconds,
                no.seconds
            );
        }
    }

    #[test]
    fn cpu_wants_heavy_coarsening_gpu_does_not() {
        // Paper Table 2 CPU column: 128 px/thread; GPUs: 1-4.
        let fine = "wg=16x2 px=1x1 map=interleaved cmem=f";
        let fat = "wg=16x2 px=64x1 map=interleaved cmem=f";
        let cpu_fine = pred(&INTEL_I7, SEPCONV_ROW, fine, N, N);
        let cpu_fat = pred(&INTEL_I7, SEPCONV_ROW, fat, N, N);
        assert!(
            cpu_fat.seconds < cpu_fine.seconds,
            "i7: fat {} !< fine {}",
            cpu_fat.seconds,
            cpu_fine.seconds
        );
        // On a GPU the same jump to 64 px/thread must not help.
        let blocked_fine = "wg=16x16 px=1x1 map=blocked cmem=f";
        let blocked_fat = "wg=16x16 px=64x1 map=blocked cmem=f";
        let gpu_fine = pred(&K40, SEPCONV_ROW, blocked_fine, N, N);
        let gpu_fat = pred(&K40, SEPCONV_ROW, blocked_fat, N, N);
        assert!(gpu_fat.seconds > gpu_fine.seconds);
    }

    #[test]
    fn interleaving_fixes_blocked_coarsening_on_gpu() {
        // Paper §5.2.3: blocked coarsening breaks coalescing; interleaved
        // restores it.
        // Clear-cut on the cache-poor GPUs (7970, K40); on Maxwell the
        // cache absorbs the blocked stride, matching the paper's Table 2
        // where the GTX 960 tuned configs are blocked.
        let blocked = "wg=16x16 px=4x1 map=blocked cmem=f";
        let inter = "wg=16x16 px=4x1 map=interleaved cmem=f";
        for dev in [&AMD_7970, &K40] {
            let b = pred(dev, SEPCONV_ROW, blocked, N, N);
            let i = pred(dev, SEPCONV_ROW, inter, N, N);
            assert!(
                i.seconds < b.seconds,
                "{}: interleaved {} !< blocked {}",
                dev.name,
                i.seconds,
                b.seconds
            );
        }
    }

    #[test]
    fn clamped_boundary_about_2x_on_cpu_conv2d() {
        // Paper §7: constant instead of clamped halves CPU conv2d time.
        let info = KernelInfo::analyze(frontend(CONV2D).unwrap());
        let cfg = TuningConfig::parse("wg=2x8 px=64x2 map=interleaved cmem=f").unwrap();
        let km = KernelModel::build(&info, &cfg);
        let clamped = predict(&INTEL_I7, &km, 2048, 2048);
        // Same kernel with constant boundary.
        let const_src = CONV2D.replace("boundary(in, clamped)", "boundary(in, constant, 0.0)");
        let info2 = KernelInfo::analyze(frontend(&const_src).unwrap());
        let km2 = KernelModel::build(&info2, &cfg);
        let constant = predict(&INTEL_I7, &km2, 2048, 2048);
        let ratio = clamped.seconds / constant.seconds;
        assert!(
            (1.4..3.0).contains(&ratio),
            "clamped/constant CPU ratio {ratio} out of the paper's ~2x band"
        );
    }

    #[test]
    fn oversized_workgroup_invalid() {
        let p = pred(&AMD_7970, SOBEL, "wg=32x32 px=1x1 map=blocked", N, N);
        assert!(!p.is_valid()); // 1024 > AMD max_wg 256
        let p = pred(&K40, SOBEL, "wg=32x32 px=1x1 map=blocked", N, N);
        assert!(p.is_valid());
    }

    #[test]
    fn local_mem_overflow_invalid() {
        // Giant group tile: staged 5x5-halo tile exceeds 48KB on K40.
        let p = pred(&K40, CONV2D, "wg=32x32 px=8x8 map=blocked lmem=in cmem=f", N, N);
        // (256+4)*(256+4) bytes = 67kB > 48kB
        assert!(!p.is_valid());
    }

    #[test]
    fn gpu_faster_than_cpu_on_big_stencils() {
        // Sanity: any reasonable GPU config beats the best CPU config on
        // the paper's workloads (Figure 6 shows GPU times ≪ CPU times).
        let g = pred(&K40, CONV2D, "wg=16x16 px=1x1 map=blocked img=in cmem=f", 8192, 8192);
        let c = pred(&INTEL_I7, CONV2D, "wg=2x8 px=64x2 map=interleaved cmem=f", 8192, 8192);
        assert!(g.seconds * 3.0 < c.seconds);
    }

    #[test]
    fn times_are_physical() {
        // 4096² f32 sep-conv on a ~200 GB/s GPU: sub-10ms; on the CPU:
        // single-digit-to-tens of ms.
        let g = pred(&AMD_7970, SEPCONV_ROW, "wg=64x4 px=4x1 map=interleaved lmem=in cmem=f", N, N);
        assert!(g.seconds > 50e-6 && g.seconds < 10e-3, "{}", g.seconds);
        let c = pred(&INTEL_I7, SEPCONV_ROW, "wg=8x1 px=128x1 map=interleaved cmem=f", N, N);
        assert!(c.seconds > 1e-3 && c.seconds < 100e-3, "{}", c.seconds);
    }

    #[test]
    fn harris_and_sobel_predictable() {
        for dev in ALL_DEVICES {
            for src in [SOBEL, HARRIS] {
                let p = pred(dev, src, "wg=16x8 px=1x1 map=blocked", 5120, 5120);
                assert!(p.is_valid());
                assert!(p.seconds > 0.0 && p.seconds < 1.0, "{}: {}", dev.name, p.seconds);
            }
        }
    }
}
