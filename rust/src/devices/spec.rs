//! Device specifications for the paper's testbed (§6): three GPUs and one
//! CPU. Parameters come from vendor datasheets; the *behavioural*
//! coefficients (cache efficiencies, overheads) encode the
//! microarchitectural mechanisms the paper's discussion (§7) attributes
//! performance differences to, and are calibrated against the qualitative
//! invariants in `devices::model::tests` — not against the authors'
//! wall-clock numbers (DESIGN.md §2: simulator substitution).

/// Device class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceKind {
    Gpu,
    Cpu,
}

/// An OpenCL device model.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceSpec {
    pub name: &'static str,
    pub kind: DeviceKind,
    /// Compute units (CUs / SMs / cores).
    pub compute_units: usize,
    /// SIMD granularity (wavefront 64 / warp 32 / AVX2 f32 lanes 8).
    pub simd_width: usize,
    /// Core clock, GHz.
    pub clock_ghz: f64,
    /// Peak simple ops per cycle per compute unit (FMA counted as 2).
    pub flops_per_cycle_cu: f64,
    /// Peak DRAM bandwidth, GB/s.
    pub mem_bw_gbs: f64,
    /// Cache line / coalescing transaction size, bytes.
    pub cacheline: usize,
    /// Fraction of *redundant* global stencil re-reads served by the
    /// general-purpose cache hierarchy (0 = every re-read pays DRAM,
    /// 1 = only cold misses pay). Kepler's L1 does not cache global
    /// loads, which is why image memory wins on the K40 (paper §7).
    pub global_cache_eff: f64,
    /// Same, for the texture path (`image2d_t` reads).
    pub tex_cache_eff: f64,
    /// Cost multiplier of one texture access relative to a global load
    /// (CPUs emulate samplers in software — big penalty, paper Table 2:
    /// the tuner avoids image memory on the i7).
    pub tex_access_cost: f64,
    /// Issue cost (int-op units) of one local/LDS access. Kepler's LDS is
    /// slow (low throughput, byte-access bank conflicts) — a key reason
    /// the texture path wins on the K40 while local memory wins on GCN.
    pub lds_access_iops: f64,
    /// Local (scratchpad) memory per compute unit, bytes.
    pub local_mem_per_cu: usize,
    /// Max work-group size.
    pub max_wg: usize,
    /// Max resident threads per CU (occupancy ceiling).
    pub max_threads_per_cu: usize,
    /// Threads per CU needed to fully hide memory latency.
    pub latency_hiding_threads: usize,
    /// Fixed kernel-launch overhead, seconds.
    pub launch_overhead_s: f64,
    /// CPU only: scheduling overhead per work-group, seconds.
    pub group_overhead_s: f64,
    /// CPU only: implicit-vectorization width achieved by the OpenCL
    /// runtime when the work-item access pattern is lane-contiguous.
    pub cpu_vector_width: usize,
}

/// AMD Radeon HD 7970 (GCN, Tahiti): big scratchpad-oriented GPU with a
/// modest general cache — local memory pays off (paper Table 2).
pub const AMD_7970: DeviceSpec = DeviceSpec {
    name: "AMD 7970",
    kind: DeviceKind::Gpu,
    compute_units: 32,
    simd_width: 64,
    clock_ghz: 0.925,
    flops_per_cycle_cu: 128.0,
    mem_bw_gbs: 264.0,
    cacheline: 64,
    global_cache_eff: 0.40,
    tex_cache_eff: 0.80,
    tex_access_cost: 1.0,
    lds_access_iops: 1.0,
    local_mem_per_cu: 64 << 10,
    max_wg: 256,
    max_threads_per_cu: 2560,
    latency_hiding_threads: 512,
    launch_overhead_s: 8e-6,
    group_overhead_s: 0.0,
    cpu_vector_width: 1,
};

/// NVIDIA GeForce GTX 960 (Maxwell): unified L1/texture cache that
/// captures stencil locality well — local memory rarely pays.
pub const GTX_960: DeviceSpec = DeviceSpec {
    name: "GTX 960",
    kind: DeviceKind::Gpu,
    compute_units: 8,
    simd_width: 32,
    clock_ghz: 1.127,
    flops_per_cycle_cu: 256.0,
    mem_bw_gbs: 112.0,
    cacheline: 128,
    global_cache_eff: 0.95,
    tex_cache_eff: 0.93,
    tex_access_cost: 1.0,
    lds_access_iops: 1.5,
    local_mem_per_cu: 96 << 10,
    max_wg: 1024,
    max_threads_per_cu: 2048,
    latency_hiding_threads: 512,
    launch_overhead_s: 6e-6,
    group_overhead_s: 0.0,
    cpu_vector_width: 1,
};

/// NVIDIA Tesla K40 (Kepler): global loads bypass L1 — the texture path
/// (image memory) is the fast road for read-only stencil data (paper §7
/// credits ImageCL's K40 wins to exactly this).
pub const K40: DeviceSpec = DeviceSpec {
    name: "K40",
    kind: DeviceKind::Gpu,
    compute_units: 15,
    simd_width: 32,
    clock_ghz: 0.745,
    flops_per_cycle_cu: 384.0,
    mem_bw_gbs: 288.0,
    cacheline: 128,
    global_cache_eff: 0.70,
    tex_cache_eff: 0.97,
    tex_access_cost: 1.0,
    lds_access_iops: 4.0,
    local_mem_per_cu: 48 << 10,
    max_wg: 1024,
    max_threads_per_cu: 2048,
    latency_hiding_threads: 768,
    launch_overhead_s: 7e-6,
    group_overhead_s: 0.0,
    cpu_vector_width: 1,
};

/// Intel Core i7-4771 (Haswell, 4C/8T, AVX2): caches absorb stencil
/// reuse; the OpenCL runtime vectorizes across work-items; per-work-group
/// scheduling overhead makes heavy thread coarsening essential
/// (paper Table 2: 128 pixels/thread on the CPU).
pub const INTEL_I7: DeviceSpec = DeviceSpec {
    name: "Intel i7",
    kind: DeviceKind::Cpu,
    compute_units: 4,
    simd_width: 8,
    clock_ghz: 3.7,
    flops_per_cycle_cu: 32.0,
    mem_bw_gbs: 25.6,
    cacheline: 64,
    global_cache_eff: 0.95,
    tex_cache_eff: 0.95,
    tex_access_cost: 6.0,
    lds_access_iops: 3.0,
    local_mem_per_cu: 32 << 10,
    max_wg: 1024,
    max_threads_per_cu: 2,
    latency_hiding_threads: 2,
    launch_overhead_s: 15e-6,
    group_overhead_s: 1.5e-6,
    cpu_vector_width: 8,
};

/// The paper's four devices, in Figure 6 order.
pub const ALL_DEVICES: [&DeviceSpec; 4] = [&AMD_7970, &GTX_960, &K40, &INTEL_I7];

pub fn by_name(name: &str) -> Option<&'static DeviceSpec> {
    ALL_DEVICES.iter().copied().find(|d| {
        d.name.eq_ignore_ascii_case(name)
            || d.name.to_lowercase().replace(' ', "_") == name.to_lowercase()
    })
}

impl DeviceSpec {
    /// Peak GFLOP/s.
    pub fn peak_gflops(&self) -> f64 {
        self.compute_units as f64 * self.flops_per_cycle_cu * self.clock_ghz
    }

    /// Constant-memory size limit (64 KiB on all of these devices).
    pub fn constant_mem_bytes(&self) -> usize {
        64 << 10
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_flops_sane() {
        // Datasheet ballparks: 7970 ≈ 3.79 TF, 960 ≈ 2.3 TF, K40 ≈ 4.3 TF,
        // i7-4771 ≈ 0.47 TF.
        assert!((AMD_7970.peak_gflops() - 3789.0).abs() < 100.0);
        assert!((GTX_960.peak_gflops() - 2308.0).abs() < 100.0);
        assert!((K40.peak_gflops() - 4291.0).abs() < 100.0);
        assert!((INTEL_I7.peak_gflops() - 473.0).abs() < 30.0);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(by_name("K40").unwrap().name, "K40");
        assert_eq!(by_name("amd_7970").unwrap().name, "AMD 7970");
        assert_eq!(by_name("intel i7").unwrap().name, "Intel i7");
        assert!(by_name("RTX 4090").is_none());
    }

    #[test]
    fn kepler_texture_beats_global_cache() {
        // The K40 mechanism the paper leans on.
        assert!(K40.tex_cache_eff > K40.global_cache_eff + 0.25);
        // Maxwell: much smaller gap.
        assert!(GTX_960.tex_cache_eff - GTX_960.global_cache_eff < 0.15);
    }

    #[test]
    fn cpu_penalizes_textures() {
        assert!(INTEL_I7.tex_access_cost > 2.0);
        assert!(AMD_7970.tex_access_cost <= 1.0);
    }
}
