//! ImageCL: an image-processing DSL, source-to-source compiler and
//! auto-tuner for performance portability on heterogeneous systems.
//!
//! Reproduction of Falch & Elster, "ImageCL: An Image Processing Language
//! for Performance Portability on Heterogeneous Systems" (HPCS 2016),
//! as a three-layer Rust + JAX + Pallas stack. See DESIGN.md.
pub mod imagecl;
pub mod analysis;
pub mod transform;
pub mod exec;
pub mod devices;
pub mod tuner;
pub mod baselines;
pub mod runtime;
pub mod pipeline;
pub mod serve;
pub mod report;
pub mod bench_defs;
pub mod testutil;
