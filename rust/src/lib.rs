//! ImageCL: an image-processing DSL, source-to-source compiler and
//! auto-tuner for performance portability on heterogeneous systems.
//!
//! Reproduction of Falch & Elster, "ImageCL: An Image Processing Language
//! for Performance Portability on Heterogeneous Systems" (HPCS 2016),
//! as a three-layer Rust + JAX + Pallas stack. See DESIGN.md.

// CI runs `cargo clippy -- -D warnings`; the two purely stylistic lints
// that collide with the crate's established idioms (config structs built
// by field assignment; shared-slot cache types) are allowed once here.
#![allow(clippy::field_reassign_with_default)]
#![allow(clippy::type_complexity)]

pub mod imagecl;
pub mod analysis;
pub mod transform;
pub mod exec;
pub mod devices;
pub mod tuner;
pub mod baselines;
pub mod runtime;
pub mod pipeline;
pub mod fsutil;
pub mod jsonlite;
pub mod obs;
pub mod serve;
pub mod tunedb;
pub mod report;
pub mod bench_defs;
pub mod testutil;
