//! Tuning-space enumeration (paper §5.2, Table 1).
//!
//! The compiler analysis determines which parameters exist for a kernel
//! (which arrays are image/constant/local eligible, which loops unroll);
//! the device bounds work-group sizes and memory capacities. The space is
//! the cross product, filtered for validity.

use std::collections::BTreeMap;

use crate::analysis::KernelInfo;
use crate::devices::DeviceSpec;
use crate::imagecl::Forced;
use crate::transform::{FuseMode, TuningConfig};

/// Candidate values for each axis. Mirrors the ranges seen in the paper's
/// result tables (work-groups up to 128 wide, coarsening up to 256 on the
/// CPU).
pub const WG_X: [usize; 8] = [1, 2, 4, 8, 16, 32, 64, 128];
pub const WG_Y: [usize; 6] = [1, 2, 4, 8, 16, 32];
pub const COARSEN_X: [usize; 8] = [1, 2, 4, 8, 16, 32, 128, 256];
pub const COARSEN_Y: [usize; 5] = [1, 2, 4, 8, 16];

/// Per-array memory-space choice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ArraySpace {
    Global,
    Image,
    Local,
}

/// The enumerated tuning space for one (kernel, device) pair.
#[derive(Debug, Clone)]
pub struct TuningSpace {
    pub configs: Vec<TuningConfig>,
}

impl TuningSpace {
    /// Enumerate all valid configurations.
    pub fn enumerate(info: &KernelInfo, dev: &DeviceSpec) -> TuningSpace {
        // Axis: memory space per buffer.
        let mut mem_axes: Vec<(String, Vec<ArraySpace>)> = Vec::new();
        let mut const_axes: Vec<String> = Vec::new();
        for p in &info.prog.kernel.params {
            if !p.ty.is_buffer() {
                continue;
            }
            let name = &p.name;
            let mut spaces = vec![ArraySpace::Global];
            // Respect force(...) directives: forced-on removes the off
            // branch, forced-off removes the on branch (eligibility
            // helpers already handle Off).
            if info.image_mem_eligible(name) {
                if info.prog.force_image_mem.get(name) == Some(&Forced::On) {
                    spaces = vec![ArraySpace::Image];
                } else {
                    spaces.push(ArraySpace::Image);
                }
            }
            if info.local_mem_eligible(name) {
                if info.prog.force_local_mem.get(name) == Some(&Forced::On) {
                    spaces = vec![ArraySpace::Local];
                } else {
                    spaces.push(ArraySpace::Local);
                }
            }
            if spaces.len() > 1 || spaces[0] != ArraySpace::Global {
                mem_axes.push((name.clone(), spaces));
            }
            if info.constant_mem_eligible(name, dev.constant_mem_bytes()) {
                const_axes.push(name.clone());
            }
        }
        let unroll_axes: Vec<usize> =
            info.unrollable_loops().iter().map(|l| l.id).collect();

        let interleave_choices: &[bool] = match info.prog.force_interleaved {
            Forced::On => &[true],
            Forced::Off => &[false],
            Forced::Tunable => &[false, true],
        };

        let mut configs = Vec::new();
        for &wx in &WG_X {
            for &wy in &WG_Y {
                if wx * wy > dev.max_wg || wx * wy == 0 {
                    continue;
                }
                // Degenerate work-groups waste the whole SIMD width; they
                // are valid but dominated — keep a few for the tuner to
                // discover that itself, but bound the explosion.
                if wx * wy < 4 && wx * wy != 1 {
                    continue;
                }
                for &cx in &COARSEN_X {
                    for &cy in &COARSEN_Y {
                        if cx * cy > 512 {
                            continue;
                        }
                        for &inter in interleave_choices {
                            // Memory-space assignment cross product.
                            let mut assignments: Vec<BTreeMap<String, ArraySpace>> =
                                vec![BTreeMap::new()];
                            for (name, spaces) in &mem_axes {
                                let mut next = Vec::new();
                                for a in &assignments {
                                    for &s in spaces {
                                        let mut a2 = a.clone();
                                        a2.insert(name.clone(), s);
                                        next.push(a2);
                                    }
                                }
                                assignments = next;
                            }
                            // Constant memory: per paper tables it is an
                            // independent on/off per eligible array; it is
                            // almost always on — enumerate both.
                            let mut const_sets: Vec<Vec<String>> = vec![vec![]];
                            for c in &const_axes {
                                let mut next = Vec::new();
                                for s in &const_sets {
                                    next.push(s.clone());
                                    let mut s2 = s.clone();
                                    s2.push(c.clone());
                                    next.push(s2);
                                }
                                const_sets = next;
                            }
                            // Unroll: binary none/full per loop.
                            let n_unroll = unroll_axes.len() as u32;
                            for assignment in &assignments {
                                for const_set in &const_sets {
                                    for umask in 0..(1u32 << n_unroll) {
                                        let mut cfg = TuningConfig {
                                            wg: [wx, wy],
                                            coarsen: [cx, cy],
                                            interleaved: inter,
                                            ..Default::default()
                                        };
                                        for (name, s) in assignment {
                                            match s {
                                                ArraySpace::Image => {
                                                    cfg.image_mem
                                                        .insert(name.clone(), true);
                                                }
                                                ArraySpace::Local => {
                                                    cfg.local_mem
                                                        .insert(name.clone(), true);
                                                }
                                                ArraySpace::Global => {}
                                            }
                                        }
                                        for c in const_set {
                                            cfg.constant_mem.insert(c.clone(), true);
                                        }
                                        for (bit, &lid) in unroll_axes.iter().enumerate()
                                        {
                                            if umask >> bit & 1 == 1 {
                                                cfg.unroll.insert(lid, 0);
                                            }
                                        }
                                        if Self::locally_valid(info, dev, &cfg) {
                                            configs.push(cfg);
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        TuningSpace { configs }
    }

    /// Enumerate the tuning space of a *fused* kernel: the mapping axes
    /// (work-group, coarsening, interleaving) crossed with the fuse mode.
    ///
    /// The per-array memory-space and unroll axes are deliberately
    /// excluded — the fuse decision dominates them on every measured
    /// device, and the synthesized kernel's `force(...)`-free source keeps
    /// the space small enough to search exhaustively per device.
    /// Local-stage candidates must fit the staged tiles (one per fused
    /// image, `(halo_x, halo_y, elem_bytes)` from
    /// `FusedKernel::lstage_tiles`) in the device scratchpad.
    pub fn enumerate_fused(
        dev: &DeviceSpec,
        modes: &[FuseMode],
        lstage_tiles: &[(usize, usize, usize)],
    ) -> TuningSpace {
        let mut configs = Vec::new();
        for &wx in &WG_X {
            for &wy in &WG_Y {
                if wx * wy > dev.max_wg || wx * wy == 0 {
                    continue;
                }
                if wx * wy < 4 && wx * wy != 1 {
                    continue;
                }
                for &cx in &COARSEN_X {
                    for &cy in &COARSEN_Y {
                        if cx * cy > 512 {
                            continue;
                        }
                        for &inter in &[false, true] {
                            for &mode in modes {
                                let cfg = TuningConfig {
                                    wg: [wx, wy],
                                    coarsen: [cx, cy],
                                    interleaved: inter,
                                    fuse: Some(mode),
                                    ..Default::default()
                                };
                                if mode == FuseMode::LocalStage {
                                    let tile = cfg.group_tile();
                                    let bytes: usize = lstage_tiles
                                        .iter()
                                        .map(|&(ex, ey, elem)| {
                                            (tile[0] + ex) * (tile[1] + ey) * elem
                                        })
                                        .sum();
                                    if lstage_tiles.is_empty() || bytes > dev.local_mem_per_cu
                                    {
                                        continue;
                                    }
                                }
                                configs.push(cfg);
                            }
                        }
                    }
                }
            }
        }
        TuningSpace { configs }
    }

    /// Cheap validity pre-filter (full validity including local-memory
    /// capacity is re-checked by the device model, which returns
    /// `Prediction::INVALID`).
    fn locally_valid(info: &KernelInfo, dev: &DeviceSpec, cfg: &TuningConfig) -> bool {
        if cfg.wg_threads() > dev.max_wg {
            return false;
        }
        // Local tiles must fit the device scratchpad.
        if cfg.any_local_mem() {
            let tile = cfg.group_tile();
            let mut bytes = 0usize;
            for (name, &on) in &cfg.local_mem {
                if !on {
                    continue;
                }
                let Some(st) = info.read_stencil(name) else {
                    return false;
                };
                let elem = info
                    .prog
                    .kernel
                    .param(name)
                    .map(|p| p.ty.elem().size_bytes())
                    .unwrap_or(4);
                bytes += (tile[0] + st.extent_x() as usize)
                    * (tile[1] + st.extent_y() as usize)
                    * elem;
            }
            if bytes > dev.local_mem_per_cu {
                return false;
            }
        }
        true
    }

    pub fn len(&self) -> usize {
        self.configs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.configs.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::KernelInfo;
    use crate::bench_defs::{CONV2D, SEPCONV_ROW, SOBEL};
    use crate::devices::{AMD_7970, INTEL_I7, K40};
    use crate::imagecl::frontend;
    use crate::transform::lower;

    fn space(src: &str, dev: &DeviceSpec) -> (KernelInfo, TuningSpace) {
        let info = KernelInfo::analyze(frontend(src).unwrap());
        let sp = TuningSpace::enumerate(&info, dev);
        (info, sp)
    }

    #[test]
    fn space_is_large_but_bounded() {
        let (_, sp) = space(SEPCONV_ROW, &K40);
        // Thousands of candidates (paper: ~1700 *executed* in search out
        // of a larger space).
        assert!(sp.len() > 2_000, "{}", sp.len());
        assert!(sp.len() < 300_000, "{}", sp.len());
    }

    #[test]
    fn all_enumerated_configs_lower() {
        let (info, sp) = space(CONV2D, &K40);
        // Lower a deterministic sample (every 97th) — must never error.
        for cfg in sp.configs.iter().step_by(97) {
            lower(&info, cfg).unwrap_or_else(|e| panic!("{cfg}: {e}"));
        }
    }

    #[test]
    fn wg_respects_device_max() {
        let (_, sp) = space(SOBEL, &AMD_7970);
        assert!(sp.configs.iter().all(|c| c.wg_threads() <= 256));
        let (_, sp) = space(SOBEL, &K40);
        assert!(sp.configs.iter().any(|c| c.wg_threads() > 256));
    }

    #[test]
    fn local_tiles_fit_scratchpad() {
        let (info, sp) = space(CONV2D, &K40);
        for cfg in sp.configs.iter().filter(|c| c.any_local_mem()) {
            let tile = cfg.group_tile();
            let st = info.read_stencil("in").unwrap();
            let bytes =
                (tile[0] + st.extent_x() as usize) * (tile[1] + st.extent_y() as usize);
            assert!(bytes <= K40.local_mem_per_cu, "{cfg}");
        }
    }

    #[test]
    fn forced_directives_shrink_space() {
        let forced = format!(
            "#pragma imcl force(local_mem(in), on)\n#pragma imcl force(interleaved, off)\n{}",
            SEPCONV_ROW.trim_start()
        );
        let info = KernelInfo::analyze(frontend(&forced).unwrap());
        let sp = TuningSpace::enumerate(&info, &K40);
        assert!(sp.configs.iter().all(|c| c.uses_local_mem("in")));
        assert!(sp.configs.iter().all(|c| !c.interleaved));
    }

    #[test]
    fn cpu_space_contains_heavy_coarsening() {
        let (_, sp) = space(SEPCONV_ROW, &INTEL_I7);
        assert!(sp.configs.iter().any(|c| c.coarsen[0] >= 128));
    }

    #[test]
    fn fused_space_covers_modes_and_respects_scratchpad() {
        use crate::transform::FuseMode;
        // Harris fused edge: two f32 gradient tiles with a 1-pixel halo.
        let tiles = [(1, 1, 4), (1, 1, 4)];
        let sp = TuningSpace::enumerate_fused(
            &K40,
            &[FuseMode::Inline, FuseMode::LocalStage],
            &tiles,
        );
        assert!(sp.configs.iter().all(|c| c.fuse.is_some()));
        assert!(sp.configs.iter().any(|c| c.fuse == Some(FuseMode::Inline)));
        assert!(sp.configs.iter().any(|c| c.fuse == Some(FuseMode::LocalStage)));
        // No memory/unroll axes in the fused space.
        assert!(sp
            .configs
            .iter()
            .all(|c| c.local_mem.is_empty() && c.image_mem.is_empty() && c.unroll.is_empty()));
        for cfg in sp.configs.iter().filter(|c| c.fuse == Some(FuseMode::LocalStage)) {
            let tile = cfg.group_tile();
            let bytes = 2 * (tile[0] + 1) * (tile[1] + 1) * 4;
            assert!(bytes <= K40.local_mem_per_cu, "{cfg}");
        }
        // Inline-only edges never enumerate local-stage configs.
        let sp = TuningSpace::enumerate_fused(&K40, &[FuseMode::Inline], &[]);
        assert!(sp.configs.iter().all(|c| c.fuse == Some(FuseMode::Inline)));
        assert!(!sp.is_empty());
    }
}
