//! Search strategies over the tuning space (paper §4).
//!
//! The headline strategy is the two-phase ML search of the authors' prior
//! work ([5], described in the paper's §4): execute a random sample,
//! train an ANN performance model on the observed times, predict the
//! entire space (cheap), then execute the top predictions and return the
//! best *measured* configuration. Exhaustive and pure-random search are
//! provided as baselines and for tests.

use crate::obs;
use crate::testutil::Rng;
use crate::transform::TuningConfig;

use super::features::FeatureMap;
use super::nn::Mlp;
use super::space::TuningSpace;

/// Result of a tuning run.
#[derive(Debug, Clone)]
pub struct TuneResult {
    pub best: TuningConfig,
    /// Best measured time (seconds).
    pub best_time: f64,
    /// Number of candidate executions ("timings") performed.
    pub evals: usize,
    /// Size of the enumerated space.
    pub space_size: usize,
    /// (config, time) pairs in evaluation order — the search history.
    pub history: Vec<(TuningConfig, f64)>,
    /// Wall-clock seconds spent inside the evaluator across every
    /// measured candidate — the real cost of the search. With the
    /// bytecode VM behind real-execution evaluators this is the number
    /// that budget accounting (and `tunedb` acceptance comparisons)
    /// should charge, not the eval count alone.
    pub wall_secs: f64,
}

/// Time one evaluator call, accumulating into `wall`.
fn timed_eval(
    eval: &mut impl FnMut(&TuningConfig) -> f64,
    cfg: &TuningConfig,
    wall: &mut f64,
) -> f64 {
    let t0 = std::time::Instant::now();
    let t = eval(cfg);
    *wall += t0.elapsed().as_secs_f64();
    t
}

/// Record one finished search into the metrics registry: the measured
/// candidate count and the evaluator wall time, labeled by strategy.
/// One registry access per *search* (not per eval) keeps the overhead
/// off the evaluation loop.
fn observe_search(strategy: &'static str, evals: u64, wall_secs: f64) {
    let reg = obs::registry();
    let labels = [("strategy", strategy)];
    reg.counter(
        "imagecl_tuner_evals_total",
        "Candidate evaluations executed by the tuner",
        &labels,
    )
    .add(evals);
    reg.histogram(
        "imagecl_tuner_search_wall_us",
        "Evaluator wall time per tuning search, microseconds",
        &labels,
    )
    .observe((wall_secs * 1e6) as u64);
}

/// Options for the ML two-phase search. Defaults mirror the paper's §7
/// tuning-cost discussion (~1700 executed candidates per device/benchmark).
#[derive(Debug, Clone, PartialEq)]
pub struct MlSearchOpts {
    /// Random configurations executed in phase 1 (training set).
    pub train_samples: usize,
    /// Best-predicted configurations executed in phase 2.
    pub top_k: usize,
    /// Training epochs for the ANN.
    pub epochs: usize,
    /// Hidden layer sizes.
    pub hidden: Vec<usize>,
    pub seed: u64,
}

impl Default for MlSearchOpts {
    fn default() -> Self {
        MlSearchOpts {
            train_samples: 1500,
            top_k: 200,
            epochs: 60,
            hidden: vec![32, 16],
            seed: 0xC0FFEE,
        }
    }
}

/// Exhaustive search: evaluate every configuration.
pub fn exhaustive(
    space: &TuningSpace,
    mut eval: impl FnMut(&TuningConfig) -> f64,
) -> TuneResult {
    let _span = obs::span("tune.exhaustive");
    let mut best: Option<(TuningConfig, f64)> = None;
    let mut evals = 0;
    let mut wall = 0.0;
    for cfg in &space.configs {
        let t = timed_eval(&mut eval, cfg, &mut wall);
        evals += 1;
        if t.is_finite() && best.as_ref().map(|(_, bt)| t < *bt).unwrap_or(true) {
            best = Some((cfg.clone(), t));
        }
    }
    observe_search("exhaustive", evals as u64, wall);
    let (best, best_time) = best.expect("space contained no valid config");
    TuneResult {
        best,
        best_time,
        evals,
        space_size: space.len(),
        history: Vec::new(),
        wall_secs: wall,
    }
}

/// Pure random search with `n` evaluations.
pub fn random(
    space: &TuningSpace,
    n: usize,
    seed: u64,
    mut eval: impl FnMut(&TuningConfig) -> f64,
) -> TuneResult {
    let _span = obs::span("tune.random");
    let mut rng = Rng::new(seed);
    let mut best: Option<(TuningConfig, f64)> = None;
    let mut history = Vec::new();
    let mut wall = 0.0;
    for _ in 0..n {
        let cfg = space.configs[rng.below(space.len())].clone();
        let t = timed_eval(&mut eval, &cfg, &mut wall);
        history.push((cfg.clone(), t));
        if t.is_finite() && best.as_ref().map(|(_, bt)| t < *bt).unwrap_or(true) {
            best = Some((cfg, t));
        }
    }
    observe_search("random", n as u64, wall);
    let (best, best_time) = best.expect("random search found no valid config");
    TuneResult { best, best_time, evals: n, space_size: space.len(), history, wall_secs: wall }
}

/// Warm-started neighborhood search: rank the whole space by feature
/// distance to a `seed` configuration (a transfer-tuned prior, e.g. the
/// winner of the nearest grid in the knowledge base) and execute only
/// the `budget` nearest candidates. The seed itself, when present in the
/// space, is at distance zero and is always measured — so the result is
/// never worse than replaying the prior directly, and usually better
/// because the neighborhood absorbs the drift between the prior's key
/// and this one.
pub fn seeded(
    space: &TuningSpace,
    fm: &FeatureMap,
    seed: &TuningConfig,
    budget: usize,
    mut eval: impl FnMut(&TuningConfig) -> f64,
) -> TuneResult {
    assert!(!space.is_empty());
    let _span = obs::span("tune.seeded");
    let budget = budget.max(1);
    let sf = fm.features(seed);
    let dist2 = |cfg: &TuningConfig| -> f64 {
        fm.features(cfg)
            .iter()
            .zip(&sf)
            .map(|(a, b)| (a - b) * (a - b))
            .sum()
    };
    let mut scored: Vec<(usize, f64)> = space
        .configs
        .iter()
        .enumerate()
        .map(|(i, cfg)| (i, dist2(cfg)))
        .collect();
    scored.sort_by(|a, b| {
        a.1.partial_cmp(&b.1)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.0.cmp(&b.0))
    });
    let mut best: Option<(TuningConfig, f64)> = None;
    let mut history = Vec::new();
    let mut evals = 0;
    let mut wall = 0.0;
    for &(i, _) in scored.iter().take(budget) {
        let cfg = &space.configs[i];
        let t = timed_eval(&mut eval, cfg, &mut wall);
        history.push((cfg.clone(), t));
        evals += 1;
        if t.is_finite() && best.as_ref().map(|(_, bt)| t < *bt).unwrap_or(true) {
            best = Some((cfg.clone(), t));
        }
    }
    observe_search("seeded", evals as u64, wall);
    match best {
        Some((best, best_time)) => TuneResult {
            best,
            best_time,
            evals,
            space_size: space.len(),
            history,
            wall_secs: wall,
        },
        // Nothing valid near the seed (it pointed at an infeasible
        // corner) — fall back to scanning everything.
        None => {
            let mut res = exhaustive(space, eval);
            res.evals += evals;
            res.wall_secs += wall;
            res
        }
    }
}

/// Execute an explicit candidate list (e.g. the top predictions of a
/// knowledge-base performance model) and return the best *measured*
/// configuration. `space_size` is carried through for reporting.
pub fn shortlist(
    space_size: usize,
    candidates: &[TuningConfig],
    mut eval: impl FnMut(&TuningConfig) -> f64,
) -> Option<TuneResult> {
    let _span = obs::span("tune.shortlist");
    let mut best: Option<(TuningConfig, f64)> = None;
    let mut history = Vec::new();
    let mut wall = 0.0;
    for cfg in candidates {
        let t = timed_eval(&mut eval, cfg, &mut wall);
        history.push((cfg.clone(), t));
        if t.is_finite() && best.as_ref().map(|(_, bt)| t < *bt).unwrap_or(true) {
            best = Some((cfg.clone(), t));
        }
    }
    observe_search("shortlist", candidates.len() as u64, wall);
    let (best, best_time) = best?;
    Some(TuneResult {
        best,
        best_time,
        evals: candidates.len(),
        space_size,
        history,
        wall_secs: wall,
    })
}

/// The two-phase ML search (paper §4).
pub fn ml_two_phase(
    space: &TuningSpace,
    fm: &FeatureMap,
    opts: &MlSearchOpts,
    mut eval: impl FnMut(&TuningConfig) -> f64,
) -> TuneResult {
    assert!(!space.is_empty());
    let _span = obs::span("tune.ml");
    let mut rng = Rng::new(opts.seed);
    let n = space.len();
    let mut history: Vec<(TuningConfig, f64)> = Vec::new();

    // Phase 1: execute a random sample, record times.
    let mut sample_idx: Vec<usize> = Vec::new();
    if opts.train_samples >= n {
        sample_idx.extend(0..n);
    } else {
        // Sample without replacement (partial Fisher-Yates).
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..opts.train_samples {
            let j = i + rng.below(n - i);
            idx.swap(i, j);
            sample_idx.push(idx[i]);
        }
    }
    let mut xs: Vec<Vec<f64>> = Vec::new();
    let mut ys: Vec<f64> = Vec::new();
    let mut best: Option<(TuningConfig, f64)> = None;
    let mut wall = 0.0;
    {
        let _p1 = obs::span("tune.ml.sample");
        for &i in &sample_idx {
            let cfg = &space.configs[i];
            let t = timed_eval(&mut eval, cfg, &mut wall);
            history.push((cfg.clone(), t));
            if t.is_finite() {
                xs.push(fm.features(cfg));
                ys.push(t.log10());
                if best.as_ref().map(|(_, bt)| t < *bt).unwrap_or(true) {
                    best = Some((cfg.clone(), t));
                }
            }
        }
    }
    let mut evals = sample_idx.len();

    // Degenerate spaces: nothing valid in the sample → fall back to
    // scanning everything.
    if xs.len() < 8 {
        observe_search("ml_two_phase", evals as u64, wall);
        let mut res = exhaustive(space, eval);
        res.evals += evals;
        res.wall_secs += wall;
        return res;
    }

    // Train the ANN performance model on log-times.
    let mut nn = Mlp::new(fm.dim(), &opts.hidden, opts.seed ^ 0x51E9);
    {
        let _train = obs::span("tune.ml.train");
        nn.fit(&xs, &ys, opts.epochs, opts.seed ^ 0x77);
    }

    // Phase 2: predict the whole space, execute the top-k predictions.
    let _p2 = obs::span("tune.ml.rank");
    let mut scored: Vec<(usize, f64)> = (0..n)
        .map(|i| (i, nn.predict(&fm.features(&space.configs[i]))))
        .collect();
    scored.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    let already: std::collections::HashSet<usize> = sample_idx.iter().copied().collect();
    let mut taken = 0;
    for (i, _) in scored {
        if taken >= opts.top_k {
            break;
        }
        if already.contains(&i) {
            continue;
        }
        let cfg = &space.configs[i];
        let t = timed_eval(&mut eval, cfg, &mut wall);
        history.push((cfg.clone(), t));
        evals += 1;
        taken += 1;
        if t.is_finite() && best.as_ref().map(|(_, bt)| t < *bt).unwrap_or(true) {
            best = Some((cfg.clone(), t));
        }
    }
    observe_search("ml_two_phase", evals as u64, wall);

    let (best, best_time) = best.expect("ML search found no valid config");
    TuneResult { best, best_time, evals, space_size: n, history, wall_secs: wall }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::KernelInfo;
    use crate::bench_defs::SEPCONV_ROW;
    use crate::devices::{predict, KernelModel, K40};
    use crate::imagecl::frontend;
    use crate::tuner::space::TuningSpace;

    fn setup() -> (KernelInfo, TuningSpace, FeatureMap) {
        let info = KernelInfo::analyze(frontend(SEPCONV_ROW).unwrap());
        // Thin the space for test speed: every 5th config (25th in debug).
        let step = if cfg!(debug_assertions) { 25 } else { 5 };
        let full = TuningSpace::enumerate(&info, &K40);
        let configs = full.configs.into_iter().step_by(step).collect();
        let fm = FeatureMap::new(&info);
        (info, TuningSpace { configs }, fm)
    }

    fn simulator_eval<'a>(
        info: &'a KernelInfo,
    ) -> impl FnMut(&TuningConfig) -> f64 + 'a {
        move |cfg| {
            let km = KernelModel::build(info, cfg);
            predict(&K40, &km, 1024, 1024).seconds
        }
    }

    #[test]
    fn ml_search_close_to_exhaustive() {
        let (info, space, fm) = setup();
        let exh = exhaustive(&space, simulator_eval(&info));
        let opts = MlSearchOpts {
            train_samples: 300,
            top_k: 40,
            epochs: 40,
            ..Default::default()
        };
        let ml = ml_two_phase(&space, &fm, &opts, simulator_eval(&info));
        assert!(
            ml.best_time <= exh.best_time * 1.15,
            "ML best {} vs exhaustive {} ({})",
            ml.best_time,
            exh.best_time,
            ml.best
        );
        // And it evaluated far fewer configs than the space size.
        assert!(ml.evals <= 340 + 8);
        assert!(ml.evals < space.len() / 3);
    }

    #[test]
    fn ml_search_beats_equal_budget_random() {
        let (info, space, fm) = setup();
        let opts = MlSearchOpts {
            train_samples: 250,
            top_k: 30,
            epochs: 40,
            ..Default::default()
        };
        let ml = ml_two_phase(&space, &fm, &opts, simulator_eval(&info));
        let rnd = random(&space, 280, 99, simulator_eval(&info));
        // ML must be competitive on a single seed (within 20% — random
        // search can get lucky on one draw; the systematic advantage is
        // asserted against the exhaustive optimum above).
        assert!(
            ml.best_time <= rnd.best_time * 1.2,
            "ML {} vs random {}",
            ml.best_time,
            rnd.best_time
        );
    }

    #[test]
    fn search_is_deterministic() {
        let (info, space, fm) = setup();
        let opts = MlSearchOpts { train_samples: 100, top_k: 10, epochs: 10, ..Default::default() };
        let a = ml_two_phase(&space, &fm, &opts, simulator_eval(&info));
        let b = ml_two_phase(&space, &fm, &opts, simulator_eval(&info));
        assert_eq!(a.best, b.best);
        assert_eq!(a.best_time, b.best_time);
        assert_eq!(a.evals, b.evals);
    }

    #[test]
    fn seeded_search_finds_optimum_near_a_good_seed() {
        let (info, space, fm) = setup();
        let exh = exhaustive(&space, simulator_eval(&info));
        // Seed with the exhaustive winner itself: the neighborhood search
        // must rediscover it (distance 0) with a fraction of the evals.
        let budget = (space.len() / 8).max(8);
        let res = seeded(&space, &fm, &exh.best, budget, simulator_eval(&info));
        assert_eq!(res.evals, budget.min(space.len()));
        assert!(
            res.best_time <= exh.best_time + 1e-15,
            "seeded {} vs exhaustive {}",
            res.best_time,
            exh.best_time
        );
    }

    #[test]
    fn seeded_search_survives_infeasible_seed_region() {
        let (_, space, fm) = setup();
        // Every candidate is invalid: the fallback must still scan the
        // space and the call must not panic on an all-infinite budget.
        let only_valid = space.configs.last().unwrap().clone();
        let res = seeded(&space, &fm, &space.configs[0], 4, |cfg| {
            if *cfg == only_valid { 1.0 } else { f64::INFINITY }
        });
        assert!(res.best_time.is_finite());
        assert_eq!(res.best, only_valid);
    }

    #[test]
    fn shortlist_returns_best_measured() {
        let (_, space, _) = setup();
        let cands: Vec<TuningConfig> =
            space.configs.iter().take(10).cloned().collect();
        let res = shortlist(space.len(), &cands, |cfg| cfg.wg_threads() as f64)
            .expect("some candidate is finite");
        assert_eq!(res.evals, 10);
        let want = cands.iter().map(|c| c.wg_threads()).min().unwrap();
        assert_eq!(res.best.wg_threads(), want);
        assert!(shortlist(space.len(), &[], |_| 1.0).is_none());
    }

    #[test]
    fn invalid_configs_skipped() {
        let (_, space, _) = setup();
        // An evaluator that declares everything with wg > 256 invalid.
        let res = exhaustive(&space, |cfg| {
            if cfg.wg_threads() > 256 {
                f64::INFINITY
            } else {
                cfg.wg_threads() as f64
            }
        });
        assert!(res.best.wg_threads() <= 256);
    }
}
