//! Featurization of tuning configurations for the ML performance model.
//!
//! The feature layout is fixed per kernel (derived from the analysis), so
//! one model serves one (kernel, device) tuning run — matching the
//! auto-tuner of the paper's reference [5].

use crate::analysis::KernelInfo;
use crate::transform::TuningConfig;

/// Stable feature layout for one kernel.
#[derive(Debug, Clone)]
pub struct FeatureMap {
    /// Buffer names with any tunable memory space, sorted.
    pub arrays: Vec<String>,
    /// Unrollable loop ids, ascending.
    pub loops: Vec<usize>,
}

impl FeatureMap {
    pub fn new(info: &KernelInfo) -> FeatureMap {
        let mut arrays: Vec<String> = info
            .prog
            .kernel
            .params
            .iter()
            .filter(|p| p.ty.is_buffer())
            .map(|p| p.name.clone())
            .collect();
        arrays.sort();
        let loops = info.unrollable_loops().iter().map(|l| l.id).collect();
        FeatureMap { arrays, loops }
    }

    /// Number of features produced.
    pub fn dim(&self) -> usize {
        7 + 3 * self.arrays.len() + self.loops.len()
    }

    /// Encode a configuration.
    pub fn features(&self, cfg: &TuningConfig) -> Vec<f64> {
        let lg = |v: usize| (v as f64).log2();
        let mut f = vec![
            lg(cfg.wg[0]),
            lg(cfg.wg[1]),
            lg(cfg.coarsen[0]),
            lg(cfg.coarsen[1]),
            if cfg.interleaved { 1.0 } else { 0.0 },
            lg(cfg.wg_threads()),
            lg(cfg.pixels_per_thread()),
        ];
        for a in &self.arrays {
            f.push(cfg.uses_image_mem(a) as u8 as f64);
            f.push(cfg.uses_constant_mem(a) as u8 as f64);
            f.push(cfg.uses_local_mem(a) as u8 as f64);
        }
        for &l in &self.loops {
            f.push(if cfg.unroll_factor(l) == 1 { 0.0 } else { 1.0 });
        }
        f
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::KernelInfo;
    use crate::bench_defs::SEPCONV_ROW;
    use crate::imagecl::frontend;

    #[test]
    fn layout_and_encoding() {
        let info = KernelInfo::analyze(frontend(SEPCONV_ROW).unwrap());
        let fm = FeatureMap::new(&info);
        assert_eq!(fm.arrays, vec!["f", "in", "out"]);
        assert_eq!(fm.loops, vec![1]);
        assert_eq!(fm.dim(), 7 + 9 + 1);

        let mut cfg = TuningConfig { wg: [64, 4], coarsen: [4, 1], ..Default::default() };
        cfg.local_mem.insert("in".into(), true);
        cfg.constant_mem.insert("f".into(), true);
        cfg.unroll.insert(1, 0);
        let f = fm.features(&cfg);
        assert_eq!(f.len(), fm.dim());
        assert_eq!(f[0], 6.0); // log2 64
        assert_eq!(f[2], 2.0); // log2 4
        assert_eq!(f[4], 0.0); // blocked
        // f: img, const, local
        assert_eq!(&f[7..10], &[0.0, 1.0, 0.0]);
        // in: img, const, local
        assert_eq!(&f[10..13], &[0.0, 0.0, 1.0]);
        // unroll flag
        assert_eq!(f[16], 1.0);
    }

    #[test]
    fn distinct_configs_distinct_features() {
        let info = KernelInfo::analyze(frontend(SEPCONV_ROW).unwrap());
        let fm = FeatureMap::new(&info);
        let a = fm.features(&TuningConfig::default());
        let b = fm.features(&TuningConfig { interleaved: true, ..Default::default() });
        assert_ne!(a, b);
    }
}
