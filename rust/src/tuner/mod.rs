//! The auto-tuner (paper §4): enumerate the tuning space for a kernel,
//! "time" candidates through an evaluator (the device simulator, or real
//! execution through the XLA runtime), and search with the two-phase
//! machine-learning strategy of the authors' prior work [5].

pub mod features;
pub mod nn;
pub mod search;
pub mod space;

pub use features::FeatureMap;
pub use nn::Mlp;
pub use search::{
    exhaustive, ml_two_phase, random, seeded, shortlist, MlSearchOpts, TuneResult,
};
pub use space::TuningSpace;

use crate::analysis::KernelInfo;
use crate::devices::{predict, DeviceSpec, KernelModel};
use crate::transform::TuningConfig;

/// Search strategy selector.
#[derive(Debug, Clone, PartialEq)]
pub enum Strategy {
    Exhaustive,
    Random { evals: usize, seed: u64 },
    MlTwoPhase(MlSearchOpts),
}

impl Default for Strategy {
    fn default() -> Self {
        Strategy::MlTwoPhase(MlSearchOpts::default())
    }
}

/// Tune one kernel for one device against the analytical device model
/// (the GPU path; the CPU additionally supports real execution — see
/// `runtime`).
pub fn tune_on_simulator(
    info: &KernelInfo,
    dev: &DeviceSpec,
    grid: (usize, usize),
    strategy: &Strategy,
) -> TuneResult {
    let space = TuningSpace::enumerate(info, dev);
    run(&space, info, strategy, simulator_eval(info, dev, grid))
}

/// The device-model evaluator used by the `*_on_simulator` entry points.
pub fn simulator_eval<'a>(
    info: &'a KernelInfo,
    dev: &'a DeviceSpec,
    grid: (usize, usize),
) -> impl FnMut(&TuningConfig) -> f64 + 'a {
    move |cfg| {
        let km = KernelModel::build(info, cfg);
        predict(dev, &km, grid.0, grid.1).seconds
    }
}

/// Tune within an already-enumerated space. Callers that hold a space
/// and a feature map (the serving layer's knowledge-base tiers try
/// several search modes against one space) avoid re-enumerating per
/// attempt.
pub fn tune_in_space(
    space: &TuningSpace,
    info: &KernelInfo,
    strategy: &Strategy,
    eval: impl FnMut(&TuningConfig) -> f64,
) -> TuneResult {
    run(space, info, strategy, eval)
}

/// Tune with a caller-provided evaluator (e.g. real execution timing).
pub fn tune_with(
    info: &KernelInfo,
    dev: &DeviceSpec,
    strategy: &Strategy,
    eval: impl FnMut(&TuningConfig) -> f64,
) -> TuneResult {
    let space = TuningSpace::enumerate(info, dev);
    run(&space, info, strategy, eval)
}

fn run(
    space: &TuningSpace,
    info: &KernelInfo,
    strategy: &Strategy,
    eval: impl FnMut(&TuningConfig) -> f64,
) -> TuneResult {
    match strategy {
        Strategy::Exhaustive => exhaustive(space, eval),
        Strategy::Random { evals, seed } => random(space, *evals, *seed, eval),
        Strategy::MlTwoPhase(opts) => {
            let fm = FeatureMap::new(info);
            ml_two_phase(space, &fm, opts, eval)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_defs::SEPCONV_ROW;
    use crate::devices::{AMD_7970, INTEL_I7};
    use crate::imagecl::frontend;

    #[test]
    fn tuned_configs_reflect_device_character() {
        let info = KernelInfo::analyze(frontend(SEPCONV_ROW).unwrap());
        let budget = if cfg!(debug_assertions) { 150 } else { 400 };
        let opts = MlSearchOpts {
            train_samples: budget,
            top_k: budget / 7,
            epochs: 20,
            ..Default::default()
        };
        let strategy = Strategy::MlTwoPhase(opts);
        let amd = tune_on_simulator(&info, &AMD_7970, (1024, 1024), &strategy);
        let cpu = tune_on_simulator(&info, &INTEL_I7, (1024, 1024), &strategy);
        // Paper Table 2 shape: the CPU wants far more pixels per thread
        // than the GPU, and never image memory.
        assert!(
            cpu.best.pixels_per_thread() > amd.best.pixels_per_thread(),
            "cpu {} vs amd {}",
            cpu.best,
            amd.best
        );
        assert!(!cpu.best.uses_image_mem("in"));
        // Constant memory is chosen everywhere (Table 2 bottom row).
        assert!(amd.best.uses_constant_mem("f"), "{}", amd.best);
        assert!(cpu.best.uses_constant_mem("f"), "{}", cpu.best);
    }
}
