//! A small feed-forward neural network, implemented from scratch (no
//! external crates are available offline).
//!
//! This is the performance model of the paper's machine-learning
//! auto-tuner (ref [5] of the paper): it learns `log(time)` from tuning
//! configuration features of executed candidates, then predicts the whole
//! space cheaply. Architecture: dense layers with tanh hidden units and a
//! linear output, trained with Adam on mean-squared error.

use crate::testutil::Rng;

/// One dense layer: `y = act(W x + b)`.
#[derive(Debug, Clone)]
struct Dense {
    inp: usize,
    out: usize,
    w: Vec<f64>, // out × inp, row-major
    b: Vec<f64>,
    tanh: bool,
    // Adam state.
    mw: Vec<f64>,
    vw: Vec<f64>,
    mb: Vec<f64>,
    vb: Vec<f64>,
}

impl Dense {
    fn new(inp: usize, out: usize, tanh: bool, rng: &mut Rng) -> Dense {
        // Xavier-ish init.
        let scale = (2.0 / (inp + out) as f64).sqrt();
        let w = (0..inp * out)
            .map(|_| (rng.unit() * 2.0 - 1.0) * scale)
            .collect();
        Dense {
            inp,
            out,
            w,
            b: vec![0.0; out],
            tanh,
            mw: vec![0.0; inp * out],
            vw: vec![0.0; inp * out],
            mb: vec![0.0; out],
            vb: vec![0.0; out],
        }
    }

    fn forward(&self, x: &[f64], pre: &mut Vec<f64>, post: &mut Vec<f64>) {
        pre.clear();
        post.clear();
        for o in 0..self.out {
            let mut s = self.b[o];
            let row = &self.w[o * self.inp..(o + 1) * self.inp];
            for (wi, xi) in row.iter().zip(x) {
                s += wi * xi;
            }
            pre.push(s);
            post.push(if self.tanh { s.tanh() } else { s });
        }
    }
}

/// The MLP performance model.
#[derive(Debug, Clone)]
pub struct Mlp {
    layers: Vec<Dense>,
    /// Adam step counter.
    t: usize,
    /// Normalization of inputs (per-feature mean/std) and target.
    x_mean: Vec<f64>,
    x_std: Vec<f64>,
    y_mean: f64,
    y_std: f64,
}

/// Adam hyper-parameters.
const LR: f64 = 3e-3;
const BETA1: f64 = 0.9;
const BETA2: f64 = 0.999;
const EPS: f64 = 1e-8;

impl Mlp {
    /// Build an MLP with the given hidden sizes (e.g. `[32, 16]`).
    pub fn new(inputs: usize, hidden: &[usize], seed: u64) -> Mlp {
        let mut rng = Rng::new(seed);
        let mut layers = Vec::new();
        let mut prev = inputs;
        for &h in hidden {
            layers.push(Dense::new(prev, h, true, &mut rng));
            prev = h;
        }
        layers.push(Dense::new(prev, 1, false, &mut rng));
        Mlp {
            layers,
            t: 0,
            x_mean: vec![0.0; inputs],
            x_std: vec![1.0; inputs],
            y_mean: 0.0,
            y_std: 1.0,
        }
    }

    fn normalize(&self, x: &[f64]) -> Vec<f64> {
        x.iter()
            .zip(self.x_mean.iter().zip(&self.x_std))
            .map(|(v, (m, s))| (v - m) / s)
            .collect()
    }

    /// Predict the (denormalized) target for one feature vector.
    pub fn predict(&self, x: &[f64]) -> f64 {
        let mut cur = self.normalize(x);
        let (mut pre, mut post) = (Vec::new(), Vec::new());
        for l in &self.layers {
            l.forward(&cur, &mut pre, &mut post);
            cur = post.clone();
        }
        cur[0] * self.y_std + self.y_mean
    }

    /// Fit on a dataset with mini-batch Adam. `xs` are raw features, `ys`
    /// raw targets (normalization is fitted here).
    pub fn fit(&mut self, xs: &[Vec<f64>], ys: &[f64], epochs: usize, seed: u64) {
        assert_eq!(xs.len(), ys.len());
        assert!(!xs.is_empty());
        let n = xs.len();
        let d = xs[0].len();

        // Fit normalization.
        self.x_mean = vec![0.0; d];
        self.x_std = vec![0.0; d];
        for x in xs {
            for (i, v) in x.iter().enumerate() {
                self.x_mean[i] += v;
            }
        }
        for m in &mut self.x_mean {
            *m /= n as f64;
        }
        for x in xs {
            for (i, v) in x.iter().enumerate() {
                self.x_std[i] += (v - self.x_mean[i]).powi(2);
            }
        }
        for s in &mut self.x_std {
            *s = (*s / n as f64).sqrt().max(1e-9);
        }
        self.y_mean = ys.iter().sum::<f64>() / n as f64;
        self.y_std = (ys.iter().map(|y| (y - self.y_mean).powi(2)).sum::<f64>()
            / n as f64)
            .sqrt()
            .max(1e-9);

        let xn: Vec<Vec<f64>> = xs.iter().map(|x| self.normalize(x)).collect();
        let yn: Vec<f64> = ys.iter().map(|y| (y - self.y_mean) / self.y_std).collect();

        let mut order: Vec<usize> = (0..n).collect();
        let mut rng = Rng::new(seed ^ 0xA5A5);
        for _ in 0..epochs {
            // Shuffle (Fisher-Yates).
            for i in (1..n).rev() {
                order.swap(i, rng.below(i + 1));
            }
            for &i in &order {
                self.step(&xn[i], yn[i]);
            }
        }
    }

    /// One SGD/Adam step on a single (normalized) sample.
    fn step(&mut self, x: &[f64], y: f64) {
        // Forward, keeping activations.
        let mut acts: Vec<Vec<f64>> = vec![x.to_vec()];
        let mut pres: Vec<Vec<f64>> = Vec::new();
        {
            let (mut pre, mut post) = (Vec::new(), Vec::new());
            let mut cur = x.to_vec();
            for l in &self.layers {
                l.forward(&cur, &mut pre, &mut post);
                pres.push(pre.clone());
                acts.push(post.clone());
                cur = post.clone();
            }
        }
        let out = acts.last().unwrap()[0];
        // dL/dout for L = (out - y)^2.
        let mut grad = vec![2.0 * (out - y)];

        self.t += 1;
        let t = self.t as f64;
        let bias1 = 1.0 - BETA1.powf(t);
        let bias2 = 1.0 - BETA2.powf(t);

        for li in (0..self.layers.len()).rev() {
            let l = &mut self.layers[li];
            let input = &acts[li];
            let mut next_grad = vec![0.0; l.inp];
            for o in 0..l.out {
                // Through activation.
                let g = if l.tanh {
                    let th = pres[li][o].tanh();
                    grad[o] * (1.0 - th * th)
                } else {
                    grad[o]
                };
                // Bias.
                let mb = &mut l.mb[o];
                let vb = &mut l.vb[o];
                *mb = BETA1 * *mb + (1.0 - BETA1) * g;
                *vb = BETA2 * *vb + (1.0 - BETA2) * g * g;
                l.b[o] -= LR * (*mb / bias1) / ((*vb / bias2).sqrt() + EPS);
                // Weights + input grad.
                for i in 0..l.inp {
                    let idx = o * l.inp + i;
                    let gw = g * input[i];
                    next_grad[i] += g * l.w[idx];
                    let mw = &mut l.mw[idx];
                    let vw = &mut l.vw[idx];
                    *mw = BETA1 * *mw + (1.0 - BETA1) * gw;
                    *vw = BETA2 * *vw + (1.0 - BETA2) * gw * gw;
                    l.w[idx] -= LR * (*mw / bias1) / ((*vw / bias2).sqrt() + EPS);
                }
            }
            grad = next_grad;
        }
    }

    /// Mean-squared error on a dataset (raw units).
    pub fn mse(&self, xs: &[Vec<f64>], ys: &[f64]) -> f64 {
        xs.iter()
            .zip(ys)
            .map(|(x, y)| (self.predict(x) - y).powi(2))
            .sum::<f64>()
            / xs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn learns_linear_function() {
        let mut rng = Rng::new(1);
        let xs: Vec<Vec<f64>> = (0..200)
            .map(|_| vec![rng.unit() * 4.0 - 2.0, rng.unit() * 4.0 - 2.0])
            .collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x[0] - 2.0 * x[1] + 1.0).collect();
        let mut nn = Mlp::new(2, &[16], 7);
        nn.fit(&xs, &ys, 200, 3);
        let mse = nn.mse(&xs, &ys);
        assert!(mse < 0.05, "mse {mse}");
    }

    #[test]
    fn learns_nonlinear_interaction() {
        // The kind of structure tuning spaces have: multiplicative
        // interactions and a sweet spot.
        let mut rng = Rng::new(2);
        let xs: Vec<Vec<f64>> = (0..400)
            .map(|_| vec![rng.unit() * 2.0, rng.unit() * 2.0])
            .collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|x| (x[0] - 1.0).powi(2) + (x[0] * x[1]).sin())
            .collect();
        let mut nn = Mlp::new(2, &[24, 12], 11);
        nn.fit(&xs, &ys, 300, 5);
        let mse = nn.mse(&xs, &ys);
        assert!(mse < 0.05, "mse {mse}");
    }

    #[test]
    fn gradient_check() {
        // Finite-difference check of the backprop (single layer, one
        // weight): loss must decrease along the analytic gradient.
        let xs = vec![vec![0.5, -1.0], vec![-0.25, 0.75], vec![1.0, 0.1]];
        let ys = vec![1.0, -0.5, 0.25];
        let mut nn = Mlp::new(2, &[4], 3);
        let before = nn.mse(&xs, &ys);
        nn.fit(&xs, &ys, 50, 9);
        let after = nn.mse(&xs, &ys);
        assert!(after < before, "training increased loss: {before} -> {after}");
    }

    #[test]
    fn deterministic_training() {
        let xs: Vec<Vec<f64>> = (0..50).map(|i| vec![i as f64 / 50.0]).collect();
        let ys: Vec<f64> = xs.iter().map(|x| x[0] * x[0]).collect();
        let mut a = Mlp::new(1, &[8], 5);
        let mut b = Mlp::new(1, &[8], 5);
        a.fit(&xs, &ys, 50, 13);
        b.fit(&xs, &ys, 50, 13);
        for x in &xs {
            assert_eq!(a.predict(x), b.predict(x));
        }
    }

    #[test]
    fn normalization_handles_constant_features() {
        // A constant feature (std 0) must not produce NaNs.
        let xs = vec![vec![1.0, 5.0], vec![2.0, 5.0], vec![3.0, 5.0]];
        let ys = vec![1.0, 2.0, 3.0];
        let mut nn = Mlp::new(2, &[4], 1);
        nn.fit(&xs, &ys, 100, 2);
        assert!(nn.predict(&[2.0, 5.0]).is_finite());
    }
}
