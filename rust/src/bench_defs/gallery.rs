//! Kernel gallery: additional ImageCL programs beyond the paper's three
//! benchmarks, exercising the breadth the language claims (paper §5:
//! "rich enough to express a wide range of parallel image processing
//! algorithms" while retaining "the generality of OpenCL").
//!
//! Each kernel ships with a direct Rust reference; the gallery sweep in
//! `rust/tests/exec_sweep.rs`-style tests (see `tests` below and the
//! integration suite) checks every tuning configuration against it.

use std::collections::BTreeMap;

use crate::exec::{Arg, Buffer, ImageBuf, Value};
use crate::imagecl::ScalarType;

use super::synth_image;

/// 3×3 box blur (constant-0 boundary) — the canonical stencil benchmark
/// (`imagecl bench` / `BENCH_exec.json` headline kernel).
pub const BLUR: &str = r#"
#pragma imcl grid(in)
void blur(Image<float> in, Image<float> out) {
  float sum = 0.0f;
  for (int i = -1; i < 2; i++) {
    for (int j = -1; j < 2; j++) { sum += in[idx + i][idy + j]; }
  }
  out[idx][idy] = sum / 9.0f;
}
"#;

/// Grayscale threshold (per-pixel, no stencil — point kernels must also
/// survive every transformation).
pub const THRESHOLD: &str = r#"
#pragma imcl grid(in)
void threshold(Image<float> in, Image<float> out, float level) {
  out[idx][idy] = in[idx][idy] > level ? 1.0 : 0.0;
}
"#;

/// 3x3 erosion (min filter) — morphological, clamped boundary.
pub const ERODE: &str = r#"
#pragma imcl grid(in)
#pragma imcl boundary(in, clamped)
void erode(Image<float> in, Image<float> out) {
  float m = in[idx][idy];
  for (int i = -1; i < 2; i++) {
    for (int j = -1; j < 2; j++) {
      m = min(m, in[idx + i][idy + j]);
    }
  }
  out[idx][idy] = m;
}
"#;

/// 3x3 dilation (max filter).
pub const DILATE: &str = r#"
#pragma imcl grid(in)
#pragma imcl boundary(in, clamped)
void dilate(Image<float> in, Image<float> out) {
  float m = in[idx][idy];
  for (int i = -1; i < 2; i++) {
    for (int j = -1; j < 2; j++) {
      m = max(m, in[idx + i][idy + j]);
    }
  }
  out[idx][idy] = m;
}
"#;

/// Gradient magnitude with sqrt (transcendental use + two inputs).
pub const GRAD_MAG: &str = r#"
#pragma imcl grid(dx)
void grad_mag(Image<float> dx, Image<float> dy, Image<float> out) {
  float gx = dx[idx][idy];
  float gy = dy[idx][idy];
  out[idx][idy] = sqrt(gx * gx + gy * gy);
}
"#;

/// Unsharp masking: out = in + amount*(in - blur3(in)) — stencil plus
/// scalar parameter plus constant boundary.
pub const UNSHARP: &str = r#"
#pragma imcl grid(in)
#pragma imcl boundary(in, constant, 0.0)
void unsharp(Image<float> in, Image<float> out, float amount) {
  float sum = 0.0f;
  for (int i = -1; i < 2; i++) {
    for (int j = -1; j < 2; j++) {
      sum += in[idx + i][idy + j];
    }
  }
  float blur = sum / 9.0f;
  out[idx][idy] = in[idx][idy] + amount * (in[idx][idy] - blur);
}
"#;

/// Downsample-by-2 (grid from the *output* image, reads a 2x2 block of a
/// larger input — exercises grid != input-image size).
pub const DOWNSAMPLE: &str = r#"
#pragma imcl grid(out)
#pragma imcl boundary(in, clamped)
void downsample(Image<float> in, Image<float> out) {
  float sum = 0.0f;
  for (int i = 0; i < 2; i++) {
    for (int j = 0; j < 2; j++) {
      sum += in[idx + idx + i][idy + idy + j];
    }
  }
  out[idx][idy] = sum / 4.0f;
}
"#;

/// Image blend with a weight array (array parameter indexed by a
/// runtime-computed subscript).
pub const BLEND: &str = r#"
#pragma imcl grid(a)
#pragma imcl array_size(w, 2)
void blend(Image<float> a, Image<float> b, Image<float> out, float* w) {
  out[idx][idy] = a[idx][idy] * w[0] + b[idx][idy] * w[1];
}
"#;

/// All gallery kernels with display names.
pub const GALLERY: [(&str, &str); 8] = [
    ("blur", BLUR),
    ("threshold", THRESHOLD),
    ("erode", ERODE),
    ("dilate", DILATE),
    ("grad_mag", GRAD_MAG),
    ("unsharp", UNSHARP),
    ("downsample", DOWNSAMPLE),
    ("blend", BLEND),
];

/// Source text of a gallery kernel.
pub fn gallery_source(name: &str) -> Option<&'static str> {
    GALLERY.iter().find(|(n, _)| *n == name).map(|(_, src)| *src)
}

/// Build the canonical argument map for a gallery kernel at grid `w`×`h`
/// (inputs synthetic, outputs zeroed). For `downsample` the grid is the
/// *output* size and the input image is 2× larger.
pub fn gallery_workload(name: &str, w: usize, h: usize, seed: u64) -> BTreeMap<String, Arg> {
    let img = |s: u64| Arg::Image(synth_image(ScalarType::F32, w, h, s));
    let out = || Arg::Image(ImageBuf::new(ScalarType::F32, w, h));
    let mut args = BTreeMap::new();
    match name {
        "blur" | "erode" | "dilate" | "unsharp" | "threshold" => {
            args.insert("in".to_string(), img(seed));
            args.insert("out".to_string(), out());
            if name == "unsharp" {
                args.insert("amount".to_string(), Arg::Scalar(Value::F(0.7)));
            }
            if name == "threshold" {
                args.insert("level".to_string(), Arg::Scalar(Value::F(128.0)));
            }
        }
        "grad_mag" => {
            args.insert("dx".to_string(), img(seed));
            args.insert("dy".to_string(), img(seed ^ 0x5EED));
            args.insert("out".to_string(), out());
        }
        "downsample" => {
            args.insert(
                "in".to_string(),
                Arg::Image(synth_image(ScalarType::F32, 2 * w, 2 * h, seed)),
            );
            args.insert("out".to_string(), out());
        }
        "blend" => {
            args.insert("a".to_string(), img(seed));
            args.insert("b".to_string(), img(seed ^ 0xB1E4D));
            args.insert("out".to_string(), out());
            args.insert(
                "w".to_string(),
                Arg::Array(Buffer::from_f64(ScalarType::F32, vec![0.25, 0.75])),
            );
        }
        other => panic!("unknown gallery kernel {other:?}"),
    }
    args
}

// ---------------------------------------------------------------------
// References
// ---------------------------------------------------------------------

/// Blur reference mirroring the kernel's f32 arithmetic exactly: the
/// `float sum` accumulator rounds through f32 at every step.
pub fn ref_blur(input: &ImageBuf) -> Vec<f64> {
    let (w, h) = (input.w as i64, input.h as i64);
    let mut out = vec![0.0; (w * h) as usize];
    for y in 0..h {
        for x in 0..w {
            let mut sum = 0.0f64;
            for i in -1..2 {
                for j in -1..2 {
                    let (xx, yy) = (x + i, y + j);
                    if xx >= 0 && xx < w && yy >= 0 && yy < h {
                        sum = (sum + input.get(xx as usize, yy as usize)) as f32 as f64;
                    }
                }
            }
            out[(y * w + x) as usize] = (sum / 9.0) as f32 as f64;
        }
    }
    out
}

pub fn ref_threshold(input: &ImageBuf, level: f64) -> Vec<f64> {
    input
        .buf
        .data
        .iter()
        .map(|&v| if v as f32 > level as f32 { 1.0 } else { 0.0 })
        .collect()
}

fn morph(input: &ImageBuf, take_min: bool) -> Vec<f64> {
    let (w, h) = (input.w as i64, input.h as i64);
    let at = |x: i64, y: i64| input.get(x.clamp(0, w - 1) as usize, y.clamp(0, h - 1) as usize);
    let mut out = vec![0.0; (w * h) as usize];
    for y in 0..h {
        for x in 0..w {
            let mut m = at(x, y);
            for i in -1..2 {
                for j in -1..2 {
                    let v = at(x + i, y + j);
                    m = if take_min { m.min(v) } else { m.max(v) };
                }
            }
            out[(y * w + x) as usize] = m;
        }
    }
    out
}

pub fn ref_erode(input: &ImageBuf) -> Vec<f64> {
    morph(input, true)
}

pub fn ref_dilate(input: &ImageBuf) -> Vec<f64> {
    morph(input, false)
}

pub fn ref_grad_mag(dx: &ImageBuf, dy: &ImageBuf) -> Vec<f64> {
    dx.buf
        .data
        .iter()
        .zip(&dy.buf.data)
        .map(|(&a, &b)| {
            let (a, b) = (a as f32, b as f32);
            ((a * a + b * b) as f32).sqrt() as f64
        })
        .collect()
}

pub fn ref_unsharp(input: &ImageBuf, amount: f64) -> Vec<f64> {
    let (w, h) = (input.w as i64, input.h as i64);
    let mut out = vec![0.0; (w * h) as usize];
    for y in 0..h {
        for x in 0..w {
            let mut sum = 0.0f64;
            for i in -1..2 {
                for j in -1..2 {
                    let (xx, yy) = (x + i, y + j);
                    if xx >= 0 && xx < w && yy >= 0 && yy < h {
                        sum += input.get(xx as usize, yy as usize);
                    }
                }
            }
            let c = input.get(x as usize, y as usize);
            let blur = (sum as f32 / 9.0) as f64;
            out[(y * w + x) as usize] = c + amount * (c - blur);
        }
    }
    out
}

/// Downsample reference: output is `w/2 x h/2` of a `w x h` input.
pub fn ref_downsample(input: &ImageBuf, ow: usize, oh: usize) -> Vec<f64> {
    let (w, h) = (input.w as i64, input.h as i64);
    let at = |x: i64, y: i64| input.get(x.clamp(0, w - 1) as usize, y.clamp(0, h - 1) as usize);
    let mut out = vec![0.0; ow * oh];
    for y in 0..oh as i64 {
        for x in 0..ow as i64 {
            let mut sum = 0.0;
            for i in 0..2 {
                for j in 0..2 {
                    sum += at(2 * x + i, 2 * y + j);
                }
            }
            out[(y as usize) * ow + x as usize] = (sum as f32 / 4.0) as f64;
        }
    }
    out
}

pub fn ref_blend(a: &ImageBuf, b: &ImageBuf, w0: f64, w1: f64) -> Vec<f64> {
    a.buf
        .data
        .iter()
        .zip(&b.buf.data)
        .map(|(&x, &y)| (x as f32 * w0 as f32 + y as f32 * w1 as f32) as f64)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::KernelInfo;
    use crate::imagecl::frontend;

    #[test]
    fn blur_matches_reference() {
        use crate::transform::{lower, TuningConfig};
        let (w, h) = (17, 13);
        let info = KernelInfo::analyze(frontend(BLUR).unwrap());
        let plan = lower(&info, &TuningConfig::default()).unwrap();
        let mut args = gallery_workload("blur", w, h, 5);
        crate::exec::execute(&plan, &mut args, (w, h)).unwrap();
        let input = synth_image(ScalarType::F32, w, h, 5);
        let want = ref_blur(&input);
        let out = match &args["out"] {
            Arg::Image(i) => &i.buf.data,
            _ => unreachable!(),
        };
        for i in 0..want.len() {
            assert!(
                (out[i] - want[i]).abs() < 1e-4,
                "blur differs at {i}: {} vs {}",
                out[i],
                want[i]
            );
        }
    }

    #[test]
    fn gallery_workloads_cover_every_kernel() {
        for (name, _) in GALLERY {
            let args = gallery_workload(name, 8, 6, 3);
            assert!(!args.is_empty(), "{name}");
        }
        assert!(gallery_source("blur").is_some());
        assert!(gallery_source("nope").is_none());
    }

    #[test]
    fn gallery_compiles_and_analyzes() {
        for (name, src) in GALLERY {
            let info = KernelInfo::analyze(
                frontend(src).unwrap_or_else(|e| panic!("{name}: {e}")),
            );
            assert!(!info.loops.is_empty() || matches!(name, "threshold" | "grad_mag" | "blend"));
        }
    }

    #[test]
    fn gallery_eligibilities() {
        // erode/dilate: read-only stencil input → local eligible.
        let info = KernelInfo::analyze(frontend(ERODE).unwrap());
        assert!(info.local_mem_eligible("in"));
        // downsample's input index is idx+idx (scaled) → NOT local
        // eligible (paper §5.2.4: idx must not be multiplied).
        let info = KernelInfo::analyze(frontend(DOWNSAMPLE).unwrap());
        assert!(!info.local_mem_eligible("in"));
        assert!(info.image_mem_eligible("in"));
        // blend: weight array constant-memory eligible via array_size.
        let info = KernelInfo::analyze(frontend(BLEND).unwrap());
        assert!(info.constant_mem_eligible("w", 64 << 10));
    }

    #[test]
    fn threshold_is_point_kernel() {
        let info = KernelInfo::analyze(frontend(THRESHOLD).unwrap());
        let st = info.read_stencil("in").unwrap();
        assert_eq!((st.extent_x(), st.extent_y()), (0, 0));
    }
}
