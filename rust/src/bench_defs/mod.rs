//! The paper's evaluation benchmarks (§6) as ImageCL programs, plus
//! synthetic workload generators and direct Rust reference filters.
//!
//! * **Separable convolution** — 5-tap row + column kernels, 4096²
//!   `float` image, constant boundary condition.
//! * **Non-separable convolution** — 5×5 kernel, 8192² `uchar` image,
//!   clamped boundary condition.
//! * **Harris corner detection** — Sobel kernel (gradients) + Harris
//!   kernel (2×2 block response), 5120² `float` image.

pub mod gallery;
pub mod reference;

use std::collections::BTreeMap;

use crate::exec::{Arg, Buffer, ImageBuf};
use crate::imagecl::ScalarType;
use crate::testutil::Rng;

/// Separable-convolution row kernel (5 taps along x).
pub const SEPCONV_ROW: &str = r#"
#pragma imcl grid(in)
#pragma imcl boundary(in, constant, 0.0)
#pragma imcl array_size(f, 5)
void conv_row(Image<float> in, Image<float> out, float* f) {
  float sum = 0.0f;
  for (int i = -2; i < 3; i++) {
    sum += in[idx + i][idy] * f[i + 2];
  }
  out[idx][idy] = sum;
}
"#;

/// Separable-convolution column kernel (5 taps along y).
pub const SEPCONV_COL: &str = r#"
#pragma imcl grid(in)
#pragma imcl boundary(in, constant, 0.0)
#pragma imcl array_size(f, 5)
void conv_col(Image<float> in, Image<float> out, float* f) {
  float sum = 0.0f;
  for (int i = -2; i < 3; i++) {
    sum += in[idx][idy + i] * f[i + 2];
  }
  out[idx][idy] = sum;
}
"#;

/// Non-separable 5×5 convolution on `uchar` pixels, clamped boundary.
pub const CONV2D: &str = r#"
#pragma imcl grid(in)
#pragma imcl boundary(in, clamped)
#pragma imcl array_size(f, 25)
void conv2d(Image<uchar> in, Image<uchar> out, float* f) {
  float sum = 0.0f;
  for (int i = -2; i < 3; i++) {
    for (int j = -2; j < 3; j++) {
      sum += (float)(in[idx + i][idy + j]) * f[(j + 2) * 5 + i + 2];
    }
  }
  out[idx][idy] = (uchar)(clamp(sum, 0.0f, 255.0f));
}
"#;

/// Sobel gradients (3×3), the first kernel of Harris corner detection.
pub const SOBEL: &str = r#"
#pragma imcl grid(in)
#pragma imcl boundary(in, clamped)
void sobel(Image<float> in, Image<float> dx, Image<float> dy) {
  float gx = in[idx + 1][idy - 1] + 2.0f * in[idx + 1][idy] + in[idx + 1][idy + 1]
           - in[idx - 1][idy - 1] - 2.0f * in[idx - 1][idy] - in[idx - 1][idy + 1];
  float gy = in[idx - 1][idy + 1] + 2.0f * in[idx][idy + 1] + in[idx + 1][idy + 1]
           - in[idx - 1][idy - 1] - 2.0f * in[idx][idy - 1] - in[idx + 1][idy - 1];
  dx[idx][idy] = gx;
  dy[idx][idy] = gy;
}
"#;

/// Harris response over a 2×2 block (paper: "a block size of 2x2").
pub const HARRIS: &str = r#"
#pragma imcl grid(dx)
#pragma imcl boundary(dx, clamped)
#pragma imcl boundary(dy, clamped)
void harris(Image<float> dx, Image<float> dy, Image<float> out) {
  float sxx = 0.0f;
  float syy = 0.0f;
  float sxy = 0.0f;
  for (int i = 0; i < 2; i++) {
    for (int j = 0; j < 2; j++) {
      float gx = dx[idx + i][idy + j];
      float gy = dy[idx + i][idy + j];
      sxx += gx * gx;
      syy += gy * gy;
      sxy += gx * gy;
    }
  }
  out[idx][idy] = sxx * syy - sxy * sxy - 0.04f * (sxx + syy) * (sxx + syy);
}
"#;

/// One kernel of a benchmark.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelDef {
    /// Kernel id used in reports/artifacts (e.g. "sepconv_row").
    pub id: &'static str,
    /// Display name matching the paper's tables ("R", "C", ...).
    pub table_name: &'static str,
    pub source: &'static str,
}

/// One of the paper's three benchmarks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Benchmark {
    pub id: &'static str,
    pub display: &'static str,
    pub kernels: &'static [KernelDef],
    /// The paper's full-size workload (grid w × h).
    pub paper_size: (usize, usize),
    pub pixel_type: ScalarType,
}

pub const SEPARABLE_CONVOLUTION: Benchmark = Benchmark {
    id: "sepconv",
    display: "Separable convolution",
    kernels: &[
        KernelDef { id: "sepconv_row", table_name: "R", source: SEPCONV_ROW },
        KernelDef { id: "sepconv_col", table_name: "C", source: SEPCONV_COL },
    ],
    paper_size: (4096, 4096),
    pixel_type: ScalarType::F32,
};

pub const NONSEP_CONVOLUTION: Benchmark = Benchmark {
    id: "conv2d",
    display: "Non-separable convolution",
    kernels: &[KernelDef { id: "conv2d", table_name: "conv2d", source: CONV2D }],
    paper_size: (8192, 8192),
    pixel_type: ScalarType::U8,
};

pub const HARRIS_CORNER: Benchmark = Benchmark {
    id: "harris",
    display: "Harris corner detection",
    kernels: &[
        KernelDef { id: "sobel", table_name: "Sobel", source: SOBEL },
        KernelDef { id: "harris", table_name: "Harris", source: HARRIS },
    ],
    paper_size: (5120, 5120),
    pixel_type: ScalarType::F32,
};

/// All benchmarks, in the paper's order.
pub const ALL: [Benchmark; 3] =
    [SEPARABLE_CONVOLUTION, NONSEP_CONVOLUTION, HARRIS_CORNER];

pub fn by_id(id: &str) -> Option<&'static Benchmark> {
    ALL.iter().find(|b| b.id == id)
}

/// Look up a servable kernel by id: the paper benchmarks first, then
/// the example-gallery kernels (blur, threshold, ...) so `imagecl
/// serve`/`stats` can exercise the full built-in set.
pub fn kernel_by_id(id: &str) -> Option<KernelDef> {
    ALL.iter()
        .flat_map(|b| b.kernels.iter())
        .find(|k| k.id == id)
        .copied()
        .or_else(|| {
            gallery::GALLERY
                .iter()
                .find(|(n, _)| *n == id)
                .map(|&(n, src)| KernelDef { id: n, table_name: n, source: src })
        })
}

/// A normalized 5-tap Gaussian-ish filter.
pub fn gauss5() -> Vec<f64> {
    let f = [1.0, 4.0, 6.0, 4.0, 1.0];
    let s: f64 = f.iter().sum();
    f.iter().map(|v| v / s).collect()
}

/// A normalized 5×5 filter (outer product of [`gauss5`]).
pub fn gauss5x5() -> Vec<f64> {
    let g = gauss5();
    let mut out = Vec::with_capacity(25);
    for y in 0..5 {
        for x in 0..5 {
            out.push(g[y] * g[x]);
        }
    }
    out
}

/// Synthetic test image: deterministic pseudo-random pixels in a realistic
/// range for the element type.
pub fn synth_image(elem: ScalarType, w: usize, h: usize, seed: u64) -> ImageBuf {
    let mut rng = Rng::new(seed);
    ImageBuf::from_fn(elem, w, h, |_x, _y| {
        if elem.is_float() {
            rng.unit() * 255.0
        } else {
            rng.below(256) as f64
        }
    })
}

/// Build the argument map for one benchmark kernel at the given grid size.
/// Inputs are synthetic; outputs are zeroed.
pub fn workload(kernel_id: &str, w: usize, h: usize, seed: u64) -> BTreeMap<String, Arg> {
    let mut args = BTreeMap::new();
    match kernel_id {
        "sepconv_row" | "sepconv_col" => {
            args.insert(
                "in".to_string(),
                Arg::Image(synth_image(ScalarType::F32, w, h, seed)),
            );
            args.insert(
                "out".to_string(),
                Arg::Image(ImageBuf::new(ScalarType::F32, w, h)),
            );
            args.insert(
                "f".to_string(),
                Arg::Array(Buffer::from_f64(ScalarType::F32, gauss5())),
            );
        }
        "conv2d" => {
            args.insert(
                "in".to_string(),
                Arg::Image(synth_image(ScalarType::U8, w, h, seed)),
            );
            args.insert(
                "out".to_string(),
                Arg::Image(ImageBuf::new(ScalarType::U8, w, h)),
            );
            args.insert(
                "f".to_string(),
                Arg::Array(Buffer::from_f64(ScalarType::F32, gauss5x5())),
            );
        }
        "sobel" => {
            args.insert(
                "in".to_string(),
                Arg::Image(synth_image(ScalarType::F32, w, h, seed)),
            );
            args.insert("dx".to_string(), Arg::Image(ImageBuf::new(ScalarType::F32, w, h)));
            args.insert("dy".to_string(), Arg::Image(ImageBuf::new(ScalarType::F32, w, h)));
        }
        "harris" => {
            args.insert(
                "dx".to_string(),
                Arg::Image(synth_image(ScalarType::F32, w, h, seed)),
            );
            args.insert(
                "dy".to_string(),
                Arg::Image(synth_image(ScalarType::F32, w, h, seed ^ 0xABCD)),
            );
            args.insert(
                "out".to_string(),
                Arg::Image(ImageBuf::new(ScalarType::F32, w, h)),
            );
        }
        other => {
            if gallery::gallery_source(other).is_some() {
                return gallery::gallery_workload(other, w, h, seed);
            }
            panic!("unknown kernel id {other:?}")
        }
    }
    args
}

/// FNV-1a checksum over an argument map's full contents (names, shapes
/// and every element's f64 bit pattern). Two executions of the same
/// workload produced bit-identical buffers iff their checksums match —
/// the serving layer's replies carry this so the chaos test can compare
/// fault-path outputs against the tree-walk oracle without shipping
/// whole images over the wire.
pub fn args_checksum(args: &BTreeMap<String, Arg>) -> u64 {
    fn eat(h: &mut u64, bytes: &[u8]) {
        for &b in bytes {
            *h ^= b as u64;
            *h = h.wrapping_mul(0x100000001b3);
        }
    }
    fn eat_u64(h: &mut u64, v: u64) {
        eat(h, &v.to_le_bytes());
    }
    let mut h: u64 = 0xcbf29ce484222325;
    for (name, arg) in args {
        eat(&mut h, name.as_bytes());
        match arg {
            Arg::Image(img) => {
                eat_u64(&mut h, img.w as u64);
                eat_u64(&mut h, img.h as u64);
                for v in &img.buf.data {
                    eat_u64(&mut h, v.to_bits());
                }
            }
            Arg::Array(buf) => {
                eat_u64(&mut h, buf.data.len() as u64);
                for v in &buf.data {
                    eat_u64(&mut h, v.to_bits());
                }
            }
            Arg::Scalar(v) => {
                let bits = match v {
                    crate::exec::Value::I(i) => *i as u64,
                    crate::exec::Value::F(f) => f.to_bits(),
                    crate::exec::Value::B(b) => *b as u64,
                };
                eat_u64(&mut h, bits);
            }
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::KernelInfo;
    use crate::imagecl::frontend;

    #[test]
    fn all_sources_compile_through_frontend() {
        for b in &ALL {
            for k in b.kernels {
                let p = frontend(k.source)
                    .unwrap_or_else(|e| panic!("{}: {e}", k.id));
                let _info = KernelInfo::analyze(p);
            }
        }
    }

    #[test]
    fn eligibilities_match_paper_tables() {
        // Table 2: sep-conv has image/local/constant rows → in is
        // image+local eligible, f constant eligible.
        let info = KernelInfo::analyze(frontend(SEPCONV_ROW).unwrap());
        assert!(info.image_mem_eligible("in"));
        assert!(info.local_mem_eligible("in"));
        assert!(info.constant_mem_eligible("f", 64 << 10));
        assert_eq!(info.unrollable_loops().len(), 1); // "Unroll loop 1"

        // Table 3: conv2d has two unrollable loops.
        let info = KernelInfo::analyze(frontend(CONV2D).unwrap());
        assert_eq!(info.unrollable_loops().len(), 2);
        assert!(info.local_mem_eligible("in"));

        // Table 4: sobel — image/local eligible input, no loops.
        let info = KernelInfo::analyze(frontend(SOBEL).unwrap());
        assert!(info.image_mem_eligible("in"));
        assert!(info.local_mem_eligible("in"));
        assert!(info.image_mem_eligible("dx"));
        assert_eq!(info.unrollable_loops().len(), 0);

        // Table 5: harris — dx & dy image/local rows, loops 1 & 2.
        let info = KernelInfo::analyze(frontend(HARRIS).unwrap());
        assert!(info.local_mem_eligible("dx"));
        assert!(info.local_mem_eligible("dy"));
        assert_eq!(info.unrollable_loops().len(), 2);
    }

    #[test]
    fn stencils_as_expected() {
        let info = KernelInfo::analyze(frontend(SEPCONV_ROW).unwrap());
        let s = info.read_stencil("in").unwrap();
        assert_eq!((s.min_dx, s.max_dx, s.min_dy, s.max_dy), (-2, 2, 0, 0));
        let info = KernelInfo::analyze(frontend(HARRIS).unwrap());
        let s = info.read_stencil("dx").unwrap();
        assert_eq!((s.min_dx, s.max_dx, s.min_dy, s.max_dy), (0, 1, 0, 1));
    }

    #[test]
    fn workloads_have_right_args() {
        let args = workload("conv2d", 16, 16, 1);
        assert!(matches!(args["in"], Arg::Image(_)));
        assert!(matches!(args["f"], Arg::Array(_)));
        let args = workload("harris", 8, 8, 1);
        assert_eq!(args.len(), 3);
    }

    #[test]
    fn gallery_kernels_are_servable_by_id() {
        let k = kernel_by_id("blur").expect("gallery fallback");
        assert_eq!(k.id, "blur");
        let args = workload("blur", 8, 8, 1);
        assert!(matches!(args["in"], Arg::Image(_)));
        assert!(kernel_by_id("sepconv_row").is_some(), "paper kernels still resolve");
        assert!(kernel_by_id("no_such_kernel").is_none());
    }

    #[test]
    fn filters_normalized() {
        assert!((gauss5().iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((gauss5x5().iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }
}
