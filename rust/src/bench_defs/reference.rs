//! Direct (scalar, obviously-correct) Rust implementations of the
//! benchmark filters. These are the *absolute* correctness oracles: the
//! candidate-equivalence sweep checks all configs against the naive
//! config, and the naive config is checked against these.

use crate::exec::ImageBuf;

/// 5-tap row convolution, constant-0 boundary.
pub fn sepconv_row(input: &ImageBuf, f: &[f64]) -> Vec<f64> {
    let (w, h) = (input.w as i64, input.h as i64);
    let mut out = vec![0.0; (w * h) as usize];
    for y in 0..h {
        for x in 0..w {
            let mut sum = 0.0;
            for i in -2..3i64 {
                let xx = x + i;
                let v = if xx >= 0 && xx < w {
                    input.get(xx as usize, y as usize)
                } else {
                    0.0
                };
                sum += v * f[(i + 2) as usize];
            }
            out[(y * w + x) as usize] = sum;
        }
    }
    out
}

/// 5-tap column convolution, constant-0 boundary.
pub fn sepconv_col(input: &ImageBuf, f: &[f64]) -> Vec<f64> {
    let (w, h) = (input.w as i64, input.h as i64);
    let mut out = vec![0.0; (w * h) as usize];
    for y in 0..h {
        for x in 0..w {
            let mut sum = 0.0;
            for i in -2..3i64 {
                let yy = y + i;
                let v = if yy >= 0 && yy < h {
                    input.get(x as usize, yy as usize)
                } else {
                    0.0
                };
                sum += v * f[(i + 2) as usize];
            }
            out[(y * w + x) as usize] = sum;
        }
    }
    out
}

/// 5×5 convolution on uchar pixels, clamped boundary; the output is
/// clamped to [0,255] and truncated like the kernel's `(uchar)` cast.
pub fn conv2d(input: &ImageBuf, f: &[f64]) -> Vec<f64> {
    let (w, h) = (input.w as i64, input.h as i64);
    let mut out = vec![0.0; (w * h) as usize];
    for y in 0..h {
        for x in 0..w {
            let mut sum = 0.0;
            for i in -2..3i64 {
                for j in -2..3i64 {
                    let xx = (x + i).clamp(0, w - 1);
                    let yy = (y + j).clamp(0, h - 1);
                    sum += input.get(xx as usize, yy as usize)
                        * f[((j + 2) * 5 + i + 2) as usize];
                }
            }
            out[(y * w + x) as usize] = (sum.clamp(0.0, 255.0) as i64 & 0xFF) as f64;
        }
    }
    out
}

/// 3×3 Sobel gradients, clamped boundary. Returns (dx, dy).
pub fn sobel(input: &ImageBuf) -> (Vec<f64>, Vec<f64>) {
    let (w, h) = (input.w as i64, input.h as i64);
    let at = |x: i64, y: i64| {
        input.get(x.clamp(0, w - 1) as usize, y.clamp(0, h - 1) as usize)
    };
    let mut dx = vec![0.0; (w * h) as usize];
    let mut dy = vec![0.0; (w * h) as usize];
    for y in 0..h {
        for x in 0..w {
            let gx = at(x + 1, y - 1) + 2.0 * at(x + 1, y) + at(x + 1, y + 1)
                - at(x - 1, y - 1)
                - 2.0 * at(x - 1, y)
                - at(x - 1, y + 1);
            let gy = at(x - 1, y + 1) + 2.0 * at(x, y + 1) + at(x + 1, y + 1)
                - at(x - 1, y - 1)
                - 2.0 * at(x, y - 1)
                - at(x + 1, y - 1);
            dx[(y * w + x) as usize] = gx;
            dy[(y * w + x) as usize] = gy;
        }
    }
    (dx, dy)
}

/// Harris response over a 2×2 block, k = 0.04, clamped boundary.
pub fn harris(dx: &ImageBuf, dy: &ImageBuf) -> Vec<f64> {
    let (w, h) = (dx.w as i64, dx.h as i64);
    let atx = |x: i64, y: i64| dx.get(x.clamp(0, w - 1) as usize, y.clamp(0, h - 1) as usize);
    let aty = |x: i64, y: i64| dy.get(x.clamp(0, w - 1) as usize, y.clamp(0, h - 1) as usize);
    let mut out = vec![0.0; (w * h) as usize];
    for y in 0..h {
        for x in 0..w {
            let (mut sxx, mut syy, mut sxy) = (0.0, 0.0, 0.0);
            for i in 0..2i64 {
                for j in 0..2i64 {
                    let gx = atx(x + i, y + j);
                    let gy = aty(x + i, y + j);
                    sxx += gx * gx;
                    syy += gy * gy;
                    sxy += gx * gy;
                }
            }
            out[(y * w + x) as usize] =
                sxx * syy - sxy * sxy - 0.04 * (sxx + syy) * (sxx + syy);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_defs::{gauss5, gauss5x5, synth_image};
    use crate::imagecl::ScalarType;

    #[test]
    fn row_then_col_equals_outer_product_2d() {
        // Separability sanity: row∘col with g == 2-D conv with g⊗g (away
        // from borders, where the boundary handling differs).
        let img = synth_image(ScalarType::F32, 24, 20, 3);
        let g = gauss5();
        let row = sepconv_row(&img, &g);
        let mut mid = ImageBuf::new(ScalarType::F32, img.w, img.h);
        for y in 0..img.h {
            for x in 0..img.w {
                mid.set(x, y, row[y * img.w + x]);
            }
        }
        let two_pass = sepconv_col(&mid, &g);

        let g2 = gauss5x5();
        for y in 4..img.h - 4 {
            for x in 4..img.w - 4 {
                let mut direct = 0.0;
                for j in -2..3i64 {
                    for i in -2..3i64 {
                        direct += img.get((x as i64 + i) as usize, (y as i64 + j) as usize)
                            * g2[((j + 2) * 5 + i + 2) as usize];
                    }
                }
                let tp = two_pass[y * img.w + x];
                assert!((tp - direct).abs() < 1e-4, "({x},{y}): {tp} vs {direct}");
            }
        }
    }

    #[test]
    fn sobel_flat_image_zero_gradient() {
        let img = ImageBuf::from_fn(ScalarType::F32, 8, 8, |_, _| 5.0);
        let (dx, dy) = sobel(&img);
        assert!(dx.iter().all(|&v| v == 0.0));
        assert!(dy.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn sobel_vertical_edge() {
        // Left half 0, right half 10 → strong dx at the edge, dy == 0.
        let img = ImageBuf::from_fn(ScalarType::F32, 8, 8, |x, _| if x < 4 { 0.0 } else { 10.0 });
        let (dx, dy) = sobel(&img);
        assert!(dx[3 + 8 * 4] > 0.0);
        assert!(dy.iter().all(|&v| v.abs() < 1e-12));
    }

    #[test]
    fn harris_corner_stronger_than_edge() {
        // Synthetic gradients: a "corner" window contains gradients in two
        // different directions (dx at one pixel, dy at another); an "edge"
        // window has gradient in a single direction. Harris response must
        // rank corner > edge.
        let mut dximg = ImageBuf::new(ScalarType::F32, 8, 8);
        let mut dyimg = ImageBuf::new(ScalarType::F32, 8, 8);
        dximg.set(2, 2, 10.0);
        dyimg.set(3, 3, 10.0); // window at (2,2) sees both → corner
        dximg.set(5, 5, 10.0); // edge at (5,5)
        let r = harris(&dximg, &dyimg);
        assert!(r[2 * 8 + 2] > r[5 * 8 + 5], "{} vs {}", r[2 * 8 + 2], r[5 * 8 + 5]);
    }

    #[test]
    fn conv2d_identity_filter() {
        let img = synth_image(ScalarType::U8, 10, 10, 5);
        let mut ident = vec![0.0; 25];
        ident[12] = 1.0; // center tap
        let out = conv2d(&img, &ident);
        for y in 0..10 {
            for x in 0..10 {
                assert_eq!(out[y * 10 + x], img.get(x, y));
            }
        }
    }
}
