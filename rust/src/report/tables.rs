//! Renderers for the paper's result tables (Tables 2–5) and the
//! Figure 6 slowdown series, in the paper's own row/column layout.

use std::fmt::Write as _;

use crate::analysis::KernelInfo;
use crate::transform::TuningConfig;

/// Render one "configurations found by the auto-tuner" table (paper
/// Tables 2–5): one column per (device, kernel) pair.
///
/// `columns`: (header, tuned config); `info` supplies the array and loop
/// inventory so rows match the paper's (image/local per array, unroll per
/// loop).
pub fn render_config_table(
    title: &str,
    info: &KernelInfo,
    columns: &[(String, TuningConfig)],
) -> String {
    let mut arrays: Vec<String> = info
        .prog
        .kernel
        .params
        .iter()
        .filter(|p| p.ty.is_buffer())
        .map(|p| p.name.clone())
        .collect();
    arrays.sort();
    let img_arrays: Vec<&String> = arrays
        .iter()
        .filter(|a| info.image_mem_eligible(a))
        .collect();
    let loc_arrays: Vec<&String> = arrays
        .iter()
        .filter(|a| info.local_mem_eligible(a))
        .collect();
    let const_arrays: Vec<&String> = arrays
        .iter()
        .filter(|a| info.constant_mem_eligible(a, 64 << 10))
        .collect();
    let loops = info.unrollable_loops();

    let mut rows: Vec<(String, Vec<String>)> = Vec::new();
    let get = |f: &dyn Fn(&TuningConfig) -> String| -> Vec<String> {
        columns.iter().map(|(_, c)| f(c)).collect()
    };
    rows.push(("Px/thread X".into(), get(&|c| c.coarsen[0].to_string())));
    rows.push(("Px/thread Y".into(), get(&|c| c.coarsen[1].to_string())));
    rows.push(("Work-group X".into(), get(&|c| c.wg[0].to_string())));
    rows.push(("Work-group Y".into(), get(&|c| c.wg[1].to_string())));
    rows.push((
        "Interleaved".into(),
        get(&|c| (c.interleaved as u8).to_string()),
    ));
    for a in &img_arrays {
        rows.push((
            format!("Image mem {a}"),
            get(&|c| (c.uses_image_mem(a) as u8).to_string()),
        ));
    }
    for a in &loc_arrays {
        rows.push((
            format!("Local mem {a}"),
            get(&|c| (c.uses_local_mem(a) as u8).to_string()),
        ));
    }
    for a in &const_arrays {
        rows.push((
            format!("Constant mem {a}"),
            get(&|c| (c.uses_constant_mem(a) as u8).to_string()),
        ));
    }
    for l in &loops {
        let id = l.id;
        rows.push((
            format!("Unroll loop {id}"),
            get(&|c| ((c.unroll_factor(id) != 1) as u8).to_string()),
        ));
    }

    let label_w = rows
        .iter()
        .map(|(l, _)| l.len())
        .chain(["Device".len()].into_iter())
        .max()
        .unwrap();
    let col_ws: Vec<usize> = columns
        .iter()
        .enumerate()
        .map(|(i, (h, _))| {
            rows.iter()
                .map(|(_, vals)| vals[i].len())
                .chain([h.len()].into_iter())
                .max()
                .unwrap()
        })
        .collect();

    let mut out = String::new();
    let _ = writeln!(out, "{title}");
    let _ = write!(out, "{:label_w$}", "Device");
    for ((h, _), w) in columns.iter().zip(&col_ws) {
        let _ = write!(out, " | {h:>w$}");
    }
    let _ = writeln!(out);
    let total = label_w + col_ws.iter().map(|w| w + 3).sum::<usize>();
    let _ = writeln!(out, "{}", "-".repeat(total));
    for (label, vals) in rows {
        let _ = write!(out, "{label:label_w$}");
        for (v, w) in vals.iter().zip(&col_ws) {
            let _ = write!(out, " | {v:>w$}");
        }
        let _ = writeln!(out);
    }
    out
}

/// Render one Figure 6 panel: slowdown of each alternative vs ImageCL
/// per device (values > 1 mean ImageCL is faster).
pub fn render_fig6(
    title: &str,
    devices: &[&str],
    series: &[(&str, Vec<f64>)],
) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "{title}");
    let _ = writeln!(out, "(slowdown vs ImageCL; >1 = ImageCL faster)");
    let label_w = series
        .iter()
        .map(|(n, _)| n.len())
        .chain(["ImageCL".len()].into_iter())
        .max()
        .unwrap();
    let _ = write!(out, "{:label_w$}", "");
    for d in devices {
        let _ = write!(out, " | {d:>9}");
    }
    let _ = writeln!(out);
    let _ = writeln!(out, "{}", "-".repeat(label_w + devices.len() * 12));
    let _ = write!(out, "{:label_w$}", "ImageCL");
    for _ in devices {
        let _ = write!(out, " | {:>9}", "1.00x");
    }
    let _ = writeln!(out);
    for (name, vals) in series {
        let _ = write!(out, "{name:label_w$}");
        for v in vals {
            let _ = write!(out, " | {:>8.2}x", v);
        }
        let _ = writeln!(out);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_defs::SEPCONV_ROW;
    use crate::imagecl::frontend;

    #[test]
    fn config_table_rows_match_paper_layout() {
        let info = KernelInfo::analyze(frontend(SEPCONV_ROW).unwrap());
        let cfg = TuningConfig::parse(
            "wg=64x4 px=4x1 map=interleaved lmem=in cmem=f unroll=1:0",
        )
        .unwrap();
        let t = render_config_table(
            "Table 2: sep-conv row",
            &info,
            &[("AMD 7970".to_string(), cfg)],
        );
        assert!(t.contains("Px/thread X"), "{t}");
        assert!(t.contains("Work-group Y"), "{t}");
        assert!(t.contains("Interleaved"), "{t}");
        assert!(t.contains("Image mem in"), "{t}");
        assert!(t.contains("Local mem in"), "{t}");
        assert!(t.contains("Constant mem f"), "{t}");
        assert!(t.contains("Unroll loop 1"), "{t}");
        // Values line up: px X = 4, wg X = 64, interleaved 1.
        for (row, val) in [
            ("Px/thread X", "4"),
            ("Work-group X", "64"),
            ("Interleaved", "1"),
            ("Local mem in", "1"),
            ("Image mem in", "0"),
        ] {
            let line = t.lines().find(|l| l.starts_with(row)).unwrap();
            assert!(line.ends_with(val), "{line}");
        }
    }

    #[test]
    fn fig6_render() {
        let s = render_fig6(
            "Separable convolution",
            &["AMD 7970", "K40"],
            &[("Halide", vec![1.5, 2.0]), ("OpenCV", vec![0.9, 1.2])],
        );
        assert!(s.contains("ImageCL"));
        assert!(s.contains("1.50x"));
        assert!(s.contains("0.90x"));
    }
}
