//! Reporting: the timing rig used by the benches (criterion is not
//! available offline) and renderers that print the paper's tables and
//! figure series.

pub mod rig;
pub mod tables;

pub use rig::{time_best_of, Ms};
pub use tables::{render_config_table, render_fig6};

use std::path::PathBuf;

/// Write a report file under `target/bench_reports/` (best effort) and
/// echo it to stdout.
pub fn emit_report(name: &str, content: &str) {
    println!("{content}");
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("target")
        .join("bench_reports");
    if std::fs::create_dir_all(&dir).is_ok() {
        let _ = std::fs::write(dir.join(name), content);
    }
}
