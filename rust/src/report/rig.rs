//! Minimal benchmarking rig (offline substitute for criterion): warmup +
//! best-of-N wall-clock timing with a human-readable duration wrapper.

use std::fmt;
use std::time::{Duration, Instant};

/// Milliseconds with 3 decimals for report rows.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd)]
pub struct Ms(pub f64);

impl From<Duration> for Ms {
    fn from(d: Duration) -> Ms {
        Ms(d.as_secs_f64() * 1e3)
    }
}

impl From<f64> for Ms {
    /// From seconds.
    fn from(s: f64) -> Ms {
        Ms(s * 1e3)
    }
}

impl fmt::Display for Ms {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0 >= 100.0 {
            write!(f, "{:.1} ms", self.0)
        } else if self.0 >= 1.0 {
            write!(f, "{:.3} ms", self.0)
        } else {
            write!(f, "{:.1} µs", self.0 * 1e3)
        }
    }
}

/// Run `f` `warmup` times untimed, then `reps` times timed; return the
/// best (minimum) duration — the standard low-noise point estimate.
pub fn time_best_of(warmup: usize, reps: usize, mut f: impl FnMut()) -> Duration {
    for _ in 0..warmup {
        f();
    }
    let mut best = Duration::MAX;
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed());
    }
    best
}

/// Throughput helper: items per second given a duration.
pub fn per_second(items: usize, d: Duration) -> f64 {
    items as f64 / d.as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_of_runs_expected_times() {
        let mut n = 0;
        let _ = time_best_of(2, 5, || n += 1);
        assert_eq!(n, 7);
    }

    #[test]
    fn ms_formatting() {
        assert_eq!(Ms(123.456).to_string(), "123.5 ms");
        assert_eq!(Ms(1.5).to_string(), "1.500 ms");
        assert_eq!(Ms(0.0123).to_string(), "12.3 µs");
    }
}
