//! Kernel-plan execution backend.
//!
//! Executes candidate implementations ([`crate::transform::KernelPlan`])
//! under full OpenCL NDRange emulation — the correctness oracle for every
//! transformation on this GPU-less testbed (DESIGN.md §2).

pub mod buffer;
pub mod compiled;
pub mod machine;

pub use buffer::{Arg, Buffer, ImageBuf, Value};
pub use machine::{execute, resolve_scalars, ExecError, PreparedKernel};
