//! Kernel-plan execution backend.
//!
//! Executes candidate implementations ([`crate::transform::KernelPlan`])
//! under full OpenCL NDRange emulation — the correctness oracle for every
//! transformation on this GPU-less testbed (DESIGN.md §2).
//!
//! Two engines implement the same semantics:
//!
//! * **Bytecode VM** ([`vm`]) — plans lower through the slot-resolved IR
//!   of [`compiled`] into flat, register-based bytecode (typed i64/f64
//!   register files, resolved buffer indices) and execute work-groups in
//!   parallel when the write-set analysis proved them independent. This
//!   is the default path: `PreparedKernel::run`, the serving workers and
//!   tuner measurements all go through it.
//! * **Tree-walker** ([`machine`]'s `Machine`) — the original serial
//!   interpreter, retained deliberately as the *differential oracle*: the
//!   VM must produce bit-identical output (`tests/vm_differential.rs`
//!   sweeps every gallery kernel × config grid), and the rare plan the VM
//!   cannot type statically falls back to it. Force it with
//!   `Engine::TreeWalk` or `IMAGECL_EXEC=tree`.
//!
//! `imagecl bench` / `benches/exec.rs` ([`bench`]) measure one engine
//! against the other and write `BENCH_exec.json`.

pub mod bench;
pub mod buffer;
pub mod compiled;
pub mod machine;
pub mod vm;

pub use buffer::{Arg, Buffer, ImageBuf, Value};
pub use machine::{
    execute, execute_with, resolve_scalars, Engine, ExecError, PreparedKernel,
};
pub use vm::VmProgram;
