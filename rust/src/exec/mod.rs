//! Kernel-plan execution backend.
//!
//! Executes candidate implementations ([`crate::transform::KernelPlan`])
//! under full OpenCL NDRange emulation — the correctness oracle for every
//! transformation on this GPU-less testbed (DESIGN.md §2).
//!
//! Two engines implement the same semantics:
//!
//! * **Bytecode VM** ([`vm`]) — plans lower through the slot-resolved IR
//!   of [`compiled`] into flat, register-based bytecode (typed i64/f64
//!   register files, resolved buffer indices), run through the
//!   [`opt`]imizer pipeline (copy/constant propagation, jump folding,
//!   dead-move elimination, `IMulAdd` re-fusion, DCE), and execute
//!   work-groups — or, for barrier-free plans with few large groups,
//!   work-item rows — in parallel when the write-set analysis proved
//!   them independent. Rows whose control flow [`opt::specialize`] can
//!   decide from the launch geometry additionally run through the
//!   batched lane interpreter (SIMD-shaped, interior/border split). This
//!   is the default path: `PreparedKernel::run`, the serving workers and
//!   tuner measurements all go through it.
//! * **Tree-walker** ([`machine`]'s `Machine`) — the original serial
//!   interpreter, retained deliberately as the *differential oracle*: the
//!   VM must produce bit-identical output (`tests/vm_differential.rs`
//!   sweeps every gallery kernel × config grid × engine variant), and
//!   the rare plan the VM cannot type statically falls back to it. Force
//!   an engine with `Engine::TreeWalk` / `Engine::VmScalar` /
//!   `Engine::VmUnopt`, or `IMAGECL_EXEC=tree|vm|vm-scalar|vm-unopt`.
//!
//! `imagecl bench` / `benches/exec.rs` ([`bench`]) measure the engines
//! against each other and write `BENCH_exec.json` (with a regression
//! gate: the optimized VM must not lose to the unoptimized VM on blur).

pub mod analyze;
pub mod bench;
pub mod buffer;
pub mod compiled;
pub mod machine;
pub mod opt;
pub mod profile;
pub mod vm;

pub use buffer::{Arg, Buffer, ImageBuf, Value};
pub use machine::{
    execute, execute_with, resolve_scalars, Engine, ExecError, PreparedKernel,
};
pub use vm::VmProgram;
