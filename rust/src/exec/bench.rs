//! The execution-engine benchmark behind `imagecl bench` and
//! `benches/exec.rs`: run the gallery kernels through both engines — the
//! bytecode VM and the tree-walking oracle — verify the outputs are
//! bit-identical, and report throughput (pixels/sec) plus the VM's
//! speedup as `BENCH_exec.json`.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::analysis::KernelInfo;
use crate::bench_defs::gallery::{gallery_workload, GALLERY};
use crate::imagecl::frontend;
use crate::transform::{lower, TuningConfig};

use super::buffer::Arg;
use super::machine::{Engine, PreparedKernel};

/// Benchmark options.
#[derive(Debug, Clone)]
pub struct BenchOpts {
    /// Grid (and image) size, `n`×`n`.
    pub size: usize,
    /// Timed repetitions per engine (best-of).
    pub iters: usize,
    /// Kernels to run (gallery names); empty = the whole gallery.
    pub kernels: Vec<String>,
    /// Output path for the JSON report; `None` = repo-root
    /// `BENCH_exec.json`.
    pub out: Option<PathBuf>,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { size: 1024, iters: 3, kernels: Vec::new(), out: None }
    }
}

impl BenchOpts {
    /// CI smoke configuration: small grid, single repetition — exercises
    /// both engines and the divergence check without burning minutes.
    pub fn smoke() -> BenchOpts {
        BenchOpts { size: 128, iters: 1, ..Default::default() }
    }
}

/// One kernel's measurements.
#[derive(Debug, Clone)]
pub struct KernelBench {
    pub name: String,
    pub pixels: usize,
    /// Best-of-`iters` wall time per engine, seconds.
    pub tree_secs: f64,
    pub vm_secs: f64,
    /// Work-groups proven independent → VM ran groups in parallel.
    pub parallel: bool,
    /// VM output was bit-identical to the tree-walker's.
    pub identical: bool,
}

impl KernelBench {
    pub fn tree_pix_per_sec(&self) -> f64 {
        self.pixels as f64 / self.tree_secs
    }

    pub fn vm_pix_per_sec(&self) -> f64 {
        self.pixels as f64 / self.vm_secs
    }

    pub fn speedup(&self) -> f64 {
        self.tree_secs / self.vm_secs
    }
}

/// The full benchmark report.
#[derive(Debug, Clone)]
pub struct BenchReport {
    pub size: usize,
    pub threads: usize,
    pub kernels: Vec<KernelBench>,
}

impl BenchReport {
    pub fn all_identical(&self) -> bool {
        self.kernels.iter().all(|k| k.identical)
    }

    /// The headline number: the blur kernel's VM speedup over the
    /// tree-walker (acceptance: ≥ 5× at 1024² on a multi-core box).
    pub fn blur_speedup(&self) -> Option<f64> {
        self.kernels.iter().find(|k| k.name == "blur").map(KernelBench::speedup)
    }

    /// Hand-rolled JSON (the offline crate set has no serde).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{{");
        let _ = writeln!(s, "  \"size\": [{}, {}],", self.size, self.size);
        let _ = writeln!(s, "  \"threads\": {},", self.threads);
        let blur = self
            .blur_speedup()
            .map(|v| format!("{v:.3}"))
            .unwrap_or_else(|| "null".to_string());
        let _ = writeln!(s, "  \"blur_speedup\": {blur},");
        let _ = writeln!(s, "  \"all_identical\": {},", self.all_identical());
        let _ = writeln!(s, "  \"kernels\": [");
        for (i, k) in self.kernels.iter().enumerate() {
            let _ = writeln!(s, "    {{");
            let _ = writeln!(s, "      \"name\": \"{}\",", k.name);
            let _ = writeln!(s, "      \"pixels\": {},", k.pixels);
            let _ = writeln!(s, "      \"tree_secs\": {:.6},", k.tree_secs);
            let _ = writeln!(s, "      \"vm_secs\": {:.6},", k.vm_secs);
            let _ = writeln!(s, "      \"tree_pix_per_sec\": {:.0},", k.tree_pix_per_sec());
            let _ = writeln!(s, "      \"vm_pix_per_sec\": {:.0},", k.vm_pix_per_sec());
            let _ = writeln!(s, "      \"speedup\": {:.3},", k.speedup());
            let _ = writeln!(s, "      \"parallel\": {},", k.parallel);
            let _ = writeln!(s, "      \"identical\": {}", k.identical);
            let _ = writeln!(s, "    }}{}", if i + 1 < self.kernels.len() { "," } else { "" });
        }
        let _ = writeln!(s, "  ]");
        let _ = writeln!(s, "}}");
        s
    }

    /// Human-readable table.
    pub fn render(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "execution-engine benchmark — {0}×{0}, {1} thread(s)",
            self.size, self.threads
        );
        let _ = writeln!(
            s,
            "{:<12} {:>14} {:>14} {:>9}  {:>8}  {}",
            "kernel", "tree (Mpix/s)", "VM (Mpix/s)", "speedup", "parallel", "identical"
        );
        for k in &self.kernels {
            let _ = writeln!(
                s,
                "{:<12} {:>14.2} {:>14.2} {:>8.2}x  {:>8}  {}",
                k.name,
                k.tree_pix_per_sec() / 1e6,
                k.vm_pix_per_sec() / 1e6,
                k.speedup(),
                if k.parallel { "yes" } else { "no" },
                if k.identical { "yes" } else { "DIVERGED" }
            );
        }
        s
    }
}

/// Default report path: the repository root's `BENCH_exec.json`.
pub fn default_report_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_exec.json")
}

/// Extract every image/array payload for the bit-identity check.
fn payloads(args: &BTreeMap<String, Arg>) -> Vec<(String, Vec<u64>)> {
    args.iter()
        .filter_map(|(name, a)| {
            let data = match a {
                Arg::Image(img) => &img.buf.data,
                Arg::Array(b) => &b.data,
                Arg::Scalar(_) => return None,
            };
            Some((name.clone(), data.iter().map(|v| v.to_bits()).collect()))
        })
        .collect()
}

/// Run the benchmark. Unknown kernel names are an error; divergence is
/// reported, not fatal (callers decide — the CLI exits non-zero).
pub fn run(opts: &BenchOpts) -> Result<BenchReport, String> {
    let n = opts.size;
    let names: Vec<&str> = if opts.kernels.is_empty() {
        GALLERY.iter().map(|(name, _)| *name).collect()
    } else {
        opts.kernels.iter().map(String::as_str).collect()
    };
    let mut kernels = Vec::new();
    for name in names {
        let Some(src) = crate::bench_defs::gallery::gallery_source(name) else {
            return Err(format!(
                "unknown gallery kernel {name:?} (known: {})",
                GALLERY.map(|(n, _)| n).join(", ")
            ));
        };
        let info = KernelInfo::analyze(frontend(src).map_err(|e| e.to_string())?);
        let plan = lower(&info, &TuningConfig::default()).map_err(|e| e.to_string())?;
        let args = gallery_workload(name, n, n, 42);
        let prepared =
            PreparedKernel::prepare(&plan, &args, (n, n)).map_err(|e| e.to_string())?;

        let time_engine = |engine: Engine| -> Result<(f64, Vec<(String, Vec<u64>)>), String> {
            let mut best = f64::INFINITY;
            let mut out = Vec::new();
            for _ in 0..opts.iters.max(1) {
                let mut a = gallery_workload(name, n, n, 42);
                let t0 = Instant::now();
                prepared
                    .run_with(&mut a, engine)
                    .map_err(|e| format!("{name} on {engine:?}: {e}"))?;
                let dt = t0.elapsed().as_secs_f64();
                if dt < best {
                    best = dt;
                }
                out = payloads(&a);
            }
            Ok((best, out))
        };

        let (tree_secs, tree_out) = time_engine(Engine::TreeWalk)?;
        let (vm_secs, vm_out) = time_engine(Engine::Vm)?;
        kernels.push(KernelBench {
            name: name.to_string(),
            pixels: n * n,
            tree_secs,
            vm_secs,
            parallel: plan.parallel_groups,
            identical: tree_out == vm_out,
        });
    }
    Ok(BenchReport {
        size: n,
        threads: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1),
        kernels,
    })
}

/// Run, print, and persist the report; `Err` on engine divergence (the
/// differential guarantee is part of the benchmark's contract).
pub fn run_and_write(opts: &BenchOpts) -> Result<BenchReport, String> {
    let report = run(opts)?;
    print!("{}", report.render());
    let path = opts.out.clone().unwrap_or_else(default_report_path);
    write_report(&report, &path)?;
    println!("wrote {}", path.display());
    if !report.all_identical() {
        return Err("VM and tree-walker outputs diverged (see report)".to_string());
    }
    Ok(report)
}

fn write_report(report: &BenchReport, path: &Path) -> Result<(), String> {
    std::fs::write(path, report.to_json())
        .map_err(|e| format!("cannot write {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_bench_runs_and_matches() {
        let opts = BenchOpts {
            size: 33,
            iters: 1,
            kernels: vec!["blur".to_string(), "blend".to_string()],
            out: None,
        };
        let report = run(&opts).unwrap();
        assert_eq!(report.kernels.len(), 2);
        assert!(report.all_identical(), "{}", report.render());
        assert!(report.blur_speedup().is_some());
        let json = report.to_json();
        assert!(json.contains("\"blur\""), "{json}");
        assert!(json.contains("\"all_identical\": true"), "{json}");
    }

    #[test]
    fn unknown_kernel_is_error() {
        let opts = BenchOpts {
            kernels: vec!["nope".to_string()],
            ..BenchOpts::smoke()
        };
        assert!(run(&opts).is_err());
    }
}
