//! The execution-engine benchmark behind `imagecl bench` and
//! `benches/exec.rs`: run the gallery kernels through the engine ladder
//! — the tree-walking oracle, the unoptimized VM (the PR-3 baseline),
//! the optimized scalar VM, and the optimized+batched VM — verify every
//! VM variant's output is bit-identical to the oracle, and report
//! per-engine throughput (pixels/sec) plus the speedups as
//! `BENCH_exec.json`. [`run_and_write`] additionally enforces the
//! regression gate: on the blur workload the optimized VM must not lose
//! to the unoptimized VM (within timer-noise slack) — CI runs this via
//! `imagecl bench --smoke`.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::analysis::KernelInfo;
use crate::bench_defs::gallery::{gallery_workload, GALLERY};
use crate::imagecl::frontend;
use crate::transform::{lower, TuningConfig};

use super::buffer::Arg;
use super::machine::{Engine, PreparedKernel};

/// Benchmark options.
#[derive(Debug, Clone)]
pub struct BenchOpts {
    /// Grid (and image) size, `n`×`n`.
    pub size: usize,
    /// Timed repetitions per engine (best-of).
    pub iters: usize,
    /// Kernels to run (gallery names); empty = the whole gallery.
    pub kernels: Vec<String>,
    /// Output path for the JSON report; `None` = repo-root
    /// `BENCH_exec.json`.
    pub out: Option<PathBuf>,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { size: 1024, iters: 3, kernels: Vec::new(), out: None }
    }
}

impl BenchOpts {
    /// CI smoke configuration: small grid, two repetitions — exercises
    /// every engine, the divergence check and the optimizer regression
    /// gate without burning minutes (best-of-2 keeps the gate off timer
    /// noise).
    pub fn smoke() -> BenchOpts {
        BenchOpts { size: 128, iters: 2, ..Default::default() }
    }
}

/// One kernel's measurements across the engine ladder.
#[derive(Debug, Clone)]
pub struct KernelBench {
    pub name: String,
    pub pixels: usize,
    /// Best-of-`iters` wall time per engine, seconds.
    pub tree_secs: f64,
    /// Unoptimized, unbatched VM — the PR-3 baseline.
    pub vm_unopt_secs: f64,
    /// Optimized VM, scalar loop (isolates the optimizer pipeline).
    pub vm_scalar_secs: f64,
    /// Optimized VM with batched row interpretation (the full path).
    pub vm_secs: f64,
    /// Work-groups proven independent → VM ran groups in parallel (and
    /// rows batched where specialization succeeded).
    pub parallel: bool,
    /// Every VM variant's output was bit-identical to the tree-walker's.
    pub identical: bool,
}

impl KernelBench {
    pub fn tree_pix_per_sec(&self) -> f64 {
        self.pixels as f64 / self.tree_secs
    }

    pub fn vm_pix_per_sec(&self) -> f64 {
        self.pixels as f64 / self.vm_secs
    }

    pub fn vm_unopt_pix_per_sec(&self) -> f64 {
        self.pixels as f64 / self.vm_unopt_secs
    }

    pub fn vm_scalar_pix_per_sec(&self) -> f64 {
        self.pixels as f64 / self.vm_scalar_secs
    }

    /// Full VM vs the oracle.
    pub fn speedup(&self) -> f64 {
        self.tree_secs / self.vm_secs
    }

    /// Optimizer + batching vs the PR-3 VM (the acceptance headline).
    pub fn opt_speedup(&self) -> f64 {
        self.vm_unopt_secs / self.vm_secs
    }
}

/// The Harris pipeline as an end-to-end fusion benchmark: the staged
/// two-kernel form (Sobel materializes `dx`/`dy`, Harris consumes them)
/// against the single fused kernel in each legal [`FuseMode`], all on
/// the optimized VM. The headline pipeline measurement of
/// `BENCH_exec.json`.
#[derive(Debug, Clone)]
pub struct HarrisFused {
    pub pixels: usize,
    /// Best-of end-to-end staged time (both kernels, optimized VM).
    pub staged_secs: f64,
    /// Best-of fused time, recompute-in-register mode.
    pub inline_secs: f64,
    /// Best-of fused time, local-stage mode (`None` when illegal).
    pub lstage_secs: Option<f64>,
    /// Intermediate-image bytes the fused forms never materialize.
    pub intermediate_bytes: usize,
    /// Every fused output was bit-identical to the staged output.
    pub identical: bool,
}

impl HarrisFused {
    /// The faster fused mode's time.
    pub fn best_fused_secs(&self) -> f64 {
        match self.lstage_secs {
            Some(l) => self.inline_secs.min(l),
            None => self.inline_secs,
        }
    }

    pub fn best_mode(&self) -> &'static str {
        match self.lstage_secs {
            Some(l) if l < self.inline_secs => "lstage",
            _ => "inline",
        }
    }

    /// Fused-vs-staged end-to-end speedup (the fusion headline).
    pub fn speedup(&self) -> f64 {
        self.staged_secs / self.best_fused_secs()
    }

    /// End-to-end pipeline throughput of the best fused form.
    pub fn frames_per_sec(&self) -> f64 {
        1.0 / self.best_fused_secs()
    }
}

/// The full benchmark report.
#[derive(Debug, Clone)]
pub struct BenchReport {
    pub size: usize,
    pub threads: usize,
    pub kernels: Vec<KernelBench>,
    /// Present on full-gallery runs: the fused Harris pipeline section.
    pub harris: Option<HarrisFused>,
}

impl BenchReport {
    pub fn all_identical(&self) -> bool {
        self.kernels.iter().all(|k| k.identical)
    }

    /// The headline number: the blur kernel's VM speedup over the
    /// tree-walker (acceptance: ≥ 5× at 1024² on a multi-core box).
    pub fn blur_speedup(&self) -> Option<f64> {
        self.kernels.iter().find(|k| k.name == "blur").map(KernelBench::speedup)
    }

    /// Optimizer + batching speedup over the PR-3 VM on blur (the PR-5
    /// acceptance headline; ≥ 1.5× expected at 1024²).
    pub fn blur_opt_speedup(&self) -> Option<f64> {
        self.kernels
            .iter()
            .find(|k| k.name == "blur")
            .map(KernelBench::opt_speedup)
    }

    /// Fused-vs-staged Harris speedup, when the section ran.
    pub fn harris_fused_speedup(&self) -> Option<f64> {
        self.harris.as_ref().map(HarrisFused::speedup)
    }

    /// The fusion CI gate: `Err` when the best fused Harris form lost to
    /// the staged pipeline (with slack for timer noise — fusion must
    /// never be a regression, or the tuner's no-fuse option would always
    /// win and the pass would be dead weight).
    pub fn check_fused_regression(&self) -> Result<(), String> {
        const SLACK: f64 = 1.25;
        let Some(h) = &self.harris else {
            return Ok(()); // section not in this run's kernel set
        };
        if !h.identical {
            return Err(
                "fusion gate: fused Harris output diverged from the staged pipeline"
                    .to_string(),
            );
        }
        if h.best_fused_secs() > h.staged_secs * SLACK {
            return Err(format!(
                "fusion gate: best fused Harris ({:.3} ms, {}) is slower than the \
                 staged pipeline ({:.3} ms) ({:.2}x, allowed slack {SLACK}x)",
                h.best_fused_secs() * 1e3,
                h.best_mode(),
                h.staged_secs * 1e3,
                h.speedup(),
            ));
        }
        Ok(())
    }

    /// The CI regression gate: `Err` when the optimized+batched VM lost
    /// to the unoptimized VM on the blur workload (with slack for timer
    /// noise on the smoke grid).
    pub fn check_opt_regression(&self) -> Result<(), String> {
        const SLACK: f64 = 1.25;
        let Some(b) = self.kernels.iter().find(|k| k.name == "blur") else {
            return Ok(()); // blur not in this run's kernel set
        };
        if b.vm_secs > b.vm_unopt_secs * SLACK {
            return Err(format!(
                "regression gate: optimized VM ({:.3} ms) is slower than the \
                 unoptimized VM ({:.3} ms) on blur ({:.2}x, allowed slack {SLACK}x)",
                b.vm_secs * 1e3,
                b.vm_unopt_secs * 1e3,
                b.opt_speedup(),
            ));
        }
        Ok(())
    }

    /// Hand-rolled JSON (the offline crate set has no serde).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{{");
        let _ = writeln!(s, "  \"size\": [{}, {}],", self.size, self.size);
        let _ = writeln!(s, "  \"threads\": {},", self.threads);
        let fmt = |v: Option<f64>| {
            v.map(|v| format!("{v:.3}")).unwrap_or_else(|| "null".to_string())
        };
        let _ = writeln!(s, "  \"blur_speedup\": {},", fmt(self.blur_speedup()));
        let _ = writeln!(
            s,
            "  \"blur_opt_speedup\": {},",
            fmt(self.blur_opt_speedup())
        );
        let _ = writeln!(
            s,
            "  \"harris_fused_speedup\": {},",
            fmt(self.harris_fused_speedup())
        );
        let _ = writeln!(
            s,
            "  \"harris_intermediate_bytes_eliminated\": {},",
            self.harris.as_ref().map(|h| h.intermediate_bytes).unwrap_or(0)
        );
        if let Some(h) = &self.harris {
            let _ = writeln!(s, "  \"harris_fused\": {{");
            let _ = writeln!(s, "    \"pixels\": {},", h.pixels);
            let _ = writeln!(s, "    \"staged_secs\": {:.6},", h.staged_secs);
            let _ = writeln!(s, "    \"inline_secs\": {:.6},", h.inline_secs);
            let _ = writeln!(
                s,
                "    \"lstage_secs\": {},",
                h.lstage_secs
                    .map(|v| format!("{v:.6}"))
                    .unwrap_or_else(|| "null".to_string())
            );
            let _ = writeln!(s, "    \"best_mode\": \"{}\",", h.best_mode());
            let _ = writeln!(s, "    \"frames_per_sec\": {:.2},", h.frames_per_sec());
            let _ = writeln!(s, "    \"identical\": {}", h.identical);
            let _ = writeln!(s, "  }},");
        }
        let _ = writeln!(s, "  \"all_identical\": {},", self.all_identical());
        let _ = writeln!(s, "  \"kernels\": [");
        for (i, k) in self.kernels.iter().enumerate() {
            let _ = writeln!(s, "    {{");
            let _ = writeln!(s, "      \"name\": \"{}\",", k.name);
            let _ = writeln!(s, "      \"pixels\": {},", k.pixels);
            let _ = writeln!(s, "      \"tree_secs\": {:.6},", k.tree_secs);
            let _ = writeln!(s, "      \"vm_unopt_secs\": {:.6},", k.vm_unopt_secs);
            let _ = writeln!(s, "      \"vm_scalar_secs\": {:.6},", k.vm_scalar_secs);
            let _ = writeln!(s, "      \"vm_secs\": {:.6},", k.vm_secs);
            let _ = writeln!(s, "      \"tree_pix_per_sec\": {:.0},", k.tree_pix_per_sec());
            let _ = writeln!(
                s,
                "      \"vm_unopt_pix_per_sec\": {:.0},",
                k.vm_unopt_pix_per_sec()
            );
            let _ = writeln!(
                s,
                "      \"vm_scalar_pix_per_sec\": {:.0},",
                k.vm_scalar_pix_per_sec()
            );
            let _ = writeln!(s, "      \"vm_pix_per_sec\": {:.0},", k.vm_pix_per_sec());
            let _ = writeln!(s, "      \"speedup\": {:.3},", k.speedup());
            let _ = writeln!(s, "      \"opt_speedup\": {:.3},", k.opt_speedup());
            let _ = writeln!(s, "      \"parallel\": {},", k.parallel);
            let _ = writeln!(s, "      \"identical\": {}", k.identical);
            let _ = writeln!(s, "    }}{}", if i + 1 < self.kernels.len() { "," } else { "" });
        }
        let _ = writeln!(s, "  ]");
        let _ = writeln!(s, "}}");
        s
    }

    /// Human-readable table.
    pub fn render(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "execution-engine benchmark — {0}×{0}, {1} thread(s)  (Mpix/s per engine)",
            self.size, self.threads
        );
        let _ = writeln!(
            s,
            "{:<12} {:>10} {:>10} {:>10} {:>10} {:>8} {:>8}  {:>8}  {}",
            "kernel", "tree", "vm-unopt", "vm-scalar", "vm", "speedup", "vs-PR3", "parallel", "identical"
        );
        for k in &self.kernels {
            let _ = writeln!(
                s,
                "{:<12} {:>10.2} {:>10.2} {:>10.2} {:>10.2} {:>7.2}x {:>7.2}x  {:>8}  {}",
                k.name,
                k.tree_pix_per_sec() / 1e6,
                k.vm_unopt_pix_per_sec() / 1e6,
                k.vm_scalar_pix_per_sec() / 1e6,
                k.vm_pix_per_sec() / 1e6,
                k.speedup(),
                k.opt_speedup(),
                if k.parallel { "yes" } else { "no" },
                if k.identical { "yes" } else { "DIVERGED" }
            );
        }
        if let Some(h) = &self.harris {
            let _ = writeln!(
                s,
                "harris pipeline: staged {:.3} ms, fused inline {:.3} ms, lstage {} → \
                 {:.2}x ({}), {:.1} frames/s, {} intermediate bytes eliminated, {}",
                h.staged_secs * 1e3,
                h.inline_secs * 1e3,
                h.lstage_secs
                    .map(|v| format!("{:.3} ms", v * 1e3))
                    .unwrap_or_else(|| "n/a".to_string()),
                h.speedup(),
                h.best_mode(),
                h.frames_per_sec(),
                h.intermediate_bytes,
                if h.identical { "bit-identical" } else { "DIVERGED" }
            );
        }
        s
    }
}

/// Default report path: the repository root's `BENCH_exec.json`.
pub fn default_report_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_exec.json")
}

/// Where [`append_history`] accumulates runs for the default report
/// path — the input `imagecl bench analyze` reads back.
pub fn default_history_path() -> PathBuf {
    default_report_path().with_file_name("BENCH_exec_history.json")
}

/// Extract every image/array payload for the bit-identity check.
fn payloads(args: &BTreeMap<String, Arg>) -> Vec<(String, Vec<u64>)> {
    args.iter()
        .filter_map(|(name, a)| {
            let data = match a {
                Arg::Image(img) => &img.buf.data,
                Arg::Array(b) => &b.data,
                Arg::Scalar(_) => return None,
            };
            Some((name.clone(), data.iter().map(|v| v.to_bits()).collect()))
        })
        .collect()
}

/// Run the benchmark. Unknown kernel names are an error; divergence is
/// reported, not fatal (callers decide — the CLI exits non-zero).
pub fn run(opts: &BenchOpts) -> Result<BenchReport, String> {
    let n = opts.size;
    let names: Vec<&str> = if opts.kernels.is_empty() {
        GALLERY.iter().map(|(name, _)| *name).collect()
    } else {
        opts.kernels.iter().map(String::as_str).collect()
    };
    let mut kernels = Vec::new();
    for name in names {
        let Some(src) = crate::bench_defs::gallery::gallery_source(name) else {
            return Err(format!(
                "unknown gallery kernel {name:?} (known: {})",
                GALLERY.map(|(n, _)| n).join(", ")
            ));
        };
        let info = KernelInfo::analyze(frontend(src).map_err(|e| e.to_string())?);
        let plan = lower(&info, &TuningConfig::default()).map_err(|e| e.to_string())?;
        let args = gallery_workload(name, n, n, 42);
        let prepared =
            PreparedKernel::prepare(&plan, &args, (n, n)).map_err(|e| e.to_string())?;

        let time_engine = |engine: Engine| -> Result<(f64, Vec<(String, Vec<u64>)>), String> {
            let mut best = f64::INFINITY;
            let mut out = Vec::new();
            for _ in 0..opts.iters.max(1) {
                let mut a = gallery_workload(name, n, n, 42);
                let t0 = Instant::now();
                prepared
                    .run_with(&mut a, engine)
                    .map_err(|e| format!("{name} on {engine:?}: {e}"))?;
                let dt = t0.elapsed().as_secs_f64();
                if dt < best {
                    best = dt;
                }
                out = payloads(&a);
            }
            Ok((best, out))
        };

        let (tree_secs, tree_out) = time_engine(Engine::TreeWalk)?;
        let (vm_unopt_secs, unopt_out) = time_engine(Engine::VmUnopt)?;
        let (vm_scalar_secs, scalar_out) = time_engine(Engine::VmScalar)?;
        let (vm_secs, vm_out) = time_engine(Engine::Vm)?;
        let identical =
            tree_out == vm_out && tree_out == scalar_out && tree_out == unopt_out;
        kernels.push(KernelBench {
            name: name.to_string(),
            pixels: n * n,
            tree_secs,
            vm_unopt_secs,
            vm_scalar_secs,
            vm_secs,
            parallel: plan.parallel_groups,
            identical,
        });
    }
    // Full-gallery runs additionally measure the fused Harris pipeline
    // (the `harris_fused` row rides the same engine ladder, so `bench
    // analyze` gates its throughput history like any gallery kernel).
    let harris = if opts.kernels.is_empty() {
        let (row, section) = bench_harris(n, opts.iters)?;
        kernels.push(row);
        Some(section)
    } else {
        None
    };
    Ok(BenchReport {
        size: n,
        threads: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1),
        kernels,
        harris,
    })
}

/// Measure the Harris pipeline end to end: staged (Sobel then Harris,
/// gradients materialized) against the fused kernel in both modes. The
/// returned [`KernelBench`] row runs the *inline* fused plan down the
/// engine ladder; the [`HarrisFused`] section carries the staged-vs-fused
/// comparison on the optimized VM, with all fused outputs bit-compared
/// against the staged pipeline's.
fn bench_harris(n: usize, iters: usize) -> Result<(KernelBench, HarrisFused), String> {
    use crate::pipeline::fusion::{self, fused_workload, image_bits};
    use crate::transform::{lower_fused, FuseMode};

    let fk = fusion::fused_by_id("fused_sobel_harris")
        .ok_or_else(|| "fused_sobel_harris is not registered".to_string())?;
    let seed = 42;
    let iters = iters.max(1);

    // Staged pipeline, optimized VM, best-of-iters end to end.
    let plan_for = |id: &str| -> Result<crate::transform::KernelPlan, String> {
        let kdef = crate::bench_defs::kernel_by_id(id)
            .ok_or_else(|| format!("unknown kernel {id:?}"))?;
        let info = KernelInfo::analyze(frontend(kdef.source).map_err(|e| e.to_string())?);
        lower(&info, &TuningConfig::default()).map_err(|e| e.to_string())
    };
    let sobel_plan = plan_for("sobel")?;
    let harris_plan = plan_for("harris")?;
    let sobel_prep = PreparedKernel::prepare(
        &sobel_plan,
        &crate::bench_defs::workload("sobel", n, n, seed),
        (n, n),
    )
    .map_err(|e| e.to_string())?;
    let harris_prep = PreparedKernel::prepare(
        &harris_plan,
        &crate::bench_defs::workload("harris", n, n, seed),
        (n, n),
    )
    .map_err(|e| e.to_string())?;
    let mut staged_secs = f64::INFINITY;
    let mut staged_out = Vec::new();
    for _ in 0..iters {
        let mut sa = crate::bench_defs::workload("sobel", n, n, seed);
        let mut ha = crate::bench_defs::workload("harris", n, n, seed);
        let t0 = Instant::now();
        sobel_prep
            .run_with(&mut sa, Engine::Vm)
            .map_err(|e| format!("staged sobel: {e}"))?;
        for (pout, cin) in &fk.bindings {
            let produced = sa.get(pout).cloned().expect("sobel output present");
            ha.insert(cin.clone(), produced);
        }
        harris_prep
            .run_with(&mut ha, Engine::Vm)
            .map_err(|e| format!("staged harris: {e}"))?;
        let dt = t0.elapsed().as_secs_f64();
        if dt < staged_secs {
            staged_secs = dt;
        }
        staged_out = image_bits(&ha, "out");
    }

    // Inline fused plan down the full engine ladder.
    let inline_cfg = TuningConfig { fuse: Some(FuseMode::Inline), ..TuningConfig::default() };
    let inline_plan = lower_fused(fk, &inline_cfg).map_err(|e| e.to_string())?;
    let inline_args0 = fused_workload(fk, &inline_plan, n, n, seed);
    let inline_prep = PreparedKernel::prepare(&inline_plan, &inline_args0, (n, n))
        .map_err(|e| e.to_string())?;
    let time_engine = |engine: Engine| -> Result<(f64, Vec<u64>), String> {
        let mut best = f64::INFINITY;
        let mut out = Vec::new();
        for _ in 0..iters {
            let mut a = fused_workload(fk, &inline_plan, n, n, seed);
            let t0 = Instant::now();
            inline_prep
                .run_with(&mut a, engine)
                .map_err(|e| format!("harris_fused on {engine:?}: {e}"))?;
            let dt = t0.elapsed().as_secs_f64();
            if dt < best {
                best = dt;
            }
            out = image_bits(&a, "out");
        }
        Ok((best, out))
    };
    let (tree_secs, tree_out) = time_engine(Engine::TreeWalk)?;
    let (vm_unopt_secs, unopt_out) = time_engine(Engine::VmUnopt)?;
    let (vm_scalar_secs, scalar_out) = time_engine(Engine::VmScalar)?;
    let (vm_secs, vm_out) = time_engine(Engine::Vm)?;

    // Local-stage fused plan, optimized VM only.
    let lstage_cfg = TuningConfig { fuse: Some(FuseMode::LocalStage), ..TuningConfig::default() };
    let lstage = match fk.merged_source() {
        Some(_) => {
            let plan = lower_fused(fk, &lstage_cfg).map_err(|e| e.to_string())?;
            let args0 = fused_workload(fk, &plan, n, n, seed);
            let prep =
                PreparedKernel::prepare(&plan, &args0, (n, n)).map_err(|e| e.to_string())?;
            let mut best = f64::INFINITY;
            let mut out = Vec::new();
            for _ in 0..iters {
                let mut a = fused_workload(fk, &plan, n, n, seed);
                let t0 = Instant::now();
                prep.run_with(&mut a, Engine::Vm)
                    .map_err(|e| format!("harris_fused lstage: {e}"))?;
                let dt = t0.elapsed().as_secs_f64();
                if dt < best {
                    best = dt;
                }
                out = image_bits(&a, "out");
            }
            Some((best, out))
        }
        None => None,
    };

    let ladder_identical =
        tree_out == vm_out && tree_out == scalar_out && tree_out == unopt_out;
    let identical = ladder_identical
        && vm_out == staged_out
        && lstage.as_ref().map(|(_, out)| *out == staged_out).unwrap_or(true);
    let row = KernelBench {
        name: "harris_fused".to_string(),
        pixels: n * n,
        tree_secs,
        vm_unopt_secs,
        vm_scalar_secs,
        vm_secs,
        parallel: inline_plan.parallel_groups,
        identical,
    };
    let section = HarrisFused {
        pixels: n * n,
        staged_secs,
        inline_secs: vm_secs,
        lstage_secs: lstage.map(|(best, _)| best),
        intermediate_bytes: fk.intermediate_bytes(n, n),
        identical,
    };
    Ok((row, section))
}

/// Run, print, and persist the report; `Err` on engine divergence (the
/// differential guarantee is part of the benchmark's contract), when
/// the optimized VM regressed below the unoptimized VM on blur, or when
/// the fused Harris pipeline lost to its staged form (the CI
/// performance gates).
pub fn run_and_write(opts: &BenchOpts) -> Result<BenchReport, String> {
    let report = run(opts)?;
    print!("{}", report.render());
    let path = opts.out.clone().unwrap_or_else(default_report_path);
    write_report(&report, &path)?;
    println!("wrote {}", path.display());
    match append_history(&report, &path) {
        Ok(hist) => println!("appended {}", hist.display()),
        Err(e) => eprintln!("warning: {e}"),
    }
    if !report.all_identical() {
        return Err("VM and tree-walker outputs diverged (see report)".to_string());
    }
    report.check_opt_regression()?;
    report.check_fused_regression()?;
    Ok(report)
}

fn write_report(report: &BenchReport, path: &Path) -> Result<(), String> {
    // Atomic (temp + fsync + rename): a crash mid-write must never leave
    // a truncated snapshot for the CI regression gate to choke on.
    crate::fsutil::write_atomic(path, report.to_json().as_bytes())
        .map_err(|e| format!("cannot write {}: {e}", path.display()))
}

/// Append a timestamped entry to `BENCH_exec_history.json` next to the
/// snapshot report — an append-only record of every bench run (ROADMAP
/// #3), while the snapshot file stays authoritative for the CI gate. A
/// missing or malformed history file is replaced with a fresh array.
fn append_history(report: &BenchReport, snapshot_path: &Path) -> Result<PathBuf, String> {
    let path = snapshot_path.with_file_name("BENCH_exec_history.json");
    let unix_time = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let entry = format!(
        "{{\"unix_time\": {unix_time}, \"report\": {}}}",
        report.to_json().trim_end()
    );
    let existing = std::fs::read_to_string(&path).unwrap_or_default();
    let trimmed = existing.trim_end();
    let body = match trimmed.strip_suffix(']') {
        Some(stripped) => match stripped.trim_start().strip_prefix('[') {
            Some(inner) if inner.trim().is_empty() => format!("[\n{entry}\n]\n"),
            Some(_) => format!("{}\n,\n{entry}\n]\n", stripped.trim_end()),
            None => format!("[\n{entry}\n]\n"),
        },
        None => format!("[\n{entry}\n]\n"),
    };
    crate::fsutil::write_atomic(&path, body.as_bytes())
        .map_err(|e| format!("cannot write {}: {e}", path.display()))?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_bench_runs_and_matches() {
        let opts = BenchOpts {
            size: 33,
            iters: 1,
            kernels: vec!["blur".to_string(), "blend".to_string()],
            out: None,
        };
        let report = run(&opts).unwrap();
        assert_eq!(report.kernels.len(), 2);
        assert!(report.all_identical(), "{}", report.render());
        assert!(report.blur_speedup().is_some());
        assert!(report.blur_opt_speedup().is_some());
        let json = report.to_json();
        assert!(json.contains("\"blur\""), "{json}");
        assert!(json.contains("\"vm_unopt_pix_per_sec\""), "{json}");
        assert!(json.contains("\"blur_opt_speedup\""), "{json}");
        assert!(json.contains("\"all_identical\": true"), "{json}");
    }

    #[test]
    fn regression_gate_trips_on_slower_optimized_vm() {
        let k = |unopt: f64, opt: f64| KernelBench {
            name: "blur".to_string(),
            pixels: 1 << 14,
            tree_secs: 1.0,
            vm_unopt_secs: unopt,
            vm_scalar_secs: opt,
            vm_secs: opt,
            parallel: true,
            identical: true,
        };
        let ok = BenchReport { size: 128, threads: 1, kernels: vec![k(1.0, 0.5)], harris: None };
        assert!(ok.check_opt_regression().is_ok());
        let bad = BenchReport { size: 128, threads: 1, kernels: vec![k(0.5, 1.0)], harris: None };
        let err = bad.check_opt_regression().unwrap_err();
        assert!(err.contains("regression gate"), "{err}");
        // A kernel set without blur has nothing to gate.
        let none = BenchReport { size: 128, threads: 1, kernels: vec![], harris: None };
        assert!(none.check_opt_regression().is_ok());
    }

    #[test]
    fn harris_section_measures_fused_pipeline() {
        let (row, section) = bench_harris(17, 1).unwrap();
        assert_eq!(row.name, "harris_fused");
        assert!(section.identical, "fused Harris diverged from staged");
        assert_eq!(section.intermediate_bytes, 2 * 17 * 17 * 4);
        assert!(section.lstage_secs.is_some());
        assert!(section.best_fused_secs() > 0.0);
        let report = BenchReport {
            size: 17,
            threads: 1,
            kernels: vec![row],
            harris: Some(section),
        };
        let json = report.to_json();
        assert!(json.contains("\"harris_fused_speedup\""), "{json}");
        assert!(json.contains("\"harris_intermediate_bytes_eliminated\": 2312"), "{json}");
        assert!(json.contains("\"best_mode\""), "{json}");
        assert!(report.render().contains("harris pipeline"), "{}", report.render());
    }

    #[test]
    fn fused_gate_trips_on_divergence_or_slowdown() {
        let h = |fused: f64, identical: bool| HarrisFused {
            pixels: 1 << 14,
            staged_secs: 1.0,
            inline_secs: fused,
            lstage_secs: None,
            intermediate_bytes: 0,
            identical,
        };
        let ok = BenchReport { size: 128, threads: 1, kernels: vec![], harris: Some(h(0.5, true)) };
        assert!(ok.check_fused_regression().is_ok());
        let slow =
            BenchReport { size: 128, threads: 1, kernels: vec![], harris: Some(h(2.0, true)) };
        assert!(slow.check_fused_regression().unwrap_err().contains("fusion gate"));
        let div =
            BenchReport { size: 128, threads: 1, kernels: vec![], harris: Some(h(0.5, false)) };
        assert!(div.check_fused_regression().unwrap_err().contains("diverged"));
        let none = BenchReport { size: 128, threads: 1, kernels: vec![], harris: None };
        assert!(none.check_fused_regression().is_ok());
    }

    #[test]
    fn history_appends_accumulate() {
        let dir = std::env::temp_dir().join(format!(
            "imagecl_bench_hist_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let snap = dir.join("BENCH_exec.json");
        let report = BenchReport {
            size: 16,
            threads: 1,
            kernels: vec![KernelBench {
                name: "blur".to_string(),
                pixels: 256,
                tree_secs: 1.0,
                vm_unopt_secs: 0.5,
                vm_scalar_secs: 0.4,
                vm_secs: 0.25,
                parallel: false,
                identical: true,
            }],
            harris: None,
        };
        let hist = append_history(&report, &snap).unwrap();
        let hist2 = append_history(&report, &snap).unwrap();
        assert_eq!(hist, hist2);
        assert_eq!(hist.file_name().unwrap(), "BENCH_exec_history.json");
        let body = std::fs::read_to_string(&hist).unwrap();
        assert!(body.trim_start().starts_with('['), "{body}");
        assert!(body.trim_end().ends_with(']'), "{body}");
        assert_eq!(body.matches("\"unix_time\"").count(), 2, "{body}");
        // Malformed history is replaced, not corrupted further.
        std::fs::write(&hist, "not json").unwrap();
        append_history(&report, &snap).unwrap();
        let body = std::fs::read_to_string(&hist).unwrap();
        assert_eq!(body.matches("\"unix_time\"").count(), 1, "{body}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unknown_kernel_is_error() {
        let opts = BenchOpts {
            kernels: vec!["nope".to_string()],
            ..BenchOpts::smoke()
        };
        assert!(run(&opts).is_err());
    }
}
