//! Runtime values and buffers for the kernel-plan interpreter.
//!
//! Values are dynamically typed (int / float / bool), mirroring C
//! promotion semantics closely enough for the ImageCL subset: integer ops
//! stay integer (C division/modulo), any float operand promotes the op to
//! float. Buffers store `f64` uniformly and convert on store according to
//! their element type (`uchar` wraps like a C cast), so `uchar` images
//! behave like the real OpenCL buffers they model.

use crate::imagecl::ScalarType;

/// A dynamically typed runtime value.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Value {
    I(i64),
    F(f64),
    B(bool),
}

impl Value {
    pub fn as_f64(self) -> f64 {
        match self {
            Value::I(v) => v as f64,
            Value::F(v) => v,
            Value::B(b) => b as i64 as f64,
        }
    }

    pub fn as_i64(self) -> i64 {
        match self {
            Value::I(v) => v,
            Value::F(v) => v as i64,
            Value::B(b) => b as i64,
        }
    }

    pub fn as_bool(self) -> bool {
        match self {
            Value::I(v) => v != 0,
            Value::F(v) => v != 0.0,
            Value::B(b) => b,
        }
    }

    pub fn is_float(self) -> bool {
        matches!(self, Value::F(_))
    }

    /// Convert to a scalar type (C cast semantics).
    pub fn cast(self, ty: ScalarType) -> Value {
        match ty {
            ScalarType::F32 => Value::F(self.as_f64() as f32 as f64),
            ScalarType::F64 => Value::F(self.as_f64()),
            ScalarType::I32 => Value::I(self.as_i64() as i32 as i64),
            ScalarType::U32 => Value::I(self.as_i64() as u32 as i64),
            ScalarType::I16 => Value::I(self.as_i64() as i16 as i64),
            ScalarType::U16 => Value::I(self.as_i64() as u16 as i64),
            ScalarType::I8 => Value::I(self.as_i64() as i8 as i64),
            ScalarType::U8 => Value::I(self.as_i64() as u8 as i64),
            ScalarType::Bool => Value::B(self.as_bool()),
        }
    }
}

/// Convert a stored f64 back to a typed [`Value`] per element type.
fn load_as(ty: ScalarType, raw: f64) -> Value {
    if ty.is_float() {
        Value::F(raw)
    } else if ty == ScalarType::Bool {
        Value::B(raw != 0.0)
    } else {
        Value::I(raw as i64)
    }
}

/// Convert a [`Value`] to the stored f64 representation for an element
/// type (applying C-cast wrapping for narrow integer types, and f32
/// rounding for `float` buffers).
fn store_as(ty: ScalarType, v: Value) -> f64 {
    match ty {
        ScalarType::F32 => v.as_f64() as f32 as f64,
        ScalarType::F64 => v.as_f64(),
        ScalarType::I32 => v.as_i64() as i32 as f64,
        ScalarType::U32 => v.as_i64() as u32 as f64,
        ScalarType::I16 => v.as_i64() as i16 as f64,
        ScalarType::U16 => v.as_i64() as u16 as f64,
        ScalarType::I8 => v.as_i64() as i8 as f64,
        ScalarType::U8 => v.as_i64() as u8 as f64,
        ScalarType::Bool => v.as_bool() as i64 as f64,
    }
}

/// A 1-D typed buffer (general arrays; also the backing store of images).
#[derive(Debug, Clone, PartialEq)]
pub struct Buffer {
    pub elem: ScalarType,
    pub data: Vec<f64>,
}

impl Buffer {
    pub fn new(elem: ScalarType, len: usize) -> Buffer {
        Buffer { elem, data: vec![0.0; len] }
    }

    pub fn from_f64(elem: ScalarType, data: Vec<f64>) -> Buffer {
        let mut b = Buffer { elem, data };
        // Normalize through the element type (e.g. uchar wrap).
        for v in &mut b.data {
            *v = store_as(elem, Value::F(*v));
        }
        b
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn load(&self, i: usize) -> Option<Value> {
        self.data.get(i).map(|&raw| load_as(self.elem, raw))
    }

    pub fn store(&mut self, i: usize, v: Value) -> bool {
        if let Some(slot) = self.data.get_mut(i) {
            *slot = store_as(self.elem, v);
            true
        } else {
            false
        }
    }
}

/// A 2-D image: a typed buffer plus its extent (row-major, `y * w + x`).
#[derive(Debug, Clone, PartialEq)]
pub struct ImageBuf {
    pub w: usize,
    pub h: usize,
    pub buf: Buffer,
}

impl ImageBuf {
    pub fn new(elem: ScalarType, w: usize, h: usize) -> ImageBuf {
        ImageBuf { w, h, buf: Buffer::new(elem, w * h) }
    }

    pub fn from_fn(
        elem: ScalarType,
        w: usize,
        h: usize,
        mut f: impl FnMut(usize, usize) -> f64,
    ) -> ImageBuf {
        let mut img = ImageBuf::new(elem, w, h);
        for y in 0..h {
            for x in 0..w {
                img.buf.store(y * w + x, Value::F(f(x, y)));
            }
        }
        img
    }

    pub fn get(&self, x: usize, y: usize) -> f64 {
        self.buf.data[y * self.w + x]
    }

    pub fn set(&mut self, x: usize, y: usize, v: f64) {
        let i = y * self.w + x;
        self.buf.store(i, Value::F(v));
    }
}

/// A kernel argument.
#[derive(Debug, Clone, PartialEq)]
pub enum Arg {
    Image(ImageBuf),
    Array(Buffer),
    Scalar(Value),
}

impl Arg {
    pub fn image(&self) -> Option<&ImageBuf> {
        match self {
            Arg::Image(i) => Some(i),
            _ => None,
        }
    }

    pub fn image_mut(&mut self) -> Option<&mut ImageBuf> {
        match self {
            Arg::Image(i) => Some(i),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_promotion() {
        assert_eq!(Value::I(3).as_f64(), 3.0);
        assert_eq!(Value::F(2.5).as_i64(), 2);
        assert!(Value::I(1).as_bool());
        assert!(!Value::F(0.0).as_bool());
    }

    #[test]
    fn cast_wraps_uchar() {
        assert_eq!(Value::I(260).cast(ScalarType::U8), Value::I(4));
        assert_eq!(Value::I(-1).cast(ScalarType::U8), Value::I(255));
        assert_eq!(Value::F(3.9).cast(ScalarType::I32), Value::I(3));
    }

    #[test]
    fn f32_store_rounds() {
        let mut b = Buffer::new(ScalarType::F32, 1);
        b.store(0, Value::F(0.1));
        assert_eq!(b.data[0], 0.1f32 as f64);
        assert_ne!(b.data[0], 0.1f64);
    }

    #[test]
    fn uchar_buffer_wraps() {
        let mut b = Buffer::new(ScalarType::U8, 1);
        b.store(0, Value::I(300));
        assert_eq!(b.load(0), Some(Value::I(44)));
    }

    #[test]
    fn bounds_checked() {
        let mut b = Buffer::new(ScalarType::F32, 2);
        assert!(b.store(1, Value::F(1.0)));
        assert!(!b.store(2, Value::F(1.0)));
        assert_eq!(b.load(2), None);
    }

    #[test]
    fn image_from_fn() {
        let img = ImageBuf::from_fn(ScalarType::F32, 3, 2, |x, y| (x + 10 * y) as f64);
        assert_eq!(img.get(2, 1), 12.0);
        assert_eq!(img.buf.len(), 6);
    }
}
