//! The bytecode optimizer pipeline and the trace specializer (§Perf,
//! stage 3).
//!
//! [`super::vm`] lowers compiled plans into flat register bytecode with
//! a pattern-shaped redundancy: every `SetVar` computes into a fresh
//! temporary and then moves it into the variable's register, boundary
//! ternaries re-evaluate `inside(x, y)` chains whose answer the launch
//! geometry already determines, and index math that crossed a statement
//! boundary misses the `IMulAdd` fusion. This module removes all of that
//! *after* lowering, plan-agnostically, so every plan — gallery, paper,
//! user-supplied — benefits without the lowering growing special cases.
//!
//! # Passes and their ordering invariants
//!
//! [`optimize`] runs the pipeline over every phase; [`optimize_ops`] is
//! the per-stream driver. Order matters:
//!
//! 1. **`propagate`** — forward copy + constant propagation with
//!    folding (including `Jz`/`Jnz` on known registers, which become
//!    `Jmp`/[`Op::Nop`]). Must run first: it canonicalizes operands so
//!    the later pattern passes see through copies. State is reset at
//!    every jump target — the pass is deliberately local to extended
//!    basic blocks, which keeps it linear and obviously sound.
//! 2. **`fuse_muladd`** — rewrites `t = a*b; d = t + c` pairs into
//!    `IMulAdd`, leaving the original multiply for DCE to collect once
//!    the temporary is provably dead. Runs after propagation so copies
//!    don't hide the pair, and before liveness so the dead multiply is
//!    visible to the same round's DCE.
//! 3. **`coalesce_moves`** — the dead-move elimination after `SetVar`:
//!    a defining op immediately followed by a move of its result into a
//!    variable register is rewritten to target the variable directly
//!    (requires fresh liveness: the temporary must be dead past the
//!    move).
//! 4. **`dce`** — backward-liveness dead-code elimination (recomputed
//!    after coalescing, which changes def sites). Ops that can trap or
//!    panic (loads, stores, div/rem, clamps, `abs`) are never removed,
//!    dead or not: error behaviour is part of the engine contract.
//! 5. **`compact`** — strips the [`Op::Nop`]s the earlier passes left
//!    and remaps jump targets. Must run last in a round; every other
//!    pass relies on instruction indices being stable.
//!
//! Rounds repeat until a fixpoint (bounded), because each pass exposes
//! work for the others (a folded jump makes code dead; a removed move
//! makes a constant propagate further).
//!
//! Registers below `VmProgram::n_slot_ri`/`n_slot_rf` are **variable
//! slots**: like the tree-walker's slot frame they persist across
//! work-items and phases, so liveness treats them as live-out at every
//! `Ret`. Temporaries above them die at the phase exit. No pass may
//! reorder instructions or move one across a trapping op — everything
//! here either rewrites in place or deletes.
//!
//! # The trace specializer
//!
//! [`specialize`] powers the VM's batched row interpretation: given the
//! index ranges of one work-group (or one row), it walks the phase
//! bytecode with **interval arithmetic** over the integer registers and
//! follows every branch whose condition the intervals decide — the grid
//! rounding guard, boundary ternaries in the image interior, and
//! constant-trip `for` loops (which simply unroll into the trace). The
//! result is a straight-line, branch-free trace that is *exactly* the
//! instruction sequence every item in the batch would execute, then
//! cleaned by the same optimizer pipeline (boundary-condition
//! computations whose `Jz` disappeared fold away as dead code). A branch
//! the intervals cannot decide aborts specialization (`None`) and the
//! row runs scalar — this is the interior/border split.

use crate::imagecl::ast::ScalarType;

use super::compiled::{
    SLOT_GDIM_X, SLOT_GDIM_Y, SLOT_GID_X, SLOT_GID_Y, SLOT_GRP_X, SLOT_GRP_Y,
    SLOT_LID_X, SLOT_LID_Y,
};
use super::vm::{pred_f, pred_i, wrap_int, Op, Pred, VmProgram};

/// Upper bound on optimizer rounds (each round is a full pass pipeline;
/// fixpoint is normally reached in two).
const MAX_ROUNDS: usize = 4;

/// Specialization gives up after this many simulated steps (runaway
/// loops the intervals happen to decide forever).
const MAX_TRACE_STEPS: usize = 1 << 14;

/// Maximum emitted trace length (fully unrolled loops are the common
/// case; anything bigger stops paying for itself).
const MAX_TRACE_LEN: usize = 1 << 12;

/// Per-pass optimizer statistics: how many live instructions each pass
/// eliminated (passes mark victims `Nop`; `compact` strips them, so
/// eliminations are measured as non-`Nop` op-count deltas), plus the
/// number of pipeline rounds run before the fixpoint. Accumulated
/// across phases per program and surfaced through the execution-tier
/// profiler ([`crate::exec::profile`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OptStats {
    pub rounds: u64,
    pub propagate: u64,
    pub fuse_muladd: u64,
    pub coalesce: u64,
    pub dce: u64,
}

impl OptStats {
    /// Total instructions eliminated across all passes.
    pub fn eliminated(&self) -> u64 {
        self.propagate + self.fuse_muladd + self.coalesce + self.dce
    }

    pub fn merge(&mut self, other: &OptStats) {
        self.rounds += other.rounds;
        self.propagate += other.propagate;
        self.fuse_muladd += other.fuse_muladd;
        self.coalesce += other.coalesce;
        self.dce += other.dce;
    }
}

/// Live (non-`Nop`) instruction count — the measure pass statistics
/// are deltas of.
fn live_len(ops: &[Op]) -> u64 {
    ops.iter().filter(|op| !matches!(op, Op::Nop)).count() as u64
}

/// Optimize every phase of a lowered program in place; returns the
/// pass statistics summed over the phases.
pub fn optimize(prog: &mut VmProgram) -> OptStats {
    let (n_ri, n_rf) = (prog.n_ri, prog.n_rf);
    let (nsi, nsf) = (prog.n_slot_ri, prog.n_slot_rf);
    let mut total = OptStats::default();
    for phase in &mut prog.phases {
        total.merge(&optimize_ops(phase, n_ri, n_rf, nsi, nsf));
    }
    total
}

/// The per-stream pass driver (see the module docs for pass ordering).
pub(crate) fn optimize_ops(
    ops: &mut Vec<Op>,
    n_ri: usize,
    n_rf: usize,
    n_slot_ri: usize,
    n_slot_rf: usize,
) -> OptStats {
    let mut stats = OptStats::default();
    for _ in 0..MAX_ROUNDS {
        let before = ops.len();
        stats.rounds += 1;
        let l0 = live_len(ops);
        propagate(ops, n_ri, n_rf);
        let l1 = live_len(ops);
        fuse_muladd(ops);
        let l2 = live_len(ops);
        let live = liveness(ops, n_ri, n_rf, n_slot_ri, n_slot_rf);
        coalesce_moves(ops, &live, n_ri, n_rf, n_slot_ri, n_slot_rf);
        let l3 = live_len(ops);
        let live = liveness(ops, n_ri, n_rf, n_slot_ri, n_slot_rf);
        dce(ops, &live, n_ri, n_rf, n_slot_ri, n_slot_rf);
        let l4 = live_len(ops);
        stats.propagate += l0.saturating_sub(l1);
        stats.fuse_muladd += l1.saturating_sub(l2);
        stats.coalesce += l2.saturating_sub(l3);
        stats.dce += l3.saturating_sub(l4);
        compact(ops);
        if ops.len() == before {
            break;
        }
    }
    stats
}

// ---------------------------------------------------------------------
// Register references: which file, which index.
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum R {
    I(u16),
    F(u16),
}

/// Destination register of an op, if it has one.
fn def_of(op: &Op) -> Option<R> {
    Some(match *op {
        Op::IConst { d, .. }
        | Op::IMov { d, .. }
        | Op::FToI { d, .. }
        | Op::IWrap { d, .. }
        | Op::FNonZero { d, .. }
        | Op::INorm { d, .. }
        | Op::IAdd { d, .. }
        | Op::ISub { d, .. }
        | Op::IMul { d, .. }
        | Op::IMulAdd { d, .. }
        | Op::IDiv { d, .. }
        | Op::IRem { d, .. }
        | Op::INeg { d, .. }
        | Op::INot { d, .. }
        | Op::IBitNot { d, .. }
        | Op::IBitAnd { d, .. }
        | Op::IBitOr { d, .. }
        | Op::IBitXor { d, .. }
        | Op::IShl { d, .. }
        | Op::IShr { d, .. }
        | Op::IMin { d, .. }
        | Op::IMax { d, .. }
        | Op::IClamp { d, .. }
        | Op::IAbs { d, .. }
        | Op::ICmp { d, .. }
        | Op::FCmp { d, .. }
        | Op::LoadI { d, .. }
        | Op::LoadB { d, .. }
        | Op::TexLoadI { d, .. } => R::I(d),
        Op::FConst { d, .. }
        | Op::FMov { d, .. }
        | Op::IToF { d, .. }
        | Op::F32Round { d, .. }
        | Op::FAdd { d, .. }
        | Op::FSub { d, .. }
        | Op::FMul { d, .. }
        | Op::FDiv { d, .. }
        | Op::FRem { d, .. }
        | Op::FNeg { d, .. }
        | Op::FMin { d, .. }
        | Op::FMax { d, .. }
        | Op::FClamp { d, .. }
        | Op::Math1 { d, .. }
        | Op::FPow { d, .. }
        | Op::LoadF { d, .. }
        | Op::TexLoadF { d, .. } => R::F(d),
        Op::StoreF { .. }
        | Op::StoreI { .. }
        | Op::TexStoreF { .. }
        | Op::TexStoreI { .. }
        | Op::Jmp { .. }
        | Op::Jz { .. }
        | Op::Jnz { .. }
        | Op::Runaway
        | Op::Ret
        | Op::Nop => return None,
    })
}

/// Rewrite the destination register of an op that has one (the move
/// coalescer's tool). Caller guarantees `def_of` is `Some` of the same
/// register file.
fn set_def(op: &mut Op, nd: u16) {
    match op {
        Op::IConst { d, .. }
        | Op::IMov { d, .. }
        | Op::FToI { d, .. }
        | Op::IWrap { d, .. }
        | Op::FNonZero { d, .. }
        | Op::INorm { d, .. }
        | Op::IAdd { d, .. }
        | Op::ISub { d, .. }
        | Op::IMul { d, .. }
        | Op::IMulAdd { d, .. }
        | Op::IDiv { d, .. }
        | Op::IRem { d, .. }
        | Op::INeg { d, .. }
        | Op::INot { d, .. }
        | Op::IBitNot { d, .. }
        | Op::IBitAnd { d, .. }
        | Op::IBitOr { d, .. }
        | Op::IBitXor { d, .. }
        | Op::IShl { d, .. }
        | Op::IShr { d, .. }
        | Op::IMin { d, .. }
        | Op::IMax { d, .. }
        | Op::IClamp { d, .. }
        | Op::IAbs { d, .. }
        | Op::ICmp { d, .. }
        | Op::FCmp { d, .. }
        | Op::LoadI { d, .. }
        | Op::LoadB { d, .. }
        | Op::TexLoadI { d, .. }
        | Op::FConst { d, .. }
        | Op::FMov { d, .. }
        | Op::IToF { d, .. }
        | Op::F32Round { d, .. }
        | Op::FAdd { d, .. }
        | Op::FSub { d, .. }
        | Op::FMul { d, .. }
        | Op::FDiv { d, .. }
        | Op::FRem { d, .. }
        | Op::FNeg { d, .. }
        | Op::FMin { d, .. }
        | Op::FMax { d, .. }
        | Op::FClamp { d, .. }
        | Op::Math1 { d, .. }
        | Op::FPow { d, .. }
        | Op::LoadF { d, .. }
        | Op::TexLoadF { d, .. } => *d = nd,
        other => unreachable!("set_def on def-less op {other:?}"),
    }
}

/// A mutable reference to one *source* operand, tagged with its file.
enum SrcRef<'a> {
    I(&'a mut u16),
    F(&'a mut u16),
}

/// Visit every source-operand register of an op, mutably — the single
/// source of truth for operand shapes. `uses_of` (read-only) and the
/// copy-propagation operand rewriter are both built on this, so a new
/// op variant only has to get its operands right once.
fn each_src(op: &mut Op, mut f: impl FnMut(SrcRef)) {
    match op {
        Op::IConst { .. }
        | Op::FConst { .. }
        | Op::Jmp { .. }
        | Op::Runaway
        | Op::Ret
        | Op::Nop => {}
        Op::IMov { s, .. }
        | Op::IWrap { s, .. }
        | Op::INorm { s, .. }
        | Op::INeg { s, .. }
        | Op::INot { s, .. }
        | Op::IBitNot { s, .. }
        | Op::IAbs { s, .. }
        | Op::IToF { s, .. } => f(SrcRef::I(s)),
        Op::FMov { s, .. }
        | Op::FToI { s, .. }
        | Op::F32Round { s, .. }
        | Op::FNonZero { s, .. }
        | Op::FNeg { s, .. }
        | Op::Math1 { s, .. } => f(SrcRef::F(s)),
        Op::IAdd { a, b, .. }
        | Op::ISub { a, b, .. }
        | Op::IMul { a, b, .. }
        | Op::IDiv { a, b, .. }
        | Op::IRem { a, b, .. }
        | Op::IBitAnd { a, b, .. }
        | Op::IBitOr { a, b, .. }
        | Op::IBitXor { a, b, .. }
        | Op::IShl { a, b, .. }
        | Op::IShr { a, b, .. }
        | Op::IMin { a, b, .. }
        | Op::IMax { a, b, .. }
        | Op::ICmp { a, b, .. } => {
            f(SrcRef::I(a));
            f(SrcRef::I(b));
        }
        Op::IMulAdd { a, b, c, .. } => {
            f(SrcRef::I(a));
            f(SrcRef::I(b));
            f(SrcRef::I(c));
        }
        Op::IClamp { v, lo, hi, .. } => {
            f(SrcRef::I(v));
            f(SrcRef::I(lo));
            f(SrcRef::I(hi));
        }
        Op::FAdd { a, b, .. }
        | Op::FSub { a, b, .. }
        | Op::FMul { a, b, .. }
        | Op::FDiv { a, b, .. }
        | Op::FRem { a, b, .. }
        | Op::FMin { a, b, .. }
        | Op::FMax { a, b, .. }
        | Op::FCmp { a, b, .. }
        | Op::FPow { a, b, .. } => {
            f(SrcRef::F(a));
            f(SrcRef::F(b));
        }
        Op::FClamp { v, lo, hi, .. } => {
            f(SrcRef::F(v));
            f(SrcRef::F(lo));
            f(SrcRef::F(hi));
        }
        Op::Jz { c, .. } | Op::Jnz { c, .. } => f(SrcRef::I(c)),
        Op::LoadF { idx, .. } | Op::LoadI { idx, .. } | Op::LoadB { idx, .. } => {
            f(SrcRef::I(idx))
        }
        Op::StoreF { idx, s, .. } => {
            f(SrcRef::I(idx));
            f(SrcRef::F(s));
        }
        Op::StoreI { idx, s, .. } => {
            f(SrcRef::I(idx));
            f(SrcRef::I(s));
        }
        Op::TexLoadF { x, y, .. } | Op::TexLoadI { x, y, .. } => {
            f(SrcRef::I(x));
            f(SrcRef::I(y));
        }
        Op::TexStoreF { x, y, s, .. } => {
            f(SrcRef::I(x));
            f(SrcRef::I(y));
            f(SrcRef::F(s));
        }
        Op::TexStoreI { x, y, s, .. } => {
            f(SrcRef::I(x));
            f(SrcRef::I(y));
            f(SrcRef::I(s));
        }
    }
}

/// Visit every *source* register of an op (read-only view over
/// [`each_src`]; `Op` is `Copy`, so the scratch clone is free).
fn uses_of(op: &Op, mut f: impl FnMut(R)) {
    let mut scratch = *op;
    each_src(&mut scratch, |s| {
        f(match s {
            SrcRef::I(r) => R::I(*r),
            SrcRef::F(r) => R::F(*r),
        })
    });
}

/// Removable when dead? `false` for anything that traps (loads, stores,
/// div/rem), panics on degenerate inputs (clamps with inverted bounds,
/// `i64::MIN.abs()`), or affects control flow — error behaviour is part
/// of the bit-identity contract with the tree-walking oracle.
fn is_pure(op: &Op) -> bool {
    !matches!(
        op,
        Op::IDiv { .. }
            | Op::IRem { .. }
            | Op::IClamp { .. }
            | Op::FClamp { .. }
            | Op::IAbs { .. }
            | Op::LoadF { .. }
            | Op::LoadI { .. }
            | Op::LoadB { .. }
            | Op::StoreF { .. }
            | Op::StoreI { .. }
            | Op::TexLoadF { .. }
            | Op::TexLoadI { .. }
            | Op::TexStoreF { .. }
            | Op::TexStoreI { .. }
            | Op::Jmp { .. }
            | Op::Jz { .. }
            | Op::Jnz { .. }
            | Op::Runaway
            | Op::Ret
    )
}

/// `true` at every index some jump targets (extended-basic-block
/// boundaries; dataflow state resets there).
fn jump_targets(ops: &[Op]) -> Vec<bool> {
    let mut t = vec![false; ops.len() + 1];
    for op in ops {
        match op {
            Op::Jmp { t: x } | Op::Jz { t: x, .. } | Op::Jnz { t: x, .. } => {
                t[*x as usize] = true;
            }
            _ => {}
        }
    }
    t
}

// ---------------------------------------------------------------------
// Pass 1: copy + constant propagation with folding.
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq)]
enum VI {
    Unk,
    Const(i64),
    /// Register currently equal to another (canonical, non-copy) one.
    Copy(u16),
}

#[derive(Debug, Clone, Copy)]
enum VF {
    Unk,
    Const(f64),
    Copy(u16),
}

fn propagate(ops: &mut [Op], n_ri: usize, n_rf: usize) {
    let labels = jump_targets(ops);
    let mut vi = vec![VI::Unk; n_ri];
    let mut vf = vec![VF::Unk; n_rf];
    for pc in 0..ops.len() {
        if labels[pc] {
            vi.fill(VI::Unk);
            vf.fill(VF::Unk);
        }
        rewrite_operands(&mut ops[pc], &vi, &vf);
        if let Some(folded) = fold(&ops[pc], &vi, &vf) {
            ops[pc] = folded;
        }
        match ops[pc] {
            // Fallthrough after these is unreachable; reset so stale
            // facts never leak into code another jump lands in.
            Op::Jmp { .. } | Op::Ret | Op::Runaway => {
                vi.fill(VI::Unk);
                vf.fill(VF::Unk);
            }
            ref op => {
                if let Some(def) = def_of(op) {
                    let op = *op;
                    kill(&mut vi, &mut vf, def);
                    match op {
                        Op::IConst { d, v } => vi[d as usize] = VI::Const(v),
                        Op::FConst { d, v } => vf[d as usize] = VF::Const(v),
                        // Operands were canonicalized above, so a
                        // surviving move's source is a plain register:
                        // record the equality.
                        Op::IMov { d, s } => vi[d as usize] = VI::Copy(s),
                        Op::FMov { d, s } => vf[d as usize] = VF::Copy(s),
                        _ => {}
                    }
                }
            }
        }
    }
}

/// Forget everything about a redefined register, including copies of it.
fn kill(vi: &mut [VI], vf: &mut [VF], def: R) {
    match def {
        R::I(d) => {
            for v in vi.iter_mut() {
                if *v == VI::Copy(d) {
                    *v = VI::Unk;
                }
            }
            vi[d as usize] = VI::Unk;
        }
        R::F(d) => {
            for v in vf.iter_mut() {
                if matches!(*v, VF::Copy(s) if s == d) {
                    *v = VF::Unk;
                }
            }
            vf[d as usize] = VF::Unk;
        }
    }
}

/// Replace source operands that are known copies by their canonical
/// register (destinations stay). Built on [`each_src`], the shared
/// operand-shape visitor.
fn rewrite_operands(op: &mut Op, vi: &[VI], vf: &[VF]) {
    each_src(op, |s| match s {
        SrcRef::I(r) => {
            if let VI::Copy(c) = vi[*r as usize] {
                *r = c;
            }
        }
        SrcRef::F(r) => {
            if let VF::Copy(c) = vf[*r as usize] {
                *r = c;
            }
        }
    });
}

/// Constant-fold one op under the current facts, replicating runtime
/// semantics *exactly* (wrapping int arithmetic, the tree-walker's
/// NaN-exact min/max, `f32` rounding). Ops whose folding would change
/// trap/panic behaviour (div by a zero constant, inverted clamp bounds,
/// `abs(i64::MIN)`) stay unfolded.
fn fold(op: &Op, vi: &[VI], vf: &[VF]) -> Option<Op> {
    let ci = |r: u16| match vi[r as usize] {
        VI::Const(v) => Some(v),
        _ => None,
    };
    let cf = |r: u16| match vf[r as usize] {
        VF::Const(v) => Some(v),
        _ => None,
    };
    Some(match *op {
        Op::IMov { d, s } if d == s => Op::Nop,
        Op::FMov { d, s } if d == s => Op::Nop,
        Op::IMov { d, s } => Op::IConst { d, v: ci(s)? },
        Op::FMov { d, s } => Op::FConst { d, v: cf(s)? },
        Op::IToF { d, s } => Op::FConst { d, v: ci(s)? as f64 },
        Op::FToI { d, s } => Op::IConst { d, v: cf(s)? as i64 },
        Op::IWrap { d, s, ty } => Op::IConst { d, v: wrap_int(ty, ci(s)?) },
        Op::F32Round { d, s } => Op::FConst { d, v: cf(s)? as f32 as f64 },
        Op::FNonZero { d, s } => Op::IConst { d, v: (cf(s)? != 0.0) as i64 },
        Op::INorm { d, s } => Op::IConst { d, v: (ci(s)? != 0) as i64 },
        Op::IAdd { d, a, b } => Op::IConst { d, v: ci(a)?.wrapping_add(ci(b)?) },
        Op::ISub { d, a, b } => Op::IConst { d, v: ci(a)?.wrapping_sub(ci(b)?) },
        Op::IMul { d, a, b } => Op::IConst { d, v: ci(a)?.wrapping_mul(ci(b)?) },
        Op::IMulAdd { d, a, b, c } => Op::IConst {
            d,
            v: ci(a)?.wrapping_mul(ci(b)?).wrapping_add(ci(c)?),
        },
        Op::IDiv { d, a, b } => {
            let bv = ci(b)?;
            if bv == 0 {
                return None; // keep the runtime trap
            }
            Op::IConst { d, v: ci(a)?.checked_div(bv)? }
        }
        Op::IRem { d, a, b } => {
            let bv = ci(b)?;
            if bv == 0 {
                return None;
            }
            Op::IConst { d, v: ci(a)?.checked_rem(bv)? }
        }
        Op::INeg { d, s } => Op::IConst { d, v: ci(s)?.wrapping_neg() },
        Op::INot { d, s } => Op::IConst { d, v: (ci(s)? == 0) as i64 },
        Op::IBitNot { d, s } => Op::IConst { d, v: !ci(s)? },
        Op::IBitAnd { d, a, b } => Op::IConst { d, v: ci(a)? & ci(b)? },
        Op::IBitOr { d, a, b } => Op::IConst { d, v: ci(a)? | ci(b)? },
        Op::IBitXor { d, a, b } => Op::IConst { d, v: ci(a)? ^ ci(b)? },
        Op::IShl { d, a, b } => Op::IConst { d, v: ci(a)?.wrapping_shl(ci(b)? as u32) },
        Op::IShr { d, a, b } => Op::IConst { d, v: ci(a)?.wrapping_shr(ci(b)? as u32) },
        Op::IMin { d, a, b } => Op::IConst { d, v: ci(a)?.min(ci(b)?) },
        Op::IMax { d, a, b } => Op::IConst { d, v: ci(a)?.max(ci(b)?) },
        Op::IClamp { d, v, lo, hi } => {
            let (x, l, h) = (ci(v)?, ci(lo)?, ci(hi)?);
            if l > h {
                return None; // keep the runtime panic
            }
            Op::IConst { d, v: x.clamp(l, h) }
        }
        Op::IAbs { d, s } => Op::IConst { d, v: ci(s)?.checked_abs()? },
        Op::ICmp { p, d, a, b } => Op::IConst { d, v: pred_i(p, ci(a)?, ci(b)?) },
        Op::FCmp { p, d, a, b } => Op::IConst { d, v: pred_f(p, cf(a)?, cf(b)?) },
        Op::FAdd { d, a, b } => Op::FConst { d, v: cf(a)? + cf(b)? },
        Op::FSub { d, a, b } => Op::FConst { d, v: cf(a)? - cf(b)? },
        Op::FMul { d, a, b } => Op::FConst { d, v: cf(a)? * cf(b)? },
        Op::FDiv { d, a, b } => Op::FConst { d, v: cf(a)? / cf(b)? },
        Op::FRem { d, a, b } => Op::FConst { d, v: cf(a)? % cf(b)? },
        Op::FNeg { d, s } => Op::FConst { d, v: -cf(s)? },
        Op::FMin { d, a, b } => {
            let (x, y) = (cf(a)?, cf(b)?);
            Op::FConst { d, v: if x <= y { x } else { y } }
        }
        Op::FMax { d, a, b } => {
            let (x, y) = (cf(a)?, cf(b)?);
            Op::FConst { d, v: if x <= y { y } else { x } }
        }
        Op::Jz { c, t } => {
            if ci(c)? == 0 {
                Op::Jmp { t }
            } else {
                Op::Nop
            }
        }
        Op::Jnz { c, t } => {
            if ci(c)? != 0 {
                Op::Jmp { t }
            } else {
                Op::Nop
            }
        }
        // Transcendentals and clamps with NaN-able bounds stay runtime.
        _ => return None,
    })
}

// ---------------------------------------------------------------------
// Pass 2: IMulAdd re-fusion.
// ---------------------------------------------------------------------

/// Rewrite `t = x * y; d = t + c` (or `d = c + t`) into
/// `d = x*y + c`, leaving the multiply for DCE. Only adjacent pairs
/// with no label between them, and only when the multiply's inputs are
/// not its own destination (their values must still be current at the
/// add).
fn fuse_muladd(ops: &mut [Op]) {
    let labels = jump_targets(ops);
    for pc in 1..ops.len() {
        if labels[pc] {
            continue;
        }
        let Op::IAdd { d, a, b } = ops[pc] else { continue };
        let Op::IMul { d: t, a: x, b: y } = ops[pc - 1] else { continue };
        if t == x || t == y {
            continue;
        }
        let c = if a == t && b != t {
            b
        } else if b == t && a != t {
            a
        } else {
            continue;
        };
        ops[pc] = Op::IMulAdd { d, a: x, b: y, c };
    }
}

// ---------------------------------------------------------------------
// Liveness, move coalescing, DCE.
// ---------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq, Eq)]
struct BitSet(Vec<u64>);

impl BitSet {
    fn new(n: usize) -> BitSet {
        BitSet(vec![0; n.div_ceil(64)])
    }

    fn set(&mut self, i: usize) {
        self.0[i / 64] |= 1u64 << (i % 64);
    }

    fn clear(&mut self, i: usize) {
        self.0[i / 64] &= !(1u64 << (i % 64));
    }

    fn get(&self, i: usize) -> bool {
        self.0[i / 64] & (1u64 << (i % 64)) != 0
    }

    fn union_with(&mut self, o: &BitSet) {
        for (a, b) in self.0.iter_mut().zip(&o.0) {
            *a |= *b;
        }
    }
}

/// Combined register index: int file first, float file after.
fn ridx(r: R, n_ri: usize) -> usize {
    match r {
        R::I(i) => i as usize,
        R::F(f) => n_ri + f as usize,
    }
}

/// Registers live at every phase exit: the variable slots (they persist
/// across work-items and phases, exactly like the tree-walker's frame).
fn slot_live(n_ri: usize, n_rf: usize, n_slot_ri: usize, n_slot_rf: usize) -> BitSet {
    let mut s = BitSet::new(n_ri + n_rf);
    for r in 0..n_slot_ri {
        s.set(r);
    }
    for r in 0..n_slot_rf {
        s.set(n_ri + r);
    }
    s
}

/// Per-instruction live-in sets by backward fixpoint iteration.
fn liveness(
    ops: &[Op],
    n_ri: usize,
    n_rf: usize,
    n_slot_ri: usize,
    n_slot_rf: usize,
) -> Vec<BitSet> {
    let n = n_ri + n_rf;
    let len = ops.len();
    let slots = slot_live(n_ri, n_rf, n_slot_ri, n_slot_rf);
    let mut live_in: Vec<BitSet> = (0..len).map(|_| BitSet::new(n)).collect();
    loop {
        let mut changed = false;
        for pc in (0..len).rev() {
            let mut lin = live_out(ops, &live_in, &slots, pc, n);
            if let Some(def) = def_of(&ops[pc]) {
                lin.clear(ridx(def, n_ri));
            }
            uses_of(&ops[pc], |r| lin.set(ridx(r, n_ri)));
            if lin != live_in[pc] {
                live_in[pc] = lin;
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
    live_in
}

/// Union of successors' live-in (slot registers at phase exits).
fn live_out(
    ops: &[Op],
    live_in: &[BitSet],
    slots: &BitSet,
    pc: usize,
    n: usize,
) -> BitSet {
    let len = ops.len();
    let mut out = BitSet::new(n);
    let mut succ = |t: usize, out: &mut BitSet| {
        if t < len {
            out.union_with(&live_in[t]);
        } else {
            out.union_with(slots);
        }
    };
    match ops[pc] {
        Op::Ret => out.union_with(slots),
        Op::Runaway => {}
        Op::Jmp { t } => succ(t as usize, &mut out),
        Op::Jz { t, .. } | Op::Jnz { t, .. } => {
            succ(pc + 1, &mut out);
            succ(t as usize, &mut out);
        }
        _ => succ(pc + 1, &mut out),
    }
    out
}

/// Dead-move elimination after `SetVar`: a defining op immediately
/// followed by a move of its result into another register of the same
/// file, where the temporary dies at the move, is retargeted to write
/// the destination directly and the move erased. (The lowering emits
/// exactly this shape for every variable assignment.)
fn coalesce_moves(
    ops: &mut [Op],
    live_in: &[BitSet],
    n_ri: usize,
    n_rf: usize,
    n_slot_ri: usize,
    n_slot_rf: usize,
) {
    if ops.len() < 2 {
        return;
    }
    let n = n_ri + n_rf;
    let labels = jump_targets(ops);
    let slots = slot_live(n_ri, n_rf, n_slot_ri, n_slot_rf);
    for pc in 0..ops.len() - 1 {
        // The move must be fall-through-only reachable from its definer.
        if labels[pc + 1] {
            continue;
        }
        let (t, dst) = match ops[pc + 1] {
            Op::IMov { d, s } if d != s => {
                if def_of(&ops[pc]) != Some(R::I(s)) {
                    continue;
                }
                (R::I(s), d)
            }
            Op::FMov { d, s } if d != s => {
                if def_of(&ops[pc]) != Some(R::F(s)) {
                    continue;
                }
                (R::F(s), d)
            }
            _ => continue,
        };
        // The temporary must be dead past the move. (Liveness at
        // positions ≥ pc+2 is unaffected by this rewrite, so the sets
        // stay valid as we sweep forward.)
        if live_out(ops, live_in, &slots, pc + 1, n).get(ridx(t, n_ri)) {
            continue;
        }
        set_def(&mut ops[pc], dst);
        ops[pc + 1] = Op::Nop;
    }
}

/// Remove pure ops whose destination is dead.
fn dce(
    ops: &mut [Op],
    live_in: &[BitSet],
    n_ri: usize,
    n_rf: usize,
    n_slot_ri: usize,
    n_slot_rf: usize,
) {
    let n = n_ri + n_rf;
    let slots = slot_live(n_ri, n_rf, n_slot_ri, n_slot_rf);
    for pc in 0..ops.len() {
        let Some(def) = def_of(&ops[pc]) else { continue };
        if !is_pure(&ops[pc]) {
            continue;
        }
        if !live_out(ops, live_in, &slots, pc, n).get(ridx(def, n_ri)) {
            ops[pc] = Op::Nop;
        }
    }
}

/// Strip `Nop`s and remap every jump target.
fn compact(ops: &mut Vec<Op>) {
    let mut map = vec![0u32; ops.len() + 1];
    let mut n = 0u32;
    for (i, op) in ops.iter().enumerate() {
        map[i] = n;
        if !matches!(op, Op::Nop) {
            n += 1;
        }
    }
    map[ops.len()] = n;
    ops.retain(|op| !matches!(op, Op::Nop));
    for op in ops.iter_mut() {
        match op {
            Op::Jmp { t } | Op::Jz { t, .. } | Op::Jnz { t, .. } => {
                *t = map[*t as usize];
            }
            _ => {}
        }
    }
}

// ---------------------------------------------------------------------
// Trace specialization (batched interpretation's front door).
// ---------------------------------------------------------------------

/// An inclusive integer interval. `UNK` is the full i64 range.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Iv {
    lo: i64,
    hi: i64,
}

impl Iv {
    const UNK: Iv = Iv { lo: i64::MIN, hi: i64::MAX };

    fn exact(v: i64) -> Iv {
        Iv { lo: v, hi: v }
    }

    fn bool_any() -> Iv {
        Iv { lo: 0, hi: 1 }
    }
}

/// The index-register ranges one batch is specialized under.
#[derive(Debug, Clone, Copy)]
pub(crate) struct SpecEnv {
    gid_x: Iv,
    gid_y: Iv,
    lid_x: Iv,
    lid_y: Iv,
    grp_x: i64,
    grp_y: i64,
    gdim_x: i64,
    gdim_y: i64,
}

impl SpecEnv {
    /// Ranges covering every work-item of one group — a successful
    /// group-wide specialization serves all of its rows.
    pub(crate) fn for_group(
        grp: (usize, usize),
        wg: [usize; 2],
        global: [usize; 2],
    ) -> SpecEnv {
        SpecEnv {
            gid_x: Iv {
                lo: (grp.0 * wg[0]) as i64,
                hi: (grp.0 * wg[0] + wg[0] - 1) as i64,
            },
            gid_y: Iv {
                lo: (grp.1 * wg[1]) as i64,
                hi: (grp.1 * wg[1] + wg[1] - 1) as i64,
            },
            lid_x: Iv { lo: 0, hi: (wg[0] - 1) as i64 },
            lid_y: Iv { lo: 0, hi: (wg[1] - 1) as i64 },
            grp_x: grp.0 as i64,
            grp_y: grp.1 as i64,
            gdim_x: global[0] as i64,
            gdim_y: global[1] as i64,
        }
    }

    /// Ranges for a single row (`lid_y` exact): the finer fallback that
    /// implements interior/border row splitting inside border groups.
    pub(crate) fn for_row(
        grp: (usize, usize),
        wg: [usize; 2],
        global: [usize; 2],
        lid_y: usize,
    ) -> SpecEnv {
        let mut env = SpecEnv::for_group(grp, wg, global);
        env.lid_y = Iv::exact(lid_y as i64);
        env.gid_y = Iv::exact((grp.1 * wg[1] + lid_y) as i64);
        env
    }
}

/// Walk `prog.phases[phase]` under `env`, following every branch the
/// intervals decide, and return the straight-line trace of ops every
/// item in the batch would execute — or `None` as soon as a branch
/// stays undecided (data-dependent condition, border-straddling index
/// range, float condition). Constant-trip loops unroll into the trace;
/// the optimizer pipeline then deletes the decided conditions' dead
/// computation.
pub(crate) fn specialize(prog: &VmProgram, phase: usize, env: &SpecEnv) -> Option<Vec<Op>> {
    let ops = &prog.phases[phase];
    let mut iv = vec![Iv::UNK; prog.n_ri];
    iv[SLOT_GID_X as usize] = env.gid_x;
    iv[SLOT_GID_Y as usize] = env.gid_y;
    iv[SLOT_LID_X as usize] = env.lid_x;
    iv[SLOT_LID_Y as usize] = env.lid_y;
    iv[SLOT_GRP_X as usize] = Iv::exact(env.grp_x);
    iv[SLOT_GRP_Y as usize] = Iv::exact(env.grp_y);
    iv[SLOT_GDIM_X as usize] = Iv::exact(env.gdim_x);
    iv[SLOT_GDIM_Y as usize] = Iv::exact(env.gdim_y);
    let mut out: Vec<Op> = Vec::new();
    let mut pc = 0usize;
    let mut steps = 0usize;
    while pc < ops.len() {
        steps += 1;
        if steps > MAX_TRACE_STEPS || out.len() > MAX_TRACE_LEN {
            return None;
        }
        match ops[pc] {
            Op::Jmp { t } => {
                pc = t as usize;
            }
            Op::Jz { c, t } => match truth(iv[c as usize]) {
                Some(true) => pc += 1,
                Some(false) => pc = t as usize,
                None => return None,
            },
            Op::Jnz { c, t } => match truth(iv[c as usize]) {
                Some(true) => pc = t as usize,
                Some(false) => pc += 1,
                None => return None,
            },
            Op::Ret => break,
            Op::Runaway => return None,
            op => {
                eval_interval(&mut iv, &op);
                out.push(op);
                pc += 1;
            }
        }
    }
    out.push(Op::Ret);
    optimize_ops(&mut out, prog.n_ri, prog.n_rf, prog.n_slot_ri, prog.n_slot_rf);
    Some(out)
}

/// Decided truthiness of an interval (`None` = straddles zero).
fn truth(v: Iv) -> Option<bool> {
    if v.lo == 0 && v.hi == 0 {
        Some(false)
    } else if v.lo > 0 || v.hi < 0 {
        Some(true)
    } else {
        None
    }
}

fn add_iv(a: Iv, b: Iv) -> Iv {
    match (a.lo.checked_add(b.lo), a.hi.checked_add(b.hi)) {
        (Some(lo), Some(hi)) => Iv { lo, hi },
        _ => Iv::UNK,
    }
}

fn sub_iv(a: Iv, b: Iv) -> Iv {
    match (a.lo.checked_sub(b.hi), a.hi.checked_sub(b.lo)) {
        (Some(lo), Some(hi)) => Iv { lo, hi },
        _ => Iv::UNK,
    }
}

fn mul_iv(a: Iv, b: Iv) -> Iv {
    let c = [
        a.lo as i128 * b.lo as i128,
        a.lo as i128 * b.hi as i128,
        a.hi as i128 * b.lo as i128,
        a.hi as i128 * b.hi as i128,
    ];
    let lo = *c.iter().min().unwrap();
    let hi = *c.iter().max().unwrap();
    if lo < i64::MIN as i128 || hi > i64::MAX as i128 {
        Iv::UNK
    } else {
        Iv { lo: lo as i64, hi: hi as i64 }
    }
}

fn neg_iv(a: Iv) -> Iv {
    match (a.hi.checked_neg(), a.lo.checked_neg()) {
        (Some(lo), Some(hi)) => Iv { lo, hi },
        _ => Iv::UNK,
    }
}

/// Interval of `max(min(v, hi), lo)` — the clamp formula, monotone in
/// every argument (computed without `clamp` itself, which asserts
/// ordered bounds).
fn clamp_iv(v: Iv, lo: Iv, hi: Iv) -> Iv {
    Iv {
        lo: v.lo.min(hi.lo).max(lo.lo),
        hi: v.hi.min(hi.hi).max(lo.hi),
    }
}

/// Interval of truncating division `a / b` for a strictly positive
/// divisor. Truncating division is monotone (non-strict) in both
/// arguments when the divisor is positive, so the extrema lie at the
/// four corner combinations. Divisors that may be zero or negative stay
/// unknown (a zero divisor traps at runtime; the interval must not
/// pretend to know the result).
fn div_iv(a: Iv, b: Iv) -> Iv {
    if b.lo < 1 {
        return Iv::UNK;
    }
    let c = [a.lo / b.lo, a.lo / b.hi, a.hi / b.lo, a.hi / b.hi];
    Iv {
        lo: *c.iter().min().unwrap(),
        hi: *c.iter().max().unwrap(),
    }
}

/// Interval of `a % b` for a strictly positive divisor. The result has
/// the numerator's sign (truncated remainder), bounded by `b.hi - 1` in
/// magnitude — and by the numerator itself when it is already smaller.
fn rem_iv(a: Iv, b: Iv) -> Iv {
    if b.lo < 1 {
        return Iv::UNK;
    }
    let m = b.hi - 1;
    if a.lo >= 0 {
        Iv { lo: 0, hi: a.hi.min(m) }
    } else if a.hi <= 0 {
        Iv { lo: a.lo.max(-m), hi: 0 }
    } else {
        Iv { lo: a.lo.max(-m), hi: a.hi.min(m) }
    }
}

fn abs_iv(a: Iv) -> Iv {
    let (Some(al), Some(ah)) = (a.lo.checked_abs(), a.hi.checked_abs()) else {
        return Iv::UNK;
    };
    if a.lo >= 0 {
        a
    } else if a.hi <= 0 {
        Iv { lo: ah, hi: al }
    } else {
        Iv { lo: 0, hi: al.max(ah) }
    }
}

fn cmp_iv(p: Pred, a: Iv, b: Iv) -> Iv {
    let t = |c: bool| Iv::exact(c as i64);
    match p {
        Pred::Lt if a.hi < b.lo => t(true),
        Pred::Lt if a.lo >= b.hi => t(false),
        Pred::Le if a.hi <= b.lo => t(true),
        Pred::Le if a.lo > b.hi => t(false),
        Pred::Gt if a.lo > b.hi => t(true),
        Pred::Gt if a.hi <= b.lo => t(false),
        Pred::Ge if a.lo >= b.hi => t(true),
        Pred::Ge if a.hi < b.lo => t(false),
        Pred::Eq if a.lo == a.hi && b.lo == b.hi && a.lo == b.lo => t(true),
        Pred::Eq if a.hi < b.lo || b.hi < a.lo => t(false),
        Pred::Ne if a.hi < b.lo || b.hi < a.lo => t(true),
        Pred::Ne if a.lo == a.hi && b.lo == b.hi && a.lo == b.lo => t(false),
        _ => Iv::bool_any(),
    }
}

fn within01(a: Iv) -> bool {
    a.lo >= 0 && a.hi <= 1
}

/// Integer wrap (`IWrap`) bounds per target type.
fn wrap_bounds(ty: ScalarType) -> Option<(i64, i64)> {
    Some(match ty {
        ScalarType::I32 => (i32::MIN as i64, i32::MAX as i64),
        ScalarType::U32 => (0, u32::MAX as i64),
        ScalarType::I16 => (i16::MIN as i64, i16::MAX as i64),
        ScalarType::U16 => (0, u16::MAX as i64),
        ScalarType::I8 => (i8::MIN as i64, i8::MAX as i64),
        ScalarType::U8 => (0, u8::MAX as i64),
        _ => return None,
    })
}

/// Advance the interval state over one non-branch op. Anything not
/// modeled simply makes its destination unknown — soundly, since only
/// branch decisions consume the intervals.
fn eval_interval(iv: &mut [Iv], op: &Op) {
    let d = match def_of(op) {
        Some(R::I(d)) => d as usize,
        // Float destinations (or no destination): nothing tracked.
        _ => return,
    };
    let v = |r: u16, iv: &[Iv]| iv[r as usize];
    iv[d] = match *op {
        Op::IConst { v, .. } => Iv::exact(v),
        Op::IMov { s, .. } => v(s, iv),
        Op::IAdd { a, b, .. } => add_iv(v(a, iv), v(b, iv)),
        Op::ISub { a, b, .. } => sub_iv(v(a, iv), v(b, iv)),
        Op::IMul { a, b, .. } => mul_iv(v(a, iv), v(b, iv)),
        Op::IMulAdd { a, b, c, .. } => add_iv(mul_iv(v(a, iv), v(b, iv)), v(c, iv)),
        Op::IDiv { a, b, .. } => div_iv(v(a, iv), v(b, iv)),
        Op::IRem { a, b, .. } => rem_iv(v(a, iv), v(b, iv)),
        Op::INeg { s, .. } => neg_iv(v(s, iv)),
        Op::IMin { a, b, .. } => Iv {
            lo: v(a, iv).lo.min(v(b, iv).lo),
            hi: v(a, iv).hi.min(v(b, iv).hi),
        },
        Op::IMax { a, b, .. } => Iv {
            lo: v(a, iv).lo.max(v(b, iv).lo),
            hi: v(a, iv).hi.max(v(b, iv).hi),
        },
        Op::IClamp { v: x, lo, hi, .. } => clamp_iv(v(x, iv), v(lo, iv), v(hi, iv)),
        Op::IAbs { s, .. } => abs_iv(v(s, iv)),
        Op::IWrap { s, ty, .. } => match wrap_bounds(ty) {
            Some((lo, hi)) => {
                let x = v(s, iv);
                if x.lo >= lo && x.hi <= hi {
                    x
                } else {
                    Iv { lo, hi }
                }
            }
            None => Iv::UNK,
        },
        Op::ICmp { p, a, b, .. } => cmp_iv(p, v(a, iv), v(b, iv)),
        Op::INorm { s, .. } => match truth(v(s, iv)) {
            Some(true) => Iv::exact(1),
            Some(false) => Iv::exact(0),
            None => Iv::bool_any(),
        },
        Op::INot { s, .. } => match truth(v(s, iv)) {
            Some(true) => Iv::exact(0),
            Some(false) => Iv::exact(1),
            None => Iv::bool_any(),
        },
        Op::IBitAnd { a, b, .. } if within01(v(a, iv)) && within01(v(b, iv)) => Iv {
            lo: v(a, iv).lo & v(b, iv).lo,
            hi: v(a, iv).hi & v(b, iv).hi,
        },
        Op::IBitOr { a, b, .. } if within01(v(a, iv)) && within01(v(b, iv)) => Iv {
            lo: v(a, iv).lo | v(b, iv).lo,
            hi: v(a, iv).hi | v(b, iv).hi,
        },
        // FCmp / FNonZero land in the int file with boolean range.
        Op::FCmp { .. } | Op::FNonZero { .. } => Iv::bool_any(),
        Op::LoadB { .. } => Iv::bool_any(),
        _ => Iv::UNK,
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Execute a stream on fresh register files via the scalar
    /// interpreter (no buffers → only pure/jump ops testable here).
    fn run(ops: &[Op], n_ri: usize, n_rf: usize) -> (Vec<i64>, Vec<f64>) {
        let mut ri = vec![0i64; n_ri];
        let mut rf = vec![0f64; n_rf];
        super::super::vm::run_ops_pure(ops, &mut ri, &mut rf).unwrap();
        (ri, rf)
    }

    /// Optimize with every register a "slot" (live at exit) so results
    /// stay observable, unless a temp count is given.
    fn opt_all_slots(mut ops: Vec<Op>, n_ri: usize, n_rf: usize) -> Vec<Op> {
        optimize_ops(&mut ops, n_ri, n_rf, n_ri, n_rf);
        ops
    }

    #[test]
    fn jz_on_known_register_folds() {
        // r0 = 1; if r0 == 0 jump over r1 = 5. The Jz is dead (cond
        // known non-zero) and folds to nothing.
        let ops = vec![
            Op::IConst { d: 0, v: 1 },
            Op::Jz { c: 0, t: 3 },
            Op::IConst { d: 1, v: 5 },
            Op::Ret,
        ];
        let o = opt_all_slots(ops.clone(), 2, 0);
        assert!(
            !o.iter().any(|op| matches!(op, Op::Jz { .. })),
            "Jz should fold: {o:?}"
        );
        assert_eq!(run(&o, 2, 0).0, run(&ops, 2, 0).0);
        // Taken direction: cond known zero → unconditional Jmp, and the
        // skipped store side never executes.
        let ops = vec![
            Op::IConst { d: 0, v: 0 },
            Op::Jz { c: 0, t: 3 },
            Op::IConst { d: 1, v: 5 },
            Op::IConst { d: 1, v: 7 },
            Op::Ret,
        ];
        let o = opt_all_slots(ops.clone(), 2, 0);
        assert!(
            !o.iter().any(|op| matches!(op, Op::Jz { .. })),
            "decided Jz should become Jmp: {o:?}"
        );
        assert_eq!(run(&o, 2, 0).0, run(&ops, 2, 0).0);
    }

    #[test]
    fn dead_move_after_setvar_coalesces() {
        // The SetVar shape with a *runtime* input (r0 is set by the
        // driver, so nothing folds): compute into temp r2, move into
        // slot r1. Coalescing retargets the add and erases the move.
        let ops = vec![
            Op::IAdd { d: 2, a: 0, b: 0 },
            Op::IMov { d: 1, s: 2 },
            Op::Ret,
        ];
        let mut o = ops.clone();
        // r0, r1 are slots; r2 is a temp.
        optimize_ops(&mut o, 3, 0, 2, 0);
        assert!(
            !o.iter().any(|op| matches!(op, Op::IMov { .. })),
            "move should coalesce away: {o:?}"
        );
        assert!(
            o.iter().any(|op| matches!(op, Op::IAdd { d: 1, a: 0, b: 0 })),
            "add should retarget the slot: {o:?}"
        );
        let mut ri = vec![21, 0, 0];
        let mut rf = vec![];
        super::super::vm::run_ops_pure(&o, &mut ri, &mut rf).unwrap();
        assert_eq!(ri[1], 42);
        // And the constant-input flavor folds end-to-end instead.
        let ops = vec![
            Op::IConst { d: 1, v: 3 },
            Op::IAdd { d: 2, a: 1, b: 1 },
            Op::IMov { d: 0, s: 2 },
            Op::Ret,
        ];
        let mut o = ops.clone();
        optimize_ops(&mut o, 3, 0, 2, 0);
        assert!(!o.iter().any(|op| matches!(op, Op::IMov { .. })), "{o:?}");
        let (ri, _) = run(&o, 3, 0);
        assert_eq!(ri[0], 6);
    }

    #[test]
    fn copy_propagation_sees_through_moves() {
        // r1 = r0; r2 = r1 + r1 → operands canonicalize to r0, and the
        // intermediate copy dies.
        let ops = vec![
            Op::IConst { d: 0, v: 21 },
            Op::IMov { d: 1, s: 0 },
            Op::IAdd { d: 2, a: 1, b: 1 },
            Op::Ret,
        ];
        let mut o = ops.clone();
        optimize_ops(&mut o, 3, 0, 1, 0); // only r0 is a slot
        let (ri, _) = run(&o, 3, 0);
        assert_eq!(ri[2], 0, "temp r2 was dead and should not be written");
        // With r2 observable the value must survive end-to-end.
        let o2 = opt_all_slots(ops.clone(), 3, 0);
        assert_eq!(run(&o2, 3, 0).0[2], 42);
    }

    #[test]
    fn constants_fold_through_arithmetic() {
        let ops = vec![
            Op::IConst { d: 1, v: 6 },
            Op::IConst { d: 2, v: 7 },
            Op::IMul { d: 0, a: 1, b: 2 },
            Op::Ret,
        ];
        let mut o = ops.clone();
        optimize_ops(&mut o, 3, 0, 1, 0);
        // The multiply folds to a constant write of r0; the const setup
        // for r1/r2 dies.
        assert!(
            o.iter().any(|op| matches!(op, Op::IConst { d: 0, v: 42 })),
            "{o:?}"
        );
        assert!(!o.iter().any(|op| matches!(op, Op::IMul { .. })), "{o:?}");
        assert_eq!(run(&o, 3, 0).0[0], 42);
    }

    #[test]
    fn muladd_refuses_and_fuses_correctly() {
        // t = a*b; d = t + c  →  d = a*b + c, multiply collected once
        // the temporary dies. Slots are r0..r3 (inputs + result), the
        // multiply temporary is r4.
        let ops = vec![
            Op::IMul { d: 4, a: 0, b: 1 },
            Op::IAdd { d: 3, a: 4, b: 2 },
            Op::Ret,
        ];
        // With t (r4) declared a live slot the multiply must survive.
        let mut o = ops.clone();
        optimize_ops(&mut o, 5, 0, 5, 0);
        assert!(o.iter().any(|op| matches!(op, Op::IMul { .. })), "{o:?}");
        assert!(o.iter().any(|op| matches!(op, Op::IMulAdd { .. })), "{o:?}");
        // With t a temp, the pair fuses and the multiply dies.
        let mut o = ops.clone();
        optimize_ops(&mut o, 5, 0, 4, 0);
        assert!(!o.iter().any(|op| matches!(op, Op::IMul { .. })), "{o:?}");
        assert!(o.iter().any(|op| matches!(op, Op::IMulAdd { .. })), "{o:?}");
        // Semantics: run the fused form against hand arithmetic. The
        // inputs stay runtime registers (set directly, not by consts in
        // the stream, so folding can't bypass the fused op).
        let mut ri = vec![0i64; 5];
        ri[0] = 11;
        ri[1] = 5;
        ri[2] = 9;
        let mut rf = vec![0f64; 0];
        super::super::vm::run_ops_pure(&o, &mut ri, &mut rf).unwrap();
        assert_eq!(ri[3], 11 * 5 + 9);
    }

    #[test]
    fn trapping_ops_survive_dce() {
        // A division whose result is dead must NOT be removed (it can
        // trap at runtime and the oracle would too).
        let ops = vec![
            Op::IConst { d: 1, v: 10 },
            Op::IConst { d: 2, v: 0 },
            Op::IDiv { d: 3, a: 1, b: 2 },
            Op::Ret,
        ];
        let mut o = ops.clone();
        optimize_ops(&mut o, 4, 0, 1, 0); // r3 dead
        assert!(
            o.iter().any(|op| matches!(op, Op::IDiv { .. })),
            "dead div must survive: {o:?}"
        );
    }

    #[test]
    fn compaction_remaps_jump_targets() {
        // A Jnz over a dead computation: after DCE + compaction the
        // branch must still land on the live store.
        let ops = vec![
            Op::IConst { d: 1, v: 1 },     // 0: cond (temp, live at Jnz)
            Op::Jnz { c: 1, t: 4 },        // 1: jump over the dead stretch
            Op::IConst { d: 2, v: 9 },     // 2: dead
            Op::IConst { d: 3, v: 9 },     // 3: dead
            Op::IConst { d: 0, v: 5 },     // 4: live slot write
            Op::Ret,                       // 5
        ];
        let mut o = ops.clone();
        optimize_ops(&mut o, 4, 0, 1, 0);
        let (ri, _) = run(&o, 4, 0);
        assert_eq!(ri[0], 5, "{o:?}");
        assert!(o.len() < ops.len(), "{o:?}");
    }

    #[test]
    fn float_copy_and_const_propagation_is_bit_exact() {
        let third = 1.0f64 / 3.0;
        let ops = vec![
            Op::FConst { d: 1, v: third },
            Op::FMov { d: 2, s: 1 },
            Op::FAdd { d: 0, a: 2, b: 2 },
            Op::Ret,
        ];
        let mut o = ops.clone();
        optimize_ops(&mut o, 0, 3, 0, 1);
        let (_, rf) = run(&o, 0, 3);
        let (_, rf_ref) = run(&ops, 0, 3);
        assert_eq!(rf[0].to_bits(), rf_ref[0].to_bits());
    }

    #[test]
    fn specializer_decides_guard_and_unrolls_loops() {
        // A synthetic phase mimicking the lowered shape: a guard on
        // gid_x < 100, then a constant-trip loop summing into a slot.
        // Register 8 = slot acc, 9 = loop counter, 10..12 temps.
        let ops = vec![
            // if !(gid_x < 100) → Ret
            Op::IConst { d: 10, v: 100 },                       // 0
            Op::ICmp { p: Pred::Lt, d: 11, a: SLOT_GID_X as u16, b: 10 }, // 1
            Op::Jz { c: 11, t: 12 },                            // 2
            // acc = 0; for i in 0..3 { acc += gid_x }
            Op::IConst { d: 8, v: 0 },                          // 3
            Op::IConst { d: 9, v: 0 },                          // 4
            // loop head
            Op::IConst { d: 10, v: 3 },                         // 5
            Op::ICmp { p: Pred::Lt, d: 11, a: 9, b: 10 },       // 6
            Op::Jz { c: 11, t: 12 },                            // 7
            Op::IAdd { d: 8, a: 8, b: SLOT_GID_X as u16 },      // 8
            Op::IConst { d: 10, v: 1 },                         // 9
            Op::IAdd { d: 9, a: 9, b: 10 },                     // 10
            Op::Jmp { t: 5 },                                   // 11
            Op::Ret,                                            // 12
        ];
        let prog = VmProgram {
            phases: vec![ops],
            n_ri: 12,
            n_rf: 0,
            n_slot_ri: 10,
            n_slot_rf: 0,
            buf_elems: vec![],
            opt_stats: None,
            opt_wall_us: 0,
        };
        // Interior: gid_x in [16, 31] decides the guard and the loop
        // fully unrolls into a branch-free trace.
        let env = SpecEnv::for_group((1, 0), [16, 1], [64, 1]);
        let trace = specialize(&prog, 0, &env).expect("interior specializes");
        assert!(
            !trace.iter().any(|op| matches!(
                op,
                Op::Jmp { .. } | Op::Jz { .. } | Op::Jnz { .. }
            )),
            "{trace:?}"
        );
        // Border: gid_x in [96, 111] straddles the guard → undecidable.
        let env = SpecEnv::for_group((6, 0), [16, 1], [112, 1]);
        assert!(specialize(&prog, 0, &env).is_none());
    }

    #[test]
    fn div_rem_intervals() {
        // __sx = __s % 18, __sy = __s / 18 — the staging-loop shape.
        let s = Iv { lo: 0, hi: 323 };
        let w = Iv::exact(18);
        assert_eq!(rem_iv(s, w), Iv { lo: 0, hi: 17 });
        assert_eq!(div_iv(s, w), Iv { lo: 0, hi: 17 });
        // Negative numerators keep the numerator's sign (truncated rem).
        assert_eq!(rem_iv(Iv { lo: -5, hi: -1 }, w), Iv { lo: -5, hi: 0 });
        assert_eq!(rem_iv(Iv { lo: -40, hi: 3 }, w), Iv { lo: -17, hi: 3 });
        assert_eq!(div_iv(Iv { lo: -36, hi: 35 }, w), Iv { lo: -2, hi: 1 });
        // Possibly-zero or negative divisors stay unknown (would trap).
        assert_eq!(div_iv(s, Iv { lo: 0, hi: 18 }), Iv::UNK);
        assert_eq!(rem_iv(s, Iv { lo: -3, hi: 3 }), Iv::UNK);
        // Varying positive divisor: extrema at the corners.
        assert_eq!(div_iv(Iv { lo: 10, hi: 20 }, Iv { lo: 2, hi: 5 }), Iv { lo: 2, hi: 10 });
    }

    #[test]
    fn constant_boundary_staging_phase_reaches_batched_tier() {
        // End-to-end satellite check: a constant-boundary local-memory
        // staging phase contains `__sx = __s % tile_w; __sy = __s /
        // tile_w` feeding the inside(gx, gy) ternary. With IRem/IDiv
        // modeled in the interval domain, an interior row's trace
        // decides every branch and the staging loop batches instead of
        // falling back to the scalar tier.
        use crate::analysis::KernelInfo;
        use crate::bench_defs::gallery;
        use crate::imagecl::frontend;
        use crate::transform::{lower, TuningConfig};

        let mut cfg = TuningConfig::default();
        cfg.local_mem.insert("in".into(), true);
        // BLUR has no boundary pragma → constant-0 boundary → the staged
        // load is an inside() ternary, the hard case for the specializer.
        let info = KernelInfo::analyze(frontend(gallery::BLUR).unwrap());
        let plan = lower(&info, &cfg).unwrap();
        assert_eq!(plan.phases.len(), 2, "staging + compute");

        let (w, h) = (64usize, 64usize);
        let args = crate::bench_defs::workload("blur", w, h, 1);
        let scalars =
            super::super::machine::resolve_scalars(&plan, &args, (w, h)).unwrap();
        let compiled = super::super::compiled::Compiler::compile(&plan, &scalars).unwrap();
        let prog = VmProgram::build(&plan, &compiled).expect("plan lowers to bytecode");

        // Interior group (1,1) of the 64×64 grid, row 0: all staged
        // coordinates are provably in bounds once %/÷ are modeled.
        let env = SpecEnv::for_row((1, 1), [16, 16], [64, 64], 0);
        let trace = specialize(&prog, 0, &env)
            .expect("constant-boundary staging loop must specialize (batched tier)");
        assert!(
            !trace.iter().any(|op| matches!(
                op,
                Op::Jmp { .. } | Op::Jz { .. } | Op::Jnz { .. }
            )),
            "staging trace should be branch-free: {trace:?}"
        );
        assert!(
            trace.iter().any(|op| matches!(op, Op::StoreF { .. } | Op::StoreI { .. })),
            "staging trace must still store into the local tile: {trace:?}"
        );
    }

    #[test]
    fn interval_comparisons_decide_correctly() {
        let a = Iv { lo: 5, hi: 9 };
        let b = Iv { lo: 10, hi: 20 };
        assert_eq!(cmp_iv(Pred::Lt, a, b), Iv::exact(1));
        assert_eq!(cmp_iv(Pred::Ge, a, b), Iv::exact(0));
        assert_eq!(cmp_iv(Pred::Lt, b, a), Iv::exact(0));
        let c = Iv { lo: 8, hi: 12 };
        assert_eq!(cmp_iv(Pred::Lt, a, c), Iv::bool_any());
        assert_eq!(truth(Iv::exact(0)), Some(false));
        assert_eq!(truth(Iv::exact(-3)), Some(true));
        assert_eq!(truth(Iv { lo: -1, hi: 1 }), None);
    }
}
