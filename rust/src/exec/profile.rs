//! The execution-tier profiler: per-plan accounting of *which* engine
//! tier actually ran (tree-walker oracle, unoptimized VM, scalar VM,
//! batched VM), batched-vs-scalar row coverage, parallel-group
//! utilization, optimizer pass statistics, and per-phase wall time
//! (lower / optimize / specialize / execute) — keyed per
//! (kernel, device, grid).
//!
//! The hot-path cost is one `Instant` pair around the launch plus one
//! mutex lock per launch to fold a [`RunStats`] into the plan's
//! profile; the VM's inner loops only bump thread-local counters that
//! are flushed once per worker. Snapshots render as a table
//! ([`Profiler::render`]) and publish into the `obs` metrics registry
//! ([`Profiler::publish`]) for the Prometheus/JSON exporters.

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};

use crate::obs;

use super::opt::OptStats;

/// The engine tier that actually executed a launch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// Tree-walking oracle (forced, or VM fallback).
    Tree,
    /// Unoptimized, unbatched VM (the PR-3 baseline).
    VmUnopt,
    /// Optimized VM, scalar row loop.
    VmScalar,
    /// Optimized VM with batched row interpretation (the full path).
    Vm,
}

impl Tier {
    pub const ALL: [Tier; 4] = [Tier::Tree, Tier::VmUnopt, Tier::VmScalar, Tier::Vm];

    pub fn name(self) -> &'static str {
        match self {
            Tier::Tree => "tree",
            Tier::VmUnopt => "vm-unopt",
            Tier::VmScalar => "vm-scalar",
            Tier::Vm => "vm",
        }
    }

    fn idx(self) -> usize {
        match self {
            Tier::Tree => 0,
            Tier::VmUnopt => 1,
            Tier::VmScalar => 2,
            Tier::Vm => 3,
        }
    }
}

/// A compilation/execution phase whose wall time is attributed per
/// plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Lower,
    Optimize,
    Specialize,
    Execute,
}

impl Phase {
    pub const ALL: [Phase; 4] =
        [Phase::Lower, Phase::Optimize, Phase::Specialize, Phase::Execute];

    pub fn name(self) -> &'static str {
        match self {
            Phase::Lower => "lower",
            Phase::Optimize => "optimize",
            Phase::Specialize => "specialize",
            Phase::Execute => "execute",
        }
    }

    fn idx(self) -> usize {
        match self {
            Phase::Lower => 0,
            Phase::Optimize => 1,
            Phase::Specialize => 2,
            Phase::Execute => 3,
        }
    }
}

/// What one VM NDRange launch did, reported by `vm::run_ndrange`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RunStats {
    /// Work-item rows that ran through the batched lane interpreter.
    pub rows_batched: u64,
    /// Rows that fell back to the scalar per-item loop.
    pub rows_scalar: u64,
    /// Work-groups (or row partitions) dispatched.
    pub groups: u64,
    /// Worker threads the launch actually spawned (1 = serial).
    pub threads: u64,
    /// Thread-pool width available to the launch.
    pub pool: u64,
    /// Wall time spent in row/group specialization, microseconds.
    pub spec_wall_us: u64,
}

/// Identifies a profiled plan: which kernel, on which device, at which
/// launch grid.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct PlanKey {
    pub kernel: String,
    pub device: &'static str,
    pub grid: (usize, usize),
}

impl PlanKey {
    pub fn new(kernel: &str, device: &'static str, grid: (usize, usize)) -> PlanKey {
        PlanKey { kernel: kernel.to_string(), device, grid }
    }

    pub fn grid_label(&self) -> String {
        format!("{}x{}", self.grid.0, self.grid.1)
    }
}

/// Accumulated profile for one plan key.
#[derive(Debug, Clone, Default)]
pub struct TierProfile {
    /// Launches per tier, indexed per [`Tier::idx`].
    pub runs: [u64; 4],
    /// Launches where `Engine::Auto` wanted the VM but fell back to
    /// the tree-walker (program untypeable or argument mismatch).
    pub fallbacks: u64,
    pub rows_batched: u64,
    pub rows_scalar: u64,
    pub groups_dispatched: u64,
    /// Worker-thread slots used, summed over launches.
    pub thread_slots: u64,
    /// Widest thread pool observed.
    pub pool_width: u64,
    /// Wall per phase, microseconds, indexed per [`Phase::idx`].
    pub phase_us: [u64; 4],
    /// How many optimized programs contributed to `opt`.
    pub opt_runs: u64,
    pub opt: OptStats,
}

impl TierProfile {
    pub fn total_runs(&self) -> u64 {
        self.runs.iter().sum()
    }

    fn rows_total(&self) -> u64 {
        self.rows_batched + self.rows_scalar
    }

    /// Fraction of VM rows that ran batched. The batched and scalar
    /// fractions sum to exactly 1.0 when any VM rows ran, and to 0.0
    /// for tree-only plans — never more than 1.0.
    pub fn batched_frac(&self) -> f64 {
        let total = self.rows_total();
        if total == 0 {
            0.0
        } else {
            self.rows_batched as f64 / total as f64
        }
    }

    /// Fraction of VM rows that ran through the scalar loop.
    pub fn scalar_frac(&self) -> f64 {
        let total = self.rows_total();
        if total == 0 {
            0.0
        } else {
            self.rows_scalar as f64 / total as f64
        }
    }

    /// Parallel-group utilization: average worker threads per launch
    /// over the pool width (1.0 = every launch filled the pool).
    pub fn utilization(&self) -> f64 {
        let runs = self.total_runs();
        if runs == 0 || self.pool_width == 0 {
            return 0.0;
        }
        (self.thread_slots as f64 / runs as f64) / self.pool_width as f64
    }
}

/// The process-global profiler: plan key → accumulated profile.
#[derive(Debug, Default)]
pub struct Profiler {
    plans: Mutex<BTreeMap<PlanKey, TierProfile>>,
}

/// The process-global profiler instance.
pub fn profiler() -> &'static Profiler {
    static PROFILER: OnceLock<Profiler> = OnceLock::new();
    PROFILER.get_or_init(Profiler::default)
}

impl Profiler {
    /// Fold one launch into the plan's profile.
    pub fn record_run(
        &self,
        key: &PlanKey,
        tier: Tier,
        fallback: bool,
        wall_us: u64,
        stats: Option<RunStats>,
    ) {
        let mut plans = self.plans.lock().unwrap();
        let p = plans.entry(key.clone()).or_default();
        p.runs[tier.idx()] += 1;
        if fallback {
            p.fallbacks += 1;
        }
        p.phase_us[Phase::Execute.idx()] += wall_us;
        if let Some(s) = stats {
            p.rows_batched += s.rows_batched;
            p.rows_scalar += s.rows_scalar;
            p.groups_dispatched += s.groups;
            p.thread_slots += s.threads;
            p.pool_width = p.pool_width.max(s.pool);
            p.phase_us[Phase::Specialize.idx()] += s.spec_wall_us;
        }
    }

    /// Attribute `us` microseconds of `phase` wall time to a plan.
    pub fn add_phase(&self, key: &PlanKey, phase: Phase, us: u64) {
        let mut plans = self.plans.lock().unwrap();
        let p = plans.entry(key.clone()).or_default();
        p.phase_us[phase.idx()] += us;
    }

    /// Fold one optimized build's pass statistics into a plan.
    pub fn record_opt(&self, key: &PlanKey, stats: &OptStats, wall_us: u64) {
        let mut plans = self.plans.lock().unwrap();
        let p = plans.entry(key.clone()).or_default();
        p.opt_runs += 1;
        p.opt.merge(stats);
        p.phase_us[Phase::Optimize.idx()] += wall_us;
    }

    /// Point-in-time copy of every plan profile, key-sorted.
    pub fn snapshot(&self) -> Vec<(PlanKey, TierProfile)> {
        let plans = self.plans.lock().unwrap();
        plans.iter().map(|(k, v)| (k.clone(), v.clone())).collect()
    }

    /// Drop all accumulated profiles (tests and bench isolation).
    pub fn reset(&self) {
        self.plans.lock().unwrap().clear();
    }

    /// Human-readable per-plan table (the "tier-profiler table" in the
    /// README).
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let snap = self.snapshot();
        let mut s = String::new();
        if snap.is_empty() {
            let _ = writeln!(s, "(no plans profiled)");
            return s;
        }
        let _ = writeln!(
            s,
            "{:<34} {:>5} {:>5} {:>5} {:>5} {:>5} {:>6} {:>6} {:>5} {:>9} {:>9}",
            "plan (kernel@device grid)",
            "tree",
            "vmU",
            "vmS",
            "vm",
            "fall",
            "batch%",
            "util%",
            "elim",
            "opt_us",
            "exec_us"
        );
        for (key, p) in &snap {
            let plan = format!("{}@{} {}", key.kernel, key.device, key.grid_label());
            let _ = writeln!(
                s,
                "{:<34} {:>5} {:>5} {:>5} {:>5} {:>5} {:>5.1}% {:>5.1}% {:>5} {:>9} {:>9}",
                plan,
                p.runs[0],
                p.runs[1],
                p.runs[2],
                p.runs[3],
                p.fallbacks,
                p.batched_frac() * 100.0,
                p.utilization() * 100.0,
                p.opt.eliminated(),
                p.phase_us[Phase::Optimize.idx()],
                p.phase_us[Phase::Execute.idx()],
            );
        }
        s
    }

    /// Publish every profile into the `obs` metrics registry under
    /// `imagecl_exec_*`, labeled by kernel/device/grid. Counters use
    /// `set_max`, so repeated publishes stay monotone.
    pub fn publish(&self) {
        let reg = obs::registry();
        for (key, p) in self.snapshot() {
            let grid = key.grid_label();
            let base: [(&str, &str); 3] =
                [("kernel", &key.kernel), ("device", key.device), ("grid", &grid)];
            for tier in Tier::ALL {
                let mut labels = base.to_vec();
                labels.push(("tier", tier.name()));
                reg.counter(
                    "imagecl_exec_tier_runs_total",
                    "Launches per engine tier",
                    &labels,
                )
                .set_max(p.runs[tier.idx()]);
            }
            reg.counter(
                "imagecl_exec_fallbacks_total",
                "Auto launches that fell back to the tree-walker",
                &base,
            )
            .set_max(p.fallbacks);
            for (mode, rows) in
                [("batched", p.rows_batched), ("scalar", p.rows_scalar)]
            {
                let mut labels = base.to_vec();
                labels.push(("mode", mode));
                reg.counter(
                    "imagecl_exec_rows_total",
                    "VM work-item rows by interpretation mode",
                    &labels,
                )
                .set_max(rows);
            }
            for phase in Phase::ALL {
                let mut labels = base.to_vec();
                labels.push(("phase", phase.name()));
                reg.counter(
                    "imagecl_exec_phase_us_total",
                    "Wall time per compilation/execution phase, microseconds",
                    &labels,
                )
                .set_max(p.phase_us[phase.idx()]);
            }
            reg.counter(
                "imagecl_exec_groups_dispatched_total",
                "Work-groups (or row partitions) dispatched",
                &base,
            )
            .set_max(p.groups_dispatched);
            reg.counter(
                "imagecl_exec_thread_slots_total",
                "Worker-thread slots used, summed over launches",
                &base,
            )
            .set_max(p.thread_slots);
            reg.gauge(
                "imagecl_exec_pool_width",
                "Widest thread pool observed for the plan",
                &base,
            )
            .set(p.pool_width as f64);
            reg.gauge(
                "imagecl_exec_utilization_ratio",
                "Average worker threads per launch over the pool width",
                &base,
            )
            .set(p.utilization());
            for (pass, n) in [
                ("propagate", p.opt.propagate),
                ("fuse_muladd", p.opt.fuse_muladd),
                ("coalesce", p.opt.coalesce),
                ("dce", p.opt.dce),
            ] {
                let mut labels = base.to_vec();
                labels.push(("pass", pass));
                reg.counter(
                    "imagecl_exec_opt_eliminated_total",
                    "Instructions eliminated per optimizer pass",
                    &labels,
                )
                .set_max(n);
            }
            reg.counter(
                "imagecl_exec_opt_rounds_total",
                "Optimizer pipeline rounds run",
                &base,
            )
            .set_max(p.opt.rounds);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coverage_fractions_sum_to_at_most_one() {
        let p = Profiler::default();
        let key = PlanKey::new("blur", "test-dev", (64, 64));
        p.record_run(
            &key,
            Tier::Vm,
            false,
            100,
            Some(RunStats {
                rows_batched: 48,
                rows_scalar: 16,
                groups: 4,
                threads: 4,
                pool: 8,
                spec_wall_us: 5,
            }),
        );
        p.record_run(&key, Tier::Tree, true, 50, None);
        let snap = p.snapshot();
        assert_eq!(snap.len(), 1);
        let prof = &snap[0].1;
        assert_eq!(prof.total_runs(), 2);
        assert_eq!(prof.fallbacks, 1);
        let total = prof.batched_frac() + prof.scalar_frac();
        assert!(total <= 1.0 + 1e-9, "{total}");
        assert!((total - 1.0).abs() < 1e-9, "rows were recorded: {total}");
        assert!((prof.batched_frac() - 0.75).abs() < 1e-9);
        assert!((prof.utilization() - 0.25).abs() < 1e-9, "(4/2 threads)/8 pool");
        assert_eq!(prof.phase_us[Phase::Execute.idx()], 150);
        assert_eq!(prof.phase_us[Phase::Specialize.idx()], 5);
    }

    #[test]
    fn empty_profile_has_zero_fractions() {
        let p = TierProfile::default();
        assert_eq!(p.batched_frac() + p.scalar_frac(), 0.0);
        assert_eq!(p.utilization(), 0.0);
    }

    #[test]
    fn opt_stats_accumulate_and_render() {
        let p = Profiler::default();
        let key = PlanKey::new("sobel", "test-dev", (32, 32));
        let stats = OptStats { rounds: 2, propagate: 3, fuse_muladd: 1, coalesce: 2, dce: 7 };
        p.record_opt(&key, &stats, 40);
        p.add_phase(&key, Phase::Lower, 11);
        let snap = p.snapshot();
        assert_eq!(snap[0].1.opt.eliminated(), 13);
        assert_eq!(snap[0].1.opt_runs, 1);
        assert_eq!(snap[0].1.phase_us[Phase::Lower.idx()], 11);
        assert_eq!(snap[0].1.phase_us[Phase::Optimize.idx()], 40);
        let table = p.render();
        assert!(table.contains("sobel@test-dev 32x32"), "{table}");
        p.reset();
        assert!(p.snapshot().is_empty());
    }
}
