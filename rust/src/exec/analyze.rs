//! Bench-history regression analysis: `imagecl bench analyze`.
//!
//! Every `imagecl bench` run appends a timestamped report to
//! `BENCH_exec_history.json` (see [`super::bench`]). This module reads
//! that history back and asks, per kernel: *is the latest run's
//! `vm_pix_per_sec` throughput credibly worse than what this machine
//! has been producing?*
//!
//! The detector is deliberately robust rather than clever:
//!
//! * The **baseline** is the *median* of up to `window` previous runs
//!   at the same grid size — medians shrug off the odd run that raced
//!   a compile job for the CPU.
//! * The **threshold** is noise-aware: `max(min_rel, 4 * MAD/median)`,
//!   where MAD is the median absolute deviation of the baseline runs.
//!   A quiet history tightens toward `min_rel` (default 30%); a noisy
//!   CI host widens its own bar instead of crying wolf.
//! * Fewer than `min_runs` prior runs at this size → *insufficient
//!   history*, which **passes**: a fresh clone must not fail its first
//!   CI run.
//!
//! The verdict is machine-readable ([`Analysis::to_json`]) and the CLI
//! exits nonzero on any regression, which is the whole CI contract.

use std::path::PathBuf;

use crate::jsonlite::{self, Json};

/// Analyzer knobs (CLI: `--history`, `--window`, `--min-runs`,
/// `--threshold`).
#[derive(Debug, Clone)]
pub struct AnalyzeOpts {
    /// Path to `BENCH_exec_history.json`.
    pub history: PathBuf,
    /// Max previous runs forming the baseline.
    pub window: usize,
    /// Minimum previous runs before verdicts are rendered at all.
    pub min_runs: usize,
    /// Floor on the relative-drop threshold (0.30 = 30%).
    pub min_rel: f64,
}

impl Default for AnalyzeOpts {
    fn default() -> AnalyzeOpts {
        AnalyzeOpts {
            history: super::bench::default_history_path(),
            window: 8,
            min_runs: 3,
            min_rel: 0.30,
        }
    }
}

/// Per-kernel verdict.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    Pass,
    Regressed,
    /// Not enough same-size history to judge (counts as a pass).
    InsufficientHistory,
}

impl Verdict {
    pub fn as_str(&self) -> &'static str {
        match self {
            Verdict::Pass => "pass",
            Verdict::Regressed => "REGRESSED",
            Verdict::InsufficientHistory => "insufficient-history",
        }
    }
}

/// One kernel's analysis row.
#[derive(Debug, Clone)]
pub struct KernelAnalysis {
    pub name: String,
    /// Latest run's throughput (pixels/second).
    pub latest: f64,
    /// Median of the baseline runs (0 when none).
    pub baseline: f64,
    /// Baseline runs actually used.
    pub runs: usize,
    /// Relative drop vs baseline (positive = slower; 0 when no baseline).
    pub drop_rel: f64,
    /// The noise-aware threshold this row was judged against.
    pub threshold: f64,
    pub verdict: Verdict,
}

/// The full analysis over the latest history entry.
#[derive(Debug, Clone)]
pub struct Analysis {
    /// Grid size (n×n) of the latest run — baselines are same-size only.
    pub size: usize,
    pub kernels: Vec<KernelAnalysis>,
}

impl Analysis {
    /// Kernels whose verdict is [`Verdict::Regressed`].
    pub fn regressions(&self) -> Vec<&KernelAnalysis> {
        self.kernels.iter().filter(|k| k.verdict == Verdict::Regressed).collect()
    }

    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(s, "bench history analysis (grid {0}x{0})", self.size);
        let _ = writeln!(
            s,
            "{:<14} {:>14} {:>14} {:>5} {:>8} {:>9}  verdict",
            "kernel", "latest pix/s", "baseline", "runs", "drop", "threshold"
        );
        for k in &self.kernels {
            let _ = writeln!(
                s,
                "{:<14} {:>14.3e} {:>14.3e} {:>5} {:>7.1}% {:>8.1}%  {}",
                k.name,
                k.latest,
                k.baseline,
                k.runs,
                k.drop_rel * 100.0,
                k.threshold * 100.0,
                k.verdict.as_str()
            );
        }
        s
    }

    /// Machine-readable verdict document for CI.
    pub fn to_json(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(s, "{{");
        let _ = writeln!(s, "  \"size\": [{0}, {0}],", self.size);
        let _ = writeln!(s, "  \"regressed\": {},", !self.regressions().is_empty());
        let _ = writeln!(s, "  \"kernels\": [");
        for (i, k) in self.kernels.iter().enumerate() {
            let _ = writeln!(
                s,
                "    {{\"name\": \"{}\", \"latest_pix_per_sec\": {:.1}, \
                 \"baseline_pix_per_sec\": {:.1}, \"baseline_runs\": {}, \
                 \"drop_rel\": {:.4}, \"threshold\": {:.4}, \"verdict\": \"{}\"}}{}",
                k.name.replace('\\', "\\\\").replace('"', "\\\""),
                k.latest,
                k.baseline,
                k.runs,
                k.drop_rel,
                k.threshold,
                k.verdict.as_str(),
                if i + 1 < self.kernels.len() { "," } else { "" }
            );
        }
        let _ = writeln!(s, "  ]");
        let _ = writeln!(s, "}}");
        s
    }
}

fn median(sorted: &[f64]) -> f64 {
    match sorted.len() {
        0 => 0.0,
        n if n % 2 == 1 => sorted[n / 2],
        n => (sorted[n / 2 - 1] + sorted[n / 2]) / 2.0,
    }
}

/// One history entry flattened to what the analyzer needs.
struct Entry {
    size: usize,
    /// (kernel name, vm_pix_per_sec), in report order.
    kernels: Vec<(String, f64)>,
}

fn parse_entries(doc: &Json) -> Result<Vec<Entry>, String> {
    let arr = doc.as_arr().ok_or("history root is not an array")?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, item) in arr.iter().enumerate() {
        let report = item.get("report").ok_or_else(|| format!("entry {i}: no report"))?;
        let size = report
            .get("size")
            .and_then(Json::as_arr)
            .and_then(|s| s.first())
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("entry {i}: no size"))? as usize;
        let kernels = report
            .get("kernels")
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("entry {i}: no kernels"))?
            .iter()
            .filter_map(|k| {
                let name = k.get("name")?.as_str()?.to_string();
                let pps = k.get("vm_pix_per_sec")?.as_f64()?;
                Some((name, pps))
            })
            .collect();
        out.push(Entry { size, kernels });
    }
    Ok(out)
}

/// Analyze a history document (the text of `BENCH_exec_history.json`).
/// The last entry is "the run under test"; earlier same-size entries
/// form the baseline. Exposed for tests; [`run`] is the file-reading
/// wrapper the CLI calls.
pub fn analyze_history(text: &str, opts: &AnalyzeOpts) -> Result<Analysis, String> {
    let doc = jsonlite::parse(text).map_err(|e| format!("history is not JSON: {e}"))?;
    let entries = parse_entries(&doc)?;
    let latest = entries.last().ok_or("history is empty")?;
    let prior: Vec<&Entry> = entries[..entries.len() - 1]
        .iter()
        .filter(|e| e.size == latest.size)
        .collect();
    let kernels = latest
        .kernels
        .iter()
        .map(|(name, latest_pps)| {
            // Up to `window` most recent prior observations of this kernel.
            let mut history: Vec<f64> = prior
                .iter()
                .rev()
                .filter_map(|e| {
                    e.kernels.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
                })
                .take(opts.window)
                .collect();
            if history.len() < opts.min_runs {
                return KernelAnalysis {
                    name: name.clone(),
                    latest: *latest_pps,
                    baseline: 0.0,
                    runs: history.len(),
                    drop_rel: 0.0,
                    threshold: opts.min_rel,
                    verdict: Verdict::InsufficientHistory,
                };
            }
            history.sort_by(|a, b| a.total_cmp(b));
            let baseline = median(&history);
            let mut devs: Vec<f64> =
                history.iter().map(|v| (v - baseline).abs()).collect();
            devs.sort_by(|a, b| a.total_cmp(b));
            let mad = median(&devs);
            let noise_rel = if baseline > 0.0 { 4.0 * mad / baseline } else { 0.0 };
            let threshold = opts.min_rel.max(noise_rel);
            let drop_rel =
                if baseline > 0.0 { 1.0 - latest_pps / baseline } else { 0.0 };
            let verdict =
                if drop_rel > threshold { Verdict::Regressed } else { Verdict::Pass };
            KernelAnalysis {
                name: name.clone(),
                latest: *latest_pps,
                baseline,
                runs: history.len(),
                drop_rel,
                threshold,
                verdict,
            }
        })
        .collect();
    Ok(Analysis { size: latest.size, kernels })
}

/// Read and analyze `opts.history` from disk.
pub fn run(opts: &AnalyzeOpts) -> Result<Analysis, String> {
    let text = std::fs::read_to_string(&opts.history)
        .map_err(|e| format!("cannot read {}: {e}", opts.history.display()))?;
    analyze_history(&text, opts)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(size: usize, blur_pps: f64, sobel_pps: f64) -> String {
        format!(
            "{{\"unix_time\": 0, \"report\": {{\"size\": [{size}, {size}], \
             \"kernels\": [\
             {{\"name\": \"blur\", \"vm_pix_per_sec\": {blur_pps}}}, \
             {{\"name\": \"sobel\", \"vm_pix_per_sec\": {sobel_pps}}}]}}}}"
        )
    }

    fn history(entries: &[String]) -> String {
        format!("[\n{}\n]", entries.join(",\n"))
    }

    fn opts() -> AnalyzeOpts {
        AnalyzeOpts {
            history: PathBuf::new(),
            window: 8,
            min_runs: 3,
            min_rel: 0.30,
        }
    }

    #[test]
    fn steady_history_passes() {
        let runs: Vec<String> =
            (0..5).map(|i| entry(128, 1.0e6 + i as f64, 2.0e6)).collect();
        let a = analyze_history(&history(&runs), &opts()).unwrap();
        assert_eq!(a.size, 128);
        assert!(a.regressions().is_empty(), "{}", a.render());
        assert!(a.kernels.iter().all(|k| k.verdict == Verdict::Pass));
    }

    #[test]
    fn injected_2x_regression_is_caught() {
        // Four steady runs, then blur collapses to half throughput.
        let mut runs: Vec<String> =
            (0..4).map(|_| entry(128, 1.0e6, 2.0e6)).collect();
        runs.push(entry(128, 0.5e6, 2.0e6));
        let a = analyze_history(&history(&runs), &opts()).unwrap();
        let reg = a.regressions();
        assert_eq!(reg.len(), 1, "{}", a.render());
        assert_eq!(reg[0].name, "blur");
        assert!((reg[0].drop_rel - 0.5).abs() < 1e-9);
        // The JSON verdict is machine-readable and flags the run.
        let v = crate::jsonlite::parse(&a.to_json()).unwrap();
        assert_eq!(v.get("regressed").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn short_history_is_insufficient_not_failing() {
        let runs = vec![entry(128, 1.0e6, 2.0e6), entry(128, 0.1e6, 2.0e6)];
        let a = analyze_history(&history(&runs), &opts()).unwrap();
        assert!(a.regressions().is_empty());
        assert!(a
            .kernels
            .iter()
            .all(|k| k.verdict == Verdict::InsufficientHistory));
    }

    #[test]
    fn baseline_ignores_other_sizes() {
        // Plenty of 64×64 history, but only two 128×128 runs: the size
        // change must not compare across sizes.
        let mut runs: Vec<String> = (0..6).map(|_| entry(64, 9.0e6, 9.0e6)).collect();
        runs.push(entry(128, 1.0e6, 2.0e6));
        runs.push(entry(128, 0.4e6, 2.0e6));
        let a = analyze_history(&history(&runs), &opts()).unwrap();
        assert_eq!(a.size, 128);
        assert!(a
            .kernels
            .iter()
            .all(|k| k.verdict == Verdict::InsufficientHistory));
    }

    #[test]
    fn noisy_history_widens_the_threshold() {
        // Baseline alternates 1.0 / 2.0 Mpix/s (median 1.5, MAD 0.5):
        // noise threshold 4*0.5/1.5 ≈ 1.33 ⇒ even a 60% drop passes.
        let mut runs: Vec<String> = (0..6)
            .map(|i| entry(128, if i % 2 == 0 { 1.0e6 } else { 2.0e6 }, 2.0e6))
            .collect();
        runs.push(entry(128, 0.6e6, 2.0e6));
        let a = analyze_history(&history(&runs), &opts()).unwrap();
        let blur = a.kernels.iter().find(|k| k.name == "blur").unwrap();
        assert!(blur.threshold > 1.0, "{}", a.render());
        assert_eq!(blur.verdict, Verdict::Pass, "{}", a.render());
    }

    #[test]
    fn malformed_history_is_an_error() {
        assert!(analyze_history("not json", &opts()).is_err());
        assert!(analyze_history("[]", &opts()).is_err());
        assert!(analyze_history("{\"k\": 1}", &opts()).is_err());
    }
}
