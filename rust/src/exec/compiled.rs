//! Slot-resolved execution IR (§Perf optimization).
//!
//! The first interpreter resolved every identifier through string-keyed
//! scope maps on every expression evaluation — ~0.09 Mpixel/s. Plans are
//! now *compiled* once per launch: variables become dense slot indices
//! (types resolved statically, so C truncation semantics are applied at
//! the single assignment site), buffers become vector indices, and
//! builtin calls become direct enum dispatch. The NDRange driver in
//! [`super::machine`] then runs this IR with zero hashing on the hot
//! path.

use std::collections::HashMap;

use crate::imagecl::ast::*;
use crate::transform::clir::*;

use super::buffer::Value;
use super::machine::ExecError;

/// Builtin function codes (arity encoded by the variant).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Fn1 {
    Sqrt,
    Rsqrt,
    Fabs,
    Exp,
    Log,
    Sin,
    Cos,
    Floor,
    Ceil,
    Abs,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Fn2 {
    Min,
    Max,
    Pow,
}

/// Compiled expression.
#[derive(Debug, Clone)]
pub enum CExpr {
    I(i64),
    F(f64),
    B(bool),
    Var(u32),
    Unary(UnOp, Box<CExpr>),
    Binary(BinOp, Box<CExpr>, Box<CExpr>),
    Load {
        buf: u32,
        idx: Box<CExpr>,
    },
    TexRead {
        buf: u32,
        x: Box<CExpr>,
        y: Box<CExpr>,
    },
    Call1(Fn1, Box<CExpr>),
    Call2(Fn2, Box<CExpr>, Box<CExpr>),
    Clamp(Box<CExpr>, Box<CExpr>, Box<CExpr>),
    Ternary(Box<CExpr>, Box<CExpr>, Box<CExpr>),
    Cast(ScalarType, Box<CExpr>),
}

/// Compiled statement.
#[derive(Debug, Clone)]
pub enum CStmt {
    /// Assignment to a variable slot; `ty` applies the variable's declared
    /// type (C semantics: float→int truncation etc.). Compound ops are
    /// pre-expanded at compile time.
    SetVar {
        slot: u32,
        ty: ScalarType,
        value: CExpr,
    },
    Store {
        buf: u32,
        idx: CExpr,
        value: CExpr,
        /// Compound op: load-modify-store.
        op: Option<BinOp>,
    },
    TexWrite {
        buf: u32,
        x: CExpr,
        y: CExpr,
        value: CExpr,
    },
    If {
        cond: CExpr,
        then: Vec<CStmt>,
        els: Vec<CStmt>,
    },
    For {
        slot: u32,
        init: CExpr,
        cond: CExpr,
        step: CExpr,
        body: Vec<CStmt>,
    },
    While {
        cond: CExpr,
        body: Vec<CStmt>,
    },
    Return,
    /// Expression evaluated for effect.
    Eval(CExpr),
}

/// Work-item builtin slots (fixed layout at the front of the slot frame).
pub const SLOT_GID_X: u32 = 0;
pub const SLOT_GID_Y: u32 = 1;
pub const SLOT_LID_X: u32 = 2;
pub const SLOT_LID_Y: u32 = 3;
pub const SLOT_GRP_X: u32 = 4;
pub const SLOT_GRP_Y: u32 = 5;
pub const SLOT_GDIM_X: u32 = 6;
pub const SLOT_GDIM_Y: u32 = 7;
pub const FIRST_FREE_SLOT: u32 = 8;

/// One compiled plan: barrier-separated phases over a slot frame.
#[derive(Debug, Clone)]
pub struct CompiledPlan {
    pub phases: Vec<Vec<CStmt>>,
    pub n_slots: usize,
    /// Buffer index → display name (error messages only).
    pub buffer_names: Vec<String>,
}

/// Compilation context.
pub struct Compiler<'a> {
    /// name → (slot, declared type)
    vars: HashMap<String, (u32, ScalarType)>,
    /// buffer name → index (plan buffers first, then locals).
    bufs: HashMap<String, u32>,
    /// scalar parameter name → constant value for this launch.
    scalar_consts: &'a HashMap<String, Value>,
    next_slot: u32,
}

impl<'a> Compiler<'a> {
    /// Compile a plan. `scalar_consts` maps every scalar parameter (ABI
    /// scalars and user scalars) to its launch value — they are inlined
    /// as constants, which also unlocks constant folding below.
    pub fn compile(
        plan: &KernelPlan,
        scalar_consts: &'a HashMap<String, Value>,
    ) -> Result<CompiledPlan, ExecError> {
        let mut bufs = HashMap::new();
        let mut buffer_names = Vec::new();
        for b in &plan.buffers {
            bufs.insert(b.name.clone(), buffer_names.len() as u32);
            buffer_names.push(b.name.clone());
        }
        for l in &plan.locals {
            bufs.insert(l.name.clone(), buffer_names.len() as u32);
            buffer_names.push(l.name.clone());
        }
        let mut c = Compiler {
            vars: HashMap::new(),
            bufs,
            scalar_consts,
            next_slot: FIRST_FREE_SLOT,
        };
        // Pre-register builtins (typed I64; values injected by the driver).
        for (name, slot) in [
            (GID_X, SLOT_GID_X),
            (GID_Y, SLOT_GID_Y),
            (LID_X, SLOT_LID_X),
            (LID_Y, SLOT_LID_Y),
            (GRP_X, SLOT_GRP_X),
            (GRP_Y, SLOT_GRP_Y),
            (GDIM_X, SLOT_GDIM_X),
            (GDIM_Y, SLOT_GDIM_Y),
        ] {
            c.vars.insert(name.to_string(), (slot, ScalarType::I32));
        }
        let mut phases = Vec::new();
        for phase in &plan.phases {
            phases.push(c.stmts(phase)?);
        }
        Ok(CompiledPlan {
            phases,
            n_slots: c.next_slot as usize,
            buffer_names,
        })
    }

    fn slot_of(&mut self, name: &str, ty: ScalarType) -> u32 {
        if let Some(&(s, _)) = self.vars.get(name) {
            return s;
        }
        let s = self.next_slot;
        self.next_slot += 1;
        self.vars.insert(name.to_string(), (s, ty));
        s
    }

    fn expr(&mut self, e: &Expr) -> Result<CExpr, ExecError> {
        Ok(match e {
            Expr::IntLit(v) => CExpr::I(*v),
            Expr::FloatLit(v) => CExpr::F(*v),
            Expr::BoolLit(b) => CExpr::B(*b),
            Expr::Ident(n) => {
                if let Some(&(slot, _)) = self.vars.get(n) {
                    CExpr::Var(slot)
                } else if let Some(v) = self.scalar_consts.get(n) {
                    match v {
                        Value::I(i) => CExpr::I(*i),
                        Value::F(f) => CExpr::F(*f),
                        Value::B(b) => CExpr::B(*b),
                    }
                } else {
                    return Err(ExecError::Undefined(n.clone()));
                }
            }
            Expr::Unary { op, expr } => CExpr::Unary(*op, Box::new(self.expr(expr)?)),
            Expr::Binary { op, lhs, rhs } => fold_binary(
                *op,
                self.expr(lhs)?,
                self.expr(rhs)?,
            ),
            Expr::Index { base, indices } => {
                debug_assert_eq!(indices.len(), 1);
                let buf = *self
                    .bufs
                    .get(base)
                    .ok_or_else(|| ExecError::Undefined(base.clone()))?;
                CExpr::Load { buf, idx: Box::new(self.expr(&indices[0])?) }
            }
            Expr::Call { name, args } => self.call(name, args)?,
            Expr::Ternary { cond, then, els } => CExpr::Ternary(
                Box::new(self.expr(cond)?),
                Box::new(self.expr(then)?),
                Box::new(self.expr(els)?),
            ),
            Expr::Cast { ty, expr } => CExpr::Cast(*ty, Box::new(self.expr(expr)?)),
        })
    }

    fn call(&mut self, name: &str, args: &[Expr]) -> Result<CExpr, ExecError> {
        if name == READ_TEX {
            let Expr::Ident(img) = &args[0] else {
                return Err(ExecError::Other("bad __read_tex target".into()));
            };
            let buf = *self
                .bufs
                .get(img)
                .ok_or_else(|| ExecError::Undefined(img.clone()))?;
            return Ok(CExpr::TexRead {
                buf,
                x: Box::new(self.expr(&args[1])?),
                y: Box::new(self.expr(&args[2])?),
            });
        }
        let f1 = |f: Fn1, c: &mut Self| -> Result<CExpr, ExecError> {
            Ok(CExpr::Call1(f, Box::new(c.expr(&args[0])?)))
        };
        let f2 = |f: Fn2, c: &mut Self| -> Result<CExpr, ExecError> {
            Ok(CExpr::Call2(
                f,
                Box::new(c.expr(&args[0])?),
                Box::new(c.expr(&args[1])?),
            ))
        };
        match name {
            "sqrt" => f1(Fn1::Sqrt, self),
            "rsqrt" => f1(Fn1::Rsqrt, self),
            "fabs" => f1(Fn1::Fabs, self),
            "exp" => f1(Fn1::Exp, self),
            "log" => f1(Fn1::Log, self),
            "sin" => f1(Fn1::Sin, self),
            "cos" => f1(Fn1::Cos, self),
            "floor" => f1(Fn1::Floor, self),
            "ceil" => f1(Fn1::Ceil, self),
            "abs" => f1(Fn1::Abs, self),
            "min" => f2(Fn2::Min, self),
            "max" => f2(Fn2::Max, self),
            "pow" => f2(Fn2::Pow, self),
            "clamp" => Ok(CExpr::Clamp(
                Box::new(self.expr(&args[0])?),
                Box::new(self.expr(&args[1])?),
                Box::new(self.expr(&args[2])?),
            )),
            other => Err(ExecError::UnknownFn(other.to_string())),
        }
    }

    fn stmts(&mut self, stmts: &[Stmt]) -> Result<Vec<CStmt>, ExecError> {
        let mut out = Vec::with_capacity(stmts.len());
        for s in stmts {
            match s {
                Stmt::Decl { ty, name, init } => {
                    let value = match init {
                        Some(e) => self.expr(e)?,
                        None => CExpr::I(0),
                    };
                    let slot = self.slot_of(name, *ty);
                    out.push(CStmt::SetVar { slot, ty: *ty, value });
                }
                Stmt::Assign { lhs, op, value } => {
                    let value = self.expr(value)?;
                    match lhs {
                        LValue::Var(name) => {
                            let &(slot, ty) = self
                                .vars
                                .get(name)
                                .ok_or_else(|| ExecError::Undefined(name.clone()))?;
                            let value = match op.binop() {
                                None => value,
                                Some(b) => fold_binary(b, CExpr::Var(slot), value),
                            };
                            out.push(CStmt::SetVar { slot, ty, value });
                        }
                        LValue::Index { base, indices } => {
                            debug_assert_eq!(indices.len(), 1);
                            let buf = *self
                                .bufs
                                .get(base)
                                .ok_or_else(|| ExecError::Undefined(base.clone()))?;
                            out.push(CStmt::Store {
                                buf,
                                idx: self.expr(&indices[0])?,
                                value,
                                op: op.binop(),
                            });
                        }
                    }
                }
                Stmt::If { cond, then, els } => out.push(CStmt::If {
                    cond: self.expr(cond)?,
                    then: self.stmts(then)?,
                    els: self.stmts(els)?,
                }),
                Stmt::For { var, init, cond, step, body } => {
                    let init = self.expr(init)?;
                    let slot = self.slot_of(var, ScalarType::I32);
                    out.push(CStmt::For {
                        slot,
                        init,
                        cond: self.expr(cond)?,
                        step: self.expr(step)?,
                        body: self.stmts(body)?,
                    });
                }
                Stmt::While { cond, body } => out.push(CStmt::While {
                    cond: self.expr(cond)?,
                    body: self.stmts(body)?,
                }),
                Stmt::Return => out.push(CStmt::Return),
                Stmt::ExprStmt(e) => {
                    if let Expr::Call { name, args } = e {
                        if name == WRITE_TEX {
                            let Expr::Ident(img) = &args[0] else {
                                return Err(ExecError::Other(
                                    "bad __write_tex target".into(),
                                ));
                            };
                            let buf = *self
                                .bufs
                                .get(img)
                                .ok_or_else(|| ExecError::Undefined(img.clone()))?;
                            out.push(CStmt::TexWrite {
                                buf,
                                x: self.expr(&args[1])?,
                                y: self.expr(&args[2])?,
                                value: self.expr(&args[3])?,
                            });
                            continue;
                        }
                    }
                    out.push(CStmt::Eval(self.expr(e)?));
                }
                Stmt::Barrier => { /* phase boundary; no-op inside */ }
            }
        }
        Ok(out)
    }
}

/// Constant-fold integer binary ops at compile time (scalar parameters
/// are inlined as constants, so index arithmetic like `idy * in_w + idx`
/// partially folds; boundary comparisons against `w-1` fold fully).
fn fold_binary(op: BinOp, l: CExpr, r: CExpr) -> CExpr {
    if let (CExpr::I(a), CExpr::I(b)) = (&l, &r) {
        let (a, b) = (*a, *b);
        let v = match op {
            BinOp::Add => Some(a.wrapping_add(b)),
            BinOp::Sub => Some(a.wrapping_sub(b)),
            BinOp::Mul => Some(a.wrapping_mul(b)),
            BinOp::Div if b != 0 => Some(a / b),
            BinOp::Rem if b != 0 => Some(a % b),
            _ => None,
        };
        if let Some(v) = v {
            return CExpr::I(v);
        }
        let c = match op {
            BinOp::Eq => Some(a == b),
            BinOp::Ne => Some(a != b),
            BinOp::Lt => Some(a < b),
            BinOp::Gt => Some(a > b),
            BinOp::Le => Some(a <= b),
            BinOp::Ge => Some(a >= b),
            _ => None,
        };
        if let Some(c) = c {
            return CExpr::B(c);
        }
    }
    // x * 1, x + 0 (common after coarsen=1 lowering).
    match (&op, &l, &r) {
        (BinOp::Mul, _, CExpr::I(1)) | (BinOp::Add, _, CExpr::I(0)) | (BinOp::Sub, _, CExpr::I(0)) => {
            return l
        }
        (BinOp::Mul, CExpr::I(1), _) | (BinOp::Add, CExpr::I(0), _) => return r,
        _ => {}
    }
    CExpr::Binary(op, Box::new(l), Box::new(r))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fold_constants() {
        assert!(matches!(
            fold_binary(BinOp::Add, CExpr::I(2), CExpr::I(3)),
            CExpr::I(5)
        ));
        assert!(matches!(
            fold_binary(BinOp::Lt, CExpr::I(2), CExpr::I(3)),
            CExpr::B(true)
        ));
        assert!(matches!(
            fold_binary(BinOp::Mul, CExpr::Var(3), CExpr::I(1)),
            CExpr::Var(3)
        ));
        assert!(matches!(
            fold_binary(BinOp::Add, CExpr::I(0), CExpr::Var(9)),
            CExpr::Var(9)
        ));
        // Non-foldable stays a Binary.
        assert!(matches!(
            fold_binary(BinOp::Add, CExpr::Var(1), CExpr::I(2)),
            CExpr::Binary(..)
        ));
    }
}
