//! The bytecode VM: a flat, register-based execution engine for compiled
//! kernel plans (§Perf, stage 2).
//!
//! [`super::compiled`] already resolves names to slots; this module lowers
//! that tree IR one stage further into straight-line bytecode over *typed*
//! register files — a `i64` file and a `f64` file — with all control flow
//! as jumps. The hot loop then has:
//!
//! * no `Value` enum dispatch (operand classes are resolved statically,
//!   mirroring the dynamic int/float promotion rules exactly);
//! * no recursion or `Box` chasing (one linear `match` over ops);
//! * resolved buffer indices and raw `f64` loads/stores (still
//!   bounds-checked — an OOB access is an [`ExecError`], same as the
//!   tree-walker).
//!
//! The NDRange driver here additionally executes **work-groups in
//! parallel** across a scoped thread pool when the plan's write-set
//! analysis proved independence ([`KernelPlan::parallel_groups`], from
//! `analysis/rw.rs`): every written buffer is touched only at elements
//! the work-item provably owns (its own grid point, or a disjoint affine
//! strided pattern), and nothing written is ever read, so groups can run
//! in any order — or concurrently — with bit-identical results.
//! Barrier-free single-phase plans with too few groups to fill the pool
//! partition at work-item-*row* granularity instead
//! ([`KernelPlan::row_parallel`]). Plans that can't be proven
//! independent run serially (still through the bytecode), and the
//! tree-walking interpreter in [`super::machine`] is retained as the
//! differential oracle (`Engine::TreeWalk`).
//!
//! Lowering is total for everything the transformations emit today; the
//! few dynamically-typed corners of the language the register files cannot
//! represent statically (e.g. `min(int, float)`, whose result *variant*
//! depends on runtime values) return `None` from [`VmProgram::build`] and
//! the plan transparently executes on the tree-walker instead.
//!
//! Two further stages sit on top of the raw bytecode (PR 5):
//!
//! * an **optimizer pipeline** ([`super::opt`]) — peephole/dataflow
//!   passes (copy/constant propagation, `Jz` folding on known registers,
//!   dead-move elimination after `SetVar` lowering, `IMulAdd` re-fusion,
//!   dead-code elimination) run over every phase at build time;
//! * **row-batched interpretation** — when the plan's write-set analysis
//!   proved work-*items* independent ([`KernelPlan::batchable`]), the
//!   driver asks [`super::opt::specialize`] for a branch-free trace of
//!   the phase under this group/row's known index ranges (interval
//!   analysis decides grid guards, boundary ternaries and constant-trip
//!   loops), and executes a whole row of work-items per instruction over
//!   fixed-width register lanes ([`LANES`]) the autovectorizer can turn
//!   into SIMD. Border rows/groups whose branches stay data- or
//!   position-dependent fall back to the scalar loop — interior/border
//!   splitting at trace granularity.

use crate::imagecl::ast::{BinOp, ScalarType, UnOp};
use crate::transform::clir::KernelPlan;

use super::buffer::Buffer;
use super::compiled::{
    CExpr, CStmt, CompiledPlan, Fn1, Fn2, FIRST_FREE_SLOT, SLOT_GDIM_X, SLOT_GDIM_Y,
    SLOT_GID_X, SLOT_GID_Y, SLOT_GRP_X, SLOT_GRP_Y, SLOT_LID_X, SLOT_LID_Y,
};
use super::machine::{BufSlot, ExecError, MAX_WHILE};
use super::opt;
use super::profile;

/// Launches below this many logical grid pixels run serially even when
/// parallel execution is proven safe — thread spawn/join would dominate.
/// (Pixels, not work-items: coarsening moves work into each item without
/// changing how much total work the launch does.)
const PAR_MIN_PIXELS: usize = 1 << 14;

/// Lane width of the batched interpreter: this many work-items execute
/// each instruction together over fixed-width register lanes (arrays the
/// autovectorizer can turn into SIMD).
pub(crate) const LANES: usize = 8;

/// Work-group widths below this run scalar even when a batched trace
/// exists — lane setup would outweigh the win.
const MIN_BATCH_WIDTH: usize = 4;

/// Prefer row-granular work partitioning when whole groups cannot keep
/// this many× the thread pool busy (plans with few large groups).
const ROW_PARTITION_FACTOR: usize = 2;

/// Give up on per-row specialization for a group after this many failed
/// rows: border groups fail only at their edge rows, while phases with
/// data-dependent branches fail on *every* row — this caps their probe
/// cost at two interval walks per group instead of one per row.
const MAX_ROW_SPEC_FAILS: u32 = 2;

/// Comparison predicate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pred {
    Eq,
    Ne,
    Lt,
    Gt,
    Le,
    Ge,
}

/// One bytecode instruction. `d`/`a`/`b`/`s` are register indices into
/// the class-appropriate file (`I*`/`Jz`/`Jnz` → i64 file, `F*` → f64
/// file); `buf` indexes the launch's buffer table.
#[derive(Debug, Clone, Copy)]
pub enum Op {
    IConst { d: u16, v: i64 },
    FConst { d: u16, v: f64 },
    IMov { d: u16, s: u16 },
    FMov { d: u16, s: u16 },
    IToF { d: u16, s: u16 },
    FToI { d: u16, s: u16 },
    /// Integer wrap to a narrow type (C cast semantics).
    IWrap { d: u16, s: u16, ty: ScalarType },
    /// f64 → f32 → f64 (C `float` rounding).
    F32Round { d: u16, s: u16 },
    /// `(s != 0.0) as i64` — float truth test.
    FNonZero { d: u16, s: u16 },
    /// `(s != 0) as i64` — normalize an int to a 0/1 bool.
    INorm { d: u16, s: u16 },

    IAdd { d: u16, a: u16, b: u16 },
    ISub { d: u16, a: u16, b: u16 },
    IMul { d: u16, a: u16, b: u16 },
    /// `d = a * b + c` (fused index math: `y * stride + x`).
    IMulAdd { d: u16, a: u16, b: u16, c: u16 },
    IDiv { d: u16, a: u16, b: u16 },
    IRem { d: u16, a: u16, b: u16 },
    INeg { d: u16, s: u16 },
    /// Logical not: `(s == 0) as i64`.
    INot { d: u16, s: u16 },
    IBitNot { d: u16, s: u16 },
    IBitAnd { d: u16, a: u16, b: u16 },
    IBitOr { d: u16, a: u16, b: u16 },
    IBitXor { d: u16, a: u16, b: u16 },
    IShl { d: u16, a: u16, b: u16 },
    IShr { d: u16, a: u16, b: u16 },
    IMin { d: u16, a: u16, b: u16 },
    IMax { d: u16, a: u16, b: u16 },
    IClamp { d: u16, v: u16, lo: u16, hi: u16 },
    IAbs { d: u16, s: u16 },
    ICmp { p: Pred, d: u16, a: u16, b: u16 },

    FAdd { d: u16, a: u16, b: u16 },
    FSub { d: u16, a: u16, b: u16 },
    FMul { d: u16, a: u16, b: u16 },
    FDiv { d: u16, a: u16, b: u16 },
    FRem { d: u16, a: u16, b: u16 },
    FNeg { d: u16, s: u16 },
    /// `if a <= b { a } else { b }` — matches the tree-walker's NaN
    /// behaviour exactly (unlike `f64::min`).
    FMin { d: u16, a: u16, b: u16 },
    FMax { d: u16, a: u16, b: u16 },
    FClamp { d: u16, v: u16, lo: u16, hi: u16 },
    FCmp { p: Pred, d: u16, a: u16, b: u16 },
    Math1 { f: Fn1, d: u16, s: u16 },
    FPow { d: u16, a: u16, b: u16 },

    Jmp { t: u32 },
    Jz { c: u16, t: u32 },
    Jnz { c: u16, t: u32 },

    /// Load from a float-element buffer (raw f64).
    LoadF { d: u16, buf: u16, idx: u16 },
    /// Load from an int-element buffer (`raw as i64`).
    LoadI { d: u16, buf: u16, idx: u16 },
    /// Load from a bool-element buffer (`raw != 0.0`).
    LoadB { d: u16, buf: u16, idx: u16 },
    /// Store a float register, converting per element type (f32 rounds).
    StoreF { buf: u16, idx: u16, s: u16, ty: ScalarType },
    /// Store an int register, wrapping per element type.
    StoreI { buf: u16, idx: u16, s: u16, ty: ScalarType },
    TexLoadF { d: u16, buf: u16, x: u16, y: u16 },
    TexLoadI { d: u16, buf: u16, x: u16, y: u16 },
    TexStoreF { buf: u16, x: u16, y: u16, s: u16, ty: ScalarType },
    TexStoreI { buf: u16, x: u16, y: u16, s: u16, ty: ScalarType },

    /// `while` iteration cap exceeded.
    Runaway,
    Ret,
    /// Erased by an optimizer pass; removed again by compaction. Never
    /// present in a finished program, but executing one is a no-op.
    Nop,
}

/// A kernel plan lowered all the way to bytecode: one instruction stream
/// per barrier phase over shared register files.
#[derive(Debug, Clone)]
pub struct VmProgram {
    pub(crate) phases: Vec<Vec<Op>>,
    pub(crate) n_ri: usize,
    pub(crate) n_rf: usize,
    /// Registers below these indices in each file are backed by variable
    /// slots: like the tree-walker's slot frame they persist across
    /// work-items and phases, so the optimizer must treat them as live at
    /// every phase exit. Registers at or above are statement temporaries.
    pub(crate) n_slot_ri: usize,
    pub(crate) n_slot_rf: usize,
    /// Element type of each buffer index (plan buffers, then locals) —
    /// the lowering baked conversions for these types into the ops, so a
    /// launch whose argument buffers disagree must use the tree-walker.
    pub(crate) buf_elems: Vec<ScalarType>,
    /// Optimizer pass statistics from build time (`None` when the
    /// pipeline was skipped, i.e. the `VmUnopt` baseline).
    pub(crate) opt_stats: Option<opt::OptStats>,
    /// Wall time the optimizer pipeline took at build, microseconds.
    pub(crate) opt_wall_us: u64,
}

// ---------------------------------------------------------------------
// Lowering: CompiledPlan (tree IR) → VmProgram (bytecode)
// ---------------------------------------------------------------------

/// Register class: which file a value lives in. Booleans are 0/1 in the
/// i64 file (exactly the values `Value::B` can take under `as_i64`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Cls {
    I,
    F,
}

fn cls_of(ty: ScalarType) -> Cls {
    if ty.is_float() {
        Cls::F
    } else {
        Cls::I
    }
}

/// Marker: the expression's runtime value class cannot be pinned
/// statically (or an op has no bytecode form) — fall back to the oracle.
struct Unsup;

struct Builder<'a> {
    ops: Vec<Op>,
    /// Per-slot register index (class per `slot_cls`).
    slot_reg: &'a [u16],
    slot_cls: &'a [Cls],
    buf_elems: &'a [ScalarType],
    ti_next: u16,
    tf_next: u16,
    max_ti: u16,
    max_tf: u16,
}

impl VmProgram {
    /// Lower a compiled plan to bytecode and run the optimizer pipeline
    /// over it. `None` = some construct cannot be statically typed; the
    /// caller keeps the tree-walker.
    pub fn build(plan: &KernelPlan, compiled: &CompiledPlan) -> Option<VmProgram> {
        Self::build_with(plan, compiled, true)
    }

    /// [`Self::build`] with the optimizer pipeline optional — the
    /// unoptimized program is the PR-3 baseline kept addressable for the
    /// differential grid (`Engine::VmUnopt`) and the bench regression
    /// gate.
    pub fn build_with(
        plan: &KernelPlan,
        compiled: &CompiledPlan,
        optimize: bool,
    ) -> Option<VmProgram> {
        let slot_cls = scan_slot_classes(compiled)?;
        // Assign registers: slots first (builtin slots 0..8 land on int
        // registers 0..8 because they are all class I), temps after.
        let mut slot_reg = vec![0u16; compiled.n_slots];
        let (mut ni, mut nf) = (0u16, 0u16);
        for (s, cls) in slot_cls.iter().enumerate() {
            match cls {
                Cls::I => {
                    slot_reg[s] = ni;
                    ni += 1;
                }
                Cls::F => {
                    slot_reg[s] = nf;
                    nf += 1;
                }
            }
        }
        debug_assert!(
            (0..FIRST_FREE_SLOT as usize).all(|s| slot_reg[s] == s as u16),
            "builtin slots must map to int registers 0..8"
        );
        let buf_elems: Vec<ScalarType> = plan
            .buffers
            .iter()
            .map(|b| b.elem)
            .chain(plan.locals.iter().map(|l| l.elem))
            .collect();
        let mut phases = Vec::with_capacity(compiled.phases.len());
        let (mut n_ri, mut n_rf) = (ni as usize, nf as usize);
        for phase in &compiled.phases {
            let mut b = Builder {
                ops: Vec::new(),
                slot_reg: &slot_reg,
                slot_cls: &slot_cls,
                buf_elems: &buf_elems,
                ti_next: ni,
                tf_next: nf,
                max_ti: ni,
                max_tf: nf,
            };
            b.stmts(phase).ok()?;
            b.ops.push(Op::Ret);
            n_ri = n_ri.max(b.max_ti as usize);
            n_rf = n_rf.max(b.max_tf as usize);
            phases.push(b.ops);
        }
        let mut prog = VmProgram {
            phases,
            n_ri,
            n_rf,
            n_slot_ri: ni as usize,
            n_slot_rf: nf as usize,
            buf_elems,
            opt_stats: None,
            opt_wall_us: 0,
        };
        if optimize {
            let t0 = std::time::Instant::now();
            let stats = opt::optimize(&mut prog);
            prog.opt_wall_us = t0.elapsed().as_micros() as u64;
            prog.opt_stats = Some(stats);
        }
        Some(prog)
    }
}

/// Determine each slot's register class from every assignment to it
/// (`SetVar`'s declared type; `For` counters are raw i64). A slot
/// assigned under both classes has no static home → `None`.
fn scan_slot_classes(compiled: &CompiledPlan) -> Option<Vec<Cls>> {
    let mut cls: Vec<Option<Cls>> = vec![None; compiled.n_slots];
    for s in cls.iter_mut().take(FIRST_FREE_SLOT as usize) {
        *s = Some(Cls::I);
    }
    fn note(cls: &mut [Option<Cls>], slot: u32, c: Cls) -> bool {
        match &mut cls[slot as usize] {
            Some(prev) => *prev == c,
            none => {
                *none = Some(c);
                true
            }
        }
    }
    fn visit(cls: &mut [Option<Cls>], stmts: &[CStmt]) -> bool {
        stmts.iter().all(|s| match s {
            CStmt::SetVar { slot, ty, .. } => note(cls, *slot, cls_of(*ty)),
            CStmt::If { then, els, .. } => visit(cls, then) && visit(cls, els),
            CStmt::For { slot, body, .. } => {
                note(cls, *slot, Cls::I) && visit(cls, body)
            }
            CStmt::While { body, .. } => visit(cls, body),
            _ => true,
        })
    }
    for phase in &compiled.phases {
        if !visit(&mut cls, phase) {
            return None;
        }
    }
    // Slots never assigned (compiler temporaries that ended up unused)
    // default to the int file, matching the tree-walker's `Value::I(0)`.
    Some(cls.into_iter().map(|c| c.unwrap_or(Cls::I)).collect())
}

impl Builder<'_> {
    fn ti(&mut self) -> u16 {
        let r = self.ti_next;
        self.ti_next += 1;
        self.max_ti = self.max_ti.max(self.ti_next);
        r
    }

    fn tf(&mut self) -> u16 {
        let r = self.tf_next;
        self.tf_next += 1;
        self.max_tf = self.max_tf.max(self.tf_next);
        r
    }

    fn temp(&mut self, c: Cls) -> u16 {
        match c {
            Cls::I => self.ti(),
            Cls::F => self.tf(),
        }
    }

    fn here(&self) -> u32 {
        self.ops.len() as u32
    }

    /// Patch a previously-emitted jump to target the current position.
    fn patch(&mut self, at: u32) {
        let t = self.here();
        match &mut self.ops[at as usize] {
            Op::Jmp { t: tt } | Op::Jz { t: tt, .. } | Op::Jnz { t: tt, .. } => *tt = t,
            other => unreachable!("patching non-jump {other:?}"),
        }
    }

    /// `as_i64` coercion: float registers truncate.
    fn as_i(&mut self, (c, r): (Cls, u16)) -> u16 {
        match c {
            Cls::I => r,
            Cls::F => {
                let d = self.ti();
                self.ops.push(Op::FToI { d, s: r });
                d
            }
        }
    }

    /// `as_f64` coercion: int (and bool) registers widen.
    fn as_f(&mut self, (c, r): (Cls, u16)) -> u16 {
        match c {
            Cls::F => r,
            Cls::I => {
                let d = self.tf();
                self.ops.push(Op::IToF { d, s: r });
                d
            }
        }
    }

    /// `as_bool` coercion: an int register usable as a truth value
    /// (non-zero = true; not necessarily normalized to 0/1).
    fn as_truth(&mut self, (c, r): (Cls, u16)) -> u16 {
        match c {
            Cls::I => r,
            Cls::F => {
                let d = self.ti();
                self.ops.push(Op::FNonZero { d, s: r });
                d
            }
        }
    }

    /// Apply `Value::cast(ty)` semantics to a register.
    fn cast(&mut self, v: (Cls, u16), ty: ScalarType) -> (Cls, u16) {
        match ty {
            ScalarType::F64 => (Cls::F, self.as_f(v)),
            ScalarType::F32 => {
                let s = self.as_f(v);
                let d = self.tf();
                self.ops.push(Op::F32Round { d, s });
                (Cls::F, d)
            }
            ScalarType::Bool => {
                let (c, r) = v;
                let d = self.ti();
                match c {
                    Cls::F => self.ops.push(Op::FNonZero { d, s: r }),
                    Cls::I => self.ops.push(Op::INorm { d, s: r }),
                }
                (Cls::I, d)
            }
            _ => {
                let s = self.as_i(v);
                let d = self.ti();
                self.ops.push(Op::IWrap { d, s, ty });
                (Cls::I, d)
            }
        }
    }

    fn expr(&mut self, e: &CExpr) -> Result<(Cls, u16), Unsup> {
        Ok(match e {
            CExpr::I(v) => {
                let d = self.ti();
                self.ops.push(Op::IConst { d, v: *v });
                (Cls::I, d)
            }
            CExpr::F(v) => {
                let d = self.tf();
                self.ops.push(Op::FConst { d, v: *v });
                (Cls::F, d)
            }
            CExpr::B(b) => {
                let d = self.ti();
                self.ops.push(Op::IConst { d, v: *b as i64 });
                (Cls::I, d)
            }
            CExpr::Var(slot) => {
                (self.slot_cls[*slot as usize], self.slot_reg[*slot as usize])
            }
            CExpr::Unary(op, inner) => {
                let v = self.expr(inner)?;
                match op {
                    UnOp::Neg => match v.0 {
                        Cls::F => {
                            let d = self.tf();
                            self.ops.push(Op::FNeg { d, s: v.1 });
                            (Cls::F, d)
                        }
                        Cls::I => {
                            let d = self.ti();
                            self.ops.push(Op::INeg { d, s: v.1 });
                            (Cls::I, d)
                        }
                    },
                    UnOp::Not => {
                        let s = self.as_truth(v);
                        let d = self.ti();
                        self.ops.push(Op::INot { d, s });
                        (Cls::I, d)
                    }
                    UnOp::BitNot => {
                        let s = self.as_i(v);
                        let d = self.ti();
                        self.ops.push(Op::IBitNot { d, s });
                        (Cls::I, d)
                    }
                }
            }
            CExpr::Binary(op, lhs, rhs) => self.binary(*op, lhs, rhs)?,
            CExpr::Load { buf, idx } => {
                let i = self.expr(idx)?;
                let idx = self.as_i(i);
                self.load(*buf, idx)
            }
            CExpr::TexRead { buf, x, y } => {
                let xv = self.expr(x)?;
                let x = self.as_i(xv);
                let yv = self.expr(y)?;
                let y = self.as_i(yv);
                let buf = *buf as u16;
                match cls_of(self.buf_elems[buf as usize]) {
                    Cls::F => {
                        let d = self.tf();
                        self.ops.push(Op::TexLoadF { d, buf, x, y });
                        (Cls::F, d)
                    }
                    Cls::I => {
                        let d = self.ti();
                        self.ops.push(Op::TexLoadI { d, buf, x, y });
                        (Cls::I, d)
                    }
                }
            }
            CExpr::Call1(f, a) => {
                let v = self.expr(a)?;
                if *f == Fn1::Abs && v.0 == Cls::I {
                    let d = self.ti();
                    self.ops.push(Op::IAbs { d, s: v.1 });
                    return Ok((Cls::I, d));
                }
                let f = if *f == Fn1::Abs { Fn1::Fabs } else { *f };
                let s = self.as_f(v);
                let d = self.tf();
                self.ops.push(Op::Math1 { f, d, s });
                (Cls::F, d)
            }
            CExpr::Call2(f, a, b) => {
                let av = self.expr(a)?;
                let bv = self.expr(b)?;
                match f {
                    Fn2::Pow => {
                        let a = self.as_f(av);
                        let b = self.as_f(bv);
                        let d = self.tf();
                        self.ops.push(Op::FPow { d, a, b });
                        (Cls::F, d)
                    }
                    Fn2::Min | Fn2::Max => {
                        // The tree-walker returns the *original* operand
                        // value (variant and all), so a mixed int/float
                        // min has a runtime-dependent class — unsupported.
                        if av.0 != bv.0 {
                            return Err(Unsup);
                        }
                        let d = self.temp(av.0);
                        let op = match (f, av.0) {
                            (Fn2::Min, Cls::I) => Op::IMin { d, a: av.1, b: bv.1 },
                            (Fn2::Max, Cls::I) => Op::IMax { d, a: av.1, b: bv.1 },
                            (Fn2::Min, Cls::F) => Op::FMin { d, a: av.1, b: bv.1 },
                            (Fn2::Max, Cls::F) => Op::FMax { d, a: av.1, b: bv.1 },
                            _ => unreachable!(),
                        };
                        self.ops.push(op);
                        (av.0, d)
                    }
                }
            }
            CExpr::Clamp(v, lo, hi) => {
                let vv = self.expr(v)?;
                let lv = self.expr(lo)?;
                let hv = self.expr(hi)?;
                if vv.0 == Cls::F || lv.0 == Cls::F || hv.0 == Cls::F {
                    // Mixed clamp promotes everything (the tree-walker
                    // computes in f64), so the result class is static.
                    let v = self.as_f(vv);
                    let lo = self.as_f(lv);
                    let hi = self.as_f(hv);
                    let d = self.tf();
                    self.ops.push(Op::FClamp { d, v, lo, hi });
                    (Cls::F, d)
                } else {
                    let d = self.ti();
                    self.ops.push(Op::IClamp { d, v: vv.1, lo: lv.1, hi: hv.1 });
                    (Cls::I, d)
                }
            }
            CExpr::Ternary(c, t, e2) => {
                // Both arms must land in the same class for the result to
                // have a static register.
                let cls = self.peek_cls(t)?;
                if self.peek_cls(e2)? != cls {
                    return Err(Unsup);
                }
                let d = self.temp(cls);
                let cv = self.expr(c)?;
                let cond = self.as_truth(cv);
                let jz = self.here();
                self.ops.push(Op::Jz { c: cond, t: 0 });
                let tv = self.expr(t)?;
                self.mov(cls, d, tv.1);
                let jend = self.here();
                self.ops.push(Op::Jmp { t: 0 });
                self.patch(jz);
                let ev = self.expr(e2)?;
                self.mov(cls, d, ev.1);
                self.patch(jend);
                (cls, d)
            }
            CExpr::Cast(ty, inner) => {
                let v = self.expr(inner)?;
                self.cast(v, *ty)
            }
        })
    }

    fn mov(&mut self, c: Cls, d: u16, s: u16) {
        if d == s {
            return;
        }
        self.ops.push(match c {
            Cls::I => Op::IMov { d, s },
            Cls::F => Op::FMov { d, s },
        });
    }

    /// Static class of an expression *without* emitting code (used to
    /// pre-agree ternary arm classes).
    fn peek_cls(&self, e: &CExpr) -> Result<Cls, Unsup> {
        Ok(match e {
            CExpr::I(_) | CExpr::B(_) => Cls::I,
            CExpr::F(_) => Cls::F,
            CExpr::Var(slot) => self.slot_cls[*slot as usize],
            CExpr::Unary(op, inner) => match op {
                UnOp::Neg => self.peek_cls(inner)?,
                UnOp::Not | UnOp::BitNot => Cls::I,
            },
            CExpr::Binary(op, lhs, rhs) => match op {
                BinOp::Add | BinOp::Sub | BinOp::Mul | BinOp::Div | BinOp::Rem => {
                    if self.peek_cls(lhs)? == Cls::F || self.peek_cls(rhs)? == Cls::F {
                        Cls::F
                    } else {
                        Cls::I
                    }
                }
                _ => Cls::I,
            },
            CExpr::Load { buf, .. } => cls_of(self.buf_elems[*buf as usize]),
            CExpr::TexRead { buf, .. } => cls_of(self.buf_elems[*buf as usize]),
            CExpr::Call1(f, inner) => {
                if *f == Fn1::Abs {
                    self.peek_cls(inner)?
                } else {
                    Cls::F
                }
            }
            CExpr::Call2(f, a, b) => match f {
                Fn2::Pow => Cls::F,
                Fn2::Min | Fn2::Max => {
                    let (ca, cb) = (self.peek_cls(a)?, self.peek_cls(b)?);
                    if ca != cb {
                        return Err(Unsup);
                    }
                    ca
                }
            },
            CExpr::Clamp(v, lo, hi) => {
                if self.peek_cls(v)? == Cls::F
                    || self.peek_cls(lo)? == Cls::F
                    || self.peek_cls(hi)? == Cls::F
                {
                    Cls::F
                } else {
                    Cls::I
                }
            }
            CExpr::Ternary(_, t, e2) => {
                let (ct, ce) = (self.peek_cls(t)?, self.peek_cls(e2)?);
                if ct != ce {
                    return Err(Unsup);
                }
                ct
            }
            CExpr::Cast(ty, _) => cls_of(*ty),
        })
    }

    fn binary(&mut self, op: BinOp, lhs: &CExpr, rhs: &CExpr) -> Result<(Cls, u16), Unsup> {
        use BinOp::*;
        // Short-circuit logical ops (must not evaluate rhs eagerly).
        if op == And || op == Or {
            let d = self.ti();
            self.ops.push(Op::IConst { d, v: (op == Or) as i64 });
            let lv = self.expr(lhs)?;
            let c1 = self.as_truth(lv);
            let skip = self.here();
            self.ops.push(match op {
                And => Op::Jz { c: c1, t: 0 },
                _ => Op::Jnz { c: c1, t: 0 },
            });
            let rv = self.expr(rhs)?;
            let c2 = self.as_truth(rv);
            self.ops.push(Op::INorm { d, s: c2 });
            self.patch(skip);
            return Ok((Cls::I, d));
        }
        // Fused multiply-add for the ubiquitous `y * stride + x` pattern
        // (all-integer only; wrapping semantics compose identically).
        if op == Add {
            if let Some(r) = self.try_muladd(lhs, rhs)? {
                return Ok(r);
            }
        }
        let lv = self.expr(lhs)?;
        let rv = self.expr(rhs)?;
        self.binop_regs(op, lv, rv)
    }

    /// `a * b + c` / `c + a * b` with all-int operands → `IMulAdd`.
    fn try_muladd(
        &mut self,
        lhs: &CExpr,
        rhs: &CExpr,
    ) -> Result<Option<(Cls, u16)>, Unsup> {
        // Only the `a*b + c` form fuses: evaluation order must match the
        // tree-walker (lhs fully before rhs, and loads can trap), which
        // IMulAdd's a, b, c operand order preserves naturally.
        let (mul, addend) = match (lhs, rhs) {
            (CExpr::Binary(BinOp::Mul, a, b), c) => ((a, b), c),
            _ => return Ok(None),
        };
        if self.peek_cls(mul.0)? != Cls::I
            || self.peek_cls(mul.1)? != Cls::I
            || self.peek_cls(addend)? != Cls::I
        {
            return Ok(None);
        }
        let av = self.expr(mul.0)?;
        let bv = self.expr(mul.1)?;
        let cv = self.expr(addend)?;
        let d = self.ti();
        self.ops.push(Op::IMulAdd { d, a: av.1, b: bv.1, c: cv.1 });
        Ok(Some((Cls::I, d)))
    }

    fn load(&mut self, buf: u32, idx: u16) -> (Cls, u16) {
        let buf = buf as u16;
        let elem = self.buf_elems[buf as usize];
        if elem.is_float() {
            let d = self.tf();
            self.ops.push(Op::LoadF { d, buf, idx });
            (Cls::F, d)
        } else if elem == ScalarType::Bool {
            let d = self.ti();
            self.ops.push(Op::LoadB { d, buf, idx });
            (Cls::I, d)
        } else {
            let d = self.ti();
            self.ops.push(Op::LoadI { d, buf, idx });
            (Cls::I, d)
        }
    }

    /// Emit the store of `v` into `buf` (element-type conversion baked in).
    fn store(&mut self, buf: u16, idx: u16, v: (Cls, u16)) {
        let ty = self.buf_elems[buf as usize];
        if ty.is_float() {
            let s = self.as_f(v);
            self.ops.push(Op::StoreF { buf, idx, s, ty });
        } else {
            let s = self.as_i(v);
            self.ops.push(Op::StoreI { buf, idx, s, ty });
        }
    }

    fn tex_store(&mut self, buf: u16, x: u16, y: u16, v: (Cls, u16)) {
        let ty = self.buf_elems[buf as usize];
        if ty.is_float() {
            let s = self.as_f(v);
            self.ops.push(Op::TexStoreF { buf, x, y, s, ty });
        } else {
            let s = self.as_i(v);
            self.ops.push(Op::TexStoreI { buf, x, y, s, ty });
        }
    }

    fn stmts(&mut self, stmts: &[CStmt]) -> Result<(), Unsup> {
        for s in stmts {
            // Expression temporaries never outlive their statement.
            let (ti0, tf0) = (self.ti_next, self.tf_next);
            self.stmt(s)?;
            self.ti_next = ti0;
            self.tf_next = tf0;
        }
        Ok(())
    }

    fn stmt(&mut self, s: &CStmt) -> Result<(), Unsup> {
        match s {
            CStmt::SetVar { slot, ty, value } => {
                let v = self.expr(value)?;
                let (c, r) = self.cast(v, *ty);
                debug_assert_eq!(c, self.slot_cls[*slot as usize]);
                self.mov(c, self.slot_reg[*slot as usize], r);
            }
            CStmt::Store { buf, idx, value, op } => {
                let buf = *buf as u16;
                let iv = self.expr(idx)?;
                let idx = self.as_i(iv);
                let v = self.expr(value)?;
                let v = match op {
                    None => v,
                    Some(b) => {
                        let cur = self.load(buf as u32, idx);
                        self.binop_regs(*b, cur, v)?
                    }
                };
                self.store(buf, idx, v);
            }
            CStmt::TexWrite { buf, x, y, value } => {
                let xv = self.expr(x)?;
                let x = self.as_i(xv);
                let yv = self.expr(y)?;
                let y = self.as_i(yv);
                let v = self.expr(value)?;
                self.tex_store(*buf as u16, x, y, v);
            }
            CStmt::If { cond, then, els } => {
                let cv = self.expr(cond)?;
                let c = self.as_truth(cv);
                let jz = self.here();
                self.ops.push(Op::Jz { c, t: 0 });
                self.stmts(then)?;
                if els.is_empty() {
                    self.patch(jz);
                } else {
                    let jend = self.here();
                    self.ops.push(Op::Jmp { t: 0 });
                    self.patch(jz);
                    self.stmts(els)?;
                    self.patch(jend);
                }
            }
            CStmt::For { slot, init, cond, step, body } => {
                let ctr = self.slot_reg[*slot as usize];
                if self.slot_cls[*slot as usize] != Cls::I {
                    return Err(Unsup);
                }
                let iv = self.expr(init)?;
                let i = self.as_i(iv);
                self.mov(Cls::I, ctr, i);
                let head = self.here();
                let cv = self.expr(cond)?;
                let c = self.as_truth(cv);
                let jexit = self.here();
                self.ops.push(Op::Jz { c, t: 0 });
                self.stmts(body)?;
                let sv = self.expr(step)?;
                let st = self.as_i(sv);
                self.ops.push(Op::IAdd { d: ctr, a: ctr, b: st });
                self.ops.push(Op::Jmp { t: head });
                self.patch(jexit);
            }
            CStmt::While { cond, body } => {
                let cnt = self.ti();
                let one = self.ti();
                let cap = self.ti();
                let t = self.ti();
                self.ops.push(Op::IConst { d: cnt, v: 0 });
                self.ops.push(Op::IConst { d: one, v: 1 });
                self.ops.push(Op::IConst { d: cap, v: MAX_WHILE as i64 });
                let head = self.here();
                let cv = self.expr(cond)?;
                let c = self.as_truth(cv);
                let jexit = self.here();
                self.ops.push(Op::Jz { c, t: 0 });
                self.stmts(body)?;
                self.ops.push(Op::IAdd { d: cnt, a: cnt, b: one });
                self.ops.push(Op::ICmp { p: Pred::Gt, d: t, a: cnt, b: cap });
                let jrun = self.here();
                self.ops.push(Op::Jnz { c: t, t: 0 });
                self.ops.push(Op::Jmp { t: head });
                self.patch(jrun);
                self.ops.push(Op::Runaway);
                // Jz target: past the Runaway trap.
                let end = self.here();
                match &mut self.ops[jexit as usize] {
                    Op::Jz { t, .. } => *t = end,
                    _ => unreachable!(),
                }
            }
            CStmt::Return => self.ops.push(Op::Ret),
            CStmt::Eval(e) => {
                self.expr(e)?;
            }
        }
        Ok(())
    }

    /// Apply a binop to two already-evaluated registers — the shared
    /// emitter behind [`Self::binary`] and compound stores. The And/Or
    /// arm is the *non*-short-circuit form (both sides already
    /// evaluated), reached only from compound stores, mirroring the
    /// tree-walker's `binop`.
    fn binop_regs(
        &mut self,
        op: BinOp,
        lv: (Cls, u16),
        rv: (Cls, u16),
    ) -> Result<(Cls, u16), Unsup> {
        use BinOp::*;
        let float = lv.0 == Cls::F || rv.0 == Cls::F;
        Ok(match op {
            Add | Sub | Mul | Div | Rem => {
                if float {
                    let a = self.as_f(lv);
                    let b = self.as_f(rv);
                    let d = self.tf();
                    self.ops.push(match op {
                        Add => Op::FAdd { d, a, b },
                        Sub => Op::FSub { d, a, b },
                        Mul => Op::FMul { d, a, b },
                        Div => Op::FDiv { d, a, b },
                        _ => Op::FRem { d, a, b },
                    });
                    (Cls::F, d)
                } else {
                    let d = self.ti();
                    self.ops.push(match op {
                        Add => Op::IAdd { d, a: lv.1, b: rv.1 },
                        Sub => Op::ISub { d, a: lv.1, b: rv.1 },
                        Mul => Op::IMul { d, a: lv.1, b: rv.1 },
                        Div => Op::IDiv { d, a: lv.1, b: rv.1 },
                        _ => Op::IRem { d, a: lv.1, b: rv.1 },
                    });
                    (Cls::I, d)
                }
            }
            Eq | Ne | Lt | Gt | Le | Ge => {
                let p = match op {
                    Eq => Pred::Eq,
                    Ne => Pred::Ne,
                    Lt => Pred::Lt,
                    Gt => Pred::Gt,
                    Le => Pred::Le,
                    _ => Pred::Ge,
                };
                let d = self.ti();
                if float {
                    let a = self.as_f(lv);
                    let b = self.as_f(rv);
                    self.ops.push(Op::FCmp { p, d, a, b });
                } else {
                    self.ops.push(Op::ICmp { p, d, a: lv.1, b: rv.1 });
                }
                (Cls::I, d)
            }
            And | Or => {
                // Non-short-circuit here (both sides already evaluated),
                // matching the tree-walker's `binop` used by compound
                // stores.
                let a = self.as_truth(lv);
                let b = self.as_truth(rv);
                let an = self.ti();
                self.ops.push(Op::INorm { d: an, s: a });
                let bn = self.ti();
                self.ops.push(Op::INorm { d: bn, s: b });
                let d = self.ti();
                self.ops.push(match op {
                    And => Op::IBitAnd { d, a: an, b: bn },
                    _ => Op::IBitOr { d, a: an, b: bn },
                });
                (Cls::I, d)
            }
            BitAnd | BitOr | BitXor | Shl | Shr => {
                let a = self.as_i(lv);
                let b = self.as_i(rv);
                let d = self.ti();
                self.ops.push(match op {
                    BitAnd => Op::IBitAnd { d, a, b },
                    BitOr => Op::IBitOr { d, a, b },
                    BitXor => Op::IBitXor { d, a, b },
                    Shl => Op::IShl { d, a, b },
                    _ => Op::IShr { d, a, b },
                });
                (Cls::I, d)
            }
        })
    }
}

// ---------------------------------------------------------------------
// Execution
// ---------------------------------------------------------------------

/// Runtime trap raised by the interpreter loop (converted to [`ExecError`]
/// with buffer names attached by the driver).
#[derive(Debug, Clone, Copy)]
enum Trap {
    Oob { buf: u16, index: i64 },
    NotImage { buf: u16 },
    DivByZero,
    Runaway,
}

/// A raw view of one buffer's storage for the interpreter: pointer + len,
/// plus image extent (`w < 0` = not an image). Work-groups write disjoint
/// elements (proven by the plan's write-set analysis) so concurrent
/// threads may hold copies of the same view.
#[derive(Debug, Clone, Copy)]
struct RawBuf {
    ptr: *mut f64,
    len: usize,
    w: i64,
    h: i64,
}

impl RawBuf {
    fn of(slot: &mut BufSlot) -> RawBuf {
        let (w, h) = match slot {
            BufSlot::Image { w, h, .. } => (*w as i64, *h as i64),
            _ => (-1, -1),
        };
        let buf = slot.buffer_mut();
        RawBuf { ptr: buf.data.as_mut_ptr(), len: buf.data.len(), w, h }
    }

    #[inline(always)]
    fn read(&self, i: usize) -> f64 {
        debug_assert!(i < self.len);
        unsafe { *self.ptr.add(i) }
    }

    #[inline(always)]
    fn write(&self, i: usize, v: f64) {
        debug_assert!(i < self.len);
        unsafe { *self.ptr.add(i) = v }
    }
}

/// The shared per-launch buffer table (argument buffers only; locals are
/// per-thread). Safety: threads only run concurrently when the plan
/// proved all writes disjoint (`parallel_groups`).
struct SharedBufs(Vec<RawBuf>);
unsafe impl Sync for SharedBufs {}

#[inline(always)]
fn ri_get(ri: &[i64], r: u16) -> i64 {
    debug_assert!((r as usize) < ri.len());
    unsafe { *ri.get_unchecked(r as usize) }
}

#[inline(always)]
fn ri_set(ri: &mut [i64], r: u16, v: i64) {
    debug_assert!((r as usize) < ri.len());
    unsafe { *ri.get_unchecked_mut(r as usize) = v }
}

#[inline(always)]
fn rf_get(rf: &[f64], r: u16) -> f64 {
    debug_assert!((r as usize) < rf.len());
    unsafe { *rf.get_unchecked(r as usize) }
}

#[inline(always)]
fn rf_set(rf: &mut [f64], r: u16, v: f64) {
    debug_assert!((r as usize) < rf.len());
    unsafe { *rf.get_unchecked_mut(r as usize) = v }
}

/// `store_as` for an int register (C integer-wrap per element type).
#[inline(always)]
pub(crate) fn wrap_store(ty: ScalarType, v: i64) -> f64 {
    match ty {
        ScalarType::I32 => v as i32 as f64,
        ScalarType::U32 => v as u32 as f64,
        ScalarType::I16 => v as i16 as f64,
        ScalarType::U16 => v as u16 as f64,
        ScalarType::I8 => v as i8 as f64,
        ScalarType::U8 => v as u8 as f64,
        ScalarType::Bool => (v != 0) as i64 as f64,
        // Float stores go through `StoreF`.
        ScalarType::F32 | ScalarType::F64 => v as f64,
    }
}

#[inline(always)]
pub(crate) fn wrap_int(ty: ScalarType, v: i64) -> i64 {
    match ty {
        ScalarType::I32 => v as i32 as i64,
        ScalarType::U32 => v as u32 as i64,
        ScalarType::I16 => v as i16 as i64,
        ScalarType::U16 => v as u16 as i64,
        ScalarType::I8 => v as i8 as i64,
        ScalarType::U8 => v as u8 as i64,
        _ => v,
    }
}

#[inline(always)]
pub(crate) fn pred_i(p: Pred, a: i64, b: i64) -> i64 {
    (match p {
        Pred::Eq => a == b,
        Pred::Ne => a != b,
        Pred::Lt => a < b,
        Pred::Gt => a > b,
        Pred::Le => a <= b,
        Pred::Ge => a >= b,
    }) as i64
}

#[inline(always)]
pub(crate) fn pred_f(p: Pred, a: f64, b: f64) -> i64 {
    (match p {
        Pred::Eq => a == b,
        Pred::Ne => a != b,
        Pred::Lt => a < b,
        Pred::Gt => a > b,
        Pred::Le => a <= b,
        Pred::Ge => a >= b,
    }) as i64
}

/// Execute one phase's bytecode for one work-item.
fn run_ops(
    ops: &[Op],
    ri: &mut [i64],
    rf: &mut [f64],
    bufs: &[RawBuf],
) -> Result<(), Trap> {
    let mut pc = 0usize;
    while pc < ops.len() {
        match ops[pc] {
            Op::IConst { d, v } => ri_set(ri, d, v),
            Op::FConst { d, v } => rf_set(rf, d, v),
            Op::IMov { d, s } => ri_set(ri, d, ri_get(ri, s)),
            Op::FMov { d, s } => rf_set(rf, d, rf_get(rf, s)),
            Op::IToF { d, s } => rf_set(rf, d, ri_get(ri, s) as f64),
            Op::FToI { d, s } => ri_set(ri, d, rf_get(rf, s) as i64),
            Op::IWrap { d, s, ty } => ri_set(ri, d, wrap_int(ty, ri_get(ri, s))),
            Op::F32Round { d, s } => rf_set(rf, d, rf_get(rf, s) as f32 as f64),
            Op::FNonZero { d, s } => ri_set(ri, d, (rf_get(rf, s) != 0.0) as i64),
            Op::INorm { d, s } => ri_set(ri, d, (ri_get(ri, s) != 0) as i64),

            Op::IAdd { d, a, b } => {
                ri_set(ri, d, ri_get(ri, a).wrapping_add(ri_get(ri, b)))
            }
            Op::ISub { d, a, b } => {
                ri_set(ri, d, ri_get(ri, a).wrapping_sub(ri_get(ri, b)))
            }
            Op::IMul { d, a, b } => {
                ri_set(ri, d, ri_get(ri, a).wrapping_mul(ri_get(ri, b)))
            }
            Op::IMulAdd { d, a, b, c } => ri_set(
                ri,
                d,
                ri_get(ri, a).wrapping_mul(ri_get(ri, b)).wrapping_add(ri_get(ri, c)),
            ),
            Op::IDiv { d, a, b } => {
                let bv = ri_get(ri, b);
                if bv == 0 {
                    return Err(Trap::DivByZero);
                }
                ri_set(ri, d, ri_get(ri, a) / bv);
            }
            Op::IRem { d, a, b } => {
                let bv = ri_get(ri, b);
                if bv == 0 {
                    return Err(Trap::DivByZero);
                }
                ri_set(ri, d, ri_get(ri, a) % bv);
            }
            Op::INeg { d, s } => ri_set(ri, d, ri_get(ri, s).wrapping_neg()),
            Op::INot { d, s } => ri_set(ri, d, (ri_get(ri, s) == 0) as i64),
            Op::IBitNot { d, s } => ri_set(ri, d, !ri_get(ri, s)),
            Op::IBitAnd { d, a, b } => ri_set(ri, d, ri_get(ri, a) & ri_get(ri, b)),
            Op::IBitOr { d, a, b } => ri_set(ri, d, ri_get(ri, a) | ri_get(ri, b)),
            Op::IBitXor { d, a, b } => ri_set(ri, d, ri_get(ri, a) ^ ri_get(ri, b)),
            Op::IShl { d, a, b } => {
                ri_set(ri, d, ri_get(ri, a).wrapping_shl(ri_get(ri, b) as u32))
            }
            Op::IShr { d, a, b } => {
                ri_set(ri, d, ri_get(ri, a).wrapping_shr(ri_get(ri, b) as u32))
            }
            Op::IMin { d, a, b } => ri_set(ri, d, ri_get(ri, a).min(ri_get(ri, b))),
            Op::IMax { d, a, b } => ri_set(ri, d, ri_get(ri, a).max(ri_get(ri, b))),
            Op::IClamp { d, v, lo, hi } => {
                ri_set(ri, d, ri_get(ri, v).clamp(ri_get(ri, lo), ri_get(ri, hi)))
            }
            Op::IAbs { d, s } => ri_set(ri, d, ri_get(ri, s).abs()),
            Op::ICmp { p, d, a, b } => {
                ri_set(ri, d, pred_i(p, ri_get(ri, a), ri_get(ri, b)))
            }

            Op::FAdd { d, a, b } => rf_set(rf, d, rf_get(rf, a) + rf_get(rf, b)),
            Op::FSub { d, a, b } => rf_set(rf, d, rf_get(rf, a) - rf_get(rf, b)),
            Op::FMul { d, a, b } => rf_set(rf, d, rf_get(rf, a) * rf_get(rf, b)),
            Op::FDiv { d, a, b } => rf_set(rf, d, rf_get(rf, a) / rf_get(rf, b)),
            Op::FRem { d, a, b } => rf_set(rf, d, rf_get(rf, a) % rf_get(rf, b)),
            Op::FNeg { d, s } => rf_set(rf, d, -rf_get(rf, s)),
            Op::FMin { d, a, b } => {
                let (av, bv) = (rf_get(rf, a), rf_get(rf, b));
                rf_set(rf, d, if av <= bv { av } else { bv });
            }
            Op::FMax { d, a, b } => {
                let (av, bv) = (rf_get(rf, a), rf_get(rf, b));
                rf_set(rf, d, if av <= bv { bv } else { av });
            }
            Op::FClamp { d, v, lo, hi } => {
                rf_set(rf, d, rf_get(rf, v).clamp(rf_get(rf, lo), rf_get(rf, hi)))
            }
            Op::FCmp { p, d, a, b } => {
                ri_set(ri, d, pred_f(p, rf_get(rf, a), rf_get(rf, b)))
            }
            Op::Math1 { f, d, s } => {
                let v = rf_get(rf, s);
                rf_set(
                    rf,
                    d,
                    match f {
                        Fn1::Sqrt => v.sqrt(),
                        Fn1::Rsqrt => 1.0 / v.sqrt(),
                        Fn1::Fabs | Fn1::Abs => v.abs(),
                        Fn1::Exp => v.exp(),
                        Fn1::Log => v.ln(),
                        Fn1::Sin => v.sin(),
                        Fn1::Cos => v.cos(),
                        Fn1::Floor => v.floor(),
                        Fn1::Ceil => v.ceil(),
                    },
                );
            }
            Op::FPow { d, a, b } => {
                rf_set(rf, d, rf_get(rf, a).powf(rf_get(rf, b)))
            }

            Op::Jmp { t } => {
                pc = t as usize;
                continue;
            }
            Op::Jz { c, t } => {
                if ri_get(ri, c) == 0 {
                    pc = t as usize;
                    continue;
                }
            }
            Op::Jnz { c, t } => {
                if ri_get(ri, c) != 0 {
                    pc = t as usize;
                    continue;
                }
            }

            Op::LoadF { d, buf, idx } => {
                let b = &bufs[buf as usize];
                let i = ri_get(ri, idx);
                if (i as u64) >= b.len as u64 {
                    return Err(Trap::Oob { buf, index: i });
                }
                rf_set(rf, d, b.read(i as usize));
            }
            Op::LoadI { d, buf, idx } => {
                let b = &bufs[buf as usize];
                let i = ri_get(ri, idx);
                if (i as u64) >= b.len as u64 {
                    return Err(Trap::Oob { buf, index: i });
                }
                ri_set(ri, d, b.read(i as usize) as i64);
            }
            Op::LoadB { d, buf, idx } => {
                let b = &bufs[buf as usize];
                let i = ri_get(ri, idx);
                if (i as u64) >= b.len as u64 {
                    return Err(Trap::Oob { buf, index: i });
                }
                ri_set(ri, d, (b.read(i as usize) != 0.0) as i64);
            }
            Op::StoreF { buf, idx, s, ty } => {
                let b = &bufs[buf as usize];
                let i = ri_get(ri, idx);
                if (i as u64) >= b.len as u64 {
                    return Err(Trap::Oob { buf, index: i });
                }
                let v = rf_get(rf, s);
                b.write(i as usize, if ty == ScalarType::F32 { v as f32 as f64 } else { v });
            }
            Op::StoreI { buf, idx, s, ty } => {
                let b = &bufs[buf as usize];
                let i = ri_get(ri, idx);
                if (i as u64) >= b.len as u64 {
                    return Err(Trap::Oob { buf, index: i });
                }
                b.write(i as usize, wrap_store(ty, ri_get(ri, s)));
            }
            Op::TexLoadF { d, buf, x, y } => {
                let b = &bufs[buf as usize];
                if b.w < 0 {
                    return Err(Trap::NotImage { buf });
                }
                let (xi, yi) = (ri_get(ri, x), ri_get(ri, y));
                if xi < 0 || yi < 0 || xi >= b.w || yi >= b.h {
                    return Err(Trap::Oob { buf, index: yi * b.w + xi });
                }
                rf_set(rf, d, b.read((yi * b.w + xi) as usize));
            }
            Op::TexLoadI { d, buf, x, y } => {
                let b = &bufs[buf as usize];
                if b.w < 0 {
                    return Err(Trap::NotImage { buf });
                }
                let (xi, yi) = (ri_get(ri, x), ri_get(ri, y));
                if xi < 0 || yi < 0 || xi >= b.w || yi >= b.h {
                    return Err(Trap::Oob { buf, index: yi * b.w + xi });
                }
                ri_set(ri, d, b.read((yi * b.w + xi) as usize) as i64);
            }
            Op::TexStoreF { buf, x, y, s, ty } => {
                let b = &bufs[buf as usize];
                if b.w < 0 {
                    return Err(Trap::NotImage { buf });
                }
                let (xi, yi) = (ri_get(ri, x), ri_get(ri, y));
                if xi < 0 || yi < 0 || xi >= b.w || yi >= b.h {
                    return Err(Trap::Oob { buf, index: yi * b.w + xi });
                }
                let v = rf_get(rf, s);
                b.write(
                    (yi * b.w + xi) as usize,
                    if ty == ScalarType::F32 { v as f32 as f64 } else { v },
                );
            }
            Op::TexStoreI { buf, x, y, s, ty } => {
                let b = &bufs[buf as usize];
                if b.w < 0 {
                    return Err(Trap::NotImage { buf });
                }
                let (xi, yi) = (ri_get(ri, x), ri_get(ri, y));
                if xi < 0 || yi < 0 || xi >= b.w || yi >= b.h {
                    return Err(Trap::Oob { buf, index: yi * b.w + xi });
                }
                b.write((yi * b.w + xi) as usize, wrap_store(ty, ri_get(ri, s)));
            }

            Op::Runaway => return Err(Trap::Runaway),
            Op::Ret => return Ok(()),
            Op::Nop => {}
        }
        pc += 1;
    }
    Ok(())
}

/// Buffer-free scalar execution for optimizer unit tests (`Trap` mapped
/// to a debug string since no buffer names exist here).
#[cfg(test)]
pub(crate) fn run_ops_pure(
    ops: &[Op],
    ri: &mut [i64],
    rf: &mut [f64],
) -> Result<(), String> {
    run_ops(ops, ri, rf, &[]).map_err(|t| format!("{t:?}"))
}

/// Execute a straight-line trace for up to [`LANES`] work-items at once.
/// Registers are lane arrays: pure arithmetic runs full-width (the shape
/// the autovectorizer turns into SIMD), while anything that can trap,
/// panic or touch memory — loads, stores, texture ops, div/rem, clamps,
/// `abs` — covers only the `n` *active* lanes (inactive lanes hold
/// garbage from earlier batches). The trace must be branch-free, which
/// [`opt::specialize`] guarantees.
///
/// Success outputs are bit-identical to scalar execution (same ops, same
/// order per item, and items were proven to write disjoint elements). On
/// a *trap*, which item's trap surfaces first can differ from the serial
/// item order — error states are not part of the bit-identity contract.
#[allow(clippy::needless_range_loop)]
fn run_ops_batch(
    ops: &[Op],
    ri: &mut [[i64; LANES]],
    rf: &mut [[f64; LANES]],
    bufs: &[RawBuf],
    n: usize,
) -> Result<(), Trap> {
    debug_assert!(n >= 1 && n <= LANES);
    for op in ops {
        match *op {
            Op::IConst { d, v } => ri[d as usize] = [v; LANES],
            Op::FConst { d, v } => rf[d as usize] = [v; LANES],
            Op::IMov { d, s } => ri[d as usize] = ri[s as usize],
            Op::FMov { d, s } => rf[d as usize] = rf[s as usize],
            Op::IToF { d, s } => {
                let x = ri[s as usize];
                let o = &mut rf[d as usize];
                for l in 0..LANES {
                    o[l] = x[l] as f64;
                }
            }
            Op::FToI { d, s } => {
                let x = rf[s as usize];
                let o = &mut ri[d as usize];
                for l in 0..LANES {
                    o[l] = x[l] as i64;
                }
            }
            Op::IWrap { d, s, ty } => {
                let x = ri[s as usize];
                let o = &mut ri[d as usize];
                for l in 0..LANES {
                    o[l] = wrap_int(ty, x[l]);
                }
            }
            Op::F32Round { d, s } => {
                let x = rf[s as usize];
                let o = &mut rf[d as usize];
                for l in 0..LANES {
                    o[l] = x[l] as f32 as f64;
                }
            }
            Op::FNonZero { d, s } => {
                let x = rf[s as usize];
                let o = &mut ri[d as usize];
                for l in 0..LANES {
                    o[l] = (x[l] != 0.0) as i64;
                }
            }
            Op::INorm { d, s } => {
                let x = ri[s as usize];
                let o = &mut ri[d as usize];
                for l in 0..LANES {
                    o[l] = (x[l] != 0) as i64;
                }
            }

            Op::IAdd { d, a, b } => {
                let (x, y) = (ri[a as usize], ri[b as usize]);
                let o = &mut ri[d as usize];
                for l in 0..LANES {
                    o[l] = x[l].wrapping_add(y[l]);
                }
            }
            Op::ISub { d, a, b } => {
                let (x, y) = (ri[a as usize], ri[b as usize]);
                let o = &mut ri[d as usize];
                for l in 0..LANES {
                    o[l] = x[l].wrapping_sub(y[l]);
                }
            }
            Op::IMul { d, a, b } => {
                let (x, y) = (ri[a as usize], ri[b as usize]);
                let o = &mut ri[d as usize];
                for l in 0..LANES {
                    o[l] = x[l].wrapping_mul(y[l]);
                }
            }
            Op::IMulAdd { d, a, b, c } => {
                let (x, y, z) = (ri[a as usize], ri[b as usize], ri[c as usize]);
                let o = &mut ri[d as usize];
                for l in 0..LANES {
                    o[l] = x[l].wrapping_mul(y[l]).wrapping_add(z[l]);
                }
            }
            Op::IDiv { d, a, b } => {
                let (x, y) = (ri[a as usize], ri[b as usize]);
                let o = &mut ri[d as usize];
                for l in 0..n {
                    if y[l] == 0 {
                        return Err(Trap::DivByZero);
                    }
                    o[l] = x[l] / y[l];
                }
            }
            Op::IRem { d, a, b } => {
                let (x, y) = (ri[a as usize], ri[b as usize]);
                let o = &mut ri[d as usize];
                for l in 0..n {
                    if y[l] == 0 {
                        return Err(Trap::DivByZero);
                    }
                    o[l] = x[l] % y[l];
                }
            }
            Op::INeg { d, s } => {
                let x = ri[s as usize];
                let o = &mut ri[d as usize];
                for l in 0..LANES {
                    o[l] = x[l].wrapping_neg();
                }
            }
            Op::INot { d, s } => {
                let x = ri[s as usize];
                let o = &mut ri[d as usize];
                for l in 0..LANES {
                    o[l] = (x[l] == 0) as i64;
                }
            }
            Op::IBitNot { d, s } => {
                let x = ri[s as usize];
                let o = &mut ri[d as usize];
                for l in 0..LANES {
                    o[l] = !x[l];
                }
            }
            Op::IBitAnd { d, a, b } => {
                let (x, y) = (ri[a as usize], ri[b as usize]);
                let o = &mut ri[d as usize];
                for l in 0..LANES {
                    o[l] = x[l] & y[l];
                }
            }
            Op::IBitOr { d, a, b } => {
                let (x, y) = (ri[a as usize], ri[b as usize]);
                let o = &mut ri[d as usize];
                for l in 0..LANES {
                    o[l] = x[l] | y[l];
                }
            }
            Op::IBitXor { d, a, b } => {
                let (x, y) = (ri[a as usize], ri[b as usize]);
                let o = &mut ri[d as usize];
                for l in 0..LANES {
                    o[l] = x[l] ^ y[l];
                }
            }
            Op::IShl { d, a, b } => {
                let (x, y) = (ri[a as usize], ri[b as usize]);
                let o = &mut ri[d as usize];
                for l in 0..LANES {
                    o[l] = x[l].wrapping_shl(y[l] as u32);
                }
            }
            Op::IShr { d, a, b } => {
                let (x, y) = (ri[a as usize], ri[b as usize]);
                let o = &mut ri[d as usize];
                for l in 0..LANES {
                    o[l] = x[l].wrapping_shr(y[l] as u32);
                }
            }
            Op::IMin { d, a, b } => {
                let (x, y) = (ri[a as usize], ri[b as usize]);
                let o = &mut ri[d as usize];
                for l in 0..LANES {
                    o[l] = x[l].min(y[l]);
                }
            }
            Op::IMax { d, a, b } => {
                let (x, y) = (ri[a as usize], ri[b as usize]);
                let o = &mut ri[d as usize];
                for l in 0..LANES {
                    o[l] = x[l].max(y[l]);
                }
            }
            Op::IClamp { d, v, lo, hi } => {
                // Active lanes only: `clamp` panics on inverted bounds,
                // and inactive-lane garbage must not fault spuriously.
                let (x, l0, h0) = (ri[v as usize], ri[lo as usize], ri[hi as usize]);
                let o = &mut ri[d as usize];
                for l in 0..n {
                    o[l] = x[l].clamp(l0[l], h0[l]);
                }
            }
            Op::IAbs { d, s } => {
                // Active lanes only: `i64::MIN.abs()` panics.
                let x = ri[s as usize];
                let o = &mut ri[d as usize];
                for l in 0..n {
                    o[l] = x[l].abs();
                }
            }
            Op::ICmp { p, d, a, b } => {
                let (x, y) = (ri[a as usize], ri[b as usize]);
                let o = &mut ri[d as usize];
                for l in 0..LANES {
                    o[l] = pred_i(p, x[l], y[l]);
                }
            }

            Op::FAdd { d, a, b } => {
                let (x, y) = (rf[a as usize], rf[b as usize]);
                let o = &mut rf[d as usize];
                for l in 0..LANES {
                    o[l] = x[l] + y[l];
                }
            }
            Op::FSub { d, a, b } => {
                let (x, y) = (rf[a as usize], rf[b as usize]);
                let o = &mut rf[d as usize];
                for l in 0..LANES {
                    o[l] = x[l] - y[l];
                }
            }
            Op::FMul { d, a, b } => {
                let (x, y) = (rf[a as usize], rf[b as usize]);
                let o = &mut rf[d as usize];
                for l in 0..LANES {
                    o[l] = x[l] * y[l];
                }
            }
            Op::FDiv { d, a, b } => {
                let (x, y) = (rf[a as usize], rf[b as usize]);
                let o = &mut rf[d as usize];
                for l in 0..LANES {
                    o[l] = x[l] / y[l];
                }
            }
            Op::FRem { d, a, b } => {
                let (x, y) = (rf[a as usize], rf[b as usize]);
                let o = &mut rf[d as usize];
                for l in 0..LANES {
                    o[l] = x[l] % y[l];
                }
            }
            Op::FNeg { d, s } => {
                let x = rf[s as usize];
                let o = &mut rf[d as usize];
                for l in 0..LANES {
                    o[l] = -x[l];
                }
            }
            Op::FMin { d, a, b } => {
                let (x, y) = (rf[a as usize], rf[b as usize]);
                let o = &mut rf[d as usize];
                for l in 0..LANES {
                    o[l] = if x[l] <= y[l] { x[l] } else { y[l] };
                }
            }
            Op::FMax { d, a, b } => {
                let (x, y) = (rf[a as usize], rf[b as usize]);
                let o = &mut rf[d as usize];
                for l in 0..LANES {
                    o[l] = if x[l] <= y[l] { y[l] } else { x[l] };
                }
            }
            Op::FClamp { d, v, lo, hi } => {
                // Active lanes only: `f64::clamp` panics on NaN bounds.
                let (x, l0, h0) = (rf[v as usize], rf[lo as usize], rf[hi as usize]);
                let o = &mut rf[d as usize];
                for l in 0..n {
                    o[l] = x[l].clamp(l0[l], h0[l]);
                }
            }
            Op::FCmp { p, d, a, b } => {
                let (x, y) = (rf[a as usize], rf[b as usize]);
                let o = &mut ri[d as usize];
                for l in 0..LANES {
                    o[l] = pred_f(p, x[l], y[l]);
                }
            }
            Op::Math1 { f, d, s } => {
                let x = rf[s as usize];
                let o = &mut rf[d as usize];
                for l in 0..n {
                    let v = x[l];
                    o[l] = match f {
                        Fn1::Sqrt => v.sqrt(),
                        Fn1::Rsqrt => 1.0 / v.sqrt(),
                        Fn1::Fabs | Fn1::Abs => v.abs(),
                        Fn1::Exp => v.exp(),
                        Fn1::Log => v.ln(),
                        Fn1::Sin => v.sin(),
                        Fn1::Cos => v.cos(),
                        Fn1::Floor => v.floor(),
                        Fn1::Ceil => v.ceil(),
                    };
                }
            }
            Op::FPow { d, a, b } => {
                let (x, y) = (rf[a as usize], rf[b as usize]);
                let o = &mut rf[d as usize];
                for l in 0..n {
                    o[l] = x[l].powf(y[l]);
                }
            }

            Op::LoadF { d, buf, idx } => {
                let bf = &bufs[buf as usize];
                let ix = ri[idx as usize];
                let o = &mut rf[d as usize];
                for l in 0..n {
                    let i = ix[l];
                    if (i as u64) >= bf.len as u64 {
                        return Err(Trap::Oob { buf, index: i });
                    }
                    o[l] = bf.read(i as usize);
                }
            }
            Op::LoadI { d, buf, idx } => {
                let bf = &bufs[buf as usize];
                let ix = ri[idx as usize];
                let o = &mut ri[d as usize];
                for l in 0..n {
                    let i = ix[l];
                    if (i as u64) >= bf.len as u64 {
                        return Err(Trap::Oob { buf, index: i });
                    }
                    o[l] = bf.read(i as usize) as i64;
                }
            }
            Op::LoadB { d, buf, idx } => {
                let bf = &bufs[buf as usize];
                let ix = ri[idx as usize];
                let o = &mut ri[d as usize];
                for l in 0..n {
                    let i = ix[l];
                    if (i as u64) >= bf.len as u64 {
                        return Err(Trap::Oob { buf, index: i });
                    }
                    o[l] = (bf.read(i as usize) != 0.0) as i64;
                }
            }
            Op::StoreF { buf, idx, s, ty } => {
                let bf = &bufs[buf as usize];
                let ix = ri[idx as usize];
                let v = rf[s as usize];
                for l in 0..n {
                    let i = ix[l];
                    if (i as u64) >= bf.len as u64 {
                        return Err(Trap::Oob { buf, index: i });
                    }
                    bf.write(
                        i as usize,
                        if ty == ScalarType::F32 { v[l] as f32 as f64 } else { v[l] },
                    );
                }
            }
            Op::StoreI { buf, idx, s, ty } => {
                let bf = &bufs[buf as usize];
                let ix = ri[idx as usize];
                let v = ri[s as usize];
                for l in 0..n {
                    let i = ix[l];
                    if (i as u64) >= bf.len as u64 {
                        return Err(Trap::Oob { buf, index: i });
                    }
                    bf.write(i as usize, wrap_store(ty, v[l]));
                }
            }
            Op::TexLoadF { d, buf, x, y } => {
                let bf = &bufs[buf as usize];
                if bf.w < 0 {
                    return Err(Trap::NotImage { buf });
                }
                let (xs, ys) = (ri[x as usize], ri[y as usize]);
                let o = &mut rf[d as usize];
                for l in 0..n {
                    let (xi, yi) = (xs[l], ys[l]);
                    if xi < 0 || yi < 0 || xi >= bf.w || yi >= bf.h {
                        return Err(Trap::Oob { buf, index: yi * bf.w + xi });
                    }
                    o[l] = bf.read((yi * bf.w + xi) as usize);
                }
            }
            Op::TexLoadI { d, buf, x, y } => {
                let bf = &bufs[buf as usize];
                if bf.w < 0 {
                    return Err(Trap::NotImage { buf });
                }
                let (xs, ys) = (ri[x as usize], ri[y as usize]);
                let o = &mut ri[d as usize];
                for l in 0..n {
                    let (xi, yi) = (xs[l], ys[l]);
                    if xi < 0 || yi < 0 || xi >= bf.w || yi >= bf.h {
                        return Err(Trap::Oob { buf, index: yi * bf.w + xi });
                    }
                    o[l] = bf.read((yi * bf.w + xi) as usize) as i64;
                }
            }
            Op::TexStoreF { buf, x, y, s, ty } => {
                let bf = &bufs[buf as usize];
                if bf.w < 0 {
                    return Err(Trap::NotImage { buf });
                }
                let (xs, ys) = (ri[x as usize], ri[y as usize]);
                let v = rf[s as usize];
                for l in 0..n {
                    let (xi, yi) = (xs[l], ys[l]);
                    if xi < 0 || yi < 0 || xi >= bf.w || yi >= bf.h {
                        return Err(Trap::Oob { buf, index: yi * bf.w + xi });
                    }
                    bf.write(
                        (yi * bf.w + xi) as usize,
                        if ty == ScalarType::F32 { v[l] as f32 as f64 } else { v[l] },
                    );
                }
            }
            Op::TexStoreI { buf, x, y, s, ty } => {
                let bf = &bufs[buf as usize];
                if bf.w < 0 {
                    return Err(Trap::NotImage { buf });
                }
                let (xs, ys) = (ri[x as usize], ri[y as usize]);
                let v = ri[s as usize];
                for l in 0..n {
                    let (xi, yi) = (xs[l], ys[l]);
                    if xi < 0 || yi < 0 || xi >= bf.w || yi >= bf.h {
                        return Err(Trap::Oob { buf, index: yi * bf.w + xi });
                    }
                    bf.write((yi * bf.w + xi) as usize, wrap_store(ty, v[l]));
                }
            }

            Op::Ret => return Ok(()),
            Op::Nop => {}
            Op::Jmp { .. } | Op::Jz { .. } | Op::Jnz { .. } | Op::Runaway => {
                unreachable!("control flow in a batched trace: {op:?}")
            }
        }
    }
    Ok(())
}

/// Execute one row of work-items (`lid_x` = 0..`wg0`, fixed `lid_y`)
/// through a specialized trace, [`LANES`] items per dispatch with a
/// short tail batch.
///
/// Lanes start from whatever the previous batch left in the registers —
/// no cross-item state is carried, only the builtin index registers are
/// (re)initialized. That is sound because the IR can never read a
/// variable slot before writing it within one item: every `Decl` lowers
/// to a `SetVar` (uninitialized declarations compile to an assignment of
/// 0 in `exec/compiled.rs`), sema rejects undeclared uses, and `For`
/// counters are written by their init before the first condition read.
/// The tree-walker's cross-item slot persistence is therefore
/// unobservable by any compilable program.
#[allow(clippy::too_many_arguments, clippy::needless_range_loop)]
fn run_row_batched(
    trace: &[Op],
    ri: &mut [[i64; LANES]],
    rf: &mut [[f64; LANES]],
    bufs: &[RawBuf],
    global: [usize; 2],
    wg0: usize,
    grp: (usize, usize),
    lid_y: usize,
    gid_y: usize,
) -> Result<(), Trap> {
    let base = grp.0 * wg0;
    let mut lid_x = 0usize;
    while lid_x < wg0 {
        let n = LANES.min(wg0 - lid_x);
        for l in 0..LANES {
            ri[SLOT_GID_X as usize][l] = (base + lid_x + l) as i64;
            ri[SLOT_LID_X as usize][l] = (lid_x + l) as i64;
        }
        ri[SLOT_GID_Y as usize] = [gid_y as i64; LANES];
        ri[SLOT_LID_Y as usize] = [lid_y as i64; LANES];
        ri[SLOT_GRP_X as usize] = [grp.0 as i64; LANES];
        ri[SLOT_GRP_Y as usize] = [grp.1 as i64; LANES];
        ri[SLOT_GDIM_X as usize] = [global[0] as i64; LANES];
        ri[SLOT_GDIM_Y as usize] = [global[1] as i64; LANES];
        run_ops_batch(trace, ri, rf, bufs, n)?;
        lid_x += n;
    }
    Ok(())
}

// ---------------------------------------------------------------------
// NDRange driver
// ---------------------------------------------------------------------

/// Can this launch's argument buffers execute on `prog`? The bytecode
/// baked in the *plan's* element types; a caller passing a buffer of a
/// different element type (legal for the tree-walker, which reads the
/// type off the buffer at runtime) must fall back.
pub(crate) fn args_match(prog: &VmProgram, bufs: &[BufSlot]) -> bool {
    bufs.len() == prog.buf_elems.len()
        && bufs
            .iter()
            .zip(&prog.buf_elems)
            .all(|(slot, &elem)| slot.buffer().elem == elem)
}

/// Execute the NDRange through the bytecode VM: work-groups (or, for
/// barrier-free plans with too few groups, work-item *rows*) in parallel
/// when the plan proved independence and the launch is big enough to pay
/// for threads, serially otherwise — bit-identical either way. With
/// `batch`, rows whose control flow the specializer can decide from the
/// group's index ranges execute through the batched lane interpreter;
/// border rows and data-dependent branches fall back to the scalar loop.
/// Returns what the launch did — row coverage, dispatch width,
/// specialization wall — for the execution-tier profiler; workers
/// count into locals and flush once, so the hot loops stay untouched.
pub(crate) fn run_ndrange(
    plan: &KernelPlan,
    compiled: &CompiledPlan,
    prog: &VmProgram,
    bufs: &mut [BufSlot],
    grid: (usize, usize),
    batch: bool,
) -> Result<profile::RunStats, ExecError> {
    let (global, wg) = plan.launch_dims(grid.0, grid.1);
    let groups = [global[0] / wg[0], global[1] / wg[1]];
    let n_groups = groups[0] * groups[1];
    let n_args = plan.buffers.len();

    let shared = SharedBufs(
        bufs[..n_args].iter_mut().map(RawBuf::of).collect(),
    );

    let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let par_ok = plan.parallel_groups && grid.0 * grid.1 >= PAR_MIN_PIXELS;
    // Row-granular partitioning when whole groups cannot keep the pool
    // busy (few large groups) — only for barrier-free single-phase plans
    // (`KernelPlan::row_parallel`), where splitting a group across
    // threads cannot violate barrier semantics or share local scratch.
    let unit_rows = par_ok
        && plan.row_parallel
        && wg[1] >= 2
        && n_groups < avail * ROW_PARTITION_FACTOR;
    let n_units = if unit_rows { n_groups * wg[1] } else { n_groups };
    let threads = if par_ok && n_units >= 2 { avail.min(n_units) } else { 1 };

    // Batched interpretation needs the per-*item* independence proof: a
    // batch interleaves several items' instruction streams, so items must
    // not communicate through buffers within a phase.
    let batch = batch && plan.batchable && wg[0] >= MIN_BATCH_WIDTH;

    // Launch-wide profiling tallies. Workers accumulate into plain
    // locals and flush here once at the end of their range, so the
    // per-row loops never touch shared state.
    use std::sync::atomic::{AtomicU64, Ordering};
    let tally_batched = AtomicU64::new(0);
    let tally_scalar = AtomicU64::new(0);
    let tally_spec_us = AtomicU64::new(0);

    let run_range = |range: std::ops::Range<usize>| -> Result<(), Trap> {
        let mut w_batched = 0u64;
        let mut w_scalar = 0u64;
        let mut w_spec_us = 0u64;
        let mut ri = vec![0i64; prog.n_ri];
        let mut rf = vec![0f64; prog.n_rf];
        let mut bri = vec![[0i64; LANES]; if batch { prog.n_ri } else { 0 }];
        let mut brf = vec![[0f64; LANES]; if batch { prog.n_rf } else { 0 }];
        // Local scratch: allocated once per worker, zero-reset between
        // groups (fresh-allocation semantics without the allocator).
        let mut locals: Vec<Buffer> =
            plan.locals.iter().map(|l| Buffer::new(l.elem, l.len)).collect();
        let mut view: Vec<RawBuf> = shared.0.clone();
        view.extend(locals.iter_mut().map(|b| RawBuf {
            ptr: b.data.as_mut_ptr(),
            len: b.data.len(),
            w: -1,
            h: -1,
        }));
        ri[SLOT_GDIM_X as usize] = global[0] as i64;
        ri[SLOT_GDIM_Y as usize] = global[1] as i64;
        // Specialized-trace cache, one entry per worker: (phase, group) →
        // the group-wide trace (`None` = this group needs per-row
        // specialization or the scalar loop) plus the count of failed
        // row-specialization attempts (capped by MAX_ROW_SPEC_FAILS so
        // never-specializing phases don't pay an interval walk per row).
        // Workers visit consecutive units, so one entry captures almost
        // all reuse.
        let mut tcache: Option<((usize, usize), Option<Vec<Op>>, u32)> = None;
        for u in range {
            let (g, only_row) = if unit_rows {
                (u / wg[1], Some(u % wg[1]))
            } else {
                (u, None)
            };
            let (grp_x, grp_y) = (g % groups[0], g / groups[0]);
            for l in &mut locals {
                l.data.fill(0.0);
            }
            ri[SLOT_GRP_X as usize] = grp_x as i64;
            ri[SLOT_GRP_Y as usize] = grp_y as i64;
            for (pi, phase) in prog.phases.iter().enumerate() {
                // Barrier semantics: every work-item finishes phase k
                // before any starts k+1. (Row units only exist for
                // single-phase plans, so a split group never spans a
                // barrier.)
                let rows = match only_row {
                    Some(r) => r..r + 1,
                    None => 0..wg[1],
                };
                for lid_y in rows {
                    let gid_y = grp_y * wg[1] + lid_y;
                    let mut batched = false;
                    if batch {
                        if tcache.as_ref().map(|(k, _, _)| *k) != Some((pi, g)) {
                            let env = opt::SpecEnv::for_group(
                                (grp_x, grp_y),
                                wg,
                                global,
                            );
                            let t0 = std::time::Instant::now();
                            let trace = opt::specialize(prog, pi, &env);
                            w_spec_us += t0.elapsed().as_micros() as u64;
                            tcache = Some(((pi, g), trace, 0));
                        }
                        let (_, group_trace, row_fails) =
                            tcache.as_mut().unwrap();
                        // Per-row fallback: the group straddles a border,
                        // but this row alone may still be decidable.
                        let row_trace;
                        let trace = match group_trace {
                            Some(t) => Some(&*t),
                            None if *row_fails < MAX_ROW_SPEC_FAILS => {
                                let env = opt::SpecEnv::for_row(
                                    (grp_x, grp_y),
                                    wg,
                                    global,
                                    lid_y,
                                );
                                let t0 = std::time::Instant::now();
                                row_trace = opt::specialize(prog, pi, &env);
                                w_spec_us += t0.elapsed().as_micros() as u64;
                                if row_trace.is_none() {
                                    *row_fails += 1;
                                }
                                row_trace.as_ref()
                            }
                            None => None,
                        };
                        if let Some(trace) = trace {
                            run_row_batched(
                                trace,
                                &mut bri,
                                &mut brf,
                                &view,
                                global,
                                wg[0],
                                (grp_x, grp_y),
                                lid_y,
                                gid_y,
                            )?;
                            batched = true;
                        }
                    }
                    if batched {
                        w_batched += 1;
                    } else {
                        w_scalar += 1;
                        for lid_x in 0..wg[0] {
                            ri[SLOT_GID_X as usize] = (grp_x * wg[0] + lid_x) as i64;
                            ri[SLOT_GID_Y as usize] = gid_y as i64;
                            ri[SLOT_LID_X as usize] = lid_x as i64;
                            ri[SLOT_LID_Y as usize] = lid_y as i64;
                            run_ops(phase, &mut ri, &mut rf, &view)?;
                        }
                    }
                }
            }
        }
        tally_batched.fetch_add(w_batched, Ordering::Relaxed);
        tally_scalar.fetch_add(w_scalar, Ordering::Relaxed);
        tally_spec_us.fetch_add(w_spec_us, Ordering::Relaxed);
        Ok(())
    };

    let result: Result<(), Trap> = if threads <= 1 {
        run_range(0..n_units)
    } else {
        let chunk = n_units.div_ceil(threads);
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..threads)
                .map(|t| {
                    let run_range = &run_range;
                    let lo = t * chunk;
                    let hi = ((t + 1) * chunk).min(n_units);
                    s.spawn(move || run_range(lo..hi))
                })
                .collect();
            let mut out = Ok(());
            for h in handles {
                let r = h.join().expect("VM worker thread panicked");
                if out.is_ok() {
                    out = r;
                }
            }
            out
        })
    };

    result.map_err(|trap| {
        let name = |buf: u16| compiled.buffer_names[buf as usize].clone();
        match trap {
            Trap::Oob { buf, index } => ExecError::OutOfBounds {
                name: name(buf),
                index,
                len: if (buf as usize) < n_args {
                    shared.0[buf as usize].len
                } else {
                    plan.locals[buf as usize - n_args].len
                },
            },
            Trap::NotImage { buf } => ExecError::ArgKind(name(buf)),
            Trap::DivByZero => ExecError::DivByZero,
            Trap::Runaway => ExecError::Runaway(MAX_WHILE),
        }
    })?;
    Ok(profile::RunStats {
        rows_batched: tally_batched.into_inner(),
        rows_scalar: tally_scalar.into_inner(),
        groups: n_units as u64,
        threads: threads as u64,
        pool: avail as u64,
        spec_wall_us: tally_spec_us.into_inner(),
    })
}
