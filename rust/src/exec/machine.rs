//! The NDRange execution driver: run a [`KernelPlan`] with full OpenCL
//! execution-model emulation (work-groups, work-items, barrier-separated
//! phases, `__local` arrays).
//!
//! This is the correctness backend of the reproduction (DESIGN.md §2): it
//! runs the *transformed* code — index math, staging loops, boundary
//! expressions and all — so a bug in any transformation corrupts output
//! and is caught by the equivalence tests, exactly as a wrong OpenCL
//! kernel would be on real hardware. All accesses are bounds-checked.
//!
//! Two engines share this driver (selectable via [`Engine`], default
//! [`Engine::Auto`], overridable with
//! `IMAGECL_EXEC=tree|vm|vm-scalar|vm-unopt`):
//!
//! * the **bytecode VM** ([`super::vm`]) — plans are compiled through the
//!   slot-resolved IR of [`super::compiled`] down to flat, register-based
//!   bytecode, optimized by [`super::opt`]'s pass pipeline, and executed
//!   with work-groups (or rows) in parallel and rows batched over SIMD
//!   lanes when the write-set analysis proved independence. This is the
//!   production path (`PreparedKernel::run`, the serving workers, tuner
//!   measurements). `Engine::VmScalar` / `Engine::VmUnopt` pin the
//!   scalar and pre-optimizer variants for differential testing.
//! * the **tree-walker** (the [`Machine`] in this module, ~40× over the
//!   original string-resolving interpreter) — retained as the
//!   *differential oracle*: always serial, always `Value`-typed, the
//!   reference the VM must match bit-for-bit (`tests/vm_differential.rs`)
//!   and the fallback for the rare plans the VM cannot type statically.

use std::collections::{BTreeMap, HashMap};

use crate::imagecl::ast::*;
use crate::transform::clir::*;

use super::buffer::{Arg, Buffer, Value};
use super::compiled::{CExpr, CStmt, CompiledPlan, Compiler, Fn1, Fn2, *};
use super::profile;
use super::vm::{self, VmProgram};

/// Runtime error (all of these indicate a compiler bug or a bad launch).
#[derive(Debug, thiserror::Error)]
pub enum ExecError {
    #[error("missing argument `{0}`")]
    MissingArg(String),
    #[error("argument `{0}` has the wrong kind")]
    ArgKind(String),
    #[error("out-of-bounds access to `{name}` at {index} (len {len})")]
    OutOfBounds { name: String, index: i64, len: usize },
    #[error("undefined variable `{0}`")]
    Undefined(String),
    #[error("unknown function `{0}`")]
    UnknownFn(String),
    #[error("division by zero")]
    DivByZero,
    #[error("while-loop exceeded {0} iterations")]
    Runaway(usize),
    #[error("{0}")]
    Other(String),
}

/// Iteration cap for `while` loops.
pub(crate) const MAX_WHILE: usize = 1 << 24;

/// Which execution engine drives the NDRange.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Engine {
    /// The bytecode VM when the plan lowered to bytecode (and the
    /// argument buffers match the plan's element types), the tree-walker
    /// otherwise. `IMAGECL_EXEC=tree` forces the oracle;
    /// `IMAGECL_EXEC=vm|vm-scalar|vm-unopt` insists on the matching VM
    /// variant (erroring where `Auto` would fall back).
    #[default]
    Auto,
    /// The optimized bytecode VM with batched row interpretation, hard:
    /// executing a plan the VM cannot run is an error rather than a
    /// silent fallback (benchmarks and differential tests must know
    /// which engine ran).
    Vm,
    /// The optimized VM with batching disabled — isolates the optimizer
    /// pipeline's contribution in the differential grid and benchmarks.
    VmScalar,
    /// The *unoptimized*, unbatched VM — the PR-3 baseline, kept
    /// addressable for the differential grid and the bench regression
    /// gate.
    VmUnopt,
    /// The serial tree-walking interpreter — the differential oracle.
    TreeWalk,
}

impl Engine {
    /// The `IMAGECL_EXEC` environment override applied to `Auto`.
    fn resolve(self) -> Engine {
        if self != Engine::Auto {
            return self;
        }
        match std::env::var("IMAGECL_EXEC").as_deref() {
            Ok("tree") => Engine::TreeWalk,
            Ok("vm") => Engine::Vm,
            Ok("vm-scalar") => Engine::VmScalar,
            Ok("vm-unopt") => Engine::VmUnopt,
            _ => Engine::Auto,
        }
    }
}

/// A buffer during execution: either a borrowed argument or a per-group
/// local array. Images execute through their backing `Buffer` plus
/// extent (for texture bounds checks).
pub(crate) enum BufSlot {
    Array(Buffer),
    Image { w: usize, h: usize, buf: Buffer },
    /// Local scratch (recreated per work-group).
    Local { buf: Buffer },
}

impl BufSlot {
    pub(crate) fn buffer(&self) -> &Buffer {
        match self {
            BufSlot::Array(b) | BufSlot::Local { buf: b } => b,
            BufSlot::Image { buf, .. } => buf,
        }
    }

    pub(crate) fn buffer_mut(&mut self) -> &mut Buffer {
        match self {
            BufSlot::Array(b) | BufSlot::Local { buf: b } => b,
            BufSlot::Image { buf, .. } => buf,
        }
    }
}

/// Resolve every scalar parameter of a plan to its launch value: the ABI
/// scalars (`{img}_w/h`, `{arr}_n`, `__gw`, `__gh`) are derived from the
/// argument shapes and the grid; user scalars come from `args` directly.
/// These values are inlined as constants at compile time.
pub fn resolve_scalars(
    plan: &KernelPlan,
    args: &BTreeMap<String, Arg>,
    grid: (usize, usize),
) -> Result<HashMap<String, Value>, ExecError> {
    let mut scalar_vals: HashMap<String, Value> = HashMap::new();
    for (name, _ty) in &plan.scalars {
        let v = if name == GRID_W {
            Value::I(grid.0 as i64)
        } else if name == GRID_H {
            Value::I(grid.1 as i64)
        } else if let Some(img_name) = name
            .strip_suffix("_w")
            .filter(|n| plan.buffer(n).map(|b| b.image_dims.is_some()) == Some(true))
        {
            let img = args
                .get(img_name)
                .and_then(Arg::image)
                .ok_or_else(|| ExecError::MissingArg(img_name.to_string()))?;
            Value::I(img.w as i64)
        } else if let Some(img_name) = name
            .strip_suffix("_h")
            .filter(|n| plan.buffer(n).map(|b| b.image_dims.is_some()) == Some(true))
        {
            let img = args
                .get(img_name)
                .and_then(Arg::image)
                .ok_or_else(|| ExecError::MissingArg(img_name.to_string()))?;
            Value::I(img.h as i64)
        } else if let Some(arr_name) = name
            .strip_suffix("_n")
            .filter(|n| plan.buffer(n).map(|b| b.image_dims.is_none()) == Some(true))
        {
            match args.get(arr_name) {
                Some(Arg::Array(b)) => Value::I(b.len() as i64),
                Some(_) => return Err(ExecError::ArgKind(arr_name.to_string())),
                None => return Err(ExecError::MissingArg(arr_name.to_string())),
            }
        } else {
            match args.get(name) {
                Some(Arg::Scalar(v)) => *v,
                Some(_) => return Err(ExecError::ArgKind(name.clone())),
                None => return Err(ExecError::MissingArg(name.clone())),
            }
        };
        scalar_vals.insert(name.clone(), v);
    }
    Ok(scalar_vals)
}

/// Execute a plan over its NDRange. `args` maps every source-level
/// parameter name to its argument; images carry their extent, and the ABI
/// scalars are derived automatically (see [`resolve_scalars`]). `grid` is
/// the logical thread-grid size. The plan is compiled for this launch and
/// the compilation discarded — use [`PreparedKernel`] to amortize it.
pub fn execute(
    plan: &KernelPlan,
    args: &mut BTreeMap<String, Arg>,
    grid: (usize, usize),
) -> Result<(), ExecError> {
    execute_with(plan, args, grid, Engine::Auto)
}

/// [`execute`] on an explicitly chosen engine (benchmarks and the
/// differential oracle tests).
pub fn execute_with(
    plan: &KernelPlan,
    args: &mut BTreeMap<String, Arg>,
    grid: (usize, usize),
    engine: Engine,
) -> Result<(), ExecError> {
    let scalar_vals = resolve_scalars(plan, args, grid)?;
    let compiled = Compiler::compile(plan, &scalar_vals)?;
    let vm = match engine.resolve() {
        Engine::TreeWalk => None,
        Engine::VmUnopt => VmProgram::build_with(plan, &compiled, false),
        _ => VmProgram::build(plan, &compiled),
    };
    let key = profile::PlanKey::new(&plan.name, "host", grid);
    record_opt_build(&key, vm.as_ref());
    run_compiled(plan, &compiled, vm.as_ref(), args, grid, engine, &key)
}

/// A kernel plan compiled once for a fixed launch shape, reusable across
/// executions — the serving layer's cached unit (launch-time compilation
/// is hoisted out of the request path).
///
/// The compiled IR inlines the launch's scalar values (grid size, image
/// extents, array lengths, user scalars), so a prepared kernel is only
/// valid for argument sets that resolve to the same scalars; [`Self::run`]
/// re-derives them per call and rejects mismatches rather than silently
/// computing with stale constants.
#[derive(Debug, Clone)]
pub struct PreparedKernel {
    plan: KernelPlan,
    compiled: CompiledPlan,
    /// Optimized bytecode lowering of `compiled` (`None` for the rare
    /// plans the VM cannot type statically — those run on the
    /// tree-walker).
    vm: Option<VmProgram>,
    /// The unoptimized lowering, kept so `Engine::VmUnopt` (differential
    /// grid, bench regression gate) measures the PR-3 baseline without a
    /// per-run rebuild.
    vm_unopt: Option<VmProgram>,
    scalar_vals: HashMap<String, Value>,
    grid: (usize, usize),
    /// Execution-tier profiler key: which (kernel, device, grid) this
    /// prepared plan's launches are attributed to.
    key: profile::PlanKey,
}

impl PreparedKernel {
    /// Compile `plan` for the launch shape implied by `args` + `grid`.
    /// `args` is only inspected (shapes and scalar values), not consumed.
    /// Profiler attribution lands under the placeholder device `"host"`;
    /// callers that know the target device use [`Self::prepare_on`].
    pub fn prepare(
        plan: &KernelPlan,
        args: &BTreeMap<String, Arg>,
        grid: (usize, usize),
    ) -> Result<PreparedKernel, ExecError> {
        Self::prepare_on(plan, args, grid, "host")
    }

    /// [`Self::prepare`] with explicit profiler device attribution (the
    /// serving layer compiles per device; `"host"` otherwise).
    pub fn prepare_on(
        plan: &KernelPlan,
        args: &BTreeMap<String, Arg>,
        grid: (usize, usize),
        device: &'static str,
    ) -> Result<PreparedKernel, ExecError> {
        let scalar_vals = resolve_scalars(plan, args, grid)?;
        let compiled = Compiler::compile(plan, &scalar_vals)?;
        let vm = VmProgram::build(plan, &compiled);
        let vm_unopt = VmProgram::build_with(plan, &compiled, false);
        let key = profile::PlanKey::new(&plan.name, device, grid);
        record_opt_build(&key, vm.as_ref());
        Ok(PreparedKernel {
            plan: plan.clone(),
            compiled,
            vm,
            vm_unopt,
            scalar_vals,
            grid,
            key,
        })
    }

    pub fn grid(&self) -> (usize, usize) {
        self.grid
    }

    pub fn plan(&self) -> &KernelPlan {
        &self.plan
    }

    /// Did the plan lower to bytecode (the serving/tuning fast path)?
    pub fn has_vm(&self) -> bool {
        self.vm.is_some()
    }

    /// Execute the prepared kernel on a fresh argument set of the same
    /// launch shape.
    pub fn run(&self, args: &mut BTreeMap<String, Arg>) -> Result<(), ExecError> {
        self.run_with(args, Engine::Auto)
    }

    /// [`Self::run`] on an explicitly chosen engine.
    pub fn run_with(
        &self,
        args: &mut BTreeMap<String, Arg>,
        engine: Engine,
    ) -> Result<(), ExecError> {
        let scalar_vals = resolve_scalars(&self.plan, args, self.grid)?;
        if scalar_vals != self.scalar_vals {
            return Err(ExecError::Other(format!(
                "prepared kernel `{}` launched with different scalar values \
                 (shapes/scalars must match those at prepare time)",
                self.plan.name
            )));
        }
        let vm = match engine.resolve() {
            Engine::VmUnopt => self.vm_unopt.as_ref(),
            _ => self.vm.as_ref(),
        };
        run_compiled(&self.plan, &self.compiled, vm, args, self.grid, engine, &self.key)
    }
}

/// Attribute an optimized build's pass statistics and optimizer wall
/// time to the plan's profile.
fn record_opt_build(key: &profile::PlanKey, vm: Option<&VmProgram>) {
    if let Some(prog) = vm {
        if let Some(stats) = &prog.opt_stats {
            profile::profiler().record_opt(key, stats, prog.opt_wall_us);
        }
    }
}

/// Drive an already-compiled plan over the NDRange: marshal argument
/// buffers into dense slots, run, and return the buffers to the caller
/// (even on error).
fn run_compiled(
    plan: &KernelPlan,
    compiled: &CompiledPlan,
    vm: Option<&VmProgram>,
    args: &mut BTreeMap<String, Arg>,
    grid: (usize, usize),
    engine: Engine,
    key: &profile::PlanKey,
) -> Result<(), ExecError> {
    // Move buffers out of the argument map into dense slots (plan buffers
    // first, locals after — matching the compiler's indices).
    let mut bufs: Vec<BufSlot> = Vec::with_capacity(plan.buffers.len() + plan.locals.len());
    for b in &plan.buffers {
        let arg = args
            .remove(&b.name)
            .ok_or_else(|| ExecError::MissingArg(b.name.clone()))?;
        bufs.push(match arg {
            Arg::Array(buf) => BufSlot::Array(buf),
            Arg::Image(img) => BufSlot::Image { w: img.w, h: img.h, buf: img.buf },
            Arg::Scalar(_) => return Err(ExecError::ArgKind(b.name.clone())),
        });
    }
    for l in &plan.locals {
        // Allocated by the engine drivers (once per launch / per worker).
        bufs.push(BufSlot::Local { buf: Buffer::new(l.elem, 0) });
    }

    let vm_ok = vm.is_some_and(|p| vm::args_match(p, &bufs));
    let resolved = engine.resolve();
    // Batched row interpretation is the default VM behaviour;
    // `VmScalar`/`VmUnopt` pin the scalar loop for the differential grid
    // and the bench's engine isolation.
    let batch = !matches!(resolved, Engine::VmScalar | Engine::VmUnopt);
    // Tier attribution for the profiler: which engine actually runs,
    // and whether `Auto` *wanted* the VM but fell back to the oracle.
    let tier = match resolved {
        Engine::TreeWalk => profile::Tier::Tree,
        Engine::VmUnopt => profile::Tier::VmUnopt,
        Engine::VmScalar => profile::Tier::VmScalar,
        Engine::Vm => profile::Tier::Vm,
        Engine::Auto if vm_ok => profile::Tier::Vm,
        Engine::Auto => profile::Tier::Tree,
    };
    let fallback = matches!(resolved, Engine::Auto) && !vm_ok;
    let t_exec = std::time::Instant::now();
    let result = match resolved {
        Engine::TreeWalk => run_ndrange(plan, compiled, &mut bufs, grid).map(|()| None),
        Engine::Vm | Engine::VmScalar | Engine::VmUnopt => {
            if vm_ok {
                vm::run_ndrange(plan, compiled, vm.unwrap(), &mut bufs, grid, batch)
                    .map(Some)
            } else {
                Err(ExecError::Other(format!(
                    "plan `{}` is not executable on the bytecode VM \
                     (unsupported construct or argument element-type \
                     mismatch); use Engine::Auto or Engine::TreeWalk",
                    plan.name
                )))
            }
        }
        Engine::Auto => {
            if vm_ok {
                vm::run_ndrange(plan, compiled, vm.unwrap(), &mut bufs, grid, batch)
                    .map(Some)
            } else {
                run_ndrange(plan, compiled, &mut bufs, grid).map(|()| None)
            }
        }
    };
    if let Ok(stats) = &result {
        let wall_us = t_exec.elapsed().as_micros() as u64;
        profile::profiler().record_run(key, tier, fallback, wall_us, *stats);
    }

    // Move argument buffers back (even on error, so callers keep data).
    for (i, b) in plan.buffers.iter().enumerate() {
        let slot = std::mem::replace(&mut bufs[i], BufSlot::Array(Buffer::new(b.elem, 0)));
        let arg = match slot {
            BufSlot::Array(buf) => Arg::Array(buf),
            BufSlot::Image { w, h, buf } => {
                Arg::Image(super::buffer::ImageBuf { w, h, buf })
            }
            BufSlot::Local { .. } => unreachable!(),
        };
        args.insert(b.name.clone(), arg);
    }
    result.map(|_| ())
}

fn run_ndrange(
    plan: &KernelPlan,
    compiled: &CompiledPlan,
    bufs: &mut [BufSlot],
    grid: (usize, usize),
) -> Result<(), ExecError> {
    let (global, wg) = plan.launch_dims(grid.0, grid.1);
    let groups = [global[0] / wg[0], global[1] / wg[1]];
    let n_args = plan.buffers.len();

    let mut m = Machine {
        bufs,
        names: &compiled.buffer_names,
        slots: vec![Value::I(0); compiled.n_slots],
    };

    // Local scratch: allocated once per launch (the group-shape and phase
    // set are fixed), zero-reset between groups — fresh-allocation
    // semantics without a per-group trip through the allocator.
    for (li, l) in plan.locals.iter().enumerate() {
        m.bufs[n_args + li] = BufSlot::Local { buf: Buffer::new(l.elem, l.len) };
    }

    for grp_y in 0..groups[1] {
        for grp_x in 0..groups[0] {
            for li in 0..plan.locals.len() {
                m.bufs[n_args + li].buffer_mut().data.fill(0.0);
            }
            m.slots[SLOT_GRP_X as usize] = Value::I(grp_x as i64);
            m.slots[SLOT_GRP_Y as usize] = Value::I(grp_y as i64);
            m.slots[SLOT_GDIM_X as usize] = Value::I(global[0] as i64);
            m.slots[SLOT_GDIM_Y as usize] = Value::I(global[1] as i64);
            for phase in &compiled.phases {
                // Barrier semantics: all work-items complete phase k
                // before any starts k+1.
                for lid_y in 0..wg[1] {
                    for lid_x in 0..wg[0] {
                        m.slots[SLOT_GID_X as usize] =
                            Value::I((grp_x * wg[0] + lid_x) as i64);
                        m.slots[SLOT_GID_Y as usize] =
                            Value::I((grp_y * wg[1] + lid_y) as i64);
                        m.slots[SLOT_LID_X as usize] = Value::I(lid_x as i64);
                        m.slots[SLOT_LID_Y as usize] = Value::I(lid_y as i64);
                        m.exec_stmts(phase)?;
                    }
                }
            }
        }
    }
    Ok(())
}

/// Control-flow signal.
enum Flow {
    Normal,
    Return,
}

struct Machine<'a> {
    bufs: &'a mut [BufSlot],
    names: &'a [String],
    slots: Vec<Value>,
}

impl Machine<'_> {
    #[inline]
    fn oob(&self, buf: u32, index: i64) -> ExecError {
        ExecError::OutOfBounds {
            name: self.names[buf as usize].clone(),
            index,
            len: self.bufs[buf as usize].buffer().len(),
        }
    }

    fn eval(&self, e: &CExpr) -> Result<Value, ExecError> {
        Ok(match e {
            CExpr::I(v) => Value::I(*v),
            CExpr::F(v) => Value::F(*v),
            CExpr::B(b) => Value::B(*b),
            CExpr::Var(slot) => self.slots[*slot as usize],
            CExpr::Unary(op, expr) => {
                let v = self.eval(expr)?;
                match op {
                    UnOp::Neg => match v {
                        Value::F(f) => Value::F(-f),
                        other => Value::I(-other.as_i64()),
                    },
                    UnOp::Not => Value::B(!v.as_bool()),
                    UnOp::BitNot => Value::I(!v.as_i64()),
                }
            }
            CExpr::Binary(op, lhs, rhs) => {
                // Short-circuit logical ops.
                if *op == BinOp::And {
                    if !self.eval(lhs)?.as_bool() {
                        return Ok(Value::B(false));
                    }
                    return Ok(Value::B(self.eval(rhs)?.as_bool()));
                }
                if *op == BinOp::Or {
                    if self.eval(lhs)?.as_bool() {
                        return Ok(Value::B(true));
                    }
                    return Ok(Value::B(self.eval(rhs)?.as_bool()));
                }
                binop(*op, self.eval(lhs)?, self.eval(rhs)?)?
            }
            CExpr::Load { buf, idx } => {
                let i = self.eval(idx)?.as_i64();
                self.bufs[*buf as usize]
                    .buffer()
                    .load(usize::try_from(i).unwrap_or(usize::MAX))
                    .ok_or_else(|| self.oob(*buf, i))?
            }
            CExpr::TexRead { buf, x, y } => {
                let xi = self.eval(x)?.as_i64();
                let yi = self.eval(y)?.as_i64();
                let BufSlot::Image { w, h, buf: b } = &self.bufs[*buf as usize] else {
                    return Err(ExecError::ArgKind(self.names[*buf as usize].clone()));
                };
                if xi < 0 || yi < 0 || xi >= *w as i64 || yi >= *h as i64 {
                    return Err(self.oob(*buf, yi * *w as i64 + xi));
                }
                b.load((yi as usize) * *w + xi as usize).unwrap()
            }
            CExpr::Call1(f, a) => {
                let v = self.eval(a)?;
                match f {
                    Fn1::Sqrt => Value::F(v.as_f64().sqrt()),
                    Fn1::Rsqrt => Value::F(1.0 / v.as_f64().sqrt()),
                    Fn1::Fabs => Value::F(v.as_f64().abs()),
                    Fn1::Exp => Value::F(v.as_f64().exp()),
                    Fn1::Log => Value::F(v.as_f64().ln()),
                    Fn1::Sin => Value::F(v.as_f64().sin()),
                    Fn1::Cos => Value::F(v.as_f64().cos()),
                    Fn1::Floor => Value::F(v.as_f64().floor()),
                    Fn1::Ceil => Value::F(v.as_f64().ceil()),
                    Fn1::Abs => match v {
                        Value::F(f) => Value::F(f.abs()),
                        other => Value::I(other.as_i64().abs()),
                    },
                }
            }
            CExpr::Call2(f, a, b) => {
                let x = self.eval(a)?;
                let y = self.eval(b)?;
                match f {
                    Fn2::Pow => Value::F(x.as_f64().powf(y.as_f64())),
                    Fn2::Min | Fn2::Max => {
                        let take_x = if x.is_float() || y.is_float() {
                            (x.as_f64() <= y.as_f64()) == (*f == Fn2::Min)
                        } else {
                            (x.as_i64() <= y.as_i64()) == (*f == Fn2::Min)
                        };
                        if take_x {
                            x
                        } else {
                            y
                        }
                    }
                }
            }
            CExpr::Clamp(v, lo, hi) => {
                let v = self.eval(v)?;
                let lo = self.eval(lo)?;
                let hi = self.eval(hi)?;
                if v.is_float() || lo.is_float() || hi.is_float() {
                    Value::F(v.as_f64().clamp(lo.as_f64(), hi.as_f64()))
                } else {
                    Value::I(v.as_i64().clamp(lo.as_i64(), hi.as_i64()))
                }
            }
            CExpr::Ternary(c, t, e2) => {
                if self.eval(c)?.as_bool() {
                    self.eval(t)?
                } else {
                    self.eval(e2)?
                }
            }
            CExpr::Cast(ty, expr) => self.eval(expr)?.cast(*ty),
        })
    }

    fn exec_stmts(&mut self, stmts: &[CStmt]) -> Result<Flow, ExecError> {
        for s in stmts {
            match s {
                CStmt::SetVar { slot, ty, value } => {
                    let v = self.eval(value)?.cast(*ty);
                    self.slots[*slot as usize] = v;
                }
                CStmt::Store { buf, idx, value, op } => {
                    let i = self.eval(idx)?.as_i64();
                    let v = self.eval(value)?;
                    let iu = usize::try_from(i).unwrap_or(usize::MAX);
                    let v = match op {
                        None => v,
                        Some(b) => {
                            let cur = self.bufs[*buf as usize]
                                .buffer()
                                .load(iu)
                                .ok_or_else(|| self.oob(*buf, i))?;
                            binop(*b, cur, v)?
                        }
                    };
                    if !self.bufs[*buf as usize].buffer_mut().store(iu, v) {
                        return Err(self.oob(*buf, i));
                    }
                }
                CStmt::TexWrite { buf, x, y, value } => {
                    let xi = self.eval(x)?.as_i64();
                    let yi = self.eval(y)?.as_i64();
                    let v = self.eval(value)?;
                    let BufSlot::Image { w, h, buf: b } = &mut self.bufs[*buf as usize]
                    else {
                        return Err(ExecError::ArgKind(
                            self.names[*buf as usize].clone(),
                        ));
                    };
                    let (w, h) = (*w, *h);
                    if xi < 0 || yi < 0 || xi >= w as i64 || yi >= h as i64 {
                        return Err(self.oob(*buf, yi * w as i64 + xi));
                    }
                    b.store((yi as usize) * w + xi as usize, v);
                }
                CStmt::If { cond, then, els } => {
                    let branch = if self.eval(cond)?.as_bool() { then } else { els };
                    if matches!(self.exec_stmts(branch)?, Flow::Return) {
                        return Ok(Flow::Return);
                    }
                }
                CStmt::For { slot, init, cond, step, body } => {
                    let iv = self.eval(init)?;
                    self.slots[*slot as usize] = Value::I(iv.as_i64());
                    loop {
                        if !self.eval(cond)?.as_bool() {
                            break;
                        }
                        if matches!(self.exec_stmts(body)?, Flow::Return) {
                            return Ok(Flow::Return);
                        }
                        let cur = self.slots[*slot as usize].as_i64();
                        let st = self.eval(step)?.as_i64();
                        self.slots[*slot as usize] = Value::I(cur + st);
                    }
                }
                CStmt::While { cond, body } => {
                    let mut n = 0usize;
                    while self.eval(cond)?.as_bool() {
                        if matches!(self.exec_stmts(body)?, Flow::Return) {
                            return Ok(Flow::Return);
                        }
                        n += 1;
                        if n > MAX_WHILE {
                            return Err(ExecError::Runaway(MAX_WHILE));
                        }
                    }
                }
                CStmt::Return => return Ok(Flow::Return),
                CStmt::Eval(e) => {
                    self.eval(e)?;
                }
            }
        }
        Ok(Flow::Normal)
    }
}

fn binop(op: BinOp, l: Value, r: Value) -> Result<Value, ExecError> {
    use BinOp::*;
    let float = l.is_float() || r.is_float();
    Ok(match op {
        Add | Sub | Mul | Div | Rem => {
            if float {
                let (a, b) = (l.as_f64(), r.as_f64());
                Value::F(match op {
                    Add => a + b,
                    Sub => a - b,
                    Mul => a * b,
                    Div => a / b,
                    Rem => a % b,
                    _ => unreachable!(),
                })
            } else {
                let (a, b) = (l.as_i64(), r.as_i64());
                if matches!(op, Div | Rem) && b == 0 {
                    return Err(ExecError::DivByZero);
                }
                Value::I(match op {
                    Add => a.wrapping_add(b),
                    Sub => a.wrapping_sub(b),
                    Mul => a.wrapping_mul(b),
                    Div => a / b,
                    Rem => a % b,
                    _ => unreachable!(),
                })
            }
        }
        Eq | Ne | Lt | Gt | Le | Ge => {
            let c = if float {
                let (a, b) = (l.as_f64(), r.as_f64());
                match op {
                    Eq => a == b,
                    Ne => a != b,
                    Lt => a < b,
                    Gt => a > b,
                    Le => a <= b,
                    Ge => a >= b,
                    _ => unreachable!(),
                }
            } else {
                let (a, b) = (l.as_i64(), r.as_i64());
                match op {
                    Eq => a == b,
                    Ne => a != b,
                    Lt => a < b,
                    Gt => a > b,
                    Le => a <= b,
                    Ge => a >= b,
                    _ => unreachable!(),
                }
            };
            Value::B(c)
        }
        And | Or => Value::B(match op {
            And => l.as_bool() && r.as_bool(),
            Or => l.as_bool() || r.as_bool(),
            _ => unreachable!(),
        }),
        BitAnd => Value::I(l.as_i64() & r.as_i64()),
        BitOr => Value::I(l.as_i64() | r.as_i64()),
        BitXor => Value::I(l.as_i64() ^ r.as_i64()),
        Shl => Value::I(l.as_i64().wrapping_shl(r.as_i64() as u32)),
        Shr => Value::I(l.as_i64().wrapping_shr(r.as_i64() as u32)),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::buffer::ImageBuf;
    use crate::imagecl::ScalarType;
    use crate::transform::{compile, TuningConfig};

    fn run_blur(cfg: TuningConfig, w: usize, h: usize) -> ImageBuf {
        let src = "#pragma imcl grid(in)\n\
            void blur(Image<float> in, Image<float> out) {\n\
              float sum = 0.0f;\n\
              for (int i = -1; i < 2; i++) {\n\
                for (int j = -1; j < 2; j++) { sum += in[idx + i][idy + j]; }\n\
              }\n\
              out[idx][idy] = sum / 9.0f;\n\
            }";
        let plan = compile(src, &cfg).unwrap();
        let input =
            ImageBuf::from_fn(ScalarType::F32, w, h, |x, y| ((x * 7 + y * 13) % 31) as f64);
        let mut args = BTreeMap::new();
        args.insert("in".to_string(), Arg::Image(input));
        args.insert("out".to_string(), Arg::Image(ImageBuf::new(ScalarType::F32, w, h)));
        execute(&plan, &mut args, (w, h)).unwrap();
        match args.remove("out").unwrap() {
            Arg::Image(i) => i,
            _ => unreachable!(),
        }
    }

    /// Direct reference box blur with constant-0 boundary.
    fn ref_blur(w: usize, h: usize) -> Vec<f64> {
        let input: Vec<f64> = (0..w * h)
            .map(|i| (((i % w) * 7 + (i / w) * 13) % 31) as f64)
            .collect();
        let mut out = vec![0.0; w * h];
        for y in 0..h as i64 {
            for x in 0..w as i64 {
                let mut sum = 0.0f64;
                for i in -1..2i64 {
                    for j in -1..2i64 {
                        let (xx, yy) = (x + i, y + j);
                        if xx >= 0 && xx < w as i64 && yy >= 0 && yy < h as i64 {
                            sum += input[(yy as usize) * w + xx as usize] as f32 as f64;
                        }
                    }
                }
                out[(y as usize) * w + x as usize] = (sum as f32 / 9.0f32) as f64;
            }
        }
        out
    }

    fn assert_matches_ref(img: &ImageBuf) {
        let expect = ref_blur(img.w, img.h);
        for y in 0..img.h {
            for x in 0..img.w {
                let got = img.get(x, y);
                let want = expect[y * img.w + x];
                assert!(
                    (got - want).abs() < 1e-5,
                    "mismatch at ({x},{y}): got {got}, want {want}"
                );
            }
        }
    }

    #[test]
    fn naive_blur_matches_reference() {
        assert_matches_ref(&run_blur(TuningConfig::default(), 20, 13));
    }

    #[test]
    fn coarsened_blur_matches() {
        let cfg = TuningConfig { coarsen: [4, 2], wg: [8, 8], ..Default::default() };
        assert_matches_ref(&run_blur(cfg, 37, 22));
    }

    #[test]
    fn interleaved_blur_matches() {
        let cfg = TuningConfig {
            coarsen: [2, 2],
            interleaved: true,
            wg: [8, 4],
            ..Default::default()
        };
        assert_matches_ref(&run_blur(cfg, 33, 17));
    }

    #[test]
    fn local_mem_blur_matches() {
        let mut cfg = TuningConfig { wg: [8, 8], ..Default::default() };
        cfg.local_mem.insert("in".into(), true);
        assert_matches_ref(&run_blur(cfg, 29, 31));
    }

    #[test]
    fn texture_blur_matches() {
        let mut cfg = TuningConfig::default();
        cfg.image_mem.insert("in".into(), true);
        cfg.image_mem.insert("out".into(), true);
        assert_matches_ref(&run_blur(cfg, 19, 23));
    }

    #[test]
    fn everything_on_blur_matches() {
        let mut cfg = TuningConfig {
            wg: [8, 4],
            coarsen: [2, 4],
            interleaved: true,
            ..Default::default()
        };
        cfg.local_mem.insert("in".into(), true);
        cfg.unroll.insert(1, 0);
        cfg.unroll.insert(2, 0);
        assert_matches_ref(&run_blur(cfg, 41, 27));
    }

    #[test]
    fn prepared_kernel_reusable_and_matches_execute() {
        let src = "#pragma imcl grid(in)\n\
            void copy(Image<float> in, Image<float> out) {\n\
              out[idx][idy] = in[idx][idy] * 2.0f;\n\
            }";
        let plan = compile(src, &TuningConfig::default()).unwrap();
        let mk_args = |seed: f64| {
            let mut args = BTreeMap::new();
            let input = ImageBuf::from_fn(ScalarType::F32, 8, 8, |x, y| {
                seed + (x + 10 * y) as f64
            });
            args.insert("in".to_string(), Arg::Image(input));
            args.insert("out".to_string(), Arg::Image(ImageBuf::new(ScalarType::F32, 8, 8)));
            args
        };
        let prepared = PreparedKernel::prepare(&plan, &mk_args(0.0), (8, 8)).unwrap();
        // Two runs with different data both match the one-shot path.
        for seed in [0.0, 100.0] {
            let mut a = mk_args(seed);
            prepared.run(&mut a).unwrap();
            let mut b = mk_args(seed);
            execute(&plan, &mut b, (8, 8)).unwrap();
            assert_eq!(a["out"].image().unwrap().buf.data, b["out"].image().unwrap().buf.data);
        }
    }

    #[test]
    fn prepared_kernel_rejects_shape_mismatch() {
        let src = "#pragma imcl grid(in)\n\
            void k(Image<float> in, Image<float> out) { out[idx][idy] = in[idx][idy]; }";
        let plan = compile(src, &TuningConfig::default()).unwrap();
        let mut args = BTreeMap::new();
        args.insert("in".to_string(), Arg::Image(ImageBuf::new(ScalarType::F32, 8, 8)));
        args.insert("out".to_string(), Arg::Image(ImageBuf::new(ScalarType::F32, 8, 8)));
        let prepared = PreparedKernel::prepare(&plan, &args, (8, 8)).unwrap();
        // Same grid but differently-sized image arguments → scalar mismatch.
        let mut wrong = BTreeMap::new();
        wrong.insert("in".to_string(), Arg::Image(ImageBuf::new(ScalarType::F32, 16, 16)));
        wrong.insert("out".to_string(), Arg::Image(ImageBuf::new(ScalarType::F32, 16, 16)));
        let err = prepared.run(&mut wrong).unwrap_err();
        assert!(matches!(err, ExecError::Other(_)), "{err}");
    }

    #[test]
    fn oob_array_access_is_error() {
        let src = "#pragma imcl grid(16, 1)\nvoid k(float* a) { a[idx + 1] = 0.0f; }";
        let plan = compile(src, &TuningConfig { wg: [16, 1], ..Default::default() }).unwrap();
        let mut args = BTreeMap::new();
        args.insert("a".to_string(), Arg::Array(Buffer::new(ScalarType::F32, 16)));
        let err = execute(&plan, &mut args, (16, 1)).unwrap_err();
        assert!(matches!(err, ExecError::OutOfBounds { .. }), "{err}");
        // Buffers are returned to the caller even on error.
        assert!(args.contains_key("a"));
    }

    #[test]
    fn missing_arg_is_error() {
        let src = "void k(Image<float> a) { a[idx][idy] = 0.0f; }";
        let plan = compile(src, &TuningConfig::default()).unwrap();
        let mut args = BTreeMap::new();
        let err = execute(&plan, &mut args, (8, 8)).unwrap_err();
        assert!(matches!(err, ExecError::MissingArg(_)));
    }

    #[test]
    fn scalar_params_passed() {
        let src = "#pragma imcl grid(a)\n\
            void k(Image<float> a, float g) { a[idx][idy] = g; }";
        let plan = compile(src, &TuningConfig::default()).unwrap();
        let mut args = BTreeMap::new();
        args.insert("a".to_string(), Arg::Image(ImageBuf::new(ScalarType::F32, 4, 4)));
        args.insert("g".to_string(), Arg::Scalar(Value::F(2.5)));
        execute(&plan, &mut args, (4, 4)).unwrap();
        assert_eq!(args["a"].image().unwrap().get(3, 3), 2.5);
    }

    #[test]
    fn uchar_image_wraps() {
        let src = "void k(Image<uchar> a) { a[idx][idy] = 300; }";
        let plan = compile(src, &TuningConfig::default()).unwrap();
        let mut args = BTreeMap::new();
        args.insert("a".to_string(), Arg::Image(ImageBuf::new(ScalarType::U8, 4, 4)));
        execute(&plan, &mut args, (4, 4)).unwrap();
        assert_eq!(args["a"].image().unwrap().get(0, 0), 44.0);
    }

    #[test]
    fn clamped_boundary_semantics() {
        let src = "#pragma imcl grid(in)\n\
            #pragma imcl boundary(in, clamped)\n\
            void k(Image<float> in, Image<float> out) {\n\
              out[idx][idy] = in[idx - 1][idy];\n\
            }";
        let plan = compile(src, &TuningConfig { wg: [4, 4], ..Default::default() }).unwrap();
        let input = ImageBuf::from_fn(ScalarType::F32, 4, 4, |x, _| x as f64);
        let mut args = BTreeMap::new();
        args.insert("in".to_string(), Arg::Image(input));
        args.insert("out".to_string(), Arg::Image(ImageBuf::new(ScalarType::F32, 4, 4)));
        execute(&plan, &mut args, (4, 4)).unwrap();
        let out = args["out"].image().unwrap();
        // Column 0 clamps to itself (0.0), column 1 reads column 0, ...
        assert_eq!(out.get(0, 0), 0.0);
        assert_eq!(out.get(1, 2), 0.0);
        assert_eq!(out.get(3, 1), 2.0);
    }

    #[test]
    fn int_var_truncates_float_assign() {
        // C semantics via static typing: assigning a float expression to
        // an int variable truncates.
        let src = "#pragma imcl grid(4, 1)\n\
            void k(float* a) { int t = 0; t = 3; a[idx] = (float)(t) + 0.5f; }";
        let plan = compile(src, &TuningConfig { wg: [4, 1], ..Default::default() }).unwrap();
        let mut args = BTreeMap::new();
        args.insert("a".to_string(), Arg::Array(Buffer::new(ScalarType::F32, 4)));
        execute(&plan, &mut args, (4, 1)).unwrap();
        if let Arg::Array(b) = &args["a"] {
            assert_eq!(b.load(0), Some(Value::F(3.5)));
        }
    }
}
