//! The metrics registry: named counters, gauges, and log-linear
//! histograms behind a process-global [`Registry`].
//!
//! Metric names follow `imagecl_<subsystem>_<name>_<unit>` (see the
//! README's Observability section). A (name, label-set) pair maps to
//! exactly one handle: repeated `counter(...)` calls with the same name
//! and labels return the same `Arc<Counter>`, so call sites can either
//! cache the handle or re-look it up — both hit the same atomic.
//!
//! Histograms are log-linear: values below 16 get one exact bucket
//! each; above that, every power-of-two octave is split into 8 linear
//! sub-buckets. That bounds the relative quantile error to ~12.5% with
//! a fixed 496-slot table and no allocation on the observe path —
//! replacing the sorted-vec percentiles `serve::metrics` used to keep.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// A monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Raise to `n` if currently below it. Used to publish accumulated
    /// absolutes (per-service `Counters`, the exec profiler) into the
    /// registry idempotently while keeping the exported series
    /// monotone across repeated publishes.
    pub fn set_max(&self, n: u64) {
        self.0.fetch_max(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A gauge: a point-in-time `f64` (stored as bits in an atomic).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Values `0..EXACT_BUCKETS` get one exact bucket each.
const EXACT_BUCKETS: usize = 16;
/// Linear sub-buckets per power-of-two octave above the exact range.
const SUBDIV: usize = 8;
/// First octave covered by the log-linear range (2^4 = 16).
const FIRST_OCTAVE: usize = 4;
/// Last representable octave for a `u64` value.
const LAST_OCTAVE: usize = 63;
/// Total bucket count (496): fixed, so `observe` never allocates.
const BUCKETS: usize = EXACT_BUCKETS + (LAST_OCTAVE - FIRST_OCTAVE + 1) * SUBDIV;

/// Observations at or above this value land in the final octave, where
/// [`bucket_upper`] saturates and the ~12.5% relative-error guarantee no
/// longer holds — the histogram effectively *clamps* them.
const CLAMP_THRESHOLD: u64 = 1 << LAST_OCTAVE;

/// Process-wide count of clamped histogram observations (any histogram).
/// Exported as `imagecl_obs_hist_clamped_total`; a nonzero value means
/// some series' tail quantiles are untrustworthy (the observations were
/// astronomically large — usually a unit bug upstream).
static HIST_CLAMPED: AtomicU64 = AtomicU64::new(0);

/// Total histogram observations that fell into the saturating top
/// octave since process start.
pub fn hist_clamped_total() -> u64 {
    HIST_CLAMPED.load(Ordering::Relaxed)
}

fn bucket_index(v: u64) -> usize {
    if v < EXACT_BUCKETS as u64 {
        return v as usize;
    }
    let octave = 63 - v.leading_zeros() as usize;
    let sub = ((v >> (octave - 3)) & (SUBDIV as u64 - 1)) as usize;
    EXACT_BUCKETS + (octave - FIRST_OCTAVE) * SUBDIV + sub
}

/// Inclusive upper bound of bucket `i` (the value reported for any
/// quantile landing in it).
fn bucket_upper(i: usize) -> u64 {
    if i < EXACT_BUCKETS {
        return i as u64;
    }
    let r = i - EXACT_BUCKETS;
    let octave = r / SUBDIV + FIRST_OCTAVE;
    let sub = (r % SUBDIV) as u64;
    (1u64 << octave)
        .saturating_add((sub + 1).saturating_mul(1u64 << (octave - 3)))
        .saturating_sub(1)
}

/// A log-linear histogram over `u64` observations (typically
/// microseconds). Observe is wait-free: one `fetch_add` per field.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    sum: AtomicU64,
    count: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        let mut buckets = Vec::with_capacity(BUCKETS);
        buckets.resize_with(BUCKETS, AtomicU64::default);
        Histogram { buckets, sum: AtomicU64::new(0), count: AtomicU64::new(0) }
    }
}

impl Histogram {
    pub fn observe(&self, v: u64) {
        if v >= CLAMP_THRESHOLD {
            HIST_CLAMPED.fetch_add(1, Ordering::Relaxed);
        }
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Nearest-rank percentile over the buckets; returns the upper
    /// bound of the bucket holding the ranked observation. Empty
    /// histograms report 0; `q` is clamped to `[0, 100]` (NaN → 100).
    pub fn percentile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let q = if q.is_nan() { 100.0 } else { q.clamp(0.0, 100.0) };
        let rank = ((q / 100.0 * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                return bucket_upper(i);
            }
        }
        bucket_upper(BUCKETS - 1)
    }

    /// Non-empty buckets as `(upper_bound, count)` in ascending order.
    pub fn nonzero_buckets(&self) -> Vec<(u64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| {
                let n = b.load(Ordering::Relaxed);
                (n > 0).then(|| (bucket_upper(i), n))
            })
            .collect()
    }
}

/// The three exported metric kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Counter,
    Gauge,
    Histogram,
}

impl Kind {
    pub fn as_str(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Histogram => "histogram",
        }
    }
}

#[derive(Debug, Clone)]
enum Handle {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

#[derive(Debug)]
struct Family {
    kind: Kind,
    help: &'static str,
    /// Keyed by the rendered label string (`{k="v",...}` or empty).
    series: BTreeMap<String, Handle>,
}

/// A point-in-time reading of one series, for the exporters.
#[derive(Debug, Clone)]
pub enum Sample {
    Counter(u64),
    Gauge(f64),
    /// `buckets` are *cumulative* `(upper_bound, count_le)` pairs over
    /// the non-empty buckets, ready for `_bucket{le="..."}` lines.
    Histogram { buckets: Vec<(u64, u64)>, sum: u64, count: u64 },
}

/// A point-in-time reading of one metric family.
#[derive(Debug, Clone)]
pub struct FamilySnapshot {
    pub name: &'static str,
    pub kind: Kind,
    pub help: &'static str,
    /// `(rendered_labels, sample)`, sorted by label string.
    pub series: Vec<(String, Sample)>,
}

/// Renders a label set as `{k="v",k2="v2"}` (sorted by key, values
/// escaped) or the empty string for no labels.
pub fn render_labels(labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let mut sorted: Vec<&(&str, &str)> = labels.iter().collect();
    sorted.sort_by_key(|(k, _)| *k);
    let mut s = String::from("{");
    for (i, (k, v)) in sorted.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(k);
        s.push_str("=\"");
        for c in v.chars() {
            match c {
                '\\' => s.push_str("\\\\"),
                '"' => s.push_str("\\\""),
                '\n' => s.push_str("\\n"),
                c => s.push(c),
            }
        }
        s.push('"');
    }
    s.push('}');
    s
}

/// The metric registry: name → family → label-set → handle.
///
/// Lookups take one mutex; the returned `Arc` handles are lock-free to
/// bump, so hot paths should hold on to their handle.
#[derive(Debug, Default)]
pub struct Registry {
    families: Mutex<BTreeMap<&'static str, Family>>,
}

impl Registry {
    /// Get or create a counter. Panics if `name` is already registered
    /// with a different kind (a programming error, caught in tests).
    pub fn counter(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&str, &str)],
    ) -> Arc<Counter> {
        match self.handle(name, help, labels, Kind::Counter) {
            Handle::Counter(c) => c,
            _ => unreachable!(),
        }
    }

    /// Get or create a gauge (same contract as [`Registry::counter`]).
    pub fn gauge(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&str, &str)],
    ) -> Arc<Gauge> {
        match self.handle(name, help, labels, Kind::Gauge) {
            Handle::Gauge(g) => g,
            _ => unreachable!(),
        }
    }

    /// Get or create a histogram (same contract as
    /// [`Registry::counter`]).
    pub fn histogram(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&str, &str)],
    ) -> Arc<Histogram> {
        match self.handle(name, help, labels, Kind::Histogram) {
            Handle::Histogram(h) => h,
            _ => unreachable!(),
        }
    }

    fn handle(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&str, &str)],
        kind: Kind,
    ) -> Handle {
        let key = render_labels(labels);
        let mut fams = self.families.lock().unwrap();
        let fam = fams
            .entry(name)
            .or_insert_with(|| Family { kind, help, series: BTreeMap::new() });
        assert!(
            fam.kind == kind,
            "metric {name} already registered as {:?}, requested {:?}",
            fam.kind,
            kind
        );
        fam.series
            .entry(key)
            .or_insert_with(|| match kind {
                Kind::Counter => Handle::Counter(Arc::new(Counter::default())),
                Kind::Gauge => Handle::Gauge(Arc::new(Gauge::default())),
                Kind::Histogram => Handle::Histogram(Arc::new(Histogram::default())),
            })
            .clone()
    }

    /// Read every family for export, sorted by name.
    pub fn snapshot(&self) -> Vec<FamilySnapshot> {
        let fams = self.families.lock().unwrap();
        fams.iter()
            .map(|(name, fam)| FamilySnapshot {
                name,
                kind: fam.kind,
                help: fam.help,
                series: fam
                    .series
                    .iter()
                    .map(|(labels, h)| {
                        let sample = match h {
                            Handle::Counter(c) => Sample::Counter(c.get()),
                            Handle::Gauge(g) => Sample::Gauge(g.get()),
                            Handle::Histogram(h) => {
                                let mut cum = 0u64;
                                let buckets = h
                                    .nonzero_buckets()
                                    .into_iter()
                                    .map(|(upper, n)| {
                                        cum += n;
                                        (upper, cum)
                                    })
                                    .collect();
                                Sample::Histogram {
                                    buckets,
                                    sum: h.sum(),
                                    count: h.count(),
                                }
                            }
                        };
                        (labels.clone(), sample)
                    })
                    .collect(),
            })
            .collect()
    }
}

/// The process-global registry every subsystem publishes into.
pub fn registry() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(Registry::default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_and_upper_are_consistent() {
        // Every value lands in a bucket whose upper bound is >= the
        // value, and bucket uppers are non-decreasing with the value.
        let mut prev_upper = 0;
        for v in (0..4096u64).chain([1 << 20, 1 << 40, u64::MAX / 2, u64::MAX]) {
            let i = bucket_index(v);
            let upper = bucket_upper(i);
            assert!(upper >= v, "value {v} bucket {i} upper {upper}");
            assert!(upper >= prev_upper, "upper regressed at {v}");
            prev_upper = upper;
        }
        // Small values are exact.
        for v in 0..16u64 {
            assert_eq!(bucket_upper(bucket_index(v)), v);
        }
    }

    #[test]
    fn histogram_percentiles_nearest_rank() {
        let h = Histogram::default();
        assert_eq!(h.percentile(50.0), 0, "empty histogram reports 0");
        for v in [1u64, 2, 3, 4, 5] {
            h.observe(v);
        }
        // Small values are exact buckets, so nearest-rank matches the
        // sorted-vec convention from serve::metrics.
        assert_eq!(h.percentile(0.0), 1);
        assert_eq!(h.percentile(50.0), 3);
        assert_eq!(h.percentile(100.0), 5);
        assert_eq!(h.percentile(f64::NAN), 5);
        assert_eq!(h.percentile(250.0), 5);
        assert_eq!(h.sum(), 15);
        assert_eq!(h.count(), 5);
    }

    #[test]
    fn histogram_relative_error_bounded() {
        let h = Histogram::default();
        h.observe(1_000_000);
        let p = h.percentile(99.0) as f64;
        assert!(p >= 1_000_000.0);
        assert!(p <= 1_000_000.0 * 1.13, "p={p}");
    }

    #[test]
    fn top_octave_observations_count_as_clamped() {
        let h = Histogram::default();
        let before = hist_clamped_total();
        h.observe(1_000_000); // well within the accurate range
        assert_eq!(hist_clamped_total(), before, "normal values don't clamp");
        h.observe(u64::MAX);
        h.observe(CLAMP_THRESHOLD);
        assert_eq!(hist_clamped_total(), before + 2);
        assert_eq!(h.count(), 3, "clamped observations still count");
    }

    #[test]
    fn registry_reuses_series_and_checks_kind() {
        let reg = Registry::default();
        let a = reg.counter("imagecl_test_total", "help", &[("k", "v")]);
        let b = reg.counter("imagecl_test_total", "help", &[("k", "v")]);
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3, "same (name, labels) shares one atomic");
        let other = reg.counter("imagecl_test_total", "help", &[("k", "w")]);
        assert_eq!(other.get(), 0);
        let snap = reg.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].series.len(), 2);
    }

    #[test]
    #[should_panic(expected = "already registered")]
    fn registry_panics_on_kind_mismatch() {
        let reg = Registry::default();
        let _ = reg.counter("imagecl_test_total", "help", &[]);
        let _ = reg.gauge("imagecl_test_total", "help", &[]);
    }

    #[test]
    fn labels_render_sorted_and_escaped() {
        assert_eq!(render_labels(&[]), "");
        let s = render_labels(&[("z", "1"), ("a", "x\"y\\z")]);
        assert_eq!(s, "{a=\"x\\\"y\\\\z\",z=\"1\"}");
    }

    #[test]
    fn counter_set_max_is_monotone() {
        let c = Counter::default();
        c.set_max(5);
        c.set_max(3);
        assert_eq!(c.get(), 5);
        c.set_max(9);
        assert_eq!(c.get(), 9);
    }
}
