//! The span tracer: lightweight, allocation-frugal spans with
//! parent/child nesting and a per-request trace ID, recorded into a
//! fixed-capacity ring buffer.
//!
//! A span is opened with [`span`] (child of the calling thread's
//! current span, or a fresh root) or [`span_under`] (explicit parent —
//! used to continue a request's trace on a worker thread), and is
//! recorded when its [`SpanGuard`] drops. Records are `Copy` and hold
//! only a `&'static str` name, so the hot path allocates nothing; the
//! per-thread parent stack is the only non-atomic state and it never
//! crosses threads.
//!
//! Ring-buffer drop policy: the buffer holds the most recent
//! [`RING_CAPACITY`] span records. Writers claim a slot with one
//! atomic `fetch_add` on the cursor (lock-free — no writer ever waits
//! for a reader or another writer to choose a slot) and overwrite the
//! oldest record unconditionally. Under overload the *oldest spans are
//! silently dropped*, which can orphan a trace (children evicted
//! before the root is read); [`render_traces`](super::export) only
//! walks traces whose root is still resident, so partially evicted
//! traces disappear rather than render misleadingly truncated.

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Capacity of the span ring buffer (records, not bytes).
pub const RING_CAPACITY: usize = 8192;

/// One completed span. `parent == 0` marks a trace root.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanRecord {
    pub trace: u64,
    pub span: u64,
    pub parent: u64,
    pub name: &'static str,
    /// Extra context carried without allocation (a built-in kernel id
    /// for request roots, `""` elsewhere). Exported as Chrome-trace
    /// `args.kernel`.
    pub detail: &'static str,
    /// Recording thread (process-unique, assigned on first span).
    pub tid: u64,
    /// Device the recording thread serves ([`set_thread_device`];
    /// `""` for unattributed threads).
    pub device: &'static str,
    /// Microseconds since the tracer's epoch (first use in-process).
    pub start_us: u64,
    pub dur_us: u64,
}

/// The process-global tracer: a ring of span slots plus the ID well.
#[derive(Debug)]
pub struct Tracer {
    epoch: Instant,
    slots: Vec<Mutex<Option<SpanRecord>>>,
    cursor: AtomicU64,
    next_id: AtomicU64,
    /// Records overwritten before any reader saw them leave the ring —
    /// the silent-loss signal exported as
    /// `imagecl_obs_trace_drops_total`.
    dropped: AtomicU64,
}

impl Tracer {
    fn with_capacity(cap: usize) -> Tracer {
        let mut slots = Vec::with_capacity(cap);
        slots.resize_with(cap, || Mutex::new(None));
        Tracer {
            epoch: Instant::now(),
            slots,
            cursor: AtomicU64::new(0),
            // 0 is reserved to mean "no parent" / "no trace".
            next_id: AtomicU64::new(1),
            dropped: AtomicU64::new(0),
        }
    }

    /// Allocate a fresh span/trace ID (monotone, never 0).
    pub fn next_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Append a record, overwriting the oldest when full.
    pub fn record(&self, rec: SpanRecord) {
        let i = self.cursor.fetch_add(1, Ordering::Relaxed) as usize % self.slots.len();
        let mut slot = self.slots[i].lock().unwrap();
        if slot.is_some() {
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        *slot = Some(rec);
    }

    /// Span records evicted by ring overwrite since process start.
    pub fn drops(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Microseconds from the tracer epoch to `t` (0 if `t` predates
    /// the epoch — only possible for instants captured before the
    /// first tracer use).
    pub fn micros_since_epoch(&self, t: Instant) -> u64 {
        t.checked_duration_since(self.epoch)
            .map(|d| d.as_micros() as u64)
            .unwrap_or(0)
    }

    /// All resident records, sorted by `(trace, start_us, span)`.
    pub fn snapshot(&self) -> Vec<SpanRecord> {
        let mut out: Vec<SpanRecord> =
            self.slots.iter().filter_map(|s| *s.lock().unwrap()).collect();
        out.sort_by_key(|r| (r.trace, r.start_us, r.span));
        out
    }
}

/// The process-global tracer instance.
pub fn tracer() -> &'static Tracer {
    static TRACER: OnceLock<Tracer> = OnceLock::new();
    TRACER.get_or_init(|| Tracer::with_capacity(RING_CAPACITY))
}

/// Well for process-unique thread IDs (std's `ThreadId` has no stable
/// integer form on this toolchain).
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// The calling thread's open-span stack: `(trace, span)` pairs.
    static STACK: RefCell<Vec<(u64, u64)>> = const { RefCell::new(Vec::new()) };
    /// This thread's process-unique trace ID (lazily assigned).
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    /// The device this thread serves, for span attribution.
    static DEVICE: Cell<&'static str> = const { Cell::new("") };
}

/// The calling thread's process-unique ID (assigned on first use).
pub fn current_tid() -> u64 {
    TID.with(|t| *t)
}

/// Attribute the calling thread's future spans to `device` (worker
/// threads call this once at startup; Chrome-trace export groups spans
/// into processes by it).
pub fn set_thread_device(device: &'static str) {
    DEVICE.with(|d| d.set(device));
}

/// The calling thread's device attribution (`""` when unset).
pub fn thread_device() -> &'static str {
    DEVICE.with(|d| d.get())
}

/// An open span; records itself into the ring when dropped.
#[derive(Debug)]
pub struct SpanGuard {
    trace: u64,
    span: u64,
    parent: u64,
    name: &'static str,
    start: Instant,
}

impl SpanGuard {
    pub fn trace_id(&self) -> u64 {
        self.trace
    }

    pub fn span_id(&self) -> u64 {
        self.span
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        STACK.with(|s| {
            s.borrow_mut().pop();
        });
        let t = tracer();
        t.record(SpanRecord {
            trace: self.trace,
            span: self.span,
            parent: self.parent,
            name: self.name,
            detail: "",
            tid: current_tid(),
            device: thread_device(),
            start_us: t.micros_since_epoch(self.start),
            dur_us: self.start.elapsed().as_micros() as u64,
        });
    }
}

/// Open a span as a child of the calling thread's current span, or as
/// a fresh root (new trace ID) when none is open.
pub fn span(name: &'static str) -> SpanGuard {
    let t = tracer();
    let (trace, parent) = STACK
        .with(|s| s.borrow().last().copied())
        .unwrap_or((0, 0));
    let trace = if trace == 0 { t.next_id() } else { trace };
    let id = t.next_id();
    STACK.with(|s| s.borrow_mut().push((trace, id)));
    SpanGuard { trace, span: id, parent, name, start: Instant::now() }
}

/// Open a span under an explicit `(trace, parent)` — used to continue
/// a request's trace on a worker thread where the thread-local stack
/// is empty. Spans opened with [`span`] while this guard is live nest
/// under it as usual.
pub fn span_under(trace: u64, parent: u64, name: &'static str) -> SpanGuard {
    let t = tracer();
    let id = t.next_id();
    STACK.with(|s| s.borrow_mut().push((trace, id)));
    SpanGuard { trace, span: id, parent, name, start: Instant::now() }
}

/// Record an already-measured span directly (no nesting side effects).
/// Used for request roots whose lifetime is tracked by an `Instant`
/// carried in the request rather than a guard on one thread. `detail`
/// is free static context (the kernel id for request roots, `""` when
/// there is nothing to say).
pub fn record_span(
    trace: u64,
    span: u64,
    parent: u64,
    name: &'static str,
    detail: &'static str,
    start: Instant,
    dur_us: u64,
) {
    let t = tracer();
    t.record(SpanRecord {
        trace,
        span,
        parent,
        name,
        detail,
        tid: current_tid(),
        device: thread_device(),
        start_us: t.micros_since_epoch(start),
        dur_us,
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_nest_on_one_thread() {
        let (trace, outer_id, inner_parent);
        {
            let outer = span("test.outer");
            trace = outer.trace_id();
            outer_id = outer.span_id();
            {
                let inner = span("test.inner");
                assert_eq!(inner.trace_id(), trace);
                inner_parent = outer_id;
                drop(inner);
            }
        }
        let snap = tracer().snapshot();
        let inner = snap
            .iter()
            .find(|r| r.trace == trace && r.name == "test.inner")
            .expect("inner span recorded");
        assert_eq!(inner.parent, inner_parent);
        let outer = snap
            .iter()
            .find(|r| r.trace == trace && r.name == "test.outer")
            .expect("outer span recorded");
        assert_eq!(outer.parent, 0, "outer is a root");
        assert!(outer.start_us <= inner.start_us);
    }

    #[test]
    fn span_under_continues_a_trace_across_threads() {
        let t = tracer();
        let trace = t.next_id();
        let root = t.next_id();
        std::thread::spawn(move || {
            let g = span_under(trace, root, "test.worker");
            let child = span("test.worker_child");
            assert_eq!(child.trace_id(), trace);
            drop(child);
            drop(g);
        })
        .join()
        .unwrap();
        let snap = t.snapshot();
        let worker = snap
            .iter()
            .find(|r| r.trace == trace && r.name == "test.worker")
            .expect("worker span recorded");
        assert_eq!(worker.parent, root);
        let child = snap
            .iter()
            .find(|r| r.trace == trace && r.name == "test.worker_child")
            .expect("nested span recorded");
        assert_eq!(child.parent, worker.span);
    }

    #[test]
    fn ring_overwrites_oldest() {
        let t = Tracer::with_capacity(4);
        assert_eq!(t.drops(), 0);
        for i in 0..6u64 {
            t.record(SpanRecord {
                trace: 1,
                span: i + 1,
                parent: 0,
                name: "test.ring",
                detail: "",
                tid: 0,
                device: "",
                start_us: i,
                dur_us: 0,
            });
        }
        let snap = t.snapshot();
        assert_eq!(snap.len(), 4);
        // Spans 1 and 2 (the oldest) were dropped — and counted.
        assert!(snap.iter().all(|r| r.span >= 3), "{snap:?}");
        assert_eq!(t.drops(), 2);
    }

    #[test]
    fn spans_carry_thread_identity() {
        let tid_here = current_tid();
        assert!(tid_here > 0);
        assert_eq!(current_tid(), tid_here, "tid is stable per thread");
        let other = std::thread::spawn(|| {
            set_thread_device("test-dev");
            let g = span("test.tid");
            let (trace, sid) = (g.trace_id(), g.span_id());
            drop(g);
            (trace, sid, current_tid())
        })
        .join()
        .unwrap();
        assert_ne!(other.2, tid_here, "each thread gets its own tid");
        let rec = tracer()
            .snapshot()
            .into_iter()
            .find(|r| r.trace == other.0 && r.span == other.1)
            .expect("span recorded");
        assert_eq!(rec.tid, other.2);
        assert_eq!(rec.device, "test-dev");
    }

    #[test]
    fn record_span_handles_pre_epoch_instants() {
        let t = tracer();
        // An Instant from "before" the epoch must clamp to 0, not panic.
        assert_eq!(t.micros_since_epoch(t.epoch), 0);
    }
}
