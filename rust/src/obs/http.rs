//! A dependency-free HTTP observability endpoint (std `TcpListener`,
//! tiny request parser) plus the matching one-shot GET client used by
//! `imagecl stats --url`.
//!
//! The server is deliberately minimal: GET-only, HTTP/1.0-style
//! `Connection: close` responses, one connection served at a time on a
//! single accept thread. Request reads go through the serving layer's
//! guarded reader ([`crate::serve::net::read_http_head`]): an overall
//! per-request deadline defeats slow-loris senders (408 reply) and a
//! size cap defeats oversized requests (413 reply), so a hostile
//! client can delay one scrape but never wedge or balloon the
//! process. Routes:
//!
//! | path       | payload                                                |
//! |------------|--------------------------------------------------------|
//! | `/`        | plain-text index of the routes below                   |
//! | `/metrics` | Prometheus text exposition of the metrics registry     |
//! | `/healthz` | JSON liveness: queue depth, workers, tunedb (200/503)  |
//! | `/traces`  | recent trace trees (`?format=chrome\|tree\|json`)      |
//! | `/profile` | execution-tier profiler tables                         |
//! | `/slo`     | SLO attainment + burn table (`?format=json`)           |
//!
//! Shutdown is graceful: [`ObsServer::shutdown`] flips the stop flag,
//! pokes the listener with a self-connection so a blocked `accept`
//! returns, and joins the thread — any in-flight response finishes
//! writing before the socket closes.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::{export, slo};

/// A point-in-time health snapshot from the serving stack.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HealthReport {
    /// Requests currently queued across all device queues.
    pub queue_depth: usize,
    /// Total queue capacity across all device queues.
    pub queue_cap: usize,
    /// Worker threads attached to device queues.
    pub workers: usize,
    /// False once shutdown began (queues closed to new work).
    pub accepting: bool,
    /// True while any admission queue is at capacity (new submissions
    /// are being shed). Load signal, not un-health.
    pub shedding: bool,
    /// Rows visible in the tuning database.
    pub tunedb_records: usize,
    /// False when the tuning database could not be read.
    pub tunedb_ok: bool,
}

impl HealthReport {
    /// Liveness verdict: still accepting, workers attached, tunedb
    /// reachable (queue *fullness* is load, not un-health).
    pub fn healthy(&self) -> bool {
        self.accepting && self.workers > 0 && self.tunedb_ok
    }

    pub fn to_json(&self) -> String {
        format!(
            "{{\"healthy\": {}, \"accepting\": {}, \"shedding\": {}, \
             \"workers\": {}, \"queue_depth\": {}, \"queue_cap\": {}, \
             \"tunedb_records\": {}, \"tunedb_ok\": {}}}\n",
            self.healthy(),
            self.accepting,
            self.shedding,
            self.workers,
            self.queue_depth,
            self.queue_cap,
            self.tunedb_records,
            self.tunedb_ok,
        )
    }
}

/// Produces a fresh [`HealthReport`] on every `/healthz` hit.
pub type HealthFn = Arc<dyn Fn() -> HealthReport + Send + Sync>;

/// Called before rendering `/metrics` so gauges published lazily by
/// the serving stack (queue depth, cache sizes) are fresh per scrape.
pub type PublishFn = Arc<dyn Fn() + Send + Sync>;

/// Handle to a running observability server; join via [`Self::shutdown`].
pub struct ObsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: JoinHandle<()>,
}

impl std::fmt::Debug for ObsServer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ObsServer").field("addr", &self.addr).finish()
    }
}

impl ObsServer {
    /// Bind `addr` (`HOST:PORT`; port 0 picks a free one — read the
    /// result back from [`Self::addr`]) and serve until shutdown.
    pub fn start(
        addr: &str,
        health: HealthFn,
        publish: Option<PublishFn>,
    ) -> Result<ObsServer, String> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| format!("obs: cannot bind {addr}: {e}"))?;
        let bound = listener
            .local_addr()
            .map_err(|e| format!("obs: no local addr: {e}"))?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("obs-http".to_string())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    // One connection at a time; a stuck client can stall
                    // a scrape but not the process (guarded reads).
                    let _ = stream.set_write_timeout(Some(Duration::from_secs(2)));
                    let _ = serve_one(stream, &health, publish.as_ref());
                }
            })
            .map_err(|e| format!("obs: cannot spawn server thread: {e}"))?;
        Ok(ObsServer { addr: bound, stop, handle })
    }

    /// The address actually bound (resolves `:0` port requests).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, wake the accept loop, and join the thread. Any
    /// response already being written completes first.
    pub fn shutdown(self) {
        self.stop.store(true, Ordering::SeqCst);
        // Poke a blocked accept() so the loop observes the flag.
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_secs(1));
        let _ = self.handle.join();
    }
}

/// Guards on reading one request head: 16 KiB is far beyond any real
/// scrape request, and two seconds of total read time defeats a
/// slow-loris sender (the guard bounds the *whole* read, not each
/// `read()` call — trickling one byte per second gets cut off).
const READ_GUARDS: crate::serve::net::ReadGuards = crate::serve::net::ReadGuards {
    max_bytes: 16 * 1024,
    deadline: Duration::from_secs(2),
};

/// Read one request, route it, write one response, close.
fn serve_one(
    mut stream: TcpStream,
    health: &HealthFn,
    publish: Option<&PublishFn>,
) -> std::io::Result<()> {
    use crate::serve::net::{read_http_head, ReadError};
    // Read until the header terminator (we never consume a body),
    // guarded against slow and oversized senders.
    let (req, guard_reply) = match read_http_head(&mut stream, &READ_GUARDS) {
        Ok(req) => (req, None),
        Err(ReadError::TimedOut) => {
            (Vec::new(), Some((408, "request timed out\n")))
        }
        Err(ReadError::TooLarge) => {
            (Vec::new(), Some((413, "request too large\n")))
        }
        Err(ReadError::Eof) => (Vec::new(), None),
        Err(ReadError::Io(e)) => return Err(e),
    };
    let (status, content_type, body) = match guard_reply {
        Some((status, msg)) => (status, "text/plain", msg.to_string()),
        None => {
            let text = String::from_utf8_lossy(&req);
            let mut parts = text.lines().next().unwrap_or("").split_whitespace();
            let (method, target) =
                (parts.next().unwrap_or(""), parts.next().unwrap_or("/"));
            if method != "GET" {
                (405, "text/plain", "method not allowed\n".to_string())
            } else {
                route(target, health, publish)
            }
        }
    };
    let reason = match status {
        200 => "OK",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        503 => "Service Unavailable",
        _ => "Error",
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}; charset=utf-8\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// Dispatch a request target to `(status, content-type, body)`.
fn route(
    target: &str,
    health: &HealthFn,
    publish: Option<&PublishFn>,
) -> (u16, &'static str, String) {
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    let param = |key: &str| {
        query
            .split('&')
            .find_map(|kv| kv.split_once('=').filter(|(k, _)| *k == key).map(|(_, v)| v))
    };
    match path {
        "/" => (
            200,
            "text/plain",
            "imagecl observability endpoint\n\
             /metrics   Prometheus text exposition\n\
             /healthz   liveness JSON (200 healthy / 503 unhealthy)\n\
             /traces    recent traces (?format=chrome|tree|json, ?traces=N)\n\
             /profile   execution-tier profiler tables\n\
             /slo       SLO attainment and burn rates (?format=json)\n"
                .to_string(),
        ),
        "/metrics" => {
            if let Some(p) = publish {
                p();
            }
            (200, "text/plain", export::prometheus())
        }
        "/healthz" => {
            let h = health();
            let status = if h.healthy() { 200 } else { 503 };
            (status, "application/json", h.to_json())
        }
        "/traces" => {
            let n = param("traces").and_then(|v| v.parse().ok()).unwrap_or(16);
            match param("format").unwrap_or("json") {
                "chrome" => (200, "application/json", export::chrome_trace(n)),
                "tree" => (200, "text/plain", export::render_traces(n)),
                _ => (200, "application/json", export::traces_json(n)),
            }
        }
        "/profile" => (200, "text/plain", crate::exec::profile::profiler().render()),
        "/slo" => {
            let report = slo::engine().report();
            match param("format") {
                Some("json") => (200, "application/json", report.to_json()),
                _ => (200, "text/plain", report.render()),
            }
        }
        _ => (404, "text/plain", format!("no route {path}\n")),
    }
}

/// One-shot HTTP GET against `http://HOST:PORT/path`, returning
/// `(status, body)` — the client side of `imagecl stats --url`.
pub fn http_get(url: &str) -> Result<(u16, String), String> {
    let rest = url
        .strip_prefix("http://")
        .ok_or_else(|| format!("unsupported URL {url:?} (http:// only)"))?;
    let (hostport, path) = match rest.split_once('/') {
        Some((h, p)) => (h, format!("/{p}")),
        None => (rest, "/".to_string()),
    };
    let mut stream = TcpStream::connect(hostport)
        .map_err(|e| format!("connect {hostport}: {e}"))?;
    let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
    let req = format!(
        "GET {path} HTTP/1.1\r\nHost: {hostport}\r\nConnection: close\r\n\r\n"
    );
    stream.write_all(req.as_bytes()).map_err(|e| format!("send: {e}"))?;
    let mut resp = Vec::new();
    stream.read_to_end(&mut resp).map_err(|e| format!("recv: {e}"))?;
    let text = String::from_utf8_lossy(&resp).into_owned();
    let (head, body) = text
        .split_once("\r\n\r\n")
        .ok_or_else(|| "malformed HTTP response (no header terminator)".to_string())?;
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| format!("malformed status line {:?}", head.lines().next()))?;
    Ok((status, body.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_health() -> HealthFn {
        Arc::new(|| HealthReport {
            queue_depth: 1,
            queue_cap: 8,
            workers: 2,
            accepting: true,
            shedding: false,
            tunedb_records: 3,
            tunedb_ok: true,
        })
    }

    #[test]
    fn health_json_and_verdict() {
        let h = (test_health())();
        assert!(h.healthy());
        let v = crate::jsonlite::parse(&h.to_json()).unwrap();
        assert_eq!(v.get("workers").unwrap().as_f64(), Some(2.0));
        assert_eq!(v.get("healthy").unwrap().as_bool(), Some(true));
        let dead = HealthReport { workers: 0, ..h };
        assert!(!dead.healthy());
    }

    #[test]
    fn server_routes_and_shuts_down() {
        let srv = ObsServer::start("127.0.0.1:0", test_health(), None).unwrap();
        let base = format!("http://{}", srv.addr());

        let (status, body) = http_get(&format!("{base}/")).unwrap();
        assert_eq!(status, 200);
        assert!(body.contains("/metrics"), "{body}");

        crate::obs::metrics::registry()
            .counter("imagecl_obs_http_test_total", "t", &[])
            .inc();
        let (status, body) = http_get(&format!("{base}/metrics")).unwrap();
        assert_eq!(status, 200);
        assert!(body.contains("imagecl_obs_http_test_total"), "{body}");
        export::lint_prometheus(&body).expect(&body);

        let (status, body) = http_get(&format!("{base}/healthz")).unwrap();
        assert_eq!(status, 200);
        assert_eq!(
            crate::jsonlite::parse(&body).unwrap().get("healthy").unwrap().as_bool(),
            Some(true)
        );

        let (status, body) = http_get(&format!("{base}/traces?format=chrome")).unwrap();
        assert_eq!(status, 200);
        assert!(
            crate::jsonlite::parse(&body).unwrap().get("traceEvents").is_some(),
            "{body}"
        );

        let (status, _) = http_get(&format!("{base}/slo?format=json")).unwrap();
        assert_eq!(status, 200);

        let (status, body) = http_get(&format!("{base}/nope")).unwrap();
        assert_eq!(status, 404, "{body}");

        let addr = srv.addr();
        srv.shutdown();
        // The listener is gone: either refused outright or accepted by
        // nothing (read returns no response).
        assert!(http_get(&format!("http://{addr}/")).is_err());
    }

    #[test]
    fn unhealthy_reports_503() {
        let health: HealthFn = Arc::new(|| HealthReport {
            queue_depth: 0,
            queue_cap: 8,
            workers: 0,
            accepting: false,
            shedding: true,
            tunedb_records: 0,
            tunedb_ok: false,
        });
        let srv = ObsServer::start("127.0.0.1:0", health, None).unwrap();
        let (status, body) = http_get(&format!("http://{}/healthz", srv.addr())).unwrap();
        assert_eq!(status, 503);
        assert!(body.contains("\"healthy\": false"), "{body}");
        srv.shutdown();
    }

    #[test]
    fn client_rejects_non_http_urls() {
        assert!(http_get("https://example.com/").is_err());
        assert!(http_get("ftp://x/").is_err());
    }

    /// Raw-socket request against the server, returning the status code
    /// parsed from whatever reply (if any) comes back.
    fn raw_request(addr: SocketAddr, payload: &[u8], then_stall: bool) -> Option<u16> {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(payload).unwrap();
        if !then_stall {
            // Half-close so the server sees EOF if it keeps reading.
            let _ = stream.shutdown(std::net::Shutdown::Write);
        }
        let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
        let mut resp = Vec::new();
        let _ = stream.read_to_end(&mut resp);
        let text = String::from_utf8_lossy(&resp);
        text.split_whitespace().nth(1).and_then(|s| s.parse().ok())
    }

    #[test]
    fn slow_loris_request_gets_408() {
        let srv = ObsServer::start("127.0.0.1:0", test_health(), None).unwrap();
        // Partial request line, never finished: the read guard's overall
        // deadline (2s) must cut it off with 408 instead of waiting for
        // the terminator forever.
        let status = raw_request(srv.addr(), b"GET /metr", true);
        assert_eq!(status, Some(408));
        // The server is still serving afterwards.
        let (status, _) = http_get(&format!("http://{}/healthz", srv.addr())).unwrap();
        assert_eq!(status, 200);
        srv.shutdown();
    }

    #[test]
    fn oversized_request_gets_413() {
        let srv = ObsServer::start("127.0.0.1:0", test_health(), None).unwrap();
        // 3× the cap with no header terminator.
        let mut payload = b"GET / HTTP/1.1\r\nX-Junk: ".to_vec();
        payload.resize(48 * 1024, b'a');
        let status = raw_request(srv.addr(), &payload, true);
        assert_eq!(status, Some(413));
        let (status, _) = http_get(&format!("http://{}/", srv.addr())).unwrap();
        assert_eq!(status, 200);
        srv.shutdown();
    }
}
