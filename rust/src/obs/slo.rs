//! The SLO engine: per-kernel latency objectives, attainment, and
//! multi-window error-budget burn rates.
//!
//! An *objective* is a latency bound (e.g. "blur requests complete in
//! 5 ms") paired with a *target* fraction (e.g. 0.99: at most 1% of
//! requests may miss the bound). The engine records every served
//! request as good (within the objective, no error) or bad, and
//! reports:
//!
//! - **attainment** — the lifetime fraction of good requests per
//!   kernel, compared against the target;
//! - **burn rate** — over each trailing window, the bad fraction
//!   divided by the budget `(1 - target)`. Burn 1.0 means the error
//!   budget is being consumed exactly as provisioned; burn 2.0 means
//!   the budget for the window is exhausted in half the window. The
//!   standard multi-window alert pairs a short window (fast burn,
//!   page) with a long one (slow burn, ticket) — here 5m and 1h.
//!
//! Objectives come from `--slo` on `imagecl serve` / `imagecl stats`
//! (see [`SloSpec::parse`]) with sane defaults otherwise. The engine
//! keeps its own monotone epoch so tests can inject events at chosen
//! offsets via [`SloEngine::record_at_us`].

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Default per-request latency objective when a kernel has no explicit
/// entry: 100 ms, generous enough for interpreted tiers on CI hosts.
pub const DEFAULT_OBJECTIVE_US: u64 = 100_000;

/// Default attainment target (fraction of requests that must be good).
pub const DEFAULT_TARGET: f64 = 0.99;

/// Per-kernel event history cap — bounds memory under sustained load;
/// 16k events comfortably covers an hour at loadgen rates.
const MAX_EVENTS_PER_KERNEL: usize = 16_384;

/// Burn-rate windows rendered in reports: (label, width in µs).
pub const BURN_WINDOWS_US: [(&str, u64); 2] = [("5m", 300_000_000), ("1h", 3_600_000_000)];

/// A parsed SLO specification: a default objective plus per-kernel
/// overrides and a shared attainment target.
#[derive(Debug, Clone, PartialEq)]
pub struct SloSpec {
    pub default_objective_us: u64,
    pub target: f64,
    pub per_kernel: BTreeMap<String, u64>,
}

impl Default for SloSpec {
    fn default() -> SloSpec {
        SloSpec {
            default_objective_us: DEFAULT_OBJECTIVE_US,
            target: DEFAULT_TARGET,
            per_kernel: BTreeMap::new(),
        }
    }
}

impl SloSpec {
    /// Parse a comma-separated spec like
    /// `default=100ms,target=0.99,blur=5ms,sobel=800us`. Latencies
    /// accept `us`, `ms` and `s` suffixes (bare numbers are µs);
    /// `target` is a fraction in (0, 1).
    pub fn parse(text: &str) -> Result<SloSpec, String> {
        let mut spec = SloSpec::default();
        for part in text.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| format!("SLO entry {part:?} is not key=value"))?;
            let (key, val) = (key.trim(), val.trim());
            if key == "target" {
                let t: f64 =
                    val.parse().map_err(|_| format!("bad SLO target {val:?}"))?;
                if !(t > 0.0 && t < 1.0) {
                    return Err(format!("SLO target {t} must be in (0, 1)"));
                }
                spec.target = t;
            } else {
                let us = parse_latency_us(val)?;
                if key == "default" {
                    spec.default_objective_us = us;
                } else {
                    spec.per_kernel.insert(key.to_string(), us);
                }
            }
        }
        Ok(spec)
    }

    /// The objective for `kernel` (override or default).
    pub fn objective_us(&self, kernel: &str) -> u64 {
        self.per_kernel.get(kernel).copied().unwrap_or(self.default_objective_us)
    }
}

/// Parse `5ms` / `800us` / `1.5s` / bare-µs into microseconds. Public:
/// the serve CLI reuses this syntax for `--request-deadline` and the
/// fault injector's `exec_delay`.
pub fn parse_latency_us(text: &str) -> Result<u64, String> {
    let (num, scale) = if let Some(n) = text.strip_suffix("us") {
        (n, 1.0)
    } else if let Some(n) = text.strip_suffix("ms") {
        (n, 1e3)
    } else if let Some(n) = text.strip_suffix('s') {
        (n, 1e6)
    } else {
        (text, 1.0)
    };
    let v: f64 = num.trim().parse().map_err(|_| format!("bad latency {text:?}"))?;
    if !(v.is_finite() && v > 0.0) {
        return Err(format!("latency {text:?} must be positive"));
    }
    Ok((v * scale).round() as u64)
}

#[derive(Debug)]
struct KernelSlo {
    objective_us: u64,
    good: u64,
    total: u64,
    /// Recent events as (engine-epoch-µs, was_good), oldest first.
    events: VecDeque<(u64, bool)>,
}

/// Attainment and burn for one kernel, as reported.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelSloReport {
    pub kernel: String,
    pub objective_us: u64,
    pub good: u64,
    pub total: u64,
    /// Lifetime good fraction (1.0 when no requests yet).
    pub attainment: f64,
    /// Burn rate per window, aligned with [`BURN_WINDOWS_US`].
    pub burn: Vec<(&'static str, f64)>,
}

/// A full SLO report: the shared target plus one row per kernel.
#[derive(Debug, Clone, PartialEq)]
pub struct SloReport {
    pub target: f64,
    pub kernels: Vec<KernelSloReport>,
}

/// The SLO engine: thread-safe recorder + reporter.
#[derive(Debug)]
pub struct SloEngine {
    epoch: Instant,
    inner: Mutex<Inner>,
}

#[derive(Debug)]
struct Inner {
    spec: SloSpec,
    kernels: BTreeMap<String, KernelSlo>,
}

impl Default for SloEngine {
    fn default() -> SloEngine {
        SloEngine::new(SloSpec::default())
    }
}

impl SloEngine {
    pub fn new(spec: SloSpec) -> SloEngine {
        SloEngine {
            epoch: Instant::now(),
            inner: Mutex::new(Inner { spec, kernels: BTreeMap::new() }),
        }
    }

    /// Swap in a new spec; existing kernels adopt the new objectives
    /// (their event history is kept — objectives judge future events).
    pub fn configure(&self, spec: SloSpec) {
        let mut inner = self.inner.lock().unwrap();
        for (name, k) in inner.kernels.iter_mut() {
            k.objective_us = spec.objective_us(name);
        }
        inner.spec = spec;
    }

    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Record a served request for `kernel` with the given latency.
    pub fn record(&self, kernel: &str, latency_us: u64) {
        let at = self.now_us();
        self.record_at_us(kernel, at, Some(latency_us));
    }

    /// Record a failed request (always bad, regardless of latency).
    pub fn record_error(&self, kernel: &str) {
        let at = self.now_us();
        self.record_at_us(kernel, at, None);
    }

    /// Record at an explicit engine-epoch offset — the deterministic
    /// entry point tests use. `latency_us: None` means the request
    /// errored (bad regardless of the objective).
    pub fn record_at_us(&self, kernel: &str, at_us: u64, latency_us: Option<u64>) {
        let mut inner = self.inner.lock().unwrap();
        let objective = inner.spec.objective_us(kernel);
        let k = inner.kernels.entry(kernel.to_string()).or_insert_with(|| KernelSlo {
            objective_us: objective,
            good: 0,
            total: 0,
            events: VecDeque::new(),
        });
        let good = latency_us.is_some_and(|l| l <= k.objective_us);
        k.total += 1;
        if good {
            k.good += 1;
        }
        k.events.push_back((at_us, good));
        if k.events.len() > MAX_EVENTS_PER_KERNEL {
            k.events.pop_front();
        }
        // Prune events older than the widest burn window.
        let horizon = BURN_WINDOWS_US.iter().map(|(_, w)| *w).max().unwrap_or(0);
        while let Some(&(t, _)) = k.events.front() {
            if at_us.saturating_sub(t) > horizon {
                k.events.pop_front();
            } else {
                break;
            }
        }
    }

    /// Lifetime attainment state per kernel — `(kernel, objective_us,
    /// good, total)` — the part of the engine worth carrying across a
    /// process restart (burn windows are trailing-time and restart
    /// empty by design). Feeds the serve warm-restart checkpoint.
    pub fn state_snapshot(&self) -> Vec<(String, u64, u64, u64)> {
        let inner = self.inner.lock().unwrap();
        inner
            .kernels
            .iter()
            .map(|(name, k)| (name.clone(), k.objective_us, k.good, k.total))
            .collect()
    }

    /// Merge a checkpointed kernel's lifetime counts back in (warm
    /// restart): good/total add onto whatever this process has already
    /// seen; the objective only applies to kernels the current spec has
    /// no override for. Event history (burn windows) is not restored.
    pub fn absorb(&self, kernel: &str, objective_us: u64, good: u64, total: u64) {
        let mut inner = self.inner.lock().unwrap();
        // The current spec wins when it has an explicit override (or the
        // checkpoint carries no objective); otherwise keep the
        // checkpointed objective the counts were judged against.
        let objective = if inner.spec.per_kernel.contains_key(kernel) || objective_us == 0
        {
            inner.spec.objective_us(kernel)
        } else {
            objective_us
        };
        let k = inner.kernels.entry(kernel.to_string()).or_insert_with(|| KernelSlo {
            objective_us: objective,
            good: 0,
            total: 0,
            events: VecDeque::new(),
        });
        k.good += good.min(total);
        k.total += total;
    }

    /// Build the report as of "now" on the engine clock.
    pub fn report(&self) -> SloReport {
        self.report_at_us(self.now_us())
    }

    /// Build the report as of an explicit engine-epoch offset.
    pub fn report_at_us(&self, now_us: u64) -> SloReport {
        let inner = self.inner.lock().unwrap();
        let target = inner.spec.target;
        let budget = (1.0 - target).max(1e-9);
        let kernels = inner
            .kernels
            .iter()
            .map(|(name, k)| {
                let attainment =
                    if k.total == 0 { 1.0 } else { k.good as f64 / k.total as f64 };
                let burn = BURN_WINDOWS_US
                    .iter()
                    .map(|&(label, width)| {
                        let cutoff = now_us.saturating_sub(width);
                        let (mut total, mut bad) = (0u64, 0u64);
                        for &(t, good) in k.events.iter().rev() {
                            if t < cutoff {
                                break; // events are time-ordered
                            }
                            total += 1;
                            if !good {
                                bad += 1;
                            }
                        }
                        let bad_frac =
                            if total == 0 { 0.0 } else { bad as f64 / total as f64 };
                        (label, bad_frac / budget)
                    })
                    .collect();
                KernelSloReport {
                    kernel: name.clone(),
                    objective_us: k.objective_us,
                    good: k.good,
                    total: k.total,
                    attainment,
                    burn,
                }
            })
            .collect();
        SloReport { target, kernels }
    }
}

impl SloReport {
    /// True when every kernel meets its target lifetime attainment.
    pub fn all_met(&self) -> bool {
        self.kernels.iter().all(|k| k.attainment >= self.target)
    }

    /// Render as an aligned operator table.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        if self.kernels.is_empty() {
            let _ = writeln!(s, "(no SLO observations yet)");
            return s;
        }
        let _ = writeln!(
            s,
            "{:<14} {:>12} {:>8} {:>10} {:>8} {:>9} {:>9}  status",
            "kernel", "objective", "total", "attain", "target", "burn(5m)", "burn(1h)"
        );
        for k in &self.kernels {
            let burn5 = k.burn.first().map(|(_, b)| *b).unwrap_or(0.0);
            let burn1h = k.burn.get(1).map(|(_, b)| *b).unwrap_or(0.0);
            let status = if k.attainment >= self.target { "ok" } else { "MISSING" };
            let _ = writeln!(
                s,
                "{:<14} {:>10}us {:>8} {:>9.4}% {:>7.2}% {:>9.2} {:>9.2}  {status}",
                k.kernel,
                k.objective_us,
                k.total,
                k.attainment * 100.0,
                self.target * 100.0,
                burn5,
                burn1h,
            );
        }
        s
    }

    /// Render as a JSON document (hand-rolled; no serde offline).
    pub fn to_json(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(s, "{{");
        let _ = writeln!(s, "  \"target\": {},", self.target);
        let _ = writeln!(s, "  \"all_met\": {},", self.all_met());
        let _ = writeln!(s, "  \"kernels\": [");
        for (i, k) in self.kernels.iter().enumerate() {
            let burns: Vec<String> = k
                .burn
                .iter()
                .map(|(label, b)| format!("\"{label}\": {b:.4}"))
                .collect();
            let _ = writeln!(
                s,
                "    {{\"kernel\": \"{}\", \"objective_us\": {}, \"good\": {}, \
                 \"total\": {}, \"attainment\": {:.6}, \"burn\": {{{}}}}}{}",
                k.kernel.replace('\\', "\\\\").replace('"', "\\\""),
                k.objective_us,
                k.good,
                k.total,
                k.attainment,
                burns.join(", "),
                if i + 1 < self.kernels.len() { "," } else { "" }
            );
        }
        let _ = writeln!(s, "  ]");
        let _ = writeln!(s, "}}");
        s
    }
}

/// The process-global SLO engine (default spec until configured).
pub fn engine() -> &'static SloEngine {
    static ENGINE: OnceLock<SloEngine> = OnceLock::new();
    ENGINE.get_or_init(SloEngine::default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parses_suffixes_overrides_and_target() {
        let s = SloSpec::parse("default=100ms, target=0.995, blur=5ms, sobel=800us, conv2d=1.5s")
            .unwrap();
        assert_eq!(s.default_objective_us, 100_000);
        assert_eq!(s.target, 0.995);
        assert_eq!(s.objective_us("blur"), 5_000);
        assert_eq!(s.objective_us("sobel"), 800);
        assert_eq!(s.objective_us("conv2d"), 1_500_000);
        assert_eq!(s.objective_us("unlisted"), 100_000);
    }

    #[test]
    fn spec_rejects_malformed_entries() {
        assert!(SloSpec::parse("blur").is_err());
        assert!(SloSpec::parse("target=1.5").is_err());
        assert!(SloSpec::parse("blur=-3ms").is_err());
        assert!(SloSpec::parse("blur=banana").is_err());
    }

    #[test]
    fn attainment_counts_good_and_bad() {
        let e = SloEngine::new(SloSpec::parse("default=1ms,target=0.9").unwrap());
        for _ in 0..9 {
            e.record_at_us("blur", 1_000, Some(500)); // good
        }
        e.record_at_us("blur", 1_000, Some(5_000)); // bad: over objective
        let r = e.report_at_us(2_000);
        assert_eq!(r.kernels.len(), 1);
        let k = &r.kernels[0];
        assert_eq!((k.good, k.total), (9, 10));
        assert!((k.attainment - 0.9).abs() < 1e-12);
        assert!(r.all_met());
    }

    #[test]
    fn errors_are_always_bad() {
        let e = SloEngine::new(SloSpec::default());
        e.record_at_us("sobel", 0, None);
        let r = e.report_at_us(1);
        assert_eq!(r.kernels[0].good, 0);
        assert!(!r.all_met());
    }

    #[test]
    fn burn_rate_is_windowed() {
        // target 0.99 → budget 1%. 10% bad in-window → burn 10.
        let e = SloEngine::new(SloSpec::parse("default=1ms,target=0.99").unwrap());
        let hour_us = 3_600_000_000u64;
        // Old bad events: outside both windows at report time.
        for i in 0..50 {
            e.record_at_us("blur", i, Some(10_000));
        }
        // Recent: 90 good + 10 bad inside the 5m window.
        let now = 2 * hour_us;
        for i in 0..90 {
            e.record_at_us("blur", now - 1_000 - i, Some(100));
        }
        for i in 0..10 {
            e.record_at_us("blur", now - 500 - i, Some(10_000));
        }
        let r = e.report_at_us(now);
        let k = &r.kernels[0];
        let burn5 = k.burn[0].1;
        let burn1h = k.burn[1].1;
        assert!((burn5 - 10.0).abs() < 1e-6, "burn5 = {burn5}");
        // Same events fall in the 1h window too (old ones pruned/outside).
        assert!((burn1h - 10.0).abs() < 1e-6, "burn1h = {burn1h}");
    }

    #[test]
    fn configure_updates_objectives_in_place() {
        let e = SloEngine::new(SloSpec::default());
        e.record_at_us("blur", 0, Some(50_000)); // good under 100ms default
        e.configure(SloSpec::parse("blur=1ms").unwrap());
        e.record_at_us("blur", 1, Some(50_000)); // now bad under 1ms
        let r = e.report_at_us(2);
        assert_eq!((r.kernels[0].good, r.kernels[0].total), (1, 2));
        assert_eq!(r.kernels[0].objective_us, 1_000);
    }

    #[test]
    fn state_snapshot_and_absorb_carry_attainment_across_engines() {
        let a = SloEngine::new(SloSpec::parse("default=1ms,target=0.9").unwrap());
        for _ in 0..9 {
            a.record_at_us("blur", 1_000, Some(500));
        }
        a.record_at_us("blur", 1_000, Some(5_000)); // bad
        let snap = a.state_snapshot();
        assert_eq!(snap, vec![("blur".to_string(), 1_000, 9, 10)]);
        // A fresh engine (a restarted process) absorbs the lifetime
        // counts and keeps judging new events by its own spec.
        let b = SloEngine::new(SloSpec::parse("default=1ms,target=0.9").unwrap());
        for (kernel, obj, good, total) in snap {
            b.absorb(&kernel, obj, good, total);
        }
        b.record_at_us("blur", 2_000, Some(500)); // good
        let r = b.report_at_us(3_000);
        assert_eq!((r.kernels[0].good, r.kernels[0].total), (10, 11));
        // Spec overrides in the new process win over the checkpoint.
        let c = SloEngine::new(SloSpec::parse("blur=2ms").unwrap());
        c.absorb("blur", 1_000, 9, 10);
        assert_eq!(c.report_at_us(0).kernels[0].objective_us, 2_000);
    }

    #[test]
    fn report_renders_table_and_json() {
        let e = SloEngine::new(SloSpec::default());
        e.record_at_us("blur", 0, Some(1));
        let r = e.report_at_us(1);
        let table = r.render();
        assert!(table.contains("blur"), "{table}");
        assert!(table.contains("burn(5m)"), "{table}");
        let json = r.to_json();
        let v = crate::jsonlite::parse(&json).expect(&json);
        assert_eq!(v.get("all_met").unwrap().as_bool(), Some(true));
        let ks = v.get("kernels").unwrap().as_arr().unwrap();
        assert_eq!(ks[0].get("kernel").unwrap().as_str(), Some("blur"));
        assert!(ks[0].path(&["burn", "5m"]).is_some());
    }
}
