//! Exporters over the metrics registry and the span ring: Prometheus
//! text exposition, structured JSON, rendered trace trees — plus the
//! tiny in-repo Prometheus linter CI runs instead of `promtool`.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt::Write as _;

use super::metrics::{registry, Kind, Sample};
use super::trace::{tracer, SpanRecord};

/// Splice an `le` label into an already-rendered label string.
fn with_le(labels: &str, le: &str) -> String {
    if labels.is_empty() {
        format!("{{le=\"{le}\"}}")
    } else {
        format!("{},le=\"{le}\"}}", &labels[..labels.len() - 1])
    }
}

/// Publish the observability layer's own silent-loss signals into the
/// registry (monotone via `set_max`): span-ring overwrites and
/// histogram top-octave clamps. Called by every exporter so a scrape
/// always carries fresh values.
fn publish_self_metrics() {
    let reg = registry();
    reg.counter(
        "imagecl_obs_trace_drops_total",
        "Span records evicted by ring overwrite before export",
        &[],
    )
    .set_max(tracer().drops());
    reg.counter(
        "imagecl_obs_hist_clamped_total",
        "Histogram observations in the saturating top octave",
        &[],
    )
    .set_max(super::metrics::hist_clamped_total());
}

/// Render the whole registry in Prometheus text exposition format.
pub fn prometheus() -> String {
    publish_self_metrics();
    let mut s = String::new();
    for fam in registry().snapshot() {
        let _ = writeln!(s, "# HELP {} {}", fam.name, fam.help);
        let _ = writeln!(s, "# TYPE {} {}", fam.name, fam.kind.as_str());
        for (labels, sample) in &fam.series {
            match sample {
                Sample::Counter(v) => {
                    let _ = writeln!(s, "{}{} {}", fam.name, labels, v);
                }
                Sample::Gauge(v) => {
                    let _ = writeln!(s, "{}{} {}", fam.name, labels, v);
                }
                Sample::Histogram { buckets, sum, count } => {
                    for (upper, cum) in buckets {
                        let _ = writeln!(
                            s,
                            "{}_bucket{} {}",
                            fam.name,
                            with_le(labels, &upper.to_string()),
                            cum
                        );
                    }
                    let _ = writeln!(
                        s,
                        "{}_bucket{} {}",
                        fam.name,
                        with_le(labels, "+Inf"),
                        count
                    );
                    let _ = writeln!(s, "{}_sum{} {}", fam.name, labels, sum);
                    let _ = writeln!(s, "{}_count{} {}", fam.name, labels, count);
                }
            }
        }
    }
    s
}

fn json_escape(v: &str) -> String {
    let mut s = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => s.push_str("\\\\"),
            '"' => s.push_str("\\\""),
            '\n' => s.push_str("\\n"),
            '\t' => s.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(s, "\\u{:04x}", c as u32);
            }
            c => s.push(c),
        }
    }
    s
}

fn percentile_of(buckets: &[(u64, u64)], count: u64, q: f64) -> u64 {
    if count == 0 {
        return 0;
    }
    let rank = ((q / 100.0 * count as f64).ceil() as u64).clamp(1, count);
    for (upper, cum) in buckets {
        if *cum >= rank {
            return *upper;
        }
    }
    buckets.last().map(|(u, _)| *u).unwrap_or(0)
}

/// Write the `n` most recent complete traces as a JSON array at the
/// given base indentation (shared by [`json`] and [`traces_json`]).
fn write_trace_array(s: &mut String, n: usize, pad: &str) {
    let _ = writeln!(s, "{pad}[");
    let grouped = group_traces(&tracer().snapshot(), n);
    for (ti, (trace, spans)) in grouped.iter().enumerate() {
        let _ = writeln!(s, "{pad}  {{");
        let _ = writeln!(s, "{pad}    \"trace\": {trace},");
        let _ = writeln!(s, "{pad}    \"spans\": [");
        for (si, r) in spans.iter().enumerate() {
            let _ = writeln!(
                s,
                "{pad}      {{\"span\": {}, \"parent\": {}, \"name\": \"{}\", \
                 \"detail\": \"{}\", \"tid\": {}, \"device\": \"{}\", \
                 \"start_us\": {}, \"dur_us\": {}}}{}",
                r.span,
                r.parent,
                json_escape(r.name),
                json_escape(r.detail),
                r.tid,
                json_escape(r.device),
                r.start_us,
                r.dur_us,
                if si + 1 < spans.len() { "," } else { "" }
            );
        }
        let _ = writeln!(s, "{pad}    ]");
        let _ = writeln!(s, "{pad}  }}{}", if ti + 1 < grouped.len() { "," } else { "" });
    }
    let _ = writeln!(s, "{pad}]");
}

/// The `n` most recent complete traces as a standalone JSON document
/// (`{"traces": [...]}`) — the `/traces` endpoint's default payload.
pub fn traces_json(n: usize) -> String {
    publish_self_metrics();
    let mut s = String::new();
    let _ = writeln!(s, "{{");
    let _ = writeln!(s, "  \"traces\":");
    write_trace_array(&mut s, n, "  ");
    let _ = writeln!(s, "}}");
    s
}

/// Render the registry plus the `traces` most recent complete traces
/// as structured JSON (hand-rolled — the offline crate set has no
/// serde).
pub fn json(traces: usize) -> String {
    publish_self_metrics();
    let mut s = String::new();
    let _ = writeln!(s, "{{");
    let _ = writeln!(s, "  \"metrics\": [");
    let fams = registry().snapshot();
    for (fi, fam) in fams.iter().enumerate() {
        let _ = writeln!(s, "    {{");
        let _ = writeln!(s, "      \"name\": \"{}\",", json_escape(fam.name));
        let _ = writeln!(s, "      \"kind\": \"{}\",", fam.kind.as_str());
        let _ = writeln!(s, "      \"series\": [");
        for (si, (labels, sample)) in fam.series.iter().enumerate() {
            let comma = if si + 1 < fam.series.len() { "," } else { "" };
            match sample {
                Sample::Counter(v) => {
                    let _ = writeln!(
                        s,
                        "        {{\"labels\": \"{}\", \"value\": {}}}{comma}",
                        json_escape(labels),
                        v
                    );
                }
                Sample::Gauge(v) => {
                    let _ = writeln!(
                        s,
                        "        {{\"labels\": \"{}\", \"value\": {}}}{comma}",
                        json_escape(labels),
                        if v.is_finite() { format!("{v}") } else { "null".to_string() }
                    );
                }
                Sample::Histogram { buckets, sum, count } => {
                    let _ = writeln!(
                        s,
                        "        {{\"labels\": \"{}\", \"count\": {}, \"sum\": {}, \
                         \"p50\": {}, \"p90\": {}, \"p99\": {}}}{comma}",
                        json_escape(labels),
                        count,
                        sum,
                        percentile_of(buckets, *count, 50.0),
                        percentile_of(buckets, *count, 90.0),
                        percentile_of(buckets, *count, 99.0),
                    );
                }
            }
        }
        let _ = writeln!(s, "      ]");
        let _ = writeln!(s, "    }}{}", if fi + 1 < fams.len() { "," } else { "" });
    }
    let _ = writeln!(s, "  ],");
    let _ = writeln!(s, "  \"traces\":");
    write_trace_array(&mut s, traces, "  ");
    let _ = writeln!(s, "}}");
    s
}

/// Export the `n` most recent complete traces in the Chrome/Perfetto
/// trace-event format (`chrome://tracing`, <https://ui.perfetto.dev>).
///
/// Mapping: each *device* becomes a process (pid), each recording
/// *thread* a tid within it, and every span renders as an "X"
/// (complete) event with `ts`/`dur` in microseconds and args carrying
/// the span/trace IDs plus the kernel id for request roots. Metadata
/// ("M") events name the processes and threads so the viewer shows
/// device/worker labels instead of bare numbers.
pub fn chrome_trace(n: usize) -> String {
    let grouped = group_traces(&tracer().snapshot(), n);
    // Stable pid per device: sorted distinct names, pid = index + 1.
    let devices: BTreeSet<&'static str> =
        grouped.iter().flat_map(|(_, spans)| spans.iter().map(|r| r.device)).collect();
    let pid_of: BTreeMap<&'static str, u64> =
        devices.iter().enumerate().map(|(i, d)| (*d, i as u64 + 1)).collect();
    let mut events: Vec<String> = Vec::new();
    for (device, pid) in &pid_of {
        let label = if device.is_empty() { "host" } else { device };
        events.push(format!(
            "  {{\"name\": \"process_name\", \"ph\": \"M\", \"pid\": {pid}, \"tid\": 0, \
             \"args\": {{\"name\": \"{}\"}}}}",
            json_escape(label)
        ));
    }
    let mut named_tids: BTreeSet<(u64, u64)> = BTreeSet::new();
    let mut spans: Vec<&SpanRecord> =
        grouped.iter().flat_map(|(_, spans)| spans.iter()).collect();
    spans.sort_by_key(|r| (r.start_us, r.span));
    for r in &spans {
        let pid = pid_of[r.device];
        if named_tids.insert((pid, r.tid)) {
            events.push(format!(
                "  {{\"name\": \"thread_name\", \"ph\": \"M\", \"pid\": {pid}, \
                 \"tid\": {}, \"args\": {{\"name\": \"thread-{}\"}}}}",
                r.tid, r.tid
            ));
        }
        events.push(format!(
            "  {{\"name\": \"{}\", \"ph\": \"X\", \"ts\": {}, \"dur\": {}, \
             \"pid\": {pid}, \"tid\": {}, \"cat\": \"imagecl\", \
             \"args\": {{\"trace\": {}, \"span\": {}, \"parent\": {}, \"kernel\": \"{}\"}}}}",
            json_escape(r.name),
            r.start_us,
            r.dur_us,
            r.tid,
            r.trace,
            r.span,
            r.parent,
            json_escape(r.detail),
        ));
    }
    let mut s = String::new();
    let _ = writeln!(s, "{{");
    let _ = writeln!(s, "\"displayTimeUnit\": \"ms\",");
    let _ = writeln!(s, "\"traceEvents\": [");
    for (i, e) in events.iter().enumerate() {
        let _ = writeln!(s, "{e}{}", if i + 1 < events.len() { "," } else { "" });
    }
    let _ = writeln!(s, "]");
    let _ = writeln!(s, "}}");
    s
}

/// Group resident spans into complete traces (root still resident),
/// newest-first by root start, keeping at most `n`.
fn group_traces(snap: &[SpanRecord], n: usize) -> Vec<(u64, Vec<SpanRecord>)> {
    let mut by_trace: BTreeMap<u64, Vec<SpanRecord>> = BTreeMap::new();
    for r in snap {
        by_trace.entry(r.trace).or_default().push(*r);
    }
    let mut traces: Vec<(u64, Vec<SpanRecord>)> = by_trace
        .into_iter()
        .filter(|(_, spans)| spans.iter().any(|r| r.parent == 0))
        .collect();
    // Newest root first; partially evicted traces were filtered above.
    traces.sort_by_key(|(_, spans)| {
        std::cmp::Reverse(
            spans.iter().filter(|r| r.parent == 0).map(|r| r.start_us).max().unwrap_or(0),
        )
    });
    traces.truncate(n);
    traces
}

/// Render the `n` most recent complete traces as indented trees.
pub fn render_traces(n: usize) -> String {
    let mut s = String::new();
    let grouped = group_traces(&tracer().snapshot(), n);
    if grouped.is_empty() {
        let _ = writeln!(s, "(no complete traces resident)");
        return s;
    }
    for (trace, spans) in &grouped {
        let _ = writeln!(s, "trace {trace}");
        let mut children: BTreeMap<u64, Vec<&SpanRecord>> = BTreeMap::new();
        let mut roots: Vec<&SpanRecord> = Vec::new();
        let ids: BTreeSet<u64> = spans.iter().map(|r| r.span).collect();
        for r in spans {
            if r.parent != 0 && ids.contains(&r.parent) {
                children.entry(r.parent).or_default().push(r);
            } else {
                roots.push(r);
            }
        }
        fn emit(
            s: &mut String,
            r: &SpanRecord,
            children: &BTreeMap<u64, Vec<&SpanRecord>>,
            depth: usize,
        ) {
            if depth > 16 {
                return; // defensive: malformed parent links
            }
            let _ = writeln!(
                s,
                "  {:indent$}{:<w$} {:>9} us",
                "",
                r.name,
                r.dur_us,
                indent = depth * 2,
                w = 28usize.saturating_sub(depth * 2),
            );
            if let Some(kids) = children.get(&r.span) {
                let mut kids = kids.clone();
                kids.sort_by_key(|k| (k.start_us, k.span));
                for k in kids {
                    emit(s, k, children, depth + 1);
                }
            }
        }
        roots.sort_by_key(|r| (r.start_us, r.span));
        for r in roots {
            emit(&mut s, r, &children, 0);
        }
    }
    s
}

/// Lint Prometheus text exposition: every sample must belong to a
/// family with a preceding `# TYPE` line, series must be unique per
/// (name, label-set), names must match the Prometheus charset and
/// carry the `imagecl_` prefix, `_bucket` samples must be labeled with
/// `le`, and values must parse. Returns `(families, samples)` counted.
///
/// This is the "tiny in-repo parser" the CI step uses instead of an
/// external `promtool` dependency.
pub fn lint_prometheus(text: &str) -> Result<(usize, usize), String> {
    let mut types: BTreeMap<String, String> = BTreeMap::new();
    let mut seen: BTreeSet<(String, String)> = BTreeSet::new();
    let mut samples = 0usize;
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim_end();
        let err = |msg: String| Err(format!("line {}: {msg}", ln + 1));
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut parts = rest.split_whitespace();
            let (Some(name), Some(kind)) = (parts.next(), parts.next()) else {
                return err("malformed # TYPE line".to_string());
            };
            if !matches!(kind, "counter" | "gauge" | "histogram" | "summary" | "untyped") {
                return err(format!("unknown metric type {kind:?}"));
            }
            if types.insert(name.to_string(), kind.to_string()).is_some() {
                return err(format!("duplicate # TYPE for {name}"));
            }
            continue;
        }
        if line.starts_with('#') {
            continue; // HELP and free comments
        }
        // Sample line: name[{labels}] value
        let (name_labels, value) = split_sample(line).map_err(|m| format!("line {}: {m}", ln + 1))?;
        let (name, labels) = match name_labels.find('{') {
            Some(i) => (&name_labels[..i], &name_labels[i..]),
            None => (name_labels, ""),
        };
        if !valid_name(name) {
            return err(format!("invalid metric name {name:?}"));
        }
        if !name.starts_with("imagecl_") {
            return err(format!("metric {name} missing imagecl_ prefix"));
        }
        if value.parse::<f64>().is_err() {
            return err(format!("unparseable value {value:?} for {name}"));
        }
        // Resolve the declaring family: histogram children map to the
        // base name, everything else declares under its own name.
        let family = ["_bucket", "_sum", "_count"]
            .iter()
            .find_map(|suf| {
                let base = name.strip_suffix(suf)?;
                (types.get(base).map(String::as_str) == Some("histogram")).then_some(base)
            })
            .unwrap_or(name);
        match types.get(family) {
            Some(_) => {}
            None => return err(format!("sample {name} has no preceding # TYPE")),
        }
        if name.ends_with("_bucket")
            && types.get(family).map(String::as_str) == Some("histogram")
            && !labels.contains("le=\"")
        {
            return err(format!("histogram sample {name} lacks an le label"));
        }
        if !seen.insert((name.to_string(), labels.to_string())) {
            return err(format!("duplicate series {name}{labels}"));
        }
        samples += 1;
    }
    if samples == 0 {
        return Err("no samples found".to_string());
    }
    Ok((types.len(), samples))
}

/// Split a sample line into `(name_with_labels, value)`, respecting
/// quoted label values (which may contain spaces and escaped quotes).
fn split_sample(line: &str) -> Result<(&str, &str), String> {
    let bytes = line.as_bytes();
    let mut in_quotes = false;
    let mut escaped = false;
    for (i, &b) in bytes.iter().enumerate() {
        match b {
            b'\\' if in_quotes && !escaped => escaped = true,
            b'"' if !escaped => in_quotes = !in_quotes,
            b' ' | b'\t' if !in_quotes => {
                return Ok((&line[..i], line[i..].trim()));
            }
            _ => escaped = false,
        }
    }
    Err("sample line has no value".to_string())
}

fn valid_name(name: &str) -> bool {
    !name.is_empty()
        && name.chars().next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_' || c == ':')
        && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::span;

    #[test]
    fn export_lints_clean() {
        let reg = registry();
        reg.counter("imagecl_export_test_total", "test counter", &[("k", "v")]).add(3);
        reg.gauge("imagecl_export_test_gauge", "test gauge", &[]).set(1.5);
        let h = reg.histogram("imagecl_export_test_us", "test histogram", &[]);
        h.observe(7);
        h.observe(900);
        let text = prometheus();
        let (families, samples) = lint_prometheus(&text).expect(&text);
        assert!(families >= 3, "{text}");
        assert!(samples >= 5, "{text}");
        assert!(text.contains("# TYPE imagecl_export_test_us histogram"), "{text}");
        assert!(text.contains("imagecl_export_test_us_bucket{le=\"7\"} 1"), "{text}");
        assert!(text.contains("imagecl_export_test_us_bucket{le=\"+Inf\"} 2"), "{text}");
        assert!(text.contains("imagecl_export_test_total{k=\"v\"} 3"), "{text}");
    }

    #[test]
    fn lint_rejects_malformed_exposition() {
        let no_type = "imagecl_x_total 1\n";
        assert!(lint_prometheus(no_type).unwrap_err().contains("no preceding # TYPE"));

        let dup = "# TYPE imagecl_x_total counter\n\
                   imagecl_x_total 1\nimagecl_x_total 2\n";
        assert!(lint_prometheus(dup).unwrap_err().contains("duplicate series"));

        let unprefixed = "# TYPE foo_total counter\nfoo_total 1\n";
        assert!(lint_prometheus(unprefixed).unwrap_err().contains("imagecl_ prefix"));

        let unlabeled_bucket = "# TYPE imagecl_h histogram\n\
                                imagecl_h_bucket 1\nimagecl_h_sum 1\nimagecl_h_count 1\n";
        assert!(lint_prometheus(unlabeled_bucket).unwrap_err().contains("le label"));

        let bad_value = "# TYPE imagecl_x_total counter\nimagecl_x_total banana\n";
        assert!(lint_prometheus(bad_value).unwrap_err().contains("unparseable value"));

        assert!(lint_prometheus("").unwrap_err().contains("no samples"));
    }

    #[test]
    fn lint_handles_spaces_inside_label_values() {
        let text = "# TYPE imagecl_x_total counter\n\
                    imagecl_x_total{k=\"a b\"} 1\n";
        assert_eq!(lint_prometheus(text).unwrap(), (1, 1));
    }

    #[test]
    fn traces_render_as_trees() {
        {
            let _root = span("testexport.root");
            let _child = span("testexport.child");
        }
        let out = render_traces(64);
        assert!(out.contains("testexport.root"), "{out}");
        assert!(out.contains("testexport.child"), "{out}");
        // The child is indented under its root.
        let root_line = out.lines().find(|l| l.contains("testexport.root")).unwrap();
        let child_line = out.lines().find(|l| l.contains("testexport.child")).unwrap();
        let indent = |l: &str| l.len() - l.trim_start().len();
        assert!(indent(child_line) > indent(root_line), "{out}");
    }

    #[test]
    fn json_is_braced_and_mentions_metrics() {
        registry().counter("imagecl_export_json_total", "j", &[]).inc();
        let j = json(4);
        assert!(j.trim_start().starts_with('{'), "{j}");
        assert!(j.trim_end().ends_with('}'), "{j}");
        assert!(j.contains("imagecl_export_json_total"), "{j}");
        assert!(j.contains("\"traces\""), "{j}");
    }
}
