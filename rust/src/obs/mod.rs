//! `obs` — the observability substrate: span tracing, a metrics
//! registry, and exporters.
//!
//! Three pieces, layered so each is usable alone:
//!
//! * [`trace`] — lightweight spans with parent/child nesting and a
//!   per-request trace ID. `obs::span("tune.search")` opens a span as
//!   a child of the calling thread's current span (or a fresh root);
//!   the guard records itself into a fixed ring buffer on drop.
//!   Cross-thread continuation (a request hopping from the submitting
//!   client to a device worker) uses [`span_under`] with the trace and
//!   parent IDs carried in the request.
//! * [`metrics`] — named counters, gauges, and log-linear histograms
//!   under a process-global [`registry`]. Naming scheme:
//!   `imagecl_<subsystem>_<name>_<unit>` (e.g.
//!   `imagecl_serve_latency_us`); variants live in labels, not names.
//! * [`export`] — Prometheus text format, structured JSON, trace-tree
//!   rendering, and the in-repo Prometheus linter used by CI.
//!
//! # Ring-buffer drop policy
//!
//! The tracer keeps the most recent [`trace::RING_CAPACITY`] (8192)
//! span records in a ring. A writer claims its slot with a single
//! atomic `fetch_add` on the ring cursor — writers never contend on
//! slot *choice*, and never block waiting for space: when the ring is
//! full the oldest record is overwritten unconditionally. The
//! trade-off is deliberate: under overload tracing degrades by
//! forgetting the past, never by slowing the present. Eviction can
//! orphan a trace (its children overwritten while the root survives,
//! or vice versa); the exporters therefore treat "root span resident"
//! as the completeness signal and skip traces without one rather than
//! rendering a misleading fragment.
//!
//! The execution-tier profiler (which engine tier ran, batched vs
//! scalar row coverage, optimizer pass statistics, per-phase wall
//! time) lives in [`crate::exec::profile`] and publishes into this
//! module's registry via `profile::publish`.

pub mod export;
pub mod metrics;
pub mod trace;

pub use metrics::{registry, Counter, Gauge, Histogram, Registry};
pub use trace::{record_span, span, span_under, tracer, SpanGuard, SpanRecord};
