//! `obs` — the observability substrate: span tracing, a metrics
//! registry, and exporters.
//!
//! Three pieces, layered so each is usable alone:
//!
//! * [`trace`] — lightweight spans with parent/child nesting and a
//!   per-request trace ID. `obs::span("tune.search")` opens a span as
//!   a child of the calling thread's current span (or a fresh root);
//!   the guard records itself into a fixed ring buffer on drop.
//!   Cross-thread continuation (a request hopping from the submitting
//!   client to a device worker) uses [`span_under`] with the trace and
//!   parent IDs carried in the request.
//! * [`metrics`] — named counters, gauges, and log-linear histograms
//!   under a process-global [`registry`]. Naming scheme:
//!   `imagecl_<subsystem>_<name>_<unit>` (e.g.
//!   `imagecl_serve_latency_us`); variants live in labels, not names.
//! * [`export`] — Prometheus text format, structured JSON, trace-tree
//!   rendering, Chrome/Perfetto trace-event export, and the in-repo
//!   Prometheus linter used by CI.
//! * [`slo`] — per-kernel latency objectives with attainment and
//!   multi-window error-budget burn rates (`/slo`, `imagecl stats`).
//! * [`http`] — the dependency-free HTTP endpoint (`imagecl serve
//!   --obs-addr`) exposing all of the above live, plus the matching
//!   GET client for `imagecl stats --url`.
//!
//! # Ring-buffer drop policy
//!
//! The tracer keeps the most recent [`trace::RING_CAPACITY`] (8192)
//! span records in a ring. A writer claims its slot with a single
//! atomic `fetch_add` on the ring cursor — writers never contend on
//! slot *choice*, and never block waiting for space: when the ring is
//! full the oldest record is overwritten unconditionally. The
//! trade-off is deliberate: under overload tracing degrades by
//! forgetting the past, never by slowing the present. Eviction can
//! orphan a trace (its children overwritten while the root survives,
//! or vice versa); the exporters therefore treat "root span resident"
//! as the completeness signal and skip traces without one rather than
//! rendering a misleading fragment.
//!
//! # Reading the silent-loss metrics
//!
//! Both lossy degradations above are themselves counted, so "is my
//! telemetry lying to me?" is answerable from `/metrics`:
//!
//! * `imagecl_obs_trace_drops_total` — span records evicted by ring
//!   overwrite. A non-zero *rate* during a scrape interval means the
//!   trace views are incomplete for that window: raise the scrape
//!   frequency or treat `/traces` as a sample, not a census. A large
//!   static value with zero rate is history, not an active problem.
//! * `imagecl_obs_hist_clamped_total` — histogram observations that
//!   landed in the saturating top octave (≥ 2^63). Any growth means
//!   some `_bucket`/`_sum` figures understate reality — typically a
//!   unit bug (seconds recorded as µs) rather than a genuine 292k-year
//!   latency; find the offending series before trusting percentiles.
//!
//! The execution-tier profiler (which engine tier ran, batched vs
//! scalar row coverage, optimizer pass statistics, per-phase wall
//! time) lives in [`crate::exec::profile`] and publishes into this
//! module's registry via `profile::publish`.

pub mod export;
pub mod http;
pub mod metrics;
pub mod slo;
pub mod trace;

pub use metrics::{registry, Counter, Gauge, Histogram, Registry};
pub use trace::{
    record_span, set_thread_device, span, span_under, tracer, SpanGuard, SpanRecord,
};
