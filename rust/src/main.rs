//! The `imagecl` command-line tool: compiler driver, auto-tuner launcher,
//! paper-experiment runners and pipeline executor.
//!
//! Argument parsing is hand-rolled (no clap in the offline crate set).

// Mirrors the lib crate's allow-list for the CI clippy gate.
#![allow(clippy::field_reassign_with_default)]
#![allow(clippy::type_complexity)]

use std::collections::BTreeMap;
use std::process::ExitCode;

use imagecl::analysis::KernelInfo;
use imagecl::baselines::{self, Baseline, ALL_BASELINES};
use imagecl::bench_defs::{self, ALL};
use imagecl::devices::{self, ALL_DEVICES};
use imagecl::imagecl::frontend;
use imagecl::pipeline::{schedule, Pipeline, Port};
use imagecl::report::{emit_report, render_config_table, render_fig6, Ms};
use imagecl::runtime::{default_artifact_dir, Tensor, XlaRuntime};
use imagecl::serve;
use imagecl::transform::{
    emit_fast_filter, emit_opencl, emit_standalone_host, lower, TuningConfig,
};
use imagecl::tuner::{self, MlSearchOpts, Strategy};

const USAGE: &str = "\
imagecl — ImageCL compiler, auto-tuner, serving layer and benchmark runner

USAGE:
  imagecl compile <file.imcl> [--config CFG] [--emit opencl|host|fast]
  imagecl tune <kernel> [--device DEV] [--grid N] [--strategy ml|random|exhaustive]
  imagecl serve [--requests N] [--concurrency C] [--kernels a,b,c] [--device DEV]
                [--grid N] [--exec real|sim] [--queue-cap N] [--max-batch N]
                [--workers N] [--strategy S] [--db PATH] [--legacy-tsv PATH]
                [--plan-cache-cap N] [--transfer-budget N] [--predict-budget N]
                [--obs-addr HOST:PORT] [--slo SPEC]
                [--listen HOST:PORT | --remote HOST:PORT] [--tenants a,b]
                [--tenant-quota RATE[:BURST]] [--request-deadline DUR]
                [--faults SPEC] [--metrics-out PATH] [--explore-eps F]
                serve synthetic traffic through the plan cache + tunedb.
                --obs-addr serves /metrics /healthz /traces /profile /slo
                live for the duration of the run (port 0 picks a free
                port, printed on startup); --slo sets latency objectives,
                e.g. \"default=100ms,target=0.99,blur=5ms\" (us|ms|s).
                --listen runs the TCP front-end (wire protocol v1) until
                a client sends a SHUTDOWN frame, then drains gracefully;
                --remote drives the load generator against such a server
                instead of in-process pools. --tenant-quota caps each
                tenant's admission rate, --request-deadline bounds
                admission+queue+execution (us|ms|s), --faults injects
                deterministic chaos, e.g.
                \"exec_panic=0.01,net_drop=0.05,exec_delay=20ms,seed=7\",
                and --metrics-out writes the final metrics JSON snapshot.
                --explore-eps F re-measures a near-winner config on that
                fraction of real requests, feeding the samples back into
                the knowledge base (bounded online re-exploration).
                --listen checkpoints the plan-cache index + SLO state on
                graceful drain (SHUTDOWN frame or SIGTERM) and replays it
                on the next start against the same --db (warm restart)
  imagecl submit <kernel> --remote HOST:PORT [--device DEV] [--grid N]
                [--seed N] [--tenant T] [--request-deadline DUR]
                [--ping] [--shutdown]
                submit one request to an `imagecl serve --listen` server
                over TCP (or --ping it / ask it to --shutdown and drain)
  imagecl tunedb stats|export [--db PATH]
  imagecl tunedb query <kernel> [--db PATH] [--device DEV] [--grid N]
  imagecl tunedb train <kernel> [--db PATH]
  imagecl tunedb import <legacy.tsv> [--db PATH]
  imagecl tunedb compact [--db PATH] [--cap N]
  imagecl tunedb fsck [--db PATH] [--repair]
                audit the store's checksummed journal; nonzero exit on
                torn/corrupt records. --repair stashes damaged raw lines
                into the .quarantine sidecar and atomically rewrites the
                store as a clean snapshot
  imagecl tunedb merge <replica.tsv>... [--db PATH]
                conflict-free merge of replica stores into --db:
                deterministic resolution per (kernel, device, grid,
                config) — wall beats sim, then higher seq — idempotent
                and order-independent (byte-identical output)
                inspect / repair / merge / compact the tuning knowledge base
  imagecl bench [--size N] [--iters N] [--kernels a,b] [--out PATH] [--smoke]
                run the gallery kernels through the engine ladder (tree
                oracle, unoptimized VM, optimized scalar VM, batched VM);
                verify bit-identity; write BENCH_exec.json; fail if the
                optimized VM regressed below the unoptimized VM on blur
  imagecl bench analyze [--history PATH] [--window N] [--min-runs N]
                [--threshold F] [--ci]
                compare the latest BENCH_exec_history.json entry against
                a median-of-previous-runs baseline with a noise-aware
                threshold; write BENCH_analysis.json beside the history
                and exit nonzero on a credible throughput regression
                (--ci prints the JSON verdict and passes when the
                history file does not exist yet)
  imagecl stats [--prom|--json] [--traces N] [--requests N] [--grid N]
                [--kernels a,b] [--exec real|sim] [--lint PATH]
                [--url http://HOST:PORT] [--chrome PATH]
                drive a short synthetic burst through the serving stack,
                then export the metrics registry — Prometheus text
                (--prom), JSON (--json) or a human summary with recent
                request traces and the SLO table. --lint PATH instead
                checks a Prometheus dump with the in-repo parser (the CI
                gate). --url fetches /metrics, /traces and /slo from a
                live --obs-addr server instead of running a local burst.
                --chrome PATH writes the traces as a Chrome/Perfetto
                trace-event file (open in chrome://tracing or
                ui.perfetto.dev)
  imagecl fig6 [--size N]            reproduce Figure 6 (slowdown vs baselines)
  imagecl tables [--size N]          reproduce Tables 2-5 (tuned configurations)
  imagecl pipeline [--size N]        run the Harris pipeline through PJRT
  imagecl devices                    list simulated devices
  imagecl kernels                    list built-in benchmark kernels

CFG example: \"wg=64x4 px=4x1 map=interleaved lmem=in cmem=f unroll=1:0\"
<kernel> is a built-in id (sepconv_row, conv2d, sobel, harris, ...) or a path.
Env: IMAGECL_EXEC=tree|vm|vm-scalar|vm-unopt forces the execution engine
     (tree oracle / batched VM / optimizer-only VM / PR-3 baseline VM).
";

/// Tiny flag parser: positional args + `--key value` pairs. Unknown
/// flags and a trailing `--flag` with no value are hard errors (each
/// command declares its flag set via [`Args::check_known`]).
struct Args {
    positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args, String> {
        Args::parse_with_switches(argv, &[])
    }

    /// Like [`Args::parse`], but flags named in `switches` are boolean:
    /// their presence means `true` and they consume no value.
    fn parse_with_switches(argv: &[String], switches: &[&str]) -> Result<Args, String> {
        let mut positional = Vec::new();
        let mut flags = BTreeMap::new();
        let mut it = argv.iter();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    return Err("bare `--` is not a flag".to_string());
                }
                let val = if switches.contains(&key) {
                    "true".to_string()
                } else {
                    it.next()
                        .ok_or_else(|| format!("flag --{key} needs a value"))?
                        .clone()
                };
                if flags.insert(key.to_string(), val).is_some() {
                    return Err(format!("flag --{key} given twice"));
                }
            } else {
                positional.push(a.clone());
            }
        }
        Ok(Args { positional, flags })
    }

    /// A boolean switch's value (absent = false).
    fn bool_flag(&self, key: &str) -> bool {
        matches!(self.flag(key), Some("true") | Some("1"))
    }

    /// Reject any flag outside `allowed` — catches typos like
    /// `--concurency 8` instead of silently ignoring them.
    fn check_known(&self, allowed: &[&str]) -> Result<(), String> {
        for key in self.flags.keys() {
            if !allowed.contains(&key.as_str()) {
                return Err(if allowed.is_empty() {
                    format!("unknown flag --{key} (this command takes no flags)")
                } else {
                    format!("unknown flag --{key} (expected one of: --{})", allowed.join(", --"))
                });
            }
        }
        Ok(())
    }

    fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn usize_flag(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.flag(key) {
            Some(v) => v.parse().map_err(|_| format!("bad --{key}: {v:?}")),
            None => Ok(default),
        }
    }

    /// A probability-shaped flag: a finite fraction in `[0, 1]`.
    fn fraction_flag(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.flag(key) {
            Some(v) => v
                .parse::<f64>()
                .ok()
                .filter(|p| p.is_finite() && (0.0..=1.0).contains(p))
                .ok_or_else(|| {
                    format!("bad --{key}: {v:?} (want a fraction in [0, 1])")
                }),
            None => Ok(default),
        }
    }
}

fn kernel_source(name_or_path: &str) -> Result<String, String> {
    if let Some(k) = bench_defs::kernel_by_id(name_or_path) {
        return Ok(k.source.to_string());
    }
    std::fs::read_to_string(name_or_path)
        .map_err(|e| format!("cannot read {name_or_path:?}: {e}"))
}

fn run() -> Result<(), String> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        print!("{USAGE}");
        return Ok(());
    };
    let switches: &[&str] = match cmd.as_str() {
        "bench" => &["smoke", "ci"],
        "stats" => &["prom", "json"],
        "submit" => &["ping", "shutdown"],
        "tunedb" => &["repair"],
        _ => &[],
    };
    let args = Args::parse_with_switches(&argv[1..], switches)?;
    match cmd.as_str() {
        "compile" => cmd_compile(&args),
        "tune" => cmd_tune(&args),
        "serve" => cmd_serve(&args),
        "submit" => cmd_submit(&args),
        "stats" => cmd_stats(&args),
        "tunedb" => cmd_tunedb(&args),
        "bench" => cmd_bench(&args),
        "fig6" => cmd_fig6(&args),
        "tables" => cmd_tables(&args),
        "pipeline" => cmd_pipeline(&args),
        "devices" => {
            args.check_known(&[])?;
            println!("{:<10} {:>5} {:>6} {:>9} {:>9}", "device", "CUs", "SIMD", "GFLOP/s", "GB/s");
            for d in ALL_DEVICES {
                println!(
                    "{:<10} {:>5} {:>6} {:>9.0} {:>9.0}",
                    d.name, d.compute_units, d.simd_width, d.peak_gflops(), d.mem_bw_gbs
                );
            }
            Ok(())
        }
        "kernels" => {
            args.check_known(&[])?;
            for b in &ALL {
                for k in b.kernels {
                    println!("{:<12} ({}, {}x{})", k.id, b.display, b.paper_size.0, b.paper_size.1);
                }
            }
            Ok(())
        }
        "--help" | "-h" | "help" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown command {other:?}\n\n{USAGE}")),
    }
}

/// `imagecl bench`: the execution-engine benchmark — gallery kernels
/// through both the bytecode VM and the tree-walking oracle, with the
/// bit-identity check and the `BENCH_exec.json` report (see README
/// "Execution engine"). `--smoke` is the CI configuration.
fn cmd_bench(args: &Args) -> Result<(), String> {
    if args.positional.first().map(String::as_str) == Some("analyze") {
        return cmd_bench_analyze(args);
    }
    args.check_known(&["size", "iters", "kernels", "out", "smoke"])?;
    let mut opts = if args.bool_flag("smoke") {
        imagecl::exec::bench::BenchOpts::smoke()
    } else {
        imagecl::exec::bench::BenchOpts::default()
    };
    opts.size = args.usize_flag("size", opts.size)?;
    opts.iters = args.usize_flag("iters", opts.iters)?;
    if let Some(list) = args.flag("kernels") {
        opts.kernels = list.split(',').filter(|k| !k.is_empty()).map(String::from).collect();
    }
    if let Some(p) = args.flag("out") {
        opts.out = Some(std::path::PathBuf::from(p));
    }
    let report = imagecl::exec::bench::run_and_write(&opts)?;
    if let Some(s) = report.blur_speedup() {
        println!("blur speedup (VM vs tree-walker): {s:.2}x");
    }
    if let Some(s) = report.blur_opt_speedup() {
        println!("blur speedup (optimized+batched VM vs PR-3 VM): {s:.2}x");
    }
    Ok(())
}

/// `imagecl bench analyze`: the bench-history regression gate — judge
/// the latest `BENCH_exec_history.json` entry against a robust baseline
/// of previous same-size runs (see `exec::analyze` for the statistics)
/// and exit nonzero on a credible regression. `--ci` prints the JSON
/// verdict and treats a missing history file as a pass (a fresh clone
/// has no history to regress against).
fn cmd_bench_analyze(args: &Args) -> Result<(), String> {
    use imagecl::exec::analyze;
    args.check_known(&["history", "window", "min-runs", "threshold", "ci"])?;
    let mut opts = analyze::AnalyzeOpts::default();
    if let Some(p) = args.flag("history") {
        opts.history = std::path::PathBuf::from(p);
    }
    opts.window = args.usize_flag("window", opts.window)?.max(1);
    opts.min_runs = args.usize_flag("min-runs", opts.min_runs)?.max(1);
    if let Some(t) = args.flag("threshold") {
        opts.min_rel = t
            .parse::<f64>()
            .ok()
            .filter(|v| v.is_finite() && *v > 0.0)
            .ok_or_else(|| format!("bad --threshold: {t:?} (want a fraction like 0.3)"))?;
    }
    let ci = args.bool_flag("ci");
    if ci && !opts.history.exists() {
        println!(
            "no bench history at {} yet — nothing to regress against",
            opts.history.display()
        );
        return Ok(());
    }
    let analysis = analyze::run(&opts)?;
    if ci {
        print!("{}", analysis.to_json());
    } else {
        print!("{}", analysis.render());
    }
    let out = opts.history.with_file_name("BENCH_analysis.json");
    if let Err(e) = std::fs::write(&out, analysis.to_json()) {
        eprintln!("warning: cannot write {}: {e}", out.display());
    } else {
        eprintln!("wrote {}", out.display());
    }
    let regs = analysis.regressions();
    if !regs.is_empty() {
        return Err(format!(
            "performance regression in {} (vs median of previous runs)",
            regs.iter().map(|k| k.name.as_str()).collect::<Vec<_>>().join(", ")
        ));
    }
    Ok(())
}

fn cmd_compile(args: &Args) -> Result<(), String> {
    args.check_known(&["config", "emit"])?;
    let file = args
        .positional
        .first()
        .ok_or("compile needs a kernel name or file")?;
    let src = kernel_source(file)?;
    let cfg = match args.flag("config") {
        Some(c) => TuningConfig::parse(c)?,
        None => TuningConfig::default(),
    };
    let info = KernelInfo::analyze(frontend(&src).map_err(|e| e.to_string())?);
    let plan = lower(&info, &cfg).map_err(|e| e.to_string())?;
    match args.flag("emit").unwrap_or("opencl") {
        "opencl" => print!("{}", emit_opencl(&plan)),
        "host" => print!("{}", emit_standalone_host(&plan)),
        "fast" => print!("{}", emit_fast_filter(&plan)),
        other => return Err(format!("unknown --emit {other:?}")),
    }
    Ok(())
}

fn strategy_of(args: &Args) -> Result<Strategy, String> {
    Ok(match args.flag("strategy").unwrap_or("ml") {
        "ml" => Strategy::MlTwoPhase(MlSearchOpts::default()),
        "random" => Strategy::Random { evals: 1700, seed: 42 },
        "exhaustive" => Strategy::Exhaustive,
        other => return Err(format!("unknown --strategy {other:?}")),
    })
}

fn cmd_tune(args: &Args) -> Result<(), String> {
    args.check_known(&["device", "grid", "strategy"])?;
    let kernel = args.positional.first().ok_or("tune needs a kernel")?;
    let src = kernel_source(kernel)?;
    let info = KernelInfo::analyze(frontend(&src).map_err(|e| e.to_string())?);
    let n = args.usize_flag("grid", 2048)?;
    let strategy = strategy_of(args)?;
    let devs: Vec<&devices::DeviceSpec> = match args.flag("device") {
        Some(d) => vec![devices::by_name(d).ok_or(format!("unknown device {d:?}"))?],
        None => ALL_DEVICES.to_vec(),
    };
    for dev in devs {
        let res = tuner::tune_on_simulator(&info, dev, (n, n), &strategy);
        println!(
            "{:<10} best {:<55}  est {}  ({} evals over a space of {})",
            dev.name,
            res.best.to_string(),
            Ms::from(res.best_time),
            res.evals,
            res.space_size
        );
    }
    Ok(())
}

fn cmd_fig6(args: &Args) -> Result<(), String> {
    args.check_known(&["size"])?;
    let n = args.usize_flag("size", 1024)?;
    let mut full = String::new();
    for bench in &ALL {
        let mut series: Vec<(&str, Vec<f64>)> =
            ALL_BASELINES.iter().map(|b| (b.name(), Vec::new())).collect();
        for dev in ALL_DEVICES {
            let ic = baselines::imagecl_time(bench, dev, n);
            for (i, b) in ALL_BASELINES.iter().enumerate() {
                // Paper: "we only compare against OpenCV for the Harris
                // corner detection" (§6).
                let v = if bench.id == "harris" && *b != Baseline::OpenCv {
                    f64::NAN
                } else {
                    baselines::baseline_time(*b, bench, dev, n) / ic
                };
                series[i].1.push(v);
            }
        }
        let names: Vec<&str> = ALL_DEVICES.iter().map(|d| d.name).collect();
        full.push_str(&render_fig6(
            &format!("Figure 6 — {} ({}x{})", bench.display, n, n),
            &names,
            &series,
        ));
        full.push('\n');
    }
    emit_report("fig6.txt", &full);
    Ok(())
}

fn cmd_tables(args: &Args) -> Result<(), String> {
    args.check_known(&["size"])?;
    let n = args.usize_flag("size", 1024)?;
    let strategy = baselines::imagecl_strategy();
    let mut full = String::new();
    let tables: [(&str, &[&str]); 4] = [
        ("Table 2: separable convolution (row R / column C)", &["sepconv_row", "sepconv_col"]),
        ("Table 3: non-separable convolution", &["conv2d"]),
        ("Table 4: Sobel kernel of Harris", &["sobel"]),
        ("Table 5: Harris kernel", &["harris"]),
    ];
    for (title, kernels) in tables {
        let info = KernelInfo::analyze(
            frontend(bench_defs::kernel_by_id(kernels[0]).unwrap().source)
                .map_err(|e| e.to_string())?,
        );
        let mut columns = Vec::new();
        for dev in ALL_DEVICES {
            for kid in kernels {
                let kinfo = KernelInfo::analyze(
                    frontend(bench_defs::kernel_by_id(kid).unwrap().source)
                        .map_err(|e| e.to_string())?,
                );
                let res = tuner::tune_on_simulator(&kinfo, dev, (n, n), &strategy);
                let label = if kernels.len() > 1 {
                    format!("{} {}", dev.name, bench_defs::kernel_by_id(kid).unwrap().table_name)
                } else {
                    dev.name.to_string()
                };
                columns.push((label, res.best));
            }
        }
        full.push_str(&render_config_table(title, &info, &columns));
        full.push('\n');
    }
    emit_report("tables.txt", &full);
    Ok(())
}

/// Validate a `HOST:PORT` flag value without resolving it (bind/connect
/// surface reachability problems later; this catches shape mistakes
/// with an actionable message). IPv6 literals use the bracketed form.
fn host_port(flag: &str, v: &str) -> Result<String, String> {
    let shape_err =
        || format!("bad --{flag} {v:?} (want HOST:PORT, e.g. 127.0.0.1:7878)");
    let (host, port) = v.rsplit_once(':').ok_or_else(shape_err)?;
    if host.is_empty() || port.parse::<u16>().is_err() {
        return Err(shape_err());
    }
    Ok(v.to_string())
}

/// Parse an optional duration flag in the SLO syntax (`us`/`ms`/`s`).
fn duration_flag(
    args: &Args,
    key: &str,
) -> Result<Option<std::time::Duration>, String> {
    match args.flag(key) {
        None => Ok(None),
        Some(v) => {
            let us = imagecl::obs::slo::parse_latency_us(v).map_err(|e| {
                format!("bad --{key}: {e} (want e.g. 800us, 250ms or 2s)")
            })?;
            Ok(Some(std::time::Duration::from_micros(us)))
        }
    }
}

/// `--metrics-out PATH`: dump the final metrics-registry JSON snapshot
/// (the CI chaos job uploads this as its run artifact).
fn write_metrics_out(args: &Args) -> Result<(), String> {
    let Some(path) = args.flag("metrics-out") else {
        return Ok(());
    };
    let doc = imagecl::obs::export::json(0);
    imagecl::fsutil::write_atomic(std::path::Path::new(path), doc.as_bytes())
        .map_err(|e| format!("cannot write {path:?}: {e}"))?;
    eprintln!("wrote metrics JSON to {path}");
    Ok(())
}

/// `imagecl serve`: spin up the kernel service (warm-starting from the
/// tuned-config TSV when present) and either drive synthetic traffic
/// through it (in-process pools, or over TCP against a `--remote`
/// server) or expose it as a long-running TCP front-end (`--listen`).
fn cmd_serve(args: &Args) -> Result<(), String> {
    args.check_known(&[
        "requests",
        "concurrency",
        "kernels",
        "device",
        "grid",
        "exec",
        "queue-cap",
        "max-batch",
        "workers",
        "strategy",
        "db",
        "legacy-tsv",
        "plan-cache-cap",
        "transfer-budget",
        "predict-budget",
        "obs-addr",
        "slo",
        "listen",
        "remote",
        "tenants",
        "tenant-quota",
        "request-deadline",
        "faults",
        "metrics-out",
        "explore-eps",
    ])?;
    if let Some(spec) = args.flag("slo") {
        imagecl::obs::slo::engine()
            .configure(imagecl::obs::slo::SloSpec::parse(spec)?);
    }
    let mut opts = serve::LoadGenOpts {
        requests: args.usize_flag("requests", 1000)?,
        concurrency: args.usize_flag("concurrency", 8)?,
        grid: args.usize_flag("grid", 64)?,
        queue_cap: args.usize_flag("queue-cap", 256)?,
        max_batch: args.usize_flag("max-batch", 32)?,
        workers_per_device: args.usize_flag("workers", 2)?,
        ..Default::default()
    };
    opts.obs_addr = args.flag("obs-addr").map(String::from);
    if let Some(list) = args.flag("kernels") {
        opts.kernels = list.split(',').filter(|k| !k.is_empty()).map(String::from).collect();
        for k in &opts.kernels {
            if bench_defs::kernel_by_id(k).is_none() {
                return Err(format!("unknown kernel {k:?} (see `imagecl kernels`)"));
            }
        }
    }
    if let Some(d) = args.flag("device") {
        if d != "all" {
            opts.devices =
                vec![devices::by_name(d).ok_or(format!("unknown device {d:?}"))?];
        }
    }
    // PR-8 front-end / robustness flags — all validated up front, so a
    // typo fails with an actionable message before any thread spawns.
    let listen = args.flag("listen").map(|v| host_port("listen", v)).transpose()?;
    opts.remote = args.flag("remote").map(|v| host_port("remote", v)).transpose()?;
    if listen.is_some() && opts.remote.is_some() {
        return Err("--listen and --remote are mutually exclusive \
                    (--listen runs a server, --remote drives one)"
            .to_string());
    }
    if let Some(list) = args.flag("tenants") {
        opts.tenants = list
            .split(',')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(String::from)
            .collect();
        if opts.tenants.is_empty() {
            return Err(format!(
                "bad --tenants {list:?} (want a comma-separated list, \
                 e.g. \"team-a,team-b\")"
            ));
        }
    }
    let quota = args.flag("tenant-quota").map(serve::TenantQuota::parse).transpose()?;
    opts.deadline = duration_flag(args, "request-deadline")?;
    let faults = args.flag("faults").map(serve::FaultSpec::parse).transpose()?;
    if opts.remote.is_some() {
        for (flag, set) in
            [("--faults", faults.is_some()), ("--tenant-quota", quota.is_some())]
        {
            if set {
                return Err(format!(
                    "{flag} configures the serving process — pass it to the \
                     `imagecl serve --listen` server, not to a --remote client"
                ));
            }
        }
    }
    opts.quota = quota;
    let exec = match args.flag("exec").unwrap_or("real") {
        "real" => serve::ExecMode::Real,
        "sim" => serve::ExecMode::Simulate,
        other => return Err(format!("unknown --exec {other:?} (want real|sim)")),
    };
    let strategy = match args.flag("strategy") {
        None => serve::serve_strategy(),
        Some(_) => strategy_of(args)?,
    };
    let db_path = match args.flag("db") {
        Some("none") => None,
        Some(p) => Some(std::path::PathBuf::from(p)),
        None => Some(imagecl::tunedb::default_db_path()),
    };
    let legacy_tsv = match args.flag("legacy-tsv") {
        Some("none") => None,
        Some(p) => Some(std::path::PathBuf::from(p)),
        None => Some(serve::default_tuned_path()),
    };
    // 0 = unbounded; long-lived servers should set a cap (every new grid
    // is a new plan-cache key).
    let plan_cache_cap = match args.usize_flag("plan-cache-cap", 512)? {
        0 => None,
        n => Some(n),
    };

    let service = serve::KernelService::new(serve::ServiceConfig {
        strategy,
        db_path: db_path.clone(),
        legacy_tsv,
        exec,
        plan_cache_cap,
        transfer_budget: args.usize_flag("transfer-budget", 48)?,
        predict_budget: args.usize_flag("predict-budget", 48)?,
        explore_eps: args.fraction_flag("explore-eps", 0.0)?,
    });
    if let Some(spec) = faults {
        if spec.active() {
            eprintln!("chaos: fault injection armed ({spec:?})");
        }
        service.set_faults(serve::FaultInjector::new(spec));
    }
    let warm = service.tuned_len();
    match (&db_path, warm) {
        (Some(p), 0) => println!("cold start (no tuning knowledge at {p:?} yet)"),
        (Some(p), n) => println!("warm start: {n} tuned winners known via {p:?}"),
        (None, _) => println!("ephemeral run (no tuning-knowledge persistence)"),
    }
    if let Some(addr) = listen {
        return serve_listen(args, service, &opts, &addr);
    }
    match &opts.remote {
        Some(addr) => println!(
            "driving {} requests (concurrency {}) over TCP against {addr}",
            opts.requests, opts.concurrency
        ),
        None => println!(
            "serving {} requests (concurrency {}) over {} kernels × {} devices at {}x{} [{}]",
            opts.requests,
            opts.concurrency,
            opts.kernels.len(),
            opts.devices.len(),
            opts.grid,
            opts.grid,
            if exec == serve::ExecMode::Real { "real execution" } else { "simulated" },
        ),
    }

    let report = serve::run_loadgen(service, &opts).map_err(|e| e.to_string())?;
    print!("{}", report.render());
    // Loadgen published the metrics registry on completion; the
    // tier-profiler table explains where the execution time went.
    print!("{}", imagecl::exec::profile::profiler().render());
    let slo = imagecl::obs::slo::engine().report();
    if !slo.kernels.is_empty() {
        println!("SLO attainment (target {:.2}%):", slo.target * 100.0);
        print!("{}", slo.render());
    }
    write_metrics_out(args)?;
    if report.errors > 0 {
        return Err(format!("{} requests failed", report.errors));
    }
    Ok(())
}

/// SIGTERM → graceful drain, with no libc crate: std already links the
/// platform C library, so binding `signal(2)` directly is enough. The
/// handler does the only async-signal-safe thing — one atomic store —
/// and a watchdog thread polls the flag and triggers the same drain
/// path a client `SHUTDOWN` frame would.
#[cfg(unix)]
mod sigterm {
    use std::sync::atomic::{AtomicBool, Ordering};

    static TERM: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_term(_sig: i32) {
        TERM.store(true, Ordering::SeqCst);
    }

    /// Install the handler; `false` when the registration failed (the
    /// caller keeps running without SIGTERM drain).
    pub fn install() -> bool {
        extern "C" {
            fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
        }
        const SIGTERM: i32 = 15;
        const SIG_ERR: usize = usize::MAX;
        (unsafe { signal(SIGTERM, on_term) }) != SIG_ERR
    }

    pub fn pending() -> bool {
        TERM.load(Ordering::SeqCst)
    }
}

/// `imagecl serve --listen`: run the TCP front-end until a client sends
/// a `SHUTDOWN` frame (or SIGTERM arrives), then drain gracefully —
/// finish everything admitted, flush background model training, publish
/// a final metrics snapshot, checkpoint the plan-cache index + SLO state
/// beside the store for the next warm restart, join every thread.
fn serve_listen(
    args: &Args,
    service: std::sync::Arc<serve::KernelService>,
    opts: &serve::LoadGenOpts,
    addr: &str,
) -> Result<(), String> {
    // Warm restart: replay the previous run's checkpoint before the
    // socket opens, so the very first request hits a built plan (the
    // durable store answers every config lookup — no tuning search).
    let restored = service.restore_checkpoint(Some(imagecl::obs::slo::engine()));
    if restored > 0 {
        println!("warm restart: {restored} plans rebuilt from checkpoint");
    }
    let srv = serve::NetServer::start(
        service.clone(),
        serve::NetServerOpts {
            addr: addr.to_string(),
            devices: opts.devices.clone(),
            workers_per_device: opts.workers_per_device,
            queue_cap: opts.queue_cap,
            max_batch: opts.max_batch,
            quota: opts.quota,
            default_deadline: opts.deadline,
            ..Default::default()
        },
    )?;
    let obs_server = match &opts.obs_addr {
        None => None,
        Some(obs_addr) => {
            let publish_service = service.clone();
            let publish: imagecl::obs::http::PublishFn =
                std::sync::Arc::new(move || publish_service.publish_obs());
            let server = imagecl::obs::http::ObsServer::start(
                obs_addr,
                srv.health_fn(),
                Some(publish),
            )?;
            println!("obs endpoint listening on http://{}", server.addr());
            Some(server)
        }
    };
    let bound = srv.addr();
    println!(
        "listening on {bound} (wire protocol v{}) — drain with: \
         imagecl submit --shutdown --remote {bound}",
        imagecl::serve::net::VERSION
    );
    #[cfg(unix)]
    if sigterm::install() {
        let drain = srv.drain_handle();
        let _ = std::thread::Builder::new()
            .name("imagecl-sigterm".to_string())
            .spawn(move || loop {
                if sigterm::pending() {
                    drain.request_drain();
                    return;
                }
                std::thread::sleep(std::time::Duration::from_millis(100));
            });
    }
    srv.wait();
    println!("drain requested: finishing in-flight requests, flushing state");
    srv.shutdown();
    // All in-flight work is done; the plan-cache index is final. Record
    // it (atomically, beside the store) for the next start's warm-up.
    if let Some(n) = service.write_checkpoint(Some(imagecl::obs::slo::engine())) {
        println!("checkpointed {n} plan keys for warm restart");
    }
    if let Some(server) = obs_server {
        server.shutdown();
    }
    let s = service.stats();
    println!(
        "drained cleanly: {} wire requests ({} shed, {} over-quota, \
         {} past-deadline, {} caught panics, {} quarantined plans)",
        s.net_requests,
        s.sheds,
        s.quota_rejects,
        s.deadline_rejects,
        s.exec_panics,
        s.quarantines
    );
    write_metrics_out(args)
}

/// `imagecl submit`: one request to a `--listen` server over the wire
/// protocol — or `--ping` it, or ask it to `--shutdown` and drain. The
/// client retries transport failures and retryable statuses with capped
/// exponential backoff.
fn cmd_submit(args: &Args) -> Result<(), String> {
    args.check_known(&[
        "remote",
        "device",
        "grid",
        "seed",
        "tenant",
        "request-deadline",
        "ping",
        "shutdown",
    ])?;
    let addr = host_port(
        "remote",
        args.flag("remote").ok_or(
            "submit needs --remote HOST:PORT (a running `imagecl serve --listen` server)",
        )?,
    )?;
    let seed = args.usize_flag("seed", 0)? as u64;
    let mut client = serve::NetClient::new(&addr, seed);
    if args.bool_flag("ping") {
        client.ping()?;
        println!("{addr}: OK");
        return Ok(());
    }
    if args.bool_flag("shutdown") {
        client.shutdown_server()?;
        println!("{addr}: draining");
        return Ok(());
    }
    let kernel = args
        .positional
        .first()
        .ok_or("submit needs a kernel id (or --ping / --shutdown)")?;
    let n = args.usize_flag("grid", 64)?;
    let mut spec = imagecl::serve::net::SubmitSpec::new(kernel, (n, n), seed);
    if let Some(d) = args.flag("device") {
        spec.device = d.to_string();
    }
    if let Some(t) = args.flag("tenant") {
        spec.tenant = t.to_string();
    }
    if let Some(deadline) = duration_flag(args, "request-deadline")? {
        spec.deadline_us = deadline.as_micros() as u64;
    }
    match client.submit(&spec) {
        Ok(reply) => {
            println!(
                "{kernel} on {}: {} (checksum {:#018x}, server latency {}us, batch {})",
                reply.device,
                Ms::from(reply.seconds),
                reply.checksum,
                reply.latency_us,
                reply.batch
            );
            Ok(())
        }
        Err(e) => Err(format!("submit {kernel}: {e}")),
    }
}

/// `imagecl stats`: exercise the full serving stack with a short
/// synthetic burst (real execution by default, ephemeral knowledge
/// base), then export the observability state — Prometheus text
/// (`--prom`), JSON (`--json`) or a human summary with the tier-profiler
/// table and the most recent request traces. `--lint PATH` skips the
/// burst and checks a Prometheus text dump with the in-repo parser
/// instead (the CI gate; no promtool in the offline toolchain).
fn cmd_stats(args: &Args) -> Result<(), String> {
    args.check_known(&[
        "prom", "json", "traces", "lint", "requests", "grid", "kernels", "exec",
        "url", "chrome",
    ])?;
    if let Some(path) = args.flag("lint") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read {path:?}: {e}"))?;
        let (families, samples) = imagecl::obs::export::lint_prometheus(&text)?;
        println!("{path}: OK — {families} metric families, {samples} samples");
        return Ok(());
    }
    if args.bool_flag("prom") && args.bool_flag("json") {
        return Err("--prom and --json are mutually exclusive".to_string());
    }
    let traces = args.usize_flag("traces", 3)?;
    if let Some(url) = args.flag("url") {
        return stats_from_url(args, url, traces);
    }
    let mut opts = serve::LoadGenOpts {
        requests: args.usize_flag("requests", 32)?,
        concurrency: 4,
        grid: args.usize_flag("grid", 32)?,
        queue_cap: 64,
        max_batch: 8,
        workers_per_device: 1,
        ..Default::default()
    };
    if let Some(list) = args.flag("kernels") {
        opts.kernels =
            list.split(',').filter(|k| !k.is_empty()).map(String::from).collect();
        for k in &opts.kernels {
            if bench_defs::kernel_by_id(k).is_none() {
                return Err(format!("unknown kernel {k:?} (see `imagecl kernels`)"));
            }
        }
    }
    let exec = match args.flag("exec").unwrap_or("real") {
        "real" => serve::ExecMode::Real,
        "sim" => serve::ExecMode::Simulate,
        other => return Err(format!("unknown --exec {other:?} (want real|sim)")),
    };
    // Ephemeral db + fixed cheap strategy: `stats` is a diagnostic, not
    // a tuning run — it must not grow the persistent knowledge base.
    let service = serve::KernelService::new(serve::ServiceConfig {
        strategy: Strategy::Random { evals: 40, seed: 7 },
        db_path: None,
        legacy_tsv: None,
        exec,
        plan_cache_cap: None,
        transfer_budget: 0,
        predict_budget: 0,
        explore_eps: 0.0,
    });
    let report = serve::run_loadgen(service, &opts).map_err(|e| e.to_string())?;
    if let Some(path) = args.flag("chrome") {
        let doc = imagecl::obs::export::chrome_trace(traces.max(16));
        std::fs::write(path, &doc).map_err(|e| format!("cannot write {path:?}: {e}"))?;
        println!("wrote Chrome trace to {path} (open in chrome://tracing)");
    }
    if args.bool_flag("prom") {
        print!("{}", imagecl::obs::export::prometheus());
    } else if args.bool_flag("json") {
        print!("{}", imagecl::obs::export::json(traces));
    } else {
        print!("{}", report.render());
        print!("{}", imagecl::exec::profile::profiler().render());
        let slo = imagecl::obs::slo::engine().report();
        if !slo.kernels.is_empty() {
            println!("SLO attainment (target {:.2}%):", slo.target * 100.0);
            print!("{}", slo.render());
        }
        if traces > 0 {
            print!("{}", imagecl::obs::export::render_traces(traces));
        }
    }
    if report.errors > 0 {
        return Err(format!("{} requests failed", report.errors));
    }
    Ok(())
}

/// `imagecl stats --url`: read a live `--obs-addr` server instead of
/// running a local burst — `--prom` relays `/metrics` verbatim,
/// `--chrome PATH` saves `/traces?format=chrome`, and the default
/// summary prints linted `/metrics` counts, `/slo` and the trace trees.
fn stats_from_url(args: &Args, url: &str, traces: usize) -> Result<(), String> {
    use imagecl::obs::http::http_get;
    let base = url.trim_end_matches('/');
    let fetch = |path: &str| -> Result<String, String> {
        let (status, body) = http_get(&format!("{base}{path}"))?;
        if status != 200 {
            return Err(format!("GET {base}{path} -> HTTP {status}"));
        }
        Ok(body)
    };
    if args.bool_flag("json") {
        return Err("--json is not supported with --url (use --prom or the summary)"
            .to_string());
    }
    if let Some(path) = args.flag("chrome") {
        let doc = fetch(&format!("/traces?format=chrome&traces={}", traces.max(16)))?;
        std::fs::write(path, &doc).map_err(|e| format!("cannot write {path:?}: {e}"))?;
        println!("wrote Chrome trace from {base} to {path}");
        return Ok(());
    }
    let metrics = fetch("/metrics")?;
    if args.bool_flag("prom") {
        print!("{metrics}");
        return Ok(());
    }
    let (families, samples) = imagecl::obs::export::lint_prometheus(&metrics)?;
    println!("{base}/metrics: OK — {families} metric families, {samples} samples");
    println!("{base}/healthz: {}", fetch("/healthz")?.trim_end());
    println!("{base}/slo:");
    print!("{}", fetch("/slo")?);
    if traces > 0 {
        println!("{base}/traces:");
        print!("{}", fetch(&format!("/traces?format=tree&traces={traces}"))?);
    }
    Ok(())
}

/// `imagecl tunedb`: inspect and exercise the tuning knowledge base —
/// `stats` (what it knows), `export` (dump the TSV), `query` (what each
/// tier would answer for a key), `train` (fit the per-kernel performance
/// model), `import` (migrate a legacy PR-1 warm-start TSV).
fn cmd_tunedb(args: &Args) -> Result<(), String> {
    args.check_known(&["db", "device", "grid", "cap", "repair"])?;
    let sub = args
        .positional
        .first()
        .ok_or(
            "tunedb needs a subcommand: \
             stats|export|query|train|import|compact|fsck|merge",
        )?
        .as_str();
    let db_path = args
        .flag("db")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(imagecl::tunedb::default_db_path);
    // fsck and merge operate on the raw journal files, not a loaded db.
    match sub {
        "fsck" => return cmd_tunedb_fsck(args, &db_path),
        "merge" => return cmd_tunedb_merge(args, &db_path),
        _ => {}
    }
    let db = imagecl::tunedb::TuneDb::open(&db_path);
    match sub {
        "stats" => {
            println!(
                "tunedb {db_path:?}: {} records ({} winners, {} wall-clock samples)",
                db.len(),
                db.best_len(),
                db.wall_len()
            );
            // Per (kernel, device) winner counts.
            let mut per: BTreeMap<(String, &str), (usize, usize)> = BTreeMap::new();
            for r in db.snapshot() {
                let e = per.entry((r.kernel.clone(), r.device)).or_default();
                e.0 += 1;
                if r.best {
                    e.1 += 1;
                }
            }
            for ((kernel, device), (records, winners)) in per {
                println!("  {kernel:<14} {device:<10} {records:>6} records, {winners:>4} winners");
            }
            Ok(())
        }
        "export" => {
            print!("{}", imagecl::tunedb::store::HEADER);
            for r in db.snapshot() {
                println!("{}", imagecl::tunedb::store::render_line(&r));
            }
            Ok(())
        }
        "query" => {
            let kernel = args
                .positional
                .get(1)
                .ok_or("tunedb query needs a kernel id")?;
            let n = args.usize_flag("grid", 1024)?;
            let devs: Vec<&devices::DeviceSpec> = match args.flag("device") {
                Some(d) => vec![devices::by_name(d).ok_or(format!("unknown device {d:?}"))?],
                None => ALL_DEVICES.to_vec(),
            };
            let model = db.model_for(kernel);
            for dev in devs {
                use imagecl::tunedb::Answer;
                match db.lookup(kernel, dev.name, (n, n)) {
                    Answer::Exact(rec) => println!(
                        "{:<10} exact     {}  ({})",
                        dev.name,
                        rec.config,
                        Ms::from(rec.seconds)
                    ),
                    Answer::Transfer { rec, distance } => println!(
                        "{:<10} transfer  {}  (seed from {}x{}, distance {:.2})",
                        dev.name, rec.config, rec.grid.0, rec.grid.1, distance
                    ),
                    Answer::Miss => match &model {
                        Some(m) => println!(
                            "{:<10} model     ({} training records, train-MSE {:.3})",
                            dev.name, m.samples, m.train_mse
                        ),
                        None => println!("{:<10} miss      (cold: full search)", dev.name),
                    },
                }
            }
            Ok(())
        }
        "train" => {
            let kernel = args
                .positional
                .get(1)
                .ok_or("tunedb train needs a kernel id")?;
            match db.model_for(kernel) {
                Some(m) => {
                    println!(
                        "trained performance model for {kernel}: {} records, \
                         train-MSE {:.4} (log10-seconds)",
                        m.samples, m.train_mse
                    );
                    Ok(())
                }
                None => Err(format!(
                    "not enough usable records to train a model for {kernel:?} \
                     (need >= {} with feature vectors, have {} records for \
                     this kernel)",
                    imagecl::tunedb::MIN_TRAIN_RECORDS,
                    db.kernel_len(kernel)
                )),
            }
        }
        "import" => {
            let legacy = args
                .positional
                .get(1)
                .ok_or("tunedb import needs a legacy TSV path")?;
            let n = db.import_legacy_tsv(std::path::Path::new(legacy));
            println!(
                "imported {n} legacy warm-start configs from {legacy:?} into {db_path:?}"
            );
            Ok(())
        }
        "compact" => {
            let cap = args.usize_flag("cap", imagecl::tunedb::HISTORY_CAP_PER_KEY)?;
            let stats = db.compact(cap);
            println!(
                "compacted {db_path:?}: kept {} records, removed {} \
                 (history cap {cap} per key, latest winner per key)",
                stats.kept, stats.removed
            );
            Ok(())
        }
        other => Err(format!(
            "unknown tunedb subcommand {other:?} \
             (want stats|export|query|train|import|compact|fsck|merge)"
        )),
    }
}

/// `imagecl tunedb fsck [--repair]`: audit the checksummed journal —
/// every torn or corrupt record anywhere in the file is reported with
/// its line number and reason; damage without `--repair` exits nonzero
/// (the CI crash-recovery gate). `--repair` stashes the damaged raw
/// lines into the `.quarantine` sidecar, then atomically rewrites the
/// store as a clean snapshot of the intact records.
fn cmd_tunedb_fsck(args: &Args, db_path: &std::path::Path) -> Result<(), String> {
    let report = imagecl::tunedb::fsck(db_path)
        .map_err(|e| format!("cannot read {db_path:?}: {e}"))?;
    println!(
        "tunedb {db_path:?}: {} intact records, {} quarantined, {} stale, \
         epoch {}, max seq {}",
        report.records,
        report.quarantined.len(),
        report.stale,
        report.epoch.map_or_else(|| "none".to_string(), |e| format!("{e:016x}")),
        report.max_seq,
    );
    for (lno, raw) in &report.quarantined {
        let shown: String = raw.chars().take(60).collect();
        println!("  line {lno}: torn/corrupt record: {shown}");
    }
    if args.bool_flag("repair") {
        if report.clean() {
            println!("store is clean — nothing to repair");
            return Ok(());
        }
        let repaired = imagecl::tunedb::fsck_repair(db_path)
            .map_err(|e| format!("cannot repair {db_path:?}: {e}"))?;
        println!(
            "repaired: {} damaged lines stashed in {:?}, store rewritten with \
             {} records",
            repaired.quarantined.len(),
            imagecl::tunedb::quarantine_path(db_path),
            repaired.records,
        );
        return Ok(());
    }
    if !report.clean() {
        return Err(format!(
            "{} damaged record(s) in {db_path:?} — rerun with --repair to \
             quarantine them and rewrite the store",
            report.quarantined.len()
        ));
    }
    Ok(())
}

/// `imagecl tunedb merge <replica>... [--db PATH]`: conflict-free merge
/// of replica stores into `--db`. Resolution per (kernel, device
/// fingerprint, grid, config) is deterministic — measured `wall` beats
/// simulated, then higher journal seq — and the rewritten store is
/// byte-identical regardless of argument order (idempotent, commutative).
fn cmd_tunedb_merge(args: &Args, db_path: &std::path::Path) -> Result<(), String> {
    let srcs: Vec<std::path::PathBuf> =
        args.positional[1..].iter().map(std::path::PathBuf::from).collect();
    if srcs.is_empty() {
        return Err(
            "tunedb merge needs at least one replica store to merge in".to_string()
        );
    }
    let stats = imagecl::tunedb::merge_files(db_path, &srcs)
        .map_err(|e| format!("merge into {db_path:?}: {e}"))?;
    println!(
        "merged {} store(s), {} records in -> {} records in {db_path:?} \
         ({} damaged lines excluded)",
        stats.inputs, stats.records_in, stats.merged, stats.quarantined
    );
    Ok(())
}

fn cmd_pipeline(args: &Args) -> Result<(), String> {
    args.check_known(&["size"])?;
    let n = args.usize_flag("size", 512)?;
    let mut rt = XlaRuntime::new(&default_artifact_dir()).map_err(|e| e.to_string())?;
    let img = bench_defs::synth_image(imagecl::imagecl::ScalarType::F32, n, n, 42);
    let x = Tensor::new(n, n, img.buf.data.iter().map(|&v| v as f32).collect());

    let mut p = Pipeline::new();
    let src = p.source("img", x);
    let sob = p.filter("sobel", &[p.port(src)]);
    let har = p.filter(
        "harris",
        &[Port { node: sob, port: 0 }, Port { node: sob, port: 1 }],
    );
    p.output(p.port(har));

    let t0 = std::time::Instant::now();
    let outs = p.run(&mut rt, n).map_err(|e| format!("{e:#}"))?;
    let dt = t0.elapsed();
    println!(
        "harris pipeline {n}x{n}: {} (out[0] checksum {:.3})",
        Ms::from(dt),
        outs[0].data.iter().map(|&v| v as f64).sum::<f64>(),
    );
    let sched = schedule(&p, &ALL_DEVICES, n, &TuningConfig::default());
    println!("simulated heterogeneous schedule (makespan {}):", Ms::from(sched.makespan_s));
    for pl in &sched.placements {
        println!(
            "  {:<8} -> {:<9} exec {}  ready {}",
            pl.filter,
            pl.device,
            Ms::from(pl.est_exec_s),
            Ms::from(pl.est_ready_s)
        );
    }
    // The same pipeline scheduled through the serving layer's plan
    // cache: per-device *tuned* estimates instead of the naive config
    // (resolved through the tuning knowledge base when it has answers).
    let service = serve::KernelService::new(serve::ServiceConfig {
        exec: serve::ExecMode::Simulate,
        ..Default::default()
    });
    let tuned = service.schedule_pipeline(&p, &ALL_DEVICES, n);
    println!(
        "tuned schedule via plan cache (makespan {}, {} tunes / {} warm-starts):",
        Ms::from(tuned.makespan_s),
        service.stats().tunes,
        service.stats().warm_starts,
    );
    for pl in &tuned.placements {
        println!(
            "  {:<8} -> {:<9} exec {}  ready {}",
            pl.filter,
            pl.device,
            Ms::from(pl.est_exec_s),
            Ms::from(pl.est_ready_s)
        );
    }
    // And scheduled *purely from accumulated knowledge* — no tuner, no
    // plan compilation: what a per-request scheduler would do.
    let from_db = imagecl::pipeline::schedule_with_db(
        &p,
        &ALL_DEVICES,
        n,
        service.db(),
        &TuningConfig::default(),
    );
    println!(
        "knowledge-base schedule, no tuning (makespan {}):",
        Ms::from(from_db.makespan_s)
    );
    for pl in &from_db.placements {
        println!("  {:<8} -> {:<9} exec {}", pl.filter, pl.device, Ms::from(pl.est_exec_s));
    }
    Ok(())
}

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn args_parse_positional_and_flags() {
        let a = Args::parse(&argv("sobel --grid 128 --device K40")).unwrap();
        assert_eq!(a.positional, vec!["sobel"]);
        assert_eq!(a.flag("grid"), Some("128"));
        assert_eq!(a.usize_flag("grid", 0).unwrap(), 128);
        assert_eq!(a.usize_flag("missing", 7).unwrap(), 7);
    }

    #[test]
    fn args_reject_trailing_flag_without_value() {
        let err = Args::parse(&argv("sobel --grid")).unwrap_err();
        assert!(err.contains("--grid needs a value"), "{err}");
    }

    #[test]
    fn args_reject_duplicate_and_bare_dashes() {
        assert!(Args::parse(&argv("--grid 1 --grid 2")).is_err());
        assert!(Args::parse(&argv("-- foo")).is_err());
    }

    #[test]
    fn args_reject_unknown_flags() {
        let a = Args::parse(&argv("--concurency 8")).unwrap();
        let err = a.check_known(&["concurrency", "requests"]).unwrap_err();
        assert!(err.contains("--concurency"), "{err}");
        assert!(err.contains("--concurrency"), "{err}");
        let a = Args::parse(&argv("--size 4")).unwrap();
        assert!(a.check_known(&[]).is_err());
        assert!(a.check_known(&["size"]).is_ok());
    }

    #[test]
    fn bool_switches_parse() {
        let a = Args::parse_with_switches(&argv("--smoke --size 64"), &["smoke"]).unwrap();
        assert!(a.bool_flag("smoke"));
        assert_eq!(a.usize_flag("size", 0).unwrap(), 64);
        // Undeclared, `--smoke` still requires a value.
        assert!(Args::parse(&argv("--smoke")).is_err());
        assert!(!Args::parse(&argv("sobel")).unwrap().bool_flag("smoke"));
    }

    #[test]
    fn bad_numbers_are_errors() {
        let a = Args::parse(&argv("--grid banana")).unwrap();
        assert!(a.usize_flag("grid", 1).is_err());
    }
}
