//! Multi-value constant propagation (paper §5.2.4).
//!
//! The stencil analysis needs the possible values of `c1`/`c2` in
//! `image[idx + c1][idy + c2]`. Often these are not constants but depend on
//! the iteration variable of fixed-range for-loops (Listing 1). Following
//! the paper, we use "a modified version of constant propagation where we
//! allow each variable to take on a small set of constant values".
//!
//! The analysis is deliberately conservative and flow-insensitive: a
//! variable has a known [`ValueSet`] iff it is (a) declared with a
//! constant-evaluable initializer and never reassigned, or (b) a for-loop
//! induction variable with a constant-evaluable range. Anything else is
//! unknown (`None`), which makes downstream optimizations (local memory)
//! unavailable rather than incorrect.

use std::collections::{BTreeSet, HashMap};

use crate::imagecl::ast::*;

/// Maximum cardinality a tracked value set may reach; larger sets become
/// unknown. Stencils in image processing are small (a 5×5 filter is 25
/// offsets), so 256 is generous while bounding analysis cost.
pub const MAX_SET: usize = 256;

/// Maximum trip count of a loop whose induction values we enumerate.
pub const MAX_TRIPS: usize = 256;

/// A small set of possible integer values.
pub type ValueSet = BTreeSet<i64>;

/// The constant environment: variable → possible values.
#[derive(Debug, Clone, Default)]
pub struct ConstEnv {
    pub vars: HashMap<String, ValueSet>,
}

impl ConstEnv {
    /// Build the environment for a kernel body.
    pub fn build(kernel: &KernelFn) -> ConstEnv {
        // Count assignments per variable (decl-with-init counts as zero;
        // later reassignment invalidates the set).
        let mut reassigned: HashMap<String, usize> = HashMap::new();
        kernel.walk_stmts(&mut |s| {
            if let Stmt::Assign { lhs: LValue::Var(v), .. } = s {
                *reassigned.entry(v.clone()).or_insert(0) += 1;
            }
        });

        let mut env = ConstEnv::default();
        // Iterate to a fixed point so decls whose initializers reference
        // earlier const variables resolve (bounded: each pass either adds a
        // variable or stops).
        loop {
            let mut changed = false;
            kernel.walk_stmts(&mut |s| match s {
                Stmt::Decl { name, init: Some(init), .. } => {
                    if reassigned.contains_key(name) || env.vars.contains_key(name) {
                        return;
                    }
                    if let Some(vs) = env.eval_set(init) {
                        env.vars.insert(name.clone(), vs);
                        changed = true;
                    }
                }
                Stmt::For { var, init, cond, step, .. } => {
                    if env.vars.contains_key(var) {
                        return;
                    }
                    if let Some(vs) = env.loop_values(init, cond, step, var) {
                        env.vars.insert(var.clone(), vs);
                        changed = true;
                    }
                }
                _ => {}
            });
            if !changed {
                break;
            }
        }
        env
    }

    /// All possible iteration values of a restricted for-loop, if its range
    /// is compile-time constant (as a set; see [`Self::loop_values_ordered`]
    /// for the actual iteration order, which matters for float-accumulation
    /// bit-exactness when unrolling).
    pub fn loop_values(
        &self,
        init: &Expr,
        cond: &Expr,
        step: &Expr,
        var: &str,
    ) -> Option<ValueSet> {
        self.loop_values_ordered(init, cond, step, var)
            .map(|v| v.into_iter().collect())
    }

    /// Iteration values in execution order.
    pub fn loop_values_ordered(
        &self,
        init: &Expr,
        cond: &Expr,
        step: &Expr,
        var: &str,
    ) -> Option<Vec<i64>> {
        let starts = self.eval_set(init)?;
        let steps = self.eval_set(step)?;
        if starts.len() != 1 || steps.len() != 1 {
            return None;
        }
        let start = *starts.iter().next().unwrap();
        let step = *steps.iter().next().unwrap();
        if step == 0 {
            return None;
        }
        // cond must be `var < K`, `var <= K`, `var > K` or `var >= K`.
        let (op, bound) = match cond {
            Expr::Binary { op, lhs, rhs } => match (&**lhs, self.eval_set(rhs)) {
                (Expr::Ident(v), Some(b)) if v == var && b.len() == 1 => {
                    (*op, *b.iter().next().unwrap())
                }
                _ => return None,
            },
            _ => return None,
        };
        let keep = |v: i64| match op {
            BinOp::Lt => v < bound,
            BinOp::Le => v <= bound,
            BinOp::Gt => v > bound,
            BinOp::Ge => v >= bound,
            _ => false,
        };
        if !matches!(op, BinOp::Lt | BinOp::Le | BinOp::Gt | BinOp::Ge) {
            return None;
        }
        let mut out = Vec::new();
        let mut v = start;
        for _ in 0..MAX_TRIPS {
            if !keep(v) {
                return Some(out);
            }
            out.push(v);
            v += step;
        }
        None // did not terminate within MAX_TRIPS
    }

    /// Evaluate an integer expression to its set of possible values, or
    /// `None` if not compile-time determinable.
    pub fn eval_set(&self, e: &Expr) -> Option<ValueSet> {
        match e {
            Expr::IntLit(v) => Some([*v].into()),
            Expr::BoolLit(b) => Some([*b as i64].into()),
            Expr::Ident(name) => self.vars.get(name).cloned(),
            Expr::Unary { op: UnOp::Neg, expr } => {
                Some(self.eval_set(expr)?.iter().map(|v| -v).collect())
            }
            Expr::Cast { ty, expr } if !ty.is_float() => self.eval_set(expr),
            Expr::Binary { op, lhs, rhs } => {
                let a = self.eval_set(lhs)?;
                let b = self.eval_set(rhs)?;
                if a.len().checked_mul(b.len())? > MAX_SET {
                    return None;
                }
                let mut out = ValueSet::new();
                for &x in &a {
                    for &y in &b {
                        let v = match op {
                            BinOp::Add => x.checked_add(y)?,
                            BinOp::Sub => x.checked_sub(y)?,
                            BinOp::Mul => x.checked_mul(y)?,
                            BinOp::Div => {
                                if y == 0 {
                                    return None;
                                }
                                x / y
                            }
                            BinOp::Rem => {
                                if y == 0 {
                                    return None;
                                }
                                x % y
                            }
                            BinOp::Shl => x.checked_shl(u32::try_from(y).ok()?)?,
                            BinOp::Shr => x.checked_shr(u32::try_from(y).ok()?)?,
                            _ => return None,
                        };
                        out.insert(v);
                    }
                }
                if out.len() > MAX_SET {
                    None
                } else {
                    Some(out)
                }
            }
            Expr::Call { name, args } => {
                let sets: Option<Vec<ValueSet>> =
                    args.iter().map(|a| self.eval_set(a)).collect();
                let sets = sets?;
                match (name.as_str(), sets.as_slice()) {
                    ("min", [a, b]) => {
                        let mut out = ValueSet::new();
                        for &x in a {
                            for &y in b {
                                out.insert(x.min(y));
                            }
                        }
                        Some(out)
                    }
                    ("max", [a, b]) => {
                        let mut out = ValueSet::new();
                        for &x in a {
                            for &y in b {
                                out.insert(x.max(y));
                            }
                        }
                        Some(out)
                    }
                    ("abs", [a]) => Some(a.iter().map(|v| v.abs()).collect()),
                    _ => None,
                }
            }
            _ => None,
        }
    }

    /// Evaluate to a single constant, if the set is a singleton.
    pub fn eval_const(&self, e: &Expr) -> Option<i64> {
        let s = self.eval_set(e)?;
        if s.len() == 1 {
            s.into_iter().next()
        } else {
            None
        }
    }
}

/// An index expression decomposed into `scale * base + offset-set` form,
/// where `base` is one of the thread-index builtins or absent. This is
/// the *scaled* generalization of [`Affine`] used by the strided-write
/// disjointness proof ([`crate::analysis::rw::disjoint_writes`]): a write
/// to `a[idx * 2 + 1]` decomposes to `base = idx, scale = 2, offsets =
/// {1}`, and distinct threads then provably touch distinct elements
/// whenever no two offsets differ by a multiple of the scale.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScaledAffine {
    /// `Some("idx")` / `Some("idy")` / `Some("idz")` or `None` (pure
    /// constant; `scale` is then 0).
    pub base: Option<String>,
    /// Multiplier on the base (never 0 when `base` is `Some`).
    pub scale: i64,
    pub offsets: ValueSet,
}

impl ScaledAffine {
    fn constant(offsets: ValueSet) -> ScaledAffine {
        ScaledAffine { base: None, scale: 0, offsets }
    }

    /// Normalize: a zero scale means the base contributes nothing.
    fn norm(self) -> ScaledAffine {
        if self.scale == 0 {
            ScaledAffine { base: None, ..self }
        } else {
            self
        }
    }
}

/// Cross-combine two offset sets with `f`, bailing out (`None`) on
/// overflow or when the result outgrows [`MAX_SET`].
fn cross(
    a: &ValueSet,
    b: &ValueSet,
    f: impl Fn(i64, i64) -> Option<i64>,
) -> Option<ValueSet> {
    if a.len().checked_mul(b.len())? > MAX_SET {
        return None;
    }
    let mut out = ValueSet::new();
    for &x in a {
        for &y in b {
            out.insert(f(x, y)?);
        }
    }
    if out.len() > MAX_SET {
        None
    } else {
        Some(out)
    }
}

/// Decompose an index expression into [`ScaledAffine`] form w.r.t. the
/// builtin thread indices. Unlike [`affine_of`] (which implements the
/// paper's stencil restriction and rejects any scaling), this handles
/// `idx * c`, `c * idx`, `idx + idx`, negation and constant shifts —
/// everything a strided write pattern is made of. `None` = not
/// decomposable (mixed bases, non-constant scale, overflow).
pub fn scaled_affine_of(env: &ConstEnv, e: &Expr) -> Option<ScaledAffine> {
    match e {
        Expr::Ident(name) if crate::imagecl::sema::BUILTIN_IDS.contains(&name.as_str()) => {
            Some(ScaledAffine { base: Some(name.clone()), scale: 1, offsets: [0].into() })
        }
        Expr::Binary { op: op @ (BinOp::Add | BinOp::Sub), lhs, rhs } => {
            let a = scaled_affine_of(env, lhs)?;
            let b = scaled_affine_of(env, rhs)?;
            let base = match (&a.base, &b.base) {
                (Some(x), Some(y)) if x == y => Some(x.clone()),
                (Some(x), None) => Some(x.clone()),
                (None, Some(y)) => Some(y.clone()),
                (None, None) => None,
                // Mixed bases (`idx + idy`) have no single-base form.
                _ => return None,
            };
            let (scale, offsets) = if *op == BinOp::Add {
                (a.scale.checked_add(b.scale)?, cross(&a.offsets, &b.offsets, |x, y| x.checked_add(y))?)
            } else {
                (a.scale.checked_sub(b.scale)?, cross(&a.offsets, &b.offsets, |x, y| x.checked_sub(y))?)
            };
            Some(ScaledAffine { base, scale, offsets }.norm())
        }
        Expr::Binary { op: BinOp::Mul, lhs, rhs } => {
            // One side must be a *single* compile-time constant.
            let scaled = |sa: ScaledAffine, c: i64| -> Option<ScaledAffine> {
                let offsets: Option<ValueSet> =
                    sa.offsets.iter().map(|&v| v.checked_mul(c)).collect();
                Some(
                    ScaledAffine {
                        base: sa.base,
                        scale: sa.scale.checked_mul(c)?,
                        offsets: offsets?,
                    }
                    .norm(),
                )
            };
            if let Some(c) = env.eval_const(rhs) {
                return scaled(scaled_affine_of(env, lhs)?, c);
            }
            if let Some(c) = env.eval_const(lhs) {
                return scaled(scaled_affine_of(env, rhs)?, c);
            }
            None
        }
        Expr::Unary { op: UnOp::Neg, expr } => {
            let a = scaled_affine_of(env, expr)?;
            let offsets: Option<ValueSet> =
                a.offsets.iter().map(|&v| v.checked_neg()).collect();
            Some(
                ScaledAffine {
                    base: a.base,
                    scale: a.scale.checked_neg()?,
                    offsets: offsets?,
                }
                .norm(),
            )
        }
        // Casts are NOT transparent here: a narrowing cast wraps at
        // runtime (`a[(uchar)idx]` collides for idx and idx+256), so
        // seeing through one would make the disjointness proof unsound.
        // (The paper-restricted [`affine_of`] never accepted casts
        // either.) Rejecting them keeps exotic write indices on the
        // conservative serial path.
        Expr::Cast { .. } => None,
        other => env.eval_set(other).map(ScaledAffine::constant),
    }
}

/// An index expression decomposed into `base + offset-set` form, where
/// `base` is one of the thread-index builtins or absent (paper §5.2.4:
/// references must have the form `image[idx + c1][idy + c2]`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Affine {
    /// `Some("idx")` / `Some("idy")` / `Some("idz")` or `None` (pure const).
    pub base: Option<String>,
    pub offsets: ValueSet,
}

impl Affine {
    pub fn constant(v: i64) -> Affine {
        Affine { base: None, offsets: [v].into() }
    }
}

/// Decompose an index expression into [`Affine`] form w.r.t. the builtin
/// thread indices. Returns `None` for anything non-affine in the builtins
/// (e.g. `idx * 2`, `idx % n`), matching the paper's restriction.
pub fn affine_of(env: &ConstEnv, e: &Expr) -> Option<Affine> {
    match e {
        Expr::Ident(name) if crate::imagecl::sema::BUILTIN_IDS.contains(&name.as_str()) => {
            Some(Affine { base: Some(name.clone()), offsets: [0].into() })
        }
        Expr::Binary { op: op @ (BinOp::Add | BinOp::Sub), lhs, rhs } => {
            // Try base on the left: (affine) ± (const-set).
            if let (Some(a), Some(b)) = (affine_of(env, lhs), env.eval_set(rhs)) {
                if a.offsets.len().checked_mul(b.len())? > MAX_SET {
                    return None;
                }
                let mut offsets = ValueSet::new();
                for &x in &a.offsets {
                    for &y in &b {
                        offsets.insert(if *op == BinOp::Add { x + y } else { x - y });
                    }
                }
                return Some(Affine { base: a.base, offsets });
            }
            // Or base on the right (only for +): (const-set) + (affine).
            if *op == BinOp::Add {
                if let (Some(a), Some(b)) = (env.eval_set(lhs), affine_of(env, rhs)) {
                    if a.len().checked_mul(b.offsets.len())? > MAX_SET {
                        return None;
                    }
                    let mut offsets = ValueSet::new();
                    for &x in &a {
                        for &y in &b.offsets {
                            offsets.insert(x + y);
                        }
                    }
                    return Some(Affine { base: b.base, offsets });
                }
            }
            None
        }
        other => env.eval_set(other).map(|offsets| Affine { base: None, offsets }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::imagecl::Program;

    fn env_of(src: &str) -> (ConstEnv, KernelFn) {
        let p = Program::parse(src).unwrap();
        let env = ConstEnv::build(&p.kernel);
        (env, p.kernel)
    }

    #[test]
    fn decl_const_tracked() {
        let (env, _) = env_of("void k(float* a) { int r = 2; a[idx + r] = 0.0f; }");
        assert_eq!(env.vars["r"], ValueSet::from([2]));
    }

    #[test]
    fn reassigned_var_unknown() {
        let (env, _) =
            env_of("void k(float* a) { int r = 2; r = 3; a[idx + r] = 0.0f; }");
        assert!(!env.vars.contains_key("r"));
    }

    #[test]
    fn loop_var_enumerated() {
        let (env, _) = env_of(
            "void k(float* a) { for (int i = -1; i < 2; i++) { a[idx + i] = 0.0f; } }",
        );
        assert_eq!(env.vars["i"], ValueSet::from([-1, 0, 1]));
    }

    #[test]
    fn loop_var_with_step() {
        let (env, _) = env_of(
            "void k(float* a) { for (int i = 0; i <= 8; i += 4) { a[idx + i] = 0.0f; } }",
        );
        assert_eq!(env.vars["i"], ValueSet::from([0, 4, 8]));
    }

    #[test]
    fn loop_depending_on_const_decl() {
        let (env, _) = env_of(
            "void k(float* a) { int r = 2; for (int i = -r; i < r + 1; i++) { a[idx + i] = 0.0f; } }",
        );
        assert_eq!(env.vars["i"], ValueSet::from([-2, -1, 0, 1, 2]));
    }

    #[test]
    fn loop_with_runtime_bound_unknown() {
        let (env, _) = env_of(
            "void k(float* a, int n) { for (int i = 0; i < n; i++) { a[idx + i] = 0.0f; } }",
        );
        assert!(!env.vars.contains_key("i"));
    }

    #[test]
    fn eval_set_arith() {
        let (env, _) = env_of(
            "void k(float* a) { for (int i = 0; i < 3; i++) { a[idx + i * 2 - 1] = 0.0f; } }",
        );
        let e = Expr::sub(
            Expr::mul(Expr::ident("i"), Expr::int(2)),
            Expr::int(1),
        );
        assert_eq!(env.eval_set(&e).unwrap(), ValueSet::from([-1, 1, 3]));
    }

    #[test]
    fn eval_min_max() {
        let env = ConstEnv::default();
        let e = Expr::call("min", vec![Expr::int(3), Expr::int(5)]);
        assert_eq!(env.eval_set(&e).unwrap(), ValueSet::from([3]));
    }

    #[test]
    fn affine_idx_plus_loopvar() {
        let (env, _) = env_of(
            "void k(float* a) { for (int i = -1; i < 2; i++) { a[idx + i] = 0.0f; } }",
        );
        let e = Expr::add(Expr::ident("idx"), Expr::ident("i"));
        let a = affine_of(&env, &e).unwrap();
        assert_eq!(a.base.as_deref(), Some("idx"));
        assert_eq!(a.offsets, ValueSet::from([-1, 0, 1]));
    }

    #[test]
    fn affine_const_plus_idy() {
        let env = ConstEnv::default();
        let e = Expr::add(Expr::int(2), Expr::ident("idy"));
        let a = affine_of(&env, &e).unwrap();
        assert_eq!(a.base.as_deref(), Some("idy"));
        assert_eq!(a.offsets, ValueSet::from([2]));
    }

    #[test]
    fn affine_rejects_scaled_idx() {
        let env = ConstEnv::default();
        let e = Expr::mul(Expr::ident("idx"), Expr::int(2));
        assert!(affine_of(&env, &e).is_none());
    }

    #[test]
    fn affine_pure_const() {
        let env = ConstEnv::default();
        let a = affine_of(&env, &Expr::int(7)).unwrap();
        assert_eq!(a.base, None);
        assert_eq!(a.offsets, ValueSet::from([7]));
    }

    #[test]
    fn division_by_zero_unknown() {
        let env = ConstEnv::default();
        let e = Expr::bin(BinOp::Div, Expr::int(4), Expr::int(0));
        assert!(env.eval_set(&e).is_none());
    }

    #[test]
    fn scaled_affine_handles_strided_forms() {
        let env = ConstEnv::default();
        // idx * 2 + 1
        let e = Expr::add(
            Expr::mul(Expr::ident("idx"), Expr::int(2)),
            Expr::int(1),
        );
        let a = scaled_affine_of(&env, &e).unwrap();
        assert_eq!(a.base.as_deref(), Some("idx"));
        assert_eq!(a.scale, 2);
        assert_eq!(a.offsets, ValueSet::from([1]));
        // 3 * idy
        let e = Expr::mul(Expr::int(3), Expr::ident("idy"));
        let a = scaled_affine_of(&env, &e).unwrap();
        assert_eq!((a.base.as_deref(), a.scale), (Some("idy"), 3));
        // idx + idx (the downsample idiom for idx * 2)
        let e = Expr::add(Expr::ident("idx"), Expr::ident("idx"));
        let a = scaled_affine_of(&env, &e).unwrap();
        assert_eq!((a.base.as_deref(), a.scale), (Some("idx"), 2));
        assert_eq!(a.offsets, ValueSet::from([0]));
        // Plain idx + c stays scale 1.
        let e = Expr::add(Expr::ident("idx"), Expr::int(4));
        let a = scaled_affine_of(&env, &e).unwrap();
        assert_eq!((a.scale, a.offsets.clone()), (1, ValueSet::from([4])));
    }

    #[test]
    fn scaled_affine_with_loop_offsets() {
        let (env, _) = env_of(
            "void k(float* a) { for (int i = 0; i < 2; i++) { a[idx * 2 + i] = 0.0f; } }",
        );
        let e = Expr::add(
            Expr::mul(Expr::ident("idx"), Expr::int(2)),
            Expr::ident("i"),
        );
        let a = scaled_affine_of(&env, &e).unwrap();
        assert_eq!((a.base.as_deref(), a.scale), (Some("idx"), 2));
        assert_eq!(a.offsets, ValueSet::from([0, 1]));
    }

    #[test]
    fn scaled_affine_rejects_mixed_and_runtime() {
        let env = ConstEnv::default();
        // idx + idy: no single base.
        let e = Expr::add(Expr::ident("idx"), Expr::ident("idy"));
        assert!(scaled_affine_of(&env, &e).is_none());
        // idx * idx: non-constant scale.
        let e = Expr::mul(Expr::ident("idx"), Expr::ident("idx"));
        assert!(scaled_affine_of(&env, &e).is_none());
        // Runtime value.
        assert!(scaled_affine_of(&env, &Expr::ident("n")).is_none());
        // idx - idx degenerates to a pure constant.
        let e = Expr::sub(Expr::ident("idx"), Expr::ident("idx"));
        let a = scaled_affine_of(&env, &e).unwrap();
        assert_eq!((a.base, a.scale), (None, 0));
    }

    #[test]
    fn scaled_affine_rejects_casts() {
        // `(uchar)idx` wraps at runtime: idx = 0 and idx = 256 hit the
        // same element, so a cast must never look affine to the
        // disjointness proof — standalone or nested.
        let env = ConstEnv::default();
        let cast = Expr::Cast {
            ty: crate::imagecl::ScalarType::U8,
            expr: Box::new(Expr::ident("idx")),
        };
        assert!(scaled_affine_of(&env, &cast).is_none());
        let nested = Expr::add(cast, Expr::int(1));
        assert!(scaled_affine_of(&env, &nested).is_none());
    }
}
