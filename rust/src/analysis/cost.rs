//! Static per-logical-thread cost estimation.
//!
//! Counts, for ONE logical thread (= one pixel of the thread grid), the
//! arithmetic operations and buffer traffic the kernel performs. The device
//! performance model ([`crate::devices`]) scales these counts by the grid
//! size and the tuning configuration (coarsening, memory spaces, ...).
//!
//! Loop bodies are weighted by their compile-time trip count when known;
//! unknown-trip loops use [`UNKNOWN_TRIPS`] (documented approximation —
//! all loops in the paper's benchmarks have static ranges). `if` branches
//! are weighted by [`BRANCH_WEIGHT`] each, modelling a 50/50 split without
//! losing the work of either side.

use std::collections::HashMap;

use super::constprop::ConstEnv;
use crate::imagecl::ast::*;

/// Assumed trip count for loops whose range is not compile-time constant.
pub const UNKNOWN_TRIPS: f64 = 8.0;

/// Weight applied to each arm of an `if`.
pub const BRANCH_WEIGHT: f64 = 0.5;

/// Static cost of one logical thread.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ThreadCost {
    /// Floating-point add/sub/mul ops.
    pub flops: f64,
    /// Float divisions (much slower on GPUs; modeled separately).
    pub fdivs: f64,
    /// Integer/bool/compare ops (index arithmetic is added by codegen and
    /// is NOT included here — the device model accounts for it from the
    /// configuration).
    pub iops: f64,
    /// Transcendental / special function calls (sqrt, exp, ...).
    pub transcendentals: f64,
    /// Reads per buffer parameter (elements).
    pub reads: HashMap<String, f64>,
    /// Writes per buffer parameter (elements).
    pub writes: HashMap<String, f64>,
}

impl ThreadCost {
    /// Total element reads across all buffers.
    pub fn total_reads(&self) -> f64 {
        self.reads.values().sum()
    }

    pub fn total_writes(&self) -> f64 {
        self.writes.values().sum()
    }

    /// Total arithmetic (weighted: divisions and transcendentals count as
    /// several simple ops — rough throughput ratios on current hardware).
    pub fn weighted_ops(&self) -> f64 {
        self.flops + self.iops + 8.0 * self.fdivs + 16.0 * self.transcendentals
    }
}

/// Minimal expression-type inference context (params + local decls).
struct TypeCtx<'a> {
    kernel: &'a KernelFn,
    locals: HashMap<String, ScalarType>,
}

impl TypeCtx<'_> {
    fn ty(&self, e: &Expr) -> ScalarType {
        match e {
            Expr::IntLit(_) => ScalarType::I32,
            Expr::FloatLit(_) => ScalarType::F32,
            Expr::BoolLit(_) => ScalarType::Bool,
            Expr::Ident(n) => {
                if crate::imagecl::sema::BUILTIN_IDS.contains(&n.as_str()) {
                    ScalarType::I32
                } else if let Some(t) = self.locals.get(n) {
                    *t
                } else if let Some(p) = self.kernel.param(n) {
                    p.ty.elem()
                } else {
                    ScalarType::F32
                }
            }
            Expr::Unary { expr, .. } => self.ty(expr),
            Expr::Binary { op, lhs, rhs } => match op {
                BinOp::Eq
                | BinOp::Ne
                | BinOp::Lt
                | BinOp::Gt
                | BinOp::Le
                | BinOp::Ge
                | BinOp::And
                | BinOp::Or => ScalarType::Bool,
                _ => {
                    let a = self.ty(lhs);
                    let b = self.ty(rhs);
                    if a.is_float() || b.is_float() {
                        ScalarType::F32
                    } else {
                        a
                    }
                }
            },
            Expr::Index { base, .. } => self
                .kernel
                .param(base)
                .map(|p| p.ty.elem())
                .unwrap_or(ScalarType::F32),
            Expr::Call { name, args } => match name.as_str() {
                "min" | "max" | "clamp" | "abs" | "fabs" => {
                    args.first().map(|a| self.ty(a)).unwrap_or(ScalarType::F32)
                }
                _ => ScalarType::F32,
            },
            Expr::Ternary { then, .. } => self.ty(then),
            Expr::Cast { ty, .. } => *ty,
        }
    }
}

/// Estimate the per-logical-thread cost of the kernel.
pub fn estimate(kernel: &KernelFn, env: &ConstEnv) -> ThreadCost {
    let mut cost = ThreadCost::default();
    let mut ctx = TypeCtx { kernel, locals: HashMap::new() };
    // Pre-register local decls and loop variables (flow-insensitive;
    // names are unique per sema).
    kernel.walk_stmts(&mut |s| match s {
        Stmt::Decl { ty, name, .. } => {
            ctx.locals.insert(name.clone(), *ty);
        }
        Stmt::For { var, .. } => {
            ctx.locals.insert(var.clone(), ScalarType::I32);
        }
        _ => {}
    });
    count_stmts(&kernel.body, 1.0, env, &ctx, &mut cost);
    cost
}

fn count_expr(e: &Expr, w: f64, ctx: &TypeCtx, cost: &mut ThreadCost) {
    match e {
        Expr::Unary { expr, .. } => {
            count_expr(expr, w, ctx, cost);
            if ctx.ty(expr).is_float() {
                cost.flops += w;
            } else {
                cost.iops += w;
            }
        }
        Expr::Binary { op, lhs, rhs } => {
            count_expr(lhs, w, ctx, cost);
            count_expr(rhs, w, ctx, cost);
            let fl = ctx.ty(lhs).is_float() || ctx.ty(rhs).is_float();
            match op {
                BinOp::Div if fl => cost.fdivs += w,
                BinOp::Add | BinOp::Sub | BinOp::Mul if fl => cost.flops += w,
                _ => cost.iops += w,
            }
        }
        Expr::Index { base, indices } => {
            for i in indices {
                count_expr(i, w, ctx, cost);
            }
            *cost.reads.entry(base.clone()).or_default() += w;
        }
        Expr::Call { name, args } => {
            for a in args {
                count_expr(a, w, ctx, cost);
            }
            match name.as_str() {
                "sqrt" | "rsqrt" | "exp" | "log" | "sin" | "cos" | "pow" => {
                    cost.transcendentals += w
                }
                _ => cost.flops += w, // min/max/fabs/clamp ≈ one op
            }
        }
        Expr::Ternary { cond, then, els } => {
            count_expr(cond, w, ctx, cost);
            count_expr(then, w * BRANCH_WEIGHT, ctx, cost);
            count_expr(els, w * BRANCH_WEIGHT, ctx, cost);
        }
        Expr::Cast { expr, .. } => count_expr(expr, w, ctx, cost),
        _ => {}
    }
}

fn count_stmts(stmts: &[Stmt], w: f64, env: &ConstEnv, ctx: &TypeCtx, cost: &mut ThreadCost) {
    for s in stmts {
        match s {
            Stmt::Decl { init, .. } => {
                if let Some(e) = init {
                    count_expr(e, w, ctx, cost);
                }
            }
            Stmt::Assign { lhs, op, value } => {
                count_expr(value, w, ctx, cost);
                if let LValue::Index { base, indices } = lhs {
                    for i in indices {
                        count_expr(i, w, ctx, cost);
                    }
                    *cost.writes.entry(base.clone()).or_default() += w;
                    if *op != AssignOp::Set {
                        *cost.reads.entry(base.clone()).or_default() += w;
                    }
                }
                if op.binop().is_some() {
                    // The implied read-modify op.
                    cost.flops += w;
                }
            }
            Stmt::If { cond, then, els } => {
                count_expr(cond, w, ctx, cost);
                count_stmts(then, w * BRANCH_WEIGHT, env, ctx, cost);
                count_stmts(els, w * BRANCH_WEIGHT, env, ctx, cost);
            }
            Stmt::For { var, init, cond, step, body } => {
                count_expr(init, w, ctx, cost);
                let trips = env
                    .loop_values(init, cond, step, var)
                    .map(|vs| vs.len() as f64)
                    .unwrap_or(UNKNOWN_TRIPS);
                // Condition evaluated trips+1 times, step trips times.
                count_expr(cond, w * (trips + 1.0), ctx, cost);
                count_expr(step, w * trips, ctx, cost);
                cost.iops += w * trips; // induction increment
                count_stmts(body, w * trips, env, ctx, cost);
            }
            Stmt::While { cond, body } => {
                count_expr(cond, w * (UNKNOWN_TRIPS + 1.0), ctx, cost);
                count_stmts(body, w * UNKNOWN_TRIPS, env, ctx, cost);
            }
            Stmt::ExprStmt(e) => count_expr(e, w, ctx, cost),
            Stmt::Return | Stmt::Barrier => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::imagecl::Program;

    fn cost_of(src: &str) -> ThreadCost {
        let p = Program::parse(src).unwrap();
        let env = ConstEnv::build(&p.kernel);
        estimate(&p.kernel, &env)
    }

    #[test]
    fn box_filter_counts() {
        let c = cost_of(
            "void blur(Image<float> in, Image<float> out) {\n\
               float sum = 0.0f;\n\
               for (int i = -1; i < 2; i++) {\n\
                 for (int j = -1; j < 2; j++) { sum += in[idx + i][idy + j]; }\n\
               }\n\
               out[idx][idy] = sum / 9.0f;\n\
             }",
        );
        // 9 reads of `in`, 1 write of `out`.
        assert_eq!(c.reads["in"], 9.0);
        assert_eq!(c.writes["out"], 1.0);
        // 9 float adds from `sum +=` plus the final division.
        assert!(c.flops >= 9.0);
        assert_eq!(c.fdivs, 1.0);
        assert!(c.total_reads() == 9.0);
    }

    #[test]
    fn branch_weighting() {
        let c = cost_of(
            "#pragma imcl grid(a)\n\
             void k(Image<float> a, Image<float> o) {\n\
               if (idx > 0) { o[idx][idy] = a[idx][idy]; } else { o[idx][idy] = 0.0f; }\n\
             }",
        );
        assert_eq!(c.reads["a"], BRANCH_WEIGHT);
        assert_eq!(c.writes["o"], 2.0 * BRANCH_WEIGHT);
    }

    #[test]
    fn transcendental_counted() {
        let c = cost_of(
            "#pragma imcl grid(a)\n\
             void k(Image<float> a, Image<float> o) { o[idx][idy] = sqrt(a[idx][idy]); }",
        );
        assert_eq!(c.transcendentals, 1.0);
        assert!(c.weighted_ops() >= 16.0);
    }

    #[test]
    fn unknown_loop_uses_default() {
        let c = cost_of(
            "#pragma imcl grid(a)\n\
             void k(Image<float> a, Image<float> o, int n) {\n\
               float s = 0.0f;\n\
               for (int i = 0; i < n; i++) { s += a[idx][idy]; }\n\
               o[idx][idy] = s;\n\
             }",
        );
        assert_eq!(c.reads["a"], UNKNOWN_TRIPS);
    }

    #[test]
    fn integer_ops_classified() {
        let c = cost_of(
            "#pragma imcl grid(64, 64)\n\
             void k(float* a) { int t = idx * 2 + 1; a[t] = 0.0f; }",
        );
        assert!(c.iops >= 2.0);
        assert_eq!(c.flops, 0.0);
    }
}
