//! Stencil extraction (paper §5.2.4, local memory).
//!
//! For an `Image` that is a candidate for the local-memory optimization we
//! must determine, at compile time, the fixed-size neighbourhood each
//! logical thread reads: all read references must have the form
//! `image[idx + c1][idy + c2]` with `c1`, `c2` in small constant sets
//! (possibly via loop variables — multi-value constant propagation).
//! The result is the bounding box of all `(c1, c2)` offsets (the paper uses
//! the bounding box "for simplicity, although this may cause unnecessary
//! loads").

use std::collections::HashMap;

use super::constprop::{affine_of, ConstEnv};
use crate::imagecl::ast::*;

/// Inclusive offset bounding box of a stencil, in x (first index) and y
/// (second index). A single-pixel access is `(0,0)..(0,0)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Stencil {
    pub min_dx: i64,
    pub max_dx: i64,
    pub min_dy: i64,
    pub max_dy: i64,
}

impl Stencil {
    pub const POINT: Stencil = Stencil { min_dx: 0, max_dx: 0, min_dy: 0, max_dy: 0 };

    /// Halo width in each direction: how many extra pixels beyond the
    /// work-group tile must be staged into local memory (paper Figure 5).
    pub fn extent_x(&self) -> i64 {
        self.max_dx - self.min_dx
    }

    pub fn extent_y(&self) -> i64 {
        self.max_dy - self.min_dy
    }

    fn include(&mut self, dx: i64, dy: i64) {
        self.min_dx = self.min_dx.min(dx);
        self.max_dx = self.max_dx.max(dx);
        self.min_dy = self.min_dy.min(dy);
        self.max_dy = self.max_dy.max(dy);
    }

    /// Halo composition for producer→consumer fusion (Minkowski sum).
    ///
    /// If a producer stage reads its input with stencil `self` to write one
    /// output pixel, and a consumer stage reads that output with stencil
    /// `outer`, then the fused kernel reads the producer's *input* with the
    /// dilated stencil `self ⊕ outer`: every consumer offset `(cx, cy)`
    /// demands the producer value at `(idx+cx, idy+cy)`, which in turn reads
    /// the input at `(idx+cx+px, idy+cy+py)` for every producer offset
    /// `(px, py)`. The bounding boxes therefore add component-wise.
    pub fn compose(&self, outer: &Stencil) -> Stencil {
        Stencil {
            min_dx: self.min_dx + outer.min_dx,
            max_dx: self.max_dx + outer.max_dx,
            min_dy: self.min_dy + outer.min_dy,
            max_dy: self.max_dy + outer.max_dy,
        }
    }

    /// Bounding box of two stencils (used when several fused images pull
    /// from the same input: the staged halo must cover both).
    pub fn union(&self, other: &Stencil) -> Stencil {
        Stencil {
            min_dx: self.min_dx.min(other.min_dx),
            max_dx: self.max_dx.max(other.max_dx),
            min_dy: self.min_dy.min(other.min_dy),
            max_dy: self.max_dy.max(other.max_dy),
        }
    }
}

/// Why stencil extraction failed for an image (local memory then unusable).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StencilFailure {
    /// A read reference index was not `idx + const-set` / `idy + const-set`.
    NonAffineIndex(String),
    /// First index not based on `idx`, or second not on `idy`.
    WrongBase(String),
    /// The image is read with 1-D or 3-D indexing somewhere.
    WrongArity(String),
}

/// Extract the read stencil of every Image parameter that is read with 2-D
/// indexing. Returns per image either the stencil or the failure reason.
pub fn extract(
    kernel: &KernelFn,
    env: &ConstEnv,
) -> HashMap<String, Result<Stencil, StencilFailure>> {
    let mut out: HashMap<String, Result<Stencil, StencilFailure>> = HashMap::new();
    let images: Vec<String> = kernel
        .params
        .iter()
        .filter(|p| matches!(p.ty, Type::Image { .. }))
        .map(|p| p.name.clone())
        .collect();

    // Visit every *read* reference (walk_exprs does not visit assignment
    // targets, which is what we want: writes don't constrain the read
    // stencil; read-only-ness is checked separately by rw::classify).
    kernel.walk_exprs(&mut |e| {
        let Expr::Index { base, indices } = e else { return };
        if !images.contains(base) {
            return;
        }
        let entry = out
            .entry(base.clone())
            .or_insert(Ok(Stencil { min_dx: i64::MAX, max_dx: i64::MIN, min_dy: i64::MAX, max_dy: i64::MIN }));
        if entry.is_err() {
            return;
        }
        if indices.len() != 2 {
            *entry = Err(StencilFailure::WrongArity(base.clone()));
            return;
        }
        let (ax, ay) = match (affine_of(env, &indices[0]), affine_of(env, &indices[1])) {
            (Some(ax), Some(ay)) => (ax, ay),
            _ => {
                *entry = Err(StencilFailure::NonAffineIndex(base.clone()));
                return;
            }
        };
        if ax.base.as_deref() != Some("idx") || ay.base.as_deref() != Some("idy") {
            *entry = Err(StencilFailure::WrongBase(base.clone()));
            return;
        }
        if let Ok(st) = entry {
            for &dx in &ax.offsets {
                for &dy in &ay.offsets {
                    st.include(dx, dy);
                }
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::imagecl::Program;

    fn stencils(src: &str) -> HashMap<String, Result<Stencil, StencilFailure>> {
        let p = Program::parse(src).unwrap();
        let env = ConstEnv::build(&p.kernel);
        extract(&p.kernel, &env)
    }

    #[test]
    fn box_filter_3x3() {
        let st = stencils(
            "void blur(Image<float> in, Image<float> out) {\n\
               float sum = 0.0f;\n\
               for (int i = -1; i < 2; i++) {\n\
                 for (int j = -1; j < 2; j++) { sum += in[idx + i][idy + j]; }\n\
               }\n\
               out[idx][idy] = sum / 9.0f;\n\
             }",
        );
        assert_eq!(
            st["in"],
            Ok(Stencil { min_dx: -1, max_dx: 1, min_dy: -1, max_dy: 1 })
        );
        // `out` is only written — no read stencil entry.
        assert!(!st.contains_key("out"));
    }

    #[test]
    fn asymmetric_row_stencil() {
        let st = stencils(
            "#pragma imcl grid(in)\n\
             void row(Image<float> in, Image<float> out, float* f) {\n\
               float sum = 0.0f;\n\
               for (int i = -2; i < 3; i++) { sum += in[idx + i][idy] * f[i + 2]; }\n\
               out[idx][idy] = sum;\n\
             }",
        );
        assert_eq!(
            st["in"],
            Ok(Stencil { min_dx: -2, max_dx: 2, min_dy: 0, max_dy: 0 })
        );
    }

    #[test]
    fn point_access() {
        let st = stencils(
            "#pragma imcl grid(a)\n\
             void k(Image<float> a, Image<float> o) { o[idx][idy] = a[idx][idy]; }",
        );
        assert_eq!(st["a"], Ok(Stencil::POINT));
    }

    #[test]
    fn constant_offsets_without_loop() {
        let st = stencils(
            "#pragma imcl grid(a)\n\
             void k(Image<float> a, Image<float> o) {\n\
               o[idx][idy] = a[idx - 1][idy + 2] + a[idx + 3][idy];\n\
             }",
        );
        assert_eq!(
            st["a"],
            Ok(Stencil { min_dx: -1, max_dx: 3, min_dy: 0, max_dy: 2 })
        );
    }

    #[test]
    fn scaled_index_fails() {
        let st = stencils(
            "#pragma imcl grid(a)\n\
             void k(Image<float> a, Image<float> o) { o[idx][idy] = a[idx * 2][idy]; }",
        );
        assert!(matches!(st["a"], Err(StencilFailure::NonAffineIndex(_))));
    }

    #[test]
    fn swapped_bases_fail() {
        let st = stencils(
            "#pragma imcl grid(a)\n\
             void k(Image<float> a, Image<float> o) { o[idx][idy] = a[idy][idx]; }",
        );
        assert!(matches!(st["a"], Err(StencilFailure::WrongBase(_))));
    }

    #[test]
    fn runtime_offset_fails() {
        let st = stencils(
            "#pragma imcl grid(a)\n\
             void k(Image<float> a, Image<float> o, int r) {\n\
               o[idx][idy] = a[idx + r][idy];\n\
             }",
        );
        assert!(matches!(st["a"], Err(StencilFailure::NonAffineIndex(_))));
    }

    #[test]
    fn compose_is_minkowski_sum() {
        // Sobel reads (-1..1, -1..1); Harris reads its gradients at
        // (0..1, 0..1) — fused, the input halo is (-1..2, -1..2).
        let sobel = Stencil { min_dx: -1, max_dx: 1, min_dy: -1, max_dy: 1 };
        let harris = Stencil { min_dx: 0, max_dx: 1, min_dy: 0, max_dy: 1 };
        assert_eq!(
            sobel.compose(&harris),
            Stencil { min_dx: -1, max_dx: 2, min_dy: -1, max_dy: 2 }
        );
        // Composing with a point consumer is the identity.
        assert_eq!(sobel.compose(&Stencil::POINT), sobel);
        assert_eq!(Stencil::POINT.compose(&sobel), sobel);
    }

    #[test]
    fn union_is_bounding_box() {
        let a = Stencil { min_dx: -2, max_dx: 0, min_dy: 0, max_dy: 1 };
        let b = Stencil { min_dx: 0, max_dx: 1, min_dy: -1, max_dy: 0 };
        let u = a.union(&b);
        assert_eq!(u, Stencil { min_dx: -2, max_dx: 1, min_dy: -1, max_dy: 1 });
        assert_eq!(u, b.union(&a));
    }

    #[test]
    fn harris_window_stencil() {
        // 2x2 block window as used by the Harris benchmark.
        let st = stencils(
            "#pragma imcl grid(dx2)\n\
             void harris(Image<float> dx2, Image<float> out) {\n\
               float sum = 0.0f;\n\
               for (int i = 0; i < 2; i++) {\n\
                 for (int j = 0; j < 2; j++) { sum += dx2[idx + i][idy + j]; }\n\
               }\n\
               out[idx][idy] = sum;\n\
             }",
        );
        assert_eq!(
            st["dx2"],
            Ok(Stencil { min_dx: 0, max_dx: 1, min_dy: 0, max_dy: 1 })
        );
    }
}
