//! Compiler analyses (paper §5.1–§5.2).
//!
//! [`KernelInfo::analyze`] runs every pass over a checked program and
//! exposes the per-array optimization *eligibility* queries the
//! transformation stage and the tuning-space enumeration share:
//!
//! * image memory — array is read-only XOR write-only (no aliasing);
//! * constant memory — array is read-only and its size is known (via the
//!   `array_size` directive) to fit the device limit;
//! * local memory — `Image` is read-only and has a compile-time stencil.

pub mod constprop;
pub mod cost;
pub mod loops;
pub mod rw;
pub mod stencil;

use std::collections::HashMap;

pub use constprop::{affine_of, scaled_affine_of, Affine, ConstEnv, ScaledAffine, ValueSet};
pub use cost::ThreadCost;
pub use loops::LoopInfo;
pub use rw::Access;
pub use stencil::{Stencil, StencilFailure};

use crate::imagecl::{CheckedProgram, Forced, Type};

/// Aggregated analysis results for one kernel.
#[derive(Debug, Clone)]
pub struct KernelInfo {
    pub prog: CheckedProgram,
    pub env: ConstEnv,
    pub access: HashMap<String, Access>,
    pub stencils: HashMap<String, Result<Stencil, StencilFailure>>,
    pub loops: Vec<LoopInfo>,
    pub cost: ThreadCost,
}

impl KernelInfo {
    /// Run all analyses.
    pub fn analyze(prog: CheckedProgram) -> KernelInfo {
        let env = ConstEnv::build(&prog.kernel);
        let access = rw::classify(&prog.kernel);
        let stencils = stencil::extract(&prog.kernel, &env);
        let loops = loops::collect(&prog.kernel, &env);
        let cost = cost::estimate(&prog.kernel, &env);
        KernelInfo { prog, env, access, stencils, loops, cost }
    }

    pub fn access(&self, array: &str) -> Access {
        self.access.get(array).copied().unwrap_or(Access::Unused)
    }

    /// Image memory (texture) eligibility: used read-only or write-only
    /// (paper §5.2.4 — aliasing is disallowed, so reference inspection is
    /// sound). Honors `force(image_mem(..), off)`.
    pub fn image_mem_eligible(&self, array: &str) -> bool {
        if self.prog.force_image_mem.get(array) == Some(&Forced::Off) {
            return false;
        }
        matches!(self.access(array), Access::ReadOnly | Access::WriteOnly)
            && self.prog.kernel.param(array).map(|p| p.ty.is_buffer()) == Some(true)
    }

    /// Constant memory eligibility: read-only and size known to be below
    /// `max_bytes` (device limit). The size is known either never (plain
    /// images — their extent is a runtime value) or through the
    /// `array_size` directive (paper §5.2.4).
    pub fn constant_mem_eligible(&self, array: &str, max_bytes: usize) -> bool {
        if self.prog.force_constant_mem.get(array) == Some(&Forced::Off) {
            return false;
        }
        if self.access(array) != Access::ReadOnly {
            return false;
        }
        let Some(param) = self.prog.kernel.param(array) else {
            return false;
        };
        let elem_bytes = match &param.ty {
            Type::Array { elem } => elem.size_bytes(),
            _ => return false, // images use image memory, not constant
        };
        match self.prog.size_bounds.get(array) {
            Some(n) => n * elem_bytes <= max_bytes,
            None => false,
        }
    }

    /// Local memory eligibility: read-only `Image` with a compile-time
    /// stencil (paper §5.2.4). Honors `force(local_mem(..), off)`.
    pub fn local_mem_eligible(&self, array: &str) -> bool {
        if self.prog.force_local_mem.get(array) == Some(&Forced::Off) {
            return false;
        }
        self.access(array) == Access::ReadOnly && self.read_stencil(array).is_some()
    }

    /// The read stencil of an image, if extraction succeeded.
    pub fn read_stencil(&self, array: &str) -> Option<Stencil> {
        match self.stencils.get(array) {
            Some(Ok(s)) => Some(*s),
            _ => None,
        }
    }

    /// Loops eligible for the unroll tuning parameter.
    pub fn unrollable_loops(&self) -> Vec<&LoopInfo> {
        self.loops.iter().filter(|l| l.unrollable()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::imagecl::frontend;

    fn info(src: &str) -> KernelInfo {
        KernelInfo::analyze(frontend(src).unwrap())
    }

    const CONV: &str = "#pragma imcl grid(in)\n\
        #pragma imcl array_size(f, 25)\n\
        void conv(Image<float> in, Image<float> out, float* f) {\n\
          float sum = 0.0f;\n\
          for (int i = -2; i < 3; i++) {\n\
            for (int j = -2; j < 3; j++) {\n\
              sum += in[idx + i][idy + j] * f[(i + 2) * 5 + j + 2];\n\
            }\n\
          }\n\
          out[idx][idy] = sum;\n\
        }";

    #[test]
    fn conv_eligibilities() {
        let ki = info(CONV);
        // in: read-only image with 5x5 stencil → image, local eligible.
        assert!(ki.image_mem_eligible("in"));
        assert!(ki.local_mem_eligible("in"));
        assert!(!ki.constant_mem_eligible("in", 64 << 10));
        // out: write-only image → image memory eligible, not local/const.
        assert!(ki.image_mem_eligible("out"));
        assert!(!ki.local_mem_eligible("out"));
        // f: read-only array with size bound 25*4B → constant eligible.
        assert!(ki.constant_mem_eligible("f", 64 << 10));
        assert!(!ki.constant_mem_eligible("f", 64)); // too small a limit
        assert_eq!(
            ki.read_stencil("in"),
            Some(Stencil { min_dx: -2, max_dx: 2, min_dy: -2, max_dy: 2 })
        );
        assert_eq!(ki.unrollable_loops().len(), 2);
    }

    #[test]
    fn read_write_image_not_eligible() {
        let ki = info("void k(Image<float> a) { a[idx][idy] = a[idx][idy] + 1.0f; }");
        assert!(!ki.image_mem_eligible("a"));
        assert!(!ki.local_mem_eligible("a"));
    }

    #[test]
    fn forced_off_wins() {
        let ki = info(
            "#pragma imcl grid(in)\n\
             #pragma imcl force(local_mem(in), off)\n\
             #pragma imcl force(image_mem(in), off)\n\
             void k(Image<float> in, Image<float> out) { out[idx][idy] = in[idx][idy]; }",
        );
        assert!(!ki.local_mem_eligible("in"));
        assert!(!ki.image_mem_eligible("in"));
        assert!(ki.image_mem_eligible("out"));
    }

    #[test]
    fn array_without_bound_not_constant_eligible() {
        let ki = info(
            "#pragma imcl grid(a)\n\
             void k(Image<float> a, float* f) { a[idx][idy] = f[0]; }",
        );
        assert!(!ki.constant_mem_eligible("f", 64 << 10));
    }
}
