//! Read/write classification of buffer parameters (paper §5.2.4).
//!
//! ImageCL disallows aliasing, so looking at every reference to an array
//! suffices to decide whether it is only read from or only written to —
//! the prerequisite for the image-memory (read-only XOR write-only),
//! constant-memory (read-only) and local-memory (read-only) optimizations.

use std::collections::HashMap;

use crate::imagecl::ast::*;

use super::constprop::{scaled_affine_of, ConstEnv, ValueSet, MAX_SET};

/// Access classification of one buffer parameter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Access {
    Unused,
    ReadOnly,
    WriteOnly,
    ReadWrite,
}

impl Access {
    fn with_read(self) -> Access {
        match self {
            Access::Unused | Access::ReadOnly => Access::ReadOnly,
            Access::WriteOnly | Access::ReadWrite => Access::ReadWrite,
        }
    }

    fn with_write(self) -> Access {
        match self {
            Access::Unused | Access::WriteOnly => Access::WriteOnly,
            Access::ReadOnly | Access::ReadWrite => Access::ReadWrite,
        }
    }
}

/// Classify every buffer parameter of the kernel.
pub fn classify(kernel: &KernelFn) -> HashMap<String, Access> {
    let mut acc: HashMap<String, Access> = kernel
        .params
        .iter()
        .filter(|p| p.ty.is_buffer())
        .map(|p| (p.name.clone(), Access::Unused))
        .collect();

    fn read(acc: &mut HashMap<String, Access>, e: &Expr) {
        e.walk(&mut |ex| {
            if let Expr::Index { base, .. } = ex {
                if let Some(a) = acc.get_mut(base) {
                    *a = a.with_read();
                }
            }
        })
    }

    // Reads: every Index expression that appears as an rvalue. walk_exprs
    // visits value expressions and index sub-expressions of assignments but
    // NOT the assignment target itself, which is handled below.
    kernel.walk_stmts(&mut |s| {
        match s {
            Stmt::Decl { init: Some(e), .. } => read(&mut acc, e),
            Stmt::Assign { lhs, value, .. } => {
                // Index sub-expressions of the target are reads of whatever
                // they reference; the target buffer itself is a write (a
                // compound assignment additionally reads the target).
                if let LValue::Index { base, indices } = lhs {
                    for i in indices {
                        read(&mut acc, i);
                    }
                    if let Some(a) = acc.get_mut(base) {
                        *a = a.with_write();
                    }
                }
                read(&mut acc, value);
            }
            Stmt::If { cond, .. } => read(&mut acc, cond),
            Stmt::For { init, cond, step, .. } => {
                read(&mut acc, init);
                read(&mut acc, cond);
                read(&mut acc, step);
            }
            Stmt::While { cond, .. } => read(&mut acc, cond),
            Stmt::ExprStmt(e) => read(&mut acc, e),
            _ => {}
        }
        // Compound assignment (`+=` etc.) to a buffer element also reads it.
        if let Stmt::Assign { lhs: LValue::Index { base, .. }, op, .. } = s {
            if *op != AssignOp::Set {
                if let Some(a) = acc.get_mut(base) {
                    *a = a.with_read();
                }
            }
        }
    });
    acc
}

/// Write-set ownership: for each buffer parameter, `true` iff **every**
/// write to it targets exactly the work-item's own grid point — `[idx]`
/// for 1-D arrays, `[idx][idy]` for images, with no offsets or scaling.
///
/// This is the disjointness half of the parallel-execution proof used by
/// the bytecode VM's NDRange driver: distinct logical threads own
/// distinct grid points, so owned writes from different work-groups can
/// never touch the same element and groups may execute concurrently.
/// (The other half — nothing written is ever read — comes from
/// [`classify`]: the buffer must be [`Access::WriteOnly`].)
pub fn owned_writes(kernel: &KernelFn) -> HashMap<String, bool> {
    let mut owned: HashMap<String, bool> = kernel
        .params
        .iter()
        .filter(|p| p.ty.is_buffer())
        .map(|p| (p.name.clone(), true))
        .collect();
    kernel.walk_stmts(&mut |s| {
        if let Stmt::Assign { lhs: LValue::Index { base, indices }, .. } = s {
            let ok = match indices.as_slice() {
                [x] => *x == Expr::ident("idx"),
                [x, y] => *x == Expr::ident("idx") && *y == Expr::ident("idy"),
                _ => false,
            };
            if !ok {
                if let Some(e) = owned.get_mut(base) {
                    *e = false;
                }
            }
        }
    });
    owned
}

/// Per-dimension write pattern accumulated across every store to one
/// buffer: all writes must share one stride, offsets are unioned.
#[derive(Debug, Clone)]
struct DimWrites {
    scale: i64,
    offsets: ValueSet,
}

/// Per-buffer accumulation state for [`disjoint_writes`].
#[derive(Debug, Clone)]
enum WriteAcc {
    /// Never written (vacuously disjoint).
    NoWrites,
    /// Every write so far is affine in the dimension's own thread index.
    Dims(Vec<DimWrites>),
    /// Some write doesn't fit the provable pattern.
    Bad,
}

/// Affine strided-write disjointness: for each buffer parameter, `true`
/// iff distinct logical threads provably write **disjoint** element
/// sets. This generalizes [`owned_writes`] from the exact
/// `a[idx]` / `a[idx][idy]` form to *scaled* affine forms like
/// `a[idx * 2]` / `a[idx * 2 + 1]` (upsampling, interleaved-channel and
/// block-layout writes), using [`scaled_affine_of`] from the constant
/// propagation environment.
///
/// The proof per dimension: every write's index must decompose to
/// `scale * id + d` with one shared non-zero `scale` (the dimension's own
/// thread index — `idx` for x, `idy` for y) and compile-time offset set
/// `D`. Two threads `i ≠ j` (or one thread's two offsets `d1 ≠ d2`)
/// collide in that dimension only if `scale | (d1 - d2)`, so requiring
/// every pair of distinct offsets to be non-divisible by the scale makes
/// the dimension injective. Any two distinct threads differ in `idx` or
/// `idy`, so injectivity of the matching dimension separates their
/// pixels. (For 1-D arrays the caller must additionally know the grid is
/// 1-D — threads differing only in `idy` share every `a[f(idx)]`
/// element; see the gate in `transform::lower`.)
pub fn disjoint_writes(kernel: &KernelFn, env: &ConstEnv) -> HashMap<String, bool> {
    let mut acc: HashMap<String, WriteAcc> = kernel
        .params
        .iter()
        .filter(|p| p.ty.is_buffer())
        .map(|p| (p.name.clone(), WriteAcc::NoWrites))
        .collect();

    kernel.walk_stmts(&mut |s| {
        let Stmt::Assign { lhs: LValue::Index { base, indices }, .. } = s else {
            return;
        };
        let Some(entry) = acc.get_mut(base) else { return };
        if matches!(entry, WriteAcc::Bad) {
            return;
        }
        // Expected base ident per dimension: [idx] for 1-D, [idx][idy]
        // for images (3-D is rejected by the lowering anyway).
        let expected: &[&str] = match indices.len() {
            1 => &["idx"],
            2 => &["idx", "idy"],
            _ => {
                *entry = WriteAcc::Bad;
                return;
            }
        };
        let mut dims = Vec::with_capacity(indices.len());
        for (ix, &want) in indices.iter().zip(expected) {
            match scaled_affine_of(env, ix) {
                Some(sa) if sa.base.as_deref() == Some(want) && sa.scale != 0 => {
                    dims.push(DimWrites { scale: sa.scale, offsets: sa.offsets });
                }
                _ => {
                    *entry = WriteAcc::Bad;
                    return;
                }
            }
        }
        let replace = match entry {
            WriteAcc::NoWrites => Some(WriteAcc::Dims(dims)),
            WriteAcc::Dims(prev) => {
                let mut ok = true;
                for (p, d) in prev.iter_mut().zip(dims) {
                    if p.scale != d.scale {
                        ok = false;
                        break;
                    }
                    p.offsets.extend(d.offsets);
                    if p.offsets.len() > MAX_SET {
                        ok = false;
                        break;
                    }
                }
                if ok {
                    None
                } else {
                    Some(WriteAcc::Bad)
                }
            }
            WriteAcc::Bad => None,
        };
        if let Some(r) = replace {
            *entry = r;
        }
    });

    acc.into_iter()
        .map(|(name, a)| {
            let ok = match a {
                WriteAcc::NoWrites => true,
                WriteAcc::Bad => false,
                WriteAcc::Dims(dims) => dims.iter().all(dim_injective),
            };
            (name, ok)
        })
        .collect()
}

/// Is `scale * id + D` injective over distinct `(id, d)` pairs? Needs
/// every pair of *distinct* offsets to differ by a non-multiple of the
/// scale (a multiple difference is exactly what lets thread `i + k`'s
/// offset land on thread `i`'s element).
fn dim_injective(dim: &DimWrites) -> bool {
    debug_assert_ne!(dim.scale, 0);
    let offs: Vec<i64> = dim.offsets.iter().copied().collect();
    for (k, &d1) in offs.iter().enumerate() {
        for &d2 in &offs[k + 1..] {
            match d1.checked_sub(d2) {
                Some(diff) if diff % dim.scale != 0 => {}
                _ => return false,
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::imagecl::Program;

    fn classify_src(src: &str) -> HashMap<String, Access> {
        classify(&Program::parse(src).unwrap().kernel)
    }

    fn owned_src(src: &str) -> HashMap<String, bool> {
        owned_writes(&Program::parse(src).unwrap().kernel)
    }

    #[test]
    fn box_filter_classification() {
        let acc = classify_src(
            "void blur(Image<float> in, Image<float> out) {\n\
               float sum = 0.0f;\n\
               for (int i = -1; i < 2; i++) { sum += in[idx + i][idy]; }\n\
               out[idx][idy] = sum / 9.0f;\n\
             }",
        );
        assert_eq!(acc["in"], Access::ReadOnly);
        assert_eq!(acc["out"], Access::WriteOnly);
    }

    #[test]
    fn read_write_detected() {
        let acc = classify_src(
            "void k(Image<float> a) { a[idx][idy] = a[idx][idy] * 2.0f; }",
        );
        assert_eq!(acc["a"], Access::ReadWrite);
    }

    #[test]
    fn compound_assign_is_read_write() {
        let acc = classify_src("void k(Image<float> a) { a[idx][idy] += 1.0f; }");
        assert_eq!(acc["a"], Access::ReadWrite);
    }

    #[test]
    fn unused_buffer() {
        let acc = classify_src(
            "#pragma imcl grid(a)\nvoid k(Image<float> a, float* f) { a[idx][idy] = 0.0f; }",
        );
        assert_eq!(acc["f"], Access::Unused);
        assert_eq!(acc["a"], Access::WriteOnly);
    }

    #[test]
    fn read_in_condition_counts() {
        let acc = classify_src(
            "#pragma imcl grid(a)\n\
             void k(Image<float> a, Image<float> m) {\n\
               if (m[idx][idy] > 0.5f) { a[idx][idy] = 1.0f; }\n\
             }",
        );
        assert_eq!(acc["m"], Access::ReadOnly);
        assert_eq!(acc["a"], Access::WriteOnly);
    }

    #[test]
    fn owned_writes_detects_own_pixel_stores() {
        let o = owned_src(
            "#pragma imcl grid(in)\n\
             void k(Image<float> in, Image<float> out) {\n\
               out[idx][idy] = in[idx + 1][idy];\n\
             }",
        );
        // `out` only ever written at the thread's own pixel; `in` is
        // never written (vacuously owned).
        assert!(o["out"]);
        assert!(o["in"]);
    }

    #[test]
    fn offset_or_scaled_writes_are_not_owned() {
        let o = owned_src(
            "#pragma imcl grid(a)\n\
             void k(Image<float> a, Image<float> b, float* c) {\n\
               a[idx + 1][idy] = 0.0f;\n\
               b[idx][idy + idy] = 0.0f;\n\
               c[idx + 1] = 0.0f;\n\
             }",
        );
        assert!(!o["a"]);
        assert!(!o["b"]);
        assert!(!o["c"]);
    }

    #[test]
    fn one_d_own_index_is_owned() {
        let o = owned_src("#pragma imcl grid(16, 1)\nvoid k(float* a) { a[idx] = 1.0f; }");
        assert!(o["a"]);
    }

    #[test]
    fn index_of_write_target_is_read() {
        let acc = classify_src(
            "#pragma imcl grid(a)\n\
             void k(Image<float> a, float* lut) { a[(int)(lut[0])][idy] = 0.0f; }",
        );
        assert_eq!(acc["lut"], Access::ReadOnly);
    }

    fn disjoint_src(src: &str) -> HashMap<String, bool> {
        let p = Program::parse(src).unwrap();
        let env = ConstEnv::build(&p.kernel);
        disjoint_writes(&p.kernel, &env)
    }

    #[test]
    fn disjoint_covers_owned_forms() {
        let d = disjoint_src(
            "#pragma imcl grid(in)\n\
             void k(Image<float> in, Image<float> out) {\n\
               out[idx][idy] = in[idx + 1][idy];\n\
             }",
        );
        assert!(d["out"]);
        assert!(d["in"]); // never written → vacuously disjoint
    }

    #[test]
    fn strided_writes_are_disjoint() {
        // Interleaved-channel write: each thread owns {2*idx, 2*idx + 1}.
        let d = disjoint_src(
            "#pragma imcl grid(16, 1)\n\
             void k(float* a) { a[idx * 2] = 0.0f; a[idx * 2 + 1] = 1.0f; }",
        );
        assert!(d["a"]);
        // Loop-offset flavor of the same pattern.
        let d = disjoint_src(
            "#pragma imcl grid(16, 1)\n\
             void k(float* a) {\n\
               for (int i = 0; i < 2; i++) { a[idx * 2 + i] = 0.0f; }\n\
             }",
        );
        assert!(d["a"]);
        // 2-D block write: out[idx*2 + i][idy*2 + j] covers a 2x2 tile.
        let d = disjoint_src(
            "#pragma imcl grid(out)\n\
             void k(Image<float> out) {\n\
               for (int i = 0; i < 2; i++) {\n\
                 for (int j = 0; j < 2; j++) { out[idx * 2 + i][idy * 2 + j] = 0.0f; }\n\
               }\n\
             }",
        );
        assert!(d["out"]);
    }

    #[test]
    fn constant_offset_write_is_disjoint() {
        // a[idx + 1]: shifted but still one element per thread (bounds
        // are the runtime's problem, not the disjointness proof's).
        let d = disjoint_src("#pragma imcl grid(16, 1)\nvoid k(float* a) { a[idx + 1] = 0.0f; }");
        assert!(d["a"]);
    }

    #[test]
    fn colliding_strides_rejected() {
        // Offsets 0 and 2 differ by the stride → thread i+1 lands on
        // thread i's element.
        let d = disjoint_src(
            "#pragma imcl grid(16, 1)\n\
             void k(float* a) { a[idx * 2] = 0.0f; a[idx * 2 + 2] = 1.0f; }",
        );
        assert!(!d["a"]);
        // Two unit-stride offsets always collide.
        let d = disjoint_src(
            "#pragma imcl grid(16, 1)\n\
             void k(float* a) { a[idx] = 0.0f; a[idx + 1] = 1.0f; }",
        );
        assert!(!d["a"]);
        // Mismatched strides across writes are not provable.
        let d = disjoint_src(
            "#pragma imcl grid(16, 1)\n\
             void k(float* a) { a[idx * 2] = 0.0f; a[idx * 3] = 1.0f; }",
        );
        assert!(!d["a"]);
    }

    #[test]
    fn non_affine_writes_rejected() {
        let d = disjoint_src(
            "#pragma imcl grid(a)\n\
             void k(Image<float> a, float* lut) {\n\
               a[(int)(lut[0])][idy] = 0.0f;\n\
             }",
        );
        assert!(!d["a"]);
        // idy used in the x dimension: wrong base for the dimension.
        let d = disjoint_src(
            "#pragma imcl grid(a)\nvoid k(Image<float> a) { a[idy][idx] = 0.0f; }",
        );
        assert!(!d["a"]);
        // Scale that cancels to zero writes one shared element.
        let d = disjoint_src(
            "#pragma imcl grid(16, 1)\nvoid k(float* a) { a[idx - idx] = 0.0f; }",
        );
        assert!(!d["a"]);
    }
}
