//! Read/write classification of buffer parameters (paper §5.2.4).
//!
//! ImageCL disallows aliasing, so looking at every reference to an array
//! suffices to decide whether it is only read from or only written to —
//! the prerequisite for the image-memory (read-only XOR write-only),
//! constant-memory (read-only) and local-memory (read-only) optimizations.

use std::collections::HashMap;

use crate::imagecl::ast::*;

/// Access classification of one buffer parameter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Access {
    Unused,
    ReadOnly,
    WriteOnly,
    ReadWrite,
}

impl Access {
    fn with_read(self) -> Access {
        match self {
            Access::Unused | Access::ReadOnly => Access::ReadOnly,
            Access::WriteOnly | Access::ReadWrite => Access::ReadWrite,
        }
    }

    fn with_write(self) -> Access {
        match self {
            Access::Unused | Access::WriteOnly => Access::WriteOnly,
            Access::ReadOnly | Access::ReadWrite => Access::ReadWrite,
        }
    }
}

/// Classify every buffer parameter of the kernel.
pub fn classify(kernel: &KernelFn) -> HashMap<String, Access> {
    let mut acc: HashMap<String, Access> = kernel
        .params
        .iter()
        .filter(|p| p.ty.is_buffer())
        .map(|p| (p.name.clone(), Access::Unused))
        .collect();

    fn read(acc: &mut HashMap<String, Access>, e: &Expr) {
        e.walk(&mut |ex| {
            if let Expr::Index { base, .. } = ex {
                if let Some(a) = acc.get_mut(base) {
                    *a = a.with_read();
                }
            }
        })
    }

    // Reads: every Index expression that appears as an rvalue. walk_exprs
    // visits value expressions and index sub-expressions of assignments but
    // NOT the assignment target itself, which is handled below.
    kernel.walk_stmts(&mut |s| {
        match s {
            Stmt::Decl { init: Some(e), .. } => read(&mut acc, e),
            Stmt::Assign { lhs, value, .. } => {
                // Index sub-expressions of the target are reads of whatever
                // they reference; the target buffer itself is a write (a
                // compound assignment additionally reads the target).
                if let LValue::Index { base, indices } = lhs {
                    for i in indices {
                        read(&mut acc, i);
                    }
                    if let Some(a) = acc.get_mut(base) {
                        *a = a.with_write();
                    }
                }
                read(&mut acc, value);
            }
            Stmt::If { cond, .. } => read(&mut acc, cond),
            Stmt::For { init, cond, step, .. } => {
                read(&mut acc, init);
                read(&mut acc, cond);
                read(&mut acc, step);
            }
            Stmt::While { cond, .. } => read(&mut acc, cond),
            Stmt::ExprStmt(e) => read(&mut acc, e),
            _ => {}
        }
        // Compound assignment (`+=` etc.) to a buffer element also reads it.
        if let Stmt::Assign { lhs: LValue::Index { base, .. }, op, .. } = s {
            if *op != AssignOp::Set {
                if let Some(a) = acc.get_mut(base) {
                    *a = a.with_read();
                }
            }
        }
    });
    acc
}

/// Write-set ownership: for each buffer parameter, `true` iff **every**
/// write to it targets exactly the work-item's own grid point — `[idx]`
/// for 1-D arrays, `[idx][idy]` for images, with no offsets or scaling.
///
/// This is the disjointness half of the parallel-execution proof used by
/// the bytecode VM's NDRange driver: distinct logical threads own
/// distinct grid points, so owned writes from different work-groups can
/// never touch the same element and groups may execute concurrently.
/// (The other half — nothing written is ever read — comes from
/// [`classify`]: the buffer must be [`Access::WriteOnly`].)
pub fn owned_writes(kernel: &KernelFn) -> HashMap<String, bool> {
    let mut owned: HashMap<String, bool> = kernel
        .params
        .iter()
        .filter(|p| p.ty.is_buffer())
        .map(|p| (p.name.clone(), true))
        .collect();
    kernel.walk_stmts(&mut |s| {
        if let Stmt::Assign { lhs: LValue::Index { base, indices }, .. } = s {
            let ok = match indices.as_slice() {
                [x] => *x == Expr::ident("idx"),
                [x, y] => *x == Expr::ident("idx") && *y == Expr::ident("idy"),
                _ => false,
            };
            if !ok {
                if let Some(e) = owned.get_mut(base) {
                    *e = false;
                }
            }
        }
    });
    owned
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::imagecl::Program;

    fn classify_src(src: &str) -> HashMap<String, Access> {
        classify(&Program::parse(src).unwrap().kernel)
    }

    fn owned_src(src: &str) -> HashMap<String, bool> {
        owned_writes(&Program::parse(src).unwrap().kernel)
    }

    #[test]
    fn box_filter_classification() {
        let acc = classify_src(
            "void blur(Image<float> in, Image<float> out) {\n\
               float sum = 0.0f;\n\
               for (int i = -1; i < 2; i++) { sum += in[idx + i][idy]; }\n\
               out[idx][idy] = sum / 9.0f;\n\
             }",
        );
        assert_eq!(acc["in"], Access::ReadOnly);
        assert_eq!(acc["out"], Access::WriteOnly);
    }

    #[test]
    fn read_write_detected() {
        let acc = classify_src(
            "void k(Image<float> a) { a[idx][idy] = a[idx][idy] * 2.0f; }",
        );
        assert_eq!(acc["a"], Access::ReadWrite);
    }

    #[test]
    fn compound_assign_is_read_write() {
        let acc = classify_src("void k(Image<float> a) { a[idx][idy] += 1.0f; }");
        assert_eq!(acc["a"], Access::ReadWrite);
    }

    #[test]
    fn unused_buffer() {
        let acc = classify_src(
            "#pragma imcl grid(a)\nvoid k(Image<float> a, float* f) { a[idx][idy] = 0.0f; }",
        );
        assert_eq!(acc["f"], Access::Unused);
        assert_eq!(acc["a"], Access::WriteOnly);
    }

    #[test]
    fn read_in_condition_counts() {
        let acc = classify_src(
            "#pragma imcl grid(a)\n\
             void k(Image<float> a, Image<float> m) {\n\
               if (m[idx][idy] > 0.5f) { a[idx][idy] = 1.0f; }\n\
             }",
        );
        assert_eq!(acc["m"], Access::ReadOnly);
        assert_eq!(acc["a"], Access::WriteOnly);
    }

    #[test]
    fn owned_writes_detects_own_pixel_stores() {
        let o = owned_src(
            "#pragma imcl grid(in)\n\
             void k(Image<float> in, Image<float> out) {\n\
               out[idx][idy] = in[idx + 1][idy];\n\
             }",
        );
        // `out` only ever written at the thread's own pixel; `in` is
        // never written (vacuously owned).
        assert!(o["out"]);
        assert!(o["in"]);
    }

    #[test]
    fn offset_or_scaled_writes_are_not_owned() {
        let o = owned_src(
            "#pragma imcl grid(a)\n\
             void k(Image<float> a, Image<float> b, float* c) {\n\
               a[idx + 1][idy] = 0.0f;\n\
               b[idx][idy + idy] = 0.0f;\n\
               c[idx + 1] = 0.0f;\n\
             }",
        );
        assert!(!o["a"]);
        assert!(!o["b"]);
        assert!(!o["c"]);
    }

    #[test]
    fn one_d_own_index_is_owned() {
        let o = owned_src("#pragma imcl grid(16, 1)\nvoid k(float* a) { a[idx] = 1.0f; }");
        assert!(o["a"]);
    }

    #[test]
    fn index_of_write_target_is_read() {
        let acc = classify_src(
            "#pragma imcl grid(a)\n\
             void k(Image<float> a, float* lut) { a[(int)(lut[0])][idy] = 0.0f; }",
        );
        assert_eq!(acc["lut"], Access::ReadOnly);
    }
}
