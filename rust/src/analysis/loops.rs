//! Loop inventory (paper §5.2.5, loop unrolling).
//!
//! Collects the kernel's for-loops in source (pre-)order, assigning the
//! stable 1-based IDs the paper's result tables use ("Unroll loop 1",
//! "Unroll loop 2"). A loop is *unrollable* when its trip count is a
//! compile-time constant (range known via constant propagation).

use super::constprop::ConstEnv;
use crate::imagecl::ast::*;

/// Information about one for-loop in the kernel.
#[derive(Debug, Clone, PartialEq)]
pub struct LoopInfo {
    /// 1-based ID in source pre-order (paper tables: "Unroll loop 1").
    pub id: usize,
    /// Induction variable name.
    pub var: String,
    /// Trip count if compile-time constant.
    pub trips: Option<usize>,
    /// Nesting depth (0 = top level of kernel body).
    pub depth: usize,
}

impl LoopInfo {
    pub fn unrollable(&self) -> bool {
        self.trips.is_some()
    }
}

/// Collect all for-loops, in pre-order.
pub fn collect(kernel: &KernelFn, env: &ConstEnv) -> Vec<LoopInfo> {
    let mut out = Vec::new();
    fn rec(stmts: &[Stmt], depth: usize, env: &ConstEnv, out: &mut Vec<LoopInfo>) {
        for s in stmts {
            match s {
                Stmt::For { var, init, cond, step, body } => {
                    let trips = env
                        .loop_values(init, cond, step, var)
                        .map(|vs| vs.len());
                    out.push(LoopInfo {
                        id: out.len() + 1,
                        var: var.clone(),
                        trips,
                        depth,
                    });
                    rec(body, depth + 1, env, out);
                }
                Stmt::If { then, els, .. } => {
                    rec(then, depth, env, out);
                    rec(els, depth, env, out);
                }
                Stmt::While { body, .. } => rec(body, depth, env, out),
                _ => {}
            }
        }
    }
    rec(&kernel.body, 0, env, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::imagecl::Program;

    fn loops(src: &str) -> Vec<LoopInfo> {
        let p = Program::parse(src).unwrap();
        let env = ConstEnv::build(&p.kernel);
        collect(&p.kernel, &env)
    }

    #[test]
    fn nested_loops_ordered() {
        let ls = loops(
            "void k(float* a) {\n\
               for (int i = 0; i < 4; i++) {\n\
                 for (int j = 0; j < 2; j++) { a[idx] = 0.0f; }\n\
               }\n\
               for (int m = 0; m < 3; m++) { a[idx] = 1.0f; }\n\
             }",
        );
        assert_eq!(ls.len(), 3);
        assert_eq!((ls[0].id, ls[0].var.as_str(), ls[0].trips, ls[0].depth), (1, "i", Some(4), 0));
        assert_eq!((ls[1].id, ls[1].var.as_str(), ls[1].trips, ls[1].depth), (2, "j", Some(2), 1));
        assert_eq!((ls[2].id, ls[2].var.as_str(), ls[2].trips, ls[2].depth), (3, "m", Some(3), 0));
        assert!(ls.iter().all(LoopInfo::unrollable));
    }

    #[test]
    fn runtime_loop_not_unrollable() {
        let ls = loops(
            "void k(float* a, int n) { for (int i = 0; i < n; i++) { a[idx] = 0.0f; } }",
        );
        assert_eq!(ls.len(), 1);
        assert_eq!(ls[0].trips, None);
        assert!(!ls[0].unrollable());
    }

    #[test]
    fn loop_inside_if_found() {
        let ls = loops(
            "void k(float* a) { if (idx > 0) { for (int i = 0; i < 2; i++) { a[idx] = 0.0f; } } }",
        );
        assert_eq!(ls.len(), 1);
        assert_eq!(ls[0].trips, Some(2));
    }
}
