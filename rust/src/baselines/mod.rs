//! Baseline comparators for Figure 6: Halide, HIPACC and OpenCV.
//!
//! We cannot run the real binaries on this testbed (DESIGN.md §2); each
//! baseline is modelled inside our own framework by the *structural
//! restriction* the paper's §7 attributes its behaviour to:
//!
//! * **Halide** — schedules are searched (the paper hand-tuned for hours:
//!   we grant an exhaustive search over a thinned space), but the language
//!   cannot express image memory ("an optimization Halide does not
//!   expose"). It *can* fuse pipeline stages through local memory /
//!   caches (its §7 win on the GTX 960 sep-conv) and hoists boundary
//!   handling out of the hot loop via specialization (its 4.24× CPU
//!   conv2d win).
//! * **HIPACC** — one configuration chosen by its architecture model +
//!   heuristics (no empirical search): texture memory on NVIDIA, local
//!   staging for stencils, fixed work-group heuristic, full unrolling.
//! * **OpenCV** — hand-written fixed implementations: one OpenCL kernel
//!   configuration tuned for a generic GCN GPU (with `uchar4`
//!   vectorization for the 8-bit conv — its §6 win on the AMD 7970), one
//!   natively vectorized CPU path, and a multi-pass `cornerHarris`
//!   (separate Sobel/multiply/box/response kernels with DRAM round trips
//!   — why ImageCL beats it by 2-4.6× on Harris).

use crate::analysis::KernelInfo;
use crate::bench_defs::{Benchmark, KernelDef};
use crate::devices::{predict, DeviceKind, DeviceSpec, KernelModel};
use crate::imagecl::{frontend, BoundaryCond};
use crate::transform::TuningConfig;
use crate::tuner::{self, MlSearchOpts, Strategy, TuningSpace};

/// The comparators of Figure 6.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Baseline {
    Halide,
    Hipacc,
    OpenCv,
}

impl Baseline {
    pub fn name(&self) -> &'static str {
        match self {
            Baseline::Halide => "Halide",
            Baseline::Hipacc => "HIPACC",
            Baseline::OpenCv => "OpenCV",
        }
    }
}

pub const ALL_BASELINES: [Baseline; 3] =
    [Baseline::Halide, Baseline::Hipacc, Baseline::OpenCv];

/// Tuning budget used for ImageCL in Figure 6 (paper-scale ML search).
pub fn imagecl_strategy() -> Strategy {
    Strategy::MlTwoPhase(MlSearchOpts {
        train_samples: 700,
        top_k: 100,
        epochs: 30,
        ..Default::default()
    })
}

fn analyze(k: &KernelDef) -> KernelInfo {
    KernelInfo::analyze(frontend(k.source).expect("benchmark source"))
}

/// ImageCL time for one benchmark on one device: auto-tune each kernel,
/// sum the best times (paper: per-kernel tuning).
pub fn imagecl_time(bench: &Benchmark, dev: &DeviceSpec, n: usize) -> f64 {
    bench
        .kernels
        .iter()
        .map(|k| {
            let info = analyze(k);
            tuner::tune_on_simulator(&info, dev, (n, n), &imagecl_strategy()).best_time
        })
        .sum()
}

/// Baseline time for one benchmark on one device.
pub fn baseline_time(b: Baseline, bench: &Benchmark, dev: &DeviceSpec, n: usize) -> f64 {
    match b {
        Baseline::Halide => halide_time(bench, dev, n),
        Baseline::Hipacc => hipacc_time(bench, dev, n),
        Baseline::OpenCv => opencv_time(bench, dev, n),
    }
}

// ---------------------------------------------------------------------
// Halide
// ---------------------------------------------------------------------

/// Predict with Halide's boundary specialization: the clamped/constant
/// checks are hoisted out of the interior loop, so reads behave like
/// unchecked interior reads.
fn predict_hoisted_boundary(
    dev: &DeviceSpec,
    info: &KernelInfo,
    cfg: &TuningConfig,
    n: usize,
) -> f64 {
    let mut km = KernelModel::build(info, cfg);
    for b in &mut km.buffers {
        b.boundary_checked = false;
        b.boundary = BoundaryCond::Constant(0.0);
    }
    predict(dev, &km, n, n).seconds
}

fn halide_kernel_time(info: &KernelInfo, dev: &DeviceSpec, n: usize) -> f64 {
    // Restricted space: no image memory, and no explicit local-memory
    // staging of single-kernel stencils either (paper §3: "important GPU
    // optimizations, such as using specific memories, are hard or
    // impossible to express" — Halide's shared-memory use comes from
    // stage fusion, credited separately below). Thinned exhaustive search
    // stands in for the paper's hours of manual schedule tuning.
    let space = TuningSpace::enumerate(info, dev);
    let mut best = f64::INFINITY;
    for cfg in space.configs.iter().step_by(3) {
        if cfg.image_mem.values().any(|&v| v) || cfg.any_local_mem() {
            continue;
        }
        let t = predict_hoisted_boundary(dev, info, cfg, n);
        if t < best {
            best = t;
        }
    }
    best
}

fn halide_time(bench: &Benchmark, dev: &DeviceSpec, n: usize) -> f64 {
    let per_kernel: f64 = bench
        .kernels
        .iter()
        .map(|k| halide_kernel_time(&analyze(k), dev, n))
        .sum();
    if bench.id == "sepconv" {
        // Stage fusion (§7): Halide merges the row and column kernels,
        // caching the intermediate in local memory — the intermediate
        // image's DRAM round trip (one write + one read) disappears, at
        // the price of halo recomputation and tile synchronization
        // (compute_at), modelled as 15% of the unfused time.
        let elem = bench.pixel_type.size_bytes() as f64;
        let saved = 2.0 * elem * (n * n) as f64 / (dev.mem_bw_gbs * 1e9);
        let overhead = 0.15 * per_kernel;
        (per_kernel - saved + overhead).max(per_kernel * 0.6)
    } else {
        per_kernel
    }
}

// ---------------------------------------------------------------------
// HIPACC
// ---------------------------------------------------------------------

fn hipacc_config(info: &KernelInfo, dev: &DeviceSpec) -> TuningConfig {
    // Architecture-model heuristics (HIPACC paper): fixed work-group,
    // no coarsening search, texture for read-only images on NVIDIA,
    // local staging for stencils, constant memory, full unroll.
    let mut cfg = TuningConfig::default();
    cfg.wg = match dev.kind {
        DeviceKind::Gpu => [32, 4],
        DeviceKind::Cpu => [16, 1],
    };
    // The CPU backend distributes row strips per thread; GPUs get one
    // pixel per work-item (HIPACC does not search coarsening).
    cfg.coarsen = match dev.kind {
        DeviceKind::Gpu => [1, 1],
        DeviceKind::Cpu => [64, 2],
    };
    cfg.interleaved = dev.kind == DeviceKind::Cpu;
    let is_nvidia = dev.name.contains("K40") || dev.name.contains("GTX");
    for p in &info.prog.kernel.params {
        let name = &p.name;
        if info.local_mem_eligible(name) {
            if let Some(st) = info.read_stencil(name) {
                // HIPACC stages multi-row stencils; single-row reuse is
                // left to the cache.
                if st.extent_y() > 0 {
                    cfg.local_mem.insert(name.clone(), true);
                }
            }
        }
        if is_nvidia
            && info.image_mem_eligible(name)
            && !cfg.uses_local_mem(name)
            && info.access(name) == crate::analysis::Access::ReadOnly
        {
            cfg.image_mem.insert(name.clone(), true);
        }
        if info.constant_mem_eligible(name, dev.constant_mem_bytes()) {
            cfg.constant_mem.insert(name.clone(), true);
        }
    }
    for l in info.unrollable_loops() {
        cfg.unroll.insert(l.id, 0);
    }
    cfg
}

fn hipacc_time(bench: &Benchmark, dev: &DeviceSpec, n: usize) -> f64 {
    bench
        .kernels
        .iter()
        .map(|k| {
            let info = analyze(k);
            let cfg = hipacc_config(&info, dev);
            let km = KernelModel::build(&info, &cfg);
            let p = predict(dev, &km, n, n);
            if p.seconds.is_finite() {
                p.seconds
            } else {
                // Heuristic picked an invalid config (e.g. local tile too
                // big): HIPACC would fall back to plain global memory.
                let mut fb = cfg.clone();
                fb.local_mem.clear();
                predict(dev, &KernelModel::build(&info, &fb), n, n).seconds
            }
        })
        .sum()
}

// ---------------------------------------------------------------------
// OpenCV
// ---------------------------------------------------------------------

/// OpenCV's single hand-tuned GPU configuration (frozen once, shipped
/// everywhere — the paper's performance-portability cautionary tale).
fn opencv_gpu_config(info: &KernelInfo) -> TuningConfig {
    let mut cfg = TuningConfig::default();
    cfg.wg = [16, 16];
    cfg.coarsen = [1, 1];
    for p in &info.prog.kernel.params {
        if info.local_mem_eligible(&p.name) {
            cfg.local_mem.insert(p.name.clone(), true);
        }
        if info.constant_mem_eligible(&p.name, 64 << 10) {
            cfg.constant_mem.insert(p.name.clone(), true);
        }
    }
    for l in info.unrollable_loops() {
        cfg.unroll.insert(l.id, 0);
    }
    cfg
}

fn opencv_kernel_time(info: &KernelInfo, dev: &DeviceSpec, n: usize, uchar: bool) -> f64 {
    match dev.kind {
        DeviceKind::Gpu => {
            let cfg = opencv_gpu_config(info);
            let km = KernelModel::build(info, &cfg);
            let t = predict(dev, &km, n, n).seconds;
            // Hand-written uchar4 vector loads in the 8-bit conv path.
            // The kernel was tuned on GCN (why OpenCV wins conv2d on the
            // AMD 7970, paper §6); on NVIDIA the same code vectorizes
            // poorly and ImageCL stays ahead (paper: 1.17–2.82×).
            if uchar {
                if dev.name.contains("AMD") {
                    t * 0.5
                } else {
                    t * 0.9
                }
            } else {
                t
            }
        }
        DeviceKind::Cpu => {
            // Native SIMD CPU path with hoisted boundaries; fixed
            // parallelization (one strip per core).
            let mut cfg = TuningConfig::default();
            cfg.wg = [8, 1];
            cfg.coarsen = [64, 1];
            cfg.interleaved = true;
            for l in info.unrollable_loops() {
                cfg.unroll.insert(l.id, 0);
            }
            predict_hoisted_boundary(dev, info, &cfg, n)
        }
    }
}

fn opencv_time(bench: &Benchmark, dev: &DeviceSpec, n: usize) -> f64 {
    match bench.id {
        "harris" => {
            // cv::cornerHarris is a multi-pass pipeline: Sobel, three
            // products, three box filters, response — every intermediate
            // makes a DRAM round trip.
            let sobel = analyze(&bench.kernels[0]);
            let base = opencv_kernel_time(&sobel, dev, n, false);
            let elem = 4.0;
            let round_trip = 2.0 * elem * (n * n) as f64
                / (dev.mem_bw_gbs * 1e9)
                + dev.launch_overhead_s;
            // sobel + products + box filters + response, partially batched
            // by OpenCV internally: ~5 effective extra passes.
            base + 5.0 * (round_trip + base * 0.35)
        }
        _ => {
            let uchar = bench.pixel_type == crate::imagecl::ScalarType::U8;
            let per_kernel: f64 = bench
                .kernels
                .iter()
                .map(|k| opencv_kernel_time(&analyze(k), dev, n, uchar))
                .sum();
            if bench.id == "sepconv" && dev.kind == DeviceKind::Cpu {
                // cv::sepFilter2D keeps the row-pass result in a cache-
                // resident row buffer — the CPU path is effectively fused.
                let elem = bench.pixel_type.size_bytes() as f64;
                let saved = 2.0 * elem * (n * n) as f64 / (dev.mem_bw_gbs * 1e9);
                (per_kernel - saved).max(per_kernel * 0.6)
            } else {
                per_kernel
            }
        }
    }
}

/// One Figure 6 cell: slowdown of a baseline relative to ImageCL
/// (>1 = ImageCL faster, the paper's plotting convention).
pub fn fig6_slowdown(b: Baseline, bench: &Benchmark, dev: &DeviceSpec, n: usize) -> f64 {
    baseline_time(b, bench, dev, n) / imagecl_time(bench, dev, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_defs::{HARRIS_CORNER, NONSEP_CONVOLUTION, SEPARABLE_CONVOLUTION};
    use crate::devices::{AMD_7970, GTX_960, INTEL_I7, K40};

    // Smaller-than-paper grids keep test time sane; ratios are scale-
    // stable because every term is per-pixel dominated. Debug builds
    // shrink further (the tuner's search loop is ~20x slower unoptimized).
    #[cfg(debug_assertions)]
    const N: usize = 256;
    #[cfg(not(debug_assertions))]
    const N: usize = 1024;

    #[test]
    fn harris_imagecl_beats_opencv_everywhere() {
        // Paper: speedups 3.15 / 2.11 / 4.57 / 1.08 vs OpenCV on Harris.
        for dev in [&AMD_7970, &GTX_960, &K40, &INTEL_I7] {
            let s = fig6_slowdown(Baseline::OpenCv, &HARRIS_CORNER, dev, N);
            assert!(s > 1.0, "{}: OpenCV slowdown {s}", dev.name);
            assert!(s < 12.0, "{}: OpenCV slowdown {s} implausibly large", dev.name);
        }
    }

    #[test]
    fn halide_wins_cpu_conv2d() {
        // Paper §6: ImageCL 4.24x slower than Halide on the CPU conv2d
        // (vectorization + boundary specialization).
        let s = fig6_slowdown(Baseline::Halide, &NONSEP_CONVOLUTION, &INTEL_I7, N);
        assert!(s < 1.0, "Halide should win CPU conv2d, slowdown {s}");
    }

    #[test]
    fn imagecl_wins_k40_conv2d() {
        // Paper §7: image memory gives ImageCL the K40.
        for b in ALL_BASELINES {
            let s = fig6_slowdown(b, &NONSEP_CONVOLUTION, &K40, N);
            assert!(s > 1.0, "K40 conv2d vs {}: slowdown {s}", b.name());
        }
    }

    #[test]
    fn hipacc_never_absurd() {
        for dev in [&AMD_7970, &GTX_960, &K40, &INTEL_I7] {
            for bench in [&SEPARABLE_CONVOLUTION, &NONSEP_CONVOLUTION] {
                let s = fig6_slowdown(Baseline::Hipacc, bench, dev, N);
                assert!(
                    s.is_finite() && s > 0.3 && s < 20.0,
                    "{} {}: {s}",
                    dev.name,
                    bench.id
                );
            }
        }
    }
}
