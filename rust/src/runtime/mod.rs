//! Runtime layer: PJRT client + artifact manifest (the L3↔XLA bridge).
//!
//! Python AOT-compiles the benchmark graphs once (`make artifacts`); this
//! module loads the HLO text, compiles per-device executables and runs
//! them from the rust request path.

pub mod client;
pub mod manifest;

pub use client::{Tensor, XlaRuntime};
pub use manifest::{ArgSig, Artifact, Manifest};

use std::path::PathBuf;

/// Default artifact directory: `<repo>/artifacts` (override with
/// `IMAGECL_ARTIFACTS`).
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(d) = std::env::var("IMAGECL_ARTIFACTS") {
        return PathBuf::from(d);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
