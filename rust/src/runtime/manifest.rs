//! Artifact manifest parsing.
//!
//! `make artifacts` (the Python build path) writes `artifacts/manifest.tsv`
//! describing every AOT-compiled HLO module: which benchmark graph it
//! implements, the grid size, the kernel-variant key, and the argument
//! signature. The rust runtime loads modules by artifact id — Python is
//! never on the request path.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Shape + dtype of one argument, e.g. `512x512:float32`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArgSig {
    pub rows: usize,
    pub cols: usize,
    pub dtype: String,
}

impl ArgSig {
    fn parse(s: &str) -> Result<ArgSig> {
        let (shape, dtype) = s
            .split_once(':')
            .with_context(|| format!("bad arg signature {s:?}"))?;
        let (r, c) = shape
            .split_once('x')
            .with_context(|| format!("bad arg shape {shape:?}"))?;
        Ok(ArgSig {
            rows: r.parse().context("rows")?,
            cols: c.parse().context("cols")?,
            dtype: dtype.to_string(),
        })
    }

    pub fn len(&self) -> usize {
        self.rows * self.cols
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One AOT artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct Artifact {
    pub id: String,
    pub graph: String,
    pub grid_n: usize,
    /// Kernel-variant key (`bh=8 unroll=1 stage=1`).
    pub variant: String,
    pub args: Vec<ArgSig>,
    pub path: PathBuf,
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, Artifact>,
}

impl Manifest {
    /// Load `<dir>/manifest.tsv`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let mut artifacts = BTreeMap::new();
        for (lno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 6 {
                bail!("manifest line {}: expected 6 columns, got {}", lno + 1, cols.len());
            }
            let args = cols[4]
                .split(';')
                .filter(|a| !a.is_empty())
                .map(ArgSig::parse)
                .collect::<Result<Vec<_>>>()?;
            let a = Artifact {
                id: cols[0].to_string(),
                graph: cols[1].to_string(),
                grid_n: cols[2].parse().context("grid_n")?,
                variant: cols[3].to_string(),
                args,
                path: dir.join(cols[5]),
            };
            if artifacts.insert(a.id.clone(), a).is_some() {
                bail!("duplicate artifact id on line {}", lno + 1);
            }
        }
        Ok(Manifest { artifacts })
    }

    pub fn get(&self, id: &str) -> Result<&Artifact> {
        self.artifacts
            .get(id)
            .with_context(|| format!("unknown artifact {id:?}"))
    }

    /// All artifacts of one graph at one grid size.
    pub fn variants_of(&self, graph: &str, grid_n: usize) -> Vec<&Artifact> {
        self.artifacts
            .values()
            .filter(|a| a.graph == graph && a.grid_n == grid_n)
            .collect()
    }

    /// Grid sizes available for a graph.
    pub fn sizes_of(&self, graph: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .values()
            .filter(|a| a.graph == graph)
            .map(|a| a.grid_n)
            .collect();
        v.sort();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# artifact_id\tgraph\tgrid_n\tvariant\targs\tfile
conv2d_32_bh8u1s1\tconv2d\t32\tbh=8 unroll=1 stage=1\t32x32:uint8;25x1:float32\tconv2d_32_bh8u1s1.hlo.txt
sobel_32_bh8u1s1\tsobel\t32\tbh=8 unroll=1 stage=1\t32x32:float32\tsobel_32_bh8u1s1.hlo.txt
";

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.get("conv2d_32_bh8u1s1").unwrap();
        assert_eq!(a.graph, "conv2d");
        assert_eq!(a.grid_n, 32);
        assert_eq!(a.args.len(), 2);
        assert_eq!(a.args[0], ArgSig { rows: 32, cols: 32, dtype: "uint8".into() });
        assert_eq!(a.args[1].len(), 25);
        assert_eq!(a.path, Path::new("/tmp/a/conv2d_32_bh8u1s1.hlo.txt"));
    }

    #[test]
    fn queries() {
        let m = Manifest::parse(SAMPLE, Path::new("/x")).unwrap();
        assert_eq!(m.variants_of("conv2d", 32).len(), 1);
        assert_eq!(m.variants_of("conv2d", 64).len(), 0);
        assert_eq!(m.sizes_of("sobel"), vec![32]);
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn bad_lines_rejected() {
        assert!(Manifest::parse("a\tb\tc\n", Path::new("/x")).is_err());
        let dup = format!("{SAMPLE}{}", SAMPLE.lines().nth(1).unwrap());
        assert!(Manifest::parse(&dup, Path::new("/x")).is_err());
    }
}
