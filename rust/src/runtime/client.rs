//! PJRT runtime: load AOT HLO-text artifacts, compile them once on the
//! CPU client, and execute them from the rust request path.
//!
//! This is the Layer-3 ↔ XLA bridge (see /opt/xla-example/load_hlo for the
//! reference wiring). HLO *text* is the interchange format — serialized
//! jax≥0.5 protos are rejected by xla_extension 0.5.1.
//!
//! The bridge is feature-gated in two steps: `--features xla` enables the
//! serve-layer artifact *routing* (and builds against this module's API),
//! while the real PJRT client additionally needs `--features xla-client`
//! plus the `xla` crate in the dependency set. Without `xla-client` a
//! stub with the identical API loads manifests but reports a clear error
//! when execution is attempted, so every other layer (including the
//! artifact routing, which falls back to the interpreter at runtime)
//! builds and tests on machines without the XLA toolchain.

/// A 2-D tensor travelling through the runtime (f32 host representation;
/// uint8 artifacts convert at the boundary).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(rows: usize, cols: usize, data: Vec<f32>) -> Tensor {
        assert_eq!(rows * cols, data.len());
        Tensor { rows, cols, data }
    }

    pub fn zeros(rows: usize, cols: usize) -> Tensor {
        Tensor { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build a row-major tensor from `f(row, col)`. The parameter order is
    /// the same as [`Tensor::new`]'s dimension order (rows first), and is
    /// checked by `tensor_from_fn_layout` below so it cannot silently
    /// regress to `f(col, row)`.
    pub fn from_fn(
        rows: usize,
        cols: usize,
        mut f: impl FnMut(usize, usize) -> f32,
    ) -> Tensor {
        let mut data = Vec::with_capacity(rows * cols);
        for row in 0..rows {
            for col in 0..cols {
                data.push(f(row, col));
            }
        }
        Tensor { rows, cols, data }
    }

    pub fn get(&self, x: usize, y: usize) -> f32 {
        self.data[y * self.cols + x]
    }
}

#[cfg(feature = "xla-client")]
mod pjrt {
    use std::collections::HashMap;
    use std::path::Path;
    use std::time::Instant;

    use anyhow::{bail, Context, Result};

    use super::super::manifest::{Artifact, Manifest};
    use super::Tensor;

    /// The XLA runtime: one PJRT CPU client plus a cache of compiled
    /// executables keyed by artifact id.
    pub struct XlaRuntime {
        client: xla::PjRtClient,
        manifest: Manifest,
        cache: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl XlaRuntime {
        /// Create the CPU client and read the artifact manifest.
        pub fn new(artifact_dir: &Path) -> Result<XlaRuntime> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            let manifest = Manifest::load(artifact_dir)?;
            Ok(XlaRuntime { client, manifest, cache: HashMap::new() })
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile (and cache) an artifact.
        pub fn prepare(&mut self, id: &str) -> Result<()> {
            if self.cache.contains_key(id) {
                return Ok(());
            }
            let art = self.manifest.get(id)?.clone();
            let proto = xla::HloModuleProto::from_text_file(
                art.path.to_str().context("artifact path not UTF-8")?,
            )
            .with_context(|| format!("parsing HLO text {:?}", art.path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {id}"))?;
            self.cache.insert(id.to_string(), exe);
            Ok(())
        }

        /// Execute an artifact on host tensors. Inputs are converted to the
        /// artifact's declared dtypes; outputs come back as f32 tensors.
        pub fn execute(&mut self, id: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
            self.prepare(id)?;
            let art = self.manifest.get(id)?.clone();
            let lits = make_literals(&art, inputs)?;
            let exe = self.cache.get(id).unwrap();
            let result = exe
                .execute::<xla::Literal>(&lits)
                .with_context(|| format!("executing {id}"))?[0][0]
                .to_literal_sync()?;
            read_outputs(result)
        }

        /// Execute and time an artifact: returns (outputs, seconds) using the
        /// best of `reps` runs after one warmup (the auto-tuner's measurement
        /// primitive on the real-CPU path).
        pub fn time(
            &mut self,
            id: &str,
            inputs: &[&Tensor],
            reps: usize,
        ) -> Result<(Vec<Tensor>, f64)> {
            self.prepare(id)?;
            let art = self.manifest.get(id)?.clone();
            let lits = make_literals(&art, inputs)?;
            let exe = self.cache.get(id).unwrap();
            // Warmup.
            let _ = exe.execute::<xla::Literal>(&lits)?;
            let mut best = f64::INFINITY;
            let mut last = None;
            for _ in 0..reps.max(1) {
                let t0 = Instant::now();
                let r = exe.execute::<xla::Literal>(&lits)?;
                let dt = t0.elapsed().as_secs_f64();
                if dt < best {
                    best = dt;
                }
                last = Some(r);
            }
            let result = last.unwrap()[0][0].to_literal_sync()?;
            Ok((read_outputs(result)?, best))
        }
    }

    fn make_literals(art: &Artifact, inputs: &[&Tensor]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != art.args.len() {
            bail!(
                "artifact {} takes {} args, got {}",
                art.id,
                art.args.len(),
                inputs.len()
            );
        }
        let mut lits = Vec::new();
        for (sig, t) in art.args.iter().zip(inputs) {
            if sig.len() != t.data.len() {
                bail!(
                    "artifact {} arg size mismatch: manifest {}x{}, tensor {}x{}",
                    art.id,
                    sig.rows,
                    sig.cols,
                    t.rows,
                    t.cols
                );
            }
            let lit = match sig.dtype.as_str() {
                "float32" => {
                    let l = xla::Literal::vec1(&t.data);
                    if sig.cols > 1 || t.cols > 1 {
                        l.reshape(&[sig.rows as i64, sig.cols as i64])?
                    } else {
                        l.reshape(&[sig.rows as i64])?
                    }
                }
                "uint8" => {
                    let bytes: Vec<u8> = t.data.iter().map(|&v| v as u8).collect();
                    let dims: &[usize] = if sig.cols > 1 {
                        &[sig.rows, sig.cols]
                    } else {
                        &[sig.rows]
                    };
                    xla::Literal::create_from_shape_and_untyped_data(
                        xla::ElementType::U8,
                        dims,
                        &bytes,
                    )?
                }
                other => bail!("unsupported dtype {other:?} in manifest"),
            };
            lits.push(lit);
        }
        Ok(lits)
    }

    fn read_outputs(result: xla::Literal) -> Result<Vec<Tensor>> {
        // aot.py lowers with return_tuple=True: result is always a tuple.
        let parts = result.to_tuple()?;
        let mut out = Vec::new();
        for lit in parts {
            let shape = lit.array_shape()?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let (rows, cols) = match dims.as_slice() {
                [r, c] => (*r, *c),
                [n] => (*n, 1),
                [] => (1, 1),
                other => bail!("unsupported output rank {other:?}"),
            };
            let data: Vec<f32> = match lit.ty()? {
                xla::ElementType::F32 => lit.to_vec::<f32>()?,
                xla::ElementType::U8 => {
                    lit.to_vec::<u8>()?.into_iter().map(|v| v as f32).collect()
                }
                other => bail!("unsupported output dtype {other:?}"),
            };
            out.push(Tensor::new(rows, cols, data));
        }
        Ok(out)
    }
}

#[cfg(not(feature = "xla-client"))]
mod stub {
    use std::path::Path;

    use anyhow::{bail, Result};

    use super::super::manifest::Manifest;
    use super::Tensor;

    const NO_XLA: &str = "imagecl was built without the `xla-client` feature — \
        real PJRT artifact execution is unavailable (rebuild with \
        `--features xla-client` and the `xla` crate in the dependency set)";

    /// Stub runtime with the same API as the PJRT-backed one: manifests
    /// load and validate, but executing an artifact reports a clear error.
    pub struct XlaRuntime {
        manifest: Manifest,
    }

    impl XlaRuntime {
        pub fn new(artifact_dir: &Path) -> Result<XlaRuntime> {
            let manifest = Manifest::load(artifact_dir)?;
            Ok(XlaRuntime { manifest })
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn platform(&self) -> String {
            "stub (no xla feature)".to_string()
        }

        pub fn prepare(&mut self, id: &str) -> Result<()> {
            let _ = self.manifest.get(id)?;
            bail!("cannot compile artifact {id}: {NO_XLA}");
        }

        pub fn execute(&mut self, id: &str, _inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
            let _ = self.manifest.get(id)?;
            bail!("cannot execute artifact {id}: {NO_XLA}");
        }

        pub fn time(
            &mut self,
            id: &str,
            _inputs: &[&Tensor],
            _reps: usize,
        ) -> Result<(Vec<Tensor>, f64)> {
            let _ = self.manifest.get(id)?;
            bail!("cannot time artifact {id}: {NO_XLA}");
        }
    }
}

#[cfg(feature = "xla-client")]
pub use pjrt::XlaRuntime;
#[cfg(not(feature = "xla-client"))]
pub use stub::XlaRuntime;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_from_fn_layout() {
        // f receives (row, col); storage is row-major.
        let t = Tensor::from_fn(2, 3, |row, col| (row * 10 + col) as f32);
        assert_eq!(t.data, vec![0.0, 1.0, 2.0, 10.0, 11.0, 12.0]);
        // get() is (x, y) = (col, row).
        assert_eq!(t.get(2, 1), 12.0);
    }

    #[test]
    fn from_fn_agrees_with_get() {
        let t = Tensor::from_fn(4, 7, |row, col| (row * 100 + col) as f32);
        for row in 0..4 {
            for col in 0..7 {
                assert_eq!(t.get(col, row), (row * 100 + col) as f32);
            }
        }
    }
}
