//! Crash-safe filesystem helpers shared by every subsystem that
//! persists state (tunedb journal, bench history, metrics snapshots,
//! serve checkpoints).
//!
//! The one primitive is [`write_atomic`]: write to a sibling temp file,
//! fsync it, then rename into place. A reader (or a restarted process)
//! therefore sees either the complete old file or the complete new one —
//! never a torn half-write — and a kill at any byte offset of the writer
//! loses at most the update in flight.

use std::io::Write as _;
use std::path::{Path, PathBuf};

/// Sibling temp path for an atomic replace of `path`: same directory
/// (rename must not cross filesystems), extension `<ext>.tmp`.
fn temp_sibling(path: &Path) -> PathBuf {
    let mut name = path.file_name().unwrap_or_default().to_os_string();
    name.push(".tmp");
    path.with_file_name(name)
}

/// Atomically replace `path` with `bytes`: write a sibling temp file,
/// fsync it, rename over the target. Creates parent directories. On any
/// error the target is untouched (the temp file is cleaned up best
/// effort).
pub fn write_atomic(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let tmp = temp_sibling(path);
    let result = (|| {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        // Data must be on disk before the rename publishes it, or a
        // power cut could leave a renamed-but-empty file.
        f.sync_all()?;
        std::fs::rename(&tmp, path)?;
        sync_dir(path);
        Ok(())
    })();
    if result.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    result
}

/// Best-effort fsync of `path`'s parent directory, making a completed
/// rename durable across a crash. Failure is ignored: directory fsync is
/// not supported on every platform/filesystem, and the rename itself has
/// already succeeded.
pub fn sync_dir(path: &Path) {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            if let Ok(d) = std::fs::File::open(dir) {
                let _ = d.sync_all();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("imagecl_fsutil_{tag}_{}", std::process::id()))
    }

    #[test]
    fn write_atomic_creates_and_replaces() {
        let path = temp_path("replace");
        let _ = std::fs::remove_file(&path);
        write_atomic(&path, b"first").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"first");
        write_atomic(&path, b"second, longer payload").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second, longer payload");
        // No temp file left behind.
        assert!(!temp_sibling(&path).exists());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn write_atomic_creates_parent_dirs() {
        let dir = temp_path("nested_dir");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("a/b/out.json");
        write_atomic(&path, b"{}").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"{}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
