//! Lowering: (analyzed kernel, tuning config) → one candidate
//! implementation ([`KernelPlan`]).
//!
//! This implements the paper's §5.1–§5.2 transformations:
//!
//! * flat logical thread space → OpenCL NDRange with the configured
//!   work-group size;
//! * thread coarsening as for-loops around the kernel body (§5.2.2);
//! * blocked / interleaved / group-interleaved thread mapping (§5.2.3,
//!   Figure 4 a–c);
//! * `Image` 2-D accesses → 1-D global accesses, texture intrinsics or
//!   local-memory staging (+ cooperative load and barrier, Figure 5);
//! * boundary-condition code (clamped / constant, Figure 3);
//! * loop unrolling (§5.2.5, applied before index rewriting).

use crate::analysis::{KernelInfo, Stencil};
use crate::imagecl::ast::*;
use crate::imagecl::{BoundaryCond, Forced, GridSpec};

use super::clir::*;
use super::config::{MemSpace, TuningConfig};
use super::unroll;

/// Transformation error.
#[derive(Debug, thiserror::Error)]
pub enum TransformError {
    /// The configuration requests an optimization the kernel is not
    /// eligible for (the tuner's space enumeration prevents this; direct
    /// CLI users can hit it).
    #[error("invalid configuration: {0}")]
    InvalidConfig(String),
    #[error("unsupported: {0}")]
    Unsupported(String),
}

/// Apply `force(...)` directives, overriding the tuner's choices
/// (paper §5: directives can "force optimizations on or off").
pub fn effective_config(info: &KernelInfo, config: &TuningConfig) -> TuningConfig {
    let mut cfg = config.clone();
    for (arr, f) in &info.prog.force_image_mem {
        match f {
            Forced::On => {
                cfg.image_mem.insert(arr.clone(), true);
            }
            Forced::Off => {
                cfg.image_mem.insert(arr.clone(), false);
            }
            Forced::Tunable => {}
        }
    }
    for (arr, f) in &info.prog.force_constant_mem {
        match f {
            Forced::On => {
                cfg.constant_mem.insert(arr.clone(), true);
            }
            Forced::Off => {
                cfg.constant_mem.insert(arr.clone(), false);
            }
            Forced::Tunable => {}
        }
    }
    for (arr, f) in &info.prog.force_local_mem {
        match f {
            Forced::On => {
                cfg.local_mem.insert(arr.clone(), true);
            }
            Forced::Off => {
                cfg.local_mem.insert(arr.clone(), false);
            }
            Forced::Tunable => {}
        }
    }
    match info.prog.force_interleaved {
        Forced::On => cfg.interleaved = true,
        Forced::Off => cfg.interleaved = false,
        Forced::Tunable => {}
    }
    cfg
}

/// Lower an analyzed kernel under a tuning configuration.
pub fn lower(info: &KernelInfo, config: &TuningConfig) -> Result<KernelPlan, TransformError> {
    let cfg = effective_config(info, config);
    let kernel = &info.prog.kernel;

    // -- validation ---------------------------------------------------
    if cfg.wg[0] == 0 || cfg.wg[1] == 0 || cfg.coarsen[0] == 0 || cfg.coarsen[1] == 0 {
        return Err(TransformError::InvalidConfig(
            "work-group and coarsening sizes must be positive".into(),
        ));
    }
    let mut uses_idz = false;
    kernel.walk_exprs(&mut |e| {
        if matches!(e, Expr::Ident(n) if n == "idz") {
            uses_idz = true;
        }
        if matches!(e, Expr::Index { indices, .. } if indices.len() == 3) {
            uses_idz = true;
        }
    });
    if uses_idz {
        return Err(TransformError::Unsupported(
            "3-D kernels are not supported by this lowering yet".into(),
        ));
    }
    for (arr, &on) in &cfg.local_mem {
        if on && !info.local_mem_eligible(arr) {
            return Err(TransformError::InvalidConfig(format!(
                "local memory requested for `{arr}` which is not eligible \
                 (must be a read-only Image with a compile-time stencil)"
            )));
        }
    }
    for (arr, &on) in &cfg.image_mem {
        if on && !info.image_mem_eligible(arr) {
            return Err(TransformError::InvalidConfig(format!(
                "image memory requested for `{arr}` which is not read-only or write-only"
            )));
        }
    }
    for (arr, &on) in &cfg.constant_mem {
        if on && !info.constant_mem_eligible(arr, usize::MAX) {
            return Err(TransformError::InvalidConfig(format!(
                "constant memory requested for `{arr}` which is not a \
                 read-only array with a known size bound"
            )));
        }
    }

    let grid_image = match &info.prog.grid {
        GridSpec::FromImage(name) => Some(name.clone()),
        GridSpec::Explicit(_) => None,
    };

    // -- source-level unrolling ----------------------------------------
    let body = unroll::apply(&kernel.body, &info.env, &cfg.unroll);

    // -- buffer & scalar parameter lists --------------------------------
    let mut buffers = Vec::new();
    let mut scalars = Vec::new();
    for p in &kernel.params {
        match &p.ty {
            Type::Image { elem, dims } => {
                buffers.push(BufferParam {
                    name: p.name.clone(),
                    elem: *elem,
                    space: cfg.space_of(&p.name),
                    access: info.access(&p.name),
                    image_dims: Some(*dims),
                });
                scalars.push((format!("{}_w", p.name), ScalarType::I32));
                scalars.push((format!("{}_h", p.name), ScalarType::I32));
            }
            Type::Array { elem } => {
                buffers.push(BufferParam {
                    name: p.name.clone(),
                    elem: *elem,
                    space: cfg.space_of(&p.name),
                    access: info.access(&p.name),
                    image_dims: None,
                });
                scalars.push((format!("{}_n", p.name), ScalarType::I32));
            }
            Type::Scalar(s) => scalars.push((p.name.clone(), *s)),
        }
    }
    scalars.push((GRID_W.to_string(), ScalarType::I32));
    scalars.push((GRID_H.to_string(), ScalarType::I32));

    // -- local staging arrays -------------------------------------------
    let mut locals = Vec::new();
    let tile = cfg.group_tile();
    for (arr, &on) in &cfg.local_mem {
        if !on {
            continue;
        }
        let st = info.read_stencil(arr).expect("eligibility checked above");
        let tile_w = tile[0] + st.extent_x() as usize;
        let tile_h = tile[1] + st.extent_y() as usize;
        let elem = kernel.param(arr).unwrap().ty.elem();
        locals.push(LocalArray {
            name: format!("__loc_{arr}"),
            elem,
            len: tile_w * tile_h,
            tile_w,
            tile_h,
            stages: arr.clone(),
        });
    }
    locals.sort_by(|a, b| a.name.cmp(&b.name));

    let lowerer = Lowerer { info, cfg: &cfg, grid_image, locals: &locals };

    // -- compute phase ----------------------------------------------------
    let rewritten = lowerer.rewrite_stmts(&body)?;
    let compute = lowerer.wrap_mapping(rewritten);

    // -- staging phase (local memory) --------------------------------------
    let mut phases = Vec::new();
    if !locals.is_empty() {
        phases.push(lowerer.staging_phase());
        phases.push(compute);
    } else {
        phases.push(compute);
    }

    // Work-item independence proof (drives the VM's parallel NDRange
    // dispatch *and* its batched row interpretation): every buffer must
    // be either never written, or write-only with all writes at elements
    // the work-item provably owns — its own grid point, or an affine
    // strided pattern (`a[idx * 2 + 1]`-style) whose offsets never
    // collide across threads (`analysis::rw::disjoint_writes`). 1-D
    // arrays are only owned under a statically 1-D grid — with a 2-D
    // grid, threads that differ only in `idy` share every `a[f(idx)]`
    // element.
    let disjoint = crate::analysis::rw::disjoint_writes(kernel, &info.env);
    let grid_is_1d = matches!(&info.prog.grid, GridSpec::Explicit(dims) if dims.get(1) == Some(&1));
    let parallel_groups = buffers.iter().all(|b| match b.access {
        crate::analysis::Access::Unused | crate::analysis::Access::ReadOnly => true,
        crate::analysis::Access::WriteOnly => {
            disjoint.get(&b.name).copied().unwrap_or(false)
                && (b.image_dims.is_some() || grid_is_1d)
        }
        crate::analysis::Access::ReadWrite => false,
    });
    // The proof above is per work-item, so item-level batching is safe
    // exactly when group-level parallelism is; row-granular partitioning
    // additionally needs barrier-free single-phase plans (no `__local`
    // group state to share, no phase fence to respect).
    let batchable = parallel_groups;
    let row_parallel = parallel_groups && phases.len() == 1 && locals.is_empty();

    Ok(KernelPlan {
        name: kernel.name.clone(),
        config: cfg,
        grid: info.prog.grid.clone(),
        buffers,
        scalars,
        locals,
        phases,
        parallel_groups,
        batchable,
        row_parallel,
    })
}

struct Lowerer<'a> {
    info: &'a KernelInfo,
    cfg: &'a TuningConfig,
    grid_image: Option<String>,
    locals: &'a [LocalArray],
}

impl Lowerer<'_> {
    fn boundary(&self, img: &str) -> BoundaryCond {
        self.info
            .prog
            .boundary
            .get(img)
            .copied()
            .unwrap_or_default()
    }

    fn elem_of(&self, arr: &str) -> ScalarType {
        self.info.prog.kernel.param(arr).unwrap().ty.elem()
    }

    fn local_for(&self, img: &str) -> Option<&LocalArray> {
        self.locals.iter().find(|l| l.stages == img)
    }

    /// The constant used for the constant boundary condition, typed.
    fn bc_const(&self, elem: ScalarType, v: f64) -> Expr {
        if elem.is_float() {
            Expr::FloatLit(v)
        } else {
            Expr::IntLit(v as i64)
        }
    }

    /// Is this 2-D access exactly the thread's own pixel of the grid image
    /// (no offsets)? Then it cannot be out of bounds (grid guard already
    /// holds) and boundary code is skipped.
    fn is_exact_grid_point(&self, img: &str, ex: &Expr, ey: &Expr) -> bool {
        self.grid_image.as_deref() == Some(img)
            && *ex == Expr::ident("idx")
            && *ey == Expr::ident("idy")
    }

    /// clamp(v, 0, hi) with integer min/max.
    fn clamp0(v: Expr, hi: Expr) -> Expr {
        Expr::call("max", vec![Expr::call("min", vec![v, hi]), Expr::int(0)])
    }

    /// `0 <= ex < w && 0 <= ey < h`
    fn inside(ex: &Expr, ey: &Expr, w: &Expr, h: &Expr) -> Expr {
        let ge0 = |e: &Expr| Expr::bin(BinOp::Ge, e.clone(), Expr::int(0));
        let lt = |e: &Expr, b: &Expr| Expr::bin(BinOp::Lt, e.clone(), b.clone());
        Expr::bin(
            BinOp::And,
            Expr::bin(BinOp::And, ge0(ex), lt(ex, w)),
            Expr::bin(BinOp::And, ge0(ey), lt(ey, h)),
        )
    }

    /// Build the read of image `img` at coordinates (ex, ey), applying the
    /// configured memory space and the image's boundary condition.
    fn image_load(&self, img: &str, ex: Expr, ey: Expr) -> Result<Expr, TransformError> {
        let w = Expr::ident(&format!("{img}_w"));
        let h = Expr::ident(&format!("{img}_h"));
        let elem = self.elem_of(img);

        // Local staging: rewrite to the local array; boundary handling
        // happened at staging time.
        if let Some(loc) = self.local_for(img) {
            let st = self.info.read_stencil(img).unwrap();
            // Group pixel origin (declared in the compute phase prologue).
            let gox = Expr::ident("__gox");
            let goy = Expr::ident("__goy");
            // lx = ex - (gox + min_dx); ly = ey - (goy + min_dy)
            let lx = Expr::sub(ex, Expr::add(gox, Expr::int(st.min_dx)));
            let ly = Expr::sub(ey, Expr::add(goy, Expr::int(st.min_dy)));
            return Ok(Expr::Index {
                base: loc.name.clone(),
                indices: vec![Expr::add(
                    Expr::mul(ly, Expr::int(loc.tile_w as i64)),
                    lx,
                )],
            });
        }

        let space = self.cfg.space_of(img);
        let exact = self.is_exact_grid_point(img, &ex, &ey);
        let bc = self.boundary(img);

        let load_at = |x: Expr, y: Expr| -> Expr {
            match space {
                MemSpace::Image => {
                    Expr::call(READ_TEX, vec![Expr::ident(img), x, y])
                }
                _ => Expr::Index {
                    base: img.to_string(),
                    indices: vec![Expr::add(Expr::mul(y, w.clone()), x)],
                },
            }
        };

        if exact {
            return Ok(load_at(ex, ey));
        }
        match bc {
            BoundaryCond::Clamped => {
                let xc = Self::clamp0(ex, Expr::sub(w.clone(), Expr::int(1)));
                let yc = Self::clamp0(ey, Expr::sub(h.clone(), Expr::int(1)));
                Ok(load_at(xc, yc))
            }
            BoundaryCond::Constant(c) => Ok(Expr::Ternary {
                cond: Box::new(Self::inside(&ex, &ey, &w, &h)),
                then: Box::new(load_at(ex, ey)),
                els: Box::new(self.bc_const(elem, c)),
            }),
        }
    }

    /// Build the store of `value` to image `img` at (ex, ey). Returns the
    /// statement (possibly guarded).
    fn image_store(
        &self,
        img: &str,
        ex: Expr,
        ey: Expr,
        value: Expr,
    ) -> Result<Stmt, TransformError> {
        let w = Expr::ident(&format!("{img}_w"));
        let h = Expr::ident(&format!("{img}_h"));
        let space = self.cfg.space_of(img);
        let exact = self.is_exact_grid_point(img, &ex, &ey);
        let store = match space {
            MemSpace::Image => Stmt::ExprStmt(Expr::call(
                WRITE_TEX,
                vec![Expr::ident(img), ex.clone(), ey.clone(), value],
            )),
            MemSpace::Local => {
                return Err(TransformError::InvalidConfig(format!(
                    "cannot write to locally staged image `{img}`"
                )))
            }
            _ => Stmt::Assign {
                lhs: LValue::Index {
                    base: img.to_string(),
                    indices: vec![Expr::add(Expr::mul(ey.clone(), w.clone()), ex.clone())],
                },
                op: AssignOp::Set,
                value,
            },
        };
        if exact {
            Ok(store)
        } else {
            Ok(Stmt::If {
                cond: Self::inside(&ex, &ey, &w, &h),
                then: vec![store],
                els: vec![],
            })
        }
    }

    fn rewrite_expr(&self, e: &Expr) -> Result<Expr, TransformError> {
        Ok(match e {
            Expr::Index { base, indices } => {
                let idxs: Result<Vec<Expr>, _> =
                    indices.iter().map(|i| self.rewrite_expr(i)).collect();
                let mut idxs = idxs?;
                match self.info.prog.kernel.param(base).map(|p| &p.ty) {
                    Some(Type::Image { .. }) => {
                        if idxs.len() != 2 {
                            return Err(TransformError::Unsupported(format!(
                                "image `{base}` must use 2-D indexing"
                            )));
                        }
                        let ey = idxs.pop().unwrap();
                        let ex = idxs.pop().unwrap();
                        self.image_load(base, ex, ey)?
                    }
                    _ => Expr::Index { base: base.clone(), indices: idxs },
                }
            }
            Expr::Unary { op, expr } => Expr::Unary {
                op: *op,
                expr: Box::new(self.rewrite_expr(expr)?),
            },
            Expr::Binary { op, lhs, rhs } => Expr::Binary {
                op: *op,
                lhs: Box::new(self.rewrite_expr(lhs)?),
                rhs: Box::new(self.rewrite_expr(rhs)?),
            },
            Expr::Call { name, args } => Expr::Call {
                name: name.clone(),
                args: args
                    .iter()
                    .map(|a| self.rewrite_expr(a))
                    .collect::<Result<_, _>>()?,
            },
            Expr::Ternary { cond, then, els } => Expr::Ternary {
                cond: Box::new(self.rewrite_expr(cond)?),
                then: Box::new(self.rewrite_expr(then)?),
                els: Box::new(self.rewrite_expr(els)?),
            },
            Expr::Cast { ty, expr } => Expr::Cast {
                ty: *ty,
                expr: Box::new(self.rewrite_expr(expr)?),
            },
            other => other.clone(),
        })
    }

    fn rewrite_stmts(&self, stmts: &[Stmt]) -> Result<Vec<Stmt>, TransformError> {
        let mut out = Vec::new();
        for s in stmts {
            match s {
                Stmt::Assign { lhs, op, value } => {
                    let value = self.rewrite_expr(value)?;
                    match lhs {
                        LValue::Index { base, indices }
                            if matches!(
                                self.info.prog.kernel.param(base).map(|p| &p.ty),
                                Some(Type::Image { .. })
                            ) =>
                        {
                            if indices.len() != 2 {
                                return Err(TransformError::Unsupported(format!(
                                    "image `{base}` must use 2-D indexing"
                                )));
                            }
                            let ex = self.rewrite_expr(&indices[0])?;
                            let ey = self.rewrite_expr(&indices[1])?;
                            // Compound assignment: expand to load-modify.
                            let value = match op.binop() {
                                None => value,
                                Some(b) => {
                                    let cur =
                                        self.image_load(base, ex.clone(), ey.clone())?;
                                    Expr::bin(b, cur, value)
                                }
                            };
                            out.push(self.image_store(base, ex, ey, value)?);
                        }
                        LValue::Index { base, indices } => {
                            let idxs: Result<Vec<Expr>, _> =
                                indices.iter().map(|i| self.rewrite_expr(i)).collect();
                            out.push(Stmt::Assign {
                                lhs: LValue::Index { base: base.clone(), indices: idxs? },
                                op: *op,
                                value,
                            });
                        }
                        LValue::Var(v) => out.push(Stmt::Assign {
                            lhs: LValue::Var(v.clone()),
                            op: *op,
                            value,
                        }),
                    }
                }
                Stmt::Decl { ty, name, init } => out.push(Stmt::Decl {
                    ty: *ty,
                    name: name.clone(),
                    init: init.as_ref().map(|e| self.rewrite_expr(e)).transpose()?,
                }),
                Stmt::If { cond, then, els } => out.push(Stmt::If {
                    cond: self.rewrite_expr(cond)?,
                    then: self.rewrite_stmts(then)?,
                    els: self.rewrite_stmts(els)?,
                }),
                Stmt::For { var, init, cond, step, body } => out.push(Stmt::For {
                    var: var.clone(),
                    init: self.rewrite_expr(init)?,
                    cond: self.rewrite_expr(cond)?,
                    step: self.rewrite_expr(step)?,
                    body: self.rewrite_stmts(body)?,
                }),
                Stmt::While { cond, body } => out.push(Stmt::While {
                    cond: self.rewrite_expr(cond)?,
                    body: self.rewrite_stmts(body)?,
                }),
                Stmt::ExprStmt(e) => out.push(Stmt::ExprStmt(self.rewrite_expr(e)?)),
                Stmt::Return | Stmt::Barrier => out.push(s.clone()),
            }
        }
        Ok(out)
    }

    /// Logical-thread index expressions per the thread-mapping parameter
    /// (paper §5.2.3, Figure 4). `dim` 0 = x, 1 = y; `u` is the coarsening
    /// iterator expression for that dimension.
    fn map_index(&self, dim: usize, u: Expr) -> Expr {
        let c = self.cfg.coarsen[dim] as i64;
        let wg = self.cfg.wg[dim] as i64;
        let gid = Expr::ident(if dim == 0 { GID_X } else { GID_Y });
        let lid = Expr::ident(if dim == 0 { LID_X } else { LID_Y });
        let grp = Expr::ident(if dim == 0 { GRP_X } else { GRP_Y });
        let gdim = Expr::ident(if dim == 0 { GDIM_X } else { GDIM_Y });
        let gtile = wg * c;
        if !self.cfg.interleaved {
            // Blocked (Fig 4a): gid * c + u. Equals
            // grp*wg*c + lid*c + u, so a work-group covers one contiguous
            // tile — compatible with local staging as-is.
            Expr::add(Expr::mul(gid, Expr::int(c)), u)
        } else if self.cfg.any_local_mem() {
            // Work-group-local interleaving (Fig 4c): the group still
            // covers a contiguous tile, threads within it interleave.
            Expr::add(
                Expr::add(Expr::mul(grp, Expr::int(gtile)), lid),
                Expr::mul(u, Expr::int(wg)),
            )
        } else {
            // Global interleaving (Fig 4b): stride = total real threads.
            Expr::add(gid, Expr::mul(u, gdim))
        }
    }

    /// Wrap the rewritten body in coarsening loops, thread-index decls and
    /// the grid guard; prepend group-origin decls if local staging is used.
    fn wrap_mapping(&self, body: Vec<Stmt>) -> Vec<Stmt> {
        let [cx, cy] = self.cfg.coarsen;
        let guard = Expr::bin(
            BinOp::And,
            Expr::bin(BinOp::Lt, Expr::ident("idx"), Expr::ident(GRID_W)),
            Expr::bin(BinOp::Lt, Expr::ident("idy"), Expr::ident(GRID_H)),
        );
        let ux: Expr = if cx > 1 { Expr::ident("__u") } else { Expr::int(0) };
        let uy: Expr = if cy > 1 { Expr::ident("__v") } else { Expr::int(0) };
        let mut inner = vec![
            Stmt::Decl {
                ty: ScalarType::I32,
                name: "idx".into(),
                init: Some(self.map_index(0, ux)),
            },
            Stmt::Decl {
                ty: ScalarType::I32,
                name: "idy".into(),
                init: Some(self.map_index(1, uy)),
            },
            Stmt::If { cond: guard, then: body, els: vec![] },
        ];
        if cy > 1 {
            inner = vec![Stmt::For {
                var: "__v".into(),
                init: Expr::int(0),
                cond: Expr::bin(BinOp::Lt, Expr::ident("__v"), Expr::int(cy as i64)),
                step: Expr::int(1),
                body: inner,
            }];
        }
        if cx > 1 {
            inner = vec![Stmt::For {
                var: "__u".into(),
                init: Expr::int(0),
                cond: Expr::bin(BinOp::Lt, Expr::ident("__u"), Expr::int(cx as i64)),
                step: Expr::int(1),
                body: inner,
            }];
        }
        let mut out = self.origin_decls();
        out.extend(inner);
        out
    }

    /// `__gox`/`__goy` — the group's logical-pixel origin, needed by local
    /// staging (both phases).
    fn origin_decls(&self) -> Vec<Stmt> {
        if self.locals.is_empty() {
            return vec![];
        }
        let tile = self.cfg.group_tile();
        vec![
            Stmt::Decl {
                ty: ScalarType::I32,
                name: "__gox".into(),
                init: Some(Expr::mul(Expr::ident(GRP_X), Expr::int(tile[0] as i64))),
            },
            Stmt::Decl {
                ty: ScalarType::I32,
                name: "__goy".into(),
                init: Some(Expr::mul(Expr::ident(GRP_Y), Expr::int(tile[1] as i64))),
            },
        ]
    }

    /// The cooperative local-memory staging phase (paper Figure 5): the
    /// work-group's threads stride over the halo'd tile and load it from
    /// global memory with boundary handling.
    fn staging_phase(&self) -> Vec<Stmt> {
        let mut out = self.origin_decls();
        let wg_threads = self.cfg.wg_threads() as i64;
        out.push(Stmt::Decl {
            ty: ScalarType::I32,
            name: "__t".into(),
            init: Some(Expr::add(
                Expr::mul(Expr::ident(LID_Y), Expr::int(self.cfg.wg[0] as i64)),
                Expr::ident(LID_X),
            )),
        });
        for loc in self.locals {
            let img = &loc.stages;
            let st: Stencil = self.info.read_stencil(img).unwrap();
            let elem = self.elem_of(img);
            let bc = self.boundary(img);
            let w = Expr::ident(&format!("{img}_w"));
            let h = Expr::ident(&format!("{img}_h"));
            // Global coords of tile element (__sx, __sy).
            let gx = Expr::add(
                Expr::add(Expr::ident("__gox"), Expr::int(st.min_dx)),
                Expr::ident("__sx"),
            );
            let gy = Expr::add(
                Expr::add(Expr::ident("__goy"), Expr::int(st.min_dy)),
                Expr::ident("__sy"),
            );
            // Boundary-handled global load (never from texture: staged
            // images are read via the local array; their parameter stays a
            // global pointer).
            let load = match bc {
                BoundaryCond::Clamped => {
                    let xc = Self::clamp0(gx, Expr::sub(w.clone(), Expr::int(1)));
                    let yc = Self::clamp0(gy, Expr::sub(h.clone(), Expr::int(1)));
                    Expr::Index {
                        base: img.clone(),
                        indices: vec![Expr::add(Expr::mul(yc, w.clone()), xc)],
                    }
                }
                BoundaryCond::Constant(c) => Expr::Ternary {
                    cond: Box::new(Self::inside(&gx, &gy, &w, &h)),
                    then: Box::new(Expr::Index {
                        base: img.clone(),
                        indices: vec![Expr::add(
                            Expr::mul(gy.clone(), w.clone()),
                            gx.clone(),
                        )],
                    }),
                    els: Box::new(self.bc_const(elem, c)),
                },
            };
            out.push(Stmt::For {
                var: "__s".into(),
                init: Expr::ident("__t"),
                cond: Expr::bin(
                    BinOp::Lt,
                    Expr::ident("__s"),
                    Expr::int(loc.len as i64),
                ),
                step: Expr::int(wg_threads),
                body: vec![
                    Stmt::Decl {
                        ty: ScalarType::I32,
                        name: "__sx".into(),
                        init: Some(Expr::bin(
                            BinOp::Rem,
                            Expr::ident("__s"),
                            Expr::int(loc.tile_w as i64),
                        )),
                    },
                    Stmt::Decl {
                        ty: ScalarType::I32,
                        name: "__sy".into(),
                        init: Some(Expr::bin(
                            BinOp::Div,
                            Expr::ident("__s"),
                            Expr::int(loc.tile_w as i64),
                        )),
                    },
                    Stmt::Assign {
                        lhs: LValue::Index {
                            base: loc.name.clone(),
                            indices: vec![Expr::ident("__s")],
                        },
                        op: AssignOp::Set,
                        value: load,
                    },
                ],
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::KernelInfo;
    use crate::imagecl::frontend;

    const BLUR: &str = "#pragma imcl grid(in)\n\
        void blur(Image<float> in, Image<float> out) {\n\
          float sum = 0.0f;\n\
          for (int i = -1; i < 2; i++) {\n\
            for (int j = -1; j < 2; j++) { sum += in[idx + i][idy + j]; }\n\
          }\n\
          out[idx][idy] = sum / 9.0f;\n\
        }";

    fn plan(src: &str, cfg: TuningConfig) -> Result<KernelPlan, TransformError> {
        let info = KernelInfo::analyze(frontend(src).unwrap());
        lower(&info, &cfg)
    }

    fn dump(p: &KernelPlan) -> String {
        let mut s = String::new();
        for (i, ph) in p.phases.iter().enumerate() {
            s.push_str(&format!("// phase {i}\n"));
            print_stmts(ph, 0, &mut s);
        }
        s
    }

    #[test]
    fn naive_plan_structure() {
        let p = plan(BLUR, TuningConfig::default()).unwrap();
        assert_eq!(p.phases.len(), 1);
        assert!(p.locals.is_empty());
        let s = dump(&p);
        // Blocked mapping with coarsen 1: idx = __gid_x * 1 + 0.
        assert!(s.contains("int idx = __gid_x * 1 + 0;"), "{s}");
        assert!(s.contains("if (idx < __gw && idy < __gh) {"), "{s}");
        // Boundary (constant-0 default) ternary on the stencil read.
        assert!(s.contains("? in[") && s.contains(": 0.0f)"), "{s}");
        // Exact-point write without guard.
        assert!(s.contains("out[idy * out_w + idx] = sum / 9.0f;"), "{s}");
        // Scalars ABI: in_w,in_h,out_w,out_h,__gw,__gh.
        let names: Vec<&str> = p.scalars.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, ["in_w", "in_h", "out_w", "out_h", "__gw", "__gh"]);
    }

    #[test]
    fn parallel_groups_proof() {
        // blur: read-only input + write-only output at [idx][idy] → groups
        // provably independent (and items batchable / row-partitionable).
        let p = plan(BLUR, TuningConfig::default()).unwrap();
        assert!(p.parallel_groups && p.batchable && p.row_parallel);
        // In-place update (read-write buffer) → serial.
        let p = plan(
            "void k(Image<float> a) { a[idx][idy] = a[idx][idy] * 2.0f; }",
            TuningConfig::default(),
        )
        .unwrap();
        assert!(!p.parallel_groups && !p.batchable && !p.row_parallel);
        // Constant-offset write: still one element per thread → the
        // affine disjointness proof admits it.
        let p = plan(
            "#pragma imcl grid(in)\n\
             void k(Image<float> in, Image<float> out) {\n\
               out[idx + 1][idy] = in[idx][idy];\n\
             }",
            TuningConfig::default(),
        )
        .unwrap();
        assert!(p.parallel_groups);
        // Colliding offsets (thread i+1 hits thread i's pixel) → serial.
        let p = plan(
            "#pragma imcl grid(in)\n\
             void k(Image<float> in, Image<float> out) {\n\
               out[idx][idy] = in[idx][idy];\n\
               out[idx + 1][idy] = in[idx][idy];\n\
             }",
            TuningConfig::default(),
        )
        .unwrap();
        assert!(!p.parallel_groups);
        // 1-D array write at [idx] under a 1-D grid → independent.
        let p = plan(
            "#pragma imcl grid(64, 1)\nvoid k(float* a, float* b) { b[idx] = a[idx]; }",
            TuningConfig::default(),
        )
        .unwrap();
        assert!(p.parallel_groups);
        // Strided upsample-style write (each thread owns a 2-element
        // block) → independent under the scaled-affine proof.
        let p = plan(
            "#pragma imcl grid(64, 1)\n\
             void k(float* a, float* b) {\n\
               b[idx * 2] = a[idx];\n\
               b[idx * 2 + 1] = a[idx];\n\
             }",
            TuningConfig::default(),
        )
        .unwrap();
        assert!(p.parallel_groups);
    }

    #[test]
    fn local_mem_plans_stay_group_parallel_not_row_parallel() {
        let mut cfg = TuningConfig::default();
        cfg.local_mem.insert("in".into(), true);
        let p = plan(BLUR, cfg).unwrap();
        // Two barrier phases + group-shared local scratch: groups can fan
        // out and rows can batch, but a group cannot be split across
        // threads.
        assert!(p.parallel_groups && p.batchable);
        assert!(!p.row_parallel);
    }

    #[test]
    fn coarsened_blocked_mapping() {
        let cfg = TuningConfig { coarsen: [4, 2], ..Default::default() };
        let p = plan(BLUR, cfg).unwrap();
        let s = dump(&p);
        assert!(s.contains("for (int __u = 0; __u < 4; __u += 1) {"), "{s}");
        assert!(s.contains("for (int __v = 0; __v < 2; __v += 1) {"), "{s}");
        assert!(s.contains("int idx = __gid_x * 4 + __u;"), "{s}");
        assert!(s.contains("int idy = __gid_y * 2 + __v;"), "{s}");
    }

    #[test]
    fn interleaved_global_mapping() {
        let cfg = TuningConfig {
            coarsen: [4, 1],
            interleaved: true,
            ..Default::default()
        };
        let p = plan(BLUR, cfg).unwrap();
        let s = dump(&p);
        assert!(s.contains("int idx = __gid_x + __u * __gdim_x;"), "{s}");
    }

    #[test]
    fn local_mem_creates_two_phases() {
        let mut cfg = TuningConfig::default();
        cfg.local_mem.insert("in".into(), true);
        let p = plan(BLUR, cfg).unwrap();
        assert_eq!(p.phases.len(), 2);
        assert_eq!(p.locals.len(), 1);
        let loc = &p.locals[0];
        // 16x16 group, coarsen 1, 3x3 stencil → 18x18 tile.
        assert_eq!((loc.tile_w, loc.tile_h), (18, 18));
        assert_eq!(loc.len, 18 * 18);
        let s = dump(&p);
        assert!(s.contains("__loc_in[__s] ="), "{s}");
        // Compute phase reads from the local array.
        assert!(s.contains("sum += __loc_in["), "{s}");
        // Buffer marked as locally staged.
        assert_eq!(p.buffer("in").unwrap().space, MemSpace::Local);
    }

    #[test]
    fn interleaved_with_local_is_group_local() {
        let mut cfg = TuningConfig { interleaved: true, coarsen: [2, 1], ..Default::default() };
        cfg.local_mem.insert("in".into(), true);
        let p = plan(BLUR, cfg).unwrap();
        let s = dump(&p);
        // Fig 4c: grp*tile + lid + u*wg
        assert!(s.contains("int idx = __grp_x * 32 + __lid_x + __u * 16;"), "{s}");
    }

    #[test]
    fn image_mem_uses_intrinsics() {
        let mut cfg = TuningConfig::default();
        cfg.image_mem.insert("in".into(), true);
        cfg.image_mem.insert("out".into(), true);
        let p = plan(BLUR, cfg).unwrap();
        let s = dump(&p);
        assert!(s.contains("__read_tex(in,"), "{s}");
        assert!(s.contains("__write_tex(out, idx, idy, sum / 9.0f);"), "{s}");
        assert_eq!(p.buffer("in").unwrap().space, MemSpace::Image);
    }

    #[test]
    fn clamped_boundary_uses_min_max() {
        let src = "#pragma imcl grid(in)\n\
            #pragma imcl boundary(in, clamped)\n\
            void k(Image<float> in, Image<float> out) {\n\
              out[idx][idy] = in[idx - 1][idy + 1];\n\
            }";
        let p = plan(src, TuningConfig::default()).unwrap();
        let s = dump(&p);
        assert!(s.contains("max(min(idx - 1, in_w - 1), 0)"), "{s}");
        assert!(!s.contains('?'), "clamped should not emit ternaries: {s}");
    }

    #[test]
    fn ineligible_local_mem_rejected() {
        // `a` is read-write → not eligible.
        let mut cfg = TuningConfig::default();
        cfg.local_mem.insert("a".into(), true);
        let r = plan(
            "void k(Image<float> a) { a[idx][idy] = a[idx][idy] + 1.0f; }",
            cfg,
        );
        assert!(matches!(r, Err(TransformError::InvalidConfig(_))));
    }

    #[test]
    fn forced_on_applies_without_config() {
        let src = "#pragma imcl grid(in)\n\
            #pragma imcl force(image_mem(in), on)\n\
            void k(Image<float> in, Image<float> out) { out[idx][idy] = in[idx][idy]; }";
        let p = plan(src, TuningConfig::default()).unwrap();
        assert_eq!(p.buffer("in").unwrap().space, MemSpace::Image);
    }

    #[test]
    fn unroll_applied_before_lowering() {
        let mut cfg = TuningConfig::default();
        cfg.unroll.insert(1, 0);
        cfg.unroll.insert(2, 0);
        let p = plan(BLUR, cfg).unwrap();
        let s = dump(&p);
        assert!(!s.contains("for (int i"), "{s}");
        assert!(!s.contains("for (int j"), "{s}");
        // 9 unrolled reads.
        assert_eq!(s.matches("sum +=").count(), 9, "{s}");
    }

    #[test]
    fn compound_image_assign_expands() {
        let src = "void k(Image<float> a) { a[idx][idy] += 2.0f; }";
        let p = plan(src, TuningConfig::default()).unwrap();
        let s = dump(&p);
        assert!(
            s.contains("a[idy * a_w + idx] = a[idy * a_w + idx] + 2.0f;"),
            "{s}"
        );
    }

    #[test]
    fn explicit_grid_plan() {
        let src = "#pragma imcl grid(256, 1)\nvoid k(float* a, float* b) { b[idx] = a[idx] * 2.0f; }";
        let cfg = TuningConfig { wg: [64, 1], ..Default::default() };
        let p = plan(src, cfg).unwrap();
        let names: Vec<&str> = p.scalars.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, ["a_n", "b_n", "__gw", "__gh"]);
        let (global, wg) = p.launch_dims(256, 1);
        assert_eq!(global, [256, 1]);
        assert_eq!(wg, [64, 1]);
    }
}
