//! Loop unrolling (paper §5.2.5).
//!
//! Applied at the source level, before index lowering. A loop with a
//! compile-time-constant range can be:
//!
//! * fully unrolled (factor 0, the paper's tables' "1" flag): the loop is
//!   replaced by one copy of its body per iteration value, with the
//!   induction variable substituted by the constant;
//! * partially unrolled by factor *k* (only when the trip count is
//!   divisible by *k*; otherwise we conservatively unroll fully — the
//!   remainder-loop variant would change no observable behaviour but adds
//!   untested codegen surface).

use std::collections::BTreeMap;

use crate::analysis::ConstEnv;
use crate::imagecl::ast::*;

/// Substitute every use of `var` by the integer constant `value`.
pub fn subst_var(stmts: &[Stmt], var: &str, value: i64) -> Vec<Stmt> {
    fn subst_expr(e: &Expr, var: &str, value: i64) -> Expr {
        e.clone().map(|e| match e {
            Expr::Ident(ref n) if n == var => Expr::IntLit(value),
            other => other,
        })
    }
    stmts
        .iter()
        .map(|s| match s {
            Stmt::Decl { ty, name, init } => Stmt::Decl {
                ty: *ty,
                name: name.clone(),
                init: init.as_ref().map(|e| subst_expr(e, var, value)),
            },
            Stmt::Assign { lhs, op, value: v } => Stmt::Assign {
                lhs: match lhs {
                    LValue::Var(n) => LValue::Var(n.clone()),
                    LValue::Index { base, indices } => LValue::Index {
                        base: base.clone(),
                        indices: indices.iter().map(|i| subst_expr(i, var, value)).collect(),
                    },
                },
                op: *op,
                value: subst_expr(v, var, value),
            },
            Stmt::If { cond, then, els } => Stmt::If {
                cond: subst_expr(cond, var, value),
                then: subst_var(then, var, value),
                els: subst_var(els, var, value),
            },
            Stmt::For { var: v2, init, cond, step, body } => Stmt::For {
                var: v2.clone(),
                init: subst_expr(init, var, value),
                cond: subst_expr(cond, var, value),
                step: subst_expr(step, var, value),
                // Shadowing is impossible (sema rejects it), substitute on.
                body: subst_var(body, var, value),
            },
            Stmt::While { cond, body } => Stmt::While {
                cond: subst_expr(cond, var, value),
                body: subst_var(body, var, value),
            },
            Stmt::ExprStmt(e) => Stmt::ExprStmt(subst_expr(e, var, value)),
            other => other.clone(),
        })
        .collect()
}

/// Apply the unroll configuration to a statement list. `factors` maps the
/// 1-based pre-order loop id to its factor (0 = full, 1 = none, k =
/// partial). Loop ids must match [`crate::analysis::loops::collect`].
pub fn apply(
    stmts: &[Stmt],
    env: &ConstEnv,
    factors: &BTreeMap<usize, usize>,
) -> Vec<Stmt> {
    let mut next_id = 1usize;
    rec(stmts, env, factors, &mut next_id)
}

fn rec(
    stmts: &[Stmt],
    env: &ConstEnv,
    factors: &BTreeMap<usize, usize>,
    next_id: &mut usize,
) -> Vec<Stmt> {
    let mut out = Vec::new();
    for s in stmts {
        match s {
            Stmt::For { var, init, cond, step, body } => {
                let id = *next_id;
                *next_id += 1;
                let factor = factors.get(&id).copied().unwrap_or(1);
                let values = env.loop_values_ordered(init, cond, step, var);
                // Recurse first so inner loop ids are assigned in pre-order
                // regardless of what happens to this loop.
                let body = rec(body, env, factors, next_id);
                match (factor, values) {
                    (1, _) | (_, None) => out.push(Stmt::For {
                        var: var.clone(),
                        init: init.clone(),
                        cond: cond.clone(),
                        step: step.clone(),
                        body,
                    }),
                    (0, Some(values)) => {
                        // Full unroll.
                        for v in values {
                            out.extend(subst_var(&body, var, v));
                        }
                    }
                    (k, Some(vals)) => {
                        let stride_ok =
                            vals.len() > 1 && vals[1] > vals[0];
                        if k >= vals.len() || vals.len() % k != 0 || !stride_ok {
                            // Fall back to full unroll (see module docs).
                            for v in vals {
                                out.extend(subst_var(&body, var, v));
                            }
                        } else {
                            // Partial: iterate over chunk starts, emit k
                            // copies per iteration. The iteration values of
                            // a restricted loop are an arithmetic sequence,
                            // so chunk c covers vals[c*k + j].
                            let stride = if vals.len() > 1 { vals[1] - vals[0] } else { 1 };
                            let chunk_var = format!("{var}__c");
                            let mut chunk_body = Vec::new();
                            for j in 0..k {
                                // var = chunk_var + j*stride
                                let val = Expr::add(
                                    Expr::ident(&chunk_var),
                                    Expr::int(j as i64 * stride),
                                );
                                chunk_body.push(Stmt::Decl {
                                    ty: ScalarType::I32,
                                    name: format!("{var}__{j}"),
                                    init: Some(val),
                                });
                                let renamed = rename_var(&body, var, &format!("{var}__{j}"));
                                chunk_body.extend(renamed);
                            }
                            out.push(Stmt::For {
                                var: chunk_var,
                                init: Expr::int(vals[0]),
                                cond: Expr::bin(
                                    BinOp::Le,
                                    Expr::ident(&format!("{var}__c")),
                                    Expr::int(*vals.last().unwrap()),
                                ),
                                step: Expr::int(stride * k as i64),
                                body: chunk_body,
                            });
                        }
                    }
                }
            }
            Stmt::If { cond, then, els } => out.push(Stmt::If {
                cond: cond.clone(),
                then: rec(then, env, factors, next_id),
                els: rec(els, env, factors, next_id),
            }),
            Stmt::While { cond, body } => out.push(Stmt::While {
                cond: cond.clone(),
                body: rec(body, env, factors, next_id),
            }),
            other => out.push(other.clone()),
        }
    }
    out
}

/// Rename a variable (for partial-unroll copies, where each copy needs its
/// own binding of the induction variable).
fn rename_var(stmts: &[Stmt], from: &str, to: &str) -> Vec<Stmt> {
    fn ren(e: &Expr, from: &str, to: &str) -> Expr {
        e.clone().map(|e| match e {
            Expr::Ident(ref n) if n == from => Expr::ident(to),
            other => other,
        })
    }
    stmts
        .iter()
        .map(|s| match s {
            Stmt::Decl { ty, name, init } => Stmt::Decl {
                ty: *ty,
                name: name.clone(),
                init: init.as_ref().map(|e| ren(e, from, to)),
            },
            Stmt::Assign { lhs, op, value } => Stmt::Assign {
                lhs: match lhs {
                    LValue::Var(n) => LValue::Var(n.clone()),
                    LValue::Index { base, indices } => LValue::Index {
                        base: base.clone(),
                        indices: indices.iter().map(|i| ren(i, from, to)).collect(),
                    },
                },
                op: *op,
                value: ren(value, from, to),
            },
            Stmt::If { cond, then, els } => Stmt::If {
                cond: ren(cond, from, to),
                then: rename_var(then, from, to),
                els: rename_var(els, from, to),
            },
            Stmt::For { var, init, cond, step, body } => Stmt::For {
                var: var.clone(),
                init: ren(init, from, to),
                cond: ren(cond, from, to),
                step: ren(step, from, to),
                body: rename_var(body, from, to),
            },
            Stmt::While { cond, body } => Stmt::While {
                cond: ren(cond, from, to),
                body: rename_var(body, from, to),
            },
            Stmt::ExprStmt(e) => Stmt::ExprStmt(ren(e, from, to)),
            other => other.clone(),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::imagecl::Program;

    fn unrolled(src: &str, factors: &[(usize, usize)]) -> Vec<Stmt> {
        let p = Program::parse(src).unwrap();
        let env = ConstEnv::build(&p.kernel);
        apply(
            &p.kernel.body,
            &env,
            &factors.iter().copied().collect::<BTreeMap<_, _>>(),
        )
    }

    #[test]
    fn full_unroll_replaces_loop() {
        let body = unrolled(
            "void k(float* a) { for (int i = 0; i < 3; i++) { a[idx + i] = 0.0f; } }",
            &[(1, 0)],
        );
        assert_eq!(body.len(), 3);
        let mut s = String::new();
        print_stmts(&body, 0, &mut s);
        assert!(s.contains("a[idx + 0] = 0.0f;"));
        assert!(s.contains("a[idx + 2] = 0.0f;"));
        assert!(!s.contains("for"));
    }

    #[test]
    fn no_factor_keeps_loop() {
        let body = unrolled(
            "void k(float* a) { for (int i = 0; i < 3; i++) { a[idx + i] = 0.0f; } }",
            &[],
        );
        assert_eq!(body.len(), 1);
        assert!(matches!(body[0], Stmt::For { .. }));
    }

    #[test]
    fn nested_ids_in_preorder() {
        // Unroll only loop 2 (the inner one).
        let body = unrolled(
            "void k(float* a) {\n\
               for (int i = 0; i < 2; i++) {\n\
                 for (int j = 0; j < 2; j++) { a[idx + i + j] = 0.0f; }\n\
               }\n\
             }",
            &[(2, 0)],
        );
        let mut s = String::new();
        print_stmts(&body, 0, &mut s);
        assert!(s.contains("for (int i = 0;"));
        assert!(!s.contains("for (int j"));
        assert!(s.contains("a[idx + i + 0] = 0.0f;"));
        assert!(s.contains("a[idx + i + 1] = 0.0f;"));
    }

    #[test]
    fn partial_unroll_divisible() {
        let body = unrolled(
            "void k(float* a) { for (int i = 0; i < 4; i++) { a[idx + i] = 0.0f; } }",
            &[(1, 2)],
        );
        assert_eq!(body.len(), 1);
        let mut s = String::new();
        print_stmts(&body, 0, &mut s);
        // Chunked loop with 2 copies per iteration.
        assert!(s.contains("for (int i__c = 0;"), "{s}");
        assert!(s.contains("int i__0 = i__c + 0;"), "{s}");
        assert!(s.contains("int i__1 = i__c + 1;"), "{s}");
        assert!(s.contains("a[idx + i__0] = 0.0f;"), "{s}");
    }

    #[test]
    fn partial_unroll_non_divisible_falls_back_to_full() {
        let body = unrolled(
            "void k(float* a) { for (int i = 0; i < 5; i++) { a[idx + i] = 0.0f; } }",
            &[(1, 2)],
        );
        assert_eq!(body.len(), 5);
    }

    #[test]
    fn runtime_loop_untouched() {
        let body = unrolled(
            "void k(float* a, int n) { for (int i = 0; i < n; i++) { a[idx + i] = 0.0f; } }",
            &[(1, 0)],
        );
        assert!(matches!(body[0], Stmt::For { .. }));
    }

    #[test]
    fn negative_range_unroll() {
        let body = unrolled(
            "void k(float* a) { for (int i = -1; i < 2; i++) { a[idx + i] = 0.0f; } }",
            &[(1, 0)],
        );
        assert_eq!(body.len(), 3);
        let mut s = String::new();
        print_stmts(&body, 0, &mut s);
        assert!(s.contains("a[idx + -1] = 0.0f;"));
    }
}
