//! The source-to-source transformation stage (paper §5).
//!
//! From one analyzed ImageCL kernel and a [`TuningConfig`], produce one
//! candidate implementation: a [`clir::KernelPlan`] (executable by
//! [`crate::exec`], renderable as OpenCL C by [`codegen`], and launchable
//! via the generated host code of [`host`]).

pub mod clir;
pub mod codegen;
pub mod config;
pub mod fuse;
pub mod host;
pub mod lower;
pub mod unroll;

pub use clir::{BufferParam, KernelPlan, LocalArray};
pub use codegen::emit_opencl;
pub use config::{FuseMode, MemSpace, TuningConfig};
pub use fuse::{lower_fused, FuseError, FusedKernel};
pub use host::{emit_fast_filter, emit_standalone_host};
pub use lower::{effective_config, lower, TransformError};

use crate::analysis::KernelInfo;
use crate::imagecl::FrontendError;

/// Compilation error: frontend or transform.
#[derive(Debug, thiserror::Error)]
pub enum CompileError {
    #[error(transparent)]
    Frontend(#[from] FrontendError),
    #[error(transparent)]
    Transform(#[from] TransformError),
}

/// One-shot convenience: ImageCL source + config → candidate plan.
pub fn compile(src: &str, cfg: &TuningConfig) -> Result<KernelPlan, CompileError> {
    let info = KernelInfo::analyze(crate::imagecl::frontend(src)?);
    Ok(lower(&info, cfg)?)
}

/// One-shot convenience: ImageCL source + config → OpenCL C text.
pub fn compile_to_opencl(src: &str, cfg: &TuningConfig) -> Result<String, CompileError> {
    Ok(emit_opencl(&compile(src, cfg)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compile_roundtrip() {
        let cl = compile_to_opencl(
            "void k(Image<float> a) { a[idx][idy] = 1.0f; }",
            &TuningConfig::default(),
        )
        .unwrap();
        assert!(cl.contains("__kernel void k("));
    }

    #[test]
    fn compile_propagates_errors() {
        assert!(matches!(
            compile("void", &TuningConfig::default()),
            Err(CompileError::Frontend(_))
        ));
        let mut cfg = TuningConfig::default();
        cfg.local_mem.insert("a".into(), true);
        assert!(matches!(
            compile("void k(Image<float> a) { a[idx][idy] = a[idx][idy] + 1.0f; }", &cfg),
            Err(CompileError::Transform(_))
        ));
    }
}
