//! CLIR — the lowered, OpenCL-shaped kernel plan.
//!
//! A [`KernelPlan`] is one *candidate implementation*: the ImageCL kernel
//! after applying a concrete [`super::TuningConfig`]. It is consumed by
//! two backends that must agree on semantics:
//!
//! * [`crate::transform::codegen`] renders it to OpenCL C text (the
//!   paper's actual output), and
//! * [`crate::exec`] executes it directly, emulating the OpenCL NDRange
//!   model, which is how we *prove* every transformation correct on this
//!   GPU-less testbed.
//!
//! Statements reuse the AST language with reserved identifiers for the
//! OpenCL work-item builtins:
//!
//! | ident        | OpenCL                |
//! |--------------|-----------------------|
//! | `__gid_x/y`  | `get_global_id(0/1)`  |
//! | `__lid_x/y`  | `get_local_id(0/1)`   |
//! | `__grp_x/y`  | `get_group_id(0/1)`   |
//! | `__gdim_x/y` | `get_global_size(0/1)`|
//!
//! Texture accesses are the intrinsic calls `__read_tex(img, x, y)` and
//! `__write_tex(img, x, y, v)`.

use crate::analysis::Access;
use crate::imagecl::{GridSpec, ScalarType, Stmt};

pub use super::config::MemSpace;
use super::config::TuningConfig;

/// Work-item builtin identifiers.
pub const GID_X: &str = "__gid_x";
pub const GID_Y: &str = "__gid_y";
pub const LID_X: &str = "__lid_x";
pub const LID_Y: &str = "__lid_y";
pub const GRP_X: &str = "__grp_x";
pub const GRP_Y: &str = "__grp_y";
pub const GDIM_X: &str = "__gdim_x";
pub const GDIM_Y: &str = "__gdim_y";

/// Grid-size scalar parameters added to every plan.
pub const GRID_W: &str = "__gw";
pub const GRID_H: &str = "__gh";

/// Texture intrinsics.
pub const READ_TEX: &str = "__read_tex";
pub const WRITE_TEX: &str = "__write_tex";

/// A buffer parameter of the lowered kernel.
#[derive(Debug, Clone, PartialEq)]
pub struct BufferParam {
    pub name: String,
    pub elem: ScalarType,
    pub space: MemSpace,
    pub access: Access,
    /// `Some(2)` if the source parameter was an `Image` (has w/h scalars).
    pub image_dims: Option<u8>,
}

/// A `__local` staging array (compile-time size — it depends only on the
/// work-group shape, coarsening and stencil, all fixed per config).
#[derive(Debug, Clone, PartialEq)]
pub struct LocalArray {
    pub name: String,
    pub elem: ScalarType,
    pub len: usize,
    /// Staging-tile width (row pitch of the local array).
    pub tile_w: usize,
    pub tile_h: usize,
    /// The global image this array stages.
    pub stages: String,
}

/// One candidate implementation of a kernel.
#[derive(Debug, Clone)]
pub struct KernelPlan {
    pub name: String,
    pub config: TuningConfig,
    pub grid: GridSpec,
    pub buffers: Vec<BufferParam>,
    /// Scalar parameters, in ABI order: per-image `{name}_w`,`{name}_h`;
    /// per-array `{name}_n`; user scalars; `__gw`,`__gh`.
    pub scalars: Vec<(String, ScalarType)>,
    pub locals: Vec<LocalArray>,
    /// Barrier-separated phases. Executing phase *k* for every work-item
    /// of a group before phase *k+1* is exactly OpenCL barrier semantics
    /// for the structured code we generate.
    pub phases: Vec<Vec<Stmt>>,
    /// Work-groups proven independent by the write-set analysis
    /// ([`crate::analysis::rw::disjoint_writes`]): every buffer is either
    /// never written, or write-only with all writes at elements the
    /// work-item provably owns (its own grid point, or an affine strided
    /// pattern like `a[idx * 2 + 1]` whose offsets never collide across
    /// threads). Groups then write disjoint output regions and read
    /// nothing any group writes, so the execution backend may run them
    /// concurrently with bit-identical results. `false` = execute groups
    /// serially.
    pub parallel_groups: bool,
    /// The same disjointness proof, one level finer: individual
    /// *work-items* are independent, so the bytecode VM may execute a
    /// whole row of items per dispatch through its batched (SIMD-lane)
    /// interpreter. Implied by `parallel_groups` today (the proof is
    /// per-item), kept separate so future group-cooperative plans can
    /// stay group-parallel without claiming item independence.
    pub batchable: bool,
    /// Single-phase plans with no `__local` scratch have no barriers and
    /// no per-group shared state, so the parallel NDRange driver may
    /// partition work at work-item-row granularity (finer than whole
    /// groups) when there are too few groups to feed the thread pool.
    pub row_parallel: bool,
}

impl KernelPlan {
    pub fn buffer(&self, name: &str) -> Option<&BufferParam> {
        self.buffers.iter().find(|b| b.name == name)
    }

    pub fn local(&self, name: &str) -> Option<&LocalArray> {
        self.locals.iter().find(|l| l.name == name)
    }

    /// Number of *real* threads needed per dimension for a `gw`×`gh`
    /// logical grid (before work-group rounding): ceil(grid / coarsen).
    pub fn real_threads(&self, gw: usize, gh: usize) -> [usize; 2] {
        let c = &self.config.coarsen;
        [gw.div_ceil(c[0]), gh.div_ceil(c[1])]
    }

    /// NDRange launch dimensions: global size (rounded up to work-group
    /// multiples) and work-group size.
    pub fn launch_dims(&self, gw: usize, gh: usize) -> ([usize; 2], [usize; 2]) {
        let rt = self.real_threads(gw, gh);
        let wg = self.config.wg;
        (
            [rt[0].div_ceil(wg[0]) * wg[0], rt[1].div_ceil(wg[1]) * wg[1]],
            wg,
        )
    }

    /// Total local memory bytes used by this plan (device occupancy input).
    pub fn local_mem_bytes(&self) -> usize {
        self.locals.iter().map(|l| l.len * l.elem.size_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan_with(wg: [usize; 2], coarsen: [usize; 2]) -> KernelPlan {
        KernelPlan {
            name: "k".into(),
            config: TuningConfig { wg, coarsen, ..Default::default() },
            grid: GridSpec::Explicit(vec![100, 60]),
            buffers: vec![],
            scalars: vec![],
            locals: vec![],
            phases: vec![vec![]],
            parallel_groups: false,
            batchable: false,
            row_parallel: false,
        }
    }

    #[test]
    fn launch_dims_round_up() {
        let p = plan_with([16, 16], [1, 1]);
        let (global, wg) = p.launch_dims(100, 60);
        assert_eq!(global, [112, 64]);
        assert_eq!(wg, [16, 16]);
    }

    #[test]
    fn launch_dims_with_coarsening() {
        let p = plan_with([16, 4], [4, 2]);
        // real threads: ceil(100/4)=25, ceil(60/2)=30 → round to (32, 32)
        assert_eq!(p.real_threads(100, 60), [25, 30]);
        let (global, _) = p.launch_dims(100, 60);
        assert_eq!(global, [32, 32]);
    }

    #[test]
    fn local_mem_bytes() {
        let mut p = plan_with([16, 16], [1, 1]);
        p.locals.push(LocalArray {
            name: "__loc_in".into(),
            elem: ScalarType::F32,
            len: 18 * 18,
            tile_w: 18,
            tile_h: 18,
            stages: "in".into(),
        });
        assert_eq!(p.local_mem_bytes(), 18 * 18 * 4);
    }
}
